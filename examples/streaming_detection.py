"""Streaming anomaly detection with drift — the paper's Challenge 1, at
device speed.

    PYTHONPATH=src python examples/streaming_detection.py

A high-rate stream whose distribution drifts over time, with periodic
burst anomalies.  Ingest runs through ``repro.stream.StreamRunner``: T
batches stack into one chunk and ONE donated-state ``lax.scan`` device
program hashes → scores → thresholds → masked-inserts every batch, so the
host touches the device once per T batches (the stacked feed + the chunk
summary) instead of ≥ 2 syncs per batch — the difference between the
sketch running at stream rate and the Python loop being the bottleneck.

Per chunk the summary reports kept fraction, per-step anomaly counts (the
burst detector below just thresholds them) and the top-k most-anomalous
item coordinates, all computed on device.  The sketch updates online with
kept items only.

Part 2 is the SLIDING-WINDOW demo: an abrupt regime shift that a
cumulative ("frozen") sketch never recovers from — its μ/σ keep
describing a regime that stopped arriving, the μ−ασ threshold collapses,
and post-shift bursts sail through undetected — while the
``repro.window`` epoch ring (same runner, same scan program, rotation
INSIDE the donated scan body) ages the stale regime out and catches the
bursts again once the window slides past the shift.
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.pipeline import AceDataFilter
from repro.stream import StreamRunner
from repro.window import WindowedAceFilter

CHUNK_T = 10           # batches per scan chunk (one host round-trip each)
BATCH = 256
STEPS = 60
DIM = 24


def stream_batch(rng, t, poison=False):
    """Drifting inlier cone (mass on the first half of the feature dims);
    burst anomalies live on the OTHER half — angular separation, which is
    what an SRP score sees."""
    half = DIM // 2
    mu = np.zeros(DIM)
    mu[:half] = 4.0 * (1.0 + 0.1 * np.sin(t / 10.0 + np.arange(half)))
    if poison:
        nu = np.zeros(DIM)
        nu[half:] = 6.0
        return np.abs(rng.normal(size=(BATCH, DIM)) * 0.3 + nu)
    return np.abs(rng.normal(size=(BATCH, DIM)) * 0.6 + mu)


def shift_batch(rng, t, shift_t, poison=False):
    """Abrupt regime change: cone A (first half of dims) until shift_t,
    cone B (second quarter) after; bursts on the last quarter of dims
    throughout (identical distribution pre/post — only "normal" moves)."""
    q = DIM // 4
    mu = np.zeros(DIM)
    if t < shift_t:
        mu[:2 * q] = 4.0
    else:
        mu[q:2 * q] = 5.0
    if poison:
        nu = np.zeros(DIM)
        nu[3 * q:] = 6.0
        return np.abs(rng.normal(size=(BATCH, DIM)) * 0.3 + nu)
    return np.abs(rng.normal(size=(BATCH, DIM)) * 0.5 + mu)


def drift_demo():
    """Frozen vs windowed under an abrupt shift (monitor mode: flag but
    insert everything, so both sketches keep seeing the stream)."""
    steps, shift_t = 120, 40
    poison_steps = {t for t in range(steps) if t % 10 == 9}
    common = dict(d_model=DIM, num_bits=12, num_tables=32, alpha=2.5,
                  warmup_items=2048.0, insert_all=True)
    detectors = {
        "frozen  ": AceDataFilter(**common),
        "windowed": WindowedAceFilter(**common, num_epochs=4,
                                      rotate_every=10),
    }
    print(f"\n=== drift demo: regime shift at t={shift_t}, bursts every "
          f"10 steps, window = 4 epochs x 10 steps ===")
    for name, filt in detectors.items():
        rng = np.random.default_rng(1)
        runner = StreamRunner(filt, chunk_T=CHUNK_T)
        state, w = runner.init()
        feat_chunk = jax.jit(jax.vmap(lambda b: filt.features(b[:, None, :])))
        caught_pre = caught_post = missed_pre = missed_post = 0
        for c0 in range(0, steps, CHUNK_T):
            batches = [shift_batch(rng, t, shift_t, t in poison_steps)
                       for t in range(c0, c0 + CHUNK_T)]
            raw = jnp.asarray(np.stack(batches), jnp.float32)
            state, summary = runner.consume(state, w, feat_chunk(raw))
            s = jax.device_get(summary)
            for i, t in enumerate(range(c0, c0 + CHUNK_T)):
                if t not in poison_steps:
                    continue
                hit = int(s.anom_counts[i]) > BATCH // 2
                # give both detectors the window span to re-adapt
                if t < shift_t:
                    caught_pre += hit; missed_pre += not hit
                elif t >= shift_t + 40:
                    caught_post += hit; missed_post += not hit
        print(f"  {name}: bursts pre-shift {caught_pre}/"
              f"{caught_pre + missed_pre}   post-shift (re-adapted) "
              f"{caught_post}/{caught_post + missed_post}   "
              f"(1 trace, {steps // CHUNK_T} host round-trips)")


def main():
    rng = np.random.default_rng(0)
    filt = AceDataFilter(d_model=DIM, num_bits=13, num_tables=40,
                         alpha=3.0, warmup_items=1024.0)
    runner = StreamRunner(filt, chunk_T=CHUNK_T, topk=4)
    state, w = runner.init()
    # (T, B, DIM) raw chunk -> (T, B, DIM+1) features (unit-mean + bias;
    # S=1 sequences) in ONE jitted program — not T per-batch dispatches.
    feat_chunk = jax.jit(jax.vmap(lambda b: filt.features(b[:, None, :])))

    poison_steps = {t for t in range(STEPS) if t % 10 == 9 and t > 20}
    caught, missed, false_pos = 0, 0, 0
    t0 = time.perf_counter()

    for c0 in range(0, STEPS, CHUNK_T):
        batches = [stream_batch(rng, t, t in poison_steps)
                   for t in range(c0, c0 + CHUNK_T)]
        raw = jnp.asarray(np.stack(batches), jnp.float32)  # the ONE feed
        state, summary = runner.consume(state, w, feat_chunk(raw))
        s = jax.device_get(summary)            # the chunk's ONE sync

        for i, t in enumerate(range(c0, c0 + CHUNK_T)):
            flagged = int(s.anom_counts[i]) > BATCH // 2
            if t in poison_steps and flagged:
                caught += 1
            elif t in poison_steps:
                missed += 1
            elif flagged:
                false_pos += 1
        worst = ", ".join(
            f"step {c0 + int(st)} item {int(it)} (margin {m:+.2f})"
            for st, it, m in zip(s.topk_step, s.topk_item, s.topk_margin)
            if np.isfinite(m))
        print(f"chunk t=[{c0:2d},{c0 + CHUNK_T - 1:2d}]  n={s.n:7.0f}  "
              f"kept={s.kept_frac:.3f}  anom/step={s.anom_counts.tolist()}")
        if worst:
            print(f"  most anomalous: {worst}")

    dt = time.perf_counter() - t0
    print(f"\nbursts caught {caught}, missed {missed}, "
          f"clean batches falsely flagged {false_pos}")
    print(f"throughput: {STEPS * BATCH / dt:,.0f} items/s "
          f"({STEPS // CHUNK_T} host round-trips for {STEPS} batches; "
          f"scan program traced {runner.trace_count}x)")
    cfg = filt.ace_cfg
    print(f"sketch memory: {cfg.memory_bytes() / 2**20:.2f} MB; "
          f"stream processed: {STEPS * BATCH} items "
          f"({STEPS * BATCH * DIM * 4 / 2**20:.1f} MB never stored)")

    drift_demo()


if __name__ == "__main__":
    main()
