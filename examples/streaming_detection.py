"""Streaming anomaly detection with drift — the paper's Challenge 1.

    PYTHONPATH=src python examples/streaming_detection.py

A high-rate stream whose distribution drifts over time; a sliding-window
ACE sketch (insert new / delete expired — Eq. 11/12 dynamic updates) keeps
detecting burst anomalies without ever storing the stream.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import AceConfig
from repro.core import sketch as sk

WINDOW = 4096          # sliding window (items)
BATCH = 256
STEPS = 60
DIM = 24


def stream_batch(rng, t, poison=False):
    """Drifting inlier cone (mass on the first half of the feature dims);
    burst anomalies live on the OTHER half — angular separation, which is
    what an SRP score sees."""
    half = DIM // 2
    mu = np.zeros(DIM)
    mu[:half] = 4.0 * (1.0 + 0.3 * np.sin(t / 10.0 + np.arange(half)))
    if poison:
        nu = np.zeros(DIM)
        nu[half:] = 6.0
        return np.abs(rng.normal(size=(BATCH, DIM)) * 0.3 + nu)
    return np.abs(rng.normal(size=(BATCH, DIM)) * 0.6 + mu)


def main():
    rng = np.random.default_rng(0)
    cfg = AceConfig(dim=DIM, num_bits=13, num_tables=40, seed=1)
    state = sk.init(cfg)
    w = sk.make_params(cfg)
    history = []          # host-side ring buffer of batch hashes to expire

    caught, missed, false_pos = 0, 0, 0
    for t in range(STEPS):
        poison = t % 10 == 9 and t > 20
        batch = jnp.asarray(stream_batch(rng, t, poison), jnp.float32)

        # score against the current sketch (rate space: score/n)
        rates = sk.score(state, w, batch, cfg) / max(float(state.n), 1.0)
        mu = sk.mean_rate(state)
        sigma = sk.sigma_welford(state)
        armed = float(state.n) > 1024
        frac_low = float(jnp.mean(
            (rates < mu - 2.0 * sigma).astype(jnp.float32)))
        batch_anomalous = armed and frac_low > 0.5

        if poison and batch_anomalous:
            caught += 1
        elif poison:
            missed += 1
        elif batch_anomalous:
            false_pos += 1

        # sliding window: insert non-anomalous data, expire the oldest
        if not batch_anomalous:
            state = sk.insert(state, w, batch, cfg)
            history.append(batch)
        if len(history) * BATCH > WINDOW:
            state = sk.delete(state, w, history.pop(0), cfg)

        tag = ("POISON " if poison else "       ") + \
            ("FLAGGED" if batch_anomalous else "")
        if poison or batch_anomalous or t % 10 == 0:
            print(f"t={t:3d} n={float(state.n):6.0f} μ_rate={float(mu):6.3f} "
                  f"low-frac={frac_low:.2f} {tag}")

    print(f"\nbursts caught {caught}, missed {missed}, "
          f"clean batches falsely flagged {false_pos}")
    print(f"sketch memory: {cfg.memory_bytes() / 2**20:.2f} MB; "
          f"stream processed: {STEPS * BATCH} items "
          f"({STEPS * BATCH * DIM * 4 / 2**20:.1f} MB never stored)")


if __name__ == "__main__":
    main()
