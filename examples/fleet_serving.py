"""Fleet serving demo: 8 tenants behind ONE guardrail program.

Each tenant is an independent service with its own traffic distribution
(its own embedding cone).  A single multi-tenant ``Guardrail`` hosts all
8 detectors as one ``FleetState`` — every admit call takes the mixed
batch plus tenant ids, hashes once, and scores/thresholds/inserts each
request against its OWN tenant's sketch.

The demo shows the property the tenant axis exists for: when tenant 3's
traffic starts drifting (bursts of off-cone garbage), its own detector
flags the bursts — while the other 7 tenants' thresholds, admit
decisions, and sketch states stay BITWISE identical to a world where
tenant 3 never misbehaved.  One noisy neighbour cannot poison the
fleet.

Run:  PYTHONPATH=src python -m examples.fleet_serving
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.fleet import tenant_view
from repro.serve.engine import Guardrail, GuardrailConfig

T, B_PER, D, SEQ = 8, 4, 24, 3          # 8 tenants, 4 requests each/step
BURSTY = 3                              # the tenant that drifts
WARM_STEPS, LIVE_STEPS = 24, 12
BURST_AT = {2, 5, 8, 11}                # live steps where tenant 3 bursts


def tenant_traffic(rng, base, t, burst=False):
    """(B_PER, SEQ, D) embeddings for tenant t: its own cone, or garbage."""
    if burst:
        return rng.normal(size=(B_PER, SEQ, D)) * 3.0   # off-cone garbage
    return base[t] + rng.normal(size=(B_PER, SEQ, D)) * 0.1


def run_stream(bursts: bool, seed: int = 0):
    """Drive the fleet guardrail over the mixed stream; returns
    (guardrail, per-step admit masks of the live phase)."""
    rng = np.random.default_rng(seed)
    base = rng.normal(size=(T, 1, 1, D)) * 1.0          # tenant cones
    g = Guardrail(GuardrailConfig(
        d_model=D, num_bits=10, num_tables=16, alpha=3.0,
        warmup_items=float(WARM_STEPS * B_PER // 2), num_tenants=T))
    tids = jnp.asarray(np.repeat(np.arange(T), B_PER), jnp.int32)

    def step(burst_now):
        embeds = np.concatenate(
            [tenant_traffic(rng, base, t,
                            burst=(burst_now and t == BURSTY))
             for t in range(T)])
        return g.admit(jnp.asarray(embeds, jnp.float32), tids)

    for _ in range(WARM_STEPS):
        step(False)
    masks = [step(bursts and i in BURST_AT) for i in range(LIVE_STEPS)]
    return g, np.stack(masks)


def main():
    # identical RNG draws in both worlds: the burst replaces tenant 3's
    # draw, every other tenant's stream is literally the same bytes
    g_burst, masks_burst = run_stream(bursts=True)
    g_clean, masks_clean = run_stream(bursts=False)

    tids = np.repeat(np.arange(T), B_PER)
    burst_rows = tids == BURSTY
    caught = sum(int((~masks_burst[i][burst_rows]).sum())
                 for i in BURST_AT)
    total_burst = len(BURST_AT) * B_PER
    neighbour_flags = int((~masks_burst[:, ~burst_rows]).sum())

    print(f"fleet guardrail: {T} tenants, one admit program "
          f"(trace_count={g_burst.trace_count})")
    print(f"tenant {BURSTY} drift bursts flagged: {caught}/{total_burst}")
    print(f"false flags on the other {T - 1} tenants: {neighbour_flags}")

    # isolation: every non-bursty tenant's state is bitwise identical to
    # the clean world — thresholds included
    for t in range(T):
        if t == BURSTY:
            continue
        for a, b in zip(tenant_view(g_burst.state, t),
                        tenant_view(g_clean.state, t)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        masks_burst[:, ~burst_rows], masks_clean[:, ~burst_rows])
    print(f"neighbour isolation: all {T - 1} other tenants' sketches and "
          "admit masks bitwise identical to the burst-free world")

    assert caught >= total_burst * 3 // 4, "bursts largely uncaught"
    assert g_burst.trace_count == 1, "admit retraced"
    print("OK")


if __name__ == "__main__":
    main()
