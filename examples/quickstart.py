"""Quickstart: ACE in five minutes — the paper's Algorithm 1, end to end.

    PYTHONPATH=src python examples/quickstart.py

Builds a sketch over a synthetic benchmark stream, scores queries, applies
the μ−σ decision rule, demonstrates dynamic delete (Eq. 12) and sketch
merging (the multi-pod primitive), and prints the memory receipt.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (AceConfig, AceEstimator, exact_score, mean_mu,
                        merge, sigma_welford)
from repro.core import sketch as sk
from repro.data.synthetic import make_paper_dataset


def main():
    ds = make_paper_dataset("shuttle", n=20_000)
    X = jnp.asarray(ds.x)
    print(f"dataset: {ds.name} n={ds.n} d={ds.dim} "
          f"anomalies={int(ds.y.sum())} ({ds.bytes() / 2**20:.1f} MB raw)")

    # ---- build the sketch at the paper's settings (K=15, L=50, short
    # counters: the 3.2 MB configuration of §3.4) ------------------------
    cfg = AceConfig(dim=ds.dim, num_bits=15, num_tables=50, seed=0,
                    counter_dtype="int16")
    est = AceEstimator(cfg).update(X)
    print(f"sketch: {cfg.memory_bytes() / 2**20:.2f} MB of counters "
          f"(paper §3.4: 3.2 MB) — data/sketch = "
          f"{ds.bytes() / cfg.memory_bytes():.2f} (>>1 at KDD-full scale)")

    # ---- score + decide --------------------------------------------------
    scores = np.asarray(est.score(X))
    mu, sd = scores.mean(), scores.std()
    flagged = scores < mu - sd
    tp = int((flagged & (ds.y == 1)).sum())
    print(f"μ={mu:.1f} σ={sd:.1f}; flagged {int(flagged.sum())} "
          f"({tp}/{int(ds.y.sum())} true anomalies caught)")

    # ---- the estimator is unbiased: compare with the exact statistic ----
    q = X[:5]
    print("exact S(q,D):", np.round(np.asarray(exact_score(q, X, 15)), 2))
    print("ACE  Ŝ(q,D):", np.round(np.asarray(est.score(q)), 2))

    # ---- dynamic updates (paper §3.4.1) ----------------------------------
    before = float(mean_mu(est.state))
    est.remove(X[:1000])
    est.update(X[:1000])
    after = float(mean_mu(est.state))
    print(f"delete+re-insert 1000 rows: μ {before:.3f} -> {after:.3f} "
          f"(exact inverse: {np.isclose(before, after)})")

    # ---- sketches merge (the multi-pod collective is just +) ------------
    half = ds.n // 2
    e1 = AceEstimator(cfg).update(X[:half])
    e2 = AceEstimator(cfg).update(X[half:])
    merged = merge(e1.state, e2.state)
    print("shard-and-merge == bulk build:",
          bool(jnp.all(merged.counts == est.state.counts)))


if __name__ == "__main__":
    main()
