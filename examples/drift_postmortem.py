"""Drift post-mortem: WHICH dimensions (and which tenant) drove the alarm.

    PYTHONPATH=src python examples/drift_postmortem.py

The ACE tier answers "is this item anomalous" at cache-lookup speed; the
first question an operator asks when the anomaly counter jumps is WHY —
which feature dimensions does the flagged traffic differ in, and (in a
multi-tenant fleet) whose traffic is it?  Answering by pulling raw
flagged items off the device reintroduces exactly the per-item host
traffic the chunked runner exists to avoid.

The attribution tier (``repro.attribution``, enabled with
``attr_rows > 0`` on any filter) answers on-device: every chunk, the
runner splits per-coordinate energy into background vs flagged-anomaly
channels, sketches both into signed count-sketch hierarchies riding the
filter state, and drills down on the chunk's DRIFT VECTOR (mean anomaly
energy − mean background energy per coordinate) with the dyadic findHH
recursion — lowered to one fixed-shape ``lax.scan``, inside the same
jitted consume program, reported in the same single summary transfer.

This script stages a post-mortem:

1. a background regime with energy on the low feature dims warms the
   detector;
2. a drifted attack regime appears: flagged rows carry their energy on
   three PLANTED dims the background never uses;
3. the chunk summary's ``hh_coord``/``hh_est`` rows name the planted
   dims — asserted exactly, no device pull beyond the summary;
4. the same traffic through a 4-tenant fleet, attack routed to one
   tenant: ``hh_tenant`` names the offender.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.data.pipeline import AceDataFilter
from repro.fleet.filter import FleetDataFilter
from repro.stream import StreamRunner

CHUNK_T = 8
BATCH = 32
DIM = 24                       # feature dim is DIM + 1 (bias column)
PLANTED = (3, 11, 17)          # the dims the attack regime shifts onto
ATTACK_MAG = 8.0


def background(rng, T=CHUNK_T):
    """Inlier cone: energy on the low third of the dims."""
    x = rng.normal(size=(T, BATCH, DIM + 1)).astype(np.float32) * 0.3
    x[..., : DIM // 3] += 2.0
    return jnp.asarray(x)


def attacked(rng, rows=8):
    """Background chunk with ``rows`` attack rows per step: energy moved
    onto the PLANTED dims (out-of-cone → flagged once armed)."""
    x = np.array(background(rng))
    x[:, :rows, : DIM // 3] = 0.1
    for c in PLANTED:
        x[:, :rows, c] = ATTACK_MAG
    return jnp.asarray(x)


def main():
    rng = np.random.default_rng(0)

    # -- 1. flat post-mortem ------------------------------------------------
    filt = AceDataFilter(d_model=DIM, num_bits=6, num_tables=16,
                         warmup_items=64.0, alpha=3.0,
                         attr_rows=5, attr_bits=8)
    acfg = filt.ace_cfg.attr
    print(f"attribution: {acfg.rows} rows x {acfg.width} cols x "
          f"{acfg.num_levels} levels "
          f"(+{acfg.memory_bytes() / 1024:.0f} KiB on the filter state)")
    runner = StreamRunner(filt, chunk_T=CHUNK_T, topk=len(PLANTED))
    state, w = runner.init()
    for _ in range(4):                                   # warm + arm
        state, summary = runner.consume(state, w, background(rng))

    state, summary = runner.consume(state, w, attacked(rng))
    s = jax.device_get(summary)                          # the ONE pull
    assert runner.trace_count == 1, "attribution must not retrace"

    named = [int(c) for c, v in zip(s.hh_coord, s.hh_valid) if v]
    print(f"\nchunk flagged {int(s.anom_counts.sum())} rows "
          f"(kept_frac {float(s.kept_frac):.2f}); drill-down says the "
          "flagged traffic shifted on:")
    for c, e, v in zip(s.hh_coord, s.hh_est, s.hh_valid):
        if v:
            print(f"  dim {int(c):2d}  drift energy {float(e):+9.2f}")
    missing = set(PLANTED) - set(named)
    assert not missing, f"drill-down missed planted dims: {missing}"
    print(f"all planted dims {sorted(PLANTED)} named.")

    # -- 2. fleet: who is it? ----------------------------------------------
    T = 4
    OFFENDER = 2
    ff = FleetDataFilter(d_model=DIM, num_tenants=T, num_bits=6,
                         num_tables=16, warmup_items=64.0, alpha=3.0,
                         attr_rows=5, attr_bits=8)
    frunner = StreamRunner(ff, chunk_T=CHUNK_T, topk=len(PLANTED))
    fstate, fw = frunner.init()
    tids = jnp.asarray(
        rng.integers(0, T, size=(CHUNK_T, BATCH)), jnp.int32)
    for _ in range(6):                                   # arm every tenant
        fstate, fsum = frunner.consume(fstate, fw, background(rng), tids)

    # attack rows routed to ONE tenant
    feats = attacked(rng)
    tids_attack = np.array(tids)
    tids_attack[:, :8] = OFFENDER
    fstate, fsum = frunner.consume(fstate, fw, feats,
                                   jnp.asarray(tids_attack))
    fs = jax.device_get(fsum)

    print(f"\nfleet of {T}: per-tenant drift L2 ranking "
          f"(top {len(fs.hh_tenant)}):")
    for t, e in zip(fs.hh_tenant, fs.hh_tenant_est):
        print(f"  tenant {int(t)}  ||drift||_2 {float(e):9.2f}")
    assert int(fs.hh_tenant[0]) == OFFENDER, fs.hh_tenant
    fnamed = [int(c) for c, v in zip(fs.hh_coord, fs.hh_valid) if v]
    assert not set(PLANTED) - set(fnamed), fnamed
    print(f"tenant {OFFENDER} named as the offender; same planted dims "
          "recovered from the fleet summary.")


if __name__ == "__main__":
    main()
