"""Batched serving with the ACE request guardrail.

    PYTHONPATH=src python examples/serve_guardrail.py

Serves greedy continuations from a small LM while the guardrail sketches
request-embedding traffic; after warmup, out-of-distribution request
batches are rejected in O(K·L) before the model runs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import Arch
from repro.serve.engine import Guardrail, GuardrailConfig, ServeEngine


def main():
    a = Arch("qwen2_1_5b", reduced=True)
    a.cfg = dataclasses.replace(a.cfg, num_layers=4, d_model=256,
                                num_heads=4, num_kv_heads=2, head_dim=64,
                                d_ff=1024, vocab_size=4096, dtype="float32")
    params, _ = a.init_params(jax.random.PRNGKey(0))

    guard = Guardrail(GuardrailConfig(d_model=a.cfg.d_model, num_bits=8,
                                      warmup_items=64, alpha=3.0))
    engine = ServeEngine(a, s_max=64, guardrail=guard)

    rng = np.random.default_rng(0)
    B, S = 8, 16
    # In-distribution traffic: a few template prompts with 2 of 16 tokens
    # substituted per request (prompt similarity = token OVERLAP; with
    # untrained random embeddings, nearby token *ids* share nothing).
    templates = rng.integers(100, 400, (4, S))
    ood_template = rng.integers(3800, 4096, (S,))

    def _jitter(base):
        toks = base.copy()
        for b in range(toks.shape[0]):
            idx = rng.choice(S, 2, replace=False)
            toks[b, idx] = rng.integers(0, 4096, 2)
        return jnp.asarray(toks, jnp.int32)

    def normal_requests():
        return _jitter(templates[rng.integers(0, 4, B)])

    def weird_requests():
        return _jitter(np.tile(ood_template, (B, 1)))

    # warm traffic
    for i in range(12):
        toks = normal_requests()
        out = engine.generate(params, {"tokens": toks},
                              num_new_tokens=8, prompt_len=S)
    print("served 12 normal batches; guardrail n =",
          float(guard.state.n))

    emb_ok = jnp.take(params["embed"], normal_requests(), axis=0)
    emb_bad = jnp.take(params["embed"], weird_requests(), axis=0)
    admit_ok = guard.admit(emb_ok)
    admit_bad = guard.admit(emb_bad)
    print(f"normal batch admitted: {admit_ok.sum()}/{B}")
    print(f"OOD batch admitted:    {admit_bad.sum()}/{B}")
    print("guardrail cost per request: K·L =",
          guard.ace_cfg.num_bits * guard.ace_cfg.num_tables,
          "hash bits + ", guard.ace_cfg.num_tables, "lookups; memory =",
          f"{guard.ace_cfg.memory_bytes() / 2**20:.2f} MB")


if __name__ == "__main__":
    main()
