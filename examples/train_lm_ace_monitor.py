"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the full substrate — ACE data filter, ACE gradient monitor, checkpointing,
grad accumulation — on CPU.

    PYTHONPATH=src python examples/train_lm_ace_monitor.py \
        [--steps 300] [--arch olmo_1b] [--poison]

``--poison`` injects corrupted batches every 13 steps; watch the
``keep`` column drop on those steps as the ACE filter masks them.
"""
import argparse
import dataclasses

import jax

from repro.data.pipeline import DataStream, StreamConfig
from repro.models.registry import Arch
from repro.train.train_loop import TrainConfig, train


def build_100m(base: str) -> Arch:
    """~100M-param same-family variant of an assigned arch."""
    a = Arch(base, reduced=True)
    a.cfg = dataclasses.replace(
        a.cfg, num_layers=8, d_model=512, num_heads=8, num_kv_heads=4,
        head_dim=64, d_ff=2048, vocab_size=32768, dtype="float32")
    return a


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--poison", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    arch = build_100m(args.arch)
    n_params = arch.param_count()
    print(f"arch={arch.cfg.name} params={n_params / 1e6:.1f}M")

    tcfg = TrainConfig(
        optimizer="adamw", peak_lr=3e-4, warmup_steps=20,
        total_steps=args.steps, microbatches=2,
        use_data_filter=True, use_grad_monitor=True,
        ckpt_dir=args.ckpt, ckpt_interval=100, seed=0)
    scfg = StreamConfig(
        vocab_size=arch.cfg.vocab_size, seq_len=128, global_batch=8,
        seed=0, corrupt_every=13 if args.poison else 0)

    state, history = train(arch, tcfg, DataStream(scfg),
                           num_steps=args.steps, log_every=20)
    losses = [h["loss"] for h in history]
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"over {len(losses)} steps")
    kept = [h.get("filter_keep_frac", 1.0) for h in history]
    anoms = sum(h.get("grad_anomaly", 0.0) for h in history)
    print(f"filter keep-frac: min {min(kept):.2f} mean "
          f"{sum(kept) / len(kept):.3f}; monitor-skipped steps: {anoms:.0f}")
    assert losses[-1] < losses[0], "training must make progress"


if __name__ == "__main__":
    main()
