import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax import (device count locks at first init).

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes; extract memory / cost / collective analyses.

    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo_1b \
        --shape train_4k [--multi-pod | --both-meshes] [--all] [--out DIR]

Per cell:
  1. build the 16×16 ("data","model") mesh — or 2×16×16 ("pod","data",
     "model") for the multi-pod pass,
  2. install logical sharding rules (long-context cells switch the KV cache
     to sequence sharding — context parallelism),
  3. apply the cell policy: optimizer (adafactor ≥ 40B params else adamw),
     grad-accumulation microbatches sized to the activation budget,
     bf16 params for serving cells,
  4. jit-lower the step (train_step / prefill / decode) from
     ShapeDtypeStructs — zero allocation — and ``.compile()``; sharding
     mismatches / compile-OOM / unsupported collectives fail HERE,
  5. record compiled.memory_analysis(), cost_analysis(), and the
     collective-bytes breakdown (repro.dist.hlo_analysis) to
     <out>/<arch>__<shape>__<mesh>.json for §Dry-run / §Roofline.
"""

import argparse
import dataclasses
import gc
import json
import math
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.hlo_analysis import (collective_bytes_by_kind,
                                     while_loop_trip_counts)
from repro.dist.mesh import (fsdp_tree, make_production_mesh, rules_for,
                             sanitize_pspec, sharding_tree_for)
from repro.models import transformer as tf
from repro.models import whisper as wh
from repro.models.common import logical_to_pspec, set_rules
from repro.models.registry import (SHAPES, Arch, all_cells, is_whisper,
                                   wh_abstract)
from repro.train.optim import make_optimizer
from repro.train.train_loop import TrainConfig, init_train_state, \
    make_train_step

ACTIVATION_BUDGET = 3.5e9     # bytes/device of saved layer-boundary carries
BIG_MODEL_PARAMS = 4e10       # adafactor beyond this (no fp32 moment pair)


def _is_logical_axes(x):
    return (isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x))


def _param_logical(arch: Arch):
    if is_whisper(arch.cfg):
        return wh_abstract(arch.cfg)
    return tf.abstract_params(arch.cfg)


def _param_pspecs(arch: Arch, rules):
    _, logical = _param_logical(arch)
    return jax.tree.map(lambda ax: logical_to_pspec(ax, rules), logical,
                        is_leaf=_is_logical_axes)


def _reconcile(spec, shapes):
    """Align a spec tree (may have extra dict keys) with a shapes tree."""
    if shapes is None:
        return None
    if isinstance(shapes, dict):
        return {k: _reconcile(spec[k], v) for k, v in shapes.items()}
    if hasattr(shapes, "_fields"):      # NamedTuple
        return type(shapes)(*(_reconcile(getattr(spec, f), getattr(shapes, f))
                              for f in shapes._fields))
    if isinstance(shapes, (list, tuple)):
        return type(shapes)(_reconcile(s, v) for s, v in zip(spec, shapes))
    return spec


def _replicated_like(tree):
    return jax.tree.map(lambda _: P(), tree)


def _cost_analysis(compiled) -> dict:
    """compiled.cost_analysis() normalised: jax<=0.4 returns [dict]."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost


@dataclasses.dataclass
class CellPolicy:
    optimizer: str
    microbatches: int
    serve_bf16: bool = True


def _per_token_recompute_bytes(cfg, seq_len: int, model_shards: int = 16):
    """Peak live bytes/token while ONE superblock recomputes in backward.

    Rough per-layer-kind model (f32 residuals where the math is f32):
      attn/swa : score rows (S or window) × heads_local × 4 + qkv/mlp temps
      mamba    : the (delta, B, C, xc) xs streams in f32
      rwkv     : the (r, k, v, w) streams in f32
      moe adds : dispatch/combine + (E, C, D) expert slots per token
    """
    total = 0.0
    for pos, kind in enumerate(cfg.block_pattern):
        if kind in ("attn", "swa"):
            span = min(seq_len, cfg.sliding_window or seq_len) \
                if kind == "swa" else seq_len
            h_local = max(cfg.num_heads // model_shards, 1)
            total += span * 4.0 * h_local / 8.0   # chunked/flash factor
            total += 10 * cfg.d_model * 2
        elif kind == "mamba":
            d_inner = cfg.mamba_expand * cfg.d_model
            total += (2 * d_inner + 2 * cfg.mamba_d_state) * 4
            total += 6 * cfg.d_model * 2
        elif kind == "rwkv":
            total += 16 * cfg.d_model * 4
        if cfg.moe_num_experts and \
                pos % cfg.moe_layer_period == cfg.moe_layer_period - 1 \
                and kind != "rwkv":
            cf, K, E = cfg.moe_capacity_factor, cfg.moe_top_k, \
                cfg.moe_num_experts
            ff_local = max(cfg.d_ff // model_shards, 1)
            total += cf * K * (2 * cfg.d_model + ff_local) * 2  # slots
            total += E * cf * K * 4                             # disp/comb
    return total


def cell_policy(arch: Arch, shape, mesh) -> CellPolicy:
    cfg = arch.cfg
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    if shape.kind != "train":
        return CellPolicy(optimizer="adamw", microbatches=1)
    n_params = arch.param_count()
    opt = "adafactor" if n_params > BIG_MODEL_PARAMS else "adamw"
    b_local = max(shape.global_batch // dp, 1)
    n_sb = (cfg.num_layers + cfg.encoder_layers) \
        // max(len(cfg.block_pattern), 1)
    tokens_local = b_local * shape.seq_len
    # carries (whole step) + one superblock's recompute working set (per mb)
    per_tok = (2 * cfg.d_model * max(n_sb, 1)
               + _per_token_recompute_bytes(cfg, shape.seq_len))
    mb = 1
    while tokens_local * per_tok / mb > ACTIVATION_BUDGET and mb < b_local:
        mb *= 2
    while b_local % mb != 0:
        mb *= 2
    mb = min(mb, b_local)
    return CellPolicy(optimizer=opt, microbatches=mb)


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    seconds: float
    error: str | None = None
    memory: dict | None = None
    flops: float | None = None
    bytes_accessed: float | None = None
    collectives: dict | None = None
    params: int | None = None
    active_params: int | None = None
    policy: dict | None = None
    trip_counts: list | None = None
    # scan-corrected totals from the depth-1/depth-2 probe extrapolation
    # (cost_analysis counts while bodies ONCE; probes at unrolled depths 1
    #  and 2 give body = f(2)−f(1), outside = f(1)−body, total = out+R·body)
    corrected: dict | None = None
    probe_error: str | None = None


def _lower_cell(arch: Arch, shape, mesh, rules, long_ctx: bool,
                policy: CellPolicy):
    cfg = arch.cfg
    pshapes, _ = _param_logical(arch)
    param_ps = _param_pspecs(arch, rules)
    # FSDP: params (and, via state_pspecs, optimizer moments) additionally
    # shard over "data"; GSPMD inserts per-layer all-gather/reduce-scatter.
    param_ps = fsdp_tree(param_ps, pshapes, mesh, axis="data")
    param_sh = sharding_tree_for(mesh, param_ps, pshapes)
    in_specs = arch.input_specs(shape)

    def batch_spec(name, leaf):
        if long_ctx:
            return P()
        batch = rules.get("batch")
        if name == "positions":
            return P(None, batch)
        return P(batch) if len(leaf.shape) >= 1 else P()

    batch_sh = {k: NamedSharding(mesh,
                                 sanitize_pspec(batch_spec(k, v),
                                                tuple(v.shape), mesh))
                for k, v in in_specs.items()}

    if shape.kind == "train":
        tcfg = TrainConfig(
            optimizer=policy.optimizer,
            microbatches=policy.microbatches,
            use_data_filter=cfg.input_mode == "tokens" and not is_whisper(cfg),
            use_grad_monitor=True, remat=True)
        state_shapes = jax.eval_shape(
            lambda k: init_train_state(arch, tcfg, k), jax.random.PRNGKey(0))
        opt = make_optimizer(tcfg.optimizer)
        spec_tree = type(state_shapes)(
            params=param_ps,
            opt_state=_reconcile(opt.state_pspecs(param_ps),
                                 state_shapes.opt_state),
            step=P(),
            monitor=_replicated_like(state_shapes.monitor),
            monitor_w=P() if state_shapes.monitor_w is not None else None,
            filter_state=_replicated_like(state_shapes.filter_state),
            filter_w=P() if state_shapes.filter_w is not None else None,
            ef=_replicated_like(state_shapes.ef),
            rng=P())
        state_sh = sharding_tree_for(mesh, spec_tree, state_shapes)
        # ZeRO-2: per-microbatch grads constrained to the FSDP param specs
        # (sanitised against the param shapes) -> reduce-scatter not AR.
        grad_ps = jax.tree.map(
            lambda sh: sh.spec, param_sh,
            is_leaf=lambda x: hasattr(x, "spec"))
        step = make_train_step(arch, tcfg, grad_pspecs=grad_ps,
                               sketch_layout="replicated")
        return jax.jit(step, in_shardings=(state_sh, batch_sh),
                       out_shardings=(state_sh, None),
                       donate_argnums=(0,)).lower(state_shapes, in_specs)

    if shape.kind == "prefill":
        fn = (lambda p, b: arch.prefill(p, b))
        return jax.jit(fn, in_shardings=(param_sh, batch_sh)).lower(
            pshapes, in_specs)

    # decode
    cache_shapes = arch.cache_specs(shape)
    if is_whisper(cfg):
        from repro.models.attention import KVCache
        kv_ax = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
        kv = logical_to_pspec(kv_ax, rules)
        cache_ps = wh.WhisperCache(self_kv=KVCache(kv, kv),
                                   cross_k=kv, cross_v=kv)
    else:
        cache_ps = tf.cache_pspecs(cfg, long_context=long_ctx, rules=rules)
    cache_sh = sharding_tree_for(mesh, cache_ps, cache_shapes)
    pos_spec = arch.decode_pos_spec(shape)
    pos_sh = NamedSharding(mesh, P())
    fn = (lambda p, b, c, pos: arch.decode_step(p, b, c, pos))
    return jax.jit(fn, in_shardings=(param_sh, batch_sh, cache_sh, pos_sh),
                   donate_argnums=(2,)).lower(
        pshapes, in_specs, cache_shapes, pos_spec)


def _probe_arch(arch_name: str, shape, serve: bool, depth_mult: int) -> Arch:
    """Depth-{1,2} fully-unrolled variant for exact cost analysis."""
    a = Arch(arch_name)
    plen = len(a.cfg.block_pattern)
    repl = dict(
        num_layers=plen * depth_mult,
        scan_unroll=max(depth_mult, 1),
        unroll_q_chunks=True,              # exact chunked-attention costs
        time_chunk=max(shape.seq_len, 1),  # single recurrence chunk
    )
    if a.cfg.encoder_layers:
        repl["encoder_layers"] = depth_mult
    if serve:
        repl["param_dtype"] = "bfloat16"
    a.cfg = dataclasses.replace(a.cfg, **repl)
    return a


def probe_costs(arch_name: str, shape_name: str, mesh, rules,
                long_ctx: bool, n_superblocks: int) -> dict:
    """Extrapolated exact totals: {flops, bytes_accessed, collectives}."""
    shape = SHAPES[shape_name]
    serve = shape.kind != "train"
    results = []
    for depth in (1, 2):
        arch = _probe_arch(arch_name, shape, serve, depth)
        policy = CellPolicy(optimizer="adamw", microbatches=1)
        with jax.set_mesh(mesh):
            lowered = _lower_cell(arch, shape, mesh, rules, long_ctx, policy)
            compiled = lowered.compile()
            cost = _cost_analysis(compiled)
            coll = collective_bytes_by_kind(compiled.as_text())
        results.append({"flops": float(cost.get("flops", 0.0)),
                        "bytes": float(cost.get("bytes accessed", 0.0)),
                        "coll": coll})
    f1, f2 = results

    def extrap(v1, v2):
        body = max(v2 - v1, 0.0)
        outside = max(v1 - body, 0.0)
        return outside + n_superblocks * body

    coll_kinds = set(f1["coll"]) | set(f2["coll"])
    coll_kinds.discard("total_bytes")
    coll = {}
    for k in coll_kinds:
        b1 = f1["coll"].get(k, {}).get("bytes", 0)
        b2 = f2["coll"].get(k, {}).get("bytes", 0)
        coll[k] = {"bytes": extrap(b1, b2),
                   "count": int(extrap(
                       f1["coll"].get(k, {}).get("count", 0),
                       f2["coll"].get(k, {}).get("count", 0)))}
    coll["total_bytes"] = sum(v["bytes"] for v in coll.values())
    return {
        "flops": extrap(f1["flops"], f2["flops"]),
        "bytes_accessed": extrap(f1["bytes"], f2["bytes"]),
        "collectives": coll,
        "probe_depth1": f1, "probe_depth2": f2,
    }


def run_cell(arch_name: str, shape_name: str, multi_pod: bool) -> CellResult:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    shape = SHAPES[shape_name]
    arch = Arch(arch_name)
    if shape.kind != "train":
        # serving runs in bf16 weights (production inference convention)
        arch.cfg = dataclasses.replace(arch.cfg, param_dtype="bfloat16")
    long_ctx = shape_name == "long_500k"
    rules = rules_for(mesh, long_context=long_ctx)
    set_rules(rules)
    policy = cell_policy(arch, shape, mesh)
    t0 = time.time()
    try:
        with jax.set_mesh(mesh):
            lowered = _lower_cell(arch, shape, mesh, rules, long_ctx, policy)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            cost = _cost_analysis(compiled)
            hlo = compiled.as_text()
            coll = collective_bytes_by_kind(hlo)
            trips = while_loop_trip_counts(hlo)
            del hlo
        res = CellResult(
            arch=arch_name, shape=shape_name, mesh=mesh_name, ok=True,
            seconds=round(time.time() - t0, 1),
            memory={
                "temp": getattr(mem, "temp_size_in_bytes", None),
                "args": getattr(mem, "argument_size_in_bytes", None),
                "output": getattr(mem, "output_size_in_bytes", None),
                "alias": getattr(mem, "alias_size_in_bytes", None),
                "peak_estimate": (getattr(mem, "temp_size_in_bytes", 0)
                                  + getattr(mem, "argument_size_in_bytes", 0)
                                  - getattr(mem, "alias_size_in_bytes", 0)),
            },
            flops=float(cost.get("flops", -1.0)),
            bytes_accessed=float(cost.get("bytes accessed", -1.0)),
            collectives=coll,
            params=arch.param_count(),
            active_params=arch.active_param_count(),
            policy=dataclasses.asdict(policy),
            trip_counts=trips,
        )
        try:
            n_sb = (arch.cfg.num_layers
                    // max(len(arch.cfg.block_pattern), 1))
            res.corrected = probe_costs(arch_name, shape_name, mesh, rules,
                                        long_ctx, n_sb)
        except Exception as e:  # noqa: BLE001
            res.probe_error = f"{type(e).__name__}: {e}"
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        res = CellResult(arch=arch_name, shape=shape_name, mesh=mesh_name,
                         ok=False, seconds=round(time.time() - t0, 1),
                         error=f"{type(e).__name__}: {e}\n"
                               f"{traceback.format_exc(limit=6)}")
    gc.collect()
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="dryrun_results")
    args = ap.parse_args()

    cells = all_cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    for arch_name, shape_name in cells:
        for mp in meshes:
            tag = f"{arch_name}__{shape_name}__{'2x16x16' if mp else '16x16'}"
            path = f"{args.out}/{tag}.json"
            if os.path.exists(path) and not args.force:
                print(f"[skip existing] {tag}", flush=True)
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            res = run_cell(arch_name, shape_name, mp)
            with open(path, "w") as f:
                json.dump(dataclasses.asdict(res), f, indent=1)
            status = ("OK" if res.ok
                      else "FAIL: " + res.error.splitlines()[0])
            print(f"[dryrun] {tag}: {status} ({res.seconds}s)", flush=True)


if __name__ == "__main__":
    main()
