"""Deprecated shim — mesh construction and sharding rules moved to
``repro.dist.mesh`` (PR: repro.dist subsystem).  Import from there; this
module re-exports for older callers and will be removed.
"""
from repro.dist.mesh import (  # noqa: F401
    apply_fsdp, fsdp_tree, make_debug_mesh, make_production_mesh,
    named_sharding_tree, rules_for, sanitize_pspec, sharding_tree_for,
)
