"""Training launcher: --arch <id> on a data×model mesh (or 1 device).

    PYTHONPATH=src python -m repro.launch.train --arch olmo_1b --reduced \
        --steps 100 [--devices 8 --mesh 4x2] [--ckpt DIR]

On this CPU container use --devices to request fake host devices (set
BEFORE jax initialises).  On a real TPU slice, omit --devices and the
runtime topology is used.
"""
import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh", default=None, help="e.g. 4x2 = data x model")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--no-filter", action="store_true")
    ap.add_argument("--no-monitor", action="store_true")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    from repro.data.pipeline import DataStream, StreamConfig
    from repro.models.common import set_rules
    from repro.models.registry import Arch
    from repro.train.train_loop import TrainConfig, train

    arch = Arch(args.arch, reduced=args.reduced)
    tcfg = TrainConfig(
        optimizer=args.optimizer, peak_lr=args.lr,
        warmup_steps=max(args.steps // 20, 1), total_steps=args.steps,
        microbatches=args.microbatches,
        use_data_filter=not args.no_filter and arch.cfg.input_mode == "tokens",
        use_grad_monitor=not args.no_monitor,
        ckpt_dir=args.ckpt, ckpt_interval=max(args.steps // 5, 10))
    scfg = StreamConfig(vocab_size=arch.cfg.vocab_size, seq_len=args.seq,
                        global_batch=args.batch)

    ctx = None
    if args.mesh:
        from repro.dist.mesh import make_debug_mesh, rules_for
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_debug_mesh(data=d, model=m)
        set_rules(rules_for(mesh))
        ctx = jax.set_mesh(mesh)
        ctx.__enter__()
    try:
        state, hist = train(arch, tcfg, DataStream(scfg),
                            num_steps=args.steps, log_every=10)
    finally:
        if ctx is not None:
            ctx.__exit__(None, None, None)
    print(f"done: step={int(state.step)} "
          f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
