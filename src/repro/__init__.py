"""repro — ACE (Arrays of locality-sensitive Count Estimators) as a
first-class feature of a multi-pod JAX training/serving framework.

Paper: Luo & Shrivastava, "Arrays of (locality-sensitive) Count Estimators
(ACE): High-Speed Anomaly Detection via Cache Lookups", 2017 (cs.DB).
See DESIGN.md / EXPERIMENTS.md at the repo root.
"""
__version__ = "1.0.0"
