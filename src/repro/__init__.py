"""repro — ACE (Arrays of locality-sensitive Count Estimators) as a
first-class feature of a multi-pod JAX training/serving framework.

Paper: Luo & Shrivastava, "Arrays of (locality-sensitive) Count Estimators
(ACE): High-Speed Anomaly Detection via Cache Lookups", 2017 (cs.DB).
See DESIGN.md / EXPERIMENTS.md at the repo root.
"""
__version__ = "1.0.0"

# jax<0.6 compatibility: `jax.set_mesh` (used by the dry-run and the
# sharding tests) landed after the pinned 0.4.x line.  On old jax the Mesh
# object itself is the context manager with the same enter/exit semantics,
# so gate a shim rather than forking every call site.
import jax as _jax

if not hasattr(_jax, "set_mesh"):
    def _set_mesh(mesh):
        return mesh
    _jax.set_mesh = _set_mesh
del _jax
