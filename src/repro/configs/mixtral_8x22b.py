"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768 — 8 experts top-2, SWA.  [arXiv:2401.04088]
SWA => runs long_500k.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral_8x22b",
        num_layers=56,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=32768,
        block_pattern=("swa",),
        sliding_window=4096,
        moe_num_experts=8,
        moe_top_k=2,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral_8x22b_reduced",
        num_layers=4,
        d_model=192,
        num_heads=6,
        num_kv_heads=2,
        head_dim=32,
        d_ff=384,
        vocab_size=512,
        block_pattern=("swa",),
        sliding_window=16,
        moe_num_experts=4,
        moe_top_k=2,
        moe_capacity_factor=2.0,
        dtype="float32",
    )
