"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000 — 8 experts top-2, sliding-window attention everywhere.
[arXiv:2401.04088]
SWA(4096) => sub-quadratic => runs long_500k.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral_8x7b",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        block_pattern=("swa",),
        sliding_window=4096,
        moe_num_experts=8,
        moe_top_k=2,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral_8x7b_reduced",
        num_layers=4,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        block_pattern=("swa",),
        sliding_window=16,
        moe_num_experts=4,
        moe_top_k=2,
        moe_capacity_factor=2.0,
        dtype="float32",
    )
