"""olmo-1b [dense]: 16L d_model=2048 16H (kv=16, i.e. MHA) d_ff=8192
vocab=50304 — non-parametric LayerNorm.  [arXiv:2402.00838]
Full attention => long_500k SKIPPED.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmo_1b",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=8192,
        vocab_size=50304,
        block_pattern=("attn",),
        norm_type="nonparam_ln",
        tie_embeddings=True,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="olmo_1b_reduced",
        num_layers=4,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        block_pattern=("attn",),
        norm_type="nonparam_ln",
        tie_embeddings=True,
        dtype="float32",
    )
