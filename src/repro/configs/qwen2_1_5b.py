"""qwen2-1.5b [dense]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — QKV bias, tied embeddings.  [arXiv:2407.10671]
Full attention => long_500k SKIPPED.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2_1_5b",
        num_layers=28,
        d_model=1536,
        num_heads=12,
        num_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab_size=151_936,
        block_pattern=("attn",),
        qkv_bias=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2_1_5b_reduced",
        num_layers=4,
        d_model=96,
        num_heads=6,
        num_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=512,
        block_pattern=("attn",),
        qkv_bias=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        dtype="float32",
    )
