"""Assigned-architecture configs.  ``get_config(name)`` / ``list_archs()``."""
from __future__ import annotations

import importlib

ARCHS = [
    "mistral_large_123b",
    "gemma2_27b",
    "olmo_1b",
    "qwen2_1_5b",
    "jamba_v01_52b",
    "qwen2_vl_7b",
    "mixtral_8x7b",
    "mixtral_8x22b",
    "rwkv6_7b",
    "whisper_tiny",
]

ALIASES = {a.replace("_", "-"): a for a in ARCHS}
ALIASES.update({
    "mistral-large-123b": "mistral_large_123b",
    "gemma2-27b": "gemma2_27b",
    "olmo-1b": "olmo_1b",
    "qwen2-1.5b": "qwen2_1_5b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "mixtral-8x22b": "mixtral_8x22b",
    "rwkv6-7b": "rwkv6_7b",
    "whisper-tiny": "whisper_tiny",
})


def get_config(name: str, reduced: bool = False):
    """Full-size config, or the reduced same-family smoke config."""
    mod_name = ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.reduced_config() if reduced else mod.config()


def list_archs():
    return list(ARCHS)
