"""mistral-large-123b [dense]: 88L d_model=12288 96H (GQA kv=8)
d_ff=28672 vocab=32768.  [hf:mistralai/Mistral-Large-Instruct-2407]

Full attention (no sliding window in the 2407 config) => long_500k SKIPPED
(pure full-attention rule; see DESIGN.md §Arch-applicability).
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral_large_123b",
        num_layers=88,
        d_model=12288,
        num_heads=96,
        num_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=32768,
        block_pattern=("attn",),
        rope_theta=1_000_000.0,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="mistral_large_123b_reduced",
        num_layers=4,
        d_model=128,
        num_heads=8,
        num_kv_heads=2,
        head_dim=16,
        d_ff=256,
        vocab_size=512,
        block_pattern=("attn",),
        rope_theta=1_000_000.0,
        dtype="float32",
    )
