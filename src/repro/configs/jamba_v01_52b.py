"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2 — Mamba:attention 7:1 interleave, MoE
every 2nd layer.  [arXiv:2403.19887]

32 layers = 4 × 8-layer superblocks; attention sits at position 3 (the
paper places one attention layer per 8).  SSM-dominated => runs long_500k.
"""
from repro.models.common import ModelConfig

_PATTERN = ("mamba", "mamba", "mamba", "attn",
            "mamba", "mamba", "mamba", "mamba")


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba_v01_52b",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=65536,
        block_pattern=_PATTERN,
        moe_num_experts=16,
        moe_top_k=2,
        moe_layer_period=2,
        mamba_d_state=16,
        mamba_d_conv=4,
        mamba_expand=2,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="jamba_v01_52b_reduced",
        num_layers=8,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        block_pattern=_PATTERN,
        moe_num_experts=4,
        moe_top_k=2,
        moe_capacity_factor=2.0,
        moe_layer_period=2,
        mamba_d_state=8,
        mamba_d_conv=4,
        mamba_expand=2,
        dtype="float32",
    )
