"""gemma2-27b [dense]: 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000 — local/global alternating attention, logit softcaps,
sandwich norms, scaled embeddings.  [arXiv:2408.00118]

46 layers = 23 × (local SWA-4096, global) superblocks.  Half the layers are
sliding-window => runs long_500k (not pure full attention).
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2_27b",
        num_layers=46,
        d_model=4608,
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,
        d_ff=36864,
        vocab_size=256_000,
        block_pattern=("swa", "attn"),
        sliding_window=4096,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        query_scale=144.0 ** -0.5,   # query_pre_attn_scalar = d_model/heads
        scale_embeddings=True,
        post_block_norm=True,
        tie_embeddings=True,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2_27b_reduced",
        num_layers=4,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        block_pattern=("swa", "attn"),
        sliding_window=16,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        query_scale=32.0 ** -0.5,
        scale_embeddings=True,
        post_block_norm=True,
        tie_embeddings=True,
        dtype="float32",
    )
