"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — M-RoPE (t/h/w sections), dynamic-resolution vision frontend
STUBBED: input_specs() provides precomputed patch/token embeddings plus the
(3, B, S) multimodal position ids.  [arXiv:2409.12191]
Full attention => long_500k SKIPPED.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2_vl_7b",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        vocab_size=152_064,
        block_pattern=("attn",),
        qkv_bias=True,
        rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24),   # t/h/w frequency sections (sum=Dh/2)
        input_mode="embeds",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2_vl_7b_reduced",
        num_layers=4,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        block_pattern=("attn",),
        qkv_bias=True,
        mrope_sections=(6, 5, 5),
        input_mode="embeds",
        dtype="float32",
    )
