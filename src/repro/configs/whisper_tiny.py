"""whisper-tiny [audio]: 4L enc + 4L dec, d_model=384 6H (MHA) d_ff=1536
vocab=51865 — encoder-decoder; conv/log-mel frontend STUB (input_specs()
provides precomputed frame embeddings).  [arXiv:2212.04356]
Decoder is full attention => long_500k SKIPPED (also beyond the arch's
positional design).
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper_tiny",
        num_layers=4,                # decoder layers
        encoder_layers=4,
        encoder_seq=1500,
        d_model=384,
        num_heads=6,
        num_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab_size=51865,
        block_pattern=("attn",),
        norm_type="layernorm",
        tie_embeddings=True,
        input_mode="embeds",         # frame embeddings for the encoder
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="whisper_tiny_reduced",
        num_layers=2,
        encoder_layers=2,
        encoder_seq=50,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        block_pattern=("attn",),
        norm_type="layernorm",
        tie_embeddings=True,
        input_mode="embeds",
        dtype="float32",
    )
