"""rwkv6-7b [ssm]: 32L d_model=4096 (attention-free) d_ff=14336 vocab=65536
— Finch: data-dependent decay WKV recurrence.  [arXiv:2404.05892]
Attention-free => runs long_500k (O(1) state).
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6_7b",
        num_layers=32,
        d_model=4096,
        num_heads=64,           # 4096 / rwkv_head_dim(64)
        num_kv_heads=64,
        head_dim=64,
        d_ff=14336,
        vocab_size=65536,
        block_pattern=("rwkv",),
        norm_type="layernorm",
        embed_norm=True,
        rwkv_head_dim=64,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6_7b_reduced",
        num_layers=4,
        d_model=128,
        num_heads=8,
        num_kv_heads=8,
        head_dim=16,
        d_ff=256,
        vocab_size=512,
        block_pattern=("rwkv",),
        norm_type="layernorm",
        embed_norm=True,
        rwkv_head_dim=16,
        dtype="float32",
    )
