"""Heavy-hitter attribution tier — signed count-sketch + dyadic findHH.

See ``repro.attribution.sketch`` for the full story; this package is the
layer every "why did it flag" feature builds on (per-chunk offending
coordinates/tenants in the stream summaries, the drift post-mortem
example, the Pallas ``attr_estimate`` kernel).
"""
from repro.attribution.sketch import (AttrConfig, chunk_energy,
                                      chunk_planes, drift_vector, estimate,
                                      estimate_level, find_hh, init_plane,
                                      l2estimate, level_tables,
                                      observe_flat, observe_fleet,
                                      observe_fleet_window, observe_window,
                                      sketch_vector, tenant_drift_l2)

__all__ = [
    "AttrConfig", "chunk_energy", "chunk_planes", "drift_vector",
    "estimate", "estimate_level", "find_hh", "init_plane", "l2estimate",
    "level_tables", "observe_flat", "observe_fleet",
    "observe_fleet_window", "observe_window", "sketch_vector",
    "tenant_drift_l2",
]
