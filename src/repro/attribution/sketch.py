"""Heavy-hitter attribution: signed count-sketch + dyadic drill-down.

The ACE tier *flags* anomalies; this tier says *what drives them*.  The
paper's LSH-as-sampling view (ACE §2: counts of hashed buckets estimate
collision-weighted frequency mass) extends directly to the classic
signed count-sketch (Charikar–Chen–Farach-Colton): per row r a bucket
hash h_r(i) and a ±1 sign s_r(i), with

    sketch[r, h_r(i)] += s_r(i) · v_i,
    v̂_i = median_r( s_r(i) · sketch[r, h_r(i)] ).

Both hash families are drawn from the SAME SRP stack the ACE tables use
(``repro.core.srp``): hashing the one-hot vector e_i through an SRP bank
reduces to the sign pattern of projection-matrix ROW i, so the bucket
column of coordinate i is ``pack_buckets`` of row i's sign bits and the
±1 sign is a 1-bit SRP bank of its own.  No new hash machinery — the
attribution tier inherits the seeded, persisted-state hash contract of
the sketch tier.

Dyadic drill-down (the count-sketch ``findHH`` recursion): one signed
plane per level of a static binary tree over the (padded) coordinate
space.  Node k at depth d covers coords [k·2^(NL−d), (k+1)·2^(NL−d));
children of k are 2k and 2k+1; depth NL nodes ARE coordinates.  The
recursive descent is lowered to ONE ``lax.scan`` over the static depth
axis with a fixed-width beam (:func:`find_hh`) — fixed shapes end to
end, no data-dependent recursion on the host hot path, so the whole
drill-down rides inside the stream runner's single jitted program.

Plane layout (the ``attr`` state leaf): ``(2, NL, R, C)`` float32 —
channel 0 accumulates ALL finite traffic's per-coordinate energy
Σ w·x_i², channel 1 only the flagged anomalies' — windowed states carry
``(E, 2, NL, R, C)`` rings (live row at the cursor, zeroed at rotation,
exactly like the count ring) and fleets ``(T, ...)`` stacks.  The drift
vector channel1/n_anom − channel0/n_all concentrates exactly where
anomalous traffic differs from the background, and ``find_hh`` over its
sketch names those coordinates without ever materialising a dense
per-coordinate delta off-device.

Estimator error (Charikar et al., Thm.): with R rows of width C, each
point estimate errs by at most ‖v‖₂·√(8/C) with probability ≥ 1 − δ for
R = O(log 1/δ) — the median over R rows is what buys the exponential
confidence; :func:`_median_lastaxis` is the single shared median used by
the jnp path, the kernel contract and the oracle.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import srp
from repro.core.srp import SrpConfig


@dataclasses.dataclass(frozen=True)
class AttrConfig:
    """Static configuration of one attribution hierarchy.

    Attributes:
      dim:  number of attributable coordinates (the filter's feature dim).
      rows: R — independent signed rows (median over R; odd R gives the
        crisp order-statistic median, even R the midpoint).
      bits: bucket-space log2 — each row is ``1 << bits`` wide.
      seed: PRNG seed the per-level SRP banks derive from.
    """

    dim: int
    rows: int = 5
    bits: int = 8
    seed: int = 0

    def __post_init__(self):
        if self.dim < 1:
            raise ValueError(f"dim must be >= 1, got {self.dim}")
        if self.rows < 1:
            raise ValueError(f"rows must be >= 1, got {self.rows}")
        if not 1 <= self.bits <= 20:
            raise ValueError(f"bits must be in [1, 20], got {self.bits}")

    @property
    def width(self) -> int:
        return 1 << self.bits

    @property
    def num_levels(self) -> int:
        """NL — dyadic tree depth: ceil(log2(dim)), at least 1."""
        return max(1, (self.dim - 1).bit_length())

    @property
    def padded_dim(self) -> int:
        """2^NL — the padded leaf space (coords >= dim are never valid)."""
        return 1 << self.num_levels

    def plane_shape(self) -> tuple:
        """The flat-state ``attr`` leaf: (2 channels, NL, R, C)."""
        return (2, self.num_levels, self.rows, self.width)

    def memory_bytes(self) -> int:
        return 2 * self.num_levels * self.rows * self.width * 4


# ---------------------------------------------------------------------------
# Hash tables — derived from the SRP stack, host-side, cached per config.
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _level_tables_np(cfg: AttrConfig):
    """Per-level node hash tables: cols (NL, 2^NL, R) int32 in [0, C),
    signs (NL, 2^NL, R) float32 ±1.

    Level ℓ hosts the depth-(ℓ+1) nodes; all levels share the padded
    node-id space so one stacked table serves every depth.  The bucket
    column of node k is ``pack_buckets`` of the sign bits of projection
    ROW k (one-hot input ⇒ the SRP matmul degenerates to a row read);
    the ±1 sign is an independent 1-bit SRP bank.  Computed once on the
    host per config (NumPy constants closed into the jitted programs).
    """
    nl, d2, r = cfg.num_levels, cfg.padded_dim, cfg.rows
    cols = np.empty((nl, d2, r), np.int32)
    sgns = np.empty((nl, d2, r), np.float32)
    # ensure_compile_time_eval: the derivation runs through jnp (the SRP
    # stack), but its output is a host constant closed into the jitted
    # consumers — first touch may happen INSIDE a trace (lru-cached
    # thereafter), and the jnp ops must not become tracers there
    with jax.ensure_compile_time_eval():
        for lvl in range(nl):
            ccfg = SrpConfig(dim=d2, num_bits=cfg.bits, num_tables=r,
                             seed=cfg.seed * 7919 + 2 * lvl + 1)
            w = np.asarray(srp.make_projections(ccfg))
            bits = (w >= 0).astype(np.int32)[:, :ccfg.num_projections]
            cols[lvl] = np.asarray(srp.pack_buckets(jnp.asarray(bits),
                                                    ccfg))
            scfg = SrpConfig(dim=d2, num_bits=1, num_tables=r,
                             seed=cfg.seed * 7919 + 2 * lvl + 2)
            ws = np.asarray(srp.make_projections(scfg))
            sgns[lvl] = 2.0 * (ws >= 0).astype(np.float32)[:, :r] - 1.0
    return cols, sgns


@lru_cache(maxsize=None)
def _coord_tables_np(cfg: AttrConfig):
    """Coordinate-granular scatter tables: off (NL, dim, R) int32 flat
    element offsets into a ``(NL·R·C,)`` plane view, sg (NL, dim, R)
    float32 signs.  Coordinate i lives in node ``i >> (NL−1−ℓ)`` at
    level ℓ, so sketching a dense (dim,) vector into the WHOLE hierarchy
    is one flat scatter-add (:func:`sketch_vector`)."""
    cols, sgns = _level_tables_np(cfg)
    nl, r, c, d = cfg.num_levels, cfg.rows, cfg.width, cfg.dim
    off = np.empty((nl, d, r), np.int32)
    sg = np.empty((nl, d, r), np.float32)
    coords = np.arange(d)
    for lvl in range(nl):
        node = coords >> (nl - 1 - lvl)
        off[lvl] = (lvl * r + np.arange(r)[None, :]) * c + cols[lvl][node]
        sg[lvl] = sgns[lvl][node]
    return off, sg


def level_tables(cfg: AttrConfig):
    """(cols, signs) node tables as jnp constants — see _level_tables_np."""
    cols, sgns = _level_tables_np(cfg)
    return jnp.asarray(cols), jnp.asarray(sgns)


def init_plane(cfg: AttrConfig) -> jax.Array:
    """Zero flat-state attribution plane: (2, NL, R, C) float32."""
    return jnp.zeros(cfg.plane_shape(), jnp.float32)


# ---------------------------------------------------------------------------
# Sketching: dense vectors -> signed hierarchies; chunk observation.
# ---------------------------------------------------------------------------

def _median_lastaxis(x: jax.Array) -> jax.Array:
    """THE median every estimate path shares (jnp, kernel contract,
    oracle): sort the last axis; odd R takes the middle order statistic,
    even R the midpoint of the two middles."""
    r = x.shape[-1]
    s = jnp.sort(x, axis=-1)
    if r % 2:
        return s[..., r // 2]
    return 0.5 * (s[..., r // 2 - 1] + s[..., r // 2])


def sketch_vector(cfg: AttrConfig, v: jax.Array) -> jax.Array:
    """Sketch one dense (dim,) value vector into its full (NL, R, C)
    dyadic signed hierarchy — ONE fixed-shape flat scatter-add
    (O(NL·dim·R) adds, no per-level loop in the lowered program)."""
    off, sg = _coord_tables_np(cfg)
    nl, r, c = cfg.num_levels, cfg.rows, cfg.width
    vals = (v.astype(jnp.float32)[None, :, None] * jnp.asarray(sg))
    flat = jnp.zeros((nl * r * c,), jnp.float32) \
        .at[jnp.asarray(off.reshape(-1))].add(vals.reshape(-1))
    return flat.reshape(nl, r, c)


def chunk_energy(feat: jax.Array, margins: jax.Array, num_tenants: int,
                 tenant_ids: jax.Array | None = None):
    """Per-tenant per-coordinate energy split of one chunk.

    ``feat`` (N, dim) sanitized features (quarantined rows pre-zeroed by
    the filter contract), ``margins`` (N,) float32 under the runner's
    sentinel protocol: −inf = quarantined (excluded from BOTH channels),
    +inf = warmup (background only), finite < 0 = flagged anomaly.
    Returns (e_all (T, dim), e_anom (T, dim), n_all (T,), n_anom (T,)).

    The flat path calls with ``num_tenants=1`` / ``tenant_ids=None`` —
    the IDENTICAL segment-sum program with T=1, which is what makes
    fleet-of-1 attribution bitwise the single-tenant path.
    """
    n = feat.shape[0]
    tids = (jnp.zeros((n,), jnp.int32) if tenant_ids is None
            else tenant_ids.reshape(-1).astype(jnp.int32))
    allf = (~jnp.isneginf(margins)).astype(jnp.float32)
    anomf = allf * (margins < 0.0).astype(jnp.float32)
    sq = feat.astype(jnp.float32) ** 2
    e_all = jnp.zeros((num_tenants, feat.shape[1]), jnp.float32) \
        .at[tids].add(sq * allf[:, None])
    e_anom = jnp.zeros_like(e_all).at[tids].add(sq * anomf[:, None])
    n_all = jnp.zeros((num_tenants,), jnp.float32).at[tids].add(allf)
    n_anom = jnp.zeros_like(n_all).at[tids].add(anomf)
    return e_all, e_anom, n_all, n_anom


def chunk_planes(cfg: AttrConfig, e_all: jax.Array,
                 e_anom: jax.Array) -> jax.Array:
    """(T, dim) background + anomaly energies -> (T, 2, NL, R, C)
    two-channel sketch contributions (one chunk's worth)."""
    sk = jax.vmap(lambda v: sketch_vector(cfg, v))
    return jnp.stack([sk(e_all), sk(e_anom)], axis=1)


def drift_vector(e_all: jax.Array, e_anom: jax.Array, n_all: jax.Array,
                 n_anom: jax.Array) -> jax.Array:
    """Chunk-global drift: mean anomaly energy − mean background energy
    per coordinate, (dim,).  Tenant rows are summed FIRST in both the
    flat (T=1) and fleet paths — same reduction order, bitwise
    fleet-of-1 parity."""
    ea = jnp.sum(e_all, axis=0)
    ex = jnp.sum(e_anom, axis=0)
    na = jnp.sum(n_all)
    nx = jnp.sum(n_anom)
    return ex / jnp.maximum(nx, 1.0) - ea / jnp.maximum(na, 1.0)


def tenant_drift_l2(e_all: jax.Array, e_anom: jax.Array, n_all: jax.Array,
                    n_anom: jax.Array) -> jax.Array:
    """(T,) exact per-tenant drift magnitudes ‖Δ_t‖₂ — the tenant axis
    is dense state already, no sketch round-trip needed."""
    d = e_anom / jnp.maximum(n_anom, 1.0)[:, None] \
        - e_all / jnp.maximum(n_all, 1.0)[:, None]
    return jnp.sqrt(jnp.sum(d * d, axis=-1))


# -- state-plane observation (one call per chunk, all fixed-shape) ----------

def observe_flat(attr: jax.Array, planes: jax.Array) -> jax.Array:
    """Flat state: attr (2, NL, R, C) += the chunk's T=1 planes row."""
    return attr + planes[0]


def observe_fleet(attr: jax.Array, planes: jax.Array) -> jax.Array:
    """Fleet state: attr (T, 2, NL, R, C) += per-tenant chunk planes."""
    return attr + planes


def observe_window(attr: jax.Array, planes: jax.Array,
                   cursor: jax.Array) -> jax.Array:
    """Windowed state: live epoch row of attr (E, 2, NL, R, C) += the
    chunk plane (2, NL, R, C); rotation zeroes the row like the counts."""
    live = jax.lax.dynamic_index_in_dim(attr, cursor, 0, keepdims=False)
    return jax.lax.dynamic_update_index_in_dim(attr, live + planes,
                                               cursor, 0)


def observe_fleet_window(attr: jax.Array, planes: jax.Array,
                         cursor: jax.Array) -> jax.Array:
    """Fleet×window: attr (T, E, 2, NL, R, C), planes (T, 2, NL, R, C),
    cursor (T,) — each tenant's live row via one flat scatter-add."""
    t, e = attr.shape[0], attr.shape[1]
    flat = attr.reshape((t * e,) + attr.shape[2:])
    rows = jnp.arange(t, dtype=jnp.int32) * e + cursor.astype(jnp.int32)
    return flat.at[rows].add(planes).reshape(attr.shape)


# ---------------------------------------------------------------------------
# Estimation: point queries, L2, and the fixed-shape findHH drill-down.
# ---------------------------------------------------------------------------

def estimate_level(cfg: AttrConfig, plane: jax.Array, nodes: jax.Array,
                   level: int) -> jax.Array:
    """jnp-path median-of-rows point estimates of node ids at one STATIC
    level: plane (NL, R, C) single-channel hierarchy, nodes (B,) int32
    -> (B,) signed estimates."""
    cols, sgns = level_tables(cfg)
    c = cols[level][nodes]                                     # (B, R)
    s = sgns[level][nodes]
    g = plane[level][jnp.arange(cfg.rows, dtype=jnp.int32)[None, :], c]
    return _median_lastaxis(g * s)


def estimate(cfg: AttrConfig, plane: jax.Array, coords: jax.Array,
             interpret: bool | None = None) -> jax.Array:
    """Kernel-path batch point estimates of LEAF coordinates: plane
    (NL, R, C), coords (B,) int32 in [0, dim) -> (B,) v̂ via the Pallas
    signed gather + median kernel (``repro.kernels.attr_estimate``)."""
    from repro.kernels import ops
    cols, sgns = level_tables(cfg)
    lvl = cfg.num_levels - 1
    return ops.attr_estimate(plane[lvl], cols[lvl][coords],
                             sgns[lvl][coords], interpret=interpret)


def l2estimate(plane: jax.Array) -> jax.Array:
    """Median-of-rows ‖v‖₂ estimate per level: (NL, R, C) -> (NL,).
    Each row's L2 norm concentrates around the true sketched-vector norm
    (the count-sketch is an AMS sketch per row); the leaf entry is the
    hierarchy's headline estimate."""
    return _median_lastaxis(jnp.sqrt(jnp.sum(plane * plane, axis=-1)))


def find_hh(cfg: AttrConfig, plane: jax.Array, topk: int):
    """Dyadic findHH drill-down, lowered to ONE ``lax.scan`` over the
    static depth axis with a fixed beam — no data-dependent recursion.

    ``plane`` (NL, R, C) is a single-channel signed hierarchy (typically
    the sketch of a drift vector).  A beam of W = max(2·topk, 8)
    candidate nodes descends: each step expands every candidate into its
    two children, masks children that fall outside the tree or past
    ``dim``, estimates |v̂| via the level's median gather, and keeps the
    top W.  After the leaf level the beam is ranked once more and the
    top ``topk`` coordinates returned as
    (coords (topk,) int32, ests (topk,) float32 signed estimates,
    valid (topk,) bool — False lanes are beam padding, not coords).
    """
    nl, r, d2 = cfg.num_levels, cfg.rows, cfg.padded_dim
    topk = int(topk)
    if topk < 1:
        raise ValueError(f"topk must be >= 1, got {topk}")
    beam = max(2 * topk, 8)
    cols, sgns = level_tables(cfg)
    riota = jnp.arange(r, dtype=jnp.int32)[None, :]
    dim_m1 = jnp.int32(cfg.dim - 1)

    def _est(level, nodes):
        """Median estimates of ``nodes`` at a (possibly traced) level."""
        c = jnp.take(cols, level, axis=0)[nodes]               # (M, R)
        s = jnp.take(sgns, level, axis=0)[nodes]
        row = jax.lax.dynamic_index_in_dim(plane, level, 0,
                                           keepdims=False)     # (R, C)
        return _median_lastaxis(row[riota, c] * s)

    def body(carry, depth):
        keys, valid = carry
        children = jnp.concatenate([2 * keys, 2 * keys + 1])   # (2W,)
        cvalid = jnp.concatenate([valid, valid])
        cvalid &= children < jnp.left_shift(jnp.int32(1), depth)
        # the node's FIRST covered coordinate k·2^(NL−d) must be < dim;
        # tested as k <= (dim−1) >> (NL−d) so no shift can overflow
        cvalid &= children <= jnp.right_shift(dim_m1, nl - depth)
        cidx = jnp.clip(children, 0, d2 - 1)   # gather-safe ids
        rank = jnp.where(cvalid, jnp.abs(_est(depth - 1, cidx)), -jnp.inf)
        _, top = jax.lax.top_k(rank, beam)
        return (cidx[top], cvalid[top]), None

    keys = jnp.arange(beam, dtype=jnp.int32)
    valid = keys < 2                           # depth-1 nodes: {0, 1}
    if nl > 1:
        (keys, valid), _ = jax.lax.scan(
            body, (keys, valid), jnp.arange(2, nl + 1, dtype=jnp.int32))
    valid &= keys < cfg.dim                    # leaf node == coordinate
    est = estimate_level(cfg, plane, jnp.clip(keys, 0, d2 - 1), nl - 1)
    rank = jnp.where(valid, jnp.abs(est), -jnp.inf)
    _, top = jax.lax.top_k(rank, topk)
    return keys[top], est[top], valid[top]
