"""Batched serving engine: prefill + decode with KV caches, greedy/sampled
generation, and the ACE request guardrail (OOD requests rejected in O(K·L)
before touching the model — the paper's query phase as an admission filter).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sk
from repro.core.sketch import AceConfig
from repro.core.srp import hash_buckets
from repro.models.registry import Arch, is_whisper


@dataclasses.dataclass(frozen=True)
class GuardrailConfig:
    d_model: int
    num_bits: int = 13
    num_tables: int = 32
    alpha: float = 4.0
    warmup_items: float = 256.0
    bias_const: float = 0.25
    hash_mode: str = "dense"    # "dense" | "srht" | "auto" (SrpConfig)
    # Sliding-window mode (repro.window): >1 epochs turns the sketch into
    # a device-resident epoch ring whose admit threshold is computed from
    # WINDOW-combined moments, so the guardrail tracks traffic drift by
    # FORGETTING stale regimes instead of letting them pin μ/σ forever.
    window_epochs: int = 1      # 1 = the flat (cumulative) sketch
    window_decay: float = 1.0   # γ; epoch weight γ^age at query time
    rotate_every: int = 0       # admit calls per epoch (0 = never rotate)
    # Multi-tenant fleet mode (repro.fleet): >1 tenants stacks T
    # independent sketches behind ONE admit program; every admit call
    # carries a (B,) tenant_ids routing vector and each request is
    # scored / thresholded / inserted against its own tenant's state
    # (per-tenant warmup, per-tenant drift — isolation is bitwise).
    num_tenants: int = 1        # 1 = the classic single-tenant guardrail
    # Quantized count planes (repro.core.quantize): narrow count dtypes
    # cut the resident table (and every gather's bandwidth) 2–4×.
    # esc_capacity > 0 additionally enables exact overflow promotion —
    # flat (window_epochs == 1, num_tenants == 1) guardrails only.
    count_dtype: str = "int32"  # "float32" | "int32" | "int16" | "int8"
    esc_capacity: int = 0
    # Admission threshold rule (repro.quantile): "mu_sigma" is the
    # classic μ−ασ score threshold; "quantile" targets a FLAG RATE —
    # admit iff score ≥ Q_q of the running per-tenant rate histogram —
    # which stays calibrated on heavy-tailed traffic where μ−ασ
    # over-flags (power-law tails inflate σ late) or under-flags
    # (early σ underestimates the tail).  Threshold-mode dispatch is
    # trace-time Python: mu_sigma guardrails trace zero quantile code
    # and their executables stay byte-identical to the pre-PR ones.
    threshold_mode: str = "mu_sigma"
    quantile_q: float = 0.01    # target flag rate for quantile mode
    # Quarantine fail policy (repro.resilience): requests whose features
    # are non-finite are sanitized OUT of the sketch (never scored
    # against real counts, never inserted, counted in
    # ``Guardrail.quarantined``) and their admit verdict comes from this
    # policy instead: "fail_open" admits them downstream (availability
    # first), "fail_closed" rejects them (integrity first).  Multi-tenant
    # guardrails accept a per-tenant tuple of length num_tenants.
    fail_policy: str | tuple = "fail_open"


class Guardrail:
    """ACE admission filter over request embeddings (stateful host wrapper).

    ``admit`` is ONE fixed-shape jitted device program: hash once, score
    from the same bucket ids, compare against the on-device μ−ασ score
    threshold, and fold the admitted items back in with a masked
    (0/1-weighted) insert — order-invariant and shape-stable, so a single
    compiled executable serves every batch no matter how many items are
    admitted, and the only host transfer per batch is the returned (B,)
    mask.  (The pre-PR path synced n/σ to the host, hashed twice, and
    retraced on every distinct admitted-count via a data-dependent
    gather.)

    With ``use_kernels=True`` the hash→score→threshold→masked-insert runs
    as the single fused Pallas kernel ``repro.kernels.ace_admit_fused``
    (one launch, one HBM pass; ``interpret=True`` on CPU).

    With a ``mesh``, the sketch state is placed via ``repro.dist``:
    ``sketch_layout="replicated"`` mirrors the counts on every device (the
    default single-device behaviour, scaled out), while ``"table_sharded"``
    splits the (L, 2^K) counts over the L axis across ``table_axis`` —
    jit/SPMD mode of repro.dist.sketch_parallel — so guardrail sketches
    beyond one device's memory (K=18+, L=200+) stay servable; the same
    jitted admit program works in every layout (GSPMD inserts the
    collectives around the masked insert).

    With ``gcfg.window_epochs > 1`` the sketch is a sliding-window epoch
    ring (``repro.window``): the admit threshold comes from the
    WINDOW-combined μ/σ, admits insert into the live epoch, and every
    ``gcfg.rotate_every`` admit calls the ring rotates INSIDE the same
    jitted program (device-side cond on the ring's tick) — so a traffic
    regime that stops arriving ages out of the filter in
    ``window_epochs × rotate_every`` calls instead of biasing μ/σ
    forever.  Still one hash, one executable, one host transfer; the
    epoch ring shards over the SAME layouts (the L axis splits, the E
    axis never does).

    With ``gcfg.num_tenants > 1`` the guardrail is a FLEET
    (``repro.fleet``): T independent tenant sketches stacked behind the
    same single admit program, with ``admit(embeds, tenant_ids)``
    routing each request to its own tenant — per-tenant thresholds,
    per-tenant warmup, one mixed-batch scatter, and (combined with
    ``window_epochs > 1``) per-tenant epoch rings with per-tenant
    rotation clocks.  Still one hash, one executable, one host
    transfer; flat fleets shard over the tenant and/or table layouts of
    ``repro.dist.sketch_parallel.fleet_shardings_for_layout``.
    """

    def __init__(self, gcfg: GuardrailConfig, *, mesh=None,
                 sketch_layout: str = "replicated",
                 table_axis: str = "model", use_kernels: bool = False):
        self.gcfg = gcfg
        self.ace_cfg = AceConfig(dim=gcfg.d_model + 1,
                                 num_bits=gcfg.num_bits,
                                 num_tables=gcfg.num_tables, seed=41,
                                 welford_min_n=gcfg.warmup_items / 2,
                                 hash_mode=gcfg.hash_mode,
                                 counter_dtype=gcfg.count_dtype,
                                 esc_capacity=gcfg.esc_capacity)
        self.windowed = gcfg.window_epochs > 1
        self.multi_tenant = gcfg.num_tenants > 1
        if gcfg.threshold_mode not in ("mu_sigma", "quantile"):
            raise ValueError(f"unknown threshold_mode "
                             f"{gcfg.threshold_mode!r} — expected "
                             "'mu_sigma' or 'quantile'")
        quantile = gcfg.threshold_mode == "quantile"
        if self.multi_tenant:
            from repro.fleet import state as fl
            from repro.fleet import window as fw
            if self.windowed:
                from repro.window import ring
                if gcfg.rotate_every <= 0:
                    raise ValueError(
                        "windowed guardrail (window_epochs > 1) needs "
                        "rotate_every > 0 — without a rotation clock the "
                        "ring never expires and behaves like the frozen "
                        "sketch")
                # per-tenant epoch rings with per-tenant rotation clocks
                self.state = fw.init_fleet_window(ring.WindowConfig(
                    ace=self.ace_cfg, num_epochs=gcfg.window_epochs,
                    decay=gcfg.window_decay,
                    rotate_every=gcfg.rotate_every), gcfg.num_tenants,
                    quantile=quantile)
            else:
                self.state = fl.init(fl.FleetConfig(
                    ace=self.ace_cfg, num_tenants=gcfg.num_tenants),
                    quantile=quantile)
        elif self.windowed:
            from repro.window import ring
            if gcfg.rotate_every <= 0:
                # nothing else rotates a guardrail's ring: E>1 epochs
                # with no clock silently degenerates to the frozen
                # sketch at E× the memory — exactly the misconfig the
                # windowed mode exists to replace
                raise ValueError(
                    "windowed guardrail (window_epochs > 1) needs "
                    "rotate_every > 0 — without a rotation clock the "
                    "ring never expires and behaves like the frozen "
                    "sketch")
            # WindowConfig VALIDATES (epochs, decay, rotate_every) up
            # front — a bad γ must fail loudly here, not silently weight
            # stale epochs above live traffic
            self.state = ring.init_window(ring.WindowConfig(
                ace=self.ace_cfg, num_epochs=gcfg.window_epochs,
                decay=gcfg.window_decay,
                rotate_every=gcfg.rotate_every), quantile=quantile)
        else:
            self.state = sk.init(self.ace_cfg)
            if quantile:
                from repro.quantile import sketch as qsk
                self.state = self.state._replace(qhist=qsk.init_hist())
        self.w = sk.make_params(self.ace_cfg)
        if use_kernels and mesh is not None:
            raise ValueError("use_kernels admission is single-device; "
                             "drop the mesh or use the jnp path")
        self.use_kernels = use_kernels
        # Per-tenant quarantine fail policy (resilience): a scalar policy
        # broadcasts to every tenant; a tuple must cover each tenant.
        pol = gcfg.fail_policy
        if isinstance(pol, str):
            pol = (pol,) * max(gcfg.num_tenants, 1)
        if len(pol) != max(gcfg.num_tenants, 1):
            raise ValueError(
                f"fail_policy tuple has {len(pol)} entries for "
                f"{gcfg.num_tenants} tenants")
        bad = [p for p in pol if p not in ("fail_open", "fail_closed")]
        if bad:
            raise ValueError(f"unknown fail_policy {bad[0]!r} — expected "
                             "'fail_open' or 'fail_closed'")
        self._fail_open = np.array([p == "fail_open" for p in pol])
        # Host-side health/degradation state (repro.resilience).  The
        # serving table mask is None on the healthy path — the mask code
        # is then never traced, keeping the healthy executable untouched
        # — and a device (L,)/(T, L) float mask while degraded (a SECOND
        # cached executable, switched host-side with zero hot-path
        # syncs).
        self.quarantined = 0          # total non-finite rows seen
        self._table_mask = None       # device f32 serving mask | None
        self._repair_offsets = None   # flat/fleet per-table n-at-repair
        self._rewarm_admits = 0       # windowed repair re-warm countdown
        self._rewarming = None        # host bool mask of re-warming tables
        self.trace_count = 0          # incremented at TRACE time only
        # The incoming state is dead the moment admit() rebinds it, so
        # donate it: the masked insert updates the counts buffer in place
        # instead of copying (L, 2^K) every batch.
        self._admit = jax.jit(self._admit_impl, donate_argnums=0)
        if mesh is not None:
            quantile = gcfg.threshold_mode == "quantile"
            if self.multi_tenant:
                if self.windowed:
                    raise NotImplementedError(
                        "sharded windowed fleets are not wired yet — "
                        "drop the mesh or use window_epochs=1")
                from repro.dist.sketch_parallel import \
                    fleet_shardings_for_layout
                shardings = fleet_shardings_for_layout(
                    self.ace_cfg, mesh, gcfg.num_tenants, sketch_layout,
                    table_axis, quantile=quantile)
            elif self.windowed:
                from repro.dist.sketch_parallel import \
                    window_shardings_for_layout
                shardings = window_shardings_for_layout(
                    self.ace_cfg, mesh, gcfg.window_epochs, sketch_layout,
                    table_axis, quantile=quantile)
            else:
                from repro.dist.sketch_parallel import shardings_for_layout
                shardings = shardings_for_layout(
                    self.ace_cfg, mesh, sketch_layout, table_axis,
                    quantile=quantile)
            self.state = jax.device_put(self.state, shardings)

    def _features(self, embeds: jax.Array) -> jax.Array:
        """Unit-normalised mean embedding + bias coordinate — the SAME
        shared helper as the data filters (``mean_embed_features``), so
        the serving guardrail and the training-side filters can never
        drift apart on featurisation."""
        from repro.data.pipeline import mean_embed_features
        return mean_embed_features(embeds, self.gcfg.bias_const)

    def _admit_impl(self, state: sk.AceState, w: jax.Array,
                    embeds: jax.Array, tenant_ids=None, table_mask=None):
        """The whole admission step as one traced device program.

        Entry-point sanitization (repro.resilience): rows whose features
        are non-finite are zeroed BEFORE hashing (NaN·0 would re-poison
        anything downstream), barred from admission AND insertion
        (``item_mask`` — the silent fail-open bug this replaces admitted
        them into one bucket per table, skewing ssq/μ forever), and
        reported back so the host can count them as quarantined; their
        returned verdict is the per-tenant fail policy's.  For all-finite
        batches the sanitization is bitwise identity.

        ``table_mask`` ((L,) or (T, L) f32 health mask) scores over
        healthy tables only; None (the healthy path) never traces any
        mask code — the degraded program is a separate cached executable.
        """
        self.trace_count += 1
        cfg = self.ace_cfg
        feat = self._features(embeds)
        finite = jnp.all(jnp.isfinite(feat), axis=-1)         # (B,)
        feat = jnp.where(finite[:, None], feat, 0.0)
        new_state, admit = self._admit_branches(
            state, w, feat, finite, tenant_ids, table_mask)
        if self.multi_tenant:
            fail_open = jnp.asarray(self._fail_open)[tenant_ids]
        else:
            fail_open = jnp.asarray(bool(self._fail_open[0]))
        final = jnp.where(finite, admit, fail_open)
        # ONE packed (2, B) transfer: verdicts + the quarantine mask.
        return new_state, jnp.stack([final, finite])

    def _admit_branches(self, state, w, feat, finite, tenant_ids,
                        table_mask):
        """Score → threshold → masked insert for every sketch flavour.

        ``finite`` rides into each branch as the item mask: quarantined
        rows never admit and never insert (the fused kernels gate them
        in-launch; the jnp paths AND them out of the insert mask).
        """
        cfg = self.ace_cfg
        if self.multi_tenant:
            from repro.fleet import state as fl
            from repro.fleet import window as fw
            if self.windowed:
                # per-tenant windowed admission: one hash, routed tail +
                # live gathers, per-tenant windowed μ−ασ thresholds, one
                # live-epoch scatter, then the per-tenant rotation
                # clocks — mirrors the single-ring windowed branch below
                if self.use_kernels:
                    # the ONE all-in-one launch (hash + routed gathers +
                    # γ-combine + threshold + masked insert welded) —
                    # rotation clocks included in the dispatch
                    from repro.kernels import ops as kops
                    return kops.ace_fleet_window_admit(
                        state, feat, tenant_ids, w, cfg,
                        gamma=self.gcfg.window_decay,
                        alpha=self.gcfg.alpha,
                        warmup_items=self.gcfg.warmup_items,
                        rotate_every=self.gcfg.rotate_every,
                        table_mask=table_mask, item_mask=finite,
                        threshold_mode=self.gcfg.threshold_mode,
                        quantile_q=self.gcfg.quantile_q)
                buckets = hash_buckets(feat, w, cfg.srp)
                pre = fw.window_table_sums_fleet(state, tenant_ids,
                                                 buckets)
                from repro.window import ring
                if table_mask is None:
                    scores = ring.score_live(pre[0], pre[1],
                                             cfg.num_tables)
                else:
                    # degraded: masked combine for the DECISION; the
                    # insert's ssq increment keeps the true sums (pre)
                    scores = fw.window_fleet_scores(
                        state, tenant_ids, buckets,
                        table_mask=table_mask)
                admit = scores >= fw.window_admit_thresholds(
                    state, self.gcfg.window_decay, self.gcfg.alpha,
                    self.gcfg.warmup_items, table_mask=table_mask,
                    threshold_mode=self.gcfg.threshold_mode,
                    q=self.gcfg.quantile_q)[tenant_ids]
                admit = jnp.logical_and(admit, finite)
                new_state = fw.insert_current_fleet(
                    state, tenant_ids, buckets, admit, cfg,
                    gamma=self.gcfg.window_decay, pre_sums=pre)
                if self.gcfg.threshold_mode == "quantile":
                    # every finite-scored item feeds its tenant's LIVE
                    # epoch histogram, BEFORE the clocks tick (rotation
                    # retires the epoch row); admitted-only observation
                    # would freeze the rejected tail out of Q_q
                    from repro.quantile import sketch as qsk
                    n_w = jax.vmap(
                        lambda s: ring.combined_n(
                            s, self.gcfg.window_decay))(
                        ring.WindowedAceState(*state))
                    rates = scores / jnp.maximum(n_w, 1.0)[tenant_ids]
                    new_state = fw.observe_current_fleet(
                        new_state, rates, tenant_ids,
                        qsk.calib_mask(finite.astype(jnp.float32),
                                       n_w[tenant_ids],
                                       self.gcfg.warmup_items))
                new_state = fw.maybe_rotate_fleet(
                    new_state, self.gcfg.rotate_every,
                    self.gcfg.window_decay, tenant_ids=tenant_ids)
                return new_state, admit
            if self.use_kernels:
                from repro.kernels import ops as kops
                return kops.ace_fleet_admit(
                    state, feat, tenant_ids, w, cfg,
                    alpha=self.gcfg.alpha,
                    warmup_items=self.gcfg.warmup_items,
                    table_mask=table_mask, item_mask=finite,
                    threshold_mode=self.gcfg.threshold_mode,
                    quantile_q=self.gcfg.quantile_q)
            buckets = hash_buckets(feat, w, cfg.srp)   # the ONE hash
            scores = fl.fleet_scores(state, tenant_ids, buckets,
                                     table_mask=table_mask)
            admit = scores >= fl.admit_thresholds(
                state, self.gcfg.alpha, self.gcfg.warmup_items,
                table_mask=table_mask,
                threshold_mode=self.gcfg.threshold_mode,
                q=self.gcfg.quantile_q)[tenant_ids]
            admit = jnp.logical_and(admit, finite)
            new_state = fl.insert_masked(state, tenant_ids, buckets,
                                         admit, cfg)
            if self.gcfg.threshold_mode == "quantile":
                from repro.quantile import sketch as qsk
                rates = scores / jnp.maximum(state.n, 1.0)[tenant_ids]
                new_state = new_state._replace(
                    qhist=qsk.observe_rates_fleet(
                        new_state.qhist, rates, tenant_ids,
                        qsk.calib_mask(finite.astype(jnp.float32),
                                       state.n[tenant_ids],
                                       self.gcfg.warmup_items)))
            return new_state, admit
        if self.windowed:
            from repro.window import ring
            if self.use_kernels:
                from repro.kernels import ops as kops
                return kops.ace_admit_windowed(
                    state, feat, w, cfg, gamma=self.gcfg.window_decay,
                    alpha=self.gcfg.alpha,
                    warmup_items=self.gcfg.warmup_items,
                    rotate_every=self.gcfg.rotate_every,
                    table_mask=table_mask, item_mask=finite,
                    threshold_mode=self.gcfg.threshold_mode,
                    quantile_q=self.gcfg.quantile_q)
            buckets = hash_buckets(feat, w, cfg.srp)   # the ONE hash
            # tail + live gathers (the live one is the flat path's own)
            tail_sums, live_sums = ring.window_table_sums(state, buckets)
            if table_mask is None:
                scores = ring.score_live(tail_sums, live_sums,
                                         cfg.num_tables)
            else:
                # degraded: masked gathers for the DECISION; the
                # insert's ssq increment keeps the true (unmasked) sums
                mt, ml = ring.window_table_sums(state, buckets,
                                                table_mask=table_mask)
                scores = ring.score_live(mt, ml, cfg.num_tables,
                                         table_mask=table_mask)
            admit = scores >= ring.admit_threshold_windowed(
                state, self.gcfg.window_decay, self.gcfg.alpha,
                self.gcfg.warmup_items, table_mask=table_mask,
                threshold_mode=self.gcfg.threshold_mode,
                q=self.gcfg.quantile_q)
            admit = jnp.logical_and(admit, finite)
            new_state = ring.insert_current(
                state, buckets, admit, cfg,
                gamma=self.gcfg.window_decay,
                pre_sums=(tail_sums, live_sums))
            if self.gcfg.threshold_mode == "quantile":
                # observe BEFORE the clock below retires the live epoch
                from repro.quantile import sketch as qsk
                n_w = ring.combined_n(state, self.gcfg.window_decay)
                rates = scores / jnp.maximum(n_w, 1.0)
                new_state = ring.observe_current(
                    new_state, rates,
                    qsk.calib_mask(finite.astype(jnp.float32), n_w,
                                   self.gcfg.warmup_items))
            # eager epoch clock: the admit call that fills an epoch
            # rotates the ring on its way out (device-side cond)
            new_state = ring.maybe_rotate(new_state,
                                          self.gcfg.rotate_every,
                                          self.gcfg.window_decay)
            return new_state, admit
        if self.use_kernels:
            from repro.kernels import ops as kops
            return kops.ace_admit(state, feat, w, cfg,
                                  alpha=self.gcfg.alpha,
                                  warmup_items=self.gcfg.warmup_items,
                                  table_mask=table_mask,
                                  item_mask=finite,
                                  threshold_mode=self.gcfg.threshold_mode,
                                  quantile_q=self.gcfg.quantile_q)
        buckets = hash_buckets(feat, w, cfg.srp)       # the ONE hash
        scores = sk.lookup(state, buckets,             # same bucket ids
                           table_mask=table_mask)
        admit = scores >= sk.admit_threshold(
            state, self.gcfg.alpha, self.gcfg.warmup_items,
            table_mask=table_mask,
            threshold_mode=self.gcfg.threshold_mode,
            q=self.gcfg.quantile_q)
        admit = jnp.logical_and(admit, finite)
        new_state = sk.insert_buckets_masked(state, buckets, admit, cfg)
        if self.gcfg.threshold_mode == "quantile":
            from repro.quantile import sketch as qsk
            rates = scores / jnp.maximum(state.n, 1.0)
            new_state = new_state._replace(qhist=qsk.observe_rates(
                new_state.qhist, rates,
                qsk.calib_mask(finite.astype(jnp.float32), state.n,
                               self.gcfg.warmup_items)))
        return new_state, admit

    def admit(self, embeds: jax.Array,
              tenant_ids: jax.Array | None = None) -> np.ndarray:
        """(B, S, D) request embeddings -> (B,) bool admitted; admits update
        the sketch (the serving distribution drifts with traffic — the
        paper's dynamic-update property).  One host transfer: the packed
        verdict+quarantine block.

        Multi-tenant guardrails additionally take ``tenant_ids`` (B,)
        int32 routing each request to its own tenant's sketch.

        Non-finite rows are quarantined (sanitized out of the sketch,
        counted in ``self.quarantined``) and answered per
        ``gcfg.fail_policy``; while ``self.degraded`` the decision runs
        over healthy tables only — both with zero additional host syncs
        (the health mask is a device arg of a second cached executable,
        the quarantine count rides the one existing transfer)."""
        if self.multi_tenant:
            if tenant_ids is None:
                raise ValueError("multi-tenant guardrail needs tenant_ids")
            self.state, packed = self._admit(
                self.state, self.w, embeds,
                jnp.asarray(tenant_ids, jnp.int32), self._table_mask)
        else:
            if tenant_ids is not None:
                raise ValueError("tenant_ids given but num_tenants == 1")
            self.state, packed = self._admit(self.state, self.w, embeds,
                                             None, self._table_mask)
        out = np.asarray(packed)          # the ONE device→host transfer
        self.quarantined += int((~out[1]).sum())
        if self._rewarm_admits > 0:
            self._rewarm_admits -= 1      # host arithmetic, no syncs
        return out[0].astype(bool)

    @property
    def degraded(self) -> bool:
        """True while the serving mask excludes any table (health_check
        found corruption, or a repair is still re-warming)."""
        return self._table_mask is not None

    @property
    def fail_open_mask(self) -> np.ndarray:
        """(T,) host bool — True where the tenant's quarantine/shedding
        policy is fail_open (shed ⇒ admit), False for fail_closed
        (shed ⇒ reject).  The open-loop front end
        (``repro.serve.frontend``) reads this to answer load-shed
        requests with each tenant's OWN policy — the same verdict a
        quarantined row of that tenant gets."""
        return self._fail_open.copy()

    def health_check(self):
        """Audit the sketch invariants (repro.resilience.health_check)
        and refresh the serving table mask.  A control-plane call: it
        syncs the report to the host (the hot path never does).

        Returns the host-side ``HealthReport``.  Tables failing their
        invariants — or repaired tables still re-warming — are excluded
        from scoring on subsequent ``admit`` calls via the degraded
        executable; once every table passes again (and the re-warm
        window has elapsed) the mask drops back to None and the original
        healthy executable resumes.
        """
        from repro import resilience as rz
        report = rz.health_check(self.state, self._repair_offsets)
        host = jax.device_get(report)
        table_ok = np.asarray(host.table_ok, bool)
        serving = table_ok.copy()
        if self._repair_offsets is not None:
            # flat/fleet re-warm gate: a repaired table rejoins once it
            # has re-absorbed a warmup's worth of the live stream
            offs = np.asarray(jax.device_get(self._repair_offsets))
            n = np.asarray(jax.device_get(self.state.n), np.float32)
            seen = (n[..., None] if offs.ndim == n.ndim + 1 else n) - offs
            # only repaired tables (offset > 0) carry the re-warm gate
            serving &= (offs == 0) | (seen >= self.gcfg.warmup_items)
        if self._rewarm_admits > 0:
            # windowed re-warm gate: repaired ring tables stay masked
            # until the zeroed epochs have fully expired
            serving &= ~self._rewarming
        if serving.all():
            self._table_mask = None
        else:
            self._table_mask = jnp.asarray(serving, jnp.float32)
        return host

    def repair(self):
        """Re-zero every table failing its invariants (and any poisoned
        Welford stream) while the healthy tables keep serving — the
        repro.resilience repair ops, wired to this guardrail's sketch
        flavour.  Control-plane: syncs, retains the degraded mask over
        the repaired tables until they re-warm (flat/fleet: a warmup's
        worth of stream, tracked via repair offsets; windowed: one full
        ring of rotations, tracked host-side).  Returns the host-side
        pre-repair ``HealthReport``.
        """
        from repro import resilience as rz
        report = rz.health_check(self.state, self._repair_offsets)
        host = jax.device_get(report)
        table_ok = report.table_ok
        if self.multi_tenant and self.windowed:
            self.state = rz.repair_fleet_window(self.state, table_ok)
        elif self.multi_tenant:
            self.state, self._repair_offsets = rz.repair_fleet(
                self.state, table_ok, self._repair_offsets)
        elif self.windowed:
            self.state = rz.repair_window(self.state, table_ok)
        else:
            self.state, self._repair_offsets = rz.repair_ace(
                self.state, table_ok, self._repair_offsets)
        if self.windowed and not np.asarray(host.table_ok, bool).all():
            # E·rotate_every admits flush every zeroed epoch out
            self._rewarm_admits = (self.gcfg.window_epochs
                                   * self.gcfg.rotate_every)
            self._rewarming = ~np.asarray(host.table_ok, bool)
        if not np.asarray(host.moments_ok, bool).all():
            self.state = rz.repair_moments(self.state)
        self.health_check()
        return host


def _to_host(x: jax.Array) -> np.ndarray:
    """The ONE device→host transfer of a generate() call.

    A separate named function (not an inline np.asarray) so the decode
    loop's zero-sync contract is a single call site — tests wrap it to
    count transfers, and a stray np.asarray inside the loop would have to
    bypass it visibly.
    """
    return np.asarray(x)


class ServeEngine:
    """Greedy generation over a fixed batch (the paper-kind e2e driver)."""

    def __init__(self, arch: Arch, s_max: int = 256,
                 guardrail: Guardrail | None = None):
        self.arch = arch
        self.s_max = s_max
        self.guardrail = guardrail
        self._prefill = jax.jit(
            lambda p, b: arch.prefill(p, b, s_max=s_max))
        self._decode = jax.jit(arch.decode_step)

    def generate(self, params, batch, num_new_tokens: int,
                 prompt_len: int) -> np.ndarray:
        """Greedy decode.  Returns (B, num_new_tokens) int32.

        Tokens accumulate ON DEVICE across the decode loop and transfer
        once at the end — the pre-PR loop pulled every token to the host
        (``np.asarray(tok)`` per step), serialising decode on B·4-byte
        syncs; now the loop body enqueues async dispatches back-to-back
        and the only device→host transfer is the final (B, T) stack
        (``_to_host``; counted in tests/test_stream.py).
        """
        cfg = self.arch.cfg
        if self.guardrail is not None and "embeds" not in batch:
            embeds = jnp.take(params["embed"], batch["tokens"], axis=0)
            admit = self.guardrail.admit(embeds)
        logits, cache = self._prefill(params, batch)
        B = logits.shape[0]
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        toks = [tok]
        for i in range(1, num_new_tokens):
            pos = jnp.full((B,), prompt_len + i - 1, jnp.int32)
            if cfg.mrope_sections is not None:
                pos = jnp.broadcast_to(pos[None], (3, B))
            step_batch = {"tokens": tok[:, None]}
            logits, cache = self._decode(params, step_batch, cache, pos)
            tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            toks.append(tok)
        return _to_host(jnp.stack(toks, axis=1))    # the ONE transfer


def decode_throughput(arch: Arch, params, cache, batch, pos,
                      iters: int = 8) -> float:
    """tokens/sec of the jitted decode step (host-timed)."""
    step = jax.jit(arch.decode_step)
    logits, cache = step(params, batch, cache, pos)   # compile
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    for _ in range(iters):
        logits, cache = step(params, batch, cache, pos)
    jax.block_until_ready(logits)
    dt = (time.perf_counter() - t0) / iters
    return batch[next(iter(batch))].shape[0] / dt
