"""Batched serving engine: prefill + decode with KV caches, greedy/sampled
generation, and the ACE request guardrail (OOD requests rejected in O(K·L)
before touching the model — the paper's query phase as an admission filter).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sk
from repro.core.sketch import AceConfig
from repro.models.registry import Arch, is_whisper


@dataclasses.dataclass(frozen=True)
class GuardrailConfig:
    d_model: int
    num_bits: int = 13
    num_tables: int = 32
    alpha: float = 4.0
    warmup_items: float = 256.0
    bias_const: float = 0.25


class Guardrail:
    """ACE admission filter over request embeddings (stateful host wrapper).

    With a ``mesh``, the sketch state is placed via ``repro.dist``:
    ``sketch_layout="replicated"`` mirrors the counts on every device (the
    default single-device behaviour, scaled out), while ``"table_sharded"``
    splits the (L, 2^K) counts over the L axis across ``table_axis`` —
    jit/SPMD mode of repro.dist.sketch_parallel — so guardrail sketches
    beyond one device's memory (K=18+, L=200+) stay servable.
    """

    def __init__(self, gcfg: GuardrailConfig, *, mesh=None,
                 sketch_layout: str = "replicated",
                 table_axis: str = "model"):
        self.gcfg = gcfg
        self.ace_cfg = AceConfig(dim=gcfg.d_model + 1,
                                 num_bits=gcfg.num_bits,
                                 num_tables=gcfg.num_tables, seed=41,
                                 welford_min_n=gcfg.warmup_items / 2)
        self.state = sk.init(self.ace_cfg)
        self.w = sk.make_params(self.ace_cfg)
        if mesh is not None:
            from repro.dist.sketch_parallel import (
                table_shard_info, sketch_shardings,
                table_sharded_shardings)
            if sketch_layout == "table_sharded":
                table_shard_info(self.ace_cfg, mesh, table_axis)
                sh = table_sharded_shardings(mesh, table_axis)
            elif sketch_layout == "replicated":
                sh = sketch_shardings(mesh)
            else:
                raise ValueError(
                    f"unknown sketch layout {sketch_layout!r} "
                    "(want 'replicated' or 'table_sharded')")
            self.state = jax.device_put(self.state, sh)

    def _features(self, embeds: jax.Array) -> jax.Array:
        """Unit-normalised mean embedding + bias coordinate.

        Normalising first makes the (angular) SRP see DIRECTION drift at
        full resolution; the bias coordinate then re-encodes relative
        magnitude at a controlled weight (bias_const)."""
        f = jnp.mean(embeds.astype(jnp.float32), axis=1)
        f = f / (jnp.linalg.norm(f, axis=-1, keepdims=True) + 1e-9)
        bias = jnp.full((f.shape[0], 1), self.gcfg.bias_const, jnp.float32)
        return jnp.concatenate([f, bias], axis=-1)

    def admit(self, embeds: jax.Array) -> np.ndarray:
        """(B, S, D) request embeddings -> (B,) bool admitted; admits update
        the sketch (the serving distribution drifts with traffic — the
        paper's dynamic-update property)."""
        feat = self._features(embeds)
        scores = sk.score(self.state, self.w, feat, self.ace_cfg)
        rates = scores / max(float(self.state.n), 1.0)
        mu_rate = sk.mean_rate(self.state)
        sigma = sk.sigma_welford(self.state)
        armed = float(self.state.n) >= self.gcfg.warmup_items
        if armed:
            admit = np.asarray(rates >= mu_rate - self.gcfg.alpha * sigma)
        else:
            admit = np.ones(feat.shape[0], bool)
        kept = jnp.asarray(np.where(admit)[0], jnp.int32)
        if kept.size:
            self.state = sk.insert_buckets(
                self.state, sk.hash_buckets(feat[kept], self.w,
                                            self.ace_cfg.srp),
                self.ace_cfg)
        return admit


class ServeEngine:
    """Greedy generation over a fixed batch (the paper-kind e2e driver)."""

    def __init__(self, arch: Arch, s_max: int = 256,
                 guardrail: Guardrail | None = None):
        self.arch = arch
        self.s_max = s_max
        self.guardrail = guardrail
        self._prefill = jax.jit(
            lambda p, b: arch.prefill(p, b, s_max=s_max))
        self._decode = jax.jit(arch.decode_step)

    def generate(self, params, batch, num_new_tokens: int,
                 prompt_len: int) -> np.ndarray:
        """Greedy decode.  Returns (B, num_new_tokens) int32."""
        cfg = self.arch.cfg
        if self.guardrail is not None and "embeds" not in batch:
            embeds = jnp.take(params["embed"], batch["tokens"], axis=0)
            admit = self.guardrail.admit(embeds)
        logits, cache = self._prefill(params, batch)
        B = logits.shape[0]
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        out = [np.asarray(tok)]
        for i in range(1, num_new_tokens):
            pos = jnp.full((B,), prompt_len + i - 1, jnp.int32)
            if cfg.mrope_sections is not None:
                pos = jnp.broadcast_to(pos[None], (3, B))
            step_batch = {"tokens": tok[:, None]}
            logits, cache = self._decode(params, step_batch, cache, pos)
            tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            out.append(np.asarray(tok))
        return np.stack(out, axis=1)


def decode_throughput(arch: Arch, params, cache, batch, pos,
                      iters: int = 8) -> float:
    """tokens/sec of the jitted decode step (host-timed)."""
    step = jax.jit(arch.decode_step)
    logits, cache = step(params, batch, cache, pos)   # compile
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    for _ in range(iters):
        logits, cache = step(params, batch, cache, pos)
    jax.block_until_ready(logits)
    dt = (time.perf_counter() - t0) / iters
    return batch[next(iter(batch))].shape[0] / dt
