"""Open-loop serving front end: bounded queues, deadlines, load shedding.

``Guardrail.admit`` is a fixed-shape batch program; production traffic
is not — requests arrive one at a time, from many tenants, at whatever
rate the world offers.  Closed-loop benchmarks (issue the next batch
when the last returns) hide everything that matters about that gap:
an overloaded closed loop just slows its own offered rate, while an
overloaded OPEN loop grows a queue without bound and every request's
latency diverges.  This front end makes overload a measured, bounded
event instead:

* **Coalescing**: requests queue and are served as mixed-tenant
  batches of the guardrail's fixed shape ``B`` — short batches pad
  with NaN rows, which the guardrail's quarantine path already
  sanitizes (padding is never inserted into any sketch; the pad rows
  are subtracted from the quarantine stat via ``pad_rows``).
* **Bounded queue**: at most ``max_queue`` requests wait; beyond that,
  arrivals shed immediately (tail drop).  Queue memory AND worst-case
  queueing delay are both bounded by construction.
* **Deadlines**: every request carries an absolute deadline
  (``submit`` time + slack).  ``pump`` sheds, BEFORE serving, any
  request that could not make its deadline even if it rode the very
  next batch (measured EWMA service time) — the batch never wastes
  capacity on requests that are already dead on arrival at the device.
* **Policy-honoring shedding**: a shed request is answered with its
  tenant's ``fail_policy`` — fail_open tenants shed to ADMIT (availability
  over filtering: an overloaded guardrail must not take the product
  down), fail_closed tenants shed to REJECT (a security-critical
  tenant would rather drop traffic than let unscreened items through).
  Same verdict a quarantined row of that tenant gets — one policy,
  every degraded path.

Single-threaded by design: ``submit``/``pump`` are called from one
serving loop (or the Poisson bench, ``benchmarks/openloop_bench.py``);
the clock is injectable so every shedding decision is unit-testable
with a fake clock.
"""
from __future__ import annotations

import collections
import dataclasses
import time

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FrontEndConfig:
    batch_size: int                  # the guardrail's fixed batch shape
    seq: int                         # fixed (S, D) request embed shape
    d_model: int
    max_queue: int = 256             # bounded: beyond this, tail-drop
    default_deadline: float = 0.050  # seconds of slack per request
    max_wait: float = 0.005          # serve a partial batch after this
    service_ewma: float = 0.3        # EWMA weight of the newest sample

    def __post_init__(self):
        if self.batch_size < 1 or self.max_queue < 1:
            raise ValueError("batch_size and max_queue must be >= 1")


@dataclasses.dataclass
class Ticket:
    """One request's lifecycle: queued → served | shed."""

    tenant: int
    deadline: float                  # absolute, front-end clock
    t_submit: float
    status: str = "queued"           # queued | served | shed
    admitted: bool | None = None
    reason: str | None = None        # queue_full | deadline (shed only)
    t_done: float | None = None

    @property
    def latency(self) -> float | None:
        return None if self.t_done is None else self.t_done - self.t_submit


class FrontEnd:
    """Open-loop request batcher in front of one ``Guardrail``."""

    def __init__(self, guardrail, cfg: FrontEndConfig,
                 clock=time.perf_counter):
        self.g = guardrail
        self.cfg = cfg
        self.clock = clock
        self._q: collections.deque[tuple[Ticket, np.ndarray]] = \
            collections.deque()
        self._est_service: float | None = None   # EWMA sec per batch
        self.submitted = 0
        self.served = 0
        self.shed_queue_full = 0
        self.shed_deadline = 0
        self.pad_rows = 0        # NaN pad rows fed to the guardrail —
        #                          subtract from g.quarantined for the
        #                          true dirty-traffic count

    # -- intake ------------------------------------------------------------

    def submit(self, embed: np.ndarray, tenant: int = 0,
               deadline: float | None = None) -> Ticket:
        """Enqueue one (S, D) request.  Never blocks: a full queue sheds
        immediately (the bounded-queue contract).

        ``deadline`` is ABSOLUTE on the front-end clock (the documented
        ``Ticket.deadline`` contract); ``None`` derives one as submit
        time + ``cfg.default_deadline`` slack.  (This used to silently
        treat the argument as relative slack — callers anchoring
        deadlines to scheduled arrival times, e.g. the coordinated-
        omission-corrected open-loop bench, got their deadlines
        re-anchored to the submit call instead, deferring every
        deadline by the submit lag exactly when the system was
        overloaded.)"""
        now = self.clock()
        t = Ticket(tenant=int(tenant),
                   deadline=(now + self.cfg.default_deadline
                             if deadline is None else float(deadline)),
                   t_submit=now)
        self.submitted += 1
        if len(self._q) >= self.cfg.max_queue:
            self._shed(t, "queue_full")
            return t
        embed = np.asarray(embed, np.float32)
        if embed.shape != (self.cfg.seq, self.cfg.d_model):
            raise ValueError(f"request embed shape {embed.shape} != "
                             f"({self.cfg.seq}, {self.cfg.d_model})")
        self._q.append((t, embed))
        return t

    def _shed(self, ticket: Ticket, reason: str) -> None:
        mask = self.g.fail_open_mask
        fail_open = bool(mask[ticket.tenant if len(mask) > 1 else 0])
        ticket.status = "shed"
        ticket.reason = reason
        ticket.admitted = fail_open           # fail_open ⇒ shed-to-admit
        ticket.t_done = self.clock()
        if reason == "queue_full":
            self.shed_queue_full += 1
        else:
            self.shed_deadline += 1

    # -- service -----------------------------------------------------------

    @property
    def queue_len(self) -> int:
        return len(self._q)

    @property
    def est_service(self) -> float:
        """EWMA seconds per served batch (0.0 until first measurement)."""
        return self._est_service or 0.0

    def ready(self) -> bool:
        """A batch is due: the queue fills the fixed shape, or the
        oldest waiter has been queued for ``max_wait``."""
        if not self._q:
            return False
        return (len(self._q) >= self.cfg.batch_size
                or self.clock() - self._q[0][0].t_submit
                >= self.cfg.max_wait)

    def pump(self, force: bool = False) -> int:
        """Serve at most one batch.  Returns requests served (0 when the
        batch is not due yet).  Deadline-aware: requests that cannot
        make their deadline even on the NEXT batch are shed first, so
        device capacity is never spent on already-lost requests."""
        now = self.clock()
        # Cold start: until ONE batch has actually been measured there is
        # no service estimate — est_service's 0.0 placeholder is not a
        # measurement, and shedding against it turns every queued-past-
        # deadline request into a "deadline" drop before the front end
        # has served anything (the very first pump is also the jit trace,
        # so tickets routinely age past short deadlines while the
        # executable builds).  Admit optimistically: serve the batch, let
        # the first real sample arm the shed path.
        if self._est_service is not None:
            est = self._est_service
            while self._q:
                ticket, _ = self._q[0]
                if now + est > ticket.deadline:
                    self._q.popleft()
                    self._shed(ticket, "deadline")
                else:
                    break
        if not self._q or not (force or self.ready()):
            return 0
        take = min(self.cfg.batch_size, len(self._q))
        batch = [self._q.popleft() for _ in range(take)]
        B = self.cfg.batch_size
        embeds = np.full((B, self.cfg.seq, self.cfg.d_model), np.nan,
                         np.float32)
        tenants = np.zeros(B, np.int32)
        for i, (tk, e) in enumerate(batch):
            embeds[i] = e
            tenants[i] = tk.tenant
        self.pad_rows += B - take
        t0 = self.clock()
        if getattr(self.g, "multi_tenant", False):
            verdicts = self.g.admit(jnp.asarray(embeds),
                                    jnp.asarray(tenants))
        else:
            verdicts = self.g.admit(jnp.asarray(embeds))
        verdicts = np.asarray(verdicts)   # ONE packed transfer — per-
        #                                   element device reads would
        #                                   cost a sync per request
        dt = self.clock() - t0
        w = self.cfg.service_ewma
        self._est_service = dt if self._est_service is None \
            else (1 - w) * self._est_service + w * dt
        done = self.clock()
        for i, (tk, _) in enumerate(batch):
            tk.status = "served"
            tk.admitted = bool(verdicts[i])
            tk.t_done = done
        self.served += take
        return take

    def drain(self) -> int:
        """Serve everything still queued (partial final batch forced)."""
        total = 0
        while self._q:
            total += self.pump(force=True)
        return total

    # -- observability -----------------------------------------------------

    def metrics(self) -> dict:
        shed = self.shed_queue_full + self.shed_deadline
        return {
            "submitted": self.submitted,
            "served": self.served,
            "shed_queue_full": self.shed_queue_full,
            "shed_deadline": self.shed_deadline,
            "shed_rate": shed / max(self.submitted, 1),
            "queue_len": self.queue_len,
            "est_service_s": self.est_service,
            "pad_rows": self.pad_rows,
        }
