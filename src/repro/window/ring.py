"""Sliding-window ACE: a device-resident ring of sketch epochs.

The paper's dynamic-update pitch (§3.4.1: O(K·L) insert *and* delete) is
what separates ACE from batch detectors — but the repo's base sketch still
accumulates counts forever, so under concept drift μ/σ and the μ−ασ admit
threshold go stale: the historical mass dominates μ, the regime mix
inflates σ, and the filter either flags everything or nothing.  Streaming
baselines (EXPoSE's decayed feature mean, the in-DRAM active-flows table)
solve this with windows/decay; ACE's count algebra makes it cheap — counts
are an additive monoid, so a window is just a SUM OF EPOCH SKETCHES and
expiry is zeroing one epoch, never replaying a delete stream.

``WindowedAceState`` holds E epoch sketches stacked on a leading axis,
plus a maintained γ-weighted TAIL view so the hot path never recombines
epochs:

    counts        (E, L, 2^K)   per-epoch count arrays
    n             (E,)          per-epoch item counts
    welford_mean  (E,)          per-epoch streaming rate mean
    welford_m2    (E,)          per-epoch streaming rate M2
    tail          (L, 2^K) f32  Σ_{e≠cursor} γ^age · C_e  (maintained)
    ssq           ()       f32  ‖C_w‖², C_w = tail + C_cursor
    cursor        ()  int32     index of the LIVE epoch (ring pointer)
    tick          ()  int32     insert steps since init (drives rotation)

The split matters for throughput: the live epoch takes every insert, the
tail only changes at rotation.  So an insert is ONE scatter (identical
to the flat sketch's) and a windowed score is the live gather the flat
sketch does anyway plus one extra gather against the frozen tail —
O(B·L) either way, independent of E.  A maintained full-combine view
would instead pay a SECOND scatter per insert (measured: scatters cost
~2× gathers on the scan hot path), and query-time epoch recombination
would pay E gathers plus O(E·L·2^K) moment sweeps (measured: halved
ingest at E=6).

Everything here is pure and fixed-shape — jit/scan/donation safe, no
host syncs anywhere:

* ``rotate``       — advance the ring: cursor moves one slot (O(1)
                     pointer math), the slot it moves INTO (the expired
                     epoch) is zeroed, and the tail absorbs the old live
                     epoch, sheds the expired one, and decays one γ
                     step.  O(L·2^K) device work ONCE PER EPOCH —
                     amortised over the ``rotate_every`` steps the epoch
                     lasted; the per-step hot path never touches full
                     tables.
* ``insert_current`` — masked insert into the live epoch (one scatter),
                     with ``ssq`` advanced by the windowed Eq. 11
                     increment  Δ‖C_w‖² = 2⟨h, C_w⟩ + ‖h‖²  recovered
                     from the pre/post score gathers the step does
                     anyway.  Every term is an integer-valued float32
                     for γ=1 (exact while < 2^24 — the same envelope as
                     every count reduction in the repo).
* ``window_table_sums`` / ``score_live`` — the hot-path windowed score:
                     tail gather + live gather, combined per item.
* ``score_windowed`` — the query-time E-way combine (reference + the
                     contract of the ``ace_window_combine`` Pallas
                     kernel): works for ANY γ, reads all E epochs.
* ``admit_threshold_windowed`` — the μ−ασ score-space rule from
                     WINDOW-combined moments: μ_w from the maintained
                     ``ssq`` (γ-generalised Eq. 11 closed form), σ_w by
                     a γ-weighted Chan merge of the per-epoch Welford
                     streams.

γ is a CONFIG property (``WindowConfig.decay``), not stored in the
state: the ``tail``/``ssq`` caches are maintained AT that γ, so every
call that takes a ``gamma`` argument must pass the ring's own decay
(the filter/guardrail/train wrappers thread it; mixing γs is checked
only by the ``*_direct`` test oracles).

Degenerate-case contracts (tests/test_window.py + the property suite):
with E=1 every windowed op is BITWISE the plain ``AceState`` op (the
tail is identically zero, γ⁰ = 1 exactly, and the moment fold starts
from epoch 0's scalars, not a zero accumulator); with γ=1 and no
rotation the E-epoch window is ``sketch.merge`` of the epochs;
``rotate`` applied E times returns the ring to an all-zero sketch with
the cursor back where it started.

HBM accounting: E epochs + the f32 tail cost (E + 2) × the paper's
int16 base sketch (K=15, L=50: E=8 → 31 MB — still far under one
device), and the window length in items is E × rotate_every × B,
tunable at constant memory by trading E against rotate_every.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import sketch as sk
from repro.core.sketch import AceConfig, AceState


class WindowedAceState(NamedTuple):
    """Ring of E epoch sketches + the maintained γ-weighted tail view
    (a pytree — jit/scan/psum/donation safe)."""

    counts: jax.Array        # (E, L, 2^K) counter dtype
    n: jax.Array             # (E,) float32
    welford_mean: jax.Array  # (E,) float32
    welford_m2: jax.Array    # (E,) float32
    tail: jax.Array          # (L, 2^K) float32 — Σ_{e≠cursor} γ^age·C_e
    ssq: jax.Array           # () float32 — ‖tail + C_cursor‖²
    cursor: jax.Array        # ()  int32 — live epoch index
    tick: jax.Array          # ()  int32 — insert steps since init
    qhist: Optional[jax.Array] = None  # (E, quantile.NUM_BINS) float32
    #                          per-epoch collision-rate histograms for
    #                          threshold_mode="quantile"; None (default)
    #                          keeps every existing pytree contract
    attr: Optional[jax.Array] = None  # (E, 2, NL, R, C) float32 per-epoch
    #                          signed count-sketch attribution planes
    #                          (repro.attribution); None (default) keeps
    #                          every existing pytree contract

    @property
    def num_epochs(self) -> int:
        return self.counts.shape[0]


@dataclasses.dataclass(frozen=True)
class WindowConfig:
    """Static window configuration (hashable; safe as a jit static arg).

    decay γ: epoch e is weighted γ^age in the window combine.  γ=1 is
    the hard window (all live epochs weigh equally; expiry is the only
    forgetting); γ<1 additionally down-weights older epochs —
    EXPoSE-style exponential decay at epoch granularity, with none of
    the per-item decay cost.

    rotate_every: insert steps per epoch (0 = never rotate — the window
    degenerates to the frozen sketch).  The window spans
    ``num_epochs × rotate_every`` steps of history.
    """

    ace: AceConfig
    num_epochs: int = 4
    decay: float = 1.0
    rotate_every: int = 0

    def __post_init__(self):
        if self.num_epochs < 1:
            raise ValueError(f"num_epochs must be >= 1, got {self.num_epochs}")
        if not (0.0 < self.decay <= 1.0):
            raise ValueError(f"decay must be in (0, 1], got {self.decay}")
        if self.ace.esc_capacity > 0:
            raise NotImplementedError(
                "overflow promotion (esc_capacity > 0) is wired for the "
                "flat sketch only; window rings take narrow count dtypes "
                "without an escalation table (exact below saturation). "
                "See docs/ARCHITECTURE.md §7.")

    def memory_bytes(self) -> int:
        """The window's HBM bill: E epochs + the f32 tail view."""
        ace = self.ace
        tail = ace.num_tables * ace.num_buckets * 4
        return self.num_epochs * ace.memory_bytes() + tail


def init(cfg: AceConfig, num_epochs: int,
         quantile: bool = False) -> WindowedAceState:
    if num_epochs < 1:
        raise ValueError(f"num_epochs must be >= 1, got {num_epochs}")
    if cfg.esc_capacity > 0:
        raise NotImplementedError(
            "overflow promotion (esc_capacity > 0) is flat-sketch only; "
            "window rings take narrow count dtypes without promotion")
    if quantile:
        from repro.quantile import sketch as qsk
        qhist = qsk.init_hist(num_epochs)
    else:
        qhist = None
    acfg = cfg.attr
    attr = (jnp.zeros((num_epochs,) + acfg.plane_shape(), jnp.float32)
            if acfg is not None else None)
    return WindowedAceState(
        counts=jnp.zeros((num_epochs, cfg.num_tables, cfg.num_buckets),
                         dtype=jnp.dtype(cfg.counter_dtype)),
        n=jnp.zeros((num_epochs,), jnp.float32),
        welford_mean=jnp.zeros((num_epochs,), jnp.float32),
        welford_m2=jnp.zeros((num_epochs,), jnp.float32),
        tail=jnp.zeros((cfg.num_tables, cfg.num_buckets), jnp.float32),
        ssq=jnp.zeros((), jnp.float32),
        cursor=jnp.zeros((), jnp.int32),
        tick=jnp.zeros((), jnp.int32),
        qhist=qhist,
        attr=attr,
    )


def init_window(cfg: WindowConfig, quantile: bool = False) -> WindowedAceState:
    return init(cfg.ace, cfg.num_epochs, quantile=quantile)


# ---------------------------------------------------------------------------
# Ring mechanics.
# ---------------------------------------------------------------------------

def rotate(state: WindowedAceState, gamma: float = 1.0) -> WindowedAceState:
    """Advance the ring: the oldest epoch expires and becomes the new
    live epoch (zeroed counts AND zeroed moments), and the tail is
    RECOMPUTED from the updated ring as one weighted tensordot:

        tail' = Σ_e γ^age'_e · C'_e      (the zeroed new-live slab
                                          contributes nothing)

    The incremental fold this replaced — γ·(tail + C_live −
    γ^{E−1}·C_expired) — was algebraically identical but NOT bitwise
    stable for γ<1: when traced into a larger program (the maybe_rotate
    cond, a vmapped fleet, a scan body) XLA CPU fuses the
    subtract-of-product into an FMA, rounding the decayed tail up to
    1 ulp (up to ~700 ulp after the γ multiply) differently than the
    eager op-by-op sequence (an optimization_barrier did not stop it —
    measured), which forced the strict bitwise windowed contracts to
    pin γ=1.  A single dot_general lowers identically across
    eager/jit/cond/scan/vmap (and the fleet-native einsum matches the
    vmapped form bitwise — both verified empirically on this backend),
    so γ<1 is now bitwise across execution contexts, and the recompute
    additionally flushes any incremental float error in the tail once
    per epoch instead of letting it γ-decay.  Same O(L·2^K) cost class
    as the old fold — once per ``rotate_every`` steps, never on the
    per-item path, and nothing here syncs to the host.  ``ssq`` is
    recomputed from the new tail (the new live epoch is empty, so
    ‖C_w‖² = ‖tail'‖²).  Applied E times this returns the ring to the
    all-zero init with the cursor back where it started
    (property-tested).
    """
    E = state.num_epochs
    new_cursor = jnp.mod(state.cursor + 1, E)
    zero_slab = jnp.zeros(state.counts.shape[1:], state.counts.dtype)
    counts = jax.lax.dynamic_update_index_in_dim(
        state.counts, zero_slab, new_cursor, axis=0)
    w = epoch_weights(new_cursor, E, gamma)
    tail = jnp.tensordot(w, counts.astype(jnp.float32), axes=1)
    zero1 = jnp.zeros((1,), jnp.float32)
    qhist = state.qhist
    if qhist is not None:
        qhist = jax.lax.dynamic_update_index_in_dim(
            qhist, jnp.zeros((qhist.shape[1],), jnp.float32),
            new_cursor, axis=0)
    attr = state.attr
    if attr is not None:
        attr = jax.lax.dynamic_update_index_in_dim(
            attr, jnp.zeros(attr.shape[1:], jnp.float32),
            new_cursor, axis=0)
    return WindowedAceState(
        counts=counts,
        n=jax.lax.dynamic_update_slice(state.n, zero1, (new_cursor,)),
        welford_mean=jax.lax.dynamic_update_slice(
            state.welford_mean, zero1, (new_cursor,)),
        welford_m2=jax.lax.dynamic_update_slice(
            state.welford_m2, zero1, (new_cursor,)),
        tail=tail,
        ssq=jnp.sum(tail * tail),
        cursor=new_cursor,
        tick=state.tick,
        qhist=qhist,
        attr=attr,
    )


def maybe_rotate(state: WindowedAceState, rotate_every: int,
                 gamma: float = 1.0) -> WindowedAceState:
    """Rotate when the tick says the live epoch is full.

    Call AFTER an insert step (``insert_current`` bumps the tick): the
    R-th insert completes an epoch and the ring rotates eagerly, so each
    epoch holds exactly ``rotate_every`` steps and every driver (the
    per-batch filter ``__call__``, the guardrail admit, the train tail
    path) sees the same rotation positions as the stream runner's
    cond-free segment scan.  Pure device control flow (lax.cond on
    device scalars — scan-safe, no host sync), but note the cond makes
    XLA copy the carry on every call — fine once per host-driven batch,
    NOT fine inside a scan body, which is why ``StreamRunner`` lowers
    rotation to straight-line segment boundaries instead whenever the
    chunk shape allows (see ``_consume_impl``).  With
    ``rotate_every <= 0`` this is the identity.
    """
    if rotate_every <= 0:
        return state
    should = jnp.logical_and(state.tick > 0,
                             jnp.mod(state.tick, rotate_every) == 0)
    return jax.lax.cond(should, lambda s: rotate(s, gamma), lambda s: s,
                        state)


def live_epoch(state: WindowedAceState) -> AceState:
    """The live epoch as a plain ``AceState`` view (traced-index gather)."""
    return AceState(
        counts=jax.lax.dynamic_index_in_dim(
            state.counts, state.cursor, axis=0, keepdims=False),
        n=jnp.take(state.n, state.cursor),
        welford_mean=jnp.take(state.welford_mean, state.cursor),
        welford_m2=jnp.take(state.welford_m2, state.cursor),
    )


def window_table_sums(state: WindowedAceState, buckets: jax.Array,
                      table_mask: jax.Array | None = None):
    """Hot-path windowed table sums, split by provenance:

        tail_sums[i] = Σ_j tail[j, b_ij]         (frozen between rotations)
        live_sums[i] = Σ_j C_cursor[j, b_ij]     (pre-insert)

    Two (B, L) gathers — the live one is what the flat sketch gathers
    anyway, the tail one is the whole extra per-step cost of windowing,
    independent of E.  The live gather addresses the ring as an
    (E·L, 2^K) matrix with row offset cursor·L (a 3-index gather lowers
    poorly, and slab-slicing the epoch copies (L, 2^K) per step).
    Returns (tail_sums, live_sums), both (B,) integer-valued float32
    (tail exactly so only when γ=1).

    ``table_mask`` (L,) zeroes corrupted tables out of BOTH row-sums —
    the Python-level ``None`` branch keeps the healthy program untouched
    (the repo-wide degraded-mode convention, see
    ``sketch.batch_scores``).  The caller pairs this with the masked
    ``score_live`` combine, which divides by the healthy count.
    """
    E, L, nbuckets = state.counts.shape
    rows = jnp.broadcast_to(
        jnp.arange(L, dtype=jnp.int32)[None, :], buckets.shape)
    ring_rows = rows + state.cursor * L
    flat = state.counts.reshape(E * L, nbuckets)
    tail_g = state.tail[rows, buckets]                           # (B, L)
    live_g = flat[ring_rows, buckets].astype(jnp.float32)        # (B, L)
    if table_mask is not None:
        maskf = table_mask.astype(jnp.float32)
        tail_g = tail_g * maskf
        live_g = live_g * maskf
    return jnp.sum(tail_g, axis=-1), jnp.sum(live_g, axis=-1)


def score_live(tail_sums: jax.Array, live_sums: jax.Array,
               num_tables: int,
               table_mask: jax.Array | None = None) -> jax.Array:
    """(tail_sums, live_sums) -> (B,) windowed scores.

    The canonical combine: one add, ONE reciprocal multiply by 1/L
    (same literal constant as ``sketch.batch_scores``).  With E=1 the
    tail is identically zero and ``0.0 + x`` is exact, so this is
    ``batch_scores`` bitwise.

    With ``table_mask`` the sums are assumed already masked (from the
    masked ``window_table_sums``) and the reciprocal is 1/num_healthy —
    the degraded-mode mean over surviving tables."""
    if table_mask is None:
        return (tail_sums + live_sums) * jnp.float32(1.0 / num_tables)
    nh = jnp.maximum(jnp.sum(table_mask.astype(jnp.float32)), 1.0)
    return (tail_sums + live_sums) * (1.0 / nh)


def score_combined(state: WindowedAceState,
                   buckets: jax.Array) -> jax.Array:
    """Hot-path windowed Ŝ(q) at the ring's own γ: tail + live gathers,
    canonical combine.  For arbitrary-γ queries use ``score_windowed``."""
    tail_sums, live_sums = window_table_sums(state, buckets)
    return score_live(tail_sums, live_sums, state.counts.shape[1])


def insert_current(state: WindowedAceState, buckets: jax.Array,
                   mask: jax.Array, cfg: AceConfig, gamma: float = 1.0,
                   pre_sums=None) -> WindowedAceState:
    """Masked insert into the LIVE epoch; bumps the tick by one step.

    ONE 2-D scatter, exactly like ``sketch.insert_buckets_masked`` (the
    ring is addressed as an (E·L, 2^K) matrix with row offset cursor·L;
    the tail is untouched — it only changes at rotation).

    ``ssq`` advances by the windowed Eq. 11 increment without touching
    a full table: with h the masked batch histogram and m_· the masked
    sums of the pre/post gathers this step does anyway
    (``pre_sums = (tail_sums, live_sums)`` lets the caller pass the
    scoring gathers it already did),

        Δ‖C_w‖² = 2⟨h, C_w⟩ + ‖h‖²
                 = 2·m_tail + m_live_pre + m_live_post

    since ⟨h, C_w⟩ = ⟨h, tail⟩ + ⟨h, C_cur⟩ and ⟨h, C_cur + h⟩ =
    ⟨h, C_cur⟩ + ‖h‖² — the batch analogue of the paper's (2A+1)
    streaming term.

    The per-epoch Welford stream folds the POST-insert WINDOWED rates
    (score_w / n_w — the same quantity the threshold tests), mirroring
    ``sketch.masked_batch_welford`` term for term with the stream-length
    weighting on the epoch's own n; with E=1 the fold is bitwise the
    flat masked insert's, and the ``welford_min_n`` cold-start gate
    re-arms after every rotation exactly as it does at sketch init.
    """
    E = state.num_epochs
    L = buckets.shape[1]
    nbuckets = state.counts.shape[2]
    rows = jnp.broadcast_to(
        jnp.arange(L, dtype=jnp.int32)[None, :], buckets.shape)
    ring_rows = rows + state.cursor * L
    maskf = mask.astype(jnp.float32)

    if pre_sums is None:
        pre_sums = window_table_sums(state, buckets)
    tail_sums, live_pre = pre_sums

    # -- THE scatter (live epoch rows of the ring)
    w_ctr = jnp.broadcast_to(
        mask.astype(state.counts.dtype)[:, None], buckets.shape)
    new_ring = state.counts.reshape(E * L, nbuckets) \
        .at[ring_rows, buckets].add(w_ctr).reshape(state.counts.shape)

    # -- post-insert windowed sums/scores (tail unchanged; same float
    #    sequence as sketch.batch_scores: row-sum, add, ONE 1/L
    #    reciprocal multiply)
    live_post = jnp.sum(
        new_ring.reshape(E * L, nbuckets)[ring_rows, buckets]
        .astype(jnp.float32), axis=-1)
    scores = score_live(tail_sums, live_post, L)

    # -- ssq increment from masked pre/post sums only
    m_tail = jnp.sum(tail_sums * maskf)
    m_pre = jnp.sum(live_pre * maskf)
    m_post = jnp.sum(live_post * maskf)
    new_ssq = state.ssq + 2.0 * m_tail + m_pre + m_post

    # -- per-epoch Welford fold of windowed post-insert rates; mirrors
    #    sketch.masked_batch_welford with the epoch's n as the stream
    #    length and the WINDOW's n as the rate normaliser (equal when
    #    E=1 — bitwise the flat fold)
    b = jnp.sum(maskf)
    n_e = jnp.take(state.n, state.cursor)
    tot_e = n_e + b
    n_w = combined_n(state, gamma) + b
    rates = scores / jnp.maximum(n_w, 1.0)
    mean_b = jnp.sum(rates * maskf) / jnp.maximum(b, 1.0)
    m2_b = jnp.sum(((rates - mean_b) ** 2) * maskf)
    new_mean, new_m2 = sk.welford_fold(
        jnp.take(state.welford_mean, state.cursor),
        jnp.take(state.welford_m2, state.cursor),
        n_e, b, tot_e, mean_b, m2_b, cfg.welford_min_n)
    has = b > 0
    new_mean = jnp.where(has, new_mean,
                         jnp.take(state.welford_mean, state.cursor))
    new_m2 = jnp.where(has, new_m2,
                       jnp.take(state.welford_m2, state.cursor))

    c = state.cursor
    return state._replace(
        counts=new_ring,
        n=jax.lax.dynamic_update_slice(state.n, tot_e[None], (c,)),
        welford_mean=jax.lax.dynamic_update_slice(
            state.welford_mean, new_mean[None], (c,)),
        welford_m2=jax.lax.dynamic_update_slice(
            state.welford_m2, new_m2[None], (c,)),
        ssq=new_ssq,
        tick=state.tick + 1)


# ---------------------------------------------------------------------------
# Window-combined views: weights, counts, scores, moments, threshold.
# ---------------------------------------------------------------------------

def epoch_weights(cursor: jax.Array, num_epochs: int,
                  gamma: float) -> jax.Array:
    """(E,) float32 query-time weights: γ^age, age = (cursor − e) mod E.

    The live epoch always weighs exactly 1.0 (γ^0 — exact in float), so
    every windowed op with E=1 reduces to a multiply-by-1.0, keeping the
    single-epoch window bitwise equal to the plain sketch path.
    """
    ages = jnp.mod(cursor - jnp.arange(num_epochs, dtype=jnp.int32),
                   num_epochs)
    return jnp.power(jnp.float32(gamma), ages.astype(jnp.float32))


def decayed_counts(state: WindowedAceState, gamma: float) -> jax.Array:
    """γ-weighted combined counts recomputed FROM THE EPOCHS:
    C_w = Σ_e γ^age · C_e   (L, 2^K) f32.

    The test oracle for the maintained ``state.tail`` (C_w minus the
    live epoch; bitwise for γ=1 where everything is exact integers,
    float-tolerance for γ<1, where the maintained view's error also
    γ-decays every rotation).  With γ=1 this is the exact hard-window
    count sum (the monoid merge of the live epochs)."""
    w = epoch_weights(state.cursor, state.num_epochs, gamma)
    return jnp.tensordot(w, state.counts.astype(jnp.float32), axes=1)


def score_windowed(state: WindowedAceState, buckets: jax.Array,
                   gamma: float) -> jax.Array:
    """Query-time E-way windowed Ŝ(q) (any γ, reads every epoch):

        score(q) = (1/L) · Σ_e γ^age_e · Σ_j C_e[j, H_j(q)]

    CANONICAL summation order — per-epoch row-sum in float32, weighted,
    accumulated over e in ring-index order, then ONE reciprocal multiply
    by 1/L (same literal constant as ``sketch.batch_scores``).  The
    Pallas kernel (``repro.kernels.ace_window_combine``) and its
    ``kernels.ref`` oracle implement the same formula sequence
    (kernel-side reductions agree to float tolerance, the usual
    score-kernel contract); with E=1 the whole thing is ``batch_scores``
    bitwise (1.0-weight multiply is exact), and at the ring's own γ it
    matches the tail+live hot path (``score_combined``) — bitwise for
    γ=1.
    """
    L = state.counts.shape[1]
    return score_from_sums(epoch_table_sums(state, buckets),
                           state.cursor, gamma, L)


def epoch_table_sums(state: WindowedAceState,
                     buckets: jax.Array) -> jax.Array:
    """Per-epoch table sums  t[e, i] = Σ_j C_e[j, b_ij]   (E, B) f32.

    One fused gather for all E epochs (the ring addressed as an
    (E·L, 2^K) matrix) — the reference/diagnostic path behind
    ``score_windowed``; the hot path gathers tail + live instead."""
    E, L, nbuckets = state.counts.shape
    B = buckets.shape[0]
    ring_rows = (jnp.arange(E, dtype=jnp.int32)[:, None] * L
                 + jnp.arange(L, dtype=jnp.int32)[None, :]).reshape(-1)
    rows = jnp.broadcast_to(ring_rows[None, :], (B, E * L))
    cols = jnp.tile(buckets, (1, E))
    flat = state.counts.reshape(E * L, nbuckets)
    gathered = flat[rows, cols].astype(jnp.float32)      # (B, E·L)
    return jnp.sum(gathered.reshape(B, E, L), axis=-1).T  # (E, B)


def score_from_sums(sums: jax.Array, cursor: jax.Array, gamma: float,
                    num_tables: int) -> jax.Array:
    """(E, B) per-epoch table sums -> (B,) windowed scores (the canonical
    combine order; see ``score_windowed``)."""
    E = sums.shape[0]
    w = epoch_weights(cursor, E, gamma)
    acc = jnp.zeros(sums.shape[1:], jnp.float32)
    for e in range(E):  # static unroll, ring-index order (kernel parity)
        acc = acc + w[e] * sums[e]
    return acc * jnp.float32(1.0 / num_tables)


def combined_n(state: WindowedAceState, gamma: float) -> jax.Array:
    """Effective window item count  n_w = Σ_e γ^age · n_e."""
    w = epoch_weights(state.cursor, state.num_epochs, gamma)
    return jnp.sum(w * state.n)


def combined_qhist(state: WindowedAceState, gamma: float) -> jax.Array:
    """γ-weighted combined-window rate histogram:
    H_w = Σ_e γ^age · H_e   (NUM_BINS,) f32 — the same ``epoch_weights``
    tensordot as ``decayed_counts``, exact at γ=1 (integer-valued unit
    weights), and a valid weighted CDF for any γ ∈ (0, 1].  Rotation
    composes for free: the expired epoch's histogram row is zeroed, so
    its rates leave the window quantile exactly when its counts leave
    the score."""
    if state.qhist is None:
        raise ValueError("window has no qhist leaf (threshold_mode="
                         "'quantile' needs init_window(..., quantile=True))")
    w = epoch_weights(state.cursor, state.num_epochs, gamma)
    return jnp.tensordot(w, state.qhist, axes=1)


def observe_current(state: WindowedAceState, rates: jax.Array,
                    maskf: jax.Array) -> WindowedAceState:
    """Fold a batch of windowed rates into the LIVE epoch's histogram
    row — one flat scatter at cursor·NUM_BINS + bin (the ring analogue
    of ``quantile.observe_rates``; fixed-shape, scan/donation safe).
    ``maskf`` is the OBSERVE mask (finite rows), not the admit mask."""
    from repro.quantile import sketch as qsk
    E, nb = state.qhist.shape
    offs = state.cursor * nb + qsk.bin_index(rates)
    flat = state.qhist.reshape(E * nb)
    qhist = flat.at[offs].add(maskf.astype(jnp.float32)).reshape(E, nb)
    return state._replace(qhist=qhist)


def mean_mu_windowed(state: WindowedAceState, gamma: float,
                     table_mask: jax.Array | None = None) -> jax.Array:
    """γ-generalised Eq. 11 closed form:  μ_w = ‖C_w‖² / (n_w · L).

    For γ=1 this is EXACT — C_w is the merged counts and the derivation
    of ``sketch.mean_mu`` applies verbatim to the merged sketch.  For
    γ<1 it is the natural weighted self-collision estimate (each pair's
    contribution decays with both members' ages).  ‖C_w‖² is the
    maintained ``state.ssq`` stream (O(1) at query time; re-anchored
    from the tail at every rotation), never an O(L·2^K) sweep on the
    per-step path.

    ``table_mask`` (degraded mode only) cannot use the scalar ssq — it
    recomputes per-table squared norms from the epochs via
    ``decayed_counts`` (a full-table sweep, acceptable off the healthy
    hot path) and means over the healthy tables."""
    L = state.counts.shape[1]
    if table_mask is None:
        denom = jnp.maximum(combined_n(state, gamma), 1.0) * L
        return state.ssq / denom
    maskf = table_mask.astype(jnp.float32)
    nh = jnp.maximum(jnp.sum(maskf), 1.0)
    cw = decayed_counts(state, gamma)                            # (L, 2^K)
    per_table = jnp.sum(cw * cw, axis=1)                         # (L,)
    denom = jnp.maximum(combined_n(state, gamma), 1.0) * nh
    return jnp.sum(per_table * maskf) / denom


def sigma_windowed(state: WindowedAceState, gamma: float) -> jax.Array:
    """Window σ of windowed-score rates from the combined Welford stream."""
    n_w, _, m2_w = combined_moments(state, gamma)
    return jnp.sqrt(m2_w / jnp.maximum(n_w - 1.0, 1.0))


def combined_moments(state: WindowedAceState, gamma: float):
    """Window-combined Welford stream: (n_w, mean_w, m2_w).

    Chan's parallel merge rule (the same one ``sketch.merge`` uses)
    folded across epochs in ring-index order, with epoch e's stream
    entering at effective weight γ^age — i.e. n_e → γ^age·n_e and
    M2_e → γ^age·M2_e, the standard exponential-decay moment combine.
    The fold STARTS from epoch 0's own (weighted) moments, not a zero
    accumulator, so E=1 returns the epoch's scalars bitwise.
    """
    E = state.num_epochs
    w = epoch_weights(state.cursor, E, gamma)
    n_acc = w[0] * state.n[0]
    mean_acc = state.welford_mean[0]
    m2_acc = w[0] * state.welford_m2[0]
    for e in range(1, E):  # static unroll
        n_b = w[e] * state.n[e]
        delta = state.welford_mean[e] - mean_acc
        tot = n_acc + n_b
        safe = jnp.maximum(tot, 1.0)
        mean_acc = mean_acc + delta * n_b / safe
        m2_acc = (m2_acc + w[e] * state.welford_m2[e]
                  + delta**2 * n_acc * n_b / safe)
        n_acc = tot
    return n_acc, mean_acc, m2_acc


def admit_threshold_windowed(state: WindowedAceState, gamma: float,
                             alpha: float, warmup_items: float,
                             table_mask: jax.Array | None = None,
                             threshold_mode: str = "mu_sigma",
                             q: float = 0.01) -> jax.Array:
    """Score-space admission threshold from WINDOW-combined statistics.

    ``"mu_sigma"`` mirrors ``sketch.admit_threshold``
    operation-for-operation (rate = μ_w/n_w, t = (rate − α·σ_w)·
    max(n_w, 1), −inf during warmup) with every statistic swapped for
    its window-combined counterpart, so the E=1 window thresholds
    bitwise like the plain sketch.  ``"quantile"`` reads the q-quantile
    of the γ-weighted combined-window rate histogram
    (``combined_qhist``) and scales by the same max(n_w, 1) — the E=1
    quantile window is bitwise the flat quantile path (γ⁰ = 1 weight is
    exact).  Both modes are trace-time Python dispatch (one cached
    executable per mode) and return ONE device scalar.  Because expired
    epochs leave the combined statistics, the threshold TRACKS the
    stream: after a distribution shift the stale regime ages out of the
    window instead of pinning the threshold forever.  Pure device
    scalar ops — no host sync.
    """
    n_w = combined_n(state, gamma)
    if threshold_mode == "quantile":
        from repro.quantile import sketch as qsk
        t = qsk.hist_quantile(combined_qhist(state, gamma), q) \
            * jnp.maximum(n_w, 1.0)
        return jnp.where(n_w >= warmup_items, t, -jnp.inf)
    if threshold_mode != "mu_sigma":
        raise ValueError(f"unknown threshold_mode {threshold_mode!r}")
    rate = mean_mu_windowed(state, gamma, table_mask=table_mask) \
        / jnp.maximum(n_w, 1.0)
    t = (rate - alpha * sigma_windowed(state, gamma)) \
        * jnp.maximum(n_w, 1.0)
    return jnp.where(n_w >= warmup_items, t, -jnp.inf)


def combined_ace(state: WindowedAceState) -> AceState:
    """Hard-window (γ=1) combine into ONE plain ``AceState``.

    Counts sum in the counter dtype (exact); n sums; the Welford streams
    merge by Chan's rule — i.e. this is ``sketch.merge`` folded over the
    epochs.  Diagnostics/export convenience; the hot paths never
    materialise it (they read tail + live).
    """
    out = AceState(counts=state.counts[0], n=state.n[0],
                   welford_mean=state.welford_mean[0],
                   welford_m2=state.welford_m2[0])
    for e in range(1, state.num_epochs):
        out = sk.merge(out, AceState(
            counts=state.counts[e], n=state.n[e],
            welford_mean=state.welford_mean[e],
            welford_m2=state.welford_m2[e]))
    return out
