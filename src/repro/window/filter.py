"""Windowed ACE data filter — the drift-tracking drop-in for
``repro.data.pipeline.AceDataFilter``.

Same step protocol (``init``, ``features``, ``step``, ``__call__``,
``ace_cfg``), same single hash per batch, same score→threshold→masked-
insert dataflow — but the state is a ``WindowedAceState`` ring and every
statistic (score, μ, σ, admit threshold) is window-combined, so the
filter FORGETS: after a distribution shift the stale regime ages out in
``num_epochs × rotate_every`` steps instead of poisoning μ/σ forever.

Rotation is NOT performed inside ``step`` — it belongs to whoever drives
the stream clock (``StreamRunner(rotate_every=...)`` inside its scan
body, ``Guardrail`` per admit call, or the train driver's tail path via
``maybe_rotate``).  Keeping the step rotation-free means one step ==
one insert tick everywhere, and the chunk-vs-sequential equivalence
contract of the stream runner holds for windowed state exactly as it
does for the plain sketch.

With ``num_epochs=1`` (and any γ — the live epoch's weight is exactly
1.0) the filter is BITWISE ``AceDataFilter``: same buckets, same scores,
same threshold, same inserted counts (tests/test_window.py asserts it).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import srp
from repro.core.sketch import AceConfig
from repro.window import ring
from repro.window.ring import WindowConfig, WindowedAceState


@dataclasses.dataclass(frozen=True)
class WindowedAceFilter:
    """ACE anomaly filter over a sliding epoch ring (jit-compatible)."""

    d_model: int
    num_bits: int = 13
    num_tables: int = 32
    alpha: float = 4.0
    warmup_items: float = 512.0
    bias_const: float = 0.25
    hash_mode: str = "dense"
    insert_all: bool = False    # detector mode (see AceDataFilter)
    num_epochs: int = 4
    decay: float = 1.0          # γ; 1.0 = hard window
    rotate_every: int = 0       # steps per epoch (driver-enforced clock)
    threshold_mode: str = "mu_sigma"   # "mu_sigma" | "quantile": quantile
                                # mode thresholds at Q_q of the WINDOWED
                                # rate histogram (same γ-weighted epoch
                                # combine as every other window statistic)
    quantile_q: float = 0.01    # target flag rate for quantile mode
    attr_rows: int = 0          # > 0: per-epoch attribution planes
    attr_bits: int = 8          # log2 columns per attribution row

    @property
    def ace_cfg(self) -> AceConfig:
        # same construction as AceDataFilter.ace_cfg: the E=1 window must
        # be the SAME sketch (seed included) as the flat filter's.
        return AceConfig(dim=self.d_model + 1, num_bits=self.num_bits,
                         num_tables=self.num_tables, seed=29,
                         welford_min_n=self.warmup_items / 2,
                         hash_mode=self.hash_mode,
                         attr_rows=self.attr_rows,
                         attr_bits=self.attr_bits)

    @property
    def window_cfg(self) -> WindowConfig:
        return WindowConfig(ace=self.ace_cfg, num_epochs=self.num_epochs,
                            decay=self.decay,
                            rotate_every=self.rotate_every)

    def init(self):
        from repro.core import sketch as sk
        # init_window routes through WindowConfig, which VALIDATES the
        # (num_epochs, decay, rotate_every) triple up front
        return (ring.init_window(self.window_cfg,
                                 quantile=self.threshold_mode == "quantile"),
                sk.make_params(self.ace_cfg))

    def features(self, embeds: jax.Array) -> jax.Array:
        """(B, S, D) embeddings -> (B, D+1) unit-mean + bias features —
        the SAME shared helper as ``AceDataFilter`` (identical
        featurisation is what makes frozen-vs-windowed comparisons, and
        the E=1 bitwise contract, apples-to-apples)."""
        from repro.data.pipeline import mean_embed_features
        return mean_embed_features(embeds, self.bias_const)

    def step(self, state: WindowedAceState, w, feat, table_mask=None):
        """hash ONCE → window-combined score → window-combined μ−ασ
        threshold → masked insert into the live epoch.

        Returns (new_state, keep (B,) bool, margin (B,) float32); the
        scan body of ``StreamRunner`` when the filter is windowed.
        Rotation is the driver's job (see module docstring).

        Non-finite feature rows are sanitized at entry exactly like
        ``AceDataFilter.step``: zeroed pre-hash, never kept/inserted,
        ``margin = −inf``.  ``table_mask`` (L,) f32 restricts the
        DECISION (score + threshold) to healthy tables; the insert still
        folds the true unmasked ``pre_sums`` so the ssq invariant keeps
        tracking the physical ring contents."""
        cfg = self.ace_cfg
        finite = jnp.all(jnp.isfinite(feat), axis=-1)
        feat = jnp.where(finite[:, None], feat, 0.0)
        buckets = srp.hash_buckets(feat, w, cfg.srp)   # the ONE hash
        # tail + live gathers: the live one is the flat sketch's own
        # score gather; the tail one is the whole windowing surcharge
        tail_sums, live_sums = ring.window_table_sums(state, buckets)
        scores = ring.score_live(tail_sums, live_sums, cfg.num_tables,
                                 table_mask=table_mask)
        thresh = ring.admit_threshold_windowed(
            state, self.decay, self.alpha, self.warmup_items,
            table_mask=table_mask, threshold_mode=self.threshold_mode,
            q=self.quantile_q)
        keep = jnp.logical_and(scores >= thresh, finite)
        margin = jnp.where(finite, scores - thresh, -jnp.inf)
        ins = finite if self.insert_all else keep
        # the scoring gathers double as the ssq increment's ⟨h, C_w⟩ input
        new_state = ring.insert_current(
            state, buckets, ins, cfg, gamma=self.decay,
            pre_sums=(tail_sums, live_sums))
        if self.threshold_mode == "quantile":
            # every finite-scored item feeds the live epoch's rate
            # histogram (NOT just admitted ones — see AceDataFilter.step);
            # rotation retires the epoch's observations with its counts
            from repro.quantile import sketch as qsk
            n_w = ring.combined_n(state, self.decay)
            rates = scores / jnp.maximum(n_w, 1.0)
            new_state = ring.observe_current(
                new_state, rates,
                qsk.calib_mask(finite.astype(jnp.float32), n_w,
                               self.warmup_items))
        return new_state, keep, margin

    def __call__(self, state, w, embeds, mask):
        """Score + filter + update (per-batch driver convenience).

        One step, then the rotation clock (eager: the insert that fills
        an epoch rotates the ring on its way out — same positions as the
        stream runner's segment scan); returns (new_state, new_mask,
        frac_kept)."""
        feat = self.features(embeds)
        new_state, keep, _margin = self.step(state, w, feat)
        new_state = ring.maybe_rotate(new_state, self.rotate_every,
                                      self.decay)
        new_mask = mask * keep[:, None].astype(mask.dtype)
        return new_state, new_mask, jnp.mean(keep.astype(jnp.float32))
