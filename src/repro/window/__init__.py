"""Sliding-window ACE: device-resident epoch ring with on-device
rotation/decay.

``repro.window.ring`` is the state + pure ops (rotate / decayed combine /
windowed moments & threshold / masked insert into the live epoch);
``repro.window.filter`` is the drift-tracking drop-in for
``AceDataFilter``.  See docs/ARCHITECTURE.md §5.
"""
from repro.window.ring import (WindowConfig, WindowedAceState,
                               admit_threshold_windowed, combined_ace,
                               combined_moments, combined_n,
                               decayed_counts, epoch_table_sums,
                               epoch_weights, init, init_window,
                               insert_current, live_epoch, maybe_rotate,
                               mean_mu_windowed, rotate, score_combined,
                               score_from_sums, score_live,
                               score_windowed, sigma_windowed,
                               window_table_sums)
from repro.window.filter import WindowedAceFilter

__all__ = [
    "WindowConfig", "WindowedAceState", "WindowedAceFilter",
    "admit_threshold_windowed", "combined_ace", "combined_moments",
    "combined_n", "decayed_counts", "epoch_table_sums", "epoch_weights",
    "init", "init_window", "insert_current", "live_epoch",
    "maybe_rotate", "mean_mu_windowed", "rotate", "score_combined",
    "score_from_sums", "score_live", "score_windowed", "sigma_windowed",
    "window_table_sums",
]
