"""Fast SRP via the Subsampled Randomized Hadamard Transform (SRHT).

Paper §2.2 cites the Fast-JL transform for computing m random-projection
hashes in O(d log d + m) instead of O(d·m).  The classic construction is

    P x = sqrt(d/m) · R · H · D · x

where D is a random ±1 diagonal, H the Walsh–Hadamard transform, and R a
random row sampler.  Signs of (R H D x) are SRP-distributed to a very good
approximation (rows of H·D are ±1/√d vectors, near-Gaussian after D mixing;
Ailon & Chazelle 2006).  We use one extra independent D+H round to decorrelate
rows when m approaches d.

On TPU the FWHT is log2(d) reshape+butterfly steps on the VPU — no MXU use at
all, so for high-d inputs this frees the MXU entirely (beyond-paper win for
the data-pipeline filter where d = d_model can be 12288).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.srp import SrpConfig, pack_buckets


def _next_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


def fwht(x: jax.Array) -> jax.Array:
    """Walsh–Hadamard transform along the last axis (length must be 2^k).

    Implemented as log2(n) butterfly stages via reshape — each stage is a
    single fused add/sub, O(n) work, O(n log n) total.
    """
    n = x.shape[-1]
    assert n & (n - 1) == 0, f"FWHT length must be a power of two, got {n}"
    orig_shape = x.shape
    h = 1
    while h < n:
        x = x.reshape(*orig_shape[:-1], n // (2 * h), 2, h)
        a = x[..., 0, :]
        b = x[..., 1, :]
        x = jnp.concatenate([a + b, a - b], axis=-1)
        x = x.reshape(*orig_shape)
        h *= 2
    return x


class SrhtParams:
    """Static (numpy) SRHT parameters — signs and row sample, derived from seed.

    Kept as HOST numpy arrays on purpose: ``srht_params`` caches instances
    and the first construction may happen inside a jit trace (the hash
    dispatch resolves parameters at trace time) — jnp arrays built there
    would be tracers and leak through the cache.  numpy operands convert
    to device constants at the jnp op that consumes them.
    """

    def __init__(self, cfg: SrpConfig):
        self.cfg = cfg
        d_pad = _next_pow2(max(cfg.dim, 2))
        rng = np.random.default_rng(cfg.seed + 0x5A5A)
        self.d_pad = d_pad
        self.signs1 = rng.choice([-1.0, 1.0], size=(d_pad,)).astype(np.float32)
        self.signs2 = rng.choice([-1.0, 1.0], size=(d_pad,)).astype(np.float32)
        m = cfg.num_projections
        # Sample rows with replacement across possibly > d_pad projections.
        self.rows = rng.integers(0, d_pad, size=(m,)).astype(np.int32)


def srht_bits(x: jax.Array, params: SrhtParams) -> jax.Array:
    """(..., d) -> (..., K*L) sign bits via two H·D rounds + row sampling."""
    cfg = params.cfg
    pad = params.d_pad - cfg.dim
    xp = jnp.pad(x.astype(jnp.float32), [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    y = fwht(xp * params.signs1)
    y = fwht(y * params.signs2)
    proj = jnp.take(y, params.rows, axis=-1)
    return (proj >= 0).astype(jnp.int32)


def srht_hash_buckets(x: jax.Array, params: SrhtParams) -> jax.Array:
    """(..., d) -> (..., L) bucket ids, SRHT fast path."""
    return pack_buckets(srht_bits(x, params), params.cfg)


@functools.lru_cache(maxsize=64)
def srht_params(cfg: SrpConfig) -> SrhtParams:
    """Cached SRHT parameters per config.

    ``hash_buckets``/``hash_dispatch`` resolve parameters on every call
    (often at trace time inside a jitted hot path); rebuilding the sign
    diagonals + row sample from numpy each time would re-derive and
    re-upload identical constants per trace.  SrpConfig is frozen and
    hashable, so the cache key is exact.
    """
    return SrhtParams(cfg)


def flops_dense(cfg: SrpConfig, batch: int) -> int:
    """FLOPs of the dense SRP matmul path."""
    return 2 * batch * cfg.dim * cfg.padded_projections


def flops_srht(cfg: SrpConfig, batch: int) -> int:
    """FLOPs of the SRHT path: 2 FWHTs + sign flips + gather."""
    d_pad = _next_pow2(max(cfg.dim, 2))
    log2d = d_pad.bit_length() - 1
    return batch * (2 * d_pad * log2d + 2 * d_pad + cfg.num_projections)


# ---------------------------------------------------------------------------
# Dense-vs-SRHT break-even for hash_mode="auto".
#
# Raw FLOP counts (``flops_dense``/``flops_srht``) are the wrong units to
# compare directly: the dense path is ONE matmul running at MXU (or BLAS)
# throughput, while the SRHT path is log2(d) butterfly passes plus an
# m-element row gather on the VPU — a matmul FLOP is tens of times cheaper
# than a vector-op, and a gathered element costs far more than an add.
# The two weights below fold that in; they are calibrated so the model's
# pick matches the measured winner on both CPU (BLAS vs XLA elementwise)
# and the TPU roofline at the benchmark corners d ∈ {64, 4096} with the
# paper's K=15, L=50 (dense wins low-d where the matmul is tiny and the
# fixed m-gather dominates SRHT; SRHT wins high-d where the matmul grows
# O(d·KL) against O(d log d)).  ``benchmarks/stream_throughput.py``
# re-measures both corners every run and asserts the model still agrees.
# ---------------------------------------------------------------------------

DENSE_MATMUL_SPEEDUP = 32.0   # matmul FLOPs per vector-op-equivalent
GATHER_COST_FACTOR = 16.0     # cost of one gathered element vs one add


def effective_cost_dense(cfg: SrpConfig) -> float:
    """Throughput-weighted per-item cost of the dense matmul hash."""
    return flops_dense(cfg, 1) / DENSE_MATMUL_SPEEDUP


def effective_cost_srht(cfg: SrpConfig) -> float:
    """Throughput-weighted per-item cost of the SRHT hash."""
    d_pad = _next_pow2(max(cfg.dim, 2))
    log2d = d_pad.bit_length() - 1
    return (2 * d_pad * log2d + 2 * d_pad
            + GATHER_COST_FACTOR * cfg.num_projections)


def choose_hash_mode(cfg: SrpConfig) -> str:
    """The ``hash_mode="auto"`` dispatch rule: cheaper effective cost wins.

    Batch size cancels (both paths are linear in B), so the choice is a
    pure function of the static config — safe to resolve at trace time.
    """
    if effective_cost_srht(cfg) < effective_cost_dense(cfg):
        return "srht"
    return "dense"
