"""Fast SRP via the Subsampled Randomized Hadamard Transform (SRHT).

Paper §2.2 cites the Fast-JL transform for computing m random-projection
hashes in O(d log d + m) instead of O(d·m).  The classic construction is

    P x = sqrt(d/m) · R · H · D · x

where D is a random ±1 diagonal, H the Walsh–Hadamard transform, and R a
random row sampler.  Signs of (R H D x) are SRP-distributed to a very good
approximation (rows of H·D are ±1/√d vectors, near-Gaussian after D mixing;
Ailon & Chazelle 2006).  We use one extra independent D+H round to decorrelate
rows when m approaches d.

On TPU the FWHT is log2(d) reshape+butterfly steps on the VPU — no MXU use at
all, so for high-d inputs this frees the MXU entirely (beyond-paper win for
the data-pipeline filter where d = d_model can be 12288).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.srp import SrpConfig, pack_buckets


def _next_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


def fwht(x: jax.Array) -> jax.Array:
    """Walsh–Hadamard transform along the last axis (length must be 2^k).

    Implemented as log2(n) butterfly stages via reshape — each stage is a
    single fused add/sub, O(n) work, O(n log n) total.
    """
    n = x.shape[-1]
    assert n & (n - 1) == 0, f"FWHT length must be a power of two, got {n}"
    orig_shape = x.shape
    h = 1
    while h < n:
        x = x.reshape(*orig_shape[:-1], n // (2 * h), 2, h)
        a = x[..., 0, :]
        b = x[..., 1, :]
        x = jnp.concatenate([a + b, a - b], axis=-1)
        x = x.reshape(*orig_shape)
        h *= 2
    return x


class SrhtParams:
    """Static (numpy) SRHT parameters — signs and row sample, derived from seed."""

    def __init__(self, cfg: SrpConfig):
        self.cfg = cfg
        d_pad = _next_pow2(max(cfg.dim, 2))
        rng = np.random.default_rng(cfg.seed + 0x5A5A)
        self.d_pad = d_pad
        self.signs1 = jnp.asarray(rng.choice([-1.0, 1.0], size=(d_pad,)), jnp.float32)
        self.signs2 = jnp.asarray(rng.choice([-1.0, 1.0], size=(d_pad,)), jnp.float32)
        m = cfg.num_projections
        # Sample rows with replacement across possibly > d_pad projections.
        self.rows = jnp.asarray(rng.integers(0, d_pad, size=(m,)), jnp.int32)


def srht_bits(x: jax.Array, params: SrhtParams) -> jax.Array:
    """(..., d) -> (..., K*L) sign bits via two H·D rounds + row sampling."""
    cfg = params.cfg
    pad = params.d_pad - cfg.dim
    xp = jnp.pad(x.astype(jnp.float32), [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    y = fwht(xp * params.signs1)
    y = fwht(y * params.signs2)
    proj = jnp.take(y, params.rows, axis=-1)
    return (proj >= 0).astype(jnp.int32)


def srht_hash_buckets(x: jax.Array, params: SrhtParams) -> jax.Array:
    """(..., d) -> (..., L) bucket ids, SRHT fast path."""
    return pack_buckets(srht_bits(x, params), params.cfg)


def flops_dense(cfg: SrpConfig, batch: int) -> int:
    """FLOPs of the dense SRP matmul path."""
    return 2 * batch * cfg.dim * cfg.padded_projections


def flops_srht(cfg: SrpConfig, batch: int) -> int:
    """FLOPs of the SRHT path: 2 FWHTs + sign flips + gather."""
    d_pad = _next_pow2(max(cfg.dim, 2))
    log2d = d_pad.bit_length() - 1
    return batch * (2 * d_pad * log2d + 2 * d_pad + cfg.num_projections)
