"""Differentially-private ACE (paper §4).

The paper's recipe (via Kenthapadi et al. 2012): add Gaussian noise to the
random projection *before* taking the sign.  sign(Wx + N(0, σ²I)) is a
post-processing of a (ε, δ)-DP release of Wx, so the whole ACE pipeline
(counts, scores, decisions) inherits the privacy guarantee — no Laplacian
heavy tails needed.

σ is calibrated by the analytic Gaussian mechanism for sensitivity
Δ₂ = max_rows ‖W_row‖₂ · ‖x − x'‖₂; with rows ~ N(0, I_d) and unit-norm
inputs we use the standard w_2-bound σ ≥ Δ₂·sqrt(2 ln(1.25/δ))/ε.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.srp import SrpConfig, pack_buckets


def gaussian_sigma(epsilon: float, delta: float, l2_sensitivity: float) -> float:
    """Classic Gaussian-mechanism calibration (Dwork & Roth Thm A.1)."""
    if epsilon <= 0 or not (0 < delta < 1):
        raise ValueError("need epsilon > 0 and 0 < delta < 1")
    return l2_sensitivity * math.sqrt(2.0 * math.log(1.25 / delta)) / epsilon


def private_srp_bits(x: jax.Array, w: jax.Array, cfg: SrpConfig,
                     key: jax.Array, sigma: float) -> jax.Array:
    """sign(Wx + N(0, σ²)) — the DP-SRP of §4."""
    proj = jnp.einsum("...d,dp->...p", x, w.astype(x.dtype))
    noise = sigma * jax.random.normal(key, proj.shape, proj.dtype)
    bits = ((proj + noise) >= 0).astype(jnp.int32)
    return bits[..., : cfg.num_projections]


def private_hash_buckets(x: jax.Array, w: jax.Array, cfg: SrpConfig,
                         key: jax.Array, sigma: float) -> jax.Array:
    return pack_buckets(private_srp_bits(x, w, cfg, key, sigma), cfg)


def expected_bit_flip_rate(margin: jax.Array, sigma: float) -> jax.Array:
    """Pr[sign flips] = Φ(−|margin|/σ): utility-loss diagnostic.

    ``margin`` is the pre-noise projection value w·x.
    """
    if sigma == 0.0:
        return jnp.zeros_like(margin)
    z = jnp.abs(margin) / sigma
    return 0.5 * jax.scipy.special.erfc(z / jnp.sqrt(2.0))
