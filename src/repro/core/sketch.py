"""The ACE sketch: L count arrays of size 2^K + streaming statistics.

Paper Algorithm 1, made batch-parallel and SPMD-friendly:

* state  = counts (L, 2^K) integer array + n (items inserted) — nothing else;
  no data points are ever stored (the paper's core memory claim).
* insert = scatter-add of the batch bucket histogram (order-invariant).
* score  = mean over L of counts[j, H_j(q)]  (Theorem 1: unbiased for S(q,D)).
* mean   = closed form  μ = Σ_j Σ_b A_j[b]² / (n·L)

The closed form is derived from the paper's Eq. 11: inserting into a bucket
with count c changes Σ_b A²  by (c+1)² − c² = 2c+1, matching the paper's
incremental term (2A+1)/L exactly — so maintaining Σ‖A‖² tracks n·L·μ with
*no sequential dependency*.  ``tests/test_ace_core.py`` property-tests the
two formulations against each other, including deletes (Eq. 12).

Because counts are additive, sketches over disjoint data shards merge by
elementwise addition — this is the whole multi-pod story (see
``repro.dist.sketch_parallel``): each data shard sketches locally, a psum
merges; and because the L arrays are independent, counts also shard over
the L axis (the table-sharded layout there) when the sketch outgrows one
device.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.srp import SrpConfig, hash_buckets, make_projections


class AceState(NamedTuple):
    """Dynamic sketch state (a pytree — jit/scan/psum friendly).

    counts: (L, 2^K) integer counters.
    n:      () float32 — number of items currently represented.  float so the
            pytree is uniform under optimizers/donation; exact up to 2^24.
    welford_mean / welford_m2: () float32 — streaming mean/M2 of *insert-time*
            scores (for the σ estimate in the streaming threshold policy; the
            exact μ never uses these).
    """

    counts: jax.Array
    n: jax.Array
    welford_mean: jax.Array   # streaming mean of RATES score/n (stationary)
    welford_m2: jax.Array


@dataclasses.dataclass(frozen=True)
class AceConfig:
    """Static ACE configuration (hashable; safe as a jit static arg)."""

    dim: int
    num_bits: int = 15          # K
    num_tables: int = 50        # L
    seed: int = 0
    counter_dtype: str = "int32"  # "int16" reproduces the paper's 2x saving
    welford_min_n: float = 0.0  # skip σ-stream updates below this n (the
                                # cold-start rates score/n are off-scale and
                                # would inflate σ forever)

    @property
    def srp(self) -> SrpConfig:
        return SrpConfig(dim=self.dim, num_bits=self.num_bits,
                         num_tables=self.num_tables, seed=self.seed)

    @property
    def num_buckets(self) -> int:
        return 1 << self.num_bits

    def memory_bytes(self) -> int:
        """The paper's headline number: L × 2^K × sizeof(counter)."""
        itemsize = jnp.dtype(self.counter_dtype).itemsize
        return self.num_tables * self.num_buckets * itemsize


def init(cfg: AceConfig) -> AceState:
    return AceState(
        counts=jnp.zeros((cfg.num_tables, cfg.num_buckets),
                         dtype=jnp.dtype(cfg.counter_dtype)),
        n=jnp.zeros((), jnp.float32),
        welford_mean=jnp.zeros((), jnp.float32),
        welford_m2=jnp.zeros((), jnp.float32),
    )


def make_params(cfg: AceConfig, dtype=jnp.float32) -> jax.Array:
    """The SRP projection matrix W (d, KL_padded)."""
    return make_projections(cfg.srp, dtype=dtype)


# ---------------------------------------------------------------------------
# Bucket-level primitives (input: precomputed bucket ids (B, L)).
# These are what the Pallas kernels accelerate; everything here is the
# reference path and stays pure-jnp.
# ---------------------------------------------------------------------------

def lookup(state: AceState, buckets: jax.Array) -> jax.Array:
    """counts[j, buckets[., j]] averaged over j.  (B, L) -> (B,) float32.

    This is Ŝ(q, D) of Algorithm 1 (query phase).
    """
    L = state.counts.shape[0]
    rows = jnp.arange(L, dtype=jnp.int32)
    gathered = state.counts[rows[None, :], buckets]          # (B, L)
    # mean over L as an explicit reciprocal multiply: a bare `/ L` is
    # rewritten to `* (1/L)` by XLA fast-math in SOME programs but not
    # others, which would break the bitwise replicated↔table-sharded
    # parity contract (repro.dist.sketch_parallel uses the same constant).
    return jnp.sum(gathered.astype(jnp.float32), axis=-1) \
        * jnp.float32(1.0 / L)


def histogram(buckets: jax.Array, cfg: AceConfig) -> jax.Array:
    """Batch bucket histogram: (B, L) ids -> (L, 2^K) counts of this batch."""
    L = cfg.num_tables
    B = buckets.shape[0]
    rows = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None, :], (B, L))
    zero = jnp.zeros((L, cfg.num_buckets), dtype=jnp.dtype(cfg.counter_dtype))
    return zero.at[rows, buckets].add(1)


def welford_fold(welford_mean: jax.Array, welford_m2: jax.Array,
                 n: jax.Array, b: jax.Array, tot: jax.Array,
                 mean_b: jax.Array, m2_b: jax.Array, min_n: float):
    """Fold one batch's rate statistics into the Welford stream.

    (mean_b, m2_b) are the batch mean / sum-of-squared-deviations of the
    rates; the cold-start gate (min_n) RESTARTS the stream on the first
    gated batch — early rates are off-scale and Welford never forgets.
    Shared by every insert path (single-device, replicated shard_map,
    table-sharded — repro.dist.sketch_parallel) so their Welford numerics
    stay identical by construction, not by copy-synced formulas.
    """
    delta = mean_b - welford_mean
    gate = (n >= min_n).astype(jnp.float32)
    eff_n = jnp.where(gate > 0, n, 0.0)
    new_mean = jnp.where(
        gate > 0,
        welford_mean + delta * b / jnp.maximum(tot, 1.0),
        mean_b)
    new_m2 = jnp.where(
        gate > 0,
        welford_m2 + m2_b + delta**2 * eff_n * b / jnp.maximum(tot, 1.0),
        m2_b)
    return new_mean, new_m2


def insert_buckets(state: AceState, buckets: jax.Array,
                   cfg: AceConfig) -> AceState:
    """Insert a batch.  Order-invariant; exact for any batch size.

    Welford stats are updated with the *post-insert* score of each item
    (its own count included), matching Algorithm 1 line 12's convention of
    scoring x against D ∪ {x}.
    """
    L = cfg.num_tables
    rows = jnp.broadcast_to(
        jnp.arange(L, dtype=jnp.int32)[None, :], buckets.shape)
    new_counts = state.counts.at[rows, buckets].add(1)

    # Post-insert scores of the batch items (vs the fully updated arrays).
    # Reciprocal multiply, not `/ L` — see the note in ``lookup``.
    gathered = new_counts[rows, buckets].astype(jnp.float32)   # (B, L)
    scores = jnp.sum(gathered, axis=-1) * jnp.float32(1.0 / L)  # (B,)

    # Welford over collision RATES score/n, not raw scores: raw insert-time
    # scores grow ~linearly with n (item i scores ≈ O(i)), which inflates σ
    # with ramp variance and makes μ−ασ thresholds useless.  Rates are
    # stationary for a stationary stream.
    b = jnp.asarray(scores.shape[0], jnp.float32)
    n = state.n
    tot = n + b
    rates = scores / jnp.maximum(tot, 1.0)
    mean_b = jnp.mean(rates)
    m2_b = jnp.sum((rates - mean_b) ** 2)
    new_mean, new_m2 = welford_fold(
        state.welford_mean, state.welford_m2, n, b, tot, mean_b, m2_b,
        cfg.welford_min_n)

    return AceState(counts=new_counts, n=tot,
                    welford_mean=new_mean, welford_m2=new_m2)


def delete_buckets(state: AceState, buckets: jax.Array,
                   cfg: AceConfig) -> AceState:
    """Remove previously inserted items (paper §3.4.1, Eq. 12).

    Welford stats are *not* un-merged (not possible in one pass); the exact μ
    (``mean_mu``) is unaffected since it is a pure function of counts.
    """
    rows = jnp.broadcast_to(
        jnp.arange(cfg.num_tables, dtype=jnp.int32)[None, :], buckets.shape)
    new_counts = state.counts.at[rows, buckets].add(-1)
    return state._replace(counts=new_counts,
                          n=state.n - jnp.asarray(buckets.shape[0], jnp.float32))


def merge(a: AceState, b: AceState) -> AceState:
    """Merge two sketches over disjoint data (counts add — CRDT style).

    Exact for counts/n; Welford streams merge by Chan's parallel rule.
    """
    delta = b.welford_mean - a.welford_mean
    tot = a.n + b.n
    safe = jnp.maximum(tot, 1.0)
    return AceState(
        counts=a.counts + b.counts,
        n=tot,
        welford_mean=a.welford_mean + delta * b.n / safe,
        welford_m2=a.welford_m2 + b.welford_m2 + delta**2 * a.n * b.n / safe,
    )


# ---------------------------------------------------------------------------
# Statistics of the sketch.
# ---------------------------------------------------------------------------

def mean_mu(state: AceState) -> jax.Array:
    """Exact dataset mean score  μ = Σ‖A_j‖² / (n·L)  (≡ paper Eq. 11 stream).

    Proof sketch: Algorithm 1 maintains n·μ = Σ_i Ŝ(x_i, D); item i in bucket
    b of array j contributes A_j[b]/L once per array, and bucket b holds
    A_j[b] items, so Σ_i A_j[H_j(x_i)] = Σ_b A_j[b]².
    """
    L = state.counts.shape[0]
    c = state.counts.astype(jnp.float32)
    denom = jnp.maximum(state.n, 1.0) * L
    return jnp.sum(c * c) / denom


def mu_sequential_increment(state: AceState, buckets_one: jax.Array,
                            cfg: AceConfig):
    """One step of the paper's literal Eq. 11 (sequential, for testing).

    Returns (new_state, new_mu) for a SINGLE item with bucket ids (L,).
    """
    L = cfg.num_tables
    rows = jnp.arange(L, dtype=jnp.int32)
    old_mu = mean_mu(state)
    n = state.n
    new_counts = state.counts.at[rows, buckets_one].add(1)
    incr = jnp.sum(
        (2.0 * new_counts[rows, buckets_one].astype(jnp.float32) - 1.0) / L)
    new_mu = (n * old_mu + incr) / (n + 1.0)
    new_state = state._replace(counts=new_counts, n=n + 1.0)
    return new_state, new_mu


def mean_rate(state: AceState) -> jax.Array:
    """Exact mean collision RATE  μ/n  (scale-free across stream growth)."""
    return mean_mu(state) / jnp.maximum(state.n, 1.0)


def sigma_welford(state: AceState) -> jax.Array:
    """Streaming σ of collision RATES (score/n) from insert-time stream."""
    return jnp.sqrt(state.welford_m2 / jnp.maximum(state.n - 1.0, 1.0))


def sigma_cubic_proxy(state: AceState) -> jax.Array:
    """Per-array second-moment proxy:  E_i[A²] per array = Σ_b A³ / n.

    Var_proxy = mean_j Σ_b A_j[b]³/n − μ²  upper-bounds the true score
    variance when arrays are independent (Jensen); exposed as a diagnostics
    alternative to the Welford stream.
    """
    c = state.counts.astype(jnp.float32)
    n = jnp.maximum(state.n, 1.0)
    second = jnp.mean(jnp.sum(c**3, axis=1)) / n
    var = jnp.maximum(second - mean_mu(state) ** 2, 0.0)
    return jnp.sqrt(var)


# ---------------------------------------------------------------------------
# Vector-level convenience API (hashing included).
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg",))
def insert(state: AceState, w: jax.Array, x: jax.Array,
           cfg: AceConfig) -> AceState:
    """Insert raw vectors x (B, d)."""
    return insert_buckets(state, hash_buckets(x, w, cfg.srp), cfg)


@partial(jax.jit, static_argnames=("cfg",))
def delete(state: AceState, w: jax.Array, x: jax.Array,
           cfg: AceConfig) -> AceState:
    return delete_buckets(state, hash_buckets(x, w, cfg.srp), cfg)


@partial(jax.jit, static_argnames=("cfg",))
def score(state: AceState, w: jax.Array, q: jax.Array,
          cfg: AceConfig) -> jax.Array:
    """Ŝ(q, D) for raw queries q (B, d) -> (B,)."""
    return lookup(state, hash_buckets(q, w, cfg.srp))


@partial(jax.jit, static_argnames=("cfg", "alpha"))
def is_anomaly(state: AceState, w: jax.Array, q: jax.Array,
               cfg: AceConfig, alpha: float = 1.0) -> jax.Array:
    """Decision rule of Algorithm 1 line 22 with the paper's experimental
    μ − α·σ threshold, applied in RATE space (score/n vs μ/n − α·σ_rate) so
    the streaming σ is stationary."""
    r = score(state, w, q, cfg) / jnp.maximum(state.n, 1.0)
    thresh = mean_rate(state) - alpha * sigma_welford(state)
    return r < thresh
