"""The ACE sketch: L count arrays of size 2^K + streaming statistics.

Paper Algorithm 1, made batch-parallel and SPMD-friendly:

* state  = counts (L, 2^K) integer array + n (items inserted) — nothing else;
  no data points are ever stored (the paper's core memory claim).
* insert = scatter-add of the batch bucket histogram (order-invariant).
* score  = mean over L of counts[j, H_j(q)]  (Theorem 1: unbiased for S(q,D)).
* mean   = closed form  μ = Σ_j Σ_b A_j[b]² / (n·L)

The closed form is derived from the paper's Eq. 11: inserting into a bucket
with count c changes Σ_b A²  by (c+1)² − c² = 2c+1, matching the paper's
incremental term (2A+1)/L exactly — so maintaining Σ‖A‖² tracks n·L·μ with
*no sequential dependency*.  ``tests/test_ace_core.py`` property-tests the
two formulations against each other, including deletes (Eq. 12).

Because counts are additive, sketches over disjoint data shards merge by
elementwise addition — this is the whole multi-pod story (see
``repro.dist.sketch_parallel``): each data shard sketches locally, a psum
merges; and because the L arrays are independent, counts also shard over
the L axis (the table-sharded layout there) when the sketch outgrows one
device.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import quantize as qz
from repro.core.srp import SrpConfig, hash_buckets, make_projections


class AceState(NamedTuple):
    """Dynamic sketch state (a pytree — jit/scan/psum friendly).

    counts: (L, 2^K) integer counters.
    n:      () float32 — number of items currently represented.  float so the
            pytree is uniform under optimizers/donation; exact up to 2^24.
    welford_mean / welford_m2: () float32 — streaming mean/M2 of *insert-time*
            scores (for the σ estimate in the streaming threshold policy; the
            exact μ never uses these).
    esc:    overflow escalation table for quantized (int8/int16) count
            planes, or None (the default — unquantized sketches carry no
            extra leaves, so every existing pytree contract is unchanged).
            When present, ``counts`` stores ``min(count, dtype max)`` and
            the exact logical count of a promoted bucket is
            ``counts + esc`` (see repro.core.quantize).
    qhist:  (repro.quantile.NUM_BINS,) float32 collision-rate histogram
            for ``threshold_mode="quantile"`` admission, or None (the
            default — μ−ασ sketches carry no extra leaves, same contract
            as ``esc``).  Observed by the admit entry points, not the
            insert primitives (see repro.quantile.sketch for why the
            observe mask differs from the admit mask).
    attr:   (2, NL, R, C) float32 signed count-sketch attribution
            hierarchy (repro.attribution; enabled by
            ``AceConfig.attr_rows > 0``), or None (the default — same
            no-extra-leaves contract as ``esc``/``qhist``).  Channel 0
            accumulates all finite traffic's per-coordinate energy,
            channel 1 the flagged anomalies'.  Observed chunk-wise by
            the stream runner, not by the insert primitives (like
            ``qhist``); the inserts only carry the leaf through.
    """

    counts: jax.Array
    n: jax.Array
    welford_mean: jax.Array   # streaming mean of RATES score/n (stationary)
    welford_m2: jax.Array
    esc: Optional[qz.EscTable] = None
    qhist: Optional[jax.Array] = None
    attr: Optional[jax.Array] = None


@dataclasses.dataclass(frozen=True)
class AceConfig:
    """Static ACE configuration (hashable; safe as a jit static arg)."""

    dim: int
    num_bits: int = 15          # K
    num_tables: int = 50        # L
    seed: int = 0
    counter_dtype: str = "int32"  # "int16" reproduces the paper's 2x saving
    welford_min_n: float = 0.0  # skip σ-stream updates below this n (the
                                # cold-start rates score/n are off-scale and
                                # would inflate σ forever)
    hash_mode: str = "dense"    # "dense" | "srht" | "auto" — threaded into
                                # .srp; part of the persisted-sketch
                                # contract (see SrpConfig.hash_mode)
    esc_capacity: int = 0       # > 0 enables exact overflow promotion for
                                # narrow (int8/int16) count planes: that
                                # many buckets may exceed the dtype max
                                # before excess is dropped (and counted).
                                # 0 = plain counters (narrow dtypes then
                                # wrap past saturation, like any int add).
    attr_rows: int = 0          # > 0 attaches the signed count-sketch
                                # attribution hierarchy (repro.attribution)
                                # with that many median rows; 0 (default)
                                # carries no attr leaf — every existing
                                # pytree contract is unchanged.
    attr_bits: int = 8          # attribution bucket-space log2 (width
                                # 2^attr_bits per row); only read when
                                # attr_rows > 0.

    def __post_init__(self):
        if self.esc_capacity < 0:
            raise ValueError("esc_capacity must be >= 0, got "
                             f"{self.esc_capacity}")
        if self.attr_rows < 0:
            raise ValueError("attr_rows must be >= 0, got "
                             f"{self.attr_rows}")
        if self.attr_rows > 0:
            # delegate range validation (dim/rows/bits) to AttrConfig
            from repro.attribution import AttrConfig
            AttrConfig(dim=self.dim, rows=self.attr_rows,
                       bits=self.attr_bits, seed=self.seed)
        if self.esc_capacity > 0:
            if not qz.is_narrow(self.counter_dtype):
                raise ValueError(
                    "esc_capacity > 0 (overflow promotion) requires a "
                    "narrow count_dtype (int8/int16); got "
                    f"{self.counter_dtype!r}")
            if self.num_tables * (1 << self.num_bits) > qz.SENTINEL:
                raise ValueError(
                    "quantized planes must stay int32 flat-addressable: "
                    f"L·2^K = {self.num_tables * (1 << self.num_bits)}")

    @property
    def srp(self) -> SrpConfig:
        return SrpConfig(dim=self.dim, num_bits=self.num_bits,
                         num_tables=self.num_tables, seed=self.seed,
                         hash_mode=self.hash_mode)

    @property
    def num_buckets(self) -> int:
        return 1 << self.num_bits

    @property
    def count_dtype(self) -> str:
        """ISSUE/paper-facing alias of the stored ``counter_dtype``."""
        return self.counter_dtype

    @property
    def quantized(self) -> bool:
        """True when the sketch carries an overflow escalation table."""
        return self.esc_capacity > 0

    @property
    def attr(self):
        """The attribution hierarchy config, or None when disabled."""
        if self.attr_rows <= 0:
            return None
        from repro.attribution import AttrConfig
        return AttrConfig(dim=self.dim, rows=self.attr_rows,
                          bits=self.attr_bits, seed=self.seed)

    def memory_bytes(self) -> int:
        """The paper's headline number: L × 2^K × sizeof(counter)
        (plus the escalation side table when promotion is enabled, plus
        the attribution hierarchy when attr_rows > 0)."""
        itemsize = jnp.dtype(self.counter_dtype).itemsize
        base = self.num_tables * self.num_buckets * itemsize
        base += self.esc_capacity * 8 + (4 if self.quantized else 0)
        acfg = self.attr
        return base + (acfg.memory_bytes() if acfg is not None else 0)


def init(cfg: AceConfig) -> AceState:
    attr = None
    if cfg.attr_rows > 0:
        from repro.attribution import init_plane
        attr = init_plane(cfg.attr)
    return AceState(
        counts=jnp.zeros((cfg.num_tables, cfg.num_buckets),
                         dtype=jnp.dtype(cfg.counter_dtype)),
        n=jnp.zeros((), jnp.float32),
        welford_mean=jnp.zeros((), jnp.float32),
        welford_m2=jnp.zeros((), jnp.float32),
        esc=qz.init_esc(cfg.esc_capacity) if cfg.quantized else None,
        attr=attr,
    )


def _flat_offsets(buckets: jax.Array, L: int, nbuckets: int) -> jax.Array:
    """(B, L) bucket ids -> (B, L) flat element offsets j·2^K + bucket."""
    rows = jnp.broadcast_to(
        jnp.arange(L, dtype=jnp.int32)[None, :], buckets.shape)
    return buckets + rows * nbuckets


def make_params(cfg: AceConfig, dtype=jnp.float32) -> jax.Array:
    """The SRP projection matrix W (d, KL_padded)."""
    return make_projections(cfg.srp, dtype=dtype)


# ---------------------------------------------------------------------------
# Bucket-level primitives (input: precomputed bucket ids (B, L)).
# These are what the Pallas kernels accelerate; everything here is the
# reference path and stays pure-jnp.
# ---------------------------------------------------------------------------

def batch_scores(counts: jax.Array, buckets: jax.Array,
                 table_mask: jax.Array | None = None) -> jax.Array:
    """Scores of a batch of bucket ids vs a counts array: (B, L) -> (B,).

    The rows-broadcast gather + reciprocal-multiply mean.  The mean over
    L is an explicit reciprocal multiply, never a bare `/ L`: XLA
    fast-math rewrites `/ L` to `* (1/L)` in SOME programs but not
    others, which would break the bitwise parity contracts across the
    single-device, fused-kernel and repro.dist paths — every score and
    post-insert Welford gather goes through THIS helper (or mirrors its
    constant, where table-sharding makes the gather structurally
    different) so the formula exists once.

    ``table_mask`` (L,) 0/1 float32 restricts the mean to HEALTHY tables
    (repro.resilience): score = Σ_j m_j·c_j / max(Σ_j m_j, 1) — the L−k
    surviving tables are an unbiased estimator of the same Ŝ(q, D)
    (Theorem 1 holds for any subset of the independent tables).  The
    ``None`` default is a Python-level branch so the unmasked program is
    untouched — the bitwise parity contracts above never see the mask.
    """
    L = counts.shape[0]
    rows = jnp.broadcast_to(
        jnp.arange(L, dtype=jnp.int32)[None, :], buckets.shape)
    gathered = counts[rows, buckets].astype(jnp.float32)         # (B, L)
    if table_mask is None:
        return jnp.sum(gathered, axis=-1) * jnp.float32(1.0 / L)
    return masked_table_mean(gathered, table_mask)


def masked_table_mean(gathered: jax.Array,
                      table_mask: jax.Array) -> jax.Array:
    """Mean of a (..., L) gather over the healthy tables only.

    THE degraded-mode combine (single home, like the 1/L reciprocal of
    the healthy paths): masked sum × reciprocal of the healthy-table
    count.  A corrupted table contributes an exact float 0.0 (mask 0 ×
    finite gather — inject.py never writes NaN into count planes, bit
    flips yield huge-but-finite integers), so the healthy tables'
    summation values are identical to an oracle sketch that never held
    the corrupted tables.
    """
    maskf = table_mask.astype(jnp.float32)
    nh = jnp.maximum(jnp.sum(maskf), 1.0)
    return jnp.sum(gathered * maskf, axis=-1) * (1.0 / nh)


def lookup(state: AceState, buckets: jax.Array,
           table_mask: jax.Array | None = None) -> jax.Array:
    """counts[j, buckets[., j]] averaged over j.  (B, L) -> (B,) float32.

    This is Ŝ(q, D) of Algorithm 1 (query phase).  ``table_mask``
    averages over healthy tables only (see ``batch_scores``).
    """
    if state.esc is not None:
        return qz.batch_scores_logical(state.counts, state.esc, buckets,
                                       table_mask=table_mask)
    return batch_scores(state.counts, buckets, table_mask=table_mask)


def histogram(buckets: jax.Array, cfg: AceConfig) -> jax.Array:
    """Batch bucket histogram: (B, L) ids -> (L, 2^K) counts of this batch."""
    L = cfg.num_tables
    B = buckets.shape[0]
    rows = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None, :], (B, L))
    zero = jnp.zeros((L, cfg.num_buckets), dtype=jnp.dtype(cfg.counter_dtype))
    return zero.at[rows, buckets].add(1)


def welford_fold(welford_mean: jax.Array, welford_m2: jax.Array,
                 n: jax.Array, b: jax.Array, tot: jax.Array,
                 mean_b: jax.Array, m2_b: jax.Array, min_n: float):
    """Fold one batch's rate statistics into the Welford stream.

    (mean_b, m2_b) are the batch mean / sum-of-squared-deviations of the
    rates; the cold-start gate (min_n) RESTARTS the stream on the first
    gated batch — early rates are off-scale and Welford never forgets.
    Shared by every insert path (single-device, replicated shard_map,
    table-sharded — repro.dist.sketch_parallel) so their Welford numerics
    stay identical by construction, not by copy-synced formulas.
    """
    delta = mean_b - welford_mean
    gate = (n >= min_n).astype(jnp.float32)
    eff_n = jnp.where(gate > 0, n, 0.0)
    new_mean = jnp.where(
        gate > 0,
        welford_mean + delta * b / jnp.maximum(tot, 1.0),
        mean_b)
    new_m2 = jnp.where(
        gate > 0,
        welford_m2 + m2_b + delta**2 * eff_n * b / jnp.maximum(tot, 1.0),
        m2_b)
    return new_mean, new_m2


def insert_buckets(state: AceState, buckets: jax.Array,
                   cfg: AceConfig) -> AceState:
    """Insert a batch.  Order-invariant; exact for any batch size.

    Welford stats are updated with the *post-insert* score of each item
    (its own count included), matching Algorithm 1 line 12's convention of
    scoring x against D ∪ {x}.
    """
    L = cfg.num_tables
    if state.esc is not None:
        offs = _flat_offsets(buckets, L, cfg.num_buckets)
        new_counts, new_esc, post = qz.quantized_scatter(
            state.counts, state.esc, offs,
            jnp.ones((buckets.shape[0],), jnp.int32))
        # post IS the post-insert gather (exact logical counts) — same
        # row-sum + reciprocal mean as batch_scores, so below saturation
        # this is bitwise the unquantized path.
        scores = jnp.sum(post.astype(jnp.float32), axis=-1) \
            * jnp.float32(1.0 / L)
    else:
        rows = jnp.broadcast_to(
            jnp.arange(L, dtype=jnp.int32)[None, :], buckets.shape)
        new_counts = state.counts.at[rows, buckets].add(1)
        new_esc = None

        # Post-insert scores of the batch items (vs the fully updated
        # arrays).
        scores = batch_scores(new_counts, buckets)             # (B,)

    # Welford over collision RATES score/n, not raw scores: raw insert-time
    # scores grow ~linearly with n (item i scores ≈ O(i)), which inflates σ
    # with ramp variance and makes μ−ασ thresholds useless.  Rates are
    # stationary for a stationary stream.
    b = jnp.asarray(scores.shape[0], jnp.float32)
    n = state.n
    tot = n + b
    rates = scores / jnp.maximum(tot, 1.0)
    mean_b = jnp.mean(rates)
    m2_b = jnp.sum((rates - mean_b) ** 2)
    new_mean, new_m2 = welford_fold(
        state.welford_mean, state.welford_m2, n, b, tot, mean_b, m2_b,
        cfg.welford_min_n)

    return AceState(counts=new_counts, n=tot,
                    welford_mean=new_mean, welford_m2=new_m2, esc=new_esc,
                    qhist=state.qhist, attr=state.attr)


def masked_batch_welford(state: AceState, scores: jax.Array,
                         maskf: jax.Array, min_n: float, reduce=None):
    """Welford fold over only the masked items of a fixed-shape batch.

    ``scores`` are post-insert scores of ALL items (B,); ``maskf`` is the
    0/1 float admit mask.  Returns (n, welford_mean, welford_m2) after
    folding the masked subset's rate statistics — identical (up to float
    summation order) to folding ``scores[mask]`` through the dense path.
    An all-zero mask leaves the stream untouched (the dense path would
    NaN on an empty batch).

    ``reduce`` (optional) is applied to each scalar partial sum (count,
    rate sum, M2 sum) — a psum over the data axes when the batch is
    sharded, identity otherwise.  Every masked insert path (single-device,
    fused-kernel admit via repro.kernels.ops.ace_admit, and both
    repro.dist.sketch_parallel layouts) folds through THIS function, so
    their numerics stay identical by construction, not by copy-synced
    formulas (same contract as ``welford_fold`` for the dense paths).
    """
    if reduce is None:
        def reduce(v):  # noqa: A001 — identity for the unsharded batch
            return v
    b = reduce(jnp.sum(maskf))
    n = state.n
    tot = n + b
    rates = scores / jnp.maximum(tot, 1.0)
    mean_b = reduce(jnp.sum(rates * maskf)) / jnp.maximum(b, 1.0)
    m2_b = reduce(jnp.sum(((rates - mean_b) ** 2) * maskf))
    new_mean, new_m2 = welford_fold(
        state.welford_mean, state.welford_m2, n, b, tot, mean_b, m2_b,
        min_n)
    has = b > 0
    return (tot,
            jnp.where(has, new_mean, state.welford_mean),
            jnp.where(has, new_m2, state.welford_m2))


def insert_buckets_masked(state: AceState, buckets: jax.Array,
                          mask: jax.Array, cfg: AceConfig) -> AceState:
    """Masked (weighted) insert: insert only the items where ``mask``.

    Equivalent to ``insert_buckets(state, buckets[mask], cfg)`` — exactly
    for counts/n/μ (the scatter-add of 0/1 weights builds the identical
    histogram), and up to float summation order for the Welford stream —
    but FIXED-SHAPE: no data-dependent gather, so one compiled program
    serves every batch regardless of how many items are admitted.  This
    is the serving guardrail's insert (order-invariant and shape-stable;
    see Guardrail.admit).
    """
    L = cfg.num_tables
    if state.esc is not None:
        offs = _flat_offsets(buckets, L, cfg.num_buckets)
        new_counts, new_esc, post = qz.quantized_scatter(
            state.counts, state.esc, offs, mask.astype(jnp.int32))
        # post holds every item's exact post-scatter logical counts —
        # masked-out items included (colliding admits may bump their
        # buckets) — which is exactly the batch_scores(new_counts, ·)
        # gather of the unquantized path.
        scores = jnp.sum(post.astype(jnp.float32), axis=-1) \
            * jnp.float32(1.0 / L)
    else:
        rows = jnp.broadcast_to(
            jnp.arange(L, dtype=jnp.int32)[None, :], buckets.shape)
        w_ctr = jnp.broadcast_to(
            mask.astype(state.counts.dtype)[:, None], buckets.shape)
        new_counts = state.counts.at[rows, buckets].add(w_ctr)
        new_esc = None

        # Post-insert scores of ALL items vs the fully updated arrays
        # (the masked-out items just don't contribute to the Welford fold
        # below).
        scores = batch_scores(new_counts, buckets)              # (B,)

    tot, new_mean, new_m2 = masked_batch_welford(
        state, scores, mask.astype(jnp.float32), cfg.welford_min_n)
    return AceState(counts=new_counts, n=tot,
                    welford_mean=new_mean, welford_m2=new_m2, esc=new_esc,
                    qhist=state.qhist, attr=state.attr)


def delete_buckets(state: AceState, buckets: jax.Array,
                   cfg: AceConfig) -> AceState:
    """Remove previously inserted items (paper §3.4.1, Eq. 12).

    Welford stats are *not* un-merged (not possible in one pass); the exact μ
    (``mean_mu``) is unaffected since it is a pure function of counts.

    Quantized planes delete through the saturating scatter with weight
    −1: a promoted bucket whose logical count drops back to the cap is
    un-promoted (its escalation slot is freed).  Counts below the narrow
    dtype's min clamp (they cannot arise from matched insert/delete
    streams, which never go below 0).
    """
    if state.esc is not None:
        offs = _flat_offsets(buckets, cfg.num_tables, cfg.num_buckets)
        new_counts, new_esc, _ = qz.quantized_scatter(
            state.counts, state.esc, offs,
            jnp.full((buckets.shape[0],), -1, jnp.int32))
        return state._replace(
            counts=new_counts, esc=new_esc,
            n=state.n - jnp.asarray(buckets.shape[0], jnp.float32))
    rows = jnp.broadcast_to(
        jnp.arange(cfg.num_tables, dtype=jnp.int32)[None, :], buckets.shape)
    new_counts = state.counts.at[rows, buckets].add(-1)
    return state._replace(counts=new_counts,
                          n=state.n - jnp.asarray(buckets.shape[0], jnp.float32))


def merge(a: AceState, b: AceState) -> AceState:
    """Merge two sketches over disjoint data (counts add — CRDT style).

    Exact for counts/n; Welford streams merge by Chan's parallel rule.

    Quantized sketches merge exactly: both sides densify to int32
    logical planes, add, and requantize (narrow + fresh escalation
    table).  Excess that no longer fits the escalation capacity is
    accumulated into ``lost`` (plus both inputs' prior losses).
    Quantile histograms merge by exact addition (CRDT, like counts),
    and so do attribution planes (the signed count-sketch is linear).
    """
    delta = b.welford_mean - a.welford_mean
    tot = a.n + b.n
    safe = jnp.maximum(tot, 1.0)
    if (a.esc is None) != (b.esc is None):
        raise ValueError("cannot merge a quantized sketch with an "
                         "unquantized one")
    if a.esc is not None:
        if (a.esc.capacity != b.esc.capacity
                or a.counts.dtype != b.counts.dtype):
            raise ValueError("quantized merge requires matching "
                             "count_dtype and esc_capacity")
        dense = qz.densify(a.counts, a.esc) + qz.densify(b.counts, b.esc)
        counts, esc = qz.requantize(dense, a.esc.capacity,
                                    a.counts.dtype)
        esc = esc._replace(lost=esc.lost + a.esc.lost + b.esc.lost)
    else:
        counts, esc = a.counts + b.counts, None
    if (a.qhist is None) != (b.qhist is None):
        raise ValueError("cannot merge a quantile-tracking sketch with a "
                         "non-tracking one")
    qhist = None if a.qhist is None else a.qhist + b.qhist
    if (a.attr is None) != (b.attr is None):
        raise ValueError("cannot merge an attribution-tracking sketch "
                         "with a non-tracking one")
    attr = None if a.attr is None else a.attr + b.attr
    return AceState(
        counts=counts,
        n=tot,
        welford_mean=a.welford_mean + delta * b.n / safe,
        welford_m2=a.welford_m2 + b.welford_m2 + delta**2 * a.n * b.n / safe,
        esc=esc,
        qhist=qhist,
        attr=attr,
    )


# ---------------------------------------------------------------------------
# Statistics of the sketch.
# ---------------------------------------------------------------------------

def mean_mu(state: AceState,
            table_mask: jax.Array | None = None) -> jax.Array:
    """Exact dataset mean score  μ = Σ‖A_j‖² / (n·L)  (≡ paper Eq. 11 stream).

    Proof sketch: Algorithm 1 maintains n·μ = Σ_i Ŝ(x_i, D); item i in bucket
    b of array j contributes A_j[b]/L once per array, and bucket b holds
    A_j[b] items, so Σ_i A_j[H_j(x_i)] = Σ_b A_j[b]².

    ``table_mask`` (L,) restricts the closed form to healthy tables:
    μ = Σ_{j healthy} ‖A_j‖² / (n · num_healthy).  Each healthy table's
    counts still sum to n (conservation is per table), so per-table the
    formula is unchanged — only the mean over tables shrinks.  The
    masked path sweeps a densified plane for quantized sketches
    (degraded mode only — never the healthy hot path).
    """
    L = state.counts.shape[0]
    if table_mask is None:
        denom = jnp.maximum(state.n, 1.0) * L
        if state.esc is not None:
            return qz.sq_sum(state.counts, state.esc) / denom
        c = state.counts.astype(jnp.float32)
        return jnp.sum(c * c) / denom
    maskf = table_mask.astype(jnp.float32)
    nh = jnp.maximum(jnp.sum(maskf), 1.0)
    dense = (qz.densify(state.counts, state.esc)
             if state.esc is not None else state.counts)
    c = dense.astype(jnp.float32)
    per_table = jnp.sum(c * c, axis=1)                           # (L,)
    return jnp.sum(per_table * maskf) / (jnp.maximum(state.n, 1.0) * nh)


def mu_sequential_increment(state: AceState, buckets_one: jax.Array,
                            cfg: AceConfig):
    """One step of the paper's literal Eq. 11 (sequential, for testing).

    Returns (new_state, new_mu) for a SINGLE item with bucket ids (L,).
    """
    L = cfg.num_tables
    rows = jnp.arange(L, dtype=jnp.int32)
    old_mu = mean_mu(state)
    n = state.n
    new_counts = state.counts.at[rows, buckets_one].add(1)
    incr = jnp.sum(
        (2.0 * new_counts[rows, buckets_one].astype(jnp.float32) - 1.0) / L)
    new_mu = (n * old_mu + incr) / (n + 1.0)
    new_state = state._replace(counts=new_counts, n=n + 1.0)
    return new_state, new_mu


def mean_rate(state: AceState,
              table_mask: jax.Array | None = None) -> jax.Array:
    """Exact mean collision RATE  μ/n  (scale-free across stream growth)."""
    return mean_mu(state, table_mask=table_mask) / jnp.maximum(state.n, 1.0)


def sigma_welford(state: AceState) -> jax.Array:
    """Streaming σ of collision RATES (score/n) from insert-time stream."""
    return jnp.sqrt(state.welford_m2 / jnp.maximum(state.n - 1.0, 1.0))


def admit_threshold(state: AceState, alpha: float,
                    warmup_items: float,
                    table_mask: jax.Array | None = None,
                    threshold_mode: str = "mu_sigma",
                    q: float = 0.01) -> jax.Array:
    """Score-space admission threshold: admit iff  score >= threshold.

    Two modes, dispatched at trace time (``threshold_mode`` is a Python
    string, so each mode is its own cached executable and the default
    μ−ασ program is byte-identical to before the mode existed):

    * ``"mu_sigma"`` — the μ−ασ rule in rate space (rate = score/n);
      multiplying both sides by max(n, 1) > 0 moves it to score space so
      the decision is a single compare against ONE device scalar — which
      is what the fused admit kernel consumes.
    * ``"quantile"`` — flag the worst q%: the q-quantile of the
      collision-rate histogram ``state.qhist`` (repro.quantile), moved
      to score space by the same max(n, 1) multiply — still ONE device
      scalar, so the kernels never change.  Calibrated for heavy-tailed
      traffic where a single α miscalibrates FPR.

    During warmup (n < warmup_items) the threshold is −inf: everything
    is admitted.  Pure device scalar ops — no host sync.

    ``table_mask`` keeps the μ−ασ threshold consistent with masked
    scores: masked μ over the same healthy subset the scores average
    over (the Welford σ stream is a scalar over batch means —
    table-independent, so it needs no masking; the quantile histogram
    aggregates over the table MEAN, also table-independent).
    """
    if threshold_mode == "quantile":
        from repro.quantile import sketch as qsk
        if state.qhist is None:
            raise ValueError("threshold_mode='quantile' needs a sketch "
                             "with an attached qhist leaf "
                             "(see repro.quantile.sketch.init_hist)")
        return qsk.quantile_threshold(state.qhist, state.n, q, warmup_items)
    if threshold_mode != "mu_sigma":
        raise ValueError(f"unknown threshold_mode {threshold_mode!r}")
    t = (mean_rate(state, table_mask=table_mask)
         - alpha * sigma_welford(state)) * jnp.maximum(state.n, 1.0)
    return jnp.where(state.n >= warmup_items, t, -jnp.inf)


def sigma_cubic_proxy(state: AceState) -> jax.Array:
    """Per-array second-moment proxy:  E_i[A²] per array = Σ_b A³ / n.

    Var_proxy = mean_j Σ_b A_j[b]³/n − μ²  upper-bounds the true score
    variance when arrays are independent (Jensen); exposed as a diagnostics
    alternative to the Welford stream.
    """
    c = state.counts.astype(jnp.float32)
    n = jnp.maximum(state.n, 1.0)
    second = jnp.mean(jnp.sum(c**3, axis=1)) / n
    var = jnp.maximum(second - mean_mu(state) ** 2, 0.0)
    return jnp.sqrt(var)


# ---------------------------------------------------------------------------
# Vector-level convenience API (hashing included).
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg",))
def insert(state: AceState, w: jax.Array, x: jax.Array,
           cfg: AceConfig) -> AceState:
    """Insert raw vectors x (B, d)."""
    return insert_buckets(state, hash_buckets(x, w, cfg.srp), cfg)


@partial(jax.jit, static_argnames=("cfg",))
def delete(state: AceState, w: jax.Array, x: jax.Array,
           cfg: AceConfig) -> AceState:
    return delete_buckets(state, hash_buckets(x, w, cfg.srp), cfg)


@partial(jax.jit, static_argnames=("cfg",))
def score(state: AceState, w: jax.Array, q: jax.Array,
          cfg: AceConfig) -> jax.Array:
    """Ŝ(q, D) for raw queries q (B, d) -> (B,)."""
    return lookup(state, hash_buckets(q, w, cfg.srp))


@partial(jax.jit, static_argnames=("cfg", "alpha"))
def is_anomaly(state: AceState, w: jax.Array, q: jax.Array,
               cfg: AceConfig, alpha: float = 1.0) -> jax.Array:
    """Decision rule of Algorithm 1 line 22 with the paper's experimental
    μ − α·σ threshold, applied in RATE space (score/n vs μ/n − α·σ_rate) so
    the streaming σ is stationary."""
    r = score(state, w, q, cfg) / jnp.maximum(state.n, 1.0)
    thresh = mean_rate(state) - alpha * sigma_welford(state)
    return r < thresh
