"""Signed Random Projections (SRP) — the LSH family used by ACE.

The paper (§2.1) uses the Goemans–Williamson / Charikar family

    h_w(x) = sign(w^T x),   w ~ N(0, I_d)

with collision probability  Pr[h_w(x) = h_w(y)] = 1 − θ(x, y)/π.

ACE needs K·L independent SRP bits per input, grouped into L meta-hashes of
K bits each; the K bits are packed into an integer bucket id in [0, 2^K).

TPU adaptation: all K·L projections are one (B, d) @ (d, K·L) matmul (MXU),
followed by a sign + bit-pack epilogue (VPU).  ``K*L`` is padded up to a
multiple of 128 internally so the matmul is lane-aligned; pad lanes are
discarded before packing.  The Pallas kernel in ``repro.kernels.srp_hash``
implements the same contract with explicit VMEM tiling; this module is the
reference / small-scale path and the parameter factory.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

LANE = 128  # TPU vector lane width; MXU is 128x128.


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


HASH_MODES = ("dense", "srht", "auto")


@dataclasses.dataclass(frozen=True)
class SrpConfig:
    """Static configuration of an SRP meta-hash bank.

    Attributes:
      dim:  input dimensionality d.
      num_bits: K — bits per meta-hash (bucket space is 2^K).
      num_tables: L — number of independent meta-hashes / count arrays.
      seed: PRNG seed for the projection matrix.
      pad_lanes: if True, the projection matrix is materialised with K*L
        rounded up to a multiple of 128 (extra columns are ignored at pack
        time).  The paper uses K=15, L=50 -> 750 projections; we compute 768.
      hash_mode: which hash construction every hot path uses —
        ``"dense"`` (the O(d·KL) Gaussian matmul, MXU), ``"srht"`` (the
        O(d log d + KL) Fast-JL transform of paper §2.2, VPU —
        ``repro.core.srht``), or ``"auto"`` (the throughput-weighted
        break-even ``repro.core.srht.choose_hash_mode`` picks per config).
        The two families draw DIFFERENT hash functions: a sketch built
        under one mode must be queried under the same mode (the mode is
        part of the persisted-sketch contract, like ``seed``).
    """

    dim: int
    num_bits: int = 15
    num_tables: int = 50
    seed: int = 0
    pad_lanes: bool = True
    hash_mode: str = "dense"

    @property
    def num_projections(self) -> int:
        return self.num_bits * self.num_tables

    @property
    def padded_projections(self) -> int:
        if not self.pad_lanes:
            return self.num_projections
        return _round_up(self.num_projections, LANE)

    @property
    def num_buckets(self) -> int:
        return 1 << self.num_bits


def make_projections(cfg: SrpConfig, dtype=jnp.float32) -> jax.Array:
    """Sample the (d, K*L_padded) Gaussian projection matrix.

    The first K*L columns are the live projections (column j*K + k is bit k of
    meta-hash j); trailing pad columns are only there for lane alignment.

    When the config resolves to the SRHT hash family, the matrix is never
    consumed — return a (d, 0) placeholder instead of materialising (and
    threading through every jitted program) what would be ~37 MB of dead
    fp32 at d_model=12288.  The placeholder keeps every ``(state, w, x)``
    call signature intact.
    """
    if resolve_hash_mode(cfg) == "srht":
        return jnp.zeros((cfg.dim, 0), dtype=dtype)
    key = jax.random.PRNGKey(cfg.seed)
    w = jax.random.normal(key, (cfg.dim, cfg.padded_projections), dtype=dtype)
    return w


def srp_bits(x: jax.Array, w: jax.Array, cfg: SrpConfig) -> jax.Array:
    """Raw sign bits.  x: (..., d) -> (..., K*L) int32 in {0, 1}.

    sign(0) is defined as +1 (bit 1) so the map is deterministic; with
    Gaussian projections the event has measure zero for real data anyway.
    """
    proj = jnp.einsum("...d,dp->...p", x, w.astype(x.dtype))
    bits = (proj >= 0).astype(jnp.int32)
    return bits[..., : cfg.num_projections]


def pack_buckets(bits: jax.Array, cfg: SrpConfig) -> jax.Array:
    """Pack K-bit groups into bucket ids.  (..., K*L) -> (..., L) int32.

    Bit k of meta-hash j is column j*K + k; packing is big-endian on k
    (first bit = MSB) — any fixed convention works, it only has to match the
    kernel and stay stable across versions (sketch state is persisted).
    """
    K, L = cfg.num_bits, cfg.num_tables
    grouped = bits.reshape(*bits.shape[:-1], L, K)
    weights = (1 << jnp.arange(K - 1, -1, -1, dtype=jnp.int32))
    return jnp.sum(grouped * weights, axis=-1, dtype=jnp.int32)


def resolve_hash_mode(cfg: SrpConfig) -> str:
    """Resolve ``cfg.hash_mode`` to a concrete family (auto → break-even)."""
    if cfg.hash_mode not in HASH_MODES:
        raise ValueError(f"unknown hash_mode {cfg.hash_mode!r} "
                         f"(want one of {HASH_MODES})")
    if cfg.hash_mode == "auto":
        from repro.core import srht  # local: srht imports this module
        return srht.choose_hash_mode(cfg)
    return cfg.hash_mode


def hash_buckets(x: jax.Array, w: jax.Array, cfg: SrpConfig) -> jax.Array:
    """Full SRP meta-hash: (..., d) -> (..., L) bucket ids in [0, 2^K).

    THE hash entry point of every jnp hot path (sketch insert/score, both
    ``repro.dist`` layouts, the data filter, the stream runner): dispatches
    on ``cfg.hash_mode`` between the dense matmul and the SRHT fast path,
    so flipping the knob re-routes them all at once.  ``w`` is ignored
    under ``"srht"`` (the transform is parameterised by sign diagonals and
    a row sample derived from ``cfg.seed``) but keeps its place in the
    signature so the two families are drop-in interchangeable.
    """
    if resolve_hash_mode(cfg) == "srht":
        from repro.core import srht  # local: srht imports this module
        return srht.srht_hash_buckets(x, srht.srht_params(cfg))
    return pack_buckets(srp_bits(x, w, cfg), cfg)


def collision_probability(q: jax.Array, x: jax.Array) -> jax.Array:
    """p(q, x) = 1 − θ/π for SRP (paper Eq. 1).  Broadcasts over leading dims."""
    qn = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-12)
    xn = x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + 1e-12)
    cos = jnp.clip(jnp.sum(qn * xn, axis=-1), -1.0, 1.0)
    return 1.0 - jnp.arccos(cos) / jnp.pi


@partial(jax.jit, static_argnames=("cfg",))
def hash_buckets_jit(x: jax.Array, w: jax.Array, cfg: SrpConfig) -> jax.Array:
    return hash_buckets(x, w, cfg)


def projection_memory_bytes(cfg: SrpConfig, dtype_bytes: int = 4) -> int:
    """Memory to store the projections (paper §3.4: ~6d KB for K=15,L=50)."""
    return cfg.dim * cfg.padded_projections * dtype_bytes


def seeds_memory_bytes(cfg: SrpConfig) -> int:
    """Paper's alternative: store K*L integer seeds, regenerate rows on the fly."""
    return cfg.num_projections * 4
