"""Signed Random Projections (SRP) — the LSH family used by ACE.

The paper (§2.1) uses the Goemans–Williamson / Charikar family

    h_w(x) = sign(w^T x),   w ~ N(0, I_d)

with collision probability  Pr[h_w(x) = h_w(y)] = 1 − θ(x, y)/π.

ACE needs K·L independent SRP bits per input, grouped into L meta-hashes of
K bits each; the K bits are packed into an integer bucket id in [0, 2^K).

TPU adaptation: all K·L projections are one (B, d) @ (d, K·L) matmul (MXU),
followed by a sign + bit-pack epilogue (VPU).  ``K*L`` is padded up to a
multiple of 128 internally so the matmul is lane-aligned; pad lanes are
discarded before packing.  The Pallas kernel in ``repro.kernels.srp_hash``
implements the same contract with explicit VMEM tiling; this module is the
reference / small-scale path and the parameter factory.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

LANE = 128  # TPU vector lane width; MXU is 128x128.


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class SrpConfig:
    """Static configuration of an SRP meta-hash bank.

    Attributes:
      dim:  input dimensionality d.
      num_bits: K — bits per meta-hash (bucket space is 2^K).
      num_tables: L — number of independent meta-hashes / count arrays.
      seed: PRNG seed for the projection matrix.
      pad_lanes: if True, the projection matrix is materialised with K*L
        rounded up to a multiple of 128 (extra columns are ignored at pack
        time).  The paper uses K=15, L=50 -> 750 projections; we compute 768.
    """

    dim: int
    num_bits: int = 15
    num_tables: int = 50
    seed: int = 0
    pad_lanes: bool = True

    @property
    def num_projections(self) -> int:
        return self.num_bits * self.num_tables

    @property
    def padded_projections(self) -> int:
        if not self.pad_lanes:
            return self.num_projections
        return _round_up(self.num_projections, LANE)

    @property
    def num_buckets(self) -> int:
        return 1 << self.num_bits


def make_projections(cfg: SrpConfig, dtype=jnp.float32) -> jax.Array:
    """Sample the (d, K*L_padded) Gaussian projection matrix.

    The first K*L columns are the live projections (column j*K + k is bit k of
    meta-hash j); trailing pad columns are only there for lane alignment.
    """
    key = jax.random.PRNGKey(cfg.seed)
    w = jax.random.normal(key, (cfg.dim, cfg.padded_projections), dtype=dtype)
    return w


def srp_bits(x: jax.Array, w: jax.Array, cfg: SrpConfig) -> jax.Array:
    """Raw sign bits.  x: (..., d) -> (..., K*L) int32 in {0, 1}.

    sign(0) is defined as +1 (bit 1) so the map is deterministic; with
    Gaussian projections the event has measure zero for real data anyway.
    """
    proj = jnp.einsum("...d,dp->...p", x, w.astype(x.dtype))
    bits = (proj >= 0).astype(jnp.int32)
    return bits[..., : cfg.num_projections]


def pack_buckets(bits: jax.Array, cfg: SrpConfig) -> jax.Array:
    """Pack K-bit groups into bucket ids.  (..., K*L) -> (..., L) int32.

    Bit k of meta-hash j is column j*K + k; packing is big-endian on k
    (first bit = MSB) — any fixed convention works, it only has to match the
    kernel and stay stable across versions (sketch state is persisted).
    """
    K, L = cfg.num_bits, cfg.num_tables
    grouped = bits.reshape(*bits.shape[:-1], L, K)
    weights = (1 << jnp.arange(K - 1, -1, -1, dtype=jnp.int32))
    return jnp.sum(grouped * weights, axis=-1, dtype=jnp.int32)


def hash_buckets(x: jax.Array, w: jax.Array, cfg: SrpConfig) -> jax.Array:
    """Full SRP meta-hash: (..., d) -> (..., L) bucket ids in [0, 2^K)."""
    return pack_buckets(srp_bits(x, w, cfg), cfg)


def collision_probability(q: jax.Array, x: jax.Array) -> jax.Array:
    """p(q, x) = 1 − θ/π for SRP (paper Eq. 1).  Broadcasts over leading dims."""
    qn = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-12)
    xn = x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + 1e-12)
    cos = jnp.clip(jnp.sum(qn * xn, axis=-1), -1.0, 1.0)
    return 1.0 - jnp.arccos(cos) / jnp.pi


@partial(jax.jit, static_argnames=("cfg",))
def hash_buckets_jit(x: jax.Array, w: jax.Array, cfg: SrpConfig) -> jax.Array:
    return hash_buckets(x, w, cfg)


def projection_memory_bytes(cfg: SrpConfig, dtype_bytes: int = 4) -> int:
    """Memory to store the projections (paper §3.4: ~6d KB for K=15,L=50)."""
    return cfg.dim * cfg.padded_projections * dtype_bytes


def seeds_memory_bytes(cfg: SrpConfig) -> int:
    """Paper's alternative: store K*L integer seeds, regenerate rows on the fly."""
    return cfg.num_projections * 4
