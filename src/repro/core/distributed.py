"""Deprecated shim — the distributed ACE primitives moved to
``repro.dist.sketch_parallel`` (PR: repro.dist subsystem).  Import from
there; this module re-exports for older callers and will be removed.
"""
from repro.dist.sketch_parallel import (  # noqa: F401
    local_histogram, make_shardmap_update, make_table_sharded_mean_mu,
    make_table_sharded_score, make_table_sharded_update, mean_mu_table_sharded,
    score_global, score_table_sharded, sketch_shardings,
    table_sharded_shardings, update_global, update_table_sharded,
)
