"""Distributed ACE: sharded streaming update + exact psum merge.

The sketch is a commutative monoid under count addition (``sketch.merge``),
so the multi-device story is exactly gradient all-reduce's:

  * each data shard hashes + histograms its local slice of the batch,
  * one ``psum`` over the data axes yields the histogram of the global batch,
  * every device applies the same dense add to its (replicated) counts.

This keeps the counts replica-consistent without ever gathering raw data —
which is also the paper's §4 privacy story at datacenter scale: only counts
of hashes cross the network.

Two deployment modes:

1. ``update_shardmap`` / ``score_shardmap`` — explicit shard_map collectives,
   used inside training steps that are themselves shard_mapped.
2. Plain jit + NamedSharding: annotate batch as data-sharded, counts as
   replicated, and let SPMD partitioning insert the all-reduce.  This is the
   mode compiled into ``train_step`` (see repro/train/train_loop.py) so the
   dry-run HLO contains the ACE collective schedule.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import sketch as sk
from repro.core.sketch import AceConfig, AceState
from repro.core.srp import hash_buckets


def local_histogram(x: jax.Array, w: jax.Array, cfg: AceConfig) -> jax.Array:
    """Histogram of the local batch shard: (B_local, d) -> (L, 2^K)."""
    buckets = hash_buckets(x, w, cfg.srp)
    return sk.histogram(buckets, cfg)


def update_global(state: AceState, x: jax.Array, w: jax.Array,
                  cfg: AceConfig, axis_names=()) -> AceState:
    """Insert a (possibly sharded) batch into a replicated sketch.

    Inside shard_map: pass ``axis_names`` to psum the histogram.  Under plain
    jit/SPMD, call with axis_names=() and let sharding propagation reduce.
    """
    hist = local_histogram(x, w, cfg)
    if axis_names:
        hist = jax.lax.psum(hist, axis_names)
    new_counts = state.counts + hist

    # Post-insert scores of the local shard items for Welford (approximate
    # insert-time stream; exact μ never uses it).
    buckets = hash_buckets(x, w, cfg.srp)
    rows = jnp.broadcast_to(
        jnp.arange(cfg.num_tables, dtype=jnp.int32)[None, :], buckets.shape)
    scores = jnp.mean(new_counts[rows, buckets].astype(jnp.float32), axis=-1)

    b_local = jnp.asarray(scores.shape[0], jnp.float32)
    if axis_names:
        b_local = jax.lax.psum(b_local, axis_names)
    n = state.n
    tot = n + b_local
    rates = scores / jnp.maximum(tot, 1.0)   # rate stream (see sketch.py)
    sum_s = jnp.sum(rates)
    sum_s2 = jnp.sum(rates * rates)
    if axis_names:
        sum_s = jax.lax.psum(sum_s, axis_names)
        sum_s2 = jax.lax.psum(sum_s2, axis_names)
    mean_b = sum_s / jnp.maximum(b_local, 1.0)
    m2_b = jnp.maximum(sum_s2 - b_local * mean_b * mean_b, 0.0)

    b = b_local
    delta = mean_b - state.welford_mean
    safe = jnp.maximum(tot, 1.0)
    return AceState(
        counts=new_counts,
        n=tot,
        welford_mean=state.welford_mean + delta * b / safe,
        welford_m2=state.welford_m2 + m2_b + delta**2 * n * b / safe,
    )


def score_global(state: AceState, q: jax.Array, w: jax.Array,
                 cfg: AceConfig) -> jax.Array:
    """Score a sharded query batch against the replicated sketch.

    Pure map — no collective needed (counts are replicated)."""
    return sk.lookup(state, hash_buckets(q, w, cfg.srp))


def make_shardmap_update(mesh, cfg: AceConfig, data_axes=("data",)):
    """Build a shard_map'd update: batch sharded over ``data_axes``, sketch
    replicated.  Returned fn: (state, x, w) -> state."""
    from jax.experimental.shard_map import shard_map

    batch_spec = P(data_axes)
    rep = P()

    def _upd(state, x, w):
        return update_global(state, x, w, cfg, axis_names=data_axes)

    return shard_map(
        _upd, mesh=mesh,
        in_specs=(AceState(rep, rep, rep, rep), batch_spec, rep),
        out_specs=AceState(rep, rep, rep, rep),
        check_rep=False)


def sketch_shardings(mesh) -> AceState:
    """NamedSharding pytree for the replicated sketch under plain jit."""
    from jax.sharding import NamedSharding
    rep = NamedSharding(mesh, P())
    return AceState(rep, rep, rep, rep)
