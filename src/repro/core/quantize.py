"""Quantized count planes: narrow (int8/int16) counters + overflow escalation.

ACE's memory pitch is "a detector is a few MB of counts" — but the repo's
tables default to 4-byte counters, so every full-table sweep (the μ
closed form), every gather, and every resident fleet/window table pays 4×
the bandwidth and HBM the data needs.  The In-DRAM working-set counting
line (PAPERS.md, arXiv 1902.04143) shows the classic fix: keep the plane
in a NARROW dtype and *promote* the rare counter that overflows into a
small side table, so accuracy is exact while the memory is set by the
common case.

This module is that fix for the ACE sketch algebra:

* the **narrow plane** stores ``min(count, CAP)`` per bucket in int8 /
  int16 (CAP = 127 / 32767 — the dtype max, so promotion fires at
  exactly the saturation boundary);
* the **escalation table** (:class:`EscTable`) holds the excess
  ``count − CAP`` for the (few) promoted buckets as a fixed-capacity
  sorted array of flat element offsets — fixed-shape, device-resident,
  jit/scan/donation-safe like every other piece of sketch state;
* the **logical value** of a bucket is ``narrow + excess`` everywhere a
  count is read (scores, μ, merges), so estimates are EXACT past the
  dtype max as long as the promoted set fits ``esc_capacity`` (overflow
  beyond capacity is counted in ``lost`` — loud in diagnostics, never
  silent corruption).

Exactness contract (property-tested in tests/test_quantized_counts.py):
below saturation the narrow plane IS the count array — inserts, deletes,
merges, scores and μ are bitwise the float32/int32 oracle's, because the
gathered integers and the float summation orders are identical.  At and
past saturation, reads reconstruct the exact logical counts through the
escalation table, so scores/μ stay exact (not approximate) while the
plane stays narrow.

The scatter (:func:`quantized_scatter`) is the one nontrivial op: a plain
``.at[].add`` on a narrow dtype WRAPS at the dtype max (int8: 127+1 →
−128) with no error, so the masked-insert hot path instead computes each
touched bucket's exact post-value from (pre-narrow + pre-excess +
within-batch collision multiplicity) and scatter-SETS the saturated
value — every duplicate writes the same value, so the scatter is
deterministic, and the per-item logical post-values come out for free
(they are exactly the post-insert gathers every insert path already
needs for its Welford fold).

Scope: the escalation path is wired for the FLAT sketch
(``repro.core.sketch.AceState``).  Window rings and fleet tables take
narrow planes (the bandwidth/memory win — their count reads all go
through ``astype(float32)`` gathers, which are dtype-generic), but their
promotion is not wired: ``WindowConfig``/``FleetConfig`` reject
``esc_capacity > 0`` loudly rather than silently wrapping.  See
docs/ARCHITECTURE.md §7.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# Free escalation slots carry this offset; int32 max sorts AFTER every
# real flat offset (planes are validated flat-addressable, i.e. their
# element count stays below int32 max), so the offs array stays sorted
# with the live entries first and searchsorted lookups stay O(log C).
SENTINEL = 2**31 - 1

_NARROW = ("int8", "int16")


def is_narrow(dtype) -> bool:
    """True for the count dtypes that can saturate (int8/int16)."""
    return jnp.dtype(dtype).name in _NARROW


def cap_for(dtype) -> int:
    """The saturation cap of a narrow plane — the dtype max itself, so
    promotion fires at exactly 127 / 32767 (the tested contract)."""
    return int(jnp.iinfo(jnp.dtype(dtype)).max)


class EscTable(NamedTuple):
    """Fixed-capacity overflow side table (a pytree — jit/scan safe).

    offs: (C,) int32 — SORTED flat element offsets of promoted buckets;
          free slots hold :data:`SENTINEL` (sorts last).
    vals: (C,) int32 — excess above the narrow cap (> 0 for live slots,
          0 for free ones).  logical = narrow + excess.
    lost: ()  float32 — total excess dropped because the table was full
          (0.0 while estimates are exact; diagnostics, never silent).
    """

    offs: jax.Array
    vals: jax.Array
    lost: jax.Array

    @property
    def capacity(self) -> int:
        return self.offs.shape[0]


def init_esc(capacity: int) -> EscTable:
    if capacity < 1:
        raise ValueError(f"esc capacity must be >= 1, got {capacity}")
    return EscTable(
        offs=jnp.full((capacity,), SENTINEL, jnp.int32),
        vals=jnp.zeros((capacity,), jnp.int32),
        lost=jnp.zeros((), jnp.float32),
    )


def esc_lookup(esc: EscTable, offs: jax.Array) -> jax.Array:
    """Excess value at each flat offset (0 where not promoted).

    One searchsorted against the sorted live prefix — offs any int32
    shape, returns the same shape int32."""
    C = esc.offs.shape[0]
    idx = jnp.clip(jnp.searchsorted(esc.offs, offs), 0, C - 1) \
        .astype(jnp.int32)
    hit = jnp.take(esc.offs, idx) == offs
    return jnp.where(hit, jnp.take(esc.vals, idx), 0)


def gather_logical(plane: jax.Array, esc: EscTable,
                   offs: jax.Array) -> jax.Array:
    """Exact logical counts at flat element offsets: narrow + excess.

    int32 out (same shape as ``offs``); callers ``astype(float32)`` in
    the same position the unquantized paths cast their gathers, so the
    downstream float sequences stay identical."""
    nar = jnp.take(plane.reshape(-1), offs).astype(jnp.int32)
    return nar + esc_lookup(esc, offs)


def batch_scores_logical(plane: jax.Array, esc: EscTable,
                         buckets: jax.Array,
                         table_mask: jax.Array | None = None) -> jax.Array:
    """``sketch.batch_scores`` over the exact logical counts.

    Same row-sum + ONE reciprocal 1/L multiply as the unquantized
    helper (the repo-wide bitwise-parity convention); below saturation
    the gathered integers are identical, so this IS batch_scores
    bitwise.  ``table_mask`` (L,) averages over healthy tables only —
    same Python-level branch as ``sketch.batch_scores``, so the unmasked
    program never sees the mask."""
    L, nbuckets = plane.shape
    rows = jnp.broadcast_to(
        jnp.arange(L, dtype=jnp.int32)[None, :], buckets.shape)
    offs = buckets + rows * nbuckets
    g = gather_logical(plane, esc, offs).astype(jnp.float32)     # (B, L)
    if table_mask is None:
        return jnp.sum(g, axis=-1) * jnp.float32(1.0 / L)
    maskf = table_mask.astype(jnp.float32)
    nh = jnp.maximum(jnp.sum(maskf), 1.0)
    return jnp.sum(g * maskf, axis=-1) * (1.0 / nh)


def quantized_scatter(plane: jax.Array, esc: EscTable, offs: jax.Array,
                      w: jax.Array):
    """Exact saturating masked scatter into a narrow plane.

    plane: (R, 2^K) narrow dtype; offs: (B, L) int32 flat ELEMENT
    offsets (row·2^K + bucket); w: (B,) integer weights (0 = masked
    out, +1 insert, −1 delete).  Returns ``(new_plane, new_esc, post)``
    where ``post`` (B, L) int32 is each item's exact logical
    POST-scatter value at its offsets — masked-out items included
    (their buckets may still be bumped by colliding active items), which
    is precisely the post-insert gather every insert path feeds its
    Welford fold.

    Algorithm (fixed-shape, no data-dependent gathers):

    1. Within-batch multiplicity: two items share a flat offset only
       when they share the COLUMN too (the row encodes the table index
       j, and j is the column), so the collision structure is the
       (B, B, L) equality mask and each offset's total batch delta is
       ``madd[b, l] = Σ_b2 same[b, b2, l] · w[b2]``.
    2. Exact post value ``V = pre_narrow + pre_excess + madd`` — a pure
       function of the offset, so every colliding item computes the
       SAME V and the narrow write can be a scatter-``set`` of
       ``clip(V, dtype_min, CAP)`` (duplicates write equal values:
       deterministic; untouched offsets rewrite their pre value: a
       no-op).
    3. One LEADER per touched offset (the first active item holding it)
       maintains the escalation table: excess = max(V − CAP, 0)
       overwrites the offset's live slot (0 frees it — deletes
       un-promote), new promotions claim free slots in rank order, and
       excess that finds no slot is added to ``lost`` instead of being
       silently dropped.  The offs array is re-sorted (C is small) so
       lookups stay binary-search.
    """
    dtype = plane.dtype
    cap = cap_for(dtype)
    lo = int(jnp.iinfo(dtype).min)
    B, L = offs.shape
    C = esc.offs.shape[0]
    flat = plane.reshape(-1)

    w_i = w.astype(jnp.int32)                                    # (B,)
    active = w_i != 0
    same = offs[:, None, :] == offs[None, :, :]                  # (B,B,L)
    madd = jnp.sum(same * w_i[None, :, None], axis=1)            # (B,L)

    pre_nar = jnp.take(flat, offs).astype(jnp.int32)             # (B,L)
    pre_esc = esc_lookup(esc, offs)                              # (B,L)
    post = pre_nar + pre_esc + madd                              # exact V

    new_flat = flat.at[offs].set(
        jnp.clip(post, lo, cap).astype(dtype))
    new_plane = new_flat.reshape(plane.shape)

    # -- leaders: first ACTIVE item per touched offset
    bidx = jnp.arange(B, dtype=jnp.int32)
    earlier = (bidx[None, :] < bidx[:, None])                    # (B,B)
    prior = jnp.sum(same & active[None, :, None]
                    & earlier[:, :, None], axis=1)               # (B,L)
    leader = active[:, None] & (prior == 0)                      # (B,L)

    offs_f = offs.reshape(-1)
    lead_f = leader.reshape(-1)
    exc_f = jnp.maximum(post, 0).reshape(-1)
    exc_f = jnp.maximum(exc_f - cap, 0)                          # excess'

    # 1) overwrite live slots (excess 0 frees the slot)
    idx = jnp.clip(jnp.searchsorted(esc.offs, offs_f), 0, C - 1) \
        .astype(jnp.int32)
    hit = jnp.take(esc.offs, idx) == offs_f
    upd = lead_f & hit
    new_vals = esc.vals.at[jnp.where(upd, idx, C)].set(
        exc_f, mode="drop")
    new_offs = jnp.where(new_vals > 0, esc.offs, SENTINEL)

    # 2) allocate free slots for fresh promotions, in rank order
    need = lead_f & (~hit) & (exc_f > 0)
    free = new_vals == 0                                         # (C,)
    rank = jnp.cumsum(need.astype(jnp.int32)) - 1                # (B·L,)
    free_rank = jnp.cumsum(free.astype(jnp.int32)) - 1           # (C,)
    slot_of_rank = jnp.full((C,), C, jnp.int32).at[
        jnp.where(free, free_rank, C)].set(
        jnp.arange(C, dtype=jnp.int32), mode="drop")
    nfree = jnp.sum(free.astype(jnp.int32))
    ok = need & (rank < nfree)
    dest = jnp.where(ok, jnp.take(slot_of_rank,
                                  jnp.clip(rank, 0, C - 1)), C)
    new_offs = new_offs.at[dest].set(offs_f, mode="drop")
    new_vals = new_vals.at[dest].set(exc_f, mode="drop")
    dropped = jnp.sum(jnp.where(need & ~ok, exc_f, 0)
                      .astype(jnp.float32))

    # 3) restore the sorted invariant (free SENTINEL slots sort last)
    order = jnp.argsort(new_offs)
    new_esc = EscTable(offs=new_offs[order], vals=new_vals[order],
                       lost=esc.lost + dropped)
    return new_plane, new_esc, post


def densify(plane: jax.Array, esc: EscTable) -> jax.Array:
    """Exact int32 logical plane: narrow + scattered excess.

    O(plane) — the merge/diagnostic path, never the per-item hot path."""
    dense = plane.astype(jnp.int32).reshape(-1)
    dense = dense.at[esc.offs].add(
        jnp.where(esc.offs != SENTINEL, esc.vals, 0), mode="drop")
    return dense.reshape(plane.shape)


def sq_sum(plane: jax.Array, esc: EscTable) -> jax.Array:
    """Σ logical² over the plane — the Eq. 11 closed-form numerator.

    Narrow-plane sweep + per-slot correction ((nar+exc)² − nar²): below
    saturation the correction terms are exact float zeros, so this is
    bitwise ``jnp.sum(c*c)`` of the oracle plane."""
    c = plane.astype(jnp.float32)
    base = jnp.sum(c * c)
    flat = plane.reshape(-1)
    occ = esc.offs != SENTINEL
    safe = jnp.clip(jnp.where(occ, esc.offs, 0), 0, flat.shape[0] - 1)
    nar = jnp.take(flat, safe).astype(jnp.float32)
    tot = nar + esc.vals.astype(jnp.float32)
    corr = jnp.sum(jnp.where(occ, tot * tot - nar * nar, 0.0))
    return base + corr


def requantize(dense: jax.Array, capacity: int, dtype):
    """int32 logical plane -> (narrow plane, EscTable).

    The merge path: densify both sides, add exactly in int32, re-split
    into narrow + excess.  The ``capacity`` largest excesses win slots
    (top_k); any remainder lands in ``lost``."""
    cap = cap_for(dtype)
    lo = int(jnp.iinfo(dtype).min)
    flat = dense.reshape(-1)
    excess = jnp.maximum(flat - cap, 0)
    vals, idx = jax.lax.top_k(excess, capacity)
    keep = vals > 0
    offs = jnp.where(keep, idx.astype(jnp.int32), SENTINEL)
    vals = jnp.where(keep, vals, 0)
    order = jnp.argsort(offs)
    lost = jnp.sum(excess.astype(jnp.float32)) \
        - jnp.sum(vals.astype(jnp.float32))
    narrow = jnp.clip(flat, lo, cap).astype(dtype).reshape(dense.shape)
    return narrow, EscTable(offs=offs[order], vals=vals[order],
                            lost=lost)
