"""Estimators of S(q, D) = Σ_i p(q, x_i)^K and their theoretical variances.

Three estimators, matching the paper's §3.3:

* ``exact_score``   — the O(n·d) oracle (ground truth for MSE experiments).
* ``AceEstimator``  — Algorithm 1 (wraps ``repro.core.sketch``).
* ``rse_score``     — the random-sampling estimator RSE (Eq. 10, Theorem 2).

plus closed-form variance terms from Theorems 1 and 2 for the analytical
comparison plots.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import sketch as sk
from repro.core.srp import collision_probability


def collision_probs(q: jax.Array, data: jax.Array) -> jax.Array:
    """p_i = p(q, x_i) for all x_i.  q: (d,) or (B, d); data: (n, d).

    Returns (n,) or (B, n).
    """
    qn = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-12)
    dn = data / (jnp.linalg.norm(data, axis=-1, keepdims=True) + 1e-12)
    cos = jnp.clip(qn @ dn.T, -1.0, 1.0)
    return 1.0 - jnp.arccos(cos) / jnp.pi


@partial(jax.jit, static_argnames=("K",))
def exact_score(q: jax.Array, data: jax.Array, K: int) -> jax.Array:
    """S(q, D) = Σ_i p_i^K — the exact (expensive) statistic, paper Eq. 3."""
    return jnp.sum(collision_probs(q, data) ** K, axis=-1)


@partial(jax.jit, static_argnames=("K", "num_samples"))
def rse_score(q: jax.Array, data: jax.Array, K: int, num_samples: int,
              key: jax.Array) -> jax.Array:
    """Random-sampling estimator (paper Eq. 10): (n/L)·Σ_{x∈S} p(q,x)^K.

    Uniform sampling WITHOUT replacement to match Theorem 2's analysis.
    """
    n = data.shape[0]
    idx = jax.random.permutation(key, n)[:num_samples]
    sample = data[idx]
    p = collision_probs(q, sample) ** K
    return (n / num_samples) * jnp.sum(p, axis=-1)


# --------------------------------------------------------------------------
# Theoretical variances (for analysis plots / sanity tests).
# --------------------------------------------------------------------------

def ace_variance_leading(p: jax.Array, K: int, L: int) -> jax.Array:
    """Leading (diagonal) term of Theorem 1:  (1/L)·Σ p^K (1 − p^K).

    The covariance term is data-dependent (and almost always negative for
    real data — paper's argument); this is the upper-ish bound used in the
    paper's comparison.
    """
    pk = p**K
    return jnp.sum(pk * (1.0 - pk), axis=-1) / L


def rse_variance(p: jax.Array, K: int, L: int, n: int) -> jax.Array:
    """Theorem 2:  Var(RSE) = (n/L − 1)·Σ p^{2K}."""
    pk = p**K
    return (n / L - 1.0) * jnp.sum(pk * pk, axis=-1)


# --------------------------------------------------------------------------
# Convenience bundle used by benchmarks: build, fill, score.
# --------------------------------------------------------------------------

class AceEstimator:
    """Stateful convenience wrapper over the functional sketch API.

    Usage:
        est = AceEstimator(AceConfig(dim=d))
        est.fit(X)                  # or stream .update(batch) calls
        s = est.score(Q)            # Ŝ(q, D)
        flags = est.predict(Q)      # score < μ − α·σ
    """

    def __init__(self, cfg: sk.AceConfig, use_kernels: bool = False):
        self.cfg = cfg
        self.w = sk.make_params(cfg)
        self.state = sk.init(cfg)
        self.use_kernels = use_kernels
        if use_kernels:
            from repro.kernels import ops as kops  # lazy; optional dep path
            self._kops = kops

    def update(self, x: jax.Array) -> "AceEstimator":
        if self.use_kernels:
            # hash_dispatch, not srp_hash: honours cfg.hash_mode (the
            # dense w is a (d, 0) placeholder under "srht")
            buckets = self._kops.hash_dispatch(x, self.w, self.cfg.srp)
            self.state = self._kops.ace_update(self.state, buckets, self.cfg)
        else:
            self.state = sk.insert(self.state, self.w, x, self.cfg)
        return self

    def fit(self, x: jax.Array, batch: int = 4096) -> "AceEstimator":
        n = x.shape[0]
        for i in range(0, n, batch):
            self.update(x[i : i + batch])
        return self

    def remove(self, x: jax.Array) -> "AceEstimator":
        self.state = sk.delete(self.state, self.w, x, self.cfg)
        return self

    def score(self, q: jax.Array) -> jax.Array:
        if self.use_kernels:
            return self._kops.ace_score(self.state, q, self.w, self.cfg)
        return sk.score(self.state, self.w, q, self.cfg)

    def predict(self, q: jax.Array, alpha: float = 1.0,
                sigma: float | None = None) -> jax.Array:
        """Anomaly decision.  If ``sigma`` is given (absolute-score σ, e.g.
        the exact full-pass σ of the paper's §5.3 evaluation), use it on raw
        scores; else use the streaming Welford σ of RATES (score/n)."""
        s = self.score(q)
        if sigma is not None:
            return s < sk.mean_mu(self.state) - alpha * sigma
        n = jnp.maximum(self.state.n, 1.0)
        return s / n < sk.mean_rate(self.state) \
            - alpha * sk.sigma_welford(self.state)

    @property
    def mu(self) -> jax.Array:
        return sk.mean_mu(self.state)

    def memory_bytes(self) -> int:
        return self.cfg.memory_bytes()
