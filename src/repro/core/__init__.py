"""ACE core: the paper's contribution as a composable JAX module."""
from repro.core.sketch import (  # noqa: F401
    AceConfig, AceState, init, make_params, insert, delete, score,
    is_anomaly, mean_mu, sigma_welford, sigma_cubic_proxy, merge,
    insert_buckets, delete_buckets, lookup, histogram,
)
from repro.core.srp import SrpConfig, hash_buckets, collision_probability  # noqa: F401
from repro.core.estimators import (  # noqa: F401
    AceEstimator, exact_score, rse_score, collision_probs,
)
