"""On-device sketch health invariants + table repair ops.

Every ACE state type carries enough redundancy to AUDIT itself on
device: inserts are unit scatter-adds, so each table's counts must sum
to exactly the number of items inserted (conservation — integer-valued
float32, exact below 2^24); Welford M2 is a sum of squares (≥ 0 and
finite); ring cursors/ticks live in known ranges; escalation tables keep
their sorted/live-slot invariants.  ``health_check`` evaluates all of
them as ONE fixed-shape jitted program and returns a
:class:`HealthReport` of device booleans — a per-table mask, never a
host branch, so the serving stack can keep the decision on device and
only sync at health/repair boundaries it already owns.

Invariants checked (see docs/ARCHITECTURE.md §8 for the full table):

=====================  ====================================================
invariant              definition
=====================  ====================================================
count conservation     Σ_b counts[j, b] == n  per table j (per tenant, per
                       epoch), up to the repair offset / quantized ``lost``
                       slack
count range            every counter ≥ 0 (unit inserts can never go
                       negative; a flipped sign bit can)
moment sanity          n, welford_mean finite; welford_m2 finite and ≥ 0;
                       n ≥ 0
tail/ssq sanity        tail finite per table; ssq finite and ≥ 0
cursor/tick bounds     0 ≤ cursor < E; tick ≥ 0
esc consistency        offs sorted; live slots have vals > 0 and real
                       offsets; free (SENTINEL) slots have vals == 0;
                       lost finite and ≥ 0
=====================  ====================================================

Repair (``repair_*``): zero the corrupted tables' planes while the
healthy L−k keep serving.  Flat/fleet sketches return a ``repair
offset`` per table — the n at repair time — because their counts never
expire: afterwards conservation reads Σ counts[j] == n − offset[j], and
``health_check`` accepts the offsets.  Window rings need NO offsets:
a repaired (zeroed) table violates conservation only until the epochs
it was zeroed in expire, so the table naturally re-warms and the mask
lifts within one window — the self-healing property the chaos suite
asserts.  Poisoned moments are repaired separately
(:func:`repair_moments`): the streams re-zero and re-accumulate (the
exact μ never uses them, so scores are unaffected).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import quantize as qz
from repro.core.sketch import AceState
from repro.fleet.state import FleetState
from repro.fleet.window import WindowedFleetState
from repro.window.ring import WindowedAceState


class HealthReport(NamedTuple):
    """Device-boolean health verdicts (a pytree — jit/scan safe).

    table_ok:   per-table conservation+range mask — (L,) for flat and
                windowed sketches, (T, L) for fleets.  THE serving mask:
                scoring ops take it (via :func:`serving_mask`) as their
                ``table_mask``.
    moments_ok: scalar (or (T,) per tenant) — finite n/mean/M2, M2 ≥ 0.
    struct_ok:  scalar (or (T,)) — cursor/tick bounds, tail/ssq sanity,
                escalation-table slot consistency.
    ok:         all of the above (scalar or (T,)).
    """

    table_ok: jax.Array
    moments_ok: jax.Array
    struct_ok: jax.Array
    ok: jax.Array


def _finite(*xs) -> jax.Array:
    acc = jnp.asarray(True)
    for x in xs:
        acc = jnp.logical_and(acc, jnp.all(jnp.isfinite(x)))
    return acc


def _esc_ok(esc: Optional[qz.EscTable]) -> jax.Array:
    """Escalation-table slot invariants (True when no esc)."""
    if esc is None:
        return jnp.asarray(True)
    offs, vals = esc.offs, esc.vals
    sorted_ok = jnp.all(offs[1:] >= offs[:-1])
    live = offs != qz.SENTINEL
    slots_ok = jnp.all(jnp.where(live, vals > 0, vals == 0))
    lost_ok = jnp.logical_and(jnp.isfinite(esc.lost), esc.lost >= 0.0)
    return sorted_ok & slots_ok & lost_ok


def check_ace(state: AceState,
              repair_offsets: jax.Array | None = None) -> HealthReport:
    """Health of a flat ``AceState``: (L,) table mask + scalar verdicts.

    ``repair_offsets`` (L,) float32 — per-table n-at-repair bookkeeping
    (0 where never repaired); conservation then reads
    Σ counts[j] == n − offset[j].  Quantized planes audit the DENSIFIED
    logical counts, with ``esc.lost`` as downward slack (dropped excess
    legitimately leaves the plane).
    """
    L = state.counts.shape[0]
    if state.esc is not None:
        dense = qz.densify(state.counts, state.esc)
        slack = state.esc.lost
    else:
        dense = state.counts
        slack = jnp.zeros((), jnp.float32)
    c = dense.astype(jnp.float32)
    sums = jnp.sum(c, axis=1)                                    # (L,)
    expected = state.n - (repair_offsets if repair_offsets is not None
                          else jnp.zeros((L,), jnp.float32))
    conserve = jnp.logical_and(sums <= expected,
                               sums >= expected - slack)
    nonneg = jnp.all(dense >= 0, axis=1)
    table_ok = jnp.logical_and(conserve, nonneg)

    moments_ok = jnp.logical_and(
        _finite(state.n, state.welford_mean, state.welford_m2),
        jnp.logical_and(state.welford_m2 >= 0.0, state.n >= 0.0))
    struct_ok = _esc_ok(state.esc)
    ok = jnp.all(table_ok) & moments_ok & struct_ok
    return HealthReport(table_ok=table_ok, moments_ok=moments_ok,
                        struct_ok=struct_ok, ok=ok)


def check_window(state: WindowedAceState) -> HealthReport:
    """Health of a ``WindowedAceState`` ring: (L,) table mask.

    Conservation holds per table PER EPOCH (each epoch is its own flat
    sketch); a table is healthy only if every epoch of it conserves.
    No repair offsets: a repaired table's deficit expires with the
    epochs it was zeroed in (≤ E rotations — the self-healing window).
    """
    E, L, _ = state.counts.shape
    c = state.counts.astype(jnp.float32)
    sums = jnp.sum(c, axis=2)                                    # (E, L)
    conserve = jnp.all(sums <= state.n[:, None], axis=0)         # (L,)
    nonneg = jnp.all(state.counts >= 0, axis=(0, 2))             # (L,)
    tail_ok = jnp.all(jnp.isfinite(state.tail), axis=1)          # (L,)
    table_ok = conserve & nonneg & tail_ok

    moments_ok = jnp.logical_and(
        _finite(state.n, state.welford_mean, state.welford_m2),
        jnp.logical_and(jnp.all(state.welford_m2 >= 0.0),
                        jnp.all(state.n >= 0.0)))
    struct_ok = (
        (state.cursor >= 0) & (state.cursor < E) & (state.tick >= 0)
        & jnp.isfinite(state.ssq) & (state.ssq >= 0.0))
    ok = jnp.all(table_ok) & moments_ok & struct_ok
    return HealthReport(table_ok=table_ok, moments_ok=moments_ok,
                        struct_ok=struct_ok, ok=ok)


def check_fleet(state: FleetState,
                repair_offsets: jax.Array | None = None) -> HealthReport:
    """Health of a ``FleetState``: (T, L) table mask + (T,) verdicts."""
    T, L, _ = state.counts.shape
    c = state.counts.astype(jnp.float32)
    sums = jnp.sum(c, axis=2)                                    # (T, L)
    expected = state.n[:, None] - (
        repair_offsets if repair_offsets is not None
        else jnp.zeros((T, L), jnp.float32))
    conserve = sums == expected
    nonneg = jnp.all(state.counts >= 0, axis=2)                  # (T, L)
    table_ok = conserve & nonneg

    moments_ok = (
        jnp.isfinite(state.n) & jnp.isfinite(state.welford_mean)
        & jnp.isfinite(state.welford_m2)
        & (state.welford_m2 >= 0.0) & (state.n >= 0.0))          # (T,)
    struct_ok = jnp.ones((T,), bool)
    ok = jnp.all(table_ok, axis=1) & moments_ok & struct_ok      # (T,)
    return HealthReport(table_ok=table_ok, moments_ok=moments_ok,
                        struct_ok=struct_ok, ok=ok)


def check_fleet_window(state: WindowedFleetState) -> HealthReport:
    """Health of a ``WindowedFleetState``: (T, L) table mask + (T,)."""
    T, E, L, _ = state.counts.shape
    c = state.counts.astype(jnp.float32)
    sums = jnp.sum(c, axis=3)                                    # (T, E, L)
    conserve = jnp.all(sums <= state.n[:, :, None], axis=1)      # (T, L)
    nonneg = jnp.all(state.counts >= 0, axis=(1, 3))             # (T, L)
    tail_ok = jnp.all(jnp.isfinite(state.tail), axis=2)          # (T, L)
    table_ok = conserve & nonneg & tail_ok

    moments_ok = (
        jnp.all(jnp.isfinite(state.n), axis=1)
        & jnp.all(jnp.isfinite(state.welford_mean), axis=1)
        & jnp.all(jnp.isfinite(state.welford_m2), axis=1)
        & jnp.all(state.welford_m2 >= 0.0, axis=1)
        & jnp.all(state.n >= 0.0, axis=1))                       # (T,)
    struct_ok = (
        (state.cursor >= 0) & (state.cursor < E) & (state.tick >= 0)
        & jnp.isfinite(state.ssq) & (state.ssq >= 0.0))          # (T,)
    ok = jnp.all(table_ok, axis=1) & moments_ok & struct_ok
    return HealthReport(table_ok=table_ok, moments_ok=moments_ok,
                        struct_ok=struct_ok, ok=ok)


def health_check(state, repair_offsets: jax.Array | None = None
                 ) -> HealthReport:
    """Type-dispatching invariant audit — ONE fixed-shape jitted program
    per state type (the dispatch is Python-level on the pytree class,
    resolved at trace time; nothing here branches on device values)."""
    if isinstance(state, WindowedFleetState):
        return check_fleet_window(state)
    if isinstance(state, FleetState):
        return check_fleet(state, repair_offsets)
    if isinstance(state, WindowedAceState):
        return check_window(state)
    if isinstance(state, AceState):
        return check_ace(state, repair_offsets)
    raise TypeError(f"health_check: unknown state type {type(state)!r}")


def serving_mask(report: HealthReport) -> jax.Array:
    """The report's table mask as the float32 ``table_mask`` every
    scoring op takes ((L,) or (T, L))."""
    return report.table_ok.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Repair: re-zero corrupted tables; the healthy L−k keep serving.
# ---------------------------------------------------------------------------

def repair_ace(state: AceState, table_ok: jax.Array,
               repair_offsets: jax.Array | None = None):
    """Zero the corrupted tables of a flat sketch.

    Returns ``(new_state, new_offsets)``: corrupted tables' planes
    re-zero (and their escalation slots free), and their repair offset
    is set to the CURRENT n so conservation re-reads
    Σ counts[j] == n − offset[j] — the table re-warms from the live
    stream while the healthy tables' counts, n, and moments are
    bitwise untouched.
    """
    L = state.counts.shape[0]
    okf = table_ok.astype(state.counts.dtype)
    new_counts = state.counts * okf[:, None]
    old = (repair_offsets if repair_offsets is not None
           else jnp.zeros((L,), jnp.float32))
    new_offsets = jnp.where(table_ok, old, state.n)
    esc = state.esc
    if esc is not None:
        # free every escalation slot whose offset lands in a zeroed
        # table (offset // 2^K = flat row = table index for flat planes)
        nbuckets = state.counts.shape[1]
        slot_table = jnp.clip(esc.offs // nbuckets, 0, L - 1)
        keep = jnp.logical_or(esc.offs == qz.SENTINEL,
                              jnp.take(table_ok, slot_table))
        offs = jnp.where(keep, esc.offs, qz.SENTINEL)
        vals = jnp.where(keep, esc.vals, 0)
        order = jnp.argsort(offs)
        esc = qz.EscTable(offs=offs[order], vals=vals[order],
                          lost=esc.lost)
    return state._replace(counts=new_counts, esc=esc), new_offsets


def repair_window(state: WindowedAceState,
                  table_ok: jax.Array) -> WindowedAceState:
    """Zero the corrupted tables of a window ring — every epoch AND the
    tail row — and re-anchor ssq from the surviving planes.

    No offsets: the zeroed tables' conservation deficit expires with
    their epochs (≤ E rotations), after which ``check_window`` passes
    again and the serving mask lifts — self-healing within one window.
    """
    okc = table_ok.astype(state.counts.dtype)
    new_counts = state.counts * okc[None, :, None]
    new_tail = state.tail * table_ok.astype(jnp.float32)[:, None]
    live = jax.lax.dynamic_index_in_dim(
        new_counts, state.cursor, axis=0, keepdims=False)
    cw = new_tail + live.astype(jnp.float32)
    return state._replace(counts=new_counts, tail=new_tail,
                          ssq=jnp.sum(cw * cw))


def repair_fleet(state: FleetState, table_ok: jax.Array,
                 repair_offsets: jax.Array | None = None):
    """Zero corrupted (tenant, table) planes of a fleet; returns
    ``(new_state, new_offsets)`` with (T, L) offsets (the fleet analogue
    of :func:`repair_ace` — untouched tenants stay bitwise identical)."""
    T, L, _ = state.counts.shape
    okf = table_ok.astype(state.counts.dtype)
    new_counts = state.counts * okf[:, :, None]
    old = (repair_offsets if repair_offsets is not None
           else jnp.zeros((T, L), jnp.float32))
    new_offsets = jnp.where(table_ok, old, state.n[:, None])
    return state._replace(counts=new_counts), new_offsets


def repair_fleet_window(state: WindowedFleetState,
                        table_ok: jax.Array) -> WindowedFleetState:
    """Zero corrupted (tenant, table) ring planes + tail rows and
    re-anchor the per-tenant ssq streams (see :func:`repair_window`)."""
    T, E, L, _ = state.counts.shape
    okc = table_ok.astype(state.counts.dtype)
    new_counts = state.counts * okc[:, None, :, None]
    new_tail = state.tail * table_ok.astype(jnp.float32)[:, :, None]
    tidx = jnp.arange(T, dtype=jnp.int32)
    live = new_counts[tidx, state.cursor]                # (T, L, 2^K)
    cw = new_tail + live.astype(jnp.float32)
    return state._replace(counts=new_counts, tail=new_tail,
                          ssq=jnp.sum(cw * cw, axis=(1, 2)))


def repair_moments(state):
    """Re-zero poisoned Welford streams (any state type).

    The σ stream restarts from zero and re-accumulates from live
    traffic; the exact μ (Eq. 11 closed form) never used the stream, so
    scores are unaffected.  During re-accumulation μ−ασ runs with σ≈0 —
    a conservative (tight) threshold; the ``welford_min_n`` cold-start
    gate does not re-arm (n is preserved), so the stream re-converges
    within one batch-count on the order of the original warmup.
    """
    return state._replace(
        welford_mean=jnp.zeros_like(state.welford_mean),
        welford_m2=jnp.zeros_like(state.welford_m2))
