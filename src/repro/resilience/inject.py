"""Deterministic fault injectors for the chaos suite.

Every injector is seeded (``jax.random`` keys or explicit positions) so a
chaos test is a REPLAYABLE program, not a fuzzer: the same seed produces
the same corruption, the same health verdict, and the same degraded
scores — which is what lets the suite assert exact oracle parity on the
surviving tables.

Faults modelled (one injector each; see docs/ARCHITECTURE.md §8):

* poisoned input     — :func:`corrupt_embeddings` (NaN/Inf feature rows)
* memory corruption  — :func:`flip_count_bits` (bitcast single-bit flips
                       in count planes, any counter dtype) and
                       :func:`saturate_table` (stuck-at-max plane)
* moment poisoning   — :func:`poison_moments` (NaN / negative M2)
* torn checkpoint    — :func:`tear_checkpoint` (truncate or byte-flip a
                       saved step's array blob on disk)
* straggler          — :func:`stall_step` (rewind a ``StepTimer`` so its
                       next tick reads as an SLO breach, no real sleep)

Injectors that touch device state are pure (state in, state out) and
jit-safe except for the Python-level dtype dispatch; the disk/host ones
(:func:`tear_checkpoint`, :func:`stall_step`) mutate exactly the object
they are handed.
"""
from __future__ import annotations

import os
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def corrupt_embeddings(x: jax.Array, key: jax.Array, frac: float = 0.1,
                       kind: str = "nan"):
    """Poison a fraction of feature rows with non-finite values.

    Returns ``(corrupted, bad_rows)`` where ``bad_rows`` is the (B,) bool
    mask of poisoned rows (the ground truth the sanitizers must match).
    ``kind``: ``"nan"``, ``"inf"``, or ``"mixed"`` (alternating, so a
    single test covers both encodings).
    """
    if kind not in ("nan", "inf", "mixed"):
        raise ValueError(f"unknown kind {kind!r}")
    B = x.shape[0]
    row = (B,) + (1,) * (x.ndim - 1)      # rows broadcast over trailing dims
    bad_rows = jax.random.uniform(key, (B,)) < frac
    if kind == "nan":
        poison = jnp.full_like(x, jnp.nan)
    elif kind == "inf":
        poison = jnp.full_like(x, jnp.inf)
    else:
        alt = jnp.where(jnp.arange(B) % 2 == 0, jnp.nan, jnp.inf)
        poison = jnp.broadcast_to(alt.reshape(row), x.shape).astype(x.dtype)
    return jnp.where(bad_rows.reshape(row), poison, x), bad_rows


def _bits_of(dtype) -> tuple:
    """(unsigned view dtype, bit width) for a counter/plane dtype."""
    dt = jnp.dtype(dtype)
    return {1: (jnp.uint8, 8), 2: (jnp.uint16, 16),
            4: (jnp.uint32, 32)}[dt.itemsize]


def flip_count_bits(counts: jax.Array, key: jax.Array, num_flips: int = 1,
                    tables: Sequence[int] | None = None) -> jax.Array:
    """Flip ``num_flips`` random bits in a count plane (any shape whose
    leading axis — or the axis before the bucket axis — indexes tables).

    Works on every counter dtype via an unsigned bitcast (int8/int16/int32
    and the float32 tail/ring planes alike), so a sign- or high-bit flip
    produces exactly the garbage real memory corruption would.  When
    ``tables`` is given, flips land only in those leading-index slices
    (deterministic blast radius — the chaos test bounds corruption to
    ⌈L/4⌉ tables).
    """
    view_dtype, width = _bits_of(counts.dtype)
    flat = counts.reshape(-1).view(view_dtype) \
        if isinstance(counts, np.ndarray) else \
        jax.lax.bitcast_convert_type(counts.reshape(-1), view_dtype)
    kf, kl, kb, kw = jax.random.split(key, 4)
    if tables is None:
        idx = jax.random.randint(kf, (num_flips,), 0, flat.shape[0])
    else:
        # restrict flips to the chosen table slices.  The table axis is
        # the one before the bucket axis for every count layout: (L, B)
        # flat, (E, L, B) windowed, (T, L, B) fleet, (T, E, L, B)
        # fleet-window — leading tenant/epoch axes are drawn uniformly.
        *lead, L, buckets = counts.shape
        nlead = int(np.prod(lead)) if lead else 1
        t = jax.random.choice(kf, jnp.asarray(list(tables), jnp.int32),
                              (num_flips,))
        li = jax.random.randint(kl, (num_flips,), 0, nlead)
        off = jax.random.randint(kb, (num_flips,), 0, buckets)
        idx = (li * L + t) * buckets + off
    bit = jax.random.randint(kw, (num_flips,), 0, width)
    mask = (jnp.ones((), view_dtype) << bit.astype(view_dtype))
    flipped = flat.at[idx].set(flat[idx] ^ mask)
    out = jax.lax.bitcast_convert_type(flipped, counts.dtype)
    return out.reshape(counts.shape)


def saturate_table(counts: jax.Array, table: int) -> jax.Array:
    """Stuck-at-max fault: every counter of one table pinned to the
    dtype's maximum (int) or 2^31 (float planes) — the saturation
    signature of a runaway scatter or a shorted accumulator."""
    if jnp.issubdtype(counts.dtype, jnp.floating):
        top = jnp.asarray(2.0**31, counts.dtype)
    else:
        top = jnp.asarray(jnp.iinfo(counts.dtype).max, counts.dtype)
    sat = jnp.full(counts.shape[1:], top, counts.dtype)
    return counts.at[table].set(sat)


def poison_moments(state, kind: str = "nan"):
    """Corrupt the Welford stream of any ACE state type.

    ``"nan"`` poisons mean and M2 with NaN (the organic failure mode —
    one non-finite rate propagates through the fold forever);
    ``"neg"`` flips M2's sign (the bit-flip failure mode — M2 is a sum
    of squares, so any negative value is impossible).
    """
    if kind == "nan":
        return state._replace(
            welford_mean=jnp.full_like(state.welford_mean, jnp.nan),
            welford_m2=jnp.full_like(state.welford_m2, jnp.nan))
    if kind == "neg":
        return state._replace(
            welford_m2=-jnp.abs(state.welford_m2) - 1.0)
    raise ValueError(f"unknown kind {kind!r}")


def tear_checkpoint(ckpt_dir: str, step: int, mode: str = "truncate",
                    nbytes: int = 64, seed: int = 0) -> str:
    """Corrupt a saved checkpoint step ON DISK (the preemption /
    bad-sector model).  Returns the path of the torn blob.

    ``"truncate"`` chops the last ``nbytes`` off ``arrays.npz`` (a write
    torn mid-flight — past the atomic-rename guarantee, i.e. media
    failure after a successful save); ``"flip"`` XOR-flips ``nbytes``
    random bytes in place (silent bit rot).  Either way the manifest
    stays intact, so only checksum verification can catch it.
    """
    path = os.path.join(ckpt_dir, f"step_{step:010d}", "arrays.npz")
    size = os.path.getsize(path)
    if mode == "truncate":
        with open(path, "r+b") as f:
            f.truncate(max(size - nbytes, 0))
    elif mode == "flip":
        rng = np.random.default_rng(seed)
        offsets = rng.integers(0, size, size=nbytes)
        with open(path, "r+b") as f:
            for off in offsets:
                f.seek(int(off))
                b = f.read(1)
                f.seek(int(off))
                f.write(bytes([b[0] ^ 0xFF]))
    else:
        raise ValueError(f"unknown mode {mode!r}")
    return path


def stall_step(timer, seconds: float) -> None:
    """Make a ``StepTimer``'s next ``tick()`` observe a ``seconds``-long
    step without sleeping: rewind its last-tick anchor.  The chaos suite
    uses this to drive the straggler path deterministically."""
    timer._last -= seconds
