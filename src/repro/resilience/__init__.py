"""Self-healing ACE fleets: fault injection, health invariants, repair.

The sketch's pitch — a few MB of counts replaces stored data — makes that
tiny state a single point of failure: one NaN batch poisons the Welford
moments, one flipped bit corrupts every later μ−ασ decision, and a torn
checkpoint propagates silently.  ACE's L independent tables are
redundancy we already own (the same argument that makes in-DRAM flow
tables viable at line rate), so this package turns failures into
detectable, maskable, repairable events:

* ``health``  — fixed-shape jitted invariant checks over every state
                type, returning per-table (and per-tenant) health masks
                plus repair ops that re-zero a corrupted table while the
                other L−1 keep serving.
* ``inject``  — deterministic fault injectors (NaN/Inf batches, count
                bit flips, saturation, poisoned moments, torn
                checkpoints, stalled steps) for the chaos suite.

The health masks feed the ``table_mask`` parameter threaded through
every scoring op (``sketch.batch_scores`` → ``kernels.ops``): degraded
scoring means over healthy tables only, an unbiased estimator of the
same Ŝ(q, D) (Theorem 1 holds for any subset of the independent
tables).  See docs/ARCHITECTURE.md §8.
"""
from repro.resilience.inject import (  # noqa: F401
    corrupt_embeddings,
    flip_count_bits,
    poison_moments,
    saturate_table,
    stall_step,
    tear_checkpoint,
)
from repro.resilience.health import (  # noqa: F401
    HealthReport,
    check_ace,
    check_fleet,
    check_fleet_window,
    check_window,
    health_check,
    repair_ace,
    repair_fleet,
    repair_fleet_window,
    repair_moments,
    repair_window,
    serving_mask,
)
