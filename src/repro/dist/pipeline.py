"""GPipe-style pipeline parallelism over a ``pipe`` mesh axis.

shard_map mode only: each device owns one stage's parameters (leading
stage dim sharded P("pipe")) and activations hop stage→stage+1 through a
``ppermute`` ring, the classic bubble schedule — S + M − 1 ticks for S
stages and M microbatches, bubble fraction (S−1)/(S+M−1).

This is framework plumbing rather than paper math: ACE itself never needs
pipelining (the sketch is O(MB)), but the models it guards (repro.models)
do, and the dry-run's collective accounting (repro.dist.hlo_analysis)
covers the permute traffic this schedule emits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    """Idle fraction of the GPipe schedule: (S−1)/(S+M−1)."""
    return (num_stages - 1) / (num_stages + num_microbatches - 1)


def pipeline_apply(layer_fn, params, x, *, mesh, num_stages: int,
                   num_microbatches: int, axis: str = "pipe"):
    """Run ``x`` through ``num_stages`` stages of ``layer_fn`` as a pipeline.

    layer_fn: (stage_params, h) -> h, applied by each device to its stage.
    params:   pytree whose leaves have a leading stage dim (S, ...).
    x:        (M, mb, ...) microbatched input, replicated.

    Returns (M, mb, ...) — the output of stage S−1 for every microbatch,
    replicated (a masked psum broadcasts it off the last device).  Matches
    the sequential composition of the stages exactly up to float order.
    """
    from jax.experimental.shard_map import shard_map

    S, M = num_stages, num_microbatches
    if x.shape[0] != M:
        raise ValueError(f"x has {x.shape[0]} microbatches, expected {M}")

    def _stage(local_params, xs):
        p = jax.tree.map(lambda a: a[0], local_params)   # drop stage dim
        idx = jax.lax.axis_index(axis)
        perm = [(i, (i + 1) % S) for i in range(S)]

        def tick(t, carry):
            outputs, recv = carry
            mb = t - idx                                  # my microbatch id
            mb_c = jnp.clip(mb, 0, M - 1)
            # stage 0 reads from the input stream, others from the ring
            x_in = jnp.where(idx == 0, xs[mb_c], recv)
            y = layer_fn(p, x_in)
            active = (mb >= 0) & (mb < M)
            write = active & (idx == S - 1)
            outputs = outputs.at[mb_c].set(
                jnp.where(write, y, outputs[mb_c]))
            sent = jax.lax.ppermute(y, axis, perm)
            return outputs, sent

        outputs = jnp.zeros_like(xs)
        outputs, _ = jax.lax.fori_loop(
            0, M + S - 1, tick, (outputs, jnp.zeros_like(xs[0])))
        # only the last stage holds real outputs; psum broadcasts them
        mask = (idx == S - 1).astype(outputs.dtype)
        return jax.lax.psum(outputs * mask, axis)

    pspec = jax.tree.map(lambda _: P(axis), params)
    return shard_map(_stage, mesh=mesh, in_specs=(pspec, P()),
                     out_specs=P(), check_rep=False)(params, x)
