"""Device meshes, logical-axis rules, and sketch sharding layouts.

Targets the plain jit/SPMD mode: everything here produces
``PartitionSpec``/``NamedSharding`` trees that are handed to ``jax.jit``
(GSPMD inserts the collectives); the explicit shard_map mode lives in
``repro.dist.sketch_parallel``.  ``make_production_mesh`` is a FUNCTION
(not a module constant) so importing this module never touches jax device
state — required because tests and benches run on 1 real device while the
dry-run forces 512 host devices via XLA_FLAGS before any jax import (see
launch/dryrun.py).

Sketch layouts (paper §3.3: the sketch is L independent count arrays, so L
is the natural shard axis once L × 2^K outgrows one device):

* ``replicated``     — every device holds all (L, 2^K) counts; inserts
                       psum the batch histogram over the data axes.
* ``table_sharded``  — counts split over L across the ``model``/``tables``
                       mesh axis; inserts are psum-free on that axis and
                       scoring needs only one small (B,) psum.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int | None = None):
    """Small mesh for subprocess-based sharding tests (8 fake devices)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def make_host_local_mesh(table_axis: str = "model"):
    """A mesh over THIS process's local devices only (repro.cluster).

    Tenant-sharded fleets are collective-free across tenants, so a
    multi-host cluster keeps every hot-path program host-local: each
    host serves its owned tenants on its own devices and the only
    cross-host traffic is the epoch-boundary gossip (host-side bytes,
    not collectives).  A GLOBAL mesh under ``jax.distributed`` would
    instead make every ``Guardrail.admit`` a cross-host SPMD program —
    all hosts lock-stepped on every batch, which is exactly the
    coupling a host-failure-tolerant fleet cannot afford.  1-D
    (``table_axis``,) so ``fleet_pspecs("table_sharded")`` composes
    when a host has several local devices; a single-device host gets
    the trivial mesh (layouts all collapse to replicated).
    """
    local = jax.local_devices()
    return jax.sharding.Mesh(local, (table_axis,))


def rules_for(mesh, *, long_context: bool = False) -> dict:
    """Logical-axis -> mesh-axis rules for this mesh.

    long_context (batch=1 decode): batch cannot shard, so the KV-cache
    SEQUENCE axis takes the data dims (context parallelism) and activations
    stay replicated on batch.

    The ACE logical axes ride along: ``tables`` (the L axis of the sketch)
    maps to the tensor-parallel mesh axis — sharding counts over L is the
    sketch's analogue of sharding heads — and ``buckets`` (the 2^K axis)
    never shards (bucket ids are data-dependent gather indices).
    """
    has_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if has_pod else ("data",)
    rules = {
        "batch": None if long_context else batch_axes,
        "cache_seq": batch_axes if long_context else None,
        "capacity": batch_axes,
        "heads": "model",
        "kv_heads": "model",
        "ff": "model",
        "vocab": "model",
        "tables": "model",
        "buckets": None,
    }
    return rules


# ---------------------------------------------------------------------------
# Sketch pytree layouts.
# ---------------------------------------------------------------------------

def sketch_pspecs(layout: str = "replicated", table_axis: str = "model"):
    """PartitionSpec pytree (AceState-shaped) for a sketch layout.

    Returned as the raw 4-tuple ``(counts, n, welford_mean, welford_m2)``
    spec so callers can build either ``AceState(*specs)`` or shard_map
    in/out specs without this module importing ``repro.core`` (keeps the
    mesh layer dependency-free).
    """
    if layout == "replicated":
        counts = P()
    elif layout == "table_sharded":
        counts = P(table_axis, None)
    else:
        raise ValueError(f"unknown sketch layout {layout!r} "
                         "(want 'replicated' or 'table_sharded')")
    return (counts, P(), P(), P())


def window_pspecs(layout: str = "replicated", table_axis: str = "model"):
    """PartitionSpec 8-tuple for an epoch-ring ``WindowedAceState``.

    Raw-tuple convention mirrors ``sketch_pspecs``: ``(counts, n,
    welford_mean, welford_m2, tail, ssq, cursor, tick)``.  The ring's
    counts are (E, L, 2^K) — the epoch axis NEVER shards (epochs are
    time slices; every device must see the whole window to combine),
    while the L axis shards exactly like the flat sketch (``tables``
    rule) in BOTH the ring and the maintained (L, 2^K) tail view; the
    per-epoch scalar vectors and ring pointers replicate.
    """
    if layout == "replicated":
        counts = P()
        tail = P()
    elif layout == "table_sharded":
        counts = P(None, table_axis, None)
        tail = P(table_axis, None)
    else:
        raise ValueError(f"unknown sketch layout {layout!r} "
                         "(want 'replicated' or 'table_sharded')")
    return (counts, P(), P(), P(), tail, P(), P(), P())


def fleet_pspecs(layout: str = "replicated", table_axis: str = "model",
                 tenant_axis: str = "data"):
    """PartitionSpec 4-tuple for a multi-tenant ``FleetState``.

    Raw-tuple convention mirrors ``sketch_pspecs``: ``(counts, n,
    welford_mean, welford_m2)`` with counts (T, L, 2^K).  Tenants are
    FULLY independent (no cross-tenant reduction anywhere — the fleet
    analogue of the L tables being independent), so the tenant axis is
    the cheapest shard axis the sketch has ever had: inserts, scores and
    thresholds are all collective-free under tenant sharding, and it
    COMPOSES with the L-axis table sharding (a (tenant, table) 2-D
    split) because the two axes cut orthogonal dims:

    * ``replicated``           — counts P(), stats P().
    * ``table_sharded``        — counts P(None, table_axis, None): every
                                 device holds all tenants' slice of L.
    * ``tenant_sharded``       — counts P(tenant_axis, None, None) and
                                 the (T,) stat vectors shard with it.
    * ``tenant_table_sharded`` — counts P(tenant_axis, table_axis, None)
                                 + tenant-sharded stats: the composed
                                 2-D layout.
    """
    if layout == "replicated":
        counts, stats = P(), P()
    elif layout == "table_sharded":
        counts, stats = P(None, table_axis, None), P()
    elif layout == "tenant_sharded":
        counts, stats = P(tenant_axis, None, None), P(tenant_axis)
    elif layout == "tenant_table_sharded":
        counts, stats = P(tenant_axis, table_axis, None), P(tenant_axis)
    else:
        raise ValueError(
            f"unknown fleet layout {layout!r} (want 'replicated', "
            "'table_sharded', 'tenant_sharded' or 'tenant_table_sharded')")
    return (counts, stats, stats, stats)


def sketch_layout_shardings(mesh, layout: str = "replicated",
                            table_axis: str = "model"):
    """NamedSharding 4-tuple for ``sketch_pspecs`` on a concrete mesh.

    Returns the raw 4-tuple (counts, n, welford_mean, welford_m2); the
    AceState-shaped conveniences live in ``repro.dist.sketch_parallel``
    (``sketch_shardings`` / ``table_sharded_shardings``) — deliberately a
    different name so the two APIs can't be confused."""
    return tuple(NamedSharding(mesh, ps)
                 for ps in sketch_pspecs(layout, table_axis))


def named_sharding_tree(mesh, pspec_tree):
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps), pspec_tree,
        is_leaf=lambda x: isinstance(x, P))


def sanitize_pspec(ps: P, shape: tuple, mesh) -> P:
    """Drop mesh axes that do not divide the corresponding dim.

    E.g. qwen2's 2 KV heads cannot shard over a 16-way "model" axis —
    Megatron-style GQA replicates KV beyond kv_heads; whisper's 6 heads
    replicate entirely.  Documented in DESIGN.md §4 (this is policy, not a
    workaround: uneven sharding would silently pad and waste the mesh).
    The same rule keeps an L=50 sketch off a 16-way tables axis.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def axis_size(entry) -> int:
        if entry is None:
            return 1
        if isinstance(entry, (tuple, list)):
            n = 1
            for e in entry:
                n *= sizes[e]
            return n
        return sizes[entry]

    out = []
    for i, entry in enumerate(ps):
        if i >= len(shape):
            out.append(None)
            continue
        out.append(entry if entry is None
                   or shape[i] % axis_size(entry) == 0 else None)
    return P(*out)


def apply_fsdp(ps: P, shape: tuple, mesh, axis: str = "data") -> P:
    """ZeRO-3/FSDP via GSPMD: additionally shard the largest free dim of a
    parameter over ``axis``.  XLA inserts the per-layer all-gather during
    compute and the reduce-scatter on gradients — exactly FSDP semantics,
    composed with the existing "model" (TP) assignments.

    Params stay replicated across "pod" (FSDP within pod; cross-pod
    traffic stays gradient-only — the standard multi-pod layout).
    """
    if axis not in mesh.axis_names:
        return ps
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = sizes[axis]
    entries = list(ps) + [None] * (len(shape) - len(ps))
    # already sharded on `axis` somewhere?
    for e in entries:
        parts = e if isinstance(e, (tuple, list)) else (e,)
        if axis in parts:
            return ps
    best, best_dim = 0, -1
    for i, (e, d) in enumerate(zip(entries, shape)):
        if e is None and d % n == 0 and d > best:
            best, best_dim = d, i
    if best_dim < 0:
        return ps
    entries[best_dim] = axis
    return P(*entries)


def fsdp_tree(pspec_tree, shape_tree, mesh, axis: str = "data"):
    """apply_fsdp over a pytree of PartitionSpecs (+ aligned shapes)."""
    flat_ps, tdef = jax.tree.flatten(
        pspec_tree, is_leaf=lambda x: isinstance(x, P))
    flat_shapes = tdef.flatten_up_to(shape_tree)
    out = [apply_fsdp(ps, tuple(s.shape), mesh, axis)
           for ps, s in zip(flat_ps, flat_shapes)]
    return tdef.unflatten(out)


def sharding_tree_for(mesh, pspec_tree, shape_tree):
    """NamedShardings with per-leaf divisibility sanitisation."""
    flat_ps, tdef = jax.tree.flatten(
        pspec_tree, is_leaf=lambda x: isinstance(x, P))
    flat_shapes = tdef.flatten_up_to(shape_tree)
    out = [NamedSharding(mesh, sanitize_pspec(ps, tuple(s.shape), mesh))
           for ps, s in zip(flat_ps, flat_shapes)]
    return tdef.unflatten(out)
