"""Three-term roofline over the dry-run artifacts (jit/SPMD mode only —
the numbers come from compiled-module analyses, no device ever runs).

Per cell (arch × shape × mesh JSON from repro.launch.dryrun):

    compute_s    = flops / peak_flops          (MXU term)
    memory_s     = bytes_accessed / hbm_bw     (HBM term)
    collective_s = collective_bytes / ici_bw   (ICI term, from
                                                repro.dist.hlo_analysis)
    bound_s      = max of the three            (the roofline bound)

``useful_ratio`` = compute_s / bound_s is the fraction of the bound spent
on math — 1.0 means compute-bound, small means the cell ships bytes.
Scan-corrected totals (the depth-1/depth-2 probe extrapolation recorded
under ``corrected``) are preferred over the raw single-body analyses.

Hardware constants are TPU v5e per chip: 197 TF/s bf16, 819 GB/s HBM,
50 GB/s/link ICI (EXPERIMENTS.md §Roofline quotes these alongside the
generated table).
"""
from __future__ import annotations

import dataclasses
import json
import os

PEAK_FLOPS = 197e12      # bf16 MXU, TPU v5e
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s per link


@dataclasses.dataclass(frozen=True)
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    bound_s: float
    useful_ratio: float
    dominant: str          # "compute" | "memory" | "collective"
    note: str


def build_row(cell: dict) -> RooflineRow | None:
    """One dry-run JSON cell -> a RooflineRow (None for failed cells)."""
    if not cell.get("ok"):
        return None
    corr = cell.get("corrected") or {}
    flops = corr.get("flops", cell.get("flops")) or 0.0
    bytes_acc = corr.get("bytes_accessed", cell.get("bytes_accessed")) or 0.0
    coll = corr.get("collectives") or cell.get("collectives") or {}
    coll_bytes = float(coll.get("total_bytes", 0.0))

    compute_s = max(float(flops), 0.0) / PEAK_FLOPS
    memory_s = max(float(bytes_acc), 0.0) / HBM_BW
    collective_s = coll_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound_s = terms[dominant]
    useful = compute_s / bound_s if bound_s > 0 else 0.0

    kinds = [k for k in coll if k != "total_bytes"]
    kinds.sort(key=lambda k: -coll[k].get("bytes", 0))
    note = (f"top collective {kinds[0]}" if kinds and coll_bytes > 0
            else "no collective traffic")
    return RooflineRow(
        arch=cell["arch"], shape=cell["shape"], mesh=cell["mesh"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bound_s=bound_s, useful_ratio=useful, dominant=dominant, note=note)


def build_all(results_dir: str) -> list[RooflineRow]:
    """All rows from ``<results_dir>/*.json``, sorted arch/shape/mesh."""
    rows = []
    for name in sorted(os.listdir(results_dir)):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(results_dir, name)) as f:
            row = build_row(json.load(f))
        if row is not None:
            rows.append(row)
    rows.sort(key=lambda r: (r.arch, r.shape, r.mesh))
    return rows


def format_table(rows: list[RooflineRow]) -> str:
    """Markdown table of the three-term model (EXPERIMENTS.md §Roofline)."""
    out = ["| arch | shape | mesh | compute_s | memory_s | collective_s "
           "| bound_s | dominant | useful |",
           "|" + "---|" * 9]
    for r in rows:
        out.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.4f} | "
            f"{r.memory_s:.4f} | {r.collective_s:.4f} | {r.bound_s:.4f} | "
            f"{r.dominant} | {r.useful_ratio:.3f} |")
    if not rows:
        out.append("| (no dry-run artifacts) | - | - | - | - | - | - | - "
                   "| - |")
    return "\n".join(out)
