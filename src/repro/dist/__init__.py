"""repro.dist — the single home for distributed ACE.

Layers (each module header says which paper section it implements and which
execution mode — explicit ``shard_map`` collectives vs plain jit/SPMD — it
targets):

* ``repro.dist.mesh``           device meshes, logical-axis rules, and the
                                sharding-rule sets for the sketch pytree
                                (replicated and table-sharded layouts).
* ``repro.dist.sketch_parallel`` data-parallel (replicated counts) and
                                table-sharded (counts split over L) insert /
                                score / statistics, plus the exact psum merge.
* ``repro.dist.pipeline``       GPipe-style pipeline parallelism over a
                                ``pipe`` mesh axis (collective-permute ring).
* ``repro.dist.hlo_analysis``   compiled-HLO text analysis: collective bytes
                                by kind, while-loop trip counts.
* ``repro.dist.roofline``       three-term (compute/HBM/ICI) roofline model
                                over the dry-run artifacts.

The old import paths ``repro.core.distributed`` and ``repro.launch.mesh``
remain as thin deprecation shims re-exporting from here.
"""
from repro.dist import hlo_analysis, mesh, sketch_parallel  # noqa: F401
from repro.dist.sketch_parallel import (  # noqa: F401
    local_histogram, make_shardmap_update, make_table_sharded_mean_mu,
    make_table_sharded_score, make_table_sharded_update, score_global,
    sketch_shardings, table_shard_info, table_sharded_mean_mu,
    table_sharded_shardings, update_global,
)
