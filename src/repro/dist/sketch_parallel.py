"""Distributed ACE sketches: replicated and table-sharded layouts.

Implements the multi-device story of paper §3.3–§3.4 (the sketch is a
commutative monoid under count addition) and the §4 privacy claim at
datacenter scale: only counts of hashes ever cross the network, never raw
data.  Every primitive exists in two execution modes:

1. **shard_map mode** — the ``update_*``/``score_*`` inner functions take
   explicit ``axis_names`` and issue their own ``psum``; the ``make_*``
   builders wrap them in ``shard_map`` for standalone use.  This is the
   mode used inside training steps that are themselves shard_mapped.
2. **jit/SPMD mode** — call the plain ``repro.core.sketch`` ops on arrays
   placed with ``sketch_shardings``/``table_sharded_shardings`` and let
   GSPMD insert the collectives.  This is the mode compiled into
   ``train_step`` (repro/train/train_loop.py) so the dry-run HLO contains
   the ACE collective schedule (measured by ``repro.dist.hlo_analysis``).

Two layouts:

* **replicated** (the seed layout, ex ``repro.core.distributed``): every
  device holds all (L, 2^K) counts.  Each data shard hashes + histograms
  its local slice of the batch; one psum over the data axes yields the
  global-batch histogram; every device applies the same dense add.  Counts
  stay replica-consistent; scoring is a pure map (no collective).

* **table_sharded** (new): counts are split over the L (tables) axis
  across a ``model``/``tables`` mesh axis, so sketches larger than one
  device's memory become possible (K=18+, L=200+ — the flow-table capacity
  regime of Jang et al.).  Because the L arrays are fully independent
  (paper §3.1: L independent meta-hashes), the schedule is:

    - insert: each shard scatter-adds the histogram slice of its *locally
      owned tables* — **psum-free** on the tables axis;
    - score:  local partial sum over L_local tables, then ONE small (B,)
      float psum, then the /L division — bytes on the wire are 4·B per
      batch instead of 4·L·2^K;
    - μ / σ:  per-shard partial sums of Σ‖A_j‖² (Eq. 11 closed form)
      reduced by a scalar psum.

  All cross-shard reductions sum exactly-representable integers in
  float32, so table-sharded insert/score/μ are *bitwise identical* to the
  replicated path (asserted by tests/test_dist_sharded.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import sketch as sk
from repro.core.sketch import AceConfig, AceState
from repro.core.srp import hash_buckets
from repro.dist.mesh import sketch_pspecs


# ---------------------------------------------------------------------------
# Replicated layout (ex repro.core.distributed).
# ---------------------------------------------------------------------------

def _no_quantized(state: AceState, what: str) -> None:
    """Trace-time guard: overflow-promoted (quantized) sketches are wired
    for the replicated jit/SPMD layout only.  The shard_map specs and the
    table-sharded flat offsets do not (yet) carry the escalation table;
    fail loudly instead of silently dropping promoted excess."""
    if getattr(state, "esc", None) is not None:
        raise NotImplementedError(
            f"{what} does not support quantized sketches "
            "(esc_capacity > 0); use the replicated jit/SPMD layout or "
            "an unquantized narrow-dtype sketch")


def local_histogram(x: jax.Array, w: jax.Array, cfg: AceConfig) -> jax.Array:
    """Histogram of the local batch shard: (B_local, d) -> (L, 2^K)."""
    buckets = hash_buckets(x, w, cfg.srp)
    return sk.histogram(buckets, cfg)


def update_global(state: AceState, x: jax.Array, w: jax.Array,
                  cfg: AceConfig, axis_names=()) -> AceState:
    """Insert a (possibly sharded) batch into a replicated sketch.

    Inside shard_map: pass ``axis_names`` to psum the histogram.  Under plain
    jit/SPMD, call with axis_names=() and let sharding propagation reduce.
    """
    if state.esc is not None:
        # Quantized planes cannot merge by histogram-add (the narrow add
        # would wrap at saturation): under plain jit/SPMD delegate to the
        # exact saturating core path; under shard_map fail loudly.
        if axis_names:
            _no_quantized(state, "update_global under shard_map")
        return sk.insert_buckets(state, hash_buckets(x, w, cfg.srp), cfg)
    hist = local_histogram(x, w, cfg)
    if axis_names:
        hist = jax.lax.psum(hist, axis_names)
    new_counts = state.counts + hist

    # Post-insert scores of the local shard items for Welford (approximate
    # insert-time stream; exact μ never uses it).
    buckets = hash_buckets(x, w, cfg.srp)
    scores = sk.batch_scores(new_counts, buckets)

    b_local = jnp.asarray(scores.shape[0], jnp.float32)
    if axis_names:
        b_local = jax.lax.psum(b_local, axis_names)
    n = state.n
    tot = n + b_local
    rates = scores / jnp.maximum(tot, 1.0)   # rate stream (see sketch.py)
    sum_s = jnp.sum(rates)
    sum_s2 = jnp.sum(rates * rates)
    if axis_names:
        sum_s = jax.lax.psum(sum_s, axis_names)
        sum_s2 = jax.lax.psum(sum_s2, axis_names)
    mean_b = sum_s / jnp.maximum(b_local, 1.0)
    m2_b = jnp.maximum(sum_s2 - b_local * mean_b * mean_b, 0.0)

    new_mean, new_m2 = sk.welford_fold(
        state.welford_mean, state.welford_m2, n, b_local, tot, mean_b, m2_b,
        cfg.welford_min_n)
    return AceState(counts=new_counts, n=tot,
                    welford_mean=new_mean, welford_m2=new_m2)


def update_global_masked(state: AceState, x: jax.Array, w: jax.Array,
                         mask: jax.Array, cfg: AceConfig,
                         axis_names=()) -> AceState:
    """Masked insert into a replicated sketch (fixed-shape guardrail path).

    Mirrors ``sketch.insert_buckets_masked`` exactly: the 0/1-weighted
    histogram keeps counts/n bitwise equal to inserting the admitted
    subset, and the Welford fold uses the same masked-moment formulas as
    the single-device path (→ bitwise parity when ``axis_names`` is
    empty, float32-round-off otherwise).
    """
    if state.esc is not None:
        if axis_names:
            _no_quantized(state, "update_global_masked under shard_map")
        return sk.insert_buckets_masked(
            state, hash_buckets(x, w, cfg.srp), mask, cfg)
    buckets = hash_buckets(x, w, cfg.srp)
    rows = jnp.broadcast_to(
        jnp.arange(cfg.num_tables, dtype=jnp.int32)[None, :], buckets.shape)
    w_ctr = jnp.broadcast_to(
        mask.astype(state.counts.dtype)[:, None], buckets.shape)
    zero = jnp.zeros((cfg.num_tables, cfg.num_buckets),
                     dtype=jnp.dtype(cfg.counter_dtype))
    hist = zero.at[rows, buckets].add(w_ctr)
    if axis_names:
        hist = jax.lax.psum(hist, axis_names)
    new_counts = state.counts + hist

    scores = sk.batch_scores(new_counts, buckets)
    reduce = (lambda v: jax.lax.psum(v, axis_names)) if axis_names else None
    tot, new_mean, new_m2 = sk.masked_batch_welford(
        state, scores, mask.astype(jnp.float32), cfg.welford_min_n,
        reduce=reduce)
    return AceState(counts=new_counts, n=tot,
                    welford_mean=new_mean, welford_m2=new_m2)


def score_global(state: AceState, q: jax.Array, w: jax.Array,
                 cfg: AceConfig) -> jax.Array:
    """Score a sharded query batch against the replicated sketch.

    Pure map — no collective needed (counts are replicated)."""
    return sk.lookup(state, hash_buckets(q, w, cfg.srp))


def make_shardmap_update(mesh, cfg: AceConfig, data_axes=("data",)):
    """Build a shard_map'd update: batch sharded over ``data_axes``, sketch
    replicated.  Returned fn: (state, x, w) -> state."""
    from jax.experimental.shard_map import shard_map

    batch_spec = P(data_axes)
    rep = P()

    def _upd(state, x, w):
        return update_global(state, x, w, cfg, axis_names=data_axes)

    return shard_map(
        _upd, mesh=mesh,
        in_specs=(AceState(rep, rep, rep, rep), batch_spec, rep),
        out_specs=AceState(rep, rep, rep, rep),
        check_rep=False)


def make_masked_update(mesh, cfg: AceConfig, data_axes=()):
    """Build a shard_map'd replicated MASKED insert: (state, x, w, mask) ->
    state.  With ``data_axes`` empty, batch and mask are replicated and
    every device applies the identical dense masked add."""
    from jax.experimental.shard_map import shard_map

    rep = P()
    bspec = P(data_axes) if data_axes else P()

    def _upd(state, x, w, mask):
        return update_global_masked(state, x, w, mask, cfg,
                                    axis_names=data_axes)

    return shard_map(
        _upd, mesh=mesh,
        in_specs=(AceState(rep, rep, rep, rep), bspec, rep, bspec),
        out_specs=AceState(rep, rep, rep, rep),
        check_rep=False)


def sketch_shardings(mesh) -> AceState:
    """NamedSharding pytree for the replicated sketch under plain jit."""
    rep = NamedSharding(mesh, P())
    return AceState(rep, rep, rep, rep)


# ---------------------------------------------------------------------------
# Table-sharded layout: counts split over L across `table_axis`.
# ---------------------------------------------------------------------------

def table_shard_info(cfg: AceConfig, mesh, table_axis: str) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if table_axis not in sizes:
        raise ValueError(f"mesh has no axis {table_axis!r} "
                         f"(axes: {mesh.axis_names})")
    shards = sizes[table_axis]
    if cfg.num_tables % shards != 0:
        raise ValueError(
            f"L={cfg.num_tables} tables do not divide over "
            f"{table_axis}={shards} shards; pick L a multiple of the axis "
            "(sanitize_pspec would silently fall back to replicated)")
    return shards


def _local_buckets(x: jax.Array, w: jax.Array, cfg: AceConfig,
                   table_axis: str, num_shards: int):
    """Bucket ids of this shard's tables: (B, L) hashed, (B, L_local) kept.

    Hashing is ONE lane-padded matmul (see repro.core.srp) — recomputing
    all L tables' bits on every table shard costs ~1/num_shards of the
    insert and keeps W replicated (slicing W per shard would fight the
    128-lane padding); only the bucket *slice* is consumed.
    """
    l_local = cfg.num_tables // num_shards
    buckets = hash_buckets(x, w, cfg.srp)                      # (B, L)
    start = jax.lax.axis_index(table_axis) * l_local
    return jax.lax.dynamic_slice_in_dim(buckets, start, l_local, axis=1)


def update_table_sharded(state: AceState, x: jax.Array, w: jax.Array,
                         cfg: AceConfig, *, table_axis: str,
                         num_shards: int, data_axes=()) -> AceState:
    """shard_map-mode insert for the table-sharded layout.

    ``state.counts`` is the LOCAL (L_local, 2^K) block; scalars are
    replicated.  The counts update is psum-free on ``table_axis`` (each
    shard owns its tables outright); the only collectives are the (B,)
    float psum for the Welford score stream and, when the batch is also
    sharded, the histogram psum over ``data_axes``.
    """
    _no_quantized(state, "update_table_sharded")
    l_local = cfg.num_tables // num_shards
    buckets = _local_buckets(x, w, cfg, table_axis, num_shards)  # (B, Ll)
    rows = jnp.broadcast_to(
        jnp.arange(l_local, dtype=jnp.int32)[None, :], buckets.shape)

    if data_axes:
        zero = jnp.zeros((l_local, cfg.num_buckets),
                         dtype=jnp.dtype(cfg.counter_dtype))
        hist = zero.at[rows, buckets].add(1)
        hist = jax.lax.psum(hist, data_axes)
        new_counts = state.counts + hist
    else:
        new_counts = state.counts.at[rows, buckets].add(1)

    # Post-insert scores: local partial sum over owned tables, one (B,)
    # psum, then the same /L mean as sketch.insert_buckets.  All summands
    # are integer-valued float32 (< 2^24), so this matches the replicated
    # jnp.mean bitwise.
    partial = jnp.sum(new_counts[rows, buckets].astype(jnp.float32), axis=-1)
    total = jax.lax.psum(partial, table_axis)                   # (B,)
    scores = total * jnp.float32(1.0 / cfg.num_tables)

    b = jnp.asarray(scores.shape[0], jnp.float32)
    if data_axes:
        b = jax.lax.psum(b, data_axes)
    n = state.n
    tot = n + b
    rates = scores / jnp.maximum(tot, 1.0)
    if data_axes:
        sum_s = jax.lax.psum(jnp.sum(rates), data_axes)
        mean_b = sum_s / jnp.maximum(b, 1.0)
        m2_b = jax.lax.psum(jnp.sum((rates - mean_b) ** 2), data_axes)
    else:
        # exact batch-stat order of sketch.insert_buckets -> bitwise parity
        mean_b = jnp.mean(rates)
        m2_b = jnp.sum((rates - mean_b) ** 2)
    new_mean, new_m2 = sk.welford_fold(
        state.welford_mean, state.welford_m2, n, b, tot, mean_b, m2_b,
        cfg.welford_min_n)
    return AceState(counts=new_counts, n=tot,
                    welford_mean=new_mean, welford_m2=new_m2)


def update_table_sharded_masked(state: AceState, x: jax.Array,
                                w: jax.Array, mask: jax.Array,
                                cfg: AceConfig, *, table_axis: str,
                                num_shards: int,
                                data_axes=()) -> AceState:
    """shard_map-mode MASKED insert for the table-sharded layout.

    The guardrail's fixed-shape admission insert, scaled out: each shard
    scatter-adds the 0/1-weighted histogram slice of its own tables
    (psum-free on ``table_axis``); the (B,) score psum and the masked
    Welford fold follow ``update_table_sharded``.  With ``data_axes``
    empty this is bitwise-identical to ``update_global_masked`` /
    ``sketch.insert_buckets_masked`` — all cross-shard sums are over
    exactly-representable integers, and the masked-moment formulas match
    term for term (asserted by tests/test_guardrail_admit.py).
    """
    _no_quantized(state, "update_table_sharded_masked")
    l_local = cfg.num_tables // num_shards
    buckets = _local_buckets(x, w, cfg, table_axis, num_shards)  # (B, Ll)
    rows = jnp.broadcast_to(
        jnp.arange(l_local, dtype=jnp.int32)[None, :], buckets.shape)
    w_ctr = jnp.broadcast_to(
        mask.astype(state.counts.dtype)[:, None], buckets.shape)

    if data_axes:
        zero = jnp.zeros((l_local, cfg.num_buckets),
                         dtype=jnp.dtype(cfg.counter_dtype))
        hist = zero.at[rows, buckets].add(w_ctr)
        hist = jax.lax.psum(hist, data_axes)
        new_counts = state.counts + hist
    else:
        new_counts = state.counts.at[rows, buckets].add(w_ctr)

    partial = jnp.sum(new_counts[rows, buckets].astype(jnp.float32), axis=-1)
    total = jax.lax.psum(partial, table_axis)                   # (B,)
    scores = total * jnp.float32(1.0 / cfg.num_tables)

    reduce = (lambda v: jax.lax.psum(v, data_axes)) if data_axes else None
    tot, new_mean, new_m2 = sk.masked_batch_welford(
        state, scores, mask.astype(jnp.float32), cfg.welford_min_n,
        reduce=reduce)
    return AceState(counts=new_counts, n=tot,
                    welford_mean=new_mean, welford_m2=new_m2)


def score_table_sharded(state: AceState, q: jax.Array, w: jax.Array,
                        cfg: AceConfig, *, table_axis: str,
                        num_shards: int) -> jax.Array:
    """shard_map-mode Ŝ(q, D): local partial-mean + one (B,) psum.

    4·B bytes cross ``table_axis`` per call — independent of K and L, which
    is what makes the K=18+/L=200+ regime servable."""
    _no_quantized(state, "score_table_sharded")
    buckets = _local_buckets(q, w, cfg, table_axis, num_shards)
    l_local = cfg.num_tables // num_shards
    rows = jnp.broadcast_to(
        jnp.arange(l_local, dtype=jnp.int32)[None, :], buckets.shape)
    partial = jnp.sum(state.counts[rows, buckets].astype(jnp.float32),
                      axis=-1)
    # same literal reciprocal constant as sketch.lookup (bitwise parity)
    return jax.lax.psum(partial, table_axis) \
        * jnp.float32(1.0 / cfg.num_tables)


def mean_mu_table_sharded(state: AceState, cfg: AceConfig, *,
                          table_axis: str) -> jax.Array:
    """Exact μ (Eq. 11 closed form) from per-shard partial Σ‖A_j‖²."""
    _no_quantized(state, "mean_mu_table_sharded")
    c = state.counts.astype(jnp.float32)
    ssq = jax.lax.psum(jnp.sum(c * c), table_axis)
    return ssq / (jnp.maximum(state.n, 1.0) * cfg.num_tables)


def _table_sharded_specs(table_axis: str) -> AceState:
    return AceState(*(sketch_pspecs("table_sharded", table_axis)))


def make_table_sharded_update(mesh, cfg: AceConfig, *,
                              table_axis: str = "model", data_axes=()):
    """Build a shard_map'd table-sharded insert: (state, x, w) -> state.

    ``state.counts`` carries P(table_axis, None); the batch is sharded over
    ``data_axes`` when given, else replicated across the mesh."""
    from jax.experimental.shard_map import shard_map

    shards = table_shard_info(cfg, mesh, table_axis)
    st = _table_sharded_specs(table_axis)
    xspec = P(data_axes) if data_axes else P()

    def _upd(state, x, w):
        return update_table_sharded(state, x, w, cfg, table_axis=table_axis,
                                    num_shards=shards, data_axes=data_axes)

    return shard_map(_upd, mesh=mesh, in_specs=(st, xspec, P()),
                     out_specs=st, check_rep=False)


def make_table_sharded_masked_update(mesh, cfg: AceConfig, *,
                                     table_axis: str = "model",
                                     data_axes=()):
    """Build a shard_map'd table-sharded MASKED insert:
    (state, x, w, mask) -> state."""
    from jax.experimental.shard_map import shard_map

    shards = table_shard_info(cfg, mesh, table_axis)
    st = _table_sharded_specs(table_axis)
    bspec = P(data_axes) if data_axes else P()

    def _upd(state, x, w, mask):
        return update_table_sharded_masked(
            state, x, w, mask, cfg, table_axis=table_axis,
            num_shards=shards, data_axes=data_axes)

    return shard_map(_upd, mesh=mesh, in_specs=(st, bspec, P(), bspec),
                     out_specs=st, check_rep=False)


def make_table_sharded_score(mesh, cfg: AceConfig, *,
                             table_axis: str = "model"):
    """Build a shard_map'd table-sharded score: (state, q, w) -> (B,)."""
    from jax.experimental.shard_map import shard_map

    shards = table_shard_info(cfg, mesh, table_axis)
    st = _table_sharded_specs(table_axis)

    def _scr(state, q, w):
        return score_table_sharded(state, q, w, cfg, table_axis=table_axis,
                                   num_shards=shards)

    return shard_map(_scr, mesh=mesh, in_specs=(st, P(), P()),
                     out_specs=P(), check_rep=False)


def make_table_sharded_mean_mu(mesh, cfg: AceConfig, *,
                               table_axis: str = "model"):
    """Build a shard_map'd exact-μ: (state,) -> scalar."""
    from jax.experimental.shard_map import shard_map

    table_shard_info(cfg, mesh, table_axis)
    st = _table_sharded_specs(table_axis)

    def _mu(state):
        return mean_mu_table_sharded(state, cfg, table_axis=table_axis)

    return shard_map(_mu, mesh=mesh, in_specs=(st,), out_specs=P(),
                     check_rep=False)


def table_sharded_mean_mu(mesh, cfg: AceConfig, state: AceState,
                          table_axis: str = "model") -> jax.Array:
    """Convenience one-shot exact μ of a table-sharded (global) state."""
    return make_table_sharded_mean_mu(mesh, cfg, table_axis=table_axis)(state)


def shardings_for_layout(cfg: AceConfig, mesh, layout: str,
                         table_axis: str = "model",
                         quantile: bool = False,
                         attr: bool = False) -> AceState:
    """NamedSharding pytree for a named sketch layout (validated).

    The one place the "replicated"/"table_sharded" layout names resolve
    to placements — the guardrail, the stream runner, and any other
    stateful host wrapper share it instead of re-growing the same
    if/elif (+ divisibility validation) each.  ``quantile=True`` states
    carry the (NUM_BINS,) rate histogram leaf; it is tiny and read as a
    whole by the quantile threshold, so it replicates under every
    layout (the sharding tree must mirror the state tree — a None here
    against a present ``qhist`` leaf is a placement error).
    ``attr=True`` states carry the (2, NL, R, C) attribution plane —
    KBs, read whole by the findHH gathers, so it replicates under every
    layout exactly like the histogram."""
    if layout == "table_sharded":
        if cfg.esc_capacity > 0:
            raise NotImplementedError(
                "quantized sketches (esc_capacity > 0) only support the "
                "replicated layout; the table-sharded flat offsets do "
                "not carry the escalation table")
        table_shard_info(cfg, mesh, table_axis)
        tree = table_sharded_shardings(mesh, table_axis)
    elif layout == "replicated":
        tree = sketch_shardings(mesh)
        if cfg.esc_capacity > 0:
            from repro.core.quantize import EscTable
            rep = NamedSharding(mesh, P())
            tree = tree._replace(esc=EscTable(rep, rep, rep))
    else:
        raise ValueError(f"unknown sketch layout {layout!r} "
                         "(want 'replicated' or 'table_sharded')")
    if quantile:
        tree = tree._replace(qhist=NamedSharding(mesh, P()))
    if attr:
        tree = tree._replace(attr=NamedSharding(mesh, P()))
    return tree


def window_shardings_for_layout(cfg: AceConfig, mesh, num_epochs: int,
                                layout: str, table_axis: str = "model",
                                quantile: bool = False,
                                attr: bool = False):
    """NamedSharding pytree for an epoch-ring ``WindowedAceState``.

    The window analogue of ``shardings_for_layout`` (same validated
    layout names, same divisibility check): the (E, L, 2^K) ring shards
    its L axis exactly like the flat sketch — the epoch axis never
    shards — so a windowed guardrail/stream-runner places with one call
    and GSPMD keeps the per-epoch gathers and the live-epoch
    dynamic-update inside the jitted program.  ``num_epochs`` is
    accepted (and unused beyond symmetry) so call sites that only hold
    a config can still build the tree before the state exists.
    """
    from repro.dist.mesh import window_pspecs
    from repro.window.ring import WindowedAceState
    del num_epochs  # the pspec tree is epoch-count-free (P() on E)
    if layout == "table_sharded":
        table_shard_info(cfg, mesh, table_axis)
    elif layout != "replicated":
        raise ValueError(f"unknown sketch layout {layout!r} "
                         "(want 'replicated' or 'table_sharded')")
    tree = WindowedAceState(*(NamedSharding(mesh, ps)
                              for ps in window_pspecs(layout, table_axis)))
    if quantile:
        # (E, NUM_BINS) per-epoch rate histograms: tiny, combined by a
        # full-ring weighted sum at threshold time — replicate.
        tree = tree._replace(qhist=NamedSharding(mesh, P()))
    if attr:
        # (E, 2, NL, R, C) per-epoch attribution planes: KB-scale,
        # cursor-indexed as whole rows — replicate like the histograms.
        tree = tree._replace(attr=NamedSharding(mesh, P()))
    return tree


def fleet_shardings_for_layout(cfg: AceConfig, mesh, num_tenants: int,
                               layout: str, table_axis: str = "model",
                               tenant_axis: str = "data",
                               quantile: bool = False,
                               attr: bool = False):
    """NamedSharding pytree for a multi-tenant ``FleetState`` (validated).

    The fleet analogue of ``shardings_for_layout``: resolves the four
    fleet layout names of ``repro.dist.mesh.fleet_pspecs`` to placements
    with the same up-front divisibility checks (T over ``tenant_axis``,
    L over ``table_axis`` — no silent replication fallback).  Because
    tenants never couple, the tenant axis shards EVERY leaf (counts and
    the (T,) stat vectors alike) and all fleet ops stay collective-free
    on it under jit/SPMD — GSPMD only inserts collectives for the L-axis
    composition, exactly as in the single-tenant table-sharded layout.
    """
    from repro.dist.mesh import fleet_pspecs
    from repro.fleet.state import FleetState
    specs = fleet_pspecs(layout, table_axis, tenant_axis)  # validates name
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if layout in ("tenant_sharded", "tenant_table_sharded"):
        if tenant_axis not in sizes:
            raise ValueError(f"mesh has no axis {tenant_axis!r} "
                             f"(axes: {mesh.axis_names})")
        shards = sizes[tenant_axis]
        if num_tenants % shards != 0:
            raise ValueError(
                f"T={num_tenants} tenants do not divide over "
                f"{tenant_axis}={shards} shards; pick T a multiple of the "
                "axis (sanitize_pspec would silently fall back to "
                "replicated)")
    if layout in ("table_sharded", "tenant_table_sharded"):
        table_shard_info(cfg, mesh, table_axis)
    tree = FleetState(*(NamedSharding(mesh, ps) for ps in specs))
    if quantile:
        # (T, NUM_BINS) per-tenant rate histograms follow the (T,) stat
        # vectors: tenant axis shards every leaf under the tenant
        # layouts (tenants never couple), replicated otherwise.
        qspec = (P(tenant_axis) if layout in ("tenant_sharded",
                                              "tenant_table_sharded")
                 else P())
        tree = tree._replace(qhist=NamedSharding(mesh, qspec))
    if attr:
        # (T, 2, NL, R, C) per-tenant attribution planes shard their
        # tenant axis wherever the stat vectors do (tenants never
        # couple), replicated otherwise — same rule as the histograms.
        aspec = (P(tenant_axis) if layout in ("tenant_sharded",
                                              "tenant_table_sharded")
                 else P())
        tree = tree._replace(attr=NamedSharding(mesh, aspec))
    return tree


def score_window_table_sharded(counts: jax.Array, weights: jax.Array,
                               buckets: jax.Array, cfg: AceConfig, *,
                               table_axis: str,
                               num_shards: int) -> jax.Array:
    """shard_map-mode windowed Ŝ(q): per-epoch local partials, ONE
    (E, B) psum, then the γ-weighted combine in ring-index order.

    ``counts`` is the LOCAL (E, L_local, 2^K) ring block; ``weights``
    the replicated (E,) γ^age vector; ``buckets`` the (B, L_local)
    slice of this shard's tables.  The psum runs BEFORE the weighting:
    per-epoch partial sums are integer-valued float32 (< 2^24), so the
    cross-shard reduction is exact and the subsequent weighted
    accumulate is the IDENTICAL float sequence as the replicated
    ``repro.window.score_windowed`` — bitwise parity for every γ, not
    just the hard window (weighting local partials first would need
    w·(a+b) ≡ w·a + w·b, which floats do not grant).
    """
    E = counts.shape[0]
    l_local = cfg.num_tables // num_shards
    rows = jnp.broadcast_to(
        jnp.arange(l_local, dtype=jnp.int32)[None, :], buckets.shape)
    partial = jnp.stack(
        [jnp.sum(counts[e][rows, buckets].astype(jnp.float32), axis=-1)
         for e in range(E)])                                   # (E, B)
    total = jax.lax.psum(partial, table_axis)                  # exact ints
    acc = jnp.zeros(buckets.shape[:1], jnp.float32)
    for e in range(E):   # ring-index order — same as score_windowed
        acc = acc + weights[e] * total[e]
    return acc * jnp.float32(1.0 / cfg.num_tables)


def make_table_sharded_window_score(mesh, cfg: AceConfig, *,
                                    table_axis: str = "model"):
    """Build a shard_map'd windowed score:
    (ring counts (E, L, 2^K), weights (E,), q, w) -> (B,) scores.

    The table-sharded window reads move 4·E·B bytes per batch (one
    (E, B) float psum) — independent of K and L, same scaling story as
    ``make_table_sharded_score`` with an E-row combine on top."""
    from jax.experimental.shard_map import shard_map

    shards = table_shard_info(cfg, mesh, table_axis)

    def _scr(counts, weights, q, w):
        buckets = _local_buckets(q, w, cfg, table_axis, shards)
        return score_window_table_sharded(
            counts, weights, buckets, cfg, table_axis=table_axis,
            num_shards=shards)

    return shard_map(
        _scr, mesh=mesh,
        in_specs=(P(None, table_axis, None), P(), P(), P()),
        out_specs=P(), check_rep=False)


def table_sharded_shardings(mesh, table_axis: str = "model") -> AceState:
    """NamedSharding pytree placing a GLOBAL AceState table-sharded.

    Use with ``jax.device_put(sk.init(cfg), table_sharded_shardings(mesh))``
    — the global (L, 2^K) counts array is split over ``table_axis`` rows;
    ``merge``/checkpointing keep working on the global view unchanged
    (jit/SPMD mode), while the ``make_table_sharded_*`` fns consume the
    same placement in shard_map mode.
    """
    return AceState(*(NamedSharding(mesh, ps)
                      for ps in sketch_pspecs("table_sharded", table_axis)))
