"""Compiled-HLO text analysis: collective traffic and loop trip counts.

This is the measurement half of the ACE collective story (paper §4 argues
only *counts of hashes* ever cross the network; this module lets the dry-run
verify that claim on the actual compiled module).  It supports both
execution modes: programs built with explicit ``shard_map`` collectives and
plain jit/SPMD programs where GSPMD inserted the all-reduce — by the time
XLA is done, both are the same ``all-reduce``/``all-gather``/
``reduce-scatter`` instructions in the HLO text.

Consumed by ``repro.launch.dryrun`` (per-cell collective schedule recorded
to JSON) and ``repro.dist.roofline`` (the ICI term of the three-term model).
Pure string processing — importing this module never touches jax device
state, so it is safe inside the dry-run's 512-fake-device subprocesses.
"""
from __future__ import annotations

import re

# Bits per element for every dtype XLA prints in shape strings.  4-bit and
# 1-bit (pred is stored as a byte) types round up at the shape level.
_DTYPE_BITS = {
    "pred": 8,
    "s2": 2, "u2": 2, "s4": 4, "u4": 4,
    "s8": 8, "u8": 8,
    "s16": 16, "u16": 16, "f16": 16, "bf16": 16,
    "s32": 32, "u32": 32, "f32": 32,
    "s64": 64, "u64": 64, "f64": 64,
    "f8e4m3fn": 8, "f8e5m2": 8, "f8e4m3b11fnuz": 8,
    "f8e4m3fnuz": 8, "f8e5m2fnuz": 8, "f8e3m4": 8, "f8e4m3": 8,
    "c64": 64, "c128": 128,
    "token": 0, "opaque": 0,
}

_ARRAY_SHAPE_RE = re.compile(r"([a-z]\w*)\[([0-9,\s]*)\]")

# `%name = SHAPE op-kind(...)`.  SHAPE is either a tuple `( ... )` or an
# array `dtype[dims]{layout}`; the kind may carry an async -start/-done
# suffix.  Anchoring on `= SHAPE kind(` keeps instruction *names* like
# `%all-reduce.1 = ...` from matching by themselves.
_COLLECTIVE_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|[a-z]\w*\[[^\]]*\](?:\{[^}]*\})?)\s*"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute|collective-broadcast|ragged-all-to-all)"
    r"(?P<suffix>-start|-done)?\s*\(")


def _split_tuple(inner: str) -> list[str]:
    """Split a tuple-shape body on top-level commas only."""
    parts, depth, cur = [], 0, []
    for ch in inner:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return [p for p in (s.strip() for s in parts) if p]


def _shape_bytes(shape: str) -> int:
    """Bytes of an HLO shape string.

    Handles arrays (``bf16[4,8]``), scalars (``f32[]``), layout suffixes
    (``f32[16]{0}``) and tuples (``(f32[4], s32[2])`` — summed).  Unknown
    dtypes contribute 0 rather than raising: the parser must survive any
    HLO text the backend prints.
    """
    s = shape.strip()
    if s.startswith("("):
        return sum(_shape_bytes(p) for p in _split_tuple(s[1:s.rfind(")")]))
    m = _ARRAY_SHAPE_RE.match(s)
    if not m:
        return 0
    dtype, dims = m.groups()
    n = 1
    for d in dims.split(","):
        d = d.strip()
        if d:
            n *= int(d)
    return (n * _DTYPE_BITS.get(dtype, 0) + 7) // 8


def collective_bytes_by_kind(hlo_text: str) -> dict:
    """Bucket the collective traffic of a compiled module by op kind.

    Returns ``{kind: {"bytes": int, "count": int}, ..., "total_bytes": int}``
    where kind is the base HLO opcode (``all-reduce``, ``all-gather``,
    ``reduce-scatter``, ``all-to-all``, ``collective-permute``, ...).

    Bytes are the *result* shape of each op — the per-device payload one
    issue of the collective moves, which is the quantity the roofline's ICI
    term wants.  Async pairs count once: ``-start`` carries the bytes (for a
    tuple-shaped start, the last element — the destination buffer), the
    matching ``-done`` is skipped.
    """
    out: dict = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        if m.group("suffix") == "-done":
            continue
        shape = m.group("shape")
        if m.group("suffix") == "-start" and shape.startswith("("):
            parts = _split_tuple(shape[1:shape.rfind(")")])
            shape = parts[-1] if parts else shape
        kind = m.group("kind")
        slot = out.setdefault(kind, {"bytes": 0, "count": 0})
        slot["bytes"] += _shape_bytes(shape)
        slot["count"] += 1
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    return out


_TRIP_RE = re.compile(
    r'known_trip_count[^0-9]{0,16}(\d+)|trip_count[="\s:]{1,4}(\d+)')


def while_loop_trip_counts(hlo_text: str) -> list[int]:
    """Trip counts XLA proved for the module's while loops.

    Backends annotate unrollable loops with ``known_trip_count={n=R}`` (or
    ``trip_count=R`` in older dumps).  Returns every annotation found, in
    text order; an empty list just means the backend did not annotate —
    the dry-run records it as best-effort metadata, never a hard signal.
    """
    out = []
    for m in _TRIP_RE.finditer(hlo_text):
        out.append(int(m.group(1) or m.group(2)))
    return out
