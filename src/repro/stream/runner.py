"""Scan-fused streaming ingest: T batches per device program, one host
transfer per chunk.

The paper's headline claim is *stream-rate* detection — each item costs
one hash plus O(L) cache lookups.  The per-batch ingest loop this module
replaces broke that on the host side: every Python-level batch paid a
device-program dispatch, and every batch synced at least twice (the
kept-fraction metric plus the next dispatch's argument feed), so at high
stream rates the filter was bounded by the host, not the sketch.

``StreamRunner`` stacks T batches into one (T, B, d) chunk and consumes
it with ONE donated-state ``lax.scan`` program whose body is exactly
``AceDataFilter.step`` — hash once → score from the same bucket ids →
on-device μ−ασ threshold → ``sk.insert_buckets_masked`` — and returns
only a small per-chunk summary (kept fraction, per-step anomaly counts,
on-device top-k most-anomalous item coordinates).  Host traffic per T
batches: one stacked H2D feed + one summary D2H pull, versus ≥ 2·T
transfers for the legacy loop; the sketch state never leaves the device
(the carry is donated, so the counts buffer is updated in place across
chunks).  ``benchmarks/stream_throughput.py`` counts both.

Sharded ingest: pass a mesh + ``sketch_layout`` ("replicated" or
"table_sharded") and the sketch state is placed via
``repro.dist.sketch_parallel`` and sharding-constrained inside the scan
body — the SAME jitted program in every layout; GSPMD inserts the
collectives (jit/SPMD mode, exactly like the guardrail and train_step).

The hash family follows the filter's ``hash_mode`` knob (dense matmul,
SRHT fast transform, or auto break-even) because the scan body hashes
through ``repro.core.srp.hash_buckets``.

Multi-tenant fleets: with a ``repro.fleet.FleetDataFilter`` the chunk
additionally carries a (T_chunk, B) tenant-id plane and every scan step
routes its mixed-tenant batch through the fleet's flat-offset
gather/scatter — same ONE donated program, same 1 H2D + 1 D2H per
chunk, with the summary upgraded to ``FleetChunkSummary`` (per-tenant
kept/item counts and per-tenant n ride in the same single pull).
Sharded fleets place via ``repro.dist.sketch_parallel
.fleet_shardings_for_layout`` (tenant, table, or composed 2-D
sharding).

Sliding windows: with a ``repro.window.WindowedAceFilter`` (or any
filter whose state is a ``WindowedAceState`` ring), ``rotate_every=R``
advances the epoch ring every R scan steps INSIDE the donated device
program — as straight-line code between R-step scan segments when R
divides the chunk (no per-step branching; a per-step cond would copy
the multi-MB carry), or as one tick-gated clock per chunk boundary when
R spans chunks.  Windowing therefore adds ZERO extra host syncs: still
exactly 1 H2D + 1 D2H per chunk, still one executable
(``trace_count``), and rotations land at the same stream positions as
the per-batch drivers' eager ``maybe_rotate`` clock.
"""
from __future__ import annotations

from typing import Iterable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sketch import AceState
from repro.data.pipeline import AceDataFilter


class ChunkSummary(NamedTuple):
    """Everything the host learns about a chunk — ONE small transfer.

    kept_frac:   () float32 — fraction of the chunk's T·B items kept.
    anom_counts: (T,) int32 — anomalies flagged per step.
    topk_step:   (k,) int32 — step index of the k most-anomalous items.
    topk_item:   (k,) int32 — row index within that step's batch.
    topk_margin: (k,) float32 — score − threshold (most negative = most
                 anomalous; +inf while the sketch is in warmup).
    n:           () float32 — sketch item count after the chunk.
    quarantined: () int32 — non-finite feature rows sanitized at the
                 filter entry point (margin = −inf; counted among the
                 anomalies, never inserted).
    degraded:    () bool — True when the chunk was scored with a health
                 mask (some tables excluded — repro.resilience).
    falpha:      () float32 — normalized α-th frequency-moment index of
                 the post-chunk count planes (repro.quantile.moments,
                 α = 1.25): 1.0 for a uniform plane, grows with bucket
                 concentration, stationary in n — a Compressed-Counting
                 style drift statistic the host can watch chunk-over-
                 chunk without pulling the (L, 2^K) table.  Windowed
                 states report it over the γ-combined ring.  Quantized
                 planes with an escalation table report it over the
                 EXACT densified logical counts (raw saturated narrow
                 planes would understate concentration right when the
                 heavy buckets matter most).
    topk_valid:  (k,) bool — True where the topk row is a GENUINE
                 flagged anomaly (finite negative margin).  False rows
                 are report padding: +inf warmup sentinels, non-
                 anomalous fill when the chunk had fewer than k
                 anomalies, or a fully-quarantined chunk.  Hosts must
                 mask on this instead of consuming topk_* raw.
    hh_coord/hh_est/hh_valid: (topk,) heavy-hitter attribution — the
                 coordinates driving this chunk's anomalies, named by
                 the dyadic findHH drill-down over the signed sketch of
                 the chunk's drift vector (anomaly-mean − background-
                 mean energy per coordinate, repro.attribution), with
                 their signed estimated drift energies.  ``hh_valid``
                 masks beam padding.  None unless the filter enables
                 attribution (``attr_rows > 0``).
    """

    kept_frac: jax.Array
    anom_counts: jax.Array
    topk_step: jax.Array
    topk_item: jax.Array
    topk_margin: jax.Array
    n: jax.Array
    quarantined: jax.Array
    degraded: jax.Array
    falpha: jax.Array
    topk_valid: jax.Array = None
    hh_coord: jax.Array = None
    hh_est: jax.Array = None
    hh_valid: jax.Array = None


class FleetChunkSummary(NamedTuple):
    """The fleet upgrade of ``ChunkSummary`` — still ONE small transfer.

    Same global fields, plus per-tenant rows so the host can follow T
    detectors without T pulls:

    per_tenant_items: (T,) float32 — items routed to each tenant.
    per_tenant_kept:  (T,) float32 — of those, how many were kept.
    n:                (T,) float32 — each tenant's sketch n after the
                      chunk (replaces the scalar n of the flat summary).
    quarantined:      () int32 — sanitized non-finite rows (see
                      ``ChunkSummary``).
    degraded:         () bool — chunk scored under a health mask.
    misrouted:        () int32 — items routed to tenants outside the
                      replica's ownership mask (repro.cluster): scored
                      but never kept/inserted.  0 when no tenant_mask.
    falpha:           (T,) float32 — each tenant's frequency-moment
                      drift index (see ``ChunkSummary.falpha``).
    topk_valid:       (k,) bool — genuine-anomaly mask over the topk_*
                      rows (see ``ChunkSummary.topk_valid``).
    hh_coord/hh_est/hh_valid: (topk,) chunk-global heavy-hitter
                      coordinates (see ``ChunkSummary``); None unless
                      the filter enables attribution.
    hh_tenant/hh_tenant_est: (min(topk, T),) the tenants whose anomaly
                      traffic drifted hardest this chunk (exact dense
                      per-tenant drift L2, descending) and those
                      magnitudes; None unless attribution is enabled.
    """

    kept_frac: jax.Array
    anom_counts: jax.Array
    topk_step: jax.Array
    topk_item: jax.Array
    topk_margin: jax.Array
    per_tenant_items: jax.Array
    per_tenant_kept: jax.Array
    n: jax.Array
    quarantined: jax.Array
    degraded: jax.Array
    misrouted: jax.Array
    falpha: jax.Array
    topk_valid: jax.Array = None
    hh_coord: jax.Array = None
    hh_est: jax.Array = None
    hh_valid: jax.Array = None
    hh_tenant: jax.Array = None
    hh_tenant_est: jax.Array = None


class StreamRunner:
    """Chunked scan ingest around an ``AceDataFilter``.

    ``consume`` is ONE fixed-shape jitted program (state donated) per
    (T, B, d) chunk shape; ``trace_count`` asserts it stays one
    executable across chunks.  ``return_masks=True`` additionally returns
    the (T, B) keep mask — still a single transfer when the caller pulls
    it together with the summary — which is how the training loop's
    chunked prefilter applies the verdicts to its loss masks.
    """

    def __init__(self, filt: AceDataFilter, chunk_T: int, topk: int = 8,
                 return_masks: bool = False, *, mesh=None,
                 sketch_layout: str = "replicated",
                 table_axis: str = "model",
                 rotate_every: int | None = None):
        self.filt = filt
        self.chunk_T = int(chunk_T)
        self.topk = int(topk)
        self.return_masks = return_masks
        self.mesh = mesh
        self.sketch_layout = sketch_layout
        # Multi-tenant fleet filter: the scan body routes a per-step
        # (B,) tenant-id vector and the summary grows per-tenant rows.
        self.is_fleet = hasattr(filt, "num_tenants")
        # Epoch-ring rotation clock: None inherits the filter's own
        # ``rotate_every`` (0 for the flat AceDataFilter — no rotation).
        if rotate_every is None:
            rotate_every = int(getattr(filt, "rotate_every", 0))
        self.rotate_every = int(rotate_every)
        if self.is_fleet and self.rotate_every:
            raise NotImplementedError(
                "windowed fleets are host-driven for now (per-tenant "
                "clocks via repro.fleet.window.maybe_rotate_fleet); the "
                "scan runner consumes FLAT fleets only")
        if self.rotate_every and not hasattr(filt, "num_epochs"):
            raise ValueError("rotate_every needs a windowed filter "
                             "(repro.window.WindowedAceFilter); the flat "
                             "AceDataFilter has no epoch ring to rotate")
        if self.rotate_every and self.chunk_T % self.rotate_every != 0 \
                and self.rotate_every % self.chunk_T != 0:
            raise ValueError(
                f"rotate_every={self.rotate_every} must divide or be a "
                f"multiple of chunk_T={self.chunk_T} so epoch boundaries "
                "land deterministically inside or between chunks")
        self.trace_count = 0          # incremented at TRACE time only
        # Heavy-hitter attribution: non-None when the filter carries
        # attr planes (attr_rows > 0) — the consume program then also
        # observes per-chunk energy sketches and drills down for the
        # summary's hh_* fields (same single program, same 1 D2H).
        self.attr_cfg = (filt.ace_cfg.attr
                         if hasattr(filt, "ace_cfg") else None)
        self._shardings = None
        if mesh is not None:
            quantile = (getattr(filt, "threshold_mode", "mu_sigma")
                        == "quantile")
            attr = self.attr_cfg is not None
            if self.is_fleet:
                from repro.dist.sketch_parallel import \
                    fleet_shardings_for_layout
                self._shardings = fleet_shardings_for_layout(
                    filt.ace_cfg, mesh, filt.num_tenants, sketch_layout,
                    table_axis, quantile=quantile, attr=attr)
            elif hasattr(filt, "num_epochs"):
                from repro.dist.sketch_parallel import \
                    window_shardings_for_layout
                self._shardings = window_shardings_for_layout(
                    filt.ace_cfg, mesh, filt.num_epochs, sketch_layout,
                    table_axis, quantile=quantile, attr=attr)
            else:
                from repro.dist.sketch_parallel import shardings_for_layout
                self._shardings = shardings_for_layout(
                    filt.ace_cfg, mesh, sketch_layout, table_axis,
                    quantile=quantile, attr=attr)
        # The incoming state is dead the moment consume() rebinds it —
        # donate it so the (L, 2^K) counts update in place every chunk.
        self._consume = jax.jit(self._consume_impl, donate_argnums=0)

    def init(self):
        """(state, w), with the state placed per the mesh layout."""
        state, w = self.filt.init()
        return self._place(state), w

    def _place(self, state: AceState) -> AceState:
        if self._shardings is None:
            return state
        return jax.device_put(state, self._shardings)

    def _constrain(self, state: AceState) -> AceState:
        """Pin the scan carry to the requested repro.dist layout so GSPMD
        keeps the collectives inside the scan body (no-op off-mesh).
        Works for both the flat ``AceState`` and the epoch-ring
        ``WindowedAceState`` (the shardings pytree mirrors the carry —
        absent optional leaves pair None-with-None and pass through)."""
        if self._shardings is None:
            return state
        return type(state)(*(
            leaf if (leaf is None or sh is None)
            else jax.lax.with_sharding_constraint(leaf, sh)
            for leaf, sh in zip(state, self._shardings)))

    def _consume_impl(self, state: AceState, w: jax.Array,
                      feats: jax.Array, tenant_ids=None,
                      table_mask=None, tenant_mask=None):
        self.trace_count += 1
        T, B = feats.shape[0], feats.shape[1]
        R = self.rotate_every
        gamma = getattr(self.filt, "decay", 1.0)

        if self.is_fleet:
            # fleet scan: the step consumes (feat, tids) pairs — same
            # donated carry, same single program (R is 0 by __init__)
            def fstep(carry, xs):
                feat, tids = xs
                new_state, keep, margin = self.filt.step(
                    carry, w, feat, tids, table_mask=table_mask,
                    tenant_mask=tenant_mask)
                return self._constrain(new_state), (keep, margin)

            state, (keeps, margins) = jax.lax.scan(
                fstep, state, (feats, tenant_ids))
            return self._fleet_summary(state, keeps, margins,
                                       tenant_ids, feats, T, B,
                                       table_mask, tenant_mask)

        def step(carry, feat):
            new_state, keep, margin = self.filt.step(
                carry, w, feat, table_mask=table_mask)
            return self._constrain(new_state), (keep, margin)

        if R and T % R == 0:
            # Epoch-ring rotation INSIDE the donated program, with no
            # per-step branching: the chunk scans in R-step segments and
            # the tick-gated clock runs once per segment boundary.  (A
            # per-step lax.cond would make XLA copy the multi-MB ring
            # carry on EVERY step — measured, that cost more than the
            # whole flat filter step; once per R steps it is noise.)
            # The tick-gated clock, not an unconditional rotate: a state
            # handed over mid-epoch (tick off the R-grid — out of this
            # runner's contract, which owns the stream from tick 0) then
            # keeps its epoch open instead of rotating at phase-shifted
            # positions, preserving the global invariant that rotations
            # only ever land on tick ≡ 0 (mod R).  On-contract entry
            # (every chunk starts at a multiple of T, R | T) makes the
            # gate fire at every boundary — identical to the per-batch
            # eager clock, asserted bitwise in tests/test_window.py.
            from repro.window import maybe_rotate

            def segment(carry, seg_feats):
                carry, outs = jax.lax.scan(step, carry, seg_feats)
                return self._constrain(
                    maybe_rotate(carry, R, gamma)), outs

            seg_feats = feats.reshape((T // R, R) + feats.shape[1:])
            state, (keeps, margins) = jax.lax.scan(
                segment, state, seg_feats)
            keeps = keeps.reshape((T,) + keeps.shape[2:])
            margins = margins.reshape((T,) + margins.shape[2:])
        elif R:
            # R is a multiple of T (validated in __init__): rotations
            # only ever land on chunk boundaries — scan the chunk, then
            # ONE tick-gated clock check (a single cond per chunk, not
            # T per-step conds).
            from repro.window import maybe_rotate
            state, (keeps, margins) = jax.lax.scan(step, state, feats)
            state = maybe_rotate(state, R, gamma)
        else:
            state, (keeps, margins) = jax.lax.scan(step, state, feats)
        keepf = keeps.astype(jnp.float32)                     # (T, B)
        k = min(self.topk, T * B)
        # top-k most anomalous = smallest margins — but quarantined rows
        # carry margin = −inf by the sanitize contract (a SENTINEL, not
        # a measurement: their features never touched the sketch), so
        # raw top-k would let garbage rows displace every GENUINE
        # anomaly from the report during a corruption burst.  Substitute
        # +inf so they sort dead last, like warmup rows; the raw margins
        # still feed the ``quarantined`` count below.
        ranked = jnp.where(jnp.isneginf(margins), jnp.inf, margins)
        neg, idx = jax.lax.top_k(-ranked.reshape(-1), k)
        topk_margin = -neg
        # drift statistic: one O(L·2^K) pass over the post-chunk planes
        from repro.quantile import falpha_index
        if hasattr(self.filt, "num_epochs"):
            from repro.window import ring
            falpha = falpha_index(ring.decayed_counts(state, gamma),
                                  ring.combined_n(state, gamma),
                                  table_mask=table_mask)
        elif state.esc is not None:
            # quantized planes with overflow promotion: the moment index
            # must see the EXACT logical counts — a saturated narrow
            # plane clips precisely the heavy buckets the α-moment
            # weights hardest, so falpha over raw int8/int16 counts
            # diverges from the true statistic right at the saturation
            # boundary (differential-tested vs the wide dtypes)
            from repro.core import quantize as qz
            falpha = falpha_index(qz.densify(state.counts, state.esc),
                                  state.n, table_mask=table_mask)
        else:
            falpha = falpha_index(state.counts, state.n,
                                  table_mask=table_mask)
        # heavy-hitter attribution: sketch the chunk's energy split into
        # the state planes + drill down on the chunk drift vector — all
        # fixed-shape device work inside the same jitted program
        hh = None
        if self.attr_cfg is not None:
            state, hh, _ = self._attr_observe(state, feats,
                                              margins.reshape(-1))
        summary = ChunkSummary(
            kept_frac=jnp.mean(keepf),
            anom_counts=jnp.sum(1 - keeps.astype(jnp.int32), axis=1),
            topk_step=(idx // B).astype(jnp.int32),
            topk_item=(idx % B).astype(jnp.int32),
            topk_margin=topk_margin,
            # windowed carries hold per-epoch (E,) counts — report the
            # ring total so the summary shape is layout-independent
            n=state.n if state.n.ndim == 0 else jnp.sum(state.n),
            # −inf margins uniquely mark sanitized rows (warmup margins
            # are +inf, real margins finite) — count them without
            # changing the filter step protocol
            quarantined=jnp.sum(jnp.isneginf(margins)).astype(jnp.int32),
            degraded=jnp.asarray(table_mask is not None),
            falpha=falpha,
            # a topk row is real only if a GENUINE anomaly filled it:
            # finite (not +inf warmup / not a quarantine sentinel routed
            # to +inf by the ranking substitution) AND negative (flagged)
            topk_valid=jnp.isfinite(topk_margin) & (topk_margin < 0.0),
            hh_coord=None if hh is None else hh[0],
            hh_est=None if hh is None else hh[1],
            hh_valid=None if hh is None else hh[2])
        if self.return_masks:
            return state, summary, keeps
        return state, summary

    def _attr_observe(self, state, feats, margins_flat,
                      tenant_ids=None):
        """Fold one chunk's energy split into the state's attribution
        planes and drill down on the chunk drift vector.

        The flat path runs the IDENTICAL T=1 segment-sum program the
        fleet path runs per tenant (``tenant_ids=None`` ⇒ all-zero ids
        inside ``chunk_energy``), which makes fleet-of-1 attribution
        bitwise the single-tenant path.  Returns (state, (hh_coord,
        hh_est, hh_valid)) plus the raw energy split for the fleet
        summary's per-tenant rows."""
        from repro import attribution as at
        acfg = self.attr_cfg
        d = feats.shape[-1]
        feat = feats.reshape(-1, d)
        # quarantined rows carry non-finite features — margin −inf
        # already excludes them from both channels, but inf·0 = nan
        # would poison the scatter, so zero them first (same sanitize
        # the filter step applies)
        finite = jnp.all(jnp.isfinite(feat), axis=-1)
        feat = jnp.where(finite[:, None], feat, 0.0)
        nt = self.filt.num_tenants if self.is_fleet else 1
        e_all, e_anom, n_all, n_anom = at.chunk_energy(
            feat, margins_flat, nt, tenant_ids)
        planes = at.chunk_planes(acfg, e_all, e_anom)
        if self.is_fleet:
            attr = at.observe_fleet(state.attr, planes)
        elif hasattr(self.filt, "num_epochs"):
            attr = at.observe_window(state.attr, planes[0], state.cursor)
        else:
            attr = at.observe_flat(state.attr, planes)
        state = state._replace(attr=attr)
        drift = at.drift_vector(e_all, e_anom, n_all, n_anom)
        hh = at.find_hh(acfg, at.sketch_vector(acfg, drift), self.topk)
        return state, hh, (e_all, e_anom, n_all, n_anom)

    def _fleet_summary(self, state, keeps, margins, tenant_ids, feats,
                       T, B, table_mask=None, tenant_mask=None):
        """Per-tenant summary rows from the scan outputs — all device
        reductions, one transfer with the rest of the summary."""
        from repro.fleet.state import per_tenant_counts
        from repro.quantile import falpha_index
        nt = self.filt.num_tenants
        keepf = keeps.astype(jnp.float32)
        k = min(self.topk, T * B)
        # quarantined (−inf margin) rows sort LAST, not first — the
        # flat-path rationale above applies per mixed batch too
        ranked = jnp.where(jnp.isneginf(margins), jnp.inf, margins)
        neg, idx = jax.lax.top_k(-ranked.reshape(-1), k)
        topk_margin = -neg
        tids_flat = tenant_ids.reshape(-1)
        if tenant_mask is None:
            misrouted = jnp.zeros((), jnp.int32)
        else:
            misrouted = jnp.sum(
                (tenant_mask[tids_flat] <= 0).astype(jnp.int32))
        hh = split = None
        if self.attr_cfg is not None:
            from repro import attribution as at
            state, hh, split = self._attr_observe(
                state, feats, margins.reshape(-1), tids_flat)
            tl2 = at.tenant_drift_l2(*split)                     # (T,)
            kt = min(self.topk, nt)
            hh_tenant_est, hh_tenant = jax.lax.top_k(tl2, kt)
        summary = FleetChunkSummary(
            kept_frac=jnp.mean(keepf),
            anom_counts=jnp.sum(1 - keeps.astype(jnp.int32), axis=1),
            topk_step=(idx // B).astype(jnp.int32),
            topk_item=(idx % B).astype(jnp.int32),
            topk_margin=topk_margin,
            per_tenant_items=per_tenant_counts(
                tids_flat, jnp.ones_like(tids_flat), nt),
            per_tenant_kept=per_tenant_counts(
                tids_flat, keepf.reshape(-1), nt),
            n=state.n,
            quarantined=jnp.sum(jnp.isneginf(margins)).astype(jnp.int32),
            degraded=jnp.asarray(table_mask is not None),
            misrouted=misrouted,
            falpha=falpha_index(state.counts, state.n,
                                table_mask=table_mask),
            topk_valid=jnp.isfinite(topk_margin) & (topk_margin < 0.0),
            hh_coord=None if hh is None else hh[0],
            hh_est=None if hh is None else hh[1],
            hh_valid=None if hh is None else hh[2],
            hh_tenant=(None if hh is None
                       else hh_tenant.astype(jnp.int32)),
            hh_tenant_est=None if hh is None else hh_tenant_est)
        if self.return_masks:
            return state, summary, keeps
        return state, summary

    def consume(self, state: AceState, w: jax.Array, feats: jax.Array,
                tenant_ids: jax.Array | None = None,
                table_mask: jax.Array | None = None,
                tenant_mask: jax.Array | None = None):
        """One chunk: feats (T, B, d) features (d = filter's dim+1 when
        produced by ``AceDataFilter.features``), plus the (T, B) int32
        tenant-id plane when the filter is a fleet.  Returns
        (new_state, summary[, keeps]) — all still on device; pull the
        summary with ONE ``jax.device_get`` when the host needs it.

        ``table_mask`` ((L,) or (T, L) f32, repro.resilience serving
        mask) scores the chunk over healthy tables only and stamps the
        summary ``degraded``.  None (the healthy default) traces no mask
        code — the degraded program is a SECOND cached executable
        (distinct treedef), so flipping back and forth costs no retrace
        and no extra host syncs.

        ``tenant_mask`` ((T,) f32, repro.cluster ownership mask, fleet
        filters only): items of unowned tenants are scored but never
        kept/inserted and counted in the summary's ``misrouted`` — a
        re-shard updates the mask VALUE host-side with no retrace (same
        treedef), and None keeps the single-host program untouched."""
        assert feats.ndim == 3 and feats.shape[0] == self.chunk_T, \
            (feats.shape, self.chunk_T)
        if self.is_fleet:
            assert tenant_ids is not None and \
                tenant_ids.shape == feats.shape[:2], \
                "fleet filters need a (T, B) tenant_ids plane"
            return self._consume(state, w, feats, tenant_ids, table_mask,
                                 tenant_mask)
        assert tenant_ids is None, \
            "tenant_ids given but the filter is not a fleet"
        assert tenant_mask is None, \
            "tenant_mask needs a fleet filter"
        return self._consume(state, w, feats, None, table_mask)

    def run(self, state: AceState, w: jax.Array,
            batches: Iterable[np.ndarray], tenant_ids=None):
        """Host driver: chunk an iterator of (B, d) feature batches and
        consume each chunk with one device program + one summary pull.

        ``tenant_ids``: for fleet filters, an iterable of (B,) int32
        vectors aligned with ``batches``.  Returns (final state,
        [host ChunkSummary per chunk]).  A trailing partial chunk (fewer
        than T batches) is dropped — the stream is infinite in
        production; pad explicitly if the tail matters.
        """
        if self.is_fleet and tenant_ids is None:
            raise ValueError("fleet filters need tenant_ids batches")
        if not self.is_fleet and tenant_ids is not None:
            # fail loudly: silently dropping the ids would make the
            # caller believe per-tenant routing happened (and the tenant
            # buffer would grow unbounded on an infinite stream)
            raise ValueError("tenant_ids given but the filter is not a "
                             "fleet (num_tenants attribute missing)")
        summaries = []
        buf: list[np.ndarray] = []
        tbuf: list[np.ndarray] = []
        tit = iter(tenant_ids) if tenant_ids is not None else None
        for b in batches:
            buf.append(np.asarray(b))
            if tit is not None:
                tbuf.append(np.asarray(next(tit)))
            if len(buf) < self.chunk_T:
                continue
            feats = jnp.asarray(np.stack(buf))     # ONE H2D per chunk
            buf.clear()
            if self.is_fleet:
                tids = jnp.asarray(np.stack(tbuf), jnp.int32)
                tbuf.clear()
                out = self.consume(state, w, feats, tids)
            else:
                out = self.consume(state, w, feats)
            state, summary = out[0], out[1]
            summaries.append(jax.device_get(summary))  # ONE D2H per chunk
        return state, summaries
