"""Scan-fused streaming ingest: T batches per device program, one host
transfer per chunk.

The paper's headline claim is *stream-rate* detection — each item costs
one hash plus O(L) cache lookups.  The per-batch ingest loop this module
replaces broke that on the host side: every Python-level batch paid a
device-program dispatch, and every batch synced at least twice (the
kept-fraction metric plus the next dispatch's argument feed), so at high
stream rates the filter was bounded by the host, not the sketch.

``StreamRunner`` stacks T batches into one (T, B, d) chunk and consumes
it with ONE donated-state ``lax.scan`` program whose body is exactly
``AceDataFilter.step`` — hash once → score from the same bucket ids →
on-device μ−ασ threshold → ``sk.insert_buckets_masked`` — and returns
only a small per-chunk summary (kept fraction, per-step anomaly counts,
on-device top-k most-anomalous item coordinates).  Host traffic per T
batches: one stacked H2D feed + one summary D2H pull, versus ≥ 2·T
transfers for the legacy loop; the sketch state never leaves the device
(the carry is donated, so the counts buffer is updated in place across
chunks).  ``benchmarks/stream_throughput.py`` counts both.

Sharded ingest: pass a mesh + ``sketch_layout`` ("replicated" or
"table_sharded") and the sketch state is placed via
``repro.dist.sketch_parallel`` and sharding-constrained inside the scan
body — the SAME jitted program in every layout; GSPMD inserts the
collectives (jit/SPMD mode, exactly like the guardrail and train_step).

The hash family follows the filter's ``hash_mode`` knob (dense matmul,
SRHT fast transform, or auto break-even) because the scan body hashes
through ``repro.core.srp.hash_buckets``.
"""
from __future__ import annotations

from typing import Iterable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sketch import AceState
from repro.data.pipeline import AceDataFilter


class ChunkSummary(NamedTuple):
    """Everything the host learns about a chunk — ONE small transfer.

    kept_frac:   () float32 — fraction of the chunk's T·B items kept.
    anom_counts: (T,) int32 — anomalies flagged per step.
    topk_step:   (k,) int32 — step index of the k most-anomalous items.
    topk_item:   (k,) int32 — row index within that step's batch.
    topk_margin: (k,) float32 — score − threshold (most negative = most
                 anomalous; +inf while the sketch is in warmup).
    n:           () float32 — sketch item count after the chunk.
    """

    kept_frac: jax.Array
    anom_counts: jax.Array
    topk_step: jax.Array
    topk_item: jax.Array
    topk_margin: jax.Array
    n: jax.Array


class StreamRunner:
    """Chunked scan ingest around an ``AceDataFilter``.

    ``consume`` is ONE fixed-shape jitted program (state donated) per
    (T, B, d) chunk shape; ``trace_count`` asserts it stays one
    executable across chunks.  ``return_masks=True`` additionally returns
    the (T, B) keep mask — still a single transfer when the caller pulls
    it together with the summary — which is how the training loop's
    chunked prefilter applies the verdicts to its loss masks.
    """

    def __init__(self, filt: AceDataFilter, chunk_T: int, topk: int = 8,
                 return_masks: bool = False, *, mesh=None,
                 sketch_layout: str = "replicated",
                 table_axis: str = "model"):
        self.filt = filt
        self.chunk_T = int(chunk_T)
        self.topk = int(topk)
        self.return_masks = return_masks
        self.mesh = mesh
        self.sketch_layout = sketch_layout
        self.trace_count = 0          # incremented at TRACE time only
        self._shardings = None
        if mesh is not None:
            from repro.dist.sketch_parallel import shardings_for_layout
            self._shardings = shardings_for_layout(
                filt.ace_cfg, mesh, sketch_layout, table_axis)
        # The incoming state is dead the moment consume() rebinds it —
        # donate it so the (L, 2^K) counts update in place every chunk.
        self._consume = jax.jit(self._consume_impl, donate_argnums=0)

    def init(self):
        """(state, w), with the state placed per the mesh layout."""
        state, w = self.filt.init()
        return self._place(state), w

    def _place(self, state: AceState) -> AceState:
        if self._shardings is None:
            return state
        return jax.device_put(state, self._shardings)

    def _constrain(self, state: AceState) -> AceState:
        """Pin the scan carry to the requested repro.dist layout so GSPMD
        keeps the collectives inside the scan body (no-op off-mesh)."""
        if self._shardings is None:
            return state
        return AceState(*(jax.lax.with_sharding_constraint(leaf, sh)
                          for leaf, sh in zip(state, self._shardings)))

    def _consume_impl(self, state: AceState, w: jax.Array,
                      feats: jax.Array):
        self.trace_count += 1
        T, B = feats.shape[0], feats.shape[1]

        def step(carry, feat):
            new_state, keep, margin = self.filt.step(carry, w, feat)
            return self._constrain(new_state), (keep, margin)

        state, (keeps, margins) = jax.lax.scan(step, state, feats)
        keepf = keeps.astype(jnp.float32)                     # (T, B)
        k = min(self.topk, T * B)
        # top-k most anomalous = smallest margins, coordinates on device
        neg, idx = jax.lax.top_k(-margins.reshape(-1), k)
        summary = ChunkSummary(
            kept_frac=jnp.mean(keepf),
            anom_counts=jnp.sum(1 - keeps.astype(jnp.int32), axis=1),
            topk_step=(idx // B).astype(jnp.int32),
            topk_item=(idx % B).astype(jnp.int32),
            topk_margin=-neg,
            n=state.n)
        if self.return_masks:
            return state, summary, keeps
        return state, summary

    def consume(self, state: AceState, w: jax.Array, feats: jax.Array):
        """One chunk: feats (T, B, d) features (d = filter's dim+1 when
        produced by ``AceDataFilter.features``).  Returns
        (new_state, summary[, keeps]) — all still on device; pull the
        summary with ONE ``jax.device_get`` when the host needs it."""
        assert feats.ndim == 3 and feats.shape[0] == self.chunk_T, \
            (feats.shape, self.chunk_T)
        return self._consume(state, w, feats)

    def run(self, state: AceState, w: jax.Array,
            batches: Iterable[np.ndarray]):
        """Host driver: chunk an iterator of (B, d) feature batches and
        consume each chunk with one device program + one summary pull.

        Returns (final state, [host ChunkSummary per chunk]).  A trailing
        partial chunk (fewer than T batches) is dropped — the stream is
        infinite in production; pad explicitly if the tail matters.
        """
        summaries = []
        buf: list[np.ndarray] = []
        for b in batches:
            buf.append(np.asarray(b))
            if len(buf) < self.chunk_T:
                continue
            feats = jnp.asarray(np.stack(buf))     # ONE H2D per chunk
            buf.clear()
            out = self.consume(state, w, feats)
            state, summary = out[0], out[1]
            summaries.append(jax.device_get(summary))  # ONE D2H per chunk
        return state, summaries
