"""Streaming ingest at device speed (scan-fused chunk runner)."""
from repro.stream.runner import ChunkSummary, StreamRunner  # noqa: F401
