"""Streaming ingest at device speed (scan-fused chunk runner)."""
from repro.stream.runner import (ChunkSummary, FleetChunkSummary,  # noqa: F401
                                 StreamRunner)
