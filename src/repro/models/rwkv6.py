"""RWKV-6 "Finch" block (arXiv:2404.05892): attention-free time mixing with
data-dependent decay, plus squared-relu channel mixing.

Per head (head_dim = 64): state S ∈ R^{Dh×Dh},
    S_t = diag(w_t)·S_{t−1} + k_tᵀ v_t
    y_t = r_t·(S_{t−1} + diag(u)·k_tᵀ v_t)
with w_t = exp(−exp(decay_t)) data-dependent per channel (the Finch change
vs RWKV-5), and the 5-way data-dependent token-shift (ddlerp) producing the
r/k/v/w/g streams through a small LoRA.

Like the Mamba block: ``rwkv_scan`` (lax.scan over time, O(1) HLO) for
train/prefill and ``rwkv_step`` (O(1) state update) for decode — this is
what makes rwkv6-7b a long_500k-capable arch in the assignment.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import KeyGen, ModelConfig, dense_init, shard

TM_RANK = 32  # token-shift LoRA rank (RWKV6 TIME_MIX_EXTRA_DIM)


class RwkvState(NamedTuple):
    x_prev_att: jax.Array   # (B, D) last token fed to time mixing
    x_prev_ffn: jax.Array   # (B, D) last token fed to channel mixing
    wkv: jax.Array          # (B, H, Dh, Dh) per-head state, f32


def _dims(cfg: ModelConfig):
    Dh = cfg.rwkv_head_dim
    H = cfg.d_model // Dh
    return H, Dh


def init_rwkv_time(cfg: ModelConfig, kg: KeyGen):
    D = cfg.d_model
    H, Dh = _dims(cfg)
    R = cfg.rwkv_decay_lora_rank
    p = {
        "mu_x": jnp.full((D,), 0.5, cfg.pdtype),
        "mu_rkvwg": jnp.full((5, D), 0.5, cfg.pdtype),
        "tm_w1": dense_init(kg(), (D, 5 * TM_RANK), cfg.pdtype),
        "tm_w2": dense_init(kg(), (5, TM_RANK, D), cfg.pdtype),
        "decay_base": jnp.zeros((D,), cfg.pdtype),
        "dd_w1": dense_init(kg(), (D, R), cfg.pdtype),
        "dd_w2": dense_init(kg(), (R, D), cfg.pdtype),
        "bonus_u": dense_init(kg(), (H, Dh), cfg.pdtype),
        "wr": dense_init(kg(), (D, D), cfg.pdtype),
        "wk": dense_init(kg(), (D, D), cfg.pdtype),
        "wv": dense_init(kg(), (D, D), cfg.pdtype),
        "wg": dense_init(kg(), (D, D), cfg.pdtype),
        # zero-init output proj (official RWKV): residual branch silent at
        # init — tames the otherwise violent curvature of wkv+groupnorm.
        "wo": jnp.zeros((D, D), cfg.pdtype),
        "ln_scale": jnp.ones((D,), cfg.pdtype),
        "ln_bias": jnp.zeros((D,), cfg.pdtype),
    }
    s = {
        "mu_x": ("embed",), "mu_rkvwg": (None, "embed"),
        "tm_w1": ("embed", None), "tm_w2": (None, None, "embed"),
        "decay_base": ("embed",),
        "dd_w1": ("embed", None), "dd_w2": (None, "embed"),
        "bonus_u": ("heads", "head_dim"),
        "wr": ("embed", "ff"), "wk": ("embed", "ff"),
        "wv": ("embed", "ff"), "wg": ("embed", "ff"),
        "wo": ("ff", "embed"),
        "ln_scale": ("embed",), "ln_bias": ("embed",),
    }
    return p, s


def init_rwkv_channel(cfg: ModelConfig, kg: KeyGen):
    D, F = cfg.d_model, cfg.d_ff
    p = {
        "mu_k": jnp.full((D,), 0.5, cfg.pdtype),
        "mu_r": jnp.full((D,), 0.5, cfg.pdtype),
        "wk": dense_init(kg(), (D, F), cfg.pdtype),
        "wv": jnp.zeros((F, D), cfg.pdtype),   # zero-init (official RWKV)
        "wr": dense_init(kg(), (D, D), cfg.pdtype),
    }
    s = {"mu_k": ("embed",), "mu_r": ("embed",),
         "wk": ("embed", "ff"), "wv": ("ff", "embed"),
         "wr": ("embed", "ff")}
    return p, s


def _ddlerp(p, x, sx):
    """Data-dependent 5-way token shift.  x, sx: (B, S, D).

    Returns (xr, xk, xv, xw, xg), each (B, S, D).
    """
    xxx = x + sx * p["mu_x"].astype(x.dtype)
    lora = jnp.einsum("bsd,dr->bsr", xxx, p["tm_w1"].astype(x.dtype))
    lora = jnp.tanh(lora)
    B, S, _ = lora.shape
    lora = lora.reshape(B, S, 5, TM_RANK)
    mix = jnp.einsum("bsfr,frd->fbsd", lora, p["tm_w2"].astype(x.dtype))
    mu = p["mu_rkvwg"].astype(x.dtype)                       # (5, D)
    outs = x[None] + sx[None] * (mu[:, None, None, :] + mix)  # (5, B, S, D)
    return outs[0], outs[1], outs[2], outs[3], outs[4]


def _streams(p, x, x_prev, cfg: ModelConfig):
    """Compute r/k/v/g/decay streams.  x (B,S,D); x_prev (B,D) seed."""
    H, Dh = _dims(cfg)
    B, S, D = x.shape
    xp = jnp.concatenate([x_prev[:, None, :].astype(x.dtype),
                          x[:, :-1, :]], axis=1)
    sx = xp - x
    xr, xk, xv, xw, xg = _ddlerp(p, x, sx)
    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(x.dtype))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"].astype(x.dtype)))
    dd = jnp.einsum("bsd,dr->bsr", jnp.tanh(xw), p["dd_w1"].astype(x.dtype))
    decay = p["decay_base"].astype(x.dtype) + \
        jnp.einsum("bsr,rd->bsd", dd, p["dd_w2"].astype(x.dtype))
    w = jnp.exp(-jnp.exp(decay.astype(jnp.float32)))          # (B,S,D) in (0,1)
    hd = (B, S, H, Dh)
    return (r.reshape(hd), k.reshape(hd), v.reshape(hd), g,
            w.reshape(hd))


def _out_norm(p, y, g, x_dtype, cfg: ModelConfig):
    """Per-head groupnorm, gate, out projection.  y: (B, S, H, Dh)."""
    y32 = y.astype(jnp.float32)
    mu = jnp.mean(y32, -1, keepdims=True)
    var = jnp.var(y32, -1, keepdims=True)
    yn = (y32 - mu) * jax.lax.rsqrt(var + 64e-5)
    B, S, H, Dh = y.shape
    yn = yn.reshape(B, S, H * Dh)
    yn = yn * p["ln_scale"].astype(jnp.float32) \
        + p["ln_bias"].astype(jnp.float32)
    out = yn.astype(x_dtype) * g
    return jnp.einsum("bse,ed->bsd", out, p["wo"].astype(x_dtype))


def rwkv_time_scan(p, x, x_prev, wkv0, cfg: ModelConfig,
                   time_chunk: int | None = None):
    """Time mixing over a full sequence.

    x: (B, S, D); x_prev: (B, D); wkv0: (B, H, Dh, Dh) f32.
    Returns (out (B,S,D), new x_prev, new wkv state).

    Chunked scan (checkpointed outer over chunks): AD saves only the
    chunk-boundary wkv states — per-step saving would cost S·B·H·Dh² f32.
    """
    B, S, D = x.shape
    r, k, v, g, w = _streams(p, x, x_prev, cfg)
    u = p["bonus_u"].astype(jnp.float32)                      # (H, Dh)

    ck = min(time_chunk or cfg.time_chunk, S)
    assert S % ck == 0, (S, ck)
    nch = S // ck
    H, Dh = r.shape[2], r.shape[3]

    def tm(t):  # (B, S, H, Dh) -> (nch, ck, B, H, Dh)
        return jnp.moveaxis(t.astype(jnp.float32), 1, 0).reshape(
            nch, ck, B, H, Dh)

    xs = (tm(r), tm(k), tm(v), tm(w))

    def step(S_, xt):
        r_t, k_t, v_t, w_t = xt
        kv = k_t[:, :, :, None] * v_t[:, :, None, :]          # (B,H,Dh,Dh)
        y = jnp.einsum("bhk,bhkd->bhd", r_t,
                       S_ + u[None, :, :, None] * kv)
        S_ = w_t[..., None] * S_ + kv
        return S_, y

    @jax.checkpoint
    def chunk_fn(S_, xs_chunk):
        return jax.lax.scan(step, S_, xs_chunk)

    S_last, ys = jax.lax.scan(chunk_fn, wkv0, xs)             # (nch,ck,B,H,Dh)
    y = jnp.moveaxis(ys.reshape(S, B, H, Dh), 0, 1)
    out = _out_norm(p, y, g, x.dtype, cfg)
    return shard(out, "batch", "seq", "embed"), x[:, -1, :], S_last


def rwkv_channel(p, x, x_prev, cfg: ModelConfig):
    """Channel mixing (squared-relu FFN with token shift).

    Returns (out, new x_prev)."""
    xp = jnp.concatenate([x_prev[:, None, :].astype(x.dtype),
                          x[:, :-1, :]], axis=1)
    sx = xp - x
    xk = x + sx * p["mu_k"].astype(x.dtype)
    xr = x + sx * p["mu_r"].astype(x.dtype)
    kk = jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(x.dtype))
    kk = jnp.square(jax.nn.relu(kk))
    kk = shard(kk, "batch", "seq", "ff")
    vv = jnp.einsum("bsf,fd->bsd", kk, p["wv"].astype(x.dtype))
    rr = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xr, p["wr"].astype(x.dtype)))
    return rr * vv, x[:, -1, :]


def rwkv_time_step(p, x, state: RwkvState, cfg: ModelConfig):
    """Decode: x (B, 1, D) -> (out (B,1,D), updated (x_prev, wkv))."""
    B = x.shape[0]
    r, k, v, g, w = _streams(p, x, state.x_prev_att, cfg)
    u = p["bonus_u"].astype(jnp.float32)
    kv = k.astype(jnp.float32)[:, 0, :, :, None] \
        * v.astype(jnp.float32)[:, 0, :, None, :]
    y = jnp.einsum("bhk,bhkd->bhd", r.astype(jnp.float32)[:, 0],
                   state.wkv + u[None, :, :, None] * kv)
    new_wkv = w[:, 0][..., None] * state.wkv + kv
    out = _out_norm(p, y[:, None], g, x.dtype, cfg)
    return out, x[:, 0, :], new_wkv


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype) -> RwkvState:
    H, Dh = _dims(cfg)
    return RwkvState(
        x_prev_att=jnp.zeros((batch, cfg.d_model), dtype),
        x_prev_ffn=jnp.zeros((batch, cfg.d_model), dtype),
        wkv=jnp.zeros((batch, H, Dh, Dh), jnp.float32))
