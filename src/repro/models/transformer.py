"""Decoder-LM assembly for the whole assigned zoo (dense / MoE / hybrid /
SSM / VLM-backbone) — one config-driven implementation.

Depth is organised as ``num_superblocks`` repetitions of
``cfg.block_pattern`` (e.g. ("swa","attn") for gemma2, ("mamba",)*7+
("attn",) for jamba-ish hybrids); repetitions are stacked on a leading axis
and executed with ``jax.lax.scan`` so HLO size is depth-independent.

Three entry points, all pure:
    forward(params, batch)            -> logits            (training)
    prefill(params, batch)            -> logits, cache     (serving)
    decode_step(params, tok, cache)   -> logits, cache     (serving)
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import mlp as mlp_mod
from repro.models import rwkv6 as rw
from repro.models.common import (KeyGen, ModelConfig, apply_norm, dense_init,
                                 init_norm, logical_to_pspec, opt_barrier,
                                 shard, softcap)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _layer_has_moe(cfg: ModelConfig, pos_in_pattern: int) -> bool:
    if cfg.moe_num_experts is None:
        return False
    return pos_in_pattern % cfg.moe_layer_period == cfg.moe_layer_period - 1


def _init_layer(cfg: ModelConfig, kind: str, use_moe: bool, key):
    kg = KeyGen(key)
    p: dict[str, Any] = {}
    s: dict[str, Any] = {}
    p["norm1"], s["norm1"] = init_norm(cfg, kg)

    if kind in ("attn", "swa"):
        p["mixer"], s["mixer"] = attn.init_attention(cfg, kg)
    elif kind == "mamba":
        p["mixer"], s["mixer"] = mb.init_mamba(cfg, kg)
    elif kind == "rwkv":
        p["mixer"], s["mixer"] = rw.init_rwkv_time(cfg, kg)
    else:
        raise ValueError(f"unknown layer kind {kind!r}")

    p["norm2"], s["norm2"] = init_norm(cfg, kg)
    if kind == "rwkv":
        p["mlp"], s["mlp"] = rw.init_rwkv_channel(cfg, kg)
    elif use_moe:
        p["mlp"], s["mlp"] = mlp_mod.init_moe(cfg, kg)
    else:
        p["mlp"], s["mlp"] = mlp_mod.init_mlp(cfg, kg)

    if cfg.post_block_norm:   # gemma2 sandwich norms
        p["post_norm1"], s["post_norm1"] = init_norm(cfg, kg)
        p["post_norm2"], s["post_norm2"] = init_norm(cfg, kg)
    return p, s


def init_params(cfg: ModelConfig, key) -> tuple[dict, dict]:
    """Returns (params, pspecs); block params are stacked over superblocks."""
    kg = KeyGen(key)
    params: dict[str, Any] = {}
    pspecs: dict[str, Any] = {}

    if cfg.input_mode == "tokens":
        # GPT-2-style 0.02 std: keeps tied-head logits O(1) at init.
        params["embed"] = dense_init(kg(), (cfg.vocab_size, cfg.d_model),
                                     cfg.pdtype, scale=0.02)
        pspecs["embed"] = ("vocab", "embed")

    R = cfg.num_superblocks
    blocks_p, blocks_s = [], []
    for pos, kind in enumerate(cfg.block_pattern):
        use_moe = _layer_has_moe(cfg, pos) and kind != "rwkv"
        keys = jax.random.split(kg(), R)
        init_fn = functools.partial(_init_layer, cfg, kind, use_moe)
        stacked, spec = jax.vmap(lambda k: init_fn(k)[0])(keys), \
            _init_layer(cfg, kind, use_moe, keys[0])[1]
        blocks_p.append(stacked)
        blocks_s.append(jax.tree.map(
            lambda ax: ("layers",) + tuple(ax), spec,
            is_leaf=lambda x: isinstance(x, tuple)))
    params["blocks"] = blocks_p
    pspecs["blocks"] = blocks_s

    params["final_norm"], pspecs["final_norm"] = init_norm(cfg, kg)
    if cfg.embed_norm:
        params["embed_norm"], pspecs["embed_norm"] = init_norm(cfg, kg)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kg(), (cfg.d_model, cfg.vocab_size),
                                       cfg.pdtype)
        pspecs["lm_head"] = ("embed", "vocab")
    return params, pspecs


@functools.lru_cache(maxsize=None)
def abstract_params(cfg: ModelConfig):
    """(ShapeDtypeStruct pytree, logical-axes pytree) — no allocation.

    The logical-axes tree is captured through an eval_shape side channel
    (it is pure Python metadata, unaffected by tracing).
    """
    box = {}

    def capture(key):
        p, s = init_params(cfg, key)
        box["specs"] = s
        return p

    shapes = jax.eval_shape(capture, jax.random.PRNGKey(0))
    return shapes, box["specs"]


def param_pspecs(cfg: ModelConfig, rules=None):
    """PartitionSpec pytree (same structure as params)."""
    _, logical = abstract_params(cfg)
    return jax.tree.map(lambda ax: logical_to_pspec(ax, rules), logical,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and all(isinstance(e, (str, type(None)))
                                for e in x))


# ---------------------------------------------------------------------------
# Layer application (shared by full-seq and decode paths)
# ---------------------------------------------------------------------------

def _apply_layer_full(p, x, kind, use_moe, cfg: ModelConfig, positions,
                      rope_tables=None):
    """Full-sequence layer.  Returns (x, aux, cache_entry)."""
    aux = {}
    h = apply_norm(p["norm1"], x, cfg)
    if kind in ("attn", "swa"):
        out, kv = attn.attention(p["mixer"], h, cfg, positions=positions,
                                 layer_kind=kind, rope_tables=rope_tables)
        cache = kv
    elif kind == "mamba":
        out, st = mb.mamba_scan(p["mixer"], h, cfg)
        cache = st
    elif kind == "rwkv":
        B = x.shape[0]
        st0 = rw.init_rwkv_state(cfg, B, x.dtype)
        out, xp, wkv = rw.rwkv_time_scan(p["mixer"], h, st0.x_prev_att,
                                         st0.wkv, cfg)
        cache = (xp, wkv)
    if cfg.post_block_norm:
        out = apply_norm(p["post_norm1"], out, cfg)
    x = x + out

    h = apply_norm(p["norm2"], x, cfg)
    if kind == "rwkv":
        out, xp_f = rw.rwkv_channel(p["mlp"], h, jnp.zeros_like(h[:, 0]),
                                    cfg)
        cache = cache + (xp_f,)
    elif use_moe:
        out, aux = mlp_mod.moe(p["mlp"], h, cfg)
    else:
        out = mlp_mod.mlp(p["mlp"], h, cfg)
    if cfg.post_block_norm:
        out = apply_norm(p["post_norm2"], out, cfg)
    x = x + out
    return x, aux, cache


def _apply_layer_decode(p, x, kind, use_moe, cfg: ModelConfig, pos, cache):
    """One-token layer.  Returns (x, new_cache_entry)."""
    h = apply_norm(p["norm1"], x, cfg)
    if kind in ("attn", "swa"):
        out, new_cache = attn.decode_attention(p["mixer"], h, cache, pos,
                                               cfg, layer_kind=kind)
    elif kind == "mamba":
        out, new_cache = mb.mamba_step(p["mixer"], h, cache, cfg)
    elif kind == "rwkv":
        xp_att, wkv, xp_ffn = cache
        out, new_xp, new_wkv = rw.rwkv_time_step(
            p["mixer"], h, rw.RwkvState(xp_att, xp_ffn, wkv), cfg)
        new_cache = (new_xp, new_wkv, xp_ffn)
    if cfg.post_block_norm:
        out = apply_norm(p["post_norm1"], out, cfg)
    x = x + out

    h = apply_norm(p["norm2"], x, cfg)
    if kind == "rwkv":
        xp_att2, wkv2, xp_ffn = new_cache
        out, new_xpf = rw.rwkv_channel(p["mlp"], h, xp_ffn.astype(h.dtype),
                                       cfg)
        new_cache = (xp_att2, wkv2, new_xpf.astype(xp_ffn.dtype))
    elif use_moe:
        # decode: capacity E/K ⇒ C = T, mathematically zero token drops
        out, _ = mlp_mod.moe(p["mlp"], h, cfg,
                             capacity_factor=float(cfg.moe_num_experts)
                             / cfg.moe_top_k)
    else:
        out = mlp_mod.mlp(p["mlp"], h, cfg)
    if cfg.post_block_norm:
        out = apply_norm(p["post_norm2"], out, cfg)
    return x + out, new_cache


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_inputs(params, batch, cfg: ModelConfig):
    if cfg.input_mode == "tokens":
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        x = x.astype(cfg.adtype)
    else:
        x = batch["embeds"].astype(cfg.adtype)
    if cfg.scale_embeddings:
        x = x * jnp.sqrt(jnp.asarray(cfg.d_model, x.dtype))
    if cfg.embed_norm:
        x = apply_norm(params["embed_norm"], x, cfg)   # rwkv ln0
    return shard(x, "batch", "seq", "embed")


def lm_head(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        w = params["embed"].T
    else:
        w = params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    logits = softcap(logits, cfg.final_logit_softcap)
    return shard(logits, "batch", "seq", "vocab")


def _positions_for(batch, cfg: ModelConfig, S: int, B: int):
    if cfg.mrope_sections is not None:
        return batch["positions"]            # (3, B, S) provided by pipeline
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))


# ---------------------------------------------------------------------------
# Full-sequence forward (training) — scan over superblocks
# ---------------------------------------------------------------------------

def forward(params, batch, cfg: ModelConfig, remat: bool = True,
            remat_policy: str = "full"):
    """batch: {"tokens": (B,S)} or {"embeds": (B,S,D)} (+"positions" for
    M-RoPE).  Returns (logits (B,S,V), aux dict).

    remat_policy: "full" (save only layer boundaries — min memory) or
    "dots" (jax.checkpoint_policies.checkpoint_dots — save matmul outputs,
    skip their recompute in backward; §Perf iteration C1)."""
    x = embed_inputs(params, batch, cfg)
    B, S = x.shape[0], x.shape[1]
    positions = _positions_for(batch, cfg, S, B)
    rope = attn.make_rope_tables(positions, cfg, cfg.head_dim) \
        if cfg.block_pattern != ("rwkv",) else None

    def superblock(carry, layer_p):
        # barrier: stops XLA hoisting the per-iteration FSDP all-gather /
        # bf16 cast of the whole stacked weights out of the loop (which
        # would materialise every layer's gathered weights at once).
        layer_p = opt_barrier(layer_p)
        x, aux_acc = carry
        for pos, kind in enumerate(cfg.block_pattern):
            use_moe = _layer_has_moe(cfg, pos) and kind != "rwkv"
            x, aux, _ = _apply_layer_full(layer_p[pos], x, kind, use_moe,
                                          cfg, positions, rope_tables=rope)
            if aux:
                aux_acc = {k: aux_acc.get(k, 0.0) + v for k, v in aux.items()}
        return (x, aux_acc), None

    # prevent_cse=False is the documented choice for remat-inside-scan:
    # the default CSE barriers make XLA materialise duplicate (f32+bf16)
    # copies of the saved carry stack.
    if remat:
        policy = None if remat_policy == "full" \
            else jax.checkpoint_policies.checkpoint_dots
        body = jax.checkpoint(superblock, prevent_cse=False, policy=policy)
    else:
        body = superblock
    aux0 = {"moe_load_balance": jnp.zeros((), jnp.float32),
            "moe_drop_frac": jnp.zeros((), jnp.float32)} \
        if cfg.moe_num_experts else {}
    (x, aux), _ = jax.lax.scan(body, (x, aux0), params["blocks"],
                               unroll=cfg.scan_unroll)
    x = apply_norm(params["final_norm"], x, cfg)
    return lm_head(params, x, cfg), aux


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, s_max: int):
    """Cache pytree: list per pattern position, stacked over superblocks."""
    R = cfg.num_superblocks
    Hk, Dh = cfg.num_kv_heads, cfg.head_dim
    cdt = cfg.adtype
    out = []
    for kind in cfg.block_pattern:
        if kind in ("attn", "swa"):
            c = attn.KVCache(
                k=jnp.zeros((R, batch, s_max, Hk, Dh), cdt),
                v=jnp.zeros((R, batch, s_max, Hk, Dh), cdt))
        elif kind == "mamba":
            st = mb.init_mamba_state(cfg, batch, cdt)
            c = jax.tree.map(lambda a: jnp.broadcast_to(a[None],
                                                        (R,) + a.shape), st)
        elif kind == "rwkv":
            st = rw.init_rwkv_state(cfg, batch, cdt)
            c = (jnp.zeros((R,) + st.x_prev_att.shape, cdt),
                 jnp.zeros((R,) + st.wkv.shape, jnp.float32),
                 jnp.zeros((R,) + st.x_prev_ffn.shape, cdt))
        out.append(c)
    return out


def cache_pspecs(cfg: ModelConfig, long_context: bool = False, rules=None):
    """PartitionSpecs for the cache: batch on (pod,data) normally; for
    batch=1 long-context, the attention cache shards SEQUENCE on data
    (context parallelism)."""
    def kv_axes():
        if long_context:
            return ("layers", None, "cache_seq", "kv_heads", "head_dim")
        return ("layers", "batch", "cache_seq", "kv_heads", "head_dim")

    out = []
    for kind in cfg.block_pattern:
        if kind in ("attn", "swa"):
            out.append(attn.KVCache(
                k=logical_to_pspec(kv_axes(), rules),
                v=logical_to_pspec(kv_axes(), rules)))
        elif kind == "mamba":
            out.append(mb.MambaState(
                conv=logical_to_pspec(("layers", "batch", None, "ff"), rules),
                ssm=logical_to_pspec(("layers", "batch", "ff", None), rules)))
        elif kind == "rwkv":
            out.append((
                logical_to_pspec(("layers", "batch", "embed"), rules),
                logical_to_pspec(("layers", "batch", "heads", None, None),
                                 rules),
                logical_to_pspec(("layers", "batch", "embed"), rules)))
    return out


def prefill(params, batch, cfg: ModelConfig, s_max: int | None = None):
    """Full-context pass building the cache.  Returns (logits, cache)."""
    x = embed_inputs(params, batch, cfg)
    B, S = x.shape[0], x.shape[1]
    s_max = s_max or S
    positions = _positions_for(batch, cfg, S, B)
    rope = attn.make_rope_tables(positions, cfg, cfg.head_dim) \
        if cfg.block_pattern != ("rwkv",) else None

    def superblock(x, layer_p):
        layer_p = opt_barrier(layer_p)
        caches = []
        for pos, kind in enumerate(cfg.block_pattern):
            use_moe = _layer_has_moe(cfg, pos) and kind != "rwkv"
            x, _, cache = _apply_layer_full(layer_p[pos], x, kind, use_moe,
                                            cfg, positions,
                                            rope_tables=rope)
            caches.append(cache)
        return x, caches

    x, caches = jax.lax.scan(superblock, x, params["blocks"],
                             unroll=cfg.scan_unroll)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = lm_head(params, x[:, -1:, :], cfg)

    # pad KV caches out to s_max slots
    if s_max > S:
        def pad_kv(c):
            if isinstance(c, attn.KVCache):
                pad = ((0, 0), (0, 0), (0, s_max - S), (0, 0), (0, 0))
                return attn.KVCache(jnp.pad(c.k, pad), jnp.pad(c.v, pad))
            return c
        caches = [pad_kv(c) if isinstance(c, attn.KVCache) else c
                  for c in caches]
    return logits, caches


def decode_step(params, batch, cache, pos, cfg: ModelConfig):
    """One token for the whole batch.

    batch: {"tokens": (B, 1)} or {"embeds": (B, 1, D)};
    pos: (B,) int32 (or (3, B) for M-RoPE).  Returns (logits, new cache).
    """
    x = embed_inputs(params, batch, cfg)

    def superblock(x, scanned):
        layer_p, layer_c = scanned
        layer_p = opt_barrier(layer_p)
        new_caches = []
        for p_idx, kind in enumerate(cfg.block_pattern):
            use_moe = _layer_has_moe(cfg, p_idx) and kind != "rwkv"
            x, nc = _apply_layer_decode(layer_p[p_idx], x, kind, use_moe,
                                        cfg, pos, layer_c[p_idx])
            new_caches.append(nc)
        return x, new_caches

    x, new_cache = jax.lax.scan(superblock, x,
                                (params["blocks"], cache),
                                unroll=cfg.scan_unroll)
    x = apply_norm(params["final_norm"], x, cfg)
    return lm_head(params, x, cfg), new_cache


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def next_token_loss(params, batch, cfg: ModelConfig, remat: bool = True,
                    remat_policy: str = "full"):
    """Causal LM loss with shift; returns (loss, aux)."""
    logits, aux = forward(params, batch, cfg, remat=remat,
                          remat_policy=remat_policy)
    if cfg.input_mode == "tokens":
        targets = batch["labels"]
    else:
        targets = batch["labels"]
    logits = logits[:, :-1, :].astype(jnp.float32)
    targets = targets[:, 1:]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None],
                              axis=-1)[..., 0]
    mask = batch.get("mask")
    nll = logz - tgt
    if mask is not None:
        m = mask[:, 1:].astype(jnp.float32)
        loss = jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    else:
        loss = jnp.mean(nll)
    if cfg.moe_num_experts:
        loss = loss + 0.01 * aux.get("moe_load_balance", 0.0) \
            / cfg.num_layers
    aux["nll"] = loss
    return loss, aux
