"""Arch registry: --arch name -> (config, model fns, input specs).

``input_specs(cfg, shape)`` builds ShapeDtypeStruct stand-ins for every
model input of a (train | prefill | decode) step — the dry-run contract:
weak-type-correct, shardable, zero allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs import ALIASES, get_config, list_archs
from repro.models import transformer as tf
from repro.models import whisper as wh
from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# archs for which long_500k is skipped (pure full attention — DESIGN.md)
LONG_CONTEXT_SKIP = {
    "mistral_large_123b": "pure full attention (no SWA in 2407 config)",
    "olmo_1b": "pure full attention",
    "qwen2_1_5b": "pure full attention",
    "qwen2_vl_7b": "pure full attention",
    "whisper_tiny": "full-attention decoder; 500k beyond positional design",
}


def is_whisper(cfg: ModelConfig) -> bool:
    return cfg.encoder_layers > 0


class Arch:
    """Bundles config + step functions for one architecture."""

    def __init__(self, name: str, reduced: bool = False):
        self.name = ALIASES.get(name, name)
        self.cfg = get_config(name, reduced=reduced)

    # ---- model fns --------------------------------------------------------
    @property
    def mod(self):
        return wh if is_whisper(self.cfg) else tf

    def init_params(self, key):
        return self.mod.init_params(self.cfg, key)

    def forward(self, params, batch, remat=True):
        return self.mod.forward(params, batch, self.cfg, remat=remat)

    def loss(self, params, batch, remat=True, remat_policy="full"):
        if is_whisper(self.cfg):
            return self.mod.next_token_loss(params, batch, self.cfg,
                                            remat=remat)
        return self.mod.next_token_loss(params, batch, self.cfg,
                                        remat=remat,
                                        remat_policy=remat_policy)

    def prefill(self, params, batch, s_max=None):
        return self.mod.prefill(params, batch, self.cfg, s_max=s_max)

    def decode_step(self, params, batch, cache, pos):
        return self.mod.decode_step(params, batch, cache, pos, self.cfg)

    # ---- shape cells ------------------------------------------------------
    def supports(self, shape_name: str) -> bool:
        if shape_name == "long_500k" and self.name in LONG_CONTEXT_SKIP:
            return False
        return True

    def skip_reason(self, shape_name: str) -> str | None:
        if shape_name == "long_500k":
            return LONG_CONTEXT_SKIP.get(self.name)
        return None

    # ---- dry-run input specs ---------------------------------------------
    def input_specs(self, shape: ShapeSpec, batch_override: int | None = None
                    ) -> dict[str, Any]:
        cfg = self.cfg
        B = batch_override or shape.global_batch
        S = shape.seq_len
        i32 = jnp.int32
        f = cfg.adtype

        def tok(shape_):
            return jax.ShapeDtypeStruct(shape_, i32)

        if is_whisper(cfg):
            enc = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), f)
            if shape.kind == "train":
                return {"embeds": enc, "tokens": tok((B, S)),
                        "labels": tok((B, S))}
            if shape.kind == "prefill":
                return {"embeds": enc, "tokens": tok((B, S))}
            return {"tokens": tok((B, 1))}

        if cfg.input_mode == "embeds":   # qwen2-vl backbone
            emb = jax.ShapeDtypeStruct((B, S, cfg.d_model), f)
            pos = jax.ShapeDtypeStruct((3, B, S), i32) \
                if cfg.mrope_sections else None
            out = {"embeds": emb}
            if pos is not None:
                out["positions"] = pos
            if shape.kind == "train":
                out["labels"] = tok((B, S))
            if shape.kind == "decode":
                out = {"embeds": jax.ShapeDtypeStruct((B, 1, cfg.d_model), f)}
                if cfg.mrope_sections:
                    out["positions"] = jax.ShapeDtypeStruct((3, B, 1), i32)
            return out

        if shape.kind == "decode":
            return {"tokens": tok((B, 1))}
        out = {"tokens": tok((B, S))}
        if shape.kind == "train":
            out["labels"] = tok((B, S))
        return out

    def decode_pos_spec(self, shape: ShapeSpec,
                        batch_override: int | None = None):
        B = batch_override or shape.global_batch
        if self.cfg.mrope_sections is not None:
            return jax.ShapeDtypeStruct((3, B), jnp.int32)
        return jax.ShapeDtypeStruct((B,), jnp.int32)

    def cache_specs(self, shape: ShapeSpec, batch_override: int | None = None):
        """Abstract cache for decode dry-runs (ShapeDtypeStruct pytree)."""
        B = batch_override or shape.global_batch
        fn = (lambda: wh_cache_abstract(self.cfg, B, shape.seq_len)) \
            if is_whisper(self.cfg) else \
            (lambda: jax.eval_shape(
                lambda: tf.init_cache(self.cfg, B, shape.seq_len)))
        return fn()

    # ---- analytics ---------------------------------------------------------
    def param_count(self) -> int:
        shapes, _ = (wh_abstract(self.cfg) if is_whisper(self.cfg)
                     else tf.abstract_params(self.cfg))
        import numpy as np
        return int(sum(np.prod(l.shape) for l in jax.tree.leaves(shapes)))

    def active_param_count(self) -> int:
        """MoE-aware active params per token (for 6·N_active·D)."""
        total = self.param_count()
        cfg = self.cfg
        if not cfg.moe_num_experts:
            return total
        shapes = (wh_abstract(cfg) if is_whisper(cfg)
                  else tf.abstract_params(cfg))[0]
        import numpy as np
        expert = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
            if "mlp" in keys and len(leaf.shape) == 3:   # (E, ., .) experts
                expert += int(np.prod(leaf.shape))
        inactive = expert * (1 - cfg.moe_top_k / cfg.moe_num_experts)
        return int(total - inactive)


def wh_abstract(cfg: ModelConfig):
    box = {}

    def capture(key):
        p, s = wh.init_params(cfg, key)
        box["s"] = s
        return p

    shapes = jax.eval_shape(capture, jax.random.PRNGKey(0))
    return shapes, box["s"]


def wh_cache_abstract(cfg: ModelConfig, B: int, s_max: int):
    L, H, Dh = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    f = cfg.adtype
    from repro.models.attention import KVCache
    return wh.WhisperCache(
        self_kv=KVCache(
            k=jax.ShapeDtypeStruct((L, B, s_max, H, Dh), f),
            v=jax.ShapeDtypeStruct((L, B, s_max, H, Dh), f)),
        cross_k=jax.ShapeDtypeStruct((L, B, cfg.encoder_seq, H, Dh), f),
        cross_v=jax.ShapeDtypeStruct((L, B, cfg.encoder_seq, H, Dh), f))


def all_cells(include_skipped: bool = False):
    """Every (arch × shape) cell of the assignment (40 total)."""
    out = []
    for arch_name in list_archs():
        a = Arch(arch_name)
        for sname, sspec in SHAPES.items():
            if a.supports(sname) or include_skipped:
                out.append((arch_name, sname))
    return out
