"""Whisper (tiny) — encoder-decoder with a stubbed conv/audio frontend.

Per the assignment, [audio] entries specify the transformer BACKBONE only:
``input_specs()`` feeds precomputed log-mel FRAME EMBEDDINGS (B, T_enc, D)
(the two conv layers + GELU that produce them are the stub), so the encoder
here is the bidirectional transformer stack, and the decoder is a standard
causal LM with cross-attention.

Faithfulness notes (DESIGN.md §Arch-applicability): LayerNorm + GELU MLP +
MHA per the paper; sinusoidal absolute positions for BOTH encoder and
decoder (Whisper learns the decoder's — a stub-level simplification);
decoder embeddings tied to the LM head as in the paper.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import (KeyGen, ModelConfig, apply_norm, dense_init,
                                 init_norm, shard, sinusoidal_positions)


def _init_gelu_mlp(cfg: ModelConfig, kg: KeyGen):
    D, F = cfg.d_model, cfg.d_ff
    p = {"w_up": dense_init(kg(), (D, F), cfg.pdtype),
         "b_up": jnp.zeros((F,), cfg.pdtype),
         "w_down": dense_init(kg(), (F, D), cfg.pdtype),
         "b_down": jnp.zeros((D,), cfg.pdtype)}
    s = {"w_up": ("embed", "ff"), "b_up": ("ff",),
         "w_down": ("ff", "embed"), "b_down": ("embed",)}
    return p, s


def _gelu_mlp(p, x):
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype)) \
        + p["b_up"].astype(x.dtype)
    h = jax.nn.gelu(h)
    h = shard(h, "batch", "seq", "ff")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype)) \
        + p["b_down"].astype(x.dtype)


def _init_enc_layer(cfg, key):
    kg = KeyGen(key)
    p, s = {}, {}
    p["norm1"], s["norm1"] = init_norm(cfg, kg)
    p["self"], s["self"] = attn.init_attention(cfg, kg)
    p["norm2"], s["norm2"] = init_norm(cfg, kg)
    p["mlp"], s["mlp"] = _init_gelu_mlp(cfg, kg)
    return p, s


def _init_dec_layer(cfg, key):
    kg = KeyGen(key)
    p, s = {}, {}
    p["norm1"], s["norm1"] = init_norm(cfg, kg)
    p["self"], s["self"] = attn.init_attention(cfg, kg)
    p["norm_x"], s["norm_x"] = init_norm(cfg, kg)
    p["cross"], s["cross"] = attn.init_attention(cfg, kg, cross=True)
    p["norm2"], s["norm2"] = init_norm(cfg, kg)
    p["mlp"], s["mlp"] = _init_gelu_mlp(cfg, kg)
    return p, s


def init_params(cfg: ModelConfig, key):
    kg = KeyGen(key)
    params: dict[str, Any] = {}
    pspecs: dict[str, Any] = {}
    params["embed"] = dense_init(kg(), (cfg.vocab_size, cfg.d_model),
                                 cfg.pdtype, scale=0.02)
    pspecs["embed"] = ("vocab", "embed")

    def stack(init_fn, n, k):
        keys = jax.random.split(k, n)
        stacked = jax.vmap(lambda kk: init_fn(cfg, kk)[0])(keys)
        spec = init_fn(cfg, keys[0])[1]
        spec = jax.tree.map(lambda ax: ("layers",) + tuple(ax), spec,
                            is_leaf=lambda x: isinstance(x, tuple))
        return stacked, spec

    params["enc"], pspecs["enc"] = stack(_init_enc_layer,
                                         cfg.encoder_layers, kg())
    params["dec"], pspecs["dec"] = stack(_init_dec_layer,
                                         cfg.num_layers, kg())
    params["enc_norm"], pspecs["enc_norm"] = init_norm(cfg, kg)
    params["dec_norm"], pspecs["dec_norm"] = init_norm(cfg, kg)
    return params, pspecs


def encode(params, frames, cfg: ModelConfig):
    """frames: (B, T_enc, D) stub embeddings -> encoder memory (B, T_enc, D)."""
    x = frames.astype(cfg.adtype)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                 (B, S))

    def layer(x, p):
        h = apply_norm(p["norm1"], x, cfg)
        out, _ = attn.attention(p["self"], h, cfg, positions=positions,
                                causal=False, use_rope=False)
        x = x + out
        h = apply_norm(p["norm2"], x, cfg)
        return x + _gelu_mlp(p["mlp"], h), None

    x, _ = jax.lax.scan(layer, x, params["enc"],
                        unroll=cfg.scan_unroll)
    return apply_norm(params["enc_norm"], x, cfg)


def decode_full(params, tokens, memory, cfg: ModelConfig):
    """Teacher-forced decoder pass. tokens (B, S); memory (B, T_enc, D)."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype)
    S = x.shape[1]
    x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)
    B = x.shape[0]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                 (B, S))
    mem_pos = jnp.broadcast_to(
        jnp.arange(memory.shape[1], dtype=jnp.int32)[None],
        (B, memory.shape[1]))

    def layer(x, p):
        h = apply_norm(p["norm1"], x, cfg)
        out, _ = attn.attention(p["self"], h, cfg, positions=positions,
                                causal=True, use_rope=False)
        x = x + out
        h = apply_norm(p["norm_x"], x, cfg)
        out, _ = attn.attention(p["cross"], h, cfg, positions=positions,
                                causal=False, use_rope=False,
                                xkv=memory, kv_positions=mem_pos)
        x = x + out
        h = apply_norm(p["norm2"], x, cfg)
        return x + _gelu_mlp(p["mlp"], h), None

    x, _ = jax.lax.scan(layer, x, params["dec"],
                        unroll=cfg.scan_unroll)
    x = apply_norm(params["dec_norm"], x, cfg)
    logits = jnp.einsum("bsd,vd->bsv", x,
                        params["embed"].astype(x.dtype))
    return shard(logits, "batch", "seq", "vocab")


def forward(params, batch, cfg: ModelConfig, remat: bool = True):
    """batch: {"embeds": (B,T_enc,D) frames, "tokens": (B,S)}."""
    memory = encode(params, batch["embeds"], cfg)
    logits = decode_full(params, batch["tokens"], memory, cfg)
    return logits, {}


def next_token_loss(params, batch, cfg: ModelConfig, remat: bool = True):
    logits, aux = forward(params, batch, cfg, remat)
    logits = logits[:, :-1, :].astype(jnp.float32)
    targets = batch["labels"][:, 1:]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(logz - tgt)
    aux["nll"] = loss
    return loss, aux


# --------------------------- serving path ----------------------------------

class WhisperCache(NamedTuple):
    self_kv: attn.KVCache          # stacked (L, B, S_max, H, Dh)
    cross_k: jax.Array             # (L, B, T_enc, H, Dh) — precomputed
    cross_v: jax.Array


def prefill(params, batch, cfg: ModelConfig, s_max: int | None = None):
    """Encode audio stub + run the prompt tokens; build decoder cache."""
    memory = encode(params, batch["embeds"], cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    s_max = s_max or S
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype)
    x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                 (B, S))
    mem_pos = jnp.broadcast_to(
        jnp.arange(memory.shape[1], dtype=jnp.int32)[None],
        (B, memory.shape[1]))

    def layer(x, p):
        h = apply_norm(p["norm1"], x, cfg)
        out, kv = attn.attention(p["self"], h, cfg, positions=positions,
                                 causal=True, use_rope=False)
        x = x + out
        h = apply_norm(p["norm_x"], x, cfg)
        out, xkv = attn.attention(p["cross"], h, cfg, positions=positions,
                                  causal=False, use_rope=False,
                                  xkv=memory, kv_positions=mem_pos)
        x = x + out
        h = apply_norm(p["norm2"], x, cfg)
        return x + _gelu_mlp(p["mlp"], h), (kv, xkv)

    x, (self_kv, cross_kv) = jax.lax.scan(
        layer, x, params["dec"], unroll=cfg.scan_unroll)
    x = apply_norm(params["dec_norm"], x, cfg)
    logits = jnp.einsum("bsd,vd->bsv", x[:, -1:, :],
                        params["embed"].astype(x.dtype))
    pad = ((0, 0), (0, 0), (0, s_max - S), (0, 0), (0, 0))
    cache = WhisperCache(
        self_kv=attn.KVCache(jnp.pad(self_kv.k, pad),
                             jnp.pad(self_kv.v, pad)),
        cross_k=cross_kv.k, cross_v=cross_kv.v)
    return logits, cache


def decode_step(params, batch, cache: WhisperCache, pos, cfg: ModelConfig):
    """One decoder token against (self cache, precomputed cross K/V)."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype)
    S_max = cache.self_kv.k.shape[2]
    pe = sinusoidal_positions(S_max, cfg.d_model)
    x = x + pe[pos][:, None, :].astype(x.dtype)

    T_enc = cache.cross_k.shape[2]
    mem_pos = jnp.broadcast_to(jnp.arange(T_enc, dtype=jnp.int32)[None],
                               (B, T_enc))

    def layer(x, scanned):
        p, kv, ck, cv = scanned
        h = apply_norm(p["norm1"], x, cfg)
        out, new_kv = attn.decode_attention(p["self"], h, kv, pos, cfg,
                                            use_rope=False)
        x = x + out
        h = apply_norm(p["norm_x"], x, cfg)
        # cross attention reads the precomputed memory K/V directly
        q, _, _ = attn._project_qkv(p["cross"], h, h, cfg)
        out = attn._attend(q, ck.astype(h.dtype), cv.astype(h.dtype), cfg,
                           pos[:, None], mem_pos, causal=False, window=None)
        out = jnp.einsum("bshk,hkd->bsd", out,
                         p["cross"]["wo"].astype(h.dtype))
        x = x + out
        h = apply_norm(p["norm2"], x, cfg)
        return x + _gelu_mlp(p["mlp"], h), new_kv

    x, new_kv = jax.lax.scan(
        layer, x, (params["dec"], cache.self_kv, cache.cross_k,
                   cache.cross_v), unroll=cfg.scan_unroll)
    x = apply_norm(params["dec_norm"], x, cfg)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    return logits, cache._replace(self_kv=new_kv)
