"""Model zoo: config-driven implementations of the 10 assigned archs."""
from repro.models.common import ModelConfig, set_rules, get_rules  # noqa: F401
from repro.models.registry import Arch, SHAPES, all_cells, LONG_CONTEXT_SKIP  # noqa: F401
