"""GQA attention with every assigned-zoo variation:

* grouped KV heads (all archs), optional QKV bias (qwen2),
* sliding-window masking (mixtral, gemma2 local layers),
* attention-logit softcapping (gemma2),
* RoPE / M-RoPE / no-PE (whisper uses absolute sinusoidal at embed time),
* bidirectional mode (whisper encoder), cross-attention (whisper decoder),
* decode mode against a KV cache (one new token, arbitrary cache length).

Shapes: x (B, S, D);  q (B, S, H, Dh);  kv (B, S, Hk, Dh);  Hk | H.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import (KeyGen, ModelConfig, apply_mrope,
                                 apply_rope, dense_init, shard, softcap)


def init_attention(cfg: ModelConfig, kg: KeyGen, cross: bool = False):
    D, H, Hk, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(kg(), (D, H, Dh), cfg.pdtype),
        "wk": dense_init(kg(), (D, Hk, Dh), cfg.pdtype),
        "wv": dense_init(kg(), (D, Hk, Dh), cfg.pdtype),
        "wo": dense_init(kg(), (H, Dh, D), cfg.pdtype),
    }
    s = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, Dh), cfg.pdtype)
        p["bk"] = jnp.zeros((Hk, Dh), cfg.pdtype)
        p["bv"] = jnp.zeros((Hk, Dh), cfg.pdtype)
        s["bq"] = ("heads", "head_dim")
        s["bk"] = ("kv_heads", "head_dim")
        s["bv"] = ("kv_heads", "head_dim")
    return p, s


class KVCache(NamedTuple):
    k: jax.Array          # (B, S_max, Hk, Dh)
    v: jax.Array          # (B, S_max, Hk, Dh)


def _project_qkv(p, x, xkv, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return q, k, v


def make_rope_tables(positions, cfg: ModelConfig, dim: int):
    """Precompute (cos, sin) (B, S, dim/2) ONCE per forward — computing them
    per layer gets stacked across the superblock scan by loop hoisting.

    positions: (B, S) int32, or (3, B, S) for M-RoPE.
    """
    from repro.models.common import rope_freqs
    half = dim // 2
    inv = rope_freqs(cfg, dim)                               # (half,)
    if cfg.mrope_sections is not None:
        sec = cfg.mrope_sections
        sect_id = jnp.repeat(jnp.arange(3), jnp.asarray(sec),
                             total_repeat_length=half)       # (half,)
        pos = jnp.take(positions, sect_id, axis=0)           # (half, B, S)
        pos = jnp.moveaxis(pos, 0, -1).astype(jnp.float32)   # (B, S, half)
    else:
        pos = positions.astype(jnp.float32)[..., None]       # (B, S, 1)
    ang = pos * inv
    return jnp.cos(ang), jnp.sin(ang)


def _apply_tables(x, cos, sin):
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out


def _pe(q, k, positions, kv_positions, cfg: ModelConfig, use_rope: bool,
        rope_tables=None, kv_rope_tables=None):
    if not use_rope:
        return q, k
    if rope_tables is None:
        rope_tables = make_rope_tables(positions, cfg, q.shape[-1])
    if kv_rope_tables is None:
        kv_rope_tables = rope_tables if kv_positions is positions \
            else make_rope_tables(kv_positions, cfg, k.shape[-1])
    q = _apply_tables(q, *rope_tables).astype(q.dtype)
    k = _apply_tables(k, *kv_rope_tables).astype(k.dtype)
    return q, k


def _scores_mask(scores, q_pos, k_pos, causal: bool, window: int | None,
                 k_valid=None):
    """scores (B, H, Sq, Sk); q_pos (B, Sq), k_pos (B, Sk) absolute."""
    neg = jnp.finfo(scores.dtype).min
    mask = jnp.ones((), bool)
    dq = q_pos[:, None, :, None]
    dk = k_pos[:, None, None, :]
    if causal:
        mask = dk <= dq
    if window is not None:
        mask = jnp.logical_and(mask, dk > dq - window)
    if k_valid is not None:
        mask = jnp.logical_and(mask, k_valid[:, None, None, :])
    return jnp.where(mask, scores, neg)


CHUNK_THRESHOLD = 8192   # q-chunk the score matrix beyond this Sq
Q_CHUNK = 512


def _attend_dense(q, k, v, cfg: ModelConfig, q_pos, k_pos, causal, window,
                  k_valid=None):
    """GQA via KV-head REPET (k/v broadcast to H heads), NOT q-grouping.

    Grouping q as (B,S,Hk,rep,Dh) reshapes the model-sharded H dim into
    (Hk, rep); whenever the mesh's model size does not divide Hk, GSPMD
    must fully replicate the tensor (multi-GB "involuntary full
    rematerialization" gathers in every layer — §Perf iteration B2).
    Repeating kv keeps every einsum's head dim = H, which shards cleanly;
    head h = hk·rep + r pairs with kv head hk, exactly the grouped maths.
    XLA fuses the broadcast into the matmul, so no materialised copy.
    """
    B, Sq, H, Dh = q.shape
    Hk = k.shape[2]
    rep = H // Hk
    scale = cfg.query_scale if cfg.query_scale is not None \
        else 1.0 / math.sqrt(Dh)
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q * scale, k)
    scores = softcap(scores, cfg.attn_logit_softcap)
    scores = _scores_mask(scores, q_pos, k_pos, causal, window, k_valid)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(q.dtype), v)
    return out


def _attend(q, k, v, cfg: ModelConfig, q_pos, k_pos, causal, window,
            k_valid=None):
    """Dispatch: dense scores for short Sq; q-chunked (flash-style memory
    bound: O(B·H·chunk·Sk) live scores) for long prefills."""
    B, Sq, H, Dh = q.shape
    if Sq <= cfg.q_chunk_threshold or Sq % Q_CHUNK != 0:
        return _attend_dense(q, k, v, cfg, q_pos, k_pos, causal, window,
                             k_valid)

    nc = Sq // Q_CHUNK

    def one_chunk(i):
        sl = lambda x: jax.lax.dynamic_slice_in_dim(x, i * Q_CHUNK,
                                                    Q_CHUNK, axis=1)
        return _attend_dense(sl(q), k, v, cfg, sl(q_pos), k_pos, causal,
                             window, k_valid)

    if cfg.unroll_q_chunks:
        # static unroll: every chunk appears in HLO — exact cost_analysis
        # accounting for the dry-run probes (lax.map bodies count once)
        outs = [one_chunk(jnp.asarray(i)) for i in range(nc)]
        return jnp.concatenate(outs, axis=1)

    chunks = jax.lax.map(one_chunk, jnp.arange(nc))   # (nc, B, cq, H, Dh)
    out = jnp.moveaxis(chunks, 0, 1).reshape(B, Sq, H, Dh)
    return out


def attention(p, x, cfg: ModelConfig, *, positions, layer_kind: str = "attn",
              causal: bool = True, use_rope: bool = True,
              xkv=None, kv_positions=None, k_valid=None, rope_tables=None):
    """Full (training / prefill / encoder / cross) attention.

    xkv: memory stream for cross-attention (defaults to x).
    ``positions`` drive the PE (may be (3,B,S) for M-RoPE); MASKING always
    uses plain slot indices, which for M-RoPE differ from the t-positions.
    Returns (B, S, D) plus the (k, v) tensors for cache construction.
    """
    xkv = x if xkv is None else xkv
    kv_positions = positions if kv_positions is None else kv_positions
    q, k, v = _project_qkv(p, x, xkv, cfg)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")
    q, k = _pe(q, k, positions, kv_positions, cfg, use_rope,
               rope_tables=rope_tables)

    B, Sq = x.shape[0], x.shape[1]
    Sk = xkv.shape[1]
    mask_q = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32)[None], (B, Sq))
    mask_k = jnp.broadcast_to(jnp.arange(Sk, dtype=jnp.int32)[None], (B, Sk))
    window = cfg.sliding_window if layer_kind == "swa" else None
    out = _attend(q, k, v, cfg, mask_q, mask_k, causal, window, k_valid)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return shard(out, "batch", "seq", "embed"), KVCache(k, v)


def decode_attention(p, x, cache: KVCache, pos: jax.Array,
                     cfg: ModelConfig, *, layer_kind: str = "attn",
                     use_rope: bool = True):
    """One-token decode against a cache.

    x: (B, 1, D); pos: (B,) int32 absolute position of the new token (for
    M-RoPE: (3, B)).  Cache slots ≥ pos are invalid (k_valid mask).

    RING-BUFFER mode (§Perf iteration B4): for sliding-window layers, a
    cache with S_max ≤ window is treated as a ring — the new token writes
    slot pos % S_max, and each slot's ABSOLUTE position is reconstructed
    for masking.  An SWA layer only ever attends to the last `window`
    tokens, so ring(window) ≡ full cache exactly, at window/seq_len the
    memory (8× for mixtral decode_32k).

    Returns (out (B,1,D), updated cache).
    """
    B = x.shape[0]
    S_max = cache.k.shape[1]
    if cfg.mrope_sections is not None:
        positions = pos[:, :, None]            # (3, B, 1)
        scalar_pos = pos[0]                     # text stream drives slots
    else:
        positions = pos[:, None]                # (B, 1)
        scalar_pos = pos
    q, k_new, v_new = _project_qkv(p, x, x, cfg)
    q, k_new = _pe(q, k_new, positions, positions, cfg, use_rope)

    ring = (layer_kind == "swa" and cfg.sliding_window is not None
            and S_max <= cfg.sliding_window)
    slot = scalar_pos % S_max if ring else scalar_pos

    # write the new kv at its slot (one-hot blend per batch row)
    def write(buf, new):
        oh = jax.nn.one_hot(slot, S_max, dtype=buf.dtype)    # (B, S)
        return buf * (1 - oh[:, :, None, None]) + \
            new.astype(buf.dtype) * oh[:, :, None, None]

    k = write(cache.k, k_new)
    v = write(cache.v, v_new)

    idx = jnp.arange(S_max, dtype=jnp.int32)[None, :]        # (1, S_max)
    if ring:
        # absolute position held by each ring slot after this write:
        # abs = pos − ((pos − slot_idx) mod S_max)  ∈ (pos − S_max, pos]
        k_pos = scalar_pos[:, None] - \
            jnp.mod(scalar_pos[:, None] - idx, S_max)
        k_valid = k_pos >= 0                                  # unwritten<0
        window = None       # ring residency already enforces the window
    else:
        k_pos = jnp.broadcast_to(idx, (B, S_max))
        k_valid = k_pos <= scalar_pos[:, None]
        window = cfg.sliding_window if layer_kind == "swa" else None
    out = _attend(q, k.astype(x.dtype), v.astype(x.dtype), cfg,
                  scalar_pos[:, None], k_pos, causal=False, window=window,
                  k_valid=k_valid)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, KVCache(k, v)
