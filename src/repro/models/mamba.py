"""Mamba (selective SSM) block — Jamba's sequence mixer (arXiv:2403.19887).

Selective state space: per token, input-dependent (Δ, B, C) select what the
state keeps;  h_t = exp(Δ_t·A)·h_{t-1} + Δ_t·B_t·x_t,  y_t = C_t·h_t + D·x_t.

Two execution paths sharing parameters:
* ``mamba_scan``: full-sequence training/prefill via ``jax.lax.scan`` over
  time (HLO size O(1) in seq — the priority on this container; a chunked
  parallel scan is a recorded §Perf candidate for real-TPU throughput).
* ``mamba_step``: O(1) decode update carrying (conv window, ssm state).

Jamba uses inner RMSNorm on the SSM branch (their stabilization trick) —
included.  d_inner = expand·d_model; heads are channel-wise (Mamba-1).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import KeyGen, ModelConfig, dense_init, shard


class MambaState(NamedTuple):
    conv: jax.Array    # (B, d_conv-1, d_inner) trailing window
    ssm: jax.Array     # (B, d_inner, d_state)


def _dims(cfg: ModelConfig):
    d_inner = cfg.mamba_expand * cfg.d_model
    dt_rank = max(cfg.d_model // 16, 1)
    return d_inner, dt_rank, cfg.mamba_d_state, cfg.mamba_d_conv


def init_mamba(cfg: ModelConfig, kg: KeyGen):
    D = cfg.d_model
    d_inner, dt_rank, N, Kc = _dims(cfg)
    p = {
        "in_proj": dense_init(kg(), (D, 2 * d_inner), cfg.pdtype),
        "conv_w": dense_init(kg(), (Kc, d_inner), cfg.pdtype, scale=0.5),
        "conv_b": jnp.zeros((d_inner,), cfg.pdtype),
        "x_proj": dense_init(kg(), (d_inner, dt_rank + 2 * N), cfg.pdtype),
        "dt_proj_w": dense_init(kg(), (dt_rank, d_inner), cfg.pdtype),
        "dt_proj_b": jnp.asarray(
            jnp.log(jnp.expm1(jnp.full((d_inner,), 0.01))), cfg.pdtype),
        # A init: -[1..N] per channel (S4D-real), stored as log
        "A_log": jnp.broadcast_to(
            jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32)),
            (d_inner, N)).astype(cfg.pdtype),
        "D": jnp.ones((d_inner,), cfg.pdtype),
        "norm_scale": jnp.ones((d_inner,), cfg.pdtype),   # jamba inner norm
        "out_proj": dense_init(kg(), (d_inner, D), cfg.pdtype),
    }
    s = {
        "in_proj": ("embed", "ff"),
        "conv_w": ("conv", "ff"),
        "conv_b": ("ff",),
        "x_proj": ("ff", None),
        "dt_proj_w": (None, "ff"),
        "dt_proj_b": ("ff",),
        "A_log": ("ff", "state"),
        "D": ("ff",),
        "norm_scale": ("ff",),
        "out_proj": ("ff", "embed"),
    }
    return p, s


def _ssm_inputs(p, xz, cfg: ModelConfig):
    """Shared front half: split, activation; returns (x_conv_in, z)."""
    d_inner, *_ = _dims(cfg)
    x, z = jnp.split(xz, 2, axis=-1)
    return x, z


def _selective_params(p, x, cfg: ModelConfig):
    """x: (..., d_inner) -> (delta, B, C). delta (..., d_inner); B/C (..., N)."""
    d_inner, dt_rank, N, _ = _dims(cfg)
    proj = jnp.einsum("...i,ir->...r", x, p["x_proj"].astype(x.dtype))
    dt, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("...r,ri->...i", dt, p["dt_proj_w"].astype(x.dtype))
        + p["dt_proj_b"].astype(x.dtype))
    return delta, Bm, Cm


def _inner_norm(p, y, cfg: ModelConfig):
    y32 = y.astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(y32 * y32, -1, keepdims=True) + cfg.norm_eps)
    return (y32 / rms * p["norm_scale"].astype(jnp.float32)).astype(y.dtype)


def mamba_scan(p, xin, cfg: ModelConfig, time_chunk: int | None = None):
    """Full-sequence pass.  xin: (B, S, D) -> (B, S, D), final MambaState.

    Memory discipline: the recurrence runs as an outer scan over TIME
    CHUNKS with a checkpointed inner scan, and dA/dBx (B, i, N) tensors are
    formed per-step INSIDE the scan.  AD therefore saves only the
    chunk-boundary states (S/chunk × B·i·N) instead of every step's —
    without this, one 4k-seq jamba layer would save ~2 GB of hidden states.
    """
    B, S, D = xin.shape
    d_inner, dt_rank, N, Kc = _dims(cfg)
    xz = jnp.einsum("bsd,di->bsi", xin, p["in_proj"].astype(xin.dtype))
    x, z = _ssm_inputs(p, xz, cfg)

    # causal depthwise conv over time (window Kc)
    xpad = jnp.pad(x, ((0, 0), (Kc - 1, 0), (0, 0)))
    conv = sum(xpad[:, i:i + S, :] * p["conv_w"][i].astype(x.dtype)
               for i in range(Kc)) + p["conv_b"].astype(x.dtype)
    xc = jax.nn.silu(conv)
    xc = shard(xc, "batch", "seq", "ff")

    delta, Bm, Cm = _selective_params(p, xc, cfg)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (d_inner, N)

    ck = min(time_chunk or cfg.time_chunk, S)
    assert S % ck == 0, (S, ck)
    nch = S // ck

    def tm(t):  # (B, S, F) -> (nch, ck, B, F) time-major chunks
        return jnp.moveaxis(t, 1, 0).reshape(nch, ck, B, t.shape[-1])

    xs = (tm(delta.astype(jnp.float32)), tm(Bm.astype(jnp.float32)),
          tm(Cm.astype(jnp.float32)), tm(xc.astype(jnp.float32)))

    def step(h, xt):
        d_t, b_t, c_t, x_t = xt                                # (B, ·)
        dA = jnp.exp(d_t[..., None] * A)                       # (B, i, N)
        dBx = (d_t * x_t)[..., None] * b_t[:, None, :]
        h = dA * h + dBx
        y_t = jnp.einsum("bin,bn->bi", h, c_t)
        return h, y_t

    @jax.checkpoint
    def chunk_fn(h, xs_chunk):
        return jax.lax.scan(step, h, xs_chunk)

    h0 = jnp.zeros((B, d_inner, N), jnp.float32)
    h_last, ys = jax.lax.scan(chunk_fn, h0, xs)                # (nch,ck,B,i)
    y = jnp.moveaxis(ys.reshape(S, B, d_inner), 0, 1).astype(xin.dtype)
    y = y + xc * p["D"].astype(xin.dtype)
    y = _inner_norm(p, y, cfg) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(xin.dtype))

    state = MambaState(conv=x[:, S - (Kc - 1):, :], ssm=h_last)
    return shard(out, "batch", "seq", "embed"), state


def mamba_step(p, xin, state: MambaState, cfg: ModelConfig):
    """Decode: xin (B, 1, D) -> (B, 1, D), new state.  O(1) in context."""
    B = xin.shape[0]
    d_inner, dt_rank, N, Kc = _dims(cfg)
    xz = jnp.einsum("bsd,di->bsi", xin, p["in_proj"].astype(xin.dtype))
    x, z = _ssm_inputs(p, xz, cfg)                 # (B, 1, i)

    window = jnp.concatenate([state.conv.astype(x.dtype), x], axis=1)
    conv = jnp.einsum("bki,ki->bi", window, p["conv_w"].astype(x.dtype)) \
        + p["conv_b"].astype(x.dtype)
    xc = jax.nn.silu(conv)[:, None, :]             # (B, 1, i)

    delta, Bm, Cm = _selective_params(p, xc, cfg)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(delta.astype(jnp.float32)[..., None] * A)[:, 0]   # (B,i,N)
    dBx = ((delta * xc).astype(jnp.float32)[..., None]
           * Bm.astype(jnp.float32)[..., None, :])[:, 0]
    h = dA * state.ssm + dBx
    y = jnp.einsum("bin,bn->bi", h, Cm[:, 0].astype(jnp.float32))
    y = y[:, None, :].astype(xin.dtype) + xc * p["D"].astype(xin.dtype)
    y = _inner_norm(p, y, cfg) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(xin.dtype))
    return out, MambaState(conv=window[:, 1:, :], ssm=h)


def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> MambaState:
    d_inner, _, N, Kc = _dims(cfg)
    return MambaState(conv=jnp.zeros((batch, Kc - 1, d_inner), dtype),
                      ssm=jnp.zeros((batch, d_inner, N), jnp.float32))
