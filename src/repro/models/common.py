"""Model-zoo substrate: config schema, logical-axis sharding, shared layers.

Design notes
------------
* Pure-functional: params are plain dict pytrees; every init function
  returns ``(params, pspecs)`` where ``pspecs`` mirrors params with
  ``PartitionSpec`` leaves derived from LOGICAL axis names via a rules
  table — the MaxText pattern, so one model definition serves any mesh.
* Layers are grouped into repeated "super-blocks" and scanned
  (``jax.lax.scan``) so the HLO size is independent of depth — essential
  for compiling 88-layer models on this container, and standard practice
  at scale.
* Mixed precision: params live in float32 (or bf16 for dry-runs), activations
  are computed in ``cfg.dtype``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@jax.custom_vjp
def opt_barrier(tree):
    """``jax.lax.optimization_barrier`` that stays differentiable.

    jax<0.5 has no differentiation rules for the barrier primitive; this
    wrapper supplies the upstream behaviour (barrier the primal on the way
    forward, the cotangent on the way back) so remat'd scans keep their
    anti-hoisting barrier under grad on the pinned 0.4.x line and behave
    identically on newer jax.
    """
    return jax.lax.optimization_barrier(tree)


def _opt_barrier_fwd(tree):
    return opt_barrier(tree), None


def _opt_barrier_bwd(_, ct):
    return (jax.lax.optimization_barrier(ct),)


opt_barrier.defvjp(_opt_barrier_fwd, _opt_barrier_bwd)


# ---------------------------------------------------------------------------
# Logical-axis sharding rules
# ---------------------------------------------------------------------------
# Logical axis vocabulary used across the zoo:
#   batch, seq, embed, heads, kv_heads, head_dim, ff, vocab,
#   experts, capacity, conv, state
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "cache_seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "experts": None,
    "capacity": ("pod", "data"),
    "conv": None,
    "state": None,
    "layers": None,   # stacked scan dim — never sharded
}

_ACTIVE_RULES: dict[str, Any] = dict(DEFAULT_RULES)


def set_rules(rules: dict[str, Any]) -> None:
    """Install the active logical→mesh rules (launcher calls this)."""
    _ACTIVE_RULES.clear()
    _ACTIVE_RULES.update(DEFAULT_RULES)
    _ACTIVE_RULES.update(rules)


def get_rules() -> dict[str, Any]:
    return dict(_ACTIVE_RULES)


def logical_to_pspec(axes: tuple[str | None, ...],
                     rules: dict[str, Any] | None = None) -> P:
    """('layers','embed','ff') -> PartitionSpec(None, None, 'model')."""
    rules = rules if rules is not None else _ACTIVE_RULES
    out = []
    for a in axes:
        out.append(None if a is None else rules.get(a))
    # trim trailing Nones for cleanliness
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """Annotate an activation with its logical sharding (no-op off-mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, logical_to_pspec(axes))
    except (ValueError, RuntimeError):
        return x  # no mesh context (unit tests on 1 device)


# ---------------------------------------------------------------------------
# Config schema — one dataclass covers the whole assigned zoo.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # layer pattern: one entry per layer in the super-block, e.g.
    # ("attn",) dense; ("swa", "attn") gemma2; ("mamba",)*7+("attn",) jamba.
    block_pattern: tuple[str, ...] = ("attn",)

    # attention variations
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int | None = None          # for "swa" layers
    attn_logit_softcap: float | None = None
    final_logit_softcap: float | None = None
    query_scale: float | None = None           # None -> 1/sqrt(head_dim)
    mrope_sections: tuple[int, int, int] | None = None  # qwen2-vl M-RoPE

    # norm / embedding
    norm_type: str = "rmsnorm"                 # rmsnorm | layernorm | nonparam_ln
    norm_eps: float = 1e-5
    scale_embeddings: bool = False             # gemma2: x *= sqrt(d_model)
    embed_norm: bool = False                   # rwkv ln0 (post-embedding LN)
    tie_embeddings: bool = False
    post_block_norm: bool = False              # gemma2 sandwich norms

    # MLP / MoE
    mlp_type: str = "swiglu"                   # swiglu | relu2 (rwkv)
    moe_num_experts: int | None = None
    moe_top_k: int = 2
    moe_layer_period: int = 1                  # jamba: MoE every 2nd layer
    moe_capacity_factor: float = 1.25

    # mamba (jamba)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # rwkv6
    rwkv_head_dim: int = 64
    rwkv_lora_rank: int = 64
    rwkv_decay_lora_rank: int = 64

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500                    # whisper frame count (stub)

    # input mode: "tokens" (LM) or "embeds" (vlm/audio frontend stubs)
    input_mode: str = "tokens"

    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # --- lowering/cost knobs (semantics-neutral; see launch/dryrun.py) ---
    scan_unroll: int = 1          # lax.scan unroll for the superblock scan
    time_chunk: int = 256         # mamba/rwkv recurrence chunk (remat unit)
    q_chunk_threshold: int = 8192  # q-chunk attention beyond this Sq
    unroll_q_chunks: bool = False  # python-unroll the q-chunk loop (exact
                                   # HLO cost counting in dry-run probes)

    @property
    def num_superblocks(self) -> int:
        assert self.num_layers % len(self.block_pattern) == 0, (
            self.name, self.num_layers, self.block_pattern)
        return self.num_layers // len(self.block_pattern)

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline numbers)."""
        n = count_params_tree(None, self)  # placeholder: computed elsewhere
        return n


def count_params_tree(params, cfg) -> int:
    if params is None:
        return 0
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(params)))


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 1 else 1
    std = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (std * jax.random.truncated_normal(
        key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


class KeyGen:
    """Splittable key stream so init code reads linearly."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, kg: KeyGen):
    if cfg.norm_type == "rmsnorm":
        return {"scale": jnp.ones((cfg.d_model,), cfg.pdtype)}, \
               {"scale": ("embed",)}
    if cfg.norm_type == "layernorm":      # rwkv / whisper
        return ({"scale": jnp.ones((cfg.d_model,), cfg.pdtype),
                 "bias": jnp.zeros((cfg.d_model,), cfg.pdtype)},
                {"scale": ("embed",), "bias": ("embed",)})
    # olmo: non-parametric layernorm — no params at all
    return {}, {}


def apply_norm(p, x, cfg: ModelConfig):
    x32 = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        rms = jnp.sqrt(jnp.mean(x32 * x32, -1, keepdims=True) + cfg.norm_eps)
        out = x32 / rms * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(x32, -1, keepdims=True)
        var = jnp.var(x32, -1, keepdims=True)
        out = (x32 - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        if cfg.norm_type == "layernorm":
            out = out * p["scale"].astype(jnp.float32) \
                + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(cfg: ModelConfig, dim: int) -> jax.Array:
    half = dim // 2
    return 1.0 / (cfg.rope_theta ** (jnp.arange(0, half, dtype=jnp.float32)
                                     / half))


def apply_rope(x: jax.Array, positions: jax.Array,
               cfg: ModelConfig) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32 -> same shape, rotated.

    Rotate-half convention (LLaMA/Mistral/Qwen style).
    """
    d = x.shape[-1]
    inv = rope_freqs(cfg, d)                                   # (d/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv       # (B, S, d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array,
                cfg: ModelConfig) -> jax.Array:
    """Qwen2-VL multimodal RoPE.  positions3: (3, B, S) for (t, h, w).

    The head_dim/2 frequency slots are split into three contiguous sections
    (cfg.mrope_sections, summing to head_dim/2); each section takes its
    angle from the corresponding positional stream.
    """
    d = x.shape[-1]
    half = d // 2
    sec = cfg.mrope_sections
    assert sec is not None and sum(sec) == half, (sec, half)
    inv = rope_freqs(cfg, d)                                  # (half,)
    # build a per-slot position by selecting the stream for its section
    sect_id = jnp.repeat(jnp.arange(3), jnp.asarray(sec),
                         total_repeat_length=half)            # (half,)
    # (B, S, half): gather positions per slot
    pos = jnp.take(positions3, sect_id, axis=0)               # (half, B, S)
    pos = jnp.moveaxis(pos, 0, -1).astype(jnp.float32)        # (B, S, half)
    ang = pos * inv
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (S, D)."""
    half = dim // 2
    pos = np.arange(seq)[:, None]
    freq = np.exp(-np.log(10000.0) * np.arange(half) / max(half - 1, 1))
    ang = pos * freq[None, :]
    return jnp.asarray(
        np.concatenate([np.sin(ang), np.cos(ang)], axis=1), jnp.float32)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
