"""MLP blocks: SwiGLU (dense), relu² (rwkv channel-mix handled in rwkv6.py),
and GShard-style top-k MoE (mixtral, jamba).

MoE sharding story (see DESIGN.md §4): router + dispatch are computed on
data-sharded tokens; dispatched activations (E, C, D) carry the capacity
axis on ("pod","data") and expert FFN hidden on "model" (EP×TP).  The
dispatch/combine einsums thus induce the all-to-all under SPMD — the
collective that §Roofline attributes to MoE cells.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import KeyGen, ModelConfig, dense_init, shard


# ---------------------------------------------------------------------------
# Dense SwiGLU
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, kg: KeyGen):
    D, F = cfg.d_model, cfg.d_ff
    p = {
        "w_gate": dense_init(kg(), (D, F), cfg.pdtype),
        "w_up": dense_init(kg(), (D, F), cfg.pdtype),
        "w_down": dense_init(kg(), (F, D), cfg.pdtype),
    }
    s = {
        "w_gate": ("embed", "ff"),
        "w_up": ("embed", "ff"),
        "w_down": ("ff", "embed"),
    }
    return p, s


def mlp(p, x, cfg: ModelConfig):
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    h = shard(h, "batch", "seq", "ff")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))


# ---------------------------------------------------------------------------
# MoE (mixtral / jamba): top-k routing + capacity-bounded dispatch einsums
# ---------------------------------------------------------------------------

def init_moe(cfg: ModelConfig, kg: KeyGen):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.moe_num_experts
    p = {
        "router": dense_init(kg(), (D, E), cfg.pdtype),
        "w_gate": dense_init(kg(), (E, D, F), cfg.pdtype),
        "w_up": dense_init(kg(), (E, D, F), cfg.pdtype),
        "w_down": dense_init(kg(), (E, F, D), cfg.pdtype),
    }
    s = {
        "router": ("embed", "experts"),
        "w_gate": ("experts", "embed", "ff"),
        "w_up": ("experts", "embed", "ff"),
        "w_down": ("experts", "ff", "embed"),
    }
    return p, s


def moe(p, x, cfg: ModelConfig, capacity_factor: float | None = None,
        group_size: int = 4096):
    """x: (B, S, D) -> (B, S, D), plus aux losses dict.

    GROUPED GShard dispatch: tokens are split into groups of ≤ group_size
    contiguous tokens, each with its own capacity buffer, so the one-hot
    dispatch/combine tensors are (G, Tg, E, Cg) — O(T·E·Cg) with Cg fixed,
    instead of the O(T²·E) a single global capacity would cost.  Groups
    shard over the batch axes; expert FFN hidden shards over "model"
    (dense-dispatch + TP; a2a-based EP is a recorded §Perf candidate).

    top-k gate probs are softmaxed over the selected logits (mixtral
    convention); tokens over a group's capacity are dropped.
    """
    B, S, D = x.shape
    E, K = cfg.moe_num_experts, cfg.moe_top_k
    cf = capacity_factor or cfg.moe_capacity_factor
    T = B * S
    Tg = min(group_size, T)
    assert T % Tg == 0, (T, Tg)
    G = T // Tg
    C = max(int(cf * Tg * K / E), 1)
    xt = x.reshape(G, Tg, D)
    xt = shard(xt, "batch", None, "embed")

    logits = jnp.einsum("gtd,de->gte", xt, p["router"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    top_val, top_idx = jax.lax.top_k(logits, K)              # (G, Tg, K)
    gates = jax.nn.softmax(top_val, axis=-1)

    # position of each (token, k) inside its expert's per-group buffer
    expert_onehot = jax.nn.one_hot(top_idx, E, dtype=jnp.int32)  # (G,Tg,K,E)
    flat = expert_onehot.reshape(G, Tg * K, E)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(
        G, Tg, K, E)
    pos = jnp.sum(pos_in_expert * expert_onehot, axis=-1)    # (G, Tg, K)
    keep = pos < C                                           # capacity drop

    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C, dtype=x.dtype)
    disp = jnp.einsum("gtke,gtkc->gtec",
                      expert_onehot.astype(x.dtype), pos_oh)
    comb = jnp.einsum("gtke,gtkc,gtk->gtec",
                      expert_onehot.astype(jnp.float32),
                      pos_oh.astype(jnp.float32), gates).astype(x.dtype)

    xe = jnp.einsum("gtec,gtd->gecd", disp, xt)              # (G, E, C, D)
    xe = shard(xe, "batch", "experts", None, "embed")
    g = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    h = shard(h, "batch", "experts", None, "ff")
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))
    out = jnp.einsum("gtec,gecd->gtd", comb, ye).reshape(B, S, D)

    # load-balance aux loss (Switch/GShard): E * Σ_e f_e · p_e
    me = jnp.mean(jax.nn.softmax(logits, -1), axis=(0, 1))          # (E,)
    ce = jnp.mean(expert_onehot[:, :, 0, :].astype(jnp.float32),
                  axis=(0, 1))                                      # top-1
    aux = {"moe_load_balance": E * jnp.sum(me * ce),
           "moe_drop_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}
    return shard(out, "batch", "seq", "embed"), aux
