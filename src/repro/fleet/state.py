"""Multi-tenant ACE fleets: T tenants' sketches stacked on a leading axis.

The paper's headline is that a full detector is ~4 MB of count arrays —
which means ONE accelerator can host thousands of independent detectors.
But every stateful subsystem in this repo (filter, Guardrail,
StreamRunner, window ring, dist layouts) assumes exactly one ``AceState``,
so serving per-user / per-stream detectors meant a Python loop of separate
device programs: T dispatches, T host syncs, T executables per arrival
wave.  EXPOSE (Schneider et al., 2016) makes the same one-model-per-stream
argument at scale; ACE's count algebra makes the batched fix trivial —
counts and moments have NO cross-tenant coupling, so T sketches stack
along a leading tenant axis and a mixed-tenant batch is served by one
fused program:

    counts        (T, L, 2^K)   per-tenant count arrays
    n             (T,)          per-tenant item counts
    welford_mean  (T,)          per-tenant streaming rate means
    welford_m2    (T,)          per-tenant streaming rate M2s

Routing is ONE gather index computation: the fleet addressed as a
(T·L, 2^K) matrix makes item i's table j live at row
``tenant_ids[i]·L + j`` — the tenant·L row-offset extension of the
``flat_table_gather`` trick the fused score kernel already uses (one
vectorised gather, no per-tenant loop, no padding).  Inserts are ONE
scatter-add at the same rows; thresholds are per-tenant μ−ασ computed as
(T,) vectors of the exact same elementwise ops as ``sketch``'s scalars,
then routed by ``thresholds[tenant_ids]``.

Differential contracts (tests/test_fleet.py):

* **fleet-of-1**: with T=1 and all-zero tenant_ids every op here is
  BITWISE the corresponding ``repro.core.sketch`` op (the row offset is
  identically ``j``; the (1,)-vector stats are the same float ops as the
  scalars).
* **mixed batch ≡ per-tenant sequential**: routing a mixed batch through
  ``insert_masked`` equals, bitwise on counts/n/μ AND the Welford
  moments, giving each tenant the full fixed-shape batch with its own
  sub-mask via ``sketch.insert_buckets_masked`` — because the per-tenant
  moment sums here are rows of a (T, B) masked reduction whose masked-out
  entries are exact float zeros, each row reduces the identical value
  sequence the single-tenant path reduces.
* **tenant isolation**: items routed to tenant a touch only rows
  ``a·L..a·L+L`` of the flat fleet and slot a of every stat vector —
  every other tenant's state is bitwise untouched (property-tested).

Like the base sketch, everything is pure, fixed-shape, and
jit/scan/donation-safe; the tenant axis never forces a host sync.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import sketch as sk
from repro.core.sketch import AceConfig, AceState


_INT32_MAX = 2**31 - 1


def check_flat_addressable(n_rows: int, nbuckets: int, what: str) -> None:
    """Fail loudly where the flat-offset gather/scatter would overflow.

    Every fleet hot path addresses the stacked tables as one flattened
    space of ``n_rows × 2^K`` int32 element offsets; past 2^31 the
    offsets wrap silently and jnp.take/scatter clamp the wrapped
    indices — every high-tenant item would score against and insert
    into the WRONG rows with no error.  At the paper's K=15, L=50 that
    caps one fleet at T ≈ 1310 tenants; beyond it, split into multiple
    ``FleetState``s (the offsets are computed on the GLOBAL logical
    array, so device sharding does not lift the cap).
    """
    if n_rows * nbuckets > _INT32_MAX:
        raise ValueError(
            f"{what}: flat table space {n_rows} rows × {nbuckets} "
            f"buckets = {n_rows * nbuckets} exceeds the int32 offset "
            f"range ({_INT32_MAX}); the routed gather/scatter offsets "
            "would silently wrap.  Split the fleet into multiple "
            "FleetStates (device sharding does not lift this cap — the "
            "offsets address the global logical array).")


class FleetState(NamedTuple):
    """T stacked tenant sketches (a pytree — jit/scan/psum/donation safe)."""

    counts: jax.Array        # (T, L, 2^K) counter dtype
    n: jax.Array             # (T,) float32
    welford_mean: jax.Array  # (T,) float32
    welford_m2: jax.Array    # (T,) float32
    qhist: Optional[jax.Array] = None  # (T, quantile.NUM_BINS) float32
    #                          per-tenant rate histograms for
    #                          threshold_mode="quantile"; None (default)
    #                          keeps every existing pytree contract
    attr: Optional[jax.Array] = None  # (T, 2, NL, R, C) float32 per-tenant
    #                          signed count-sketch attribution planes
    #                          (repro.attribution); None (default) keeps
    #                          every existing pytree contract

    @property
    def num_tenants(self) -> int:
        return self.counts.shape[0]


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Static fleet configuration (hashable; safe as a jit static arg).

    Every tenant shares one ``AceConfig`` — same K/L/seed, hence the SAME
    hash functions.  Sharing the hash bank is what makes the fleet one
    program (hash once for the whole mixed batch); tenants are isolated
    by their counts, not their projections, exactly like L tables within
    one sketch are isolated by rows of one matrix.
    """

    ace: AceConfig
    num_tenants: int

    def __post_init__(self):
        if self.num_tenants < 1:
            raise ValueError(
                f"num_tenants must be >= 1, got {self.num_tenants}")
        check_flat_addressable(self.num_tenants * self.ace.num_tables,
                               self.ace.num_buckets, "FleetConfig")
        if self.ace.esc_capacity > 0:
            raise NotImplementedError(
                "overflow promotion (esc_capacity > 0) is wired for the "
                "flat sketch only; fleet tables take narrow count dtypes "
                "without an escalation table (exact below saturation). "
                "See docs/ARCHITECTURE.md §7.")

    def memory_bytes(self) -> int:
        """The fleet HBM bill: T × the paper's per-detector table."""
        return self.num_tenants * self.ace.memory_bytes()


def init(cfg: FleetConfig, quantile: bool = False) -> FleetState:
    ace = cfg.ace
    if quantile:
        from repro.quantile import sketch as qsk
        qhist = qsk.init_hist(cfg.num_tenants)
    else:
        qhist = None
    acfg = ace.attr
    attr = (jnp.zeros((cfg.num_tenants,) + acfg.plane_shape(), jnp.float32)
            if acfg is not None else None)
    return FleetState(
        counts=jnp.zeros(
            (cfg.num_tenants, ace.num_tables, ace.num_buckets),
            dtype=jnp.dtype(ace.counter_dtype)),
        n=jnp.zeros((cfg.num_tenants,), jnp.float32),
        welford_mean=jnp.zeros((cfg.num_tenants,), jnp.float32),
        welford_m2=jnp.zeros((cfg.num_tenants,), jnp.float32),
        qhist=qhist,
        attr=attr,
    )


def tenant_view(state: FleetState, t) -> AceState:
    """Tenant t's sketch as a plain ``AceState`` (static or traced t)."""
    return AceState(counts=state.counts[t], n=state.n[t],
                    welford_mean=state.welford_mean[t],
                    welford_m2=state.welford_m2[t],
                    qhist=None if state.qhist is None else state.qhist[t],
                    attr=None if state.attr is None else state.attr[t])


def set_tenant(state: FleetState, t: int, ace: AceState) -> FleetState:
    """Write one tenant's sketch back into the fleet (static index)."""
    qhist = state.qhist
    if qhist is not None and ace.qhist is not None:
        qhist = qhist.at[t].set(ace.qhist)
    attr = state.attr
    if attr is not None and ace.attr is not None:
        attr = attr.at[t].set(ace.attr)
    return FleetState(
        counts=state.counts.at[t].set(ace.counts),
        n=state.n.at[t].set(ace.n),
        welford_mean=state.welford_mean.at[t].set(ace.welford_mean),
        welford_m2=state.welford_m2.at[t].set(ace.welford_m2),
        qhist=qhist,
        attr=attr,
    )


def promote_fleet(state: FleetState, dtype=jnp.int32) -> FleetState:
    """Widen a fleet's count planes to ``dtype`` (default int32).

    The cross-host promotion point (repro.cluster): narrow int8/int16
    planes are exact below saturation per host, but ADDING two hosts'
    planes in the narrow dtype would wrap silently — gossip merges
    therefore promote first and add in the wide dtype.  Stats are
    untouched (they are float and dtype-independent).
    """
    return state._replace(counts=state.counts.astype(jnp.dtype(dtype)))


def merge_fleet(a: FleetState, b: FleetState) -> FleetState:
    """Merge two fleets over disjoint data — ``sketch.merge`` vectorised
    over the tenant axis (counts add CRDT-style, Welford streams by
    Chan's parallel rule applied elementwise to the (T,) stat vectors —
    per tenant these are literally the same float ops as the scalar
    merge, so merging tenant-by-tenant via ``sketch.merge`` is bitwise
    identical; tests/test_cluster.py asserts it).

    Counts always add in int32: narrow (int8/int16) planes would wrap
    at their dtype cap, so ``merge_fleet(a8, b8)`` ≡
    ``merge_fleet(promote_fleet(a8), promote_fleet(b8))`` by
    construction — the merge-then-promote ≡ promote-then-merge
    differential oracle.  Requantize the result back down only if every
    bucket provably fits (the caller knows its stream); the merged fleet
    defaults to staying wide.
    """
    if a.counts.shape != b.counts.shape:
        raise ValueError(f"fleet shape mismatch: {a.counts.shape} vs "
                         f"{b.counts.shape}")
    counts = (a.counts.astype(jnp.int32) + b.counts.astype(jnp.int32))
    delta = b.welford_mean - a.welford_mean                    # (T,)
    tot = a.n + b.n
    safe = jnp.maximum(tot, 1.0)
    if (a.qhist is None) != (b.qhist is None):
        raise ValueError("cannot merge a quantile-tracking fleet with a "
                         "non-tracking one")
    if (a.attr is None) != (b.attr is None):
        raise ValueError("cannot merge an attribution-tracking fleet with "
                         "a non-tracking one")
    return FleetState(
        counts=counts,
        n=tot,
        welford_mean=a.welford_mean + delta * b.n / safe,
        welford_m2=(a.welford_m2 + b.welford_m2
                    + delta**2 * a.n * b.n / safe),
        qhist=None if a.qhist is None else a.qhist + b.qhist,
        # count-sketch planes are linear — disjoint-data merge is a sum
        attr=None if a.attr is None else a.attr + b.attr,
    )


def from_states(states: Sequence[AceState]) -> FleetState:
    """Stack existing single-tenant sketches into a fleet."""
    qhists = [s.qhist for s in states]
    attrs = [s.attr for s in states]
    return FleetState(
        counts=jnp.stack([s.counts for s in states]),
        n=jnp.stack([s.n for s in states]),
        welford_mean=jnp.stack([s.welford_mean for s in states]),
        welford_m2=jnp.stack([s.welford_m2 for s in states]),
        qhist=(jnp.stack(qhists)
               if all(h is not None for h in qhists) else None),
        attr=(jnp.stack(attrs)
              if all(p is not None for p in attrs) else None),
    )


# ---------------------------------------------------------------------------
# Batched tenant-routed primitives (input: precomputed bucket ids (B, L)
# + tenant ids (B,)).  These are the fleet analogues of the bucket-level
# sketch primitives, and what the ace_fleet_score kernel accelerates.
# ---------------------------------------------------------------------------

def fleet_table_gather(counts: jax.Array, tenant_ids: jax.Array,
                       buckets: jax.Array) -> jax.Array:
    """Gather counts[tid_i, j, buckets[i, j]] as ONE flattened take.

    The tenant·L row-offset extension of the fused score kernel's
    ``flat_table_gather``: the (T, L, 2^K) fleet ravels row-major so
    item i's table j is row ``tenant_ids[i]·L + j`` of a (T·L, 2^K)
    matrix — a single vectorised gather routes the whole mixed batch,
    no per-tenant loop, no sorting, no padding.  (B, L) float32 out;
    the gathered integers are exact, so downstream sums match the
    single-tenant ``batch_scores`` bitwise.
    """
    T, L, nbuckets = counts.shape
    check_flat_addressable(T * L, nbuckets, "fleet_table_gather")
    flat = counts.reshape(T * L * nbuckets)
    rows = tenant_ids[:, None] * L + jnp.arange(L, dtype=jnp.int32)[None, :]
    offs = buckets + rows * nbuckets
    return jnp.take(flat, offs, axis=0).astype(jnp.float32)


def fleet_scores(state: FleetState, tenant_ids: jax.Array,
                 buckets: jax.Array,
                 table_mask: jax.Array | None = None) -> jax.Array:
    """Each item's Ŝ(q, D_tenant) vs its OWN tenant's sketch: (B,) f32.

    Same row-sum + ONE reciprocal 1/L multiply sequence as
    ``sketch.batch_scores`` (the bitwise-parity convention every score
    path in the repo shares).

    ``table_mask`` (T, L) 0/1 restricts each item's mean to ITS OWN
    tenant's healthy tables: item i averages over
    Σ_j mask[tid_i, j] tables — per-tenant degradation, routed by the
    same tenant_ids gather as everything else.  Python-level ``None``
    branch keeps the healthy program untouched.
    """
    L = state.counts.shape[1]
    gathered = fleet_table_gather(state.counts, tenant_ids, buckets)
    if table_mask is None:
        return jnp.sum(gathered, axis=-1) * jnp.float32(1.0 / L)
    maskf = table_mask.astype(jnp.float32)[tenant_ids]           # (B, L)
    nh = jnp.maximum(jnp.sum(maskf, axis=-1), 1.0)               # (B,)
    return jnp.sum(gathered * maskf, axis=-1) * (1.0 / nh)


def _tenant_onehot(tenant_ids: jax.Array, num_tenants: int) -> jax.Array:
    """(T, B) float32 routing matrix; row t selects tenant t's items."""
    return (jnp.arange(num_tenants, dtype=jnp.int32)[:, None]
            == tenant_ids[None, :]).astype(jnp.float32)


def fleet_masked_welford(state: FleetState, tenant_ids: jax.Array,
                         scores: jax.Array, maskf: jax.Array,
                         min_n: float):
    """Per-tenant masked Welford fold of a mixed batch — segment-reduced.

    The fleet analogue of ``sketch.masked_batch_welford``: every
    per-tenant partial sum is a row of a (T, B) masked reduction.  A
    masked-out entry contributes an exact float 0.0 (finite × 0), so row
    t reduces the identical value sequence that
    ``masked_batch_welford(state_t, scores, maskf·[tid==t])`` reduces —
    per-tenant moments are BITWISE the sequential single-tenant fold's
    (the contract tests/test_fleet.py asserts), and the fold itself is
    ``sketch.welford_fold`` applied elementwise to (T,) vectors, i.e.
    literally the same jnp ops as the scalars.  Tenants with no masked
    items keep their stream untouched; the ``min_n`` cold-start gate
    applies per tenant.  Returns (n, welford_mean, welford_m2), all (T,).
    """
    onehot = _tenant_onehot(tenant_ids, state.num_tenants)      # (T, B)
    b = jnp.sum(onehot * maskf[None, :], axis=1)                # (T,)
    n = state.n
    tot = n + b                                                 # (T,)
    # each item's rate is normalised by its OWN tenant's post-batch n —
    # the same scalar the sequential fold divides by
    rates = scores / jnp.maximum(tot, 1.0)[tenant_ids]          # (B,)
    rm = rates * maskf                                          # (B,)
    mean_b = jnp.sum(onehot * rm[None, :], axis=1) \
        / jnp.maximum(b, 1.0)                                   # (T,)
    dev = (rates - mean_b[tenant_ids]) ** 2 * maskf             # (B,)
    m2_b = jnp.sum(onehot * dev[None, :], axis=1)               # (T,)
    new_mean, new_m2 = sk.welford_fold(
        state.welford_mean, state.welford_m2, n, b, tot, mean_b, m2_b,
        min_n)
    has = b > 0
    return (tot,
            jnp.where(has, new_mean, state.welford_mean),
            jnp.where(has, new_m2, state.welford_m2))


def insert_masked(state: FleetState, tenant_ids: jax.Array,
                  buckets: jax.Array, mask: jax.Array,
                  cfg: AceConfig) -> FleetState:
    """Masked insert of a mixed-tenant batch: ONE scatter-add.

    The fleet analogue of ``sketch.insert_buckets_masked``, fixed-shape
    and order-invariant: the 0/1-weighted scatter at rows
    ``tenant_ids·L + j`` of the (T·L, 2^K) flat fleet lands every item
    in its own tenant's tables (identical integer adds as T sequential
    single-tenant inserts), post-insert scores come from the same rows,
    and the Welford streams fold per tenant via
    ``fleet_masked_welford``.  Items of absent tenants simply contribute
    no rows — no per-tenant branching anywhere.
    """
    T, L, nbuckets = state.counts.shape
    rows = tenant_ids[:, None] * L + jnp.arange(L, dtype=jnp.int32)[None, :]
    w_ctr = jnp.broadcast_to(
        mask.astype(state.counts.dtype)[:, None], buckets.shape)
    new_counts = state.counts.reshape(T * L, nbuckets) \
        .at[rows, buckets].add(w_ctr).reshape(state.counts.shape)

    # Post-insert scores of ALL items vs their own tenant's updated
    # tables (Algorithm 1 line 12's x-vs-D∪{x} convention, same as every
    # other insert path).
    new_state_counts = state._replace(counts=new_counts)
    scores = fleet_scores(new_state_counts, tenant_ids, buckets)  # (B,)

    tot, new_mean, new_m2 = fleet_masked_welford(
        state, tenant_ids, scores, mask.astype(jnp.float32),
        cfg.welford_min_n)
    return FleetState(counts=new_counts, n=tot,
                      welford_mean=new_mean, welford_m2=new_m2,
                      qhist=state.qhist, attr=state.attr)


# ---------------------------------------------------------------------------
# Per-tenant statistics and thresholds — (T,) vectors of the exact same
# elementwise ops as the repro.core.sketch scalars (bitwise per tenant).
# ---------------------------------------------------------------------------

def mean_mu_fleet(state: FleetState,
                  table_mask: jax.Array | None = None) -> jax.Array:
    """(T,) exact per-tenant μ = Σ‖A_j‖² / (n·L) (Eq. 11 closed form).

    ``table_mask`` (T, L) restricts each tenant's table mean to its
    healthy tables (μ_t = Σ_{j healthy} ‖A_tj‖² / (n_t · nh_t))."""
    L = state.counts.shape[1]
    c = state.counts.astype(jnp.float32)
    if table_mask is None:
        return jnp.sum(c * c, axis=(1, 2)) \
            / (jnp.maximum(state.n, 1.0) * L)
    maskf = table_mask.astype(jnp.float32)                       # (T, L)
    nh = jnp.maximum(jnp.sum(maskf, axis=1), 1.0)                # (T,)
    per_table = jnp.sum(c * c, axis=2)                           # (T, L)
    return jnp.sum(per_table * maskf, axis=1) \
        / (jnp.maximum(state.n, 1.0) * nh)


def mean_rate_fleet(state: FleetState,
                    table_mask: jax.Array | None = None) -> jax.Array:
    """(T,) exact per-tenant mean collision rate μ/n."""
    return mean_mu_fleet(state, table_mask=table_mask) \
        / jnp.maximum(state.n, 1.0)


def sigma_welford_fleet(state: FleetState) -> jax.Array:
    """(T,) per-tenant streaming σ of collision rates."""
    return jnp.sqrt(state.welford_m2 / jnp.maximum(state.n - 1.0, 1.0))


def admit_thresholds(state: FleetState, alpha: float,
                     warmup_items: float,
                     table_mask: jax.Array | None = None,
                     threshold_mode: str = "mu_sigma",
                     q: float = 0.01) -> jax.Array:
    """(T,) per-tenant score-space admission thresholds.

    ``sketch.admit_threshold`` vectorised over the tenant axis — same
    formula sequence per mode (μ−ασ: rate − ασ moved to score space by
    max(n, 1); quantile: each tenant's OWN q-quantile from its row of
    ``state.qhist`` — THE heavy-tailed fleet fix, since one α
    miscalibrates FPR across tenants with different score-distribution
    shapes while the per-tenant quantile holds FPR ≈ q for every shape
    — with −inf during each tenant's OWN warmup), so each component is
    bitwise the single-tenant threshold.  Route to items with
    ``admit_thresholds(...)[tenant_ids]``.  ``table_mask`` (T, L) keeps
    each tenant's μ−ασ threshold consistent with its masked scores (the
    σ stream is per tenant but table-independent — no masking needed).
    """
    if threshold_mode == "quantile":
        from repro.quantile import sketch as qsk
        if state.qhist is None:
            raise ValueError("threshold_mode='quantile' needs a fleet "
                             "initialised with quantile=True")
        rates = jax.vmap(lambda h: qsk.hist_quantile(h, q))(state.qhist)
        t = rates * jnp.maximum(state.n, 1.0)
        return jnp.where(state.n >= warmup_items, t, -jnp.inf)
    if threshold_mode != "mu_sigma":
        raise ValueError(f"unknown threshold_mode {threshold_mode!r}")
    t = (mean_rate_fleet(state, table_mask=table_mask)
         - alpha * sigma_welford_fleet(state)) \
        * jnp.maximum(state.n, 1.0)
    return jnp.where(state.n >= warmup_items, t, -jnp.inf)


def per_tenant_counts(tenant_ids: jax.Array, values: jax.Array,
                      num_tenants: int) -> jax.Array:
    """(T,) masked per-tenant sums of a (B,) value vector (0/1 masks,
    margins, ...) — the summary-building helper the stream runner and
    benchmarks use; one (T, B) reduction, no host loop."""
    onehot = _tenant_onehot(tenant_ids, num_tenants)
    return jnp.sum(onehot * values.astype(jnp.float32)[None, :], axis=1)
