"""Multi-tenant ACE fleets: tenant-axis sketch stacking with batched
routing on every hot path.

One accelerator, thousands of independent detectors: ``FleetState``
stacks T tenants' count arrays and moments on a leading axis, and every
op takes a mixed-tenant batch with ``tenant_ids`` routing — hash once,
one gather, one scatter, per-tenant thresholds.  See
``repro.fleet.state`` (flat fleets), ``repro.fleet.filter`` (the
drop-in multi-tenant data filter), ``repro.fleet.window`` (per-tenant
epoch rings with per-tenant rotation clocks), and
``docs/ARCHITECTURE.md`` §6.
"""
from repro.fleet.state import (FleetConfig, FleetState, admit_thresholds,
                               fleet_scores, fleet_table_gather,
                               from_states, init, insert_masked,
                               mean_mu_fleet, merge_fleet, per_tenant_counts,
                               promote_fleet, set_tenant, tenant_view)
from repro.fleet.filter import FleetDataFilter
from repro.fleet.window import (WindowedFleetState, init_fleet_window,
                                insert_current_fleet, maybe_rotate_fleet,
                                tenant_window_view, window_admit_thresholds,
                                window_fleet_scores)

__all__ = [
    "FleetConfig", "FleetState", "FleetDataFilter", "WindowedFleetState",
    "admit_thresholds", "fleet_scores", "fleet_table_gather",
    "from_states", "init", "init_fleet_window", "insert_current_fleet",
    "insert_masked", "maybe_rotate_fleet", "mean_mu_fleet", "merge_fleet",
    "per_tenant_counts", "promote_fleet", "set_tenant", "tenant_view",
    "tenant_window_view", "window_admit_thresholds",
    "window_fleet_scores",
]
