"""Multi-tenant ACE data filter — the fleet drop-in for
``repro.data.pipeline.AceDataFilter``.

Same step protocol (``init``, ``features``, ``step``, ``__call__``,
``ace_cfg``), same single hash per batch, same score→threshold→masked-
insert dataflow — but the state is a ``FleetState`` of T independent
tenant sketches and every batch carries ``tenant_ids`` (B,) routing each
item to its own tenant: scores gather from the item's tenant tables, the
μ−ασ threshold is the item's tenant's own (each tenant warms up, drifts,
and alarms independently), and the masked insert scatters the whole mixed
batch in one shot.

With ``num_tenants=1`` (and all-zero tenant_ids) the filter is BITWISE
``AceDataFilter``: same buckets, same scores, same threshold, same
inserted counts and Welford stream (tests/test_fleet.py asserts it).

``step`` takes ``(state, w, feat, tenant_ids)`` — one extra (B,) int32
operand vs the single-tenant protocol; ``StreamRunner`` feeds it from the
chunk's stacked tenant-id plane, and the per-batch ``__call__`` driver
takes it alongside the embeddings.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import srp
from repro.core.sketch import AceConfig
from repro.fleet import state as fl
from repro.fleet.state import FleetConfig, FleetState


@dataclasses.dataclass(frozen=True)
class FleetDataFilter:
    """ACE anomaly filter over a tenant fleet (jit-compatible)."""

    d_model: int
    num_tenants: int = 1
    num_bits: int = 13
    num_tables: int = 32
    alpha: float = 4.0
    warmup_items: float = 512.0
    bias_const: float = 0.25
    hash_mode: str = "dense"
    insert_all: bool = False    # detector mode (see AceDataFilter)
    count_dtype: str = "int32"  # narrow fleet planes: the (T·L, 2^K)
                                # table is the dominant HBM resident at
                                # production T — int16/int8 cut it 2–4×
                                # (promotion stays flat-sketch only)
    threshold_mode: str = "mu_sigma"   # "mu_sigma" | "quantile": quantile
                                # mode holds each TENANT's flag rate at q
                                # from its own rate histogram — per-tenant
                                # calibration, like every fleet statistic
    quantile_q: float = 0.01    # target per-tenant flag rate
    attr_rows: int = 0          # > 0: per-tenant attribution planes
    attr_bits: int = 8          # log2 columns per attribution row

    @property
    def ace_cfg(self) -> AceConfig:
        # same construction as AceDataFilter.ace_cfg: the fleet-of-1 must
        # be the SAME sketch (seed included) as the flat filter's.
        return AceConfig(dim=self.d_model + 1, num_bits=self.num_bits,
                         num_tables=self.num_tables, seed=29,
                         welford_min_n=self.warmup_items / 2,
                         hash_mode=self.hash_mode,
                         counter_dtype=self.count_dtype,
                         attr_rows=self.attr_rows,
                         attr_bits=self.attr_bits)

    @property
    def fleet_cfg(self) -> FleetConfig:
        return FleetConfig(ace=self.ace_cfg, num_tenants=self.num_tenants)

    def init(self):
        from repro.core import sketch as sk
        return (fl.init(self.fleet_cfg,
                        quantile=self.threshold_mode == "quantile"),
                sk.make_params(self.ace_cfg))

    def features(self, embeds: jax.Array) -> jax.Array:
        """(B, S, D) embeddings -> (B, D+1) unit-mean + bias features —
        the SAME shared helper as ``AceDataFilter`` (identical
        featurisation keeps the fleet-of-1 contract bitwise)."""
        from repro.data.pipeline import mean_embed_features
        return mean_embed_features(embeds, self.bias_const)

    def step(self, state: FleetState, w, feat, tenant_ids,
             table_mask=None, tenant_mask=None):
        """hash ONCE → tenant-routed score → per-tenant μ−ασ threshold →
        one mixed-batch masked insert.

        Returns (new_state, keep (B,) bool, margin (B,) float32); the
        scan body of ``StreamRunner`` when the filter is a fleet.
        ``tenant_ids`` (B,) int32 in [0, T).

        Non-finite feature rows are sanitized at entry exactly like
        ``AceDataFilter.step`` (zeroed pre-hash, never kept/inserted,
        ``margin = −inf``); ``table_mask`` (T, L) f32 scores and
        thresholds each tenant over its healthy tables only.

        ``tenant_mask`` (T,) f32 is the OWNERSHIP mask (repro.cluster):
        items routed to a tenant this replica does not own are neither
        kept nor inserted — a misrouted request right after a re-shard
        must never mutate a non-authoritative copy, or a later gossip
        merge would double-count it.  Misrouted rows still report a
        finite margin (they were scored), just ``keep=False``; ``None``
        (single-host default) traces no ownership code at all, keeping
        the existing program bitwise untouched.
        """
        cfg = self.ace_cfg
        finite = jnp.all(jnp.isfinite(feat), axis=-1)
        feat = jnp.where(finite[:, None], feat, 0.0)
        buckets = srp.hash_buckets(feat, w, cfg.srp)   # the ONE hash
        scores = fl.fleet_scores(state, tenant_ids, buckets,
                                 table_mask=table_mask)
        thresh = fl.admit_thresholds(
            state, self.alpha, self.warmup_items,
            table_mask=table_mask, threshold_mode=self.threshold_mode,
            q=self.quantile_q)[tenant_ids]
        keep = jnp.logical_and(scores >= thresh, finite)
        margin = jnp.where(finite, scores - thresh, -jnp.inf)
        ins = finite if self.insert_all else keep
        if tenant_mask is not None:
            owned = tenant_mask[tenant_ids] > 0        # (B,)
            keep = jnp.logical_and(keep, owned)
            ins = jnp.logical_and(ins, owned)
        new_state = fl.insert_masked(state, tenant_ids, buckets, ins, cfg)
        if self.threshold_mode == "quantile":
            # every finite-scored item feeds its OWN tenant's rate
            # histogram (not just admitted ones — see AceDataFilter.step)
            from repro.quantile import sketch as qsk
            rates = scores / jnp.maximum(state.n, 1.0)[tenant_ids]
            new_state = new_state._replace(qhist=qsk.observe_rates_fleet(
                new_state.qhist, rates, tenant_ids,
                qsk.calib_mask(finite.astype(jnp.float32),
                               state.n[tenant_ids], self.warmup_items)))
        return new_state, keep, margin

    def __call__(self, state, w, embeds, mask, tenant_ids):
        """Score + filter + update a mixed-tenant batch.

        mask: (B, S) loss mask; anomalous sequences are zeroed out.
        Returns (new_state, new_mask, frac_kept).
        """
        feat = self.features(embeds)
        new_state, keep, _margin = self.step(state, w, feat, tenant_ids)
        new_mask = mask * keep[:, None].astype(mask.dtype)
        return new_state, new_mask, jnp.mean(keep.astype(jnp.float32))
