"""Windowed multi-tenant fleets: T independent epoch rings, one program.

The tenant-axis extension of ``repro.window``: every tenant owns a full
``WindowedAceState`` ring (E epochs + tail view + ssq stream + cursor +
tick), stacked on a leading T axis:

    counts        (T, E, L, 2^K)   per-tenant epoch rings
    n / welford_* (T, E)           per-tenant per-epoch moments
    tail          (T, L, 2^K) f32  per-tenant γ-weighted tail views
    ssq           (T,)             per-tenant ‖C_w‖² streams
    cursor        (T,)  int32      per-tenant ring pointers
    tick          (T,)  int32      per-tenant insert-step clocks

The clocks are the point: tenants receive traffic at DIFFERENT rates, so
each tenant's tick advances only on batches that actually contained its
items, and ``maybe_rotate_fleet`` rotates exactly the tenants whose live
epoch just filled — a bursty tenant cycles its window fast, an idle one
keeps its history, and neither perturbs the other (the isolation
property, tested).  One batch = one tick for every PRESENT tenant
(mask-independent, like the flat ring's per-step tick).

Routing reuses the fleet's flat-offset trick twice over: the live-epoch
scatter/gathers address the (T·E·L, 2^K) flat ring at row
``tid·E·L + cursor[tid]·L + j``, the tail gathers address the
(T·L, 2^K) flat tail at ``tid·L + j``.  Per-tenant scalar streams
(ssq, Welford) fold through the same (T, B) masked segment reductions
as the flat fleet — masked-out entries are exact float zeros, so each
tenant's fold is bitwise the single-ring ``ring.insert_current`` fold.

Differential contracts (tests/test_fleet.py): fleet-of-1 ≡ the plain
``WindowedAceState`` ops bitwise; a mixed batch ≡ per-tenant sequential
``ring.insert_current`` with per-tenant sub-masks; rotation of tenant a
leaves tenant b bitwise untouched.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import sketch as sk
from repro.core.sketch import AceConfig
from repro.fleet.state import _tenant_onehot
from repro.window import ring
from repro.window.ring import WindowConfig, WindowedAceState


class WindowedFleetState(NamedTuple):
    """T stacked epoch rings (a pytree — jit/scan/donation safe)."""

    counts: jax.Array        # (T, E, L, 2^K) counter dtype
    n: jax.Array             # (T, E) float32
    welford_mean: jax.Array  # (T, E) float32
    welford_m2: jax.Array    # (T, E) float32
    tail: jax.Array          # (T, L, 2^K) float32
    ssq: jax.Array           # (T,) float32
    cursor: jax.Array        # (T,) int32
    tick: jax.Array          # (T,) int32
    qhist: Optional[jax.Array] = None  # (T, E, quantile.NUM_BINS) f32
    #                          per-tenant per-epoch rate histograms for
    #                          threshold_mode="quantile"; None (default)
    #                          keeps every existing pytree contract
    attr: Optional[jax.Array] = None  # (T, E, 2, NL, R, C) f32 per-tenant
    #                          per-epoch attribution planes — POSITION
    #                          matters: leaf order mirrors
    #                          WindowedAceState exactly (the
    #                          ``WindowedAceState(*state)`` splats below
    #                          and in kernels/ops.py rely on it)

    @property
    def num_tenants(self) -> int:
        return self.counts.shape[0]

    @property
    def num_epochs(self) -> int:
        return self.counts.shape[1]


def init_fleet_window(cfg: WindowConfig, num_tenants: int,
                      quantile: bool = False) -> WindowedFleetState:
    if num_tenants < 1:
        raise ValueError(f"num_tenants must be >= 1, got {num_tenants}")
    from repro.fleet.state import check_flat_addressable
    check_flat_addressable(num_tenants * cfg.num_epochs
                           * cfg.ace.num_tables, cfg.ace.num_buckets,
                           "init_fleet_window")
    one = ring.init_window(cfg, quantile=quantile)
    return WindowedFleetState(*(
        None if leaf is None
        else jnp.broadcast_to(leaf, (num_tenants,) + leaf.shape)
        for leaf in one))


def tenant_window_view(state: WindowedFleetState, t) -> WindowedAceState:
    """Tenant t's ring as a plain ``WindowedAceState`` (static/traced t)."""
    return WindowedAceState(*(
        None if leaf is None else leaf[t] for leaf in state))


def set_tenant_window(state: WindowedFleetState, t: int,
                      one: WindowedAceState) -> WindowedFleetState:
    return WindowedFleetState(*(
        leaf if leaf is None else leaf.at[t].set(lf)
        for leaf, lf in zip(state, one)))


# ---------------------------------------------------------------------------
# Hot-path routed scoring: tail + live gathers, both flat-offset.
# ---------------------------------------------------------------------------

def window_table_sums_fleet(state: WindowedFleetState,
                            tenant_ids: jax.Array, buckets: jax.Array,
                            table_mask: jax.Array | None = None):
    """Per-item (tail_sums, live_sums), each vs the item's OWN tenant's
    ring — the fleet analogue of ``ring.window_table_sums`` (same
    gathered integers, same row-sum order → bitwise per tenant).
    ``table_mask`` (T, L) zeroes each item's corrupted tables out of
    both row-sums, routed by tenant_ids (degraded mode; the ``None``
    branch keeps the healthy program untouched)."""
    T, E, L, nbuckets = state.counts.shape
    iota_j = jnp.arange(L, dtype=jnp.int32)[None, :]
    tail_rows = tenant_ids[:, None] * L + iota_j                 # (B, L)
    tail_flat = state.tail.reshape(T * L, nbuckets)
    ring_rows = (tenant_ids[:, None] * (E * L)
                 + state.cursor[tenant_ids][:, None] * L + iota_j)
    flat = state.counts.reshape(T * E * L, nbuckets)
    tail_g = tail_flat[tail_rows, buckets]                       # (B, L)
    live_g = flat[ring_rows, buckets].astype(jnp.float32)        # (B, L)
    if table_mask is not None:
        maskf = table_mask.astype(jnp.float32)[tenant_ids]       # (B, L)
        tail_g = tail_g * maskf
        live_g = live_g * maskf
    return jnp.sum(tail_g, axis=-1), jnp.sum(live_g, axis=-1)


def window_fleet_scores(state: WindowedFleetState, tenant_ids: jax.Array,
                        buckets: jax.Array,
                        table_mask: jax.Array | None = None) -> jax.Array:
    """(B,) windowed scores, each item vs its own tenant's window."""
    tail_sums, live_sums = window_table_sums_fleet(
        state, tenant_ids, buckets, table_mask=table_mask)
    if table_mask is None:
        return ring.score_live(tail_sums, live_sums,
                               state.counts.shape[2])
    maskf = table_mask.astype(jnp.float32)[tenant_ids]           # (B, L)
    nh = jnp.maximum(jnp.sum(maskf, axis=-1), 1.0)               # (B,)
    return (tail_sums + live_sums) * (1.0 / nh)


def window_admit_thresholds(state: WindowedFleetState, gamma: float,
                            alpha: float, warmup_items: float,
                            table_mask: jax.Array | None = None,
                            threshold_mode: str = "mu_sigma",
                            q: float = 0.01) -> jax.Array:
    """(T,) per-tenant windowed admission thresholds —
    ``ring.admit_threshold_windowed`` vmapped over the tenant axis (the
    per-tenant component is the identical elementwise formula; the
    ``threshold_mode``/``q`` knobs dispatch inside it at trace time).
    ``table_mask`` (T, L) vmaps alongside the state so each tenant's
    threshold averages over its own healthy tables."""
    if table_mask is None:
        return jax.vmap(lambda s: ring.admit_threshold_windowed(
            s, gamma, alpha, warmup_items,
            threshold_mode=threshold_mode, q=q))(WindowedAceState(*state))
    return jax.vmap(lambda s, m: ring.admit_threshold_windowed(
        s, gamma, alpha, warmup_items, table_mask=m,
        threshold_mode=threshold_mode, q=q))(
        WindowedAceState(*state), table_mask)


def observe_current_fleet(state: WindowedFleetState, rates: jax.Array,
                          tenant_ids: jax.Array,
                          maskf: jax.Array) -> WindowedFleetState:
    """Fold a mixed-tenant batch of windowed rates into each item's
    tenant's LIVE epoch histogram row — ONE flat scatter at
    ``tid·E·NUM_BINS + cursor[tid]·NUM_BINS + bin`` (the same routing
    trick as the live-epoch count scatter).  ``maskf`` is the OBSERVE
    mask (finite rows), not the admit mask."""
    from repro.quantile import sketch as qsk
    T, E, nb = state.qhist.shape
    offs = (tenant_ids.astype(jnp.int32) * (E * nb)
            + state.cursor[tenant_ids] * nb + qsk.bin_index(rates))
    flat = state.qhist.reshape(T * E * nb)
    qhist = flat.at[offs].add(maskf.astype(jnp.float32)).reshape(T, E, nb)
    return state._replace(qhist=qhist)


# ---------------------------------------------------------------------------
# Routed insert + per-tenant clocks.
# ---------------------------------------------------------------------------

def insert_current_fleet(state: WindowedFleetState, tenant_ids: jax.Array,
                         buckets: jax.Array, mask: jax.Array,
                         cfg: AceConfig, gamma: float = 1.0,
                         pre_sums=None) -> WindowedFleetState:
    """Masked mixed-batch insert into each item's tenant's LIVE epoch.

    ONE scatter on the (T·E·L, 2^K) flat ring; per-tenant ssq/Welford
    streams advance by (T, B) masked segment reductions of the exact
    per-item terms ``ring.insert_current`` reduces (masked-out entries
    are exact zeros → bitwise per tenant).  Each PRESENT tenant's tick
    advances by one step — absent tenants' clocks, moments, and counts
    are bitwise untouched.
    """
    T, E, L, nbuckets = state.counts.shape
    iota_j = jnp.arange(L, dtype=jnp.int32)[None, :]
    ring_rows = (tenant_ids[:, None] * (E * L)
                 + state.cursor[tenant_ids][:, None] * L + iota_j)

    if pre_sums is None:
        pre_sums = window_table_sums_fleet(state, tenant_ids, buckets)
    tail_sums, live_pre = pre_sums

    # -- THE scatter (each item's tenant's live-epoch rows)
    w_ctr = jnp.broadcast_to(
        mask.astype(state.counts.dtype)[:, None], buckets.shape)
    new_ring = state.counts.reshape(T * E * L, nbuckets) \
        .at[ring_rows, buckets].add(w_ctr).reshape(state.counts.shape)

    # -- post-insert windowed sums (tails unchanged)
    live_post = jnp.sum(
        new_ring.reshape(T * E * L, nbuckets)[ring_rows, buckets]
        .astype(jnp.float32), axis=-1)
    return _apply_insert_stats(state, new_ring, tenant_ids, mask, cfg,
                               gamma, tail_sums, live_pre, live_post)


def _apply_insert_stats(state: WindowedFleetState, new_ring: jax.Array,
                        tenant_ids: jax.Array, mask: jax.Array,
                        cfg: AceConfig, gamma: float,
                        tail_sums: jax.Array, live_pre: jax.Array,
                        live_post: jax.Array) -> WindowedFleetState:
    """Per-tenant ssq/Welford/tick advance for an already-scattered ring.

    The stats half of ``insert_current_fleet``, shared verbatim with the
    fused ``ace_fleet_window_admit`` kernel path (which performs the
    hash/gather/threshold/scatter in one Pallas launch and hands the
    kernel's tail/live sums here) — ONE home for the fold, so the two
    ingest paths cannot drift.
    """
    T, E, L, nbuckets = state.counts.shape
    maskf = mask.astype(jnp.float32)
    onehot = _tenant_onehot(tenant_ids, T)                       # (T, B)
    present = (jnp.sum(onehot, axis=1) > 0)                      # (T,)
    scores = ring.score_live(tail_sums, live_post, L)

    def seg(v):   # (B,) -> (T,) per-tenant masked sums
        return jnp.sum(onehot * v[None, :], axis=1)

    # -- per-tenant ssq increment: Δ‖C_w‖² = 2·m_tail + m_pre + m_post,
    #    accumulated in the SAME association order as ring.insert_current
    #    (((ssq + 2·m_tail) + m_pre) + m_post) — float addition does not
    #    reassociate, and the per-tenant streams must stay bitwise
    new_ssq = state.ssq + 2.0 * seg(tail_sums * maskf)
    new_ssq = new_ssq + seg(live_pre * maskf)
    new_ssq = new_ssq + seg(live_post * maskf)

    # -- per-tenant live-epoch Welford fold of windowed post-insert
    #    rates (mirrors ring.insert_current term for term)
    b = seg(maskf)                                               # (T,)
    rows_te = jnp.arange(T, dtype=jnp.int32) * E + state.cursor  # (T,)
    n_flat = state.n.reshape(T * E)
    n_e = jnp.take(n_flat, rows_te)                              # (T,)
    tot_e = n_e + b
    n_w = jax.vmap(lambda s: ring.combined_n(s, gamma))(
        WindowedAceState(*state)) + b                            # (T,)
    rates = scores / jnp.maximum(n_w, 1.0)[tenant_ids]           # (B,)
    mean_b = seg(rates * maskf) / jnp.maximum(b, 1.0)            # (T,)
    m2_b = seg(((rates - mean_b[tenant_ids]) ** 2) * maskf)      # (T,)
    mean_flat = state.welford_mean.reshape(T * E)
    m2_flat = state.welford_m2.reshape(T * E)
    new_mean, new_m2 = sk.welford_fold(
        jnp.take(mean_flat, rows_te), jnp.take(m2_flat, rows_te),
        n_e, b, tot_e, mean_b, m2_b, cfg.welford_min_n)
    has = b > 0
    new_mean = jnp.where(has, new_mean, jnp.take(mean_flat, rows_te))
    new_m2 = jnp.where(has, new_m2, jnp.take(m2_flat, rows_te))

    return state._replace(
        counts=new_ring,
        n=n_flat.at[rows_te].set(tot_e).reshape(T, E),
        welford_mean=mean_flat.at[rows_te].set(new_mean).reshape(T, E),
        welford_m2=m2_flat.at[rows_te].set(new_m2).reshape(T, E),
        ssq=new_ssq,
        tick=state.tick + present.astype(jnp.int32))


def rotate_fleet(state: WindowedFleetState,
                 gamma: float = 1.0) -> WindowedFleetState:
    """Rotate EVERY tenant's ring once.

    Fleet-native (NOT a vmapped ``ring.rotate``), mirroring the flat
    ring's tensordot-recompute tail fold: each tenant's tail is
    recomputed from its updated ring as one per-tenant-weighted
    contraction  tail'_t = Σ_e γ^age'_te · C'_te  — an einsum whose
    batched dot_general lowers bitwise-identically to the single-ring
    tensordot across eager/jit/cond/scan/vmap (verified empirically on
    this backend; the old incremental γ·(tail + live − γ^{E−1}·expired)
    fold FMA-drifted up to 1 ulp in traced contexts for γ<1, which
    forced the windowed fleet contract tests to pin γ=1 — see
    ``ring.rotate``).  Keeps the fleet-of-1 and per-tenant differential
    contracts bitwise at EVERY γ.
    """
    T, E, L, nbuckets = state.counts.shape
    tidx = jnp.arange(T, dtype=jnp.int32)
    new_cursor = jnp.mod(state.cursor + 1, E)
    rows = tidx * E + new_cursor                       # (T,)
    zero_slab = jnp.zeros((L, nbuckets), state.counts.dtype)
    counts = state.counts.reshape(T * E, L, nbuckets) \
        .at[rows].set(zero_slab).reshape(state.counts.shape)
    # per-tenant epoch weights at the NEW cursor: (T, E); the zeroed
    # new-live slab contributes nothing to the contraction
    w = jax.vmap(lambda c: ring.epoch_weights(c, E, gamma))(new_cursor)
    tail = jnp.einsum("te,telb->tlb", w, counts.astype(jnp.float32))
    zero = jnp.zeros((T,), jnp.float32)

    def clear(leaf):
        return leaf.reshape(T * E).at[rows].set(zero).reshape(T, E)

    qhist = state.qhist
    if qhist is not None:
        nb = qhist.shape[2]
        qhist = qhist.reshape(T * E, nb) \
            .at[rows].set(jnp.zeros((nb,), jnp.float32)) \
            .reshape(T, E, nb)

    attr = state.attr
    if attr is not None:
        pshape = attr.shape[2:]
        attr = attr.reshape((T * E,) + pshape) \
            .at[rows].set(jnp.zeros(pshape, jnp.float32)) \
            .reshape(state.attr.shape)

    return WindowedFleetState(
        counts=counts,
        n=clear(state.n),
        welford_mean=clear(state.welford_mean),
        welford_m2=clear(state.welford_m2),
        tail=tail,
        ssq=jnp.sum(tail * tail, axis=(1, 2)),
        cursor=new_cursor,
        tick=state.tick,
        qhist=qhist,
        attr=attr,
    )


def maybe_rotate_fleet(state: WindowedFleetState, rotate_every: int,
                       gamma: float = 1.0, *,
                       tenant_ids: jax.Array) -> WindowedFleetState:
    """Per-tenant rotation clocks: rotate exactly the tenants whose tick
    says their live epoch JUST filled.

    Call AFTER an insert step with the SAME ``tenant_ids`` — the
    predicate is ``present ∧ tick % R == 0``, where ``present`` marks
    the tenants that batch actually ticked.  Presence is load-bearing,
    not an optimisation: the flat ring's ``tick > 0 ∧ tick % R == 0``
    test is safe only because its tick advances on every call, so each
    boundary fires once; a fleet tenant's tick freezes while it is
    absent, and a tick parked on a boundary would otherwise re-fire on
    EVERY later batch it sits out — cycling its cursor and wiping its
    window history from pure neighbour traffic (the exact isolation
    violation the per-tenant clocks exist to prevent).  Gating on
    presence makes each tenant's rotation positions identical to the
    sequential per-tenant driver, which only runs its ``maybe_rotate``
    on that tenant's own steps.

    Vectorised select (the fleet-native rotate computes all T candidate
    rotations and keeps the due ones) — pure device work, fine for
    host-driven admit/filter batches; a fleet stream runner would lower
    it to segment boundaries the way ``StreamRunner`` does for single
    rings.  ``rotate_every <= 0`` is the identity.
    """
    if rotate_every <= 0:
        return state
    rotated = rotate_fleet(state, gamma)
    present = jnp.sum(_tenant_onehot(tenant_ids, state.num_tenants),
                      axis=1) > 0
    should = jnp.logical_and(
        present, jnp.logical_and(state.tick > 0,
                                 jnp.mod(state.tick, rotate_every) == 0))
    out = []
    for leaf_new, leaf_old in zip(rotated, state):
        if leaf_old is None:
            out.append(None)
            continue
        sel = should.reshape((-1,) + (1,) * (leaf_old.ndim - 1))
        out.append(jnp.where(sel, leaf_new, leaf_old))
    return WindowedFleetState(*out)
