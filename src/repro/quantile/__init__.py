"""Quantile-calibrated admission: streaming quantile sketches over
collision rates + Compressed-Counting frequency-moment drift statistics.

See :mod:`repro.quantile.sketch` for the fixed-shape histogram quantile
sketch (the ``threshold_mode="quantile"`` backend of every admit path)
and :mod:`repro.quantile.moments` for the α-th frequency-moment skew
index surfaced in the stream summaries.
"""
from repro.quantile.moments import falpha_index  # noqa: F401
from repro.quantile.sketch import (  # noqa: F401
    NUM_BINS, RATE_MIN, bin_edges, bin_index, hist_quantile, init_hist,
    merge_hists, observe_rates, observe_rates_fleet,
    quantile_threshold)
