"""α-th frequency-moment skew index over the ACE count planes.

Compressed Counting (Ping Li, arXiv 1205.2632) shows the α-th frequency
moment  F_α = Σ_b A[b]^α  for α near 1 is a far sharper detector of
distribution skew/drift than second-moment statistics: dF_α/dα at α=1
is the (negative) entropy of the bucket distribution, so small moves of
α around 1 read out entropy-like concentration changes that a variance
(our Welford σ stream) smears.  CC itself estimates F_α from
skewed-stable projections when the frequency vector cannot be stored —
here each ACE table IS a materialized 2^K-bucket frequency vector of
the (hashed) stream, so we compute F_α directly per table and average
the L independent tables, which is the zero-variance limit of the CC
estimator on this representation.

The surfaced statistic is the scale-free NORMALIZED index

    I_α = mean_j  F_α(A_j) / (n^α · m^{1−α}),     m = 2^K

which is exactly 1 for a perfectly uniform plane (every bucket n/m) and
grows with concentration (all mass in one bucket gives m^{α−1} ≫ 1 for
α > 1).  Dividing out n^α makes it stationary across stream growth —
the same trick as scoring in rate space — so a moving I_α is a drift
signal, not a volume signal.  It is computed once per stream chunk
(O(L·2^K), never on the per-item path) and surfaced as ``falpha`` in
``ChunkSummary``/``FleetChunkSummary``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def falpha_index(counts: jax.Array, n: jax.Array,
                 alpha: float = 1.25,
                 table_mask: jax.Array | None = None) -> jax.Array:
    """Normalized α-th frequency-moment index of count planes.

    ``counts`` is (..., L, M) (flat: (L, M); fleet: (T, L, M); any
    float-convertible dtype — quantized planes pass their densified
    view), ``n`` broadcasts against the leading axes.  Returns (...,)
    float32.  Negative counters (corruption) clamp to 0 so the
    fractional power is defined; ``table_mask`` (L,) restricts the
    table mean to healthy planes (the repro.resilience convention —
    ``None`` keeps the healthy program untouched).
    """
    c = jnp.maximum(counts.astype(jnp.float32), 0.0)
    m = c.shape[-1]
    f_alpha = jnp.sum(c ** jnp.float32(alpha), axis=-1)       # (..., L)
    denom = (jnp.maximum(jnp.asarray(n, jnp.float32), 1.0) ** alpha
             * jnp.float32(m ** (1.0 - alpha)))
    per_table = f_alpha / denom[..., None]
    if table_mask is None:
        return jnp.mean(per_table, axis=-1)
    maskf = table_mask.astype(jnp.float32)        # (L,) or (T, L)
    nh = jnp.maximum(jnp.sum(maskf, axis=-1), 1.0)
    return jnp.sum(per_table * maskf, axis=-1) / nh
