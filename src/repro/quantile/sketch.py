"""Fixed-shape streaming quantile sketch over collision rates.

The μ−ασ admission rule assumes roughly Gaussian per-tenant score
distributions; heavy-tailed real traffic miscalibrates FPR across
tenants (a single α over-flags the light-tailed tenants and
under-flags the heavy-tailed ones).  This module gives the direct
"flag the worst q%" semantics instead: a per-tenant per-epoch
histogram of observed collision RATES (score/n ∈ [0, 1] — the same
stationary quantity the Welford σ stream folds), from which the
q-quantile is read as an interpolated inverse CDF and moved to score
space by one multiply — so the fused admit kernels keep consuming ONE
score-space device scalar per tenant and never change.

Design constraints (why a log-binned additive histogram and not P²/KLL
proper):

* **Fixed shape, donation-safe**: the state is one ``(NUM_BINS,)``
  float32 vector per tenant per epoch; insertion is a single masked
  scatter-add per batch; no data-dependent host control flow anywhere —
  it rides the same donated ``lax.scan`` as the count planes.  P² is
  inherently sequential per item (scan-hostile); KLL compactions are
  data-dependent.
* **Exact mergeability**: merge = elementwise addition, which is
  commutative/associative (exactly so for the unit-weight integer-valued
  histograms the streams build, f32 being exact below 2^24) and composes
  with the window ring's γ-decay: the combined-window histogram is the
  γ^age-weighted sum of the per-epoch histograms — the same
  ``epoch_weights`` tensordot the decayed count view uses.  Rotation
  resets one epoch's histogram row; nothing else moves.
* **Resolution where anomalies live**: rates concentrate near 0 for
  rare items, so bins 1..NUM_BINS−2 are geometric over
  [RATE_MIN, 1) (relative value error ≤ ratio−1 ≈ 11.6% per bin at the
  default 128 bins), bin 0 is the underflow bin [0, RATE_MIN) and the
  last bin catches rate ≥ 1.  The returned quantile is within one bin
  of the exact empirical quantile — the rank of the estimate's bin
  brackets the target rank (property-tested in tests/test_quantile.py).

Calibration semantics: the histogram observes EVERY finite-scored item
(the sanitize mask, NOT the admit mask) — observing only admitted items
would freeze the rejected tail out of the histogram and the threshold
would creep (a self-reinforcing feedback loop).  Observing the full
traffic keeps the q-quantile an unbiased estimate of the traffic
distribution, so per-tenant FPR ≈ q by construction, independent of the
distribution's shape.  The ONE exception is the cold start
(:func:`calib_mask`): rates measured against a near-empty sketch sit at
~0 regardless of the item, and on a CUMULATIVE histogram that early
underflow-bin mass permanently pins every quantile q below the warmup
fraction — so observation is gated at the same half-warmup floor the
Welford σ stream uses (``welford_min_n``).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

NUM_BINS: int = 128
RATE_MIN: float = 1e-6
# bins 1..126 are geometric over [RATE_MIN, 1): 127 inner edges.
_N_INNER = NUM_BINS - 1
_RATIO = float((1.0 / RATE_MIN) ** (1.0 / (_N_INNER - 1)))
_INV_LOG_RATIO = float(1.0 / np.log(_RATIO))


def _edges_np() -> np.ndarray:
    inner = RATE_MIN * _RATIO ** np.arange(_N_INNER, dtype=np.float64)
    inner[-1] = 1.0  # close the geometric ladder exactly at 1
    return np.concatenate([[0.0], inner, [1.5]]).astype(np.float32)


# host-side constant (NOT jnp at module scope: this module may first be
# imported from inside a jit trace, where jnp ops stage as tracers)
_EDGES_NP = _edges_np()


def bin_edges() -> jax.Array:
    """The (NUM_BINS+1,) float32 edge vector: [0, RATE_MIN .. 1, 1.5]."""
    return jnp.asarray(_EDGES_NP)


def init_hist(*lead: int) -> jax.Array:
    """A zero histogram with optional leading axes, e.g.
    ``init_hist()`` -> (NUM_BINS,), ``init_hist(E)`` -> (E, NUM_BINS),
    ``init_hist(T, E)`` -> (T, E, NUM_BINS).  Always float32."""
    return jnp.zeros(tuple(lead) + (NUM_BINS,), jnp.float32)


def bin_index(rates: jax.Array) -> jax.Array:
    """Map rates (...,) -> int32 bin ids (...,) — pure vector math."""
    r = rates.astype(jnp.float32)
    safe = jnp.maximum(r, jnp.float32(RATE_MIN))
    k = jnp.floor(jnp.log(safe * jnp.float32(1.0 / RATE_MIN))
                  * jnp.float32(_INV_LOG_RATIO)).astype(jnp.int32) + 1
    return jnp.where(r < RATE_MIN, 0,
                     jnp.clip(k, 1, NUM_BINS - 1)).astype(jnp.int32)


def observe_rates(hist: jax.Array, rates: jax.Array,
                  maskf: jax.Array) -> jax.Array:
    """Fold a batch of rates into one (NUM_BINS,) histogram.

    ``maskf`` is the 0/1 float32 OBSERVE mask (finite rows — see module
    docstring); masked-out items add exact float 0.0 weight, so the
    fixed-shape scatter equals the dense insert of the masked subset.
    """
    return hist.at[bin_index(rates)].add(maskf.astype(jnp.float32))


def observe_rates_fleet(hist: jax.Array, rates: jax.Array,
                        tenant_ids: jax.Array,
                        maskf: jax.Array) -> jax.Array:
    """Fold a mixed-tenant batch into a (T, NUM_BINS) histogram stack —
    ONE flat scatter at tenant·NUM_BINS + bin (the same row-offset
    routing trick as ``fleet_table_gather``)."""
    T = hist.shape[0]
    flat = hist.reshape(T * NUM_BINS)
    offs = tenant_ids.astype(jnp.int32) * NUM_BINS + bin_index(rates)
    return flat.at[offs].add(maskf.astype(jnp.float32)).reshape(T, NUM_BINS)


def calib_mask(maskf: jax.Array, n: jax.Array,
               warmup_items: float) -> jax.Array:
    """Cold-start gate for the calibration stream: zero the observe mask
    while the sketch holds fewer than ``warmup_items / 2`` items.

    A rate measured against a near-empty sketch is ~0 whatever the item
    looks like — it estimates the sketch's fill level, not the traffic.
    Those observations land in the underflow bin, and because the flat
    histograms are cumulative, a warmup worth of them outweighs the
    q-quantile forever once q < warmup/stream (measured: Q_q pinned at
    bin 0 and FPR == 0 over a whole benchmark run).  Gating at the same
    half-warmup floor as the Welford σ stream (``welford_min_n``) means
    that by the time the threshold arms (n ≥ warmup) the histogram holds
    only rates from a usefully-filled sketch.  ``n`` is the PRE-insert
    count the rates were normalized by — scalar, or per-item for fleet
    callers (``state.n[tenant_ids]``); broadcasts against ``maskf``.
    """
    armed = jnp.asarray(n, jnp.float32) >= jnp.float32(
        0.5 * float(warmup_items))
    return maskf * armed.astype(jnp.float32)


def merge_hists(a: jax.Array, b: jax.Array) -> jax.Array:
    """Merge two histograms over disjoint data (CRDT-style addition)."""
    return a + b


def hist_quantile(hist: jax.Array, q: float) -> jax.Array:
    """The q-quantile rate from one (NUM_BINS,) histogram — interpolated
    inverse CDF, all fixed-shape device ops (cumsum + searchsorted +
    two gathers).  An empty histogram returns 0.0 (callers gate on
    warmup anyway).  ``hist`` may carry γ-decay weights — any
    nonnegative weighting is a valid CDF."""
    cdf = jnp.cumsum(hist.astype(jnp.float32))
    total = cdf[-1]
    target = jnp.float32(q) * total
    idx = jnp.clip(jnp.searchsorted(cdf, target, side="left"),
                   0, NUM_BINS - 1)
    prev = jnp.where(idx > 0, cdf[jnp.maximum(idx - 1, 0)], 0.0)
    inbin = cdf[idx] - prev
    frac = jnp.clip((target - prev) / jnp.maximum(inbin, 1e-30), 0.0, 1.0)
    edges = jnp.asarray(_EDGES_NP)
    lo = edges[idx]
    hi = edges[idx + 1]
    return jnp.where(total > 0, lo + frac * (hi - lo), 0.0)


def quantile_threshold(hist: jax.Array, n: jax.Array, q: float,
                       warmup_items: float) -> jax.Array:
    """Score-space admission threshold from a rate histogram: admit iff
    score >= Q_q(rates) · max(n, 1).  Same shape contract as the μ−ασ
    ``admit_threshold`` — ONE device scalar, −inf during warmup — so the
    fused admit kernels consume it unchanged."""
    t = hist_quantile(hist, q) * jnp.maximum(n, 1.0)
    return jnp.where(n >= warmup_items, t, -jnp.inf)
