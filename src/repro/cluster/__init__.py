"""repro.cluster — fault-tolerant multi-host fleet serving.

The sketch's mergeability (counts add, moments merge by Chan's rule)
makes a peer's copy a valid warm restore, so host failure becomes a
degraded-but-serving event instead of an outage:

* tenants shard across hosts by rendezvous hashing (``shard`` —
  minimal movement on any membership change),
* each host gossips its owned tenants' sketches at epoch boundaries
  and checkpoints them with CRCs (``gossip`` + ``train.checkpoint``),
* heartbeat-timeout failure detection re-shards a dead host's tenants
  onto survivors, warm-restored from the last intact gossip/checkpoint
  — every candidate health-checked before install (``membership``,
  ``node``),
* declared-dead hosts re-enter through attempt-bounded exponential
  backoff (``membership.RejoinPolicy``).

Control traffic rides the coordination-service KV store every
``jax.distributed`` launch already has (``kv``); the hot path stays
the unchanged single-host fleet scan, ownership-masked.  The open-loop
serving front end lives in ``repro.serve.frontend``.  See
docs/ARCHITECTURE.md §9.
"""
from repro.cluster.gossip import (GossipBus, SnapshotCorrupt,
                                  pack_snapshot, snapshot_healthy,
                                  unpack_snapshot)
from repro.cluster.kv import DistributedStore, MemStore
from repro.cluster.membership import (FailureDetector, HeartbeatWriter,
                                      MembershipConfig, RejoinPolicy)
from repro.cluster.node import ClusterConfig, ClusterNode
from repro.cluster.shard import (ShardMap, rendezvous_owner, with_host,
                                 without_host)

__all__ = [
    "ClusterConfig", "ClusterNode", "DistributedStore", "FailureDetector",
    "GossipBus", "HeartbeatWriter", "MemStore", "MembershipConfig",
    "RejoinPolicy", "ShardMap", "SnapshotCorrupt", "pack_snapshot",
    "rendezvous_owner", "snapshot_healthy", "unpack_snapshot",
    "with_host", "without_host",
]
