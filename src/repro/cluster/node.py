"""ClusterNode: one host's slice of a fault-tolerant multi-host fleet.

Every host holds a FULL (T, L, 2^K) fleet allocation — at the paper's
4 MB/tenant that is cheap next to the model it guards — but serves only
the tenants the current :class:`~repro.cluster.shard.ShardMap` assigns
it; ownership is pure routing (``StreamRunner``'s ``tenant_mask``), so
"elastic re-sharding" never reshapes device buffers, it re-points
requests and warm-restores rows.  This is exactly the shape
``train/fault.py`` designed for: topology lives OUTSIDE the state, so
any host can adopt any tenant's sketch without resharding anything.

The control plane is deliberately boring and synchronous — three
host-side calls the serving loop interleaves between chunks:

* ``ingest_chunk``: the hot path.  One donated scan program per chunk
  (unchanged from single-host serving), heartbeat piggy-backed, epoch
  boundaries publish gossip + (every ``ckpt_every_epochs``) a CRC'd
  checkpoint.
* ``control_step``: poll heartbeats; the acting coordinator (lowest
  live host id) publishes a successor shard map when someone died;
  everyone applies newer maps, adopting gained tenants from the dead
  host's last gossiped snapshot and/or newest intact checkpoint —
  whichever intact candidate has seen more stream (max n) — each
  candidate gated by ``resilience.health_check`` before it touches the
  fleet.
* ``try_rejoin``: a host the cluster declared dead (or a cold restart)
  re-enters through attempt-bounded exponential backoff
  (:class:`~repro.cluster.membership.RejoinPolicy`) — it requests
  admission, the coordinator re-adds it, and HRW moves back only the
  tenants it wins.

Failure cost, end to end: a dead host's tenants lose at most the
partial epoch since its last gossip publish; every surviving tenant's
state is BITWISE untouched (tenant isolation + ownership masking), so
survivors' scores stay parity-exact with a never-failed run — the
chaos test in tests/test_cluster_multiprocess.py holds both properties
over two real killed-and-rehomed ``jax.distributed`` processes.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.gossip import GossipBus, snapshot_healthy
from repro.cluster.membership import (FailureDetector, HeartbeatWriter,
                                      MembershipConfig, RejoinPolicy)
from repro.cluster.shard import ShardMap, with_host, without_host
from repro.core import srp
from repro.core.sketch import AceState
from repro.fleet import state as fl
from repro.fleet.filter import FleetDataFilter
from repro.stream.runner import StreamRunner
from repro.train import checkpoint as ckpt

_MAP_KEY = "shardmap"


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """Static per-host cluster configuration (every host gets the same
    values except ``host_id``)."""

    host_id: str
    hosts: tuple[str, ...]            # the configured host universe
    num_tenants: int
    d_model: int = 16
    num_bits: int = 6
    num_tables: int = 8
    alpha: float = 4.0
    warmup_items: float = 64.0
    hash_mode: str = "dense"
    insert_all: bool = False
    count_dtype: str = "int32"
    chunk_T: int = 8                  # scan steps per ingest chunk
    epoch_chunks: int = 2             # chunks per epoch (gossip cadence)
    gossip_keep: int = 2
    ckpt_root: str | None = None      # shared fs root; None = no ckpts
    ckpt_every_epochs: int = 1
    ckpt_keep: int = 3
    membership: MembershipConfig = MembershipConfig()

    def __post_init__(self):
        if self.host_id not in self.hosts:
            raise ValueError(
                f"host_id {self.host_id!r} not in hosts {self.hosts}")
        if self.epoch_chunks < 1:
            raise ValueError("epoch_chunks must be >= 1")


class ClusterNode:
    """One host of the fleet cluster (see module docstring)."""

    def __init__(self, cfg: ClusterConfig, store,
                 clock=time.monotonic):
        self.cfg = cfg
        self.store = store
        self.clock = clock
        self.filt = FleetDataFilter(
            d_model=cfg.d_model, num_tenants=cfg.num_tenants,
            num_bits=cfg.num_bits, num_tables=cfg.num_tables,
            alpha=cfg.alpha, warmup_items=cfg.warmup_items,
            hash_mode=cfg.hash_mode, insert_all=cfg.insert_all,
            count_dtype=cfg.count_dtype)
        self.runner = StreamRunner(self.filt, chunk_T=cfg.chunk_T,
                                   return_masks=True)
        self.state, self.w = self.runner.init()
        self.map = ShardMap(version=0, hosts=cfg.hosts,
                            num_tenants=cfg.num_tenants)
        self._mask = jnp.asarray(self.map.tenant_mask(cfg.host_id))
        self.heartbeat = HeartbeatWriter(store, cfg.host_id,
                                         cfg.membership, clock)
        self.heartbeat.version = self.map.version
        self.detector = FailureDetector(store, cfg.membership, clock)
        self.gossip = GossipBus(store, cfg.host_id, keep=cfg.gossip_keep)
        self.chunk_idx = 0
        self.epoch = 0
        self.adoptions: list[dict] = []   # observability + test probes
        self.heartbeat.beat()
        # v0 is derivable by every host from the config, but publishing
        # it seeds the store for late joiners and external observers.
        if self.coordinator:
            self._publish_map(self.map)

    # -- identity ----------------------------------------------------------

    @property
    def coordinator(self) -> bool:
        """Acting coordinator = lowest host id in the CURRENT map (no
        election: the map totally orders the candidates, and the
        detector retires a dead coordinator like any other host)."""
        return self.map.hosts[0] == self.cfg.host_id

    def owned(self) -> tuple[int, ...]:
        return self.map.owned_by(self.cfg.host_id)

    # -- hot path ----------------------------------------------------------

    def ingest_chunk(self, feats, tenant_ids):
        """Serve one (chunk_T, B, d+1) feature chunk of mixed-tenant
        batches.  Returns (summary, keeps) — still on device.  Epoch
        boundaries (every ``epoch_chunks`` chunks) publish gossip and
        checkpoints; the chunk program itself is the unchanged
        single-host fleet scan, ownership-masked."""
        self.heartbeat.maybe_beat()
        self.state, summary, keeps = self.runner.consume(
            self.state, self.w, jnp.asarray(feats),
            jnp.asarray(tenant_ids, jnp.int32), tenant_mask=self._mask)
        self.chunk_idx += 1
        if self.chunk_idx % self.cfg.epoch_chunks == 0:
            self._epoch_boundary()
        return summary, keeps

    def probe_scores(self, feats, tenant_ids) -> np.ndarray:
        """Score WITHOUT inserting (read-only serving probe — the test
        hook for masked-score parity while degraded)."""
        buckets = srp.hash_buckets(jnp.asarray(feats), self.w,
                                   self.filt.ace_cfg.srp)
        return np.asarray(fl.fleet_scores(
            self.state, jnp.asarray(tenant_ids, jnp.int32), buckets))

    def _epoch_boundary(self) -> None:
        self.epoch += 1
        host_state = jax.device_get(self.state)
        self.gossip.publish(self.epoch, host_state, self.owned(),
                            map_version=self.map.version)
        if (self.cfg.ckpt_root
                and self.epoch % self.cfg.ckpt_every_epochs == 0):
            ckpt.save(self._ckpt_dir(self.cfg.host_id), self.epoch,
                      self.state, keep=self.cfg.ckpt_keep,
                      extra={"map_version": self.map.version})

    # -- control plane -----------------------------------------------------

    def control_step(self) -> list[str]:
        """One failure-detection/re-shard turn; returns hosts newly
        declared dead this turn (already re-sharded away if this node
        is the acting coordinator)."""
        self.heartbeat.maybe_beat()
        self._apply_newer_map()
        peers = [h for h in self.map.hosts if h != self.cfg.host_id]
        dead = self.detector.poll(peers)
        if dead:
            alive = [h for h in self.map.hosts if h not in dead]
            # the acting coordinator AFTER the deaths publishes — so a
            # dead coordinator cannot block its own replacement
            if alive and alive[0] == self.cfg.host_id:
                new_map = self.map
                for h in dead:
                    new_map = without_host(new_map, h)
                self._publish_map(new_map)
                self._apply_newer_map()
        if self.coordinator:
            self._admit_joiners()
        return dead

    def request_rejoin(self) -> None:
        self.store.set(f"join/{self.cfg.host_id}", str(self.map.version))

    def try_rejoin(self, policy: RejoinPolicy | None = None,
                   sleep=time.sleep) -> bool:
        """Re-enter the cluster after being declared dead: request
        admission and wait with attempt-bounded exponential backoff
        until a map containing this host appears.  Returns False when
        the attempt budget is exhausted (stay out; don't flap)."""
        policy = policy or RejoinPolicy()
        while True:
            self._apply_newer_map()
            if self.cfg.host_id in self.map.hosts:
                policy.reset()
                return True
            delay = policy.next_delay()
            if delay is None:
                return False
            self.request_rejoin()
            self.heartbeat.beat()     # prove liveness to the admitter
            sleep(delay)

    def _admit_joiners(self) -> None:
        for host in self.cfg.hosts:
            if host in self.map.hosts:
                continue
            if self.store.get(f"join/{host}") is None:
                continue
            self.detector.forget(host)     # fresh grace window
            self._publish_map(with_host(self.map, host))
            self.store.delete(f"join/{host}")
            self._apply_newer_map()

    def _publish_map(self, m: ShardMap) -> None:
        cur = self._read_map()
        if cur is None or m.version > cur.version:
            self.store.set(_MAP_KEY, m.to_json())

    def _read_map(self) -> ShardMap | None:
        blob = self.store.get(_MAP_KEY)
        return None if blob is None else ShardMap.from_json(blob)

    def _apply_newer_map(self) -> None:
        m = self._read_map()
        if m is None or m.version <= self.map.version:
            return
        prev = self.map
        old_owned = set(prev.owned_by(self.cfg.host_id))
        self.map = m
        self.heartbeat.version = m.version   # beats now carry the new
        #                                      regime (version fencing)
        for host in set(prev.hosts) - set(m.hosts):
            self.detector.forget(host)
        gained = sorted(set(self.owned()) - old_owned)
        if gained:
            by_prev: dict[str, list[int]] = {}
            for t in gained:
                by_prev.setdefault(prev.owner_of(t), []).append(t)
            for prev_host, tenants in by_prev.items():
                if prev_host != self.cfg.host_id:
                    self._adopt(tenants, prev_host)
        self._mask = jnp.asarray(self.map.tenant_mask(self.cfg.host_id))

    # -- adoption (warm restore of re-homed tenants) -----------------------

    def _adopt(self, tenants, prev_host: str) -> None:
        """Install ``tenants``' sketches from ``prev_host``'s last
        gossiped snapshot and/or newest intact checkpoint — per tenant,
        the intact candidate from the NEWEST shard-map regime wins, ties
        broken by most stream absorbed (max n); candidates failing
        ``resilience.health_check`` are refused (never merged, never
        installed).  Version outranks n deliberately: a stale revived
        host can carry a LARGER n from a divergent zombie timeline, so
        volume is not a fencing token — the map version is (the zombie
        can only hold an old one).  With no intact candidate the tenant
        cold-starts (zero row + fresh warmup) — degraded, still
        serving."""
        snap = self.gossip.latest(prev_host)
        peer_ckpt = self._restore_peer_ckpt(prev_host)
        for t in tenants:
            cands = []
            if snap is not None and t in snap[1]:
                ace = snap[1][t]
                if snapshot_healthy(ace):
                    cands.append(("gossip", snap[0], ace, snap[2]))
            if peer_ckpt is not None:
                epoch, fleet, ver = peer_ckpt
                ace = AceState(counts=np.asarray(fleet.counts[t]),
                               n=np.float32(fleet.n[t]),
                               welford_mean=np.float32(
                                   fleet.welford_mean[t]),
                               welford_m2=np.float32(fleet.welford_m2[t]))
                if snapshot_healthy(ace):
                    cands.append(("checkpoint", epoch, ace, ver))
            record = {"tenant": t, "from_host": prev_host,
                      "at_epoch": self.epoch, "at_chunk": self.chunk_idx,
                      "map_version": self.map.version}
            if not cands:
                self.adoptions.append({**record, "source": "cold",
                                       "source_epoch": None, "n": 0.0})
                continue
            source, src_epoch, ace, _ = max(
                cands, key=lambda c: (int(c[3]), float(c[2].n)))
            self.state = fl.set_tenant(self.state, t, AceState(
                counts=jnp.asarray(ace.counts).astype(
                    self.state.counts.dtype),
                n=jnp.asarray(ace.n, jnp.float32),
                welford_mean=jnp.asarray(ace.welford_mean, jnp.float32),
                welford_m2=jnp.asarray(ace.welford_m2, jnp.float32)))
            self.adoptions.append({**record, "source": source,
                                   "source_epoch": src_epoch,
                                   "n": float(ace.n)})

    def _restore_peer_ckpt(self, host: str):
        """(epoch, host-side FleetState, map_version) from ``host``'s
        newest INTACT checkpoint (PR 7's CRC path: torn/flipped steps
        are skipped, numeric step order — satellite-fixed — picks
        true-newest), or None.  Checkpoints live on a shared filesystem
        root; a deployment without one simply leans on gossip alone.
        Pre-fencing checkpoints carry map_version 0 (sort oldest)."""
        if not self.cfg.ckpt_root:
            return None
        mgr = ckpt.CheckpointManager(self._ckpt_dir(host),
                                     keep=self.cfg.ckpt_keep)
        like = fl.init(self.filt.fleet_cfg)
        tree, manifest = mgr.restore_latest(like)
        if tree is None:
            return None
        ver = int((manifest.get("extra") or {}).get("map_version", 0))
        return int(manifest["step"]), jax.device_get(tree), ver

    def _ckpt_dir(self, host: str) -> str:
        import os
        return os.path.join(self.cfg.ckpt_root, host)
