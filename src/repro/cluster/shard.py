"""Elastic tenant→host assignment via rendezvous (HRW) hashing.

The fleet's tenant axis is collective-free, so "sharding" a fleet
across hosts is pure routing: host h serves the tenants it owns and
ignores the rest (``StreamRunner``'s ``tenant_mask`` makes misroutes
inert).  What the assignment function must guarantee is MINIMAL
MOVEMENT under membership change — when a host dies, ONLY its tenants
may re-home (each survivor's warm sketches stay put), and when a host
(re)joins, only the tenants it wins move.  Rendezvous hashing gives
exactly that: tenant t is owned by ``argmax_h hash(h, t)``, so removing
h from the candidate set changes the argmax only where h was winning,
and adding h changes it only where h now wins.  Consistent-hash rings
give the same property but need virtual nodes for balance; HRW is
balanced by construction at these T/host ratios and is ~5 lines.

A :class:`ShardMap` is an immutable, versioned snapshot of the
assignment — hosts + num_tenants fully determine it, so publishing a
map costs a few hundred JSON bytes, never T entries, and every host
derives identical ownership from the same (version, hosts) pair.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json

import numpy as np


def _weight(host: str, tenant: int) -> int:
    """Deterministic 64-bit HRW weight (stable across processes/runs —
    NEVER Python's salted ``hash``)."""
    digest = hashlib.blake2b(f"{host}|{tenant}".encode(),
                             digest_size=8).digest()
    return int.from_bytes(digest, "big")


def rendezvous_owner(tenant: int, hosts: tuple[str, ...]) -> str:
    """The highest-random-weight owner of ``tenant`` among ``hosts``
    (ties broken by host id — deterministic everywhere)."""
    if not hosts:
        raise ValueError("rendezvous_owner needs at least one host")
    return max(hosts, key=lambda h: (_weight(h, tenant), h))


@dataclasses.dataclass(frozen=True)
class ShardMap:
    """Versioned tenant→host assignment (immutable; derive, don't edit).

    ``hosts`` is the ALIVE set; dead hosts are simply absent (their
    tenants re-home by HRW).  ``version`` totally orders maps — every
    consumer ignores any map older than what it already applied.
    """

    version: int
    hosts: tuple[str, ...]
    num_tenants: int

    def __post_init__(self):
        if not self.hosts:
            raise ValueError("a ShardMap needs at least one live host")
        if len(set(self.hosts)) != len(self.hosts):
            raise ValueError(f"duplicate hosts: {self.hosts}")
        object.__setattr__(self, "hosts", tuple(sorted(self.hosts)))

    def owner_of(self, tenant: int) -> str:
        return rendezvous_owner(tenant, self.hosts)

    def owned_by(self, host: str) -> tuple[int, ...]:
        return tuple(t for t in range(self.num_tenants)
                     if self.owner_of(t) == host)

    def tenant_mask(self, host: str) -> np.ndarray:
        """(T,) float32 ownership mask for ``StreamRunner.consume``."""
        mask = np.zeros(self.num_tenants, np.float32)
        mask[list(self.owned_by(host))] = 1.0
        return mask

    def to_json(self) -> str:
        return json.dumps({"version": self.version,
                           "hosts": list(self.hosts),
                           "num_tenants": self.num_tenants})

    @classmethod
    def from_json(cls, blob: str) -> "ShardMap":
        d = json.loads(blob)
        return cls(version=int(d["version"]), hosts=tuple(d["hosts"]),
                   num_tenants=int(d["num_tenants"]))


def without_host(m: ShardMap, dead: str) -> ShardMap:
    """The successor map after ``dead`` is declared gone (version+1).
    Only ``dead``'s tenants change owner (the HRW guarantee)."""
    hosts = tuple(h for h in m.hosts if h != dead)
    return ShardMap(version=m.version + 1, hosts=hosts,
                    num_tenants=m.num_tenants)


def with_host(m: ShardMap, host: str) -> ShardMap:
    """The successor map after ``host`` (re)joins (version+1).  Only
    tenants ``host`` wins under HRW move — everyone else stays warm."""
    if host in m.hosts:
        return m
    return ShardMap(version=m.version + 1, hosts=m.hosts + (host,),
                    num_tenants=m.num_tenants)
