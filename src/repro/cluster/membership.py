"""Heartbeats, failure detection, and attempt-bounded rejoin backoff.

Liveness is decided from CHANGE, not clocks: each host bumps a
monotonic sequence number under ``hb/<host>`` every
``heartbeat_interval``; the detector records, against its OWN clock,
when it last saw each host's value change.  A host whose value has not
changed for ``failure_timeout`` is DEAD.  Comparing local observation
times (never the writers' timestamps) means nothing here assumes
synchronised clocks across hosts — the only time base is the observer's.

The detector is deliberately a two-state machine (ALIVE → DEAD) with
the SUSPECT stage folded into the timeout: at our gossip cadence the
cost of a false positive is bounded — the "dead" host's tenants re-home
from its last gossiped sketch, and if it was merely slow it comes back
through the join path (:class:`RejoinPolicy`) like any other returning
host.  The rejoin path is the part that must NOT be naive: a flapping
host rejoining in a tight loop would thrash the shard map, so rejoin
attempts are bounded and exponentially backed off, and a host that
exhausts its attempts stays out until an operator intervenes.
"""
from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass(frozen=True)
class MembershipConfig:
    heartbeat_interval: float = 0.2   # seconds between beats
    failure_timeout: float = 1.0      # silence ⇒ DEAD (≥ several beats)

    def __post_init__(self):
        if self.failure_timeout <= self.heartbeat_interval:
            raise ValueError(
                f"failure_timeout ({self.failure_timeout}) must exceed "
                f"heartbeat_interval ({self.heartbeat_interval}) — a "
                "timeout under one beat declares every host dead "
                "between its own heartbeats")


class HeartbeatWriter:
    """Bumps ``hb/<host>`` at most once per interval (cheap to call
    every chunk — the hot loop never needs its own timer).

    The written value is ``"seq:map_version"`` — the shard-map regime
    the writer currently believes in rides every beat, so the detector
    can refuse to count liveness from a host revived with a STALE map
    (a rewound zombie's beats would otherwise look like fresh change).
    ``version`` is owned by the node and updated whenever it applies a
    newer map; until the zombie catches up to the current map its
    beats do not reset anyone's failure timer."""

    def __init__(self, store, host: str, cfg: MembershipConfig,
                 clock=time.monotonic):
        self._store = store
        self._key = f"hb/{host}"
        self._cfg = cfg
        self._clock = clock
        self._seq = 0
        self._last = None
        self.version = 0          # current shard-map version (fencing)

    def beat(self) -> None:
        self._seq += 1
        self._store.set(self._key, f"{self._seq}:{int(self.version)}")
        self._last = self._clock()

    def maybe_beat(self) -> bool:
        now = self._clock()
        if self._last is None or \
                now - self._last >= self._cfg.heartbeat_interval:
            self.beat()
            return True
        return False


class FailureDetector:
    """Change-based liveness: per host, the local time its heartbeat
    value last CHANGED.  ``poll`` returns the currently-dead subset of
    the hosts asked about.  A host never seen at all is given a grace
    window from the time it was first asked about (startup is not
    death)."""

    def __init__(self, store, cfg: MembershipConfig,
                 clock=time.monotonic):
        self._store = store
        self._cfg = cfg
        self._clock = clock
        # host -> (last_value | None, local time of last change/first
        #          ask, highest map_version ever seen from the host)
        self._seen: dict[str, tuple[str | None, float, int]] = {}

    @staticmethod
    def _version_of(value) -> int:
        """map_version carried by a heartbeat value; legacy bare-seq
        beats (no ':') and unreadable values count as version 0."""
        if value is None:
            return 0
        _, sep, ver = str(value).partition(":")
        if not sep:
            return 0
        try:
            return int(ver)
        except ValueError:
            return 0

    def poll(self, hosts) -> list[str]:
        now = self._clock()
        dead = []
        for host in hosts:
            value = self._store.get(f"hb/{host}")
            prev = self._seen.get(host)
            if prev is None:
                self._seen[host] = (value, now, self._version_of(value))
                continue
            pval, ptime, pver = prev
            if value != pval:
                ver = self._version_of(value)
                if ver >= pver:
                    self._seen[host] = (value, now, ver)
                    continue
                # STALE-VERSION beat (revived zombie with an old map):
                # record the value so repeats don't look like change,
                # but do NOT reset the failure clock — the host is not
                # live in any regime that matters until it catches up.
                self._seen[host] = (value, ptime, pver)
            if now - self._seen[host][1] > self._cfg.failure_timeout:
                dead.append(host)
        return dead

    def forget(self, host: str) -> None:
        """Drop observation state (host left the map; a rejoin starts a
        fresh grace window)."""
        self._seen.pop(host, None)


@dataclasses.dataclass
class RejoinPolicy:
    """Attempt-bounded exponential backoff for hosts re-entering the
    cluster.  ``next_delay`` returns the wait before the next attempt,
    or None when the budget is exhausted (stay out; don't flap)."""

    max_attempts: int = 5
    base_delay: float = 0.1
    max_delay: float = 5.0
    attempt: int = 0

    def next_delay(self) -> float | None:
        if self.attempt >= self.max_attempts:
            return None
        delay = min(self.base_delay * (2.0 ** self.attempt),
                    self.max_delay)
        self.attempt += 1
        return delay

    def reset(self) -> None:
        """A successful (re)admission refunds the budget."""
        self.attempt = 0
