"""Epoch-boundary sketch gossip: serialize, CRC-frame, publish, adopt.

Mergeability is the whole story here: an ACE sketch is a CRDT (counts
add, moments merge by Chan's rule), so a PEER'S COPY of a tenant's
sketch is not a cache — it is a valid warm restore point.  Each host
publishes its owned tenants' sketches once per epoch (a few KB per
tenant at smoke shapes, ``AceConfig.memory_bytes`` each at paper
shapes); when a host dies, the survivors adopt its tenants from the
last gossiped snapshot, losing at most the partial epoch since the
last publish — no replay log, no quorum, no transfer of the live
stream.

Integrity is layered the same way PR 7's checkpoints are:

1. transport: every array in a snapshot carries a CRC32 in the framing
   manifest; a torn or bit-flipped BLOB fails :class:`SnapshotCorrupt`
   at unpack.
2. semantics: a sketch corrupted BEFORE serialization has valid CRCs,
   so adoption additionally runs every candidate through
   ``repro.resilience.health_check`` (count conservation per table,
   finite moments) and refuses to merge or install one that fails —
   a poisoned peer cannot infect the survivors.

Publishing flips an epoch pointer LAST (blob under ``gossip/<host>/<e>``,
then ``gossip/<host>/latest`` ← e), so a reader following the pointer
never sees a half-written blob even on a store with no transactions.
"""
from __future__ import annotations

import io
import json
import zlib

import numpy as np

from repro.core.sketch import AceState
from repro.fleet.state import FleetState


class SnapshotCorrupt(RuntimeError):
    """A gossiped snapshot failed CRC/framing verification."""


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def pack_snapshot(state: FleetState, tenants, epoch: int) -> bytes:
    """Serialize ``tenants``' rows of a host-side fleet into one
    CRC-framed npz blob.  ``state`` leaves must be host numpy (callers
    ``jax.device_get`` once per epoch — this is control plane)."""
    tenants = [int(t) for t in tenants]
    counts = np.ascontiguousarray(
        np.asarray(state.counts)[tenants])            # (t, L, 2^K)
    stats = np.stack([np.asarray(state.n)[tenants],
                      np.asarray(state.welford_mean)[tenants],
                      np.asarray(state.welford_m2)[tenants]]
                     ).astype(np.float32)             # (3, t)
    manifest = {
        "epoch": int(epoch),
        "tenants": tenants,
        "count_dtype": str(counts.dtype),
        "crc_counts": _crc(counts),
        "crc_stats": _crc(stats),
    }
    buf = io.BytesIO()
    np.savez(buf, counts=counts, stats=stats,
             manifest=np.frombuffer(json.dumps(manifest).encode(),
                                    np.uint8))
    return buf.getvalue()


def unpack_snapshot(blob: bytes) -> tuple[int, dict[int, AceState]]:
    """(epoch, tenant → AceState).  Raises :class:`SnapshotCorrupt` on
    any framing/CRC mismatch — transport corruption stops HERE, before
    any state is constructed."""
    try:
        with np.load(io.BytesIO(blob)) as z:
            manifest = json.loads(bytes(z["manifest"]).decode())
            counts, stats = z["counts"], z["stats"]
    except Exception as e:
        raise SnapshotCorrupt(f"unreadable snapshot blob ({e})") from e
    if (_crc(counts) != manifest["crc_counts"]
            or _crc(stats) != manifest["crc_stats"]):
        raise SnapshotCorrupt("snapshot CRC mismatch")
    if counts.shape[0] != len(manifest["tenants"]) \
            or stats.shape != (3, len(manifest["tenants"])):
        raise SnapshotCorrupt("snapshot shape/manifest mismatch")
    states = {}
    for i, t in enumerate(manifest["tenants"]):
        states[int(t)] = AceState(
            counts=counts[i], n=np.float32(stats[0, i]),
            welford_mean=np.float32(stats[1, i]),
            welford_m2=np.float32(stats[2, i]))
    return int(manifest["epoch"]), states


def snapshot_healthy(ace: AceState) -> bool:
    """Semantic validation gate (runs BEFORE any merge/install): the
    repro.resilience invariants — per-table count conservation against
    n, finite moments.  A bit-flip applied before serialization has
    valid CRCs and fails exactly here."""
    import jax.numpy as jnp

    from repro import resilience as rz
    dev = AceState(counts=jnp.asarray(ace.counts),
                   n=jnp.asarray(ace.n, jnp.float32),
                   welford_mean=jnp.asarray(ace.welford_mean, jnp.float32),
                   welford_m2=jnp.asarray(ace.welford_m2, jnp.float32))
    report = rz.health_check(dev)
    return bool(np.asarray(report.ok))


class GossipBus:
    """Per-host publish/fetch of epoch snapshots over a ControlStore.

    ``keep`` epochs stay resident per host (older blobs are deleted at
    publish time — the store is a mailbox, not an archive);
    ``published_bytes`` accounts the control-plane traffic so the bench
    and docs can put a number on gossip cost per epoch.
    """

    def __init__(self, store, host: str, keep: int = 2):
        self._store = store
        self._host = host
        self._keep = max(int(keep), 1)
        self.published_bytes = 0
        self.published_epochs = 0

    def publish(self, epoch: int, state: FleetState, tenants) -> int:
        """Publish owned tenants' sketches for ``epoch``; returns blob
        bytes (the per-epoch gossip bill)."""
        blob = pack_snapshot(state, tenants, epoch)
        self._store.set_bytes(f"gossip/{self._host}/{epoch}", blob)
        # pointer flips LAST — readers never chase a half-written blob
        self._store.set(f"gossip/{self._host}/latest", str(epoch))
        self._store.delete(f"gossip/{self._host}/{epoch - self._keep}")
        self.published_bytes += len(blob)
        self.published_epochs += 1
        return len(blob)

    def latest(self, host: str) -> tuple[int, dict[int, AceState]] | None:
        """The newest intact snapshot a peer published, or None.  A
        corrupt newest blob falls back to the previous kept epoch —
        same newest-intact-first discipline as ``restore_latest``."""
        ptr = self._store.get(f"gossip/{host}/latest")
        if ptr is None:
            return None
        epoch = int(ptr)
        for e in range(epoch, epoch - self._keep, -1):
            blob = self._store.get_bytes(f"gossip/{host}/{e}")
            if blob is None:
                continue
            try:
                return unpack_snapshot(blob)
            except SnapshotCorrupt:
                continue
        return None
