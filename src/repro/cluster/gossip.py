"""Epoch-boundary sketch gossip: serialize, CRC-frame, publish, adopt.

Mergeability is the whole story here: an ACE sketch is a CRDT (counts
add, moments merge by Chan's rule), so a PEER'S COPY of a tenant's
sketch is not a cache — it is a valid warm restore point.  Each host
publishes its owned tenants' sketches once per epoch (a few KB per
tenant at smoke shapes, ``AceConfig.memory_bytes`` each at paper
shapes); when a host dies, the survivors adopt its tenants from the
last gossiped snapshot, losing at most the partial epoch since the
last publish — no replay log, no quorum, no transfer of the live
stream.

Integrity is layered the same way PR 7's checkpoints are:

1. transport: every array in a snapshot carries a CRC32 in the framing
   manifest; a torn or bit-flipped BLOB fails :class:`SnapshotCorrupt`
   at unpack.
2. semantics: a sketch corrupted BEFORE serialization has valid CRCs,
   so adoption additionally runs every candidate through
   ``repro.resilience.health_check`` (count conservation per table,
   finite moments) and refuses to merge or install one that fails —
   a poisoned peer cannot infect the survivors.

Publishing flips an epoch pointer LAST (blob under ``gossip/<host>/<e>``,
then ``gossip/<host>/latest`` ← e), so a reader following the pointer
never sees a half-written blob even on a store with no transactions.
"""
from __future__ import annotations

import io
import json
import zlib

import numpy as np

from repro.core.sketch import AceState
from repro.fleet.state import FleetState


class SnapshotCorrupt(RuntimeError):
    """A gossiped snapshot failed CRC/framing verification."""


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def pack_snapshot(state: FleetState, tenants, epoch: int,
                  map_version: int = 0) -> bytes:
    """Serialize ``tenants``' rows of a host-side fleet into one
    CRC-framed npz blob.  ``state`` leaves must be host numpy (callers
    ``jax.device_get`` once per epoch — this is control plane).

    ``map_version`` stamps the shard-map regime the publisher believed
    it owned these tenants under — the fencing token a revived host
    with a stale map cannot forge (it can only hold an OLD version).
    """
    tenants = [int(t) for t in tenants]
    counts = np.ascontiguousarray(
        np.asarray(state.counts)[tenants])            # (t, L, 2^K)
    stats = np.stack([np.asarray(state.n)[tenants],
                      np.asarray(state.welford_mean)[tenants],
                      np.asarray(state.welford_m2)[tenants]]
                     ).astype(np.float32)             # (3, t)
    manifest = {
        "epoch": int(epoch),
        "map_version": int(map_version),
        "tenants": tenants,
        "count_dtype": str(counts.dtype),
        "crc_counts": _crc(counts),
        "crc_stats": _crc(stats),
    }
    buf = io.BytesIO()
    np.savez(buf, counts=counts, stats=stats,
             manifest=np.frombuffer(json.dumps(manifest).encode(),
                                    np.uint8))
    return buf.getvalue()


def unpack_snapshot(blob: bytes) \
        -> tuple[int, dict[int, AceState], int]:
    """(epoch, tenant → AceState, map_version).  Raises
    :class:`SnapshotCorrupt` on any framing/CRC mismatch — transport
    corruption stops HERE, before any state is constructed.  Blobs
    packed before version fencing carry ``map_version`` 0 (every real
    map publishes at version >= 0, so legacy blobs sort oldest)."""
    try:
        with np.load(io.BytesIO(blob)) as z:
            manifest = json.loads(bytes(z["manifest"]).decode())
            counts, stats = z["counts"], z["stats"]
    except Exception as e:
        raise SnapshotCorrupt(f"unreadable snapshot blob ({e})") from e
    if (_crc(counts) != manifest["crc_counts"]
            or _crc(stats) != manifest["crc_stats"]):
        raise SnapshotCorrupt("snapshot CRC mismatch")
    if counts.shape[0] != len(manifest["tenants"]) \
            or stats.shape != (3, len(manifest["tenants"])):
        raise SnapshotCorrupt("snapshot shape/manifest mismatch")
    states = {}
    for i, t in enumerate(manifest["tenants"]):
        states[int(t)] = AceState(
            counts=counts[i], n=np.float32(stats[0, i]),
            welford_mean=np.float32(stats[1, i]),
            welford_m2=np.float32(stats[2, i]))
    return (int(manifest["epoch"]), states,
            int(manifest.get("map_version", 0)))


def snapshot_healthy(ace: AceState) -> bool:
    """Semantic validation gate (runs BEFORE any merge/install): the
    repro.resilience invariants — per-table count conservation against
    n, finite moments.  A bit-flip applied before serialization has
    valid CRCs and fails exactly here."""
    import jax.numpy as jnp

    from repro import resilience as rz
    dev = AceState(counts=jnp.asarray(ace.counts),
                   n=jnp.asarray(ace.n, jnp.float32),
                   welford_mean=jnp.asarray(ace.welford_mean, jnp.float32),
                   welford_m2=jnp.asarray(ace.welford_m2, jnp.float32))
    report = rz.health_check(dev)
    return bool(np.asarray(report.ok))


class GossipBus:
    """Per-host publish/fetch of epoch snapshots over a ControlStore.

    ``keep`` epochs stay resident per host (older blobs are deleted at
    publish time — the store is a mailbox, not an archive);
    ``published_bytes`` accounts the control-plane traffic so the bench
    and docs can put a number on gossip cost per epoch.

    **Version fencing** (split-brain narrow slice): a host revived with
    a stale shard map — a resumed VM, a restored backup, a zombie that
    slept through its own death — holds an OLD ``map_version`` and an
    old epoch counter, and its next publish would regress the latest
    pointer over state the cluster has since moved past.  Every publish
    therefore carries the publisher's map version, and a per-host fence
    key records the high-water ``(map_version, epoch)`` ever published:
    a publish that does not advance it is a counted no-op
    (``stale_publishes``), and ``latest`` refuses blobs below the
    fenced version even if one was raced into the store.
    """

    def __init__(self, store, host: str, keep: int = 2):
        self._store = store
        self._host = host
        self._keep = max(int(keep), 1)
        self.published_bytes = 0
        self.published_epochs = 0
        self.stale_publishes = 0   # fenced-off (rejected) publish calls

    def _fence(self, host: str) -> tuple[int, int]:
        """High-water (map_version, epoch) published by ``host`` — read
        from the STORE, not memory: a revived host builds a fresh bus
        and must still see its own pre-death high-water mark."""
        raw = self._store.get(f"gossip/{host}/fence")
        if raw is None:
            return (-1, -1)
        ver, _, ep = str(raw).partition(":")
        return (int(ver), int(ep))

    def publish(self, epoch: int, state: FleetState, tenants,
                map_version: int = 0) -> int:
        """Publish owned tenants' sketches for ``epoch``; returns blob
        bytes (the per-epoch gossip bill), or 0 when the publish is
        FENCED: ``(map_version, epoch)`` must strictly advance the
        host's high-water mark, so a stale revived host can neither
        overwrite newer snapshots nor regress the latest pointer."""
        fence = self._fence(self._host)
        if (int(map_version), int(epoch)) <= fence:
            self.stale_publishes += 1
            return 0
        blob = pack_snapshot(state, tenants, epoch,
                             map_version=map_version)
        self._store.set_bytes(f"gossip/{self._host}/{epoch}", blob)
        # pointer flips LAST — readers never chase a half-written blob
        self._store.set(f"gossip/{self._host}/latest", str(epoch))
        self._store.set(f"gossip/{self._host}/fence",
                        f"{int(map_version)}:{int(epoch)}")
        self._store.delete(f"gossip/{self._host}/{epoch - self._keep}")
        self.published_bytes += len(blob)
        self.published_epochs += 1
        return len(blob)

    def latest(self, host: str) \
            -> tuple[int, dict[int, AceState], int] | None:
        """The newest intact NON-STALE snapshot a peer published, or
        None.  A corrupt newest blob falls back to the previous kept
        epoch — same newest-intact-first discipline as
        ``restore_latest``; a blob stamped with a map version below the
        host's fence is refused the same way (it can only exist through
        a write race with a stale publisher)."""
        ptr = self._store.get(f"gossip/{host}/latest")
        if ptr is None:
            return None
        fence_ver = self._fence(host)[0]
        epoch = int(ptr)
        for e in range(epoch, epoch - self._keep, -1):
            blob = self._store.get_bytes(f"gossip/{host}/{e}")
            if blob is None:
                continue
            try:
                got = unpack_snapshot(blob)
            except SnapshotCorrupt:
                continue
            if got[2] < fence_ver:
                continue
            return got
        return None
