"""Control-plane key/value store for the cluster (repro.cluster).

Heartbeats, shard maps, join requests, and gossiped sketches all ride a
tiny string/bytes KV interface with exactly two implementations:

* :class:`MemStore` — an in-process dict (thread-safe).  Every cluster
  state machine (failure detection, re-shard, gossip, rejoin backoff)
  is unit-testable single-process against it, with a fake clock.
* :class:`DistributedStore` — the coordination-service KV store every
  ``jax.distributed.initialize()`` process already has (the same
  service that serves device enumeration), via
  ``jax._src.distributed.global_state.client``.  No extra server, no
  extra port: if the cluster can run a multi-process jax program at
  all, it has this store.

The interface is deliberately last-writer-wins with non-blocking reads
(`get` returns None on absence): every cluster protocol on top is
designed so that a torn read is indistinguishable from a slightly
stale one — heartbeats are monotonic sequence numbers, shard maps are
versioned and self-describing, gossip blobs are CRC-framed and
published under epoch-stamped keys with a pointer flipped last.
"""
from __future__ import annotations

import threading


class MemStore:
    """In-process ControlStore — the unit-test double (thread-safe)."""

    def __init__(self):
        self._data: dict[str, bytes] = {}
        self._lock = threading.Lock()

    def set(self, key: str, value: str) -> None:
        self.set_bytes(key, value.encode())

    def set_bytes(self, key: str, value: bytes) -> None:
        with self._lock:
            self._data[key] = bytes(value)

    def get(self, key: str) -> str | None:
        b = self.get_bytes(key)
        return None if b is None else b.decode()

    def get_bytes(self, key: str) -> bytes | None:
        with self._lock:
            return self._data.get(key)

    def delete(self, key: str) -> None:
        with self._lock:
            self._data.pop(key, None)

    def keys(self, prefix: str) -> list[str]:
        with self._lock:
            return sorted(k for k in self._data if k.startswith(prefix))


class DistributedStore:
    """ControlStore over the jax.distributed coordination-service KV.

    Requires ``jax.distributed.initialize()`` to have run in this
    process.  Reads are best-effort non-blocking: the service only
    exposes a blocking get, so ``get`` polls with a short timeout and
    maps NOT_FOUND/DEADLINE onto None (absence and not-yet-written are
    the same thing to every protocol built on this store).
    """

    def __init__(self, timeout_ms: int = 200):
        from jax._src import distributed

        client = distributed.global_state.client
        if client is None:
            raise RuntimeError(
                "DistributedStore needs jax.distributed.initialize() "
                "to have run in this process (no coordination client)")
        self._client = client
        self._timeout_ms = int(timeout_ms)

    def set(self, key: str, value: str) -> None:
        self._client.key_value_set(key, value, allow_overwrite=True)

    def set_bytes(self, key: str, value: bytes) -> None:
        self._client.key_value_set_bytes(key, bytes(value),
                                         allow_overwrite=True)

    def get(self, key: str) -> str | None:
        try:
            return self._client.blocking_key_value_get(
                key, self._timeout_ms)
        except Exception:           # NOT_FOUND / DEADLINE_EXCEEDED
            return None

    def get_bytes(self, key: str) -> bytes | None:
        try:
            return self._client.blocking_key_value_get_bytes(
                key, self._timeout_ms)
        except Exception:
            return None

    def delete(self, key: str) -> None:
        try:
            self._client.key_value_delete(key)
        except Exception:
            pass                    # deleting an absent key is a no-op

    def keys(self, prefix: str) -> list[str]:
        try:
            entries = self._client.key_value_dir_get(prefix)
        except Exception:
            return []
        return sorted(k for k, _ in entries)
