"""Optimizers from scratch (no optax in this environment — and the
assignment requires the substrate be built, not assumed).

All optimizers share the contract:
    state = opt.init(params)
    new_params, new_state = opt.update(params, grads, state, step, lr)

States are pytrees (checkpointable); updates are jit-safe.  Master weights
stay in the params' own dtype (fp32 recommended); moments are fp32.

Implemented: SGD(+momentum), AdamW (decoupled decay), Adafactor (factored
second moments — the memory-saver for 100B+ runs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), \
        norm


# ---------------------------------------------------------------------------
# SGD
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Sgd:
    momentum: float = 0.9
    nesterov: bool = False

    def init(self, params):
        if self.momentum == 0.0:
            return {}
        return {"m": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def state_pspecs(self, param_pspecs):
        """PartitionSpecs for the optimizer state, given the params'."""
        if self.momentum == 0.0:
            return {}
        return {"m": param_pspecs}

    def update(self, params, grads, state, step, lr):
        del step
        if self.momentum == 0.0:
            new_p = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new_p, state
        new_m = jax.tree.map(
            lambda m, g: self.momentum * m + g.astype(jnp.float32),
            state["m"], grads)
        upd = new_m if not self.nesterov else jax.tree.map(
            lambda m, g: self.momentum * m + g.astype(jnp.float32),
            new_m, grads)
        new_p = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) - lr * u).astype(p.dtype),
            params, upd)
        return new_p, {"m": new_m}


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params)}

    def state_pspecs(self, param_pspecs):
        """Moments shard exactly like their parameters (ZeRO-free TP/DP)."""
        return {"m": param_pspecs, "v": param_pspecs}

    def update(self, params, grads, state, step, lr):
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - self.b1 ** t
        c2 = 1.0 - self.b2 ** t

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            mhat = m / c1
            vhat = v / c2
            p32 = p.astype(jnp.float32)
            step_ = mhat / (jnp.sqrt(vhat) + self.eps) \
                + self.weight_decay * p32
            return (p32 - lr * step_).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v}


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern) — factored second moment, no first moment.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Adafactor:
    decay_pow: float = 0.8        # beta2_t = 1 - t^-0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0

    def _factored(self, shape) -> bool:
        return len(shape) >= 2

    def init(self, params):
        def per_leaf(p):
            if self._factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"slots": jax.tree.map(per_leaf, params,
                                      is_leaf=lambda x: hasattr(x, "shape"))}

    def state_pspecs(self, param_pspecs):
        """Factored rows/cols inherit the matching prefix of the param spec.

        Needs the param SHAPES to know which leaves are factored, so the
        caller passes pspecs aligned with the params tree; here we derive
        vr/vc specs structurally from each param's pspec length.
        """
        from jax.sharding import PartitionSpec as P

        def per_leaf(ps):
            entries = tuple(ps)
            # vr drops the last dim's entry; vc drops the second-to-last.
            vr = P(*entries[:-1]) if len(entries) >= 1 else P()
            vc = P(*(entries[:-2] + entries[-1:])) if len(entries) >= 2 \
                else P()
            return {"vr": vr, "vc": vc, "v": P(*entries)}

        # NOTE: includes all three keys; the dryrun reconciles against the
        # abstract state structure (which has either {vr,vc} or {v}).
        return {"slots": jax.tree.map(
            per_leaf, param_pspecs,
            is_leaf=lambda x: isinstance(x, P))}

    def update(self, params, grads, state, step, lr):
        t = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - t ** (-self.decay_pow)

        def upd(p, g, slot):
            g = g.astype(jnp.float32)
            g2 = g * g + self.eps
            if self._factored(p.shape):
                vr = beta2 * slot["vr"] + (1 - beta2) * jnp.mean(g2, -1)
                vc = beta2 * slot["vc"] + (1 - beta2) * jnp.mean(g2, -2)
                denom = jnp.sqrt(
                    vr[..., :, None] * vc[..., None, :]
                    / (jnp.mean(vr, -1, keepdims=True)[..., None] + 1e-30))
                u = g / (denom + 1e-30)
                new_slot = {"vr": vr, "vc": vc}
            else:
                v = beta2 * slot["v"] + (1 - beta2) * g2
                u = g / (jnp.sqrt(v) + 1e-30)
                new_slot = {"v": v}
            # update clipping (RMS <= threshold)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            p32 = p.astype(jnp.float32)
            if self.weight_decay:
                u = u + self.weight_decay * p32
            return (p32 - lr * u).astype(p.dtype), new_slot

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["slots"])
        out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        return tdef.unflatten([o[0] for o in out]), \
            {"slots": tdef.unflatten([o[1] for o in out])}


def make_optimizer(name: str, **kw):
    return {"sgd": Sgd, "adamw": AdamW, "adafactor": Adafactor}[name](**kw)


def optimizer_memory_bytes(name: str, param_count: int,
                           param_bytes: int = 4) -> int:
    """Analytic optimizer-state footprint (DESIGN.md capacity planning)."""
    per = {"sgd": 4, "adamw": 8, "adafactor": 0.1}[name]
    return int(param_count * (param_bytes + per))
