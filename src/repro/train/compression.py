"""Gradient compression for cross-pod all-reduce (distributed-optimization
trick for the 1000+-node regime).

int8 stochastic-rounding quantisation with per-leaf scale + error feedback
(residual carried in the optimizer state).  The cross-pod gradient
all-reduce then moves 1/4 the bytes; the within-pod reduce stays full
precision.  Error feedback keeps the scheme convergent (Karimireddy et al.,
2019) — the quantisation error is added back into the next step's gradient.

The compressed collective is expressed as quantise → psum → dequantise so
XLA emits an int8 all-reduce on the "pod" axis (see train_loop usage).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EfState(NamedTuple):
    residual: dict   # pytree matching grads, fp32


def init_error_feedback(grads_shape_tree) -> EfState:
    return EfState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape_tree))


def quantise_int8(x: jax.Array, key) -> tuple[jax.Array, jax.Array]:
    """Stochastic-rounding int8 with a per-tensor scale."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    scaled = x32 / scale
    noise = jax.random.uniform(key, x.shape, jnp.float32) - 0.5
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return q, scale


def dequantise_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads_with_ef(grads, ef: EfState, key):
    """Returns (quantised pytree, scales pytree, new EfState).

    The residual (what int8 couldn't represent) feeds back next step.
    """
    leaves, tdef = jax.tree.flatten(grads)
    res = tdef.flatten_up_to(ef.residual)
    keys = jax.random.split(key, len(leaves))
    qs, scales, new_res = [], [], []
    for g, r, k in zip(leaves, res, keys):
        corrected = g.astype(jnp.float32) + r
        q, s = quantise_int8(corrected, k)
        deq = dequantise_int8(q, s)
        qs.append(q)
        scales.append(s)
        new_res.append(corrected - deq)
    return (tdef.unflatten(qs), tdef.unflatten(scales),
            EfState(residual=tdef.unflatten(new_res)))


def decompress_grads(qs, scales):
    return jax.tree.map(dequantise_int8, qs, scales)


def compression_ratio(grads) -> float:
    """Bytes(int8+scales) / bytes(fp32)."""
    total = sum(g.size for g in jax.tree.leaves(grads))
    n_leaves = len(jax.tree.leaves(grads))
    return (total * 1 + n_leaves * 4) / (total * 4)
