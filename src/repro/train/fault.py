"""Fault tolerance: the ACE gradient monitor + rollback/skip policy +
straggler/preemption handling notes-as-code.

This is where the paper's technique becomes a FIRST-CLASS framework
feature: the per-step gradient-statistics vector (per-block gradient norms,
bias-augmented — see below) is streamed into an ACE sketch.  A healthy run
concentrates in a cone of that feature space; a corrupted step (flipped
bits from a bad host, a poisoned batch, an optimizer blow-up) lands outside
it and its ACE score collapses below μ − α·σ — O(K·L) work and 4 MB of
state, per the paper's headline claims, vs storing gradient history.

Policy on anomaly: SKIP the step (don't apply the update) and count it;
``rollback_needed`` trips after ``max_consecutive`` anomalies, signalling
the driver to restore the last checkpoint (repro.train.checkpoint).

Straggler mitigation (documented design, exercised in tests via the
timeout hook): SPMD training is synchronous, so a straggler is detected as
a step-time SLO breach on the host; the driver responds by (1) excluding
the slow host at the next restart boundary (elastic re-mesh via the
checkpoint path — topology is never baked into the checkpoint), or
(2) proactive restart from the latest checkpoint.  Both reuse exactly the
restore path tested in tests/test_train.py.

NOTE on SRP: gradient-norm features are nonnegative with magnitude
structure, and SRP is scale-invariant, so features are bias-augmented
(x ↦ [x, c]) making magnitude anomalies angular — see
repro/data/synthetic.bias_augment and DESIGN.md.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import sketch as sk
from repro.core.sketch import AceConfig, AceState


class MonitorState(NamedTuple):
    ace: AceState
    anomalies: jax.Array          # () f32 — total anomalous steps
    consecutive: jax.Array        # () f32 — current anomalous run length
    warmup_left: jax.Array        # () f32 — steps before decisions arm


@dataclasses.dataclass(frozen=True)
class GradMonitor:
    """ACE-based training-step anomaly detector (pure; jit-compatible)."""

    feature_dim: int
    num_bits: int = 12
    num_tables: int = 32
    alpha: float = 4.0            # μ/n − α·σ_rate decision threshold
    warmup: int = 20              # steps before decisions arm
    bias_const: float = 1.0
    max_consecutive: int = 3

    @property
    def ace_cfg(self) -> AceConfig:
        return AceConfig(dim=self.feature_dim + 1, num_bits=self.num_bits,
                         num_tables=self.num_tables, seed=17,
                         welford_min_n=float(self.warmup))

    def init(self) -> tuple[MonitorState, jax.Array]:
        cfg = self.ace_cfg
        return MonitorState(
            ace=sk.init(cfg),
            anomalies=jnp.zeros((), jnp.float32),
            consecutive=jnp.zeros((), jnp.float32),
            warmup_left=jnp.asarray(float(self.warmup), jnp.float32),
        ), sk.make_params(cfg)

    def features(self, grads, loss: jax.Array) -> jax.Array:
        """Per-leaf gradient log-norms + loss, padded to feature_dim, then
        bias-augmented.  Cheap: one reduction per leaf."""
        norms = [jnp.log1p(jnp.linalg.norm(g.astype(jnp.float32)))
                 for g in jax.tree.leaves(grads)]
        vec = jnp.stack(norms[: self.feature_dim - 1] if len(norms)
                        >= self.feature_dim else norms)
        pad = self.feature_dim - 1 - vec.shape[0]
        if pad > 0:
            vec = jnp.concatenate([vec, jnp.zeros((pad,), jnp.float32)])
        vec = jnp.concatenate(
            [vec, jnp.log1p(jnp.abs(loss.astype(jnp.float32)))[None]])
        return jnp.concatenate(
            [vec, jnp.asarray([self.bias_const], jnp.float32)])

    def step(self, state: MonitorState, w: jax.Array, grads,
             loss: jax.Array):
        """Score this step's features, update the sketch, decide.

        Returns (new_state, is_anomaly (bool), score).
        """
        cfg = self.ace_cfg
        feat = self.features(grads, loss)[None, :]          # (1, d+1)
        score = sk.score(state.ace, w, feat, cfg)[0]
        # rate space: stationary stream -> meaningful σ (see sketch.py)
        rate = score / jnp.maximum(state.ace.n, 1.0)
        mu_rate = sk.mean_rate(state.ace)
        sigma = sk.sigma_welford(state.ace)
        armed = state.warmup_left <= 0.0
        is_anom = jnp.logical_and(armed,
                                  rate < mu_rate - self.alpha * sigma)

        # anomalous steps are NOT inserted — they must not poison the sketch
        new_ace = jax.lax.cond(
            is_anom, lambda: state.ace,
            lambda: sk.insert(state.ace, w, feat, cfg))
        new_state = MonitorState(
            ace=new_ace,
            anomalies=state.anomalies + is_anom.astype(jnp.float32),
            consecutive=jnp.where(is_anom, state.consecutive + 1.0, 0.0),
            warmup_left=jnp.maximum(state.warmup_left - 1.0, 0.0),
        )
        return new_state, is_anom, score

    def rollback_needed(self, state: MonitorState) -> jax.Array:
        return state.consecutive >= self.max_consecutive


@dataclasses.dataclass
class StepTimer:
    """Host-side straggler detector: flags steps breaching the SLO."""
    slo_seconds: float
    _last: float = dataclasses.field(default_factory=time.perf_counter)
    breaches: int = 0

    def tick(self) -> bool:
        now = time.perf_counter()
        dt = now - self._last
        self._last = now
        if dt > self.slo_seconds:
            self.breaches += 1
            return True
        return False
