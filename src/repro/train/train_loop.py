"""The training step/loop: grad-accum microbatching, remat, mixed precision,
ACE data filter + ACE gradient monitor compiled into the step, optional
int8 error-feedback gradient compression, checkpoint/restart.

Everything dynamic lives in one TrainState pytree so the step is a pure
(state, batch) -> (state, metrics) function — jit/pjit-able, and the dry-run
lowers exactly what production would run.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sk
from repro.core.sketch import AceState
from repro.data.pipeline import AceDataFilter, DataStream, StreamConfig
from repro.dist.mesh import sketch_pspecs
from repro.models.registry import Arch, is_whisper
from repro.train import checkpoint as ckpt_lib
from repro.train.compression import (EfState, compress_grads_with_ef,
                                     decompress_grads, init_error_feedback)
from repro.train.fault import GradMonitor, MonitorState, StepTimer
from repro.train.optim import clip_by_global_norm, global_norm, \
    make_optimizer
from repro.train.schedule import ConstantSchedule, CosineSchedule


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    microbatches: int = 1            # grad accumulation
    remat: bool = True
    remat_policy: str = "full"   # "dots": save matmul outs (C1)
    use_data_filter: bool = True     # ACE filter on sequence embeddings
    filter_chunk: int = 0            # T>1: run the data filter as ONE
                                     # scan program per T batches
                                     # (repro.stream.StreamRunner) instead
                                     # of per-batch inside train_step
    filter_window_epochs: int = 1    # >1: sliding-window filter — the
                                     # sketch becomes a repro.window epoch
                                     # ring so the admit threshold tracks
                                     # stream drift instead of freezing
    filter_window_decay: float = 1.0  # γ epoch decay (1.0 = hard window)
    filter_rotate_every: int = 0     # filter steps (batches) per epoch
    filter_threshold_mode: str = "mu_sigma"  # "mu_sigma" | "quantile":
                                     # quantile mode pins the filter's
                                     # flag rate at filter_quantile_q
                                     # regardless of the embedding score
                                     # distribution's tails (repro.quantile)
    filter_quantile_q: float = 0.01  # target flag rate for quantile mode
    use_grad_monitor: bool = True    # ACE monitor on gradient stats
    grad_compression: bool = False   # int8 + error feedback
    monitor_feature_dim: int = 32
    ckpt_dir: str | None = None
    ckpt_interval: int = 200
    step_slo_seconds: float = 120.0  # host straggler SLO (StepTimer);
                                     # breaches ride the metrics stream
    max_rollbacks: int = 3           # bounded monitor-tripped rollbacks
                                     # per train() call (0 disables)
    rollback_backoff: float = 0.0    # seconds slept before the k-th
                                     # rollback (linear: k × backoff)
    seed: int = 0


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array
    monitor: MonitorState | None
    monitor_w: jax.Array | None
    filter_state: Any | None
    filter_w: jax.Array | None
    ef: EfState | None
    rng: jax.Array


def make_data_filter(tcfg: TrainConfig, d_model: int):
    """The ONE place the train stack decides flat-vs-windowed filtering.

    ``filter_window_epochs > 1`` swaps the cumulative ``AceDataFilter``
    for the epoch-ring ``repro.window.WindowedAceFilter`` — same step
    protocol, same hash/threshold/insert dataflow, but the sketch state
    is a ring whose stale epochs expire, so long-horizon training
    streams with drift don't freeze the filter's μ/σ.  Every consumer
    (init_train_state, the in-step path, the chunked prefilter, the
    tail fallback) builds through here so they agree on the state type.
    """
    if tcfg.filter_window_epochs > 1:
        if tcfg.filter_rotate_every <= 0:
            # nothing else rotates the train filter's ring: E>1 epochs
            # with no clock silently degenerates to the frozen sketch
            # at E× the memory
            raise ValueError(
                "filter_window_epochs > 1 needs filter_rotate_every > 0 "
                "— without a rotation clock the ring never expires and "
                "behaves like the frozen sketch")
        from repro.window import WindowedAceFilter
        return WindowedAceFilter(
            d_model=d_model, num_epochs=tcfg.filter_window_epochs,
            decay=tcfg.filter_window_decay,
            rotate_every=tcfg.filter_rotate_every,
            threshold_mode=tcfg.filter_threshold_mode,
            quantile_q=tcfg.filter_quantile_q)
    return AceDataFilter(d_model=d_model,
                         threshold_mode=tcfg.filter_threshold_mode,
                         quantile_q=tcfg.filter_quantile_q)


def init_train_state(arch: Arch, tcfg: TrainConfig, key) -> TrainState:
    params, _ = arch.init_params(key)
    opt = make_optimizer(tcfg.optimizer)
    opt_state = opt.init(params)
    mon = mon_w = fs = fw = ef = None
    if tcfg.use_grad_monitor:
        gm = GradMonitor(feature_dim=tcfg.monitor_feature_dim)
        mon, mon_w = gm.init()
    if tcfg.use_data_filter:
        filt = make_data_filter(tcfg, arch.cfg.d_model)
        fs, fw = filt.init()
    if tcfg.grad_compression:
        ef = init_error_feedback(params)
    return TrainState(params=params, opt_state=opt_state,
                      step=jnp.zeros((), jnp.int32),
                      monitor=mon, monitor_w=mon_w,
                      filter_state=fs, filter_w=fw, ef=ef,
                      rng=jax.random.PRNGKey(tcfg.seed))


def sequence_embeddings(params, batch, cfg):
    """Embeddings the ACE data filter scores — shared by the per-batch
    filter path inside train_step and the chunked StreamRunner prefilter
    in ``train`` so both score identical features."""
    if "embeds" in batch:
        return batch["embeds"]
    # the ACE filter only needs the sequence-mean embedding; subsample
    # ≤256 tokens/seq and gather in compute dtype — a full-batch fp32
    # (B, S, D) gather would dominate step memory for 12k-dim models.
    toks = batch["tokens"]
    stride = max(toks.shape[1] // 256, 1)
    return jnp.take(params["embed"].astype(cfg.adtype),
                    toks[:, ::stride], axis=0)


def make_train_step(arch: Arch, tcfg: TrainConfig, grad_pspecs=None,
                    sketch_layout: str | None = None):
    """Builds the pure train step.  (state, batch) -> (state, metrics).

    grad_pspecs: optional PartitionSpec pytree (params structure).  When
    given, every microbatch's gradients are constrained to the params'
    (FSDP) sharding INSIDE the accumulation loop, so XLA emits per-layer
    reduce-scatters instead of full-size all-reduces — ZeRO-2 gradient
    sharding (§Perf iteration B1).

    sketch_layout: optional ACE sketch layout name ("replicated" or
    "table_sharded", see repro.dist.mesh.sketch_pspecs).  When given, the
    data-filter and grad-monitor sketch states are sharding-constrained to
    that layout inside the step — jit/SPMD mode of
    repro.dist.sketch_parallel; GSPMD then inserts the histogram psum
    (replicated) or keeps the counts split over the tables axis
    (table_sharded, for monitor sketches past one device's memory)."""
    cfg = arch.cfg
    opt = make_optimizer(tcfg.optimizer)
    sched = CosineSchedule(peak_lr=tcfg.peak_lr,
                           warmup_steps=tcfg.warmup_steps,
                           total_steps=tcfg.total_steps)
    gm = GradMonitor(feature_dim=tcfg.monitor_feature_dim) \
        if tcfg.use_grad_monitor else None
    # With filter_chunk > 1 the driver runs the filter OUTSIDE the step as
    # one StreamRunner scan per T batches (see ``train``); the step then
    # just consumes the pre-masked batches.
    filt = make_data_filter(tcfg, cfg.d_model) \
        if tcfg.use_data_filter and tcfg.filter_chunk <= 1 else None

    def constrain_sketch(st):
        """Pin an AceState (or a windowed epoch ring) to the requested
        repro.dist layout (no-op when sketch_layout is None or the state
        is absent).  The pspec tree is picked by the state's own leaf
        count, so flat and windowed filter states coexist."""
        if sketch_layout is None or st is None:
            return st
        from jax.sharding import PartitionSpec
        # Tiny rate histogram (quantile threshold mode) replicates under
        # every layout.  Constrained explicitly — a positional zip over
        # the fixed-arity pspec tuples would silently TRUNCATE it out of
        # the rebuilt NamedTuple (back to the None default).
        qhist = st.qhist
        if qhist is not None:
            qhist = jax.lax.with_sharding_constraint(qhist,
                                                     PartitionSpec())
        if "tail" in st._fields:   # windowed epoch ring
            from repro.dist.mesh import window_pspecs
            pspecs = window_pspecs(sketch_layout)
            core = (jax.lax.with_sharding_constraint(leaf, ps)
                    for leaf, ps in zip(st, pspecs))
            return type(st)(*core, qhist=qhist)
        pspecs = sketch_pspecs(sketch_layout)
        core = [jax.lax.with_sharding_constraint(leaf, ps)
                for leaf, ps in zip(
                    (st.counts, st.n, st.welford_mean, st.welford_m2),
                    pspecs)]
        esc = st.esc
        if esc is not None:
            if sketch_layout != "replicated":
                raise NotImplementedError(
                    "quantized filter sketches only support the "
                    "replicated layout")
            esc = type(esc)(*(jax.lax.with_sharding_constraint(
                leaf, PartitionSpec()) for leaf in esc))
        return type(st)(*core, esc=esc, qhist=qhist)

    def loss_fn(params, batch):
        return arch.loss(params, batch, remat=tcfg.remat,
                         remat_policy=tcfg.remat_policy)

    def train_step(state: TrainState, batch):
        metrics = {}
        params = state.params

        # ---- ACE data filter: score sequence embeddings, mask anomalies
        filter_state = state.filter_state
        if filt is not None:
            mask = batch.get("mask",
                             jnp.ones(batch["labels"].shape, jnp.float32))
            embeds = sequence_embeddings(params, batch, cfg)
            filter_state, new_mask, kept = filt(
                state.filter_state, state.filter_w, embeds, mask)
            filter_state = constrain_sketch(filter_state)
            batch = dict(batch, mask=new_mask)
            metrics["filter_keep_frac"] = kept

        # ---- grads (with optional microbatch accumulation)
        if tcfg.microbatches > 1:
            mb = tcfg.microbatches

            def split(x, batch_axis=0):
                if batch_axis == 0:
                    return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
                # M-RoPE positions: (3, B, S) — batch on axis 1
                lead = x.shape[:batch_axis]
                rest = x.shape[batch_axis + 1:]
                x = x.reshape(lead + (mb, x.shape[batch_axis] // mb) + rest)
                return jnp.moveaxis(x, batch_axis, 0)

            mbatch = {k: split(v, 1 if k == "positions" else 0)
                      for k, v in batch.items()
                      if hasattr(v, "shape") and v.ndim >= 1}

            def acc_fn(carry, mb_batch):
                (loss, aux), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb_batch)
                if grad_pspecs is not None:
                    g = jax.tree.map(
                        jax.lax.with_sharding_constraint, g, grad_pspecs)
                carry = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / mb,
                    carry, (loss, g))
                return carry, aux

            zero = (jnp.zeros((), jnp.float32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            (loss, grads), auxs = jax.lax.scan(acc_fn, zero, mbatch)
            aux = jax.tree.map(lambda a: a[-1], auxs)
        else:
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        metrics["loss"] = loss
        metrics["grad_norm"] = gnorm

        # ---- optional int8 error-feedback compression (models the
        # cross-pod collective; see repro/train/compression.py)
        ef = state.ef
        rng = state.rng
        if tcfg.grad_compression:
            rng, sub = jax.random.split(rng)
            q, scales, ef = compress_grads_with_ef(grads, ef, sub)
            grads = decompress_grads(q, scales)

        # ---- ACE gradient monitor: skip anomalous updates
        monitor = state.monitor
        lr = sched(state.step)
        metrics["lr"] = lr
        new_params, new_opt = opt.update(params, grads, state.opt_state,
                                         state.step, lr)
        if gm is not None:
            monitor, is_anom, score = gm.step(state.monitor, state.monitor_w,
                                              grads, loss)
            monitor = monitor._replace(ace=constrain_sketch(monitor.ace))
            metrics["grad_anomaly"] = is_anom.astype(jnp.float32)
            metrics["grad_score"] = score
            # rides the existing per-step metrics pull — the rollback
            # decision costs the driver zero extra host syncs
            metrics["rollback_needed"] = gm.rollback_needed(
                monitor).astype(jnp.float32)
            new_params, new_opt = jax.tree.map(
                lambda new, old: jnp.where(is_anom, old, new),
                (new_params, new_opt), (state.params, state.opt_state))

        new_state = TrainState(
            params=new_params, opt_state=new_opt,
            step=state.step + 1,
            monitor=monitor, monitor_w=state.monitor_w,
            filter_state=filter_state, filter_w=state.filter_w,
            ef=ef, rng=rng)
        return new_state, metrics

    return train_step


def train(arch: Arch, tcfg: TrainConfig, stream: DataStream,
          num_steps: int, log_every: int = 10,
          state: TrainState | None = None):
    """Host driver: jit, checkpoint/restart, straggler timer, logging.

    With ``tcfg.filter_chunk = T > 1`` the ACE data filter runs as a
    chunked prefilter: every T batches, their sequence-embedding features
    are scored/inserted by ONE donated-state ``StreamRunner`` scan
    program (hash once per batch, masked insert, zero per-batch host
    syncs) and the returned (T, B) keep mask is applied to the loss masks
    as the batches feed the (filter-free) train step.  The sketch updates
    in the exact same per-batch order as the in-step path; the only
    semantic difference is that a chunk's features are embedded with the
    params at chunk start (embedding-table drift WITHIN a chunk is
    ignored — negligible at any sane T, and the filter only sees mean
    embeddings anyway).  Steps past the last full chunk fall back to the
    per-batch ``filt.step`` program.  Checkpoints are only taken on
    chunk-final steps (mid-chunk, the sketch already contains batches no
    step has trained on — see ``run_step``), so restart stays exact;
    pick ``ckpt_interval`` a multiple of ``filter_chunk`` to keep the
    save cadence.

    Returns (final state, list of metric dicts)."""
    from repro.stream.runner import StreamRunner

    step_fn = jax.jit(make_train_step(arch, tcfg))
    if state is None:
        state = init_train_state(arch, tcfg, jax.random.PRNGKey(tcfg.seed))

    mgr = None
    if tcfg.ckpt_dir:
        mgr = ckpt_lib.CheckpointManager(tcfg.ckpt_dir,
                                         interval=tcfg.ckpt_interval)
        restored, manifest = mgr.restore_latest(state)
        if restored is not None:
            state = restored
            stream.load_state_dict({"step": manifest["extra"]["data_step"]})

    chunk_T = tcfg.filter_chunk if tcfg.use_data_filter else 0
    runner = feat_fn = pb_step = None
    if chunk_T > 1:
        filt = make_data_filter(tcfg, arch.cfg.d_model)
        # a windowed filter carries its own rotation clock; the runner
        # inherits it and rotates inside the scan body
        runner = StreamRunner(filt, chunk_T=chunk_T, return_masks=True)
        # ONE jitted program computes the whole chunk's features (vmap
        # over the stacked T axis) — not T per-batch dispatches; the
        # batches are already device-resident for the train steps, so the
        # filter adds no extra H2D traffic.
        feat_fn = jax.jit(lambda params, stacked: jax.vmap(
            lambda jb: filt.features(
                sequence_embeddings(params, jb, arch.cfg)))(stacked))

        def _tail_step(s, w, feat):
            # tail-batch fallback: same per-step program as the scan
            # body, INCLUDING the (eager, post-insert) epoch-ring clock,
            # so rotations land at identical stream positions whether a
            # batch went through a chunk or the tail
            s, keep, margin = filt.step(s, w, feat)
            if getattr(filt, "num_epochs", 1) > 1:
                from repro.window import maybe_rotate
                s = maybe_rotate(s, filt.rotate_every, filt.decay)
            return s, keep, margin

        pb_step = jax.jit(_tail_step)

    timer = StepTimer(slo_seconds=tcfg.step_slo_seconds)
    history = []
    rollbacks = 0

    def run_step(jbatch, keep=None, saveable=True):
        nonlocal state, rollbacks
        metrics = {}
        if keep is not None:
            mask = jbatch.get("mask",
                              jnp.ones(jbatch["labels"].shape, jnp.float32))
            jbatch = dict(jbatch,
                          mask=mask * keep[:, None].astype(mask.dtype))
            metrics["filter_keep_frac"] = jnp.mean(
                keep.astype(jnp.float32))
        state, step_metrics = step_fn(state, jbatch)
        metrics.update(step_metrics)
        metrics = {k: float(v) for k, v in metrics.items()}
        metrics["straggler_breach"] = float(timer.tick())
        metrics["straggler_breaches_total"] = float(timer.breaches)
        # ---- monitor-tripped rollback: ``max_consecutive`` anomalous
        # steps in a row means skipping updates is no longer containing
        # the fault — restore the newest INTACT checkpoint (corrupt ones
        # are skipped via CRC verification, see checkpoint.restore_latest)
        # and rewind the data stream with it.  Bounded retries with
        # linear backoff; with no checkpoint (or budget spent) the trip
        # counter is cleared so training continues in skip-updates mode
        # instead of re-tripping every step.
        if metrics.get("rollback_needed", 0.0) >= 1.0:
            rolled = False
            if mgr is not None and rollbacks < tcfg.max_rollbacks:
                rollbacks += 1
                if tcfg.rollback_backoff > 0:
                    time.sleep(tcfg.rollback_backoff * rollbacks)
                restored, manifest = mgr.restore_latest(state)
                if restored is not None:
                    state = restored
                    stream.load_state_dict(
                        {"step": manifest["extra"]["data_step"]})
                    rolled = True
            metrics["rollback"] = float(rolled)
            if not rolled and state.monitor is not None:
                state = state._replace(monitor=state.monitor._replace(
                    consecutive=jnp.zeros_like(
                        state.monitor.consecutive)))
        history.append(metrics)
        step = int(state.step)
        # ``saveable`` is False for non-final steps of a prefilter chunk:
        # the chunk's runner pass already inserted ALL T batches into the
        # sketch and advanced the stream, so a checkpoint taken mid-chunk
        # would restore a sketch that has seen batches no step trained on
        # (and skip those batches on resume).  Chunk-final steps are
        # consistent: T batches trained == T batches inserted.
        if mgr is not None and saveable:
            mgr.maybe_save(step, state,
                           extra={"data_step": stream.state_dict()["step"]})
        if log_every and step % log_every == 0:
            print(f"step {step}: loss={metrics['loss']:.4f} "
                  f"gnorm={metrics['grad_norm']:.3f} "
                  f"keep={metrics.get('filter_keep_frac', 1.0):.3f} "
                  f"anom={metrics.get('grad_anomaly', 0.0):.0f}")

    def next_jbatch():
        batch = next(stream)
        return {k: jnp.asarray(v) for k, v in batch.items()
                if not k.startswith("_")}

    done = 0
    while done < num_steps:
        if runner is not None and num_steps - done >= chunk_T:
            # ---- chunked prefilter: T batches, ONE filter program
            jbatches = [next_jbatch() for _ in range(chunk_T)]
            ekey = "embeds" if "embeds" in jbatches[0] else "tokens"
            feats = feat_fn(state.params, {
                ekey: jnp.stack([jb[ekey] for jb in jbatches])})
            fstate, _summary, keeps = runner.consume(
                state.filter_state, state.filter_w, feats)
            state = state._replace(filter_state=fstate)
            for t, jb in enumerate(jbatches):
                run_step(jb, keep=keeps[t], saveable=t == chunk_T - 1)
            done += chunk_T
        else:
            jb = next_jbatch()
            if runner is not None:
                # tail batches past the last full chunk: same step fn,
                # per-batch program
                ekey = "embeds" if "embeds" in jb else "tokens"
                feat = feat_fn(state.params, {ekey: jb[ekey][None]})[0]
                fstate, keep, _m = pb_step(state.filter_state,
                                           state.filter_w, feat)
                state = state._replace(filter_state=fstate)
                run_step(jb, keep=keep)
            else:
                run_step(jb)
            done += 1
    return state, history
