"""LR schedules (pure functions of step — jit-safe scalars)."""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CosineSchedule:
    peak_lr: float = 3e-4
    warmup_steps: int = 200
    total_steps: int = 10_000
    final_frac: float = 0.1

    def __call__(self, step):
        s = jnp.asarray(step, jnp.float32)
        warm = self.peak_lr * s / max(self.warmup_steps, 1)
        prog = jnp.clip((s - self.warmup_steps)
                        / max(self.total_steps - self.warmup_steps, 1),
                        0.0, 1.0)
        cos = self.final_frac + (1 - self.final_frac) * 0.5 * (
            1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < self.warmup_steps, warm, self.peak_lr * cos)


@dataclasses.dataclass(frozen=True)
class ConstantSchedule:
    lr: float = 1e-3

    def __call__(self, step):
        return jnp.asarray(self.lr, jnp.float32)


@dataclasses.dataclass(frozen=True)
class RsqrtSchedule:
    peak_lr: float = 1e-2
    warmup_steps: int = 1000

    def __call__(self, step):
        s = jnp.asarray(step, jnp.float32) + 1.0
        w = float(self.warmup_steps)
        return self.peak_lr * jnp.minimum(s / w, jnp.sqrt(w / s))
