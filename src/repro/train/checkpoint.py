"""Elastic checkpointing: save/restore arbitrary pytrees, reshard on load.

Design for multi-pod (DESIGN.md §4):
* Leaves are gathered to host (process 0 in a multi-process deployment) and
  written as one ``.npz`` per checkpoint plus a JSON manifest (step, tree
  structure, dtypes, config fingerprint).
* Loading never assumes the saving topology: arrays are host-loaded and
  ``jax.device_put`` with the CURRENT mesh's shardings — that is the
  elastic-scaling story (checkpoint at 512 chips, resume at 256 or 1024).
* Writes are atomic (tmp + rename) so a preemption mid-write never corrupts
  the latest checkpoint; ``keep`` bounds disk usage; ``latest_step`` scans
  the directory for restart-after-failure.
* Integrity (repro.resilience): the manifest records a CRC32 per leaf;
  ``restore`` verifies every leaf against it and raises
  ``CheckpointCorruptError`` on mismatch (or on an unreadable/torn npz),
  and ``CheckpointManager.restore_latest`` falls back to the NEWEST intact
  step — a torn write or a flipped bit costs one checkpoint interval, not
  a silently-wrong resume.  Manifests predating the checksum field verify
  as intact (backward compatible).

At true 1000-node scale the npz would become per-shard files keyed by the
PartitionSpec (same manifest schema, one blob per shard); the single-blob
variant keeps this container honest while preserving the interface.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import tempfile
import time
import zipfile
import zlib

import jax
import numpy as np


class CheckpointCorruptError(RuntimeError):
    """A checkpoint failed integrity verification (bad CRC, torn npz,
    missing leaf).  ``CheckpointManager.restore_latest`` catches this and
    falls back to the next-newest intact step."""


def _leaf_crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, jax.tree.structure(tree)


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None,
         keep: int = 3) -> str:
    """Atomic checkpoint write.  Returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    names, leaves, _ = _flatten_with_names(tree)
    arrays = {f"a{i}": np.asarray(jax.device_get(l))
              for i, l in enumerate(leaves)}
    manifest = {
        "step": int(step),
        "names": names,
        "time": time.time(),
        "extra": extra or {},
        # per-leaf CRC32 over the raw bytes: restore verifies these, and
        # restore_latest uses them to skip torn/flipped checkpoints
        "checksums": [_leaf_crc(arrays[f"a{i}"])
                      for i in range(len(leaves))],
    }
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    if keep <= 0:
        return
    keep_names = {name for _, name in _step_dirs(ckpt_dir)[-keep:]}
    for name in os.listdir(ckpt_dir):
        if (re.fullmatch(r"step_(\d+)", name)
                and name not in keep_names
                and os.path.exists(os.path.join(ckpt_dir, name,
                                                "manifest.json"))):
            shutil.rmtree(os.path.join(ckpt_dir, name),
                          ignore_errors=True)


def _step_dirs(ckpt_dir: str) -> list[tuple[int, str]]:
    """(step, dirname) pairs sorted STEP-NUMERICALLY, not by name.

    Restore and GC resolve a step through this scan instead of
    reconstructing ``step_{step:010d}``: a directory written without
    zero padding (an older writer, a hand-copied checkpoint) is then a
    first-class checkpoint rather than listed-but-unrestorable — before
    this, ``restore_latest`` after a crash would hit ``step_9`` with a
    FileNotFoundError (not the CheckpointCorruptError it catches) and
    die instead of resuming, and ``_gc`` would silently never reclaim
    it.  Lexicographically ``step_9`` also sorts AFTER ``step_10``, so
    any name-ordered consumer would resume from the older step; sorting
    the parsed integers here is what keeps "latest" meaning newest.
    When one step has both a padded and an unpadded directory the
    padded (canonical-writer) one wins.
    """
    if not os.path.isdir(ckpt_dir):
        return []
    found: dict[int, str] = {}
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if not (m and os.path.exists(os.path.join(ckpt_dir, name,
                                                  "manifest.json"))):
            continue
        step = int(m.group(1))
        prev = found.get(step)
        if prev is None or name == f"step_{step:010d}":
            found[step] = name
    return sorted(found.items())


def _resolve_step_dir(ckpt_dir: str, step: int) -> str:
    canonical = os.path.join(ckpt_dir, f"step_{step:010d}")
    if os.path.exists(os.path.join(canonical, "manifest.json")):
        return canonical
    for s, name in _step_dirs(ckpt_dir):
        if s == step:
            return os.path.join(ckpt_dir, name)
    return canonical   # let restore() raise its usual error


def all_steps(ckpt_dir: str) -> list[int]:
    return [s for s, _ in _step_dirs(ckpt_dir)]


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Load a checkpoint into the structure of ``like_tree``.

    ``shardings``: optional pytree of NamedSharding (same structure) — each
    leaf is device_put to it, resharding to the CURRENT mesh regardless of
    the topology that saved it.

    Raises ``CheckpointCorruptError`` when the npz is torn/unreadable or
    any leaf's CRC32 disagrees with the manifest (checksum-less legacy
    manifests skip verification).
    """
    path = _resolve_step_dir(ckpt_dir, step)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    try:
        with np.load(os.path.join(path, "arrays.npz")) as z:
            arrays = [z[f"a{i}"] for i in range(len(manifest["names"]))]
    except (OSError, ValueError, KeyError, EOFError,
            zipfile.BadZipFile) as e:
        # torn write, truncated zip member, missing leaf — all corrupt
        raise CheckpointCorruptError(
            f"step {step}: unreadable arrays.npz ({e})") from e
    checksums = manifest.get("checksums")
    if checksums is not None:
        for i, (arr, want) in enumerate(zip(arrays, checksums)):
            got = _leaf_crc(arr)
            if got != want:
                raise CheckpointCorruptError(
                    f"step {step}: leaf a{i} ({manifest['names'][i]}) "
                    f"CRC mismatch (manifest {want:#010x}, "
                    f"file {got:#010x})")

    names, like_leaves, treedef = _flatten_with_names(like_tree)
    if names != manifest["names"]:
        raise ValueError(
            "checkpoint tree mismatch: "
            f"{set(names) ^ set(manifest['names'])}")
    leaves = []
    for arr, like in zip(arrays, like_leaves):
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"shape mismatch {arr.shape} vs {like.shape}")
        leaves.append(arr.astype(like.dtype))
    tree = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree, manifest


@dataclasses.dataclass
class CheckpointManager:
    """Step-driven convenience wrapper used by the train loop."""
    ckpt_dir: str
    interval: int = 100
    keep: int = 3

    def maybe_save(self, step: int, tree, extra=None) -> str | None:
        if step % self.interval != 0:
            return None
        return save(self.ckpt_dir, step, tree, extra=extra, keep=self.keep)

    def restore_latest(self, like_tree, shardings=None):
        """Restore the newest INTACT checkpoint.

        Steps are tried newest-first; a step that fails integrity
        verification (``CheckpointCorruptError``) is skipped and the next
        older one is tried — so a torn write or flipped bit costs one
        checkpoint interval of progress instead of a corrupt resume.
        Returns (None, None) when no intact checkpoint exists."""
        for step in reversed(all_steps(self.ckpt_dir)):
            try:
                tree, manifest = restore(self.ckpt_dir, step, like_tree,
                                         shardings)
            except CheckpointCorruptError:
                continue
            return tree, manifest
        return None, None
