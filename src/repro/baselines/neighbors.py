"""The kNN-graph-based baselines of paper §5.2 (ELKI family).

Every scorer takes the precomputed graph (dists, idx) — mirroring how ELKI
amortises one index across algorithms — and returns scores where **LOW =
anomalous** (the paper's μ−σ thresholding convention; distance-style scores
are negated).

Implemented: kNN [28], kNNW [4], LOF [6], LoOP [23], LDOF [40], ODIN [18],
KDEOS [31], LDF [24], INFLO [20].  COF and FastVOA live in their own modules.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _as_jnp(dists, idx):
    return jnp.asarray(dists, jnp.float32), jnp.asarray(idx, jnp.int32)


# -- kNN (KNNOutlier, Ramaswamy et al.) ------------------------------------

def knn_score(dists, idx):
    """distance to the k-th NN; high = anomalous -> negated."""
    d, _ = _as_jnp(dists, idx)
    return -d[:, -1]


# -- kNNW (KNNWeightOutlier, Angiulli & Pizzuti) ----------------------------

def knnw_score(dists, idx):
    """sum of distances to the k NNs."""
    d, _ = _as_jnp(dists, idx)
    return -jnp.sum(d, axis=1)


# -- LOF (Breunig et al.) ---------------------------------------------------

def lof_score(dists, idx):
    d, i = _as_jnp(dists, idx)
    kdist = d[:, -1]                                    # (n,)
    reach = jnp.maximum(kdist[i], d)                    # (n, k)
    lrd = 1.0 / (jnp.mean(reach, axis=1) + 1e-12)       # (n,)
    lof = jnp.mean(lrd[i], axis=1) / (lrd + 1e-12)
    return -lof


# -- LoOP (Kriegel et al.) --------------------------------------------------

def loop_score(dists, idx, lam: float = 2.0):
    """Local outlier probability in [0, 1]; high = anomalous -> negated.

    Note: the paper's Table 2 lists λ=0.2 for LoOP; the original LoOP paper
    recommends λ≈2–3 (λ multiplies a σ).  We accept it as a parameter.
    """
    d, i = _as_jnp(dists, idx)
    pdist = lam * jnp.sqrt(jnp.mean(d**2, axis=1) + 1e-12)
    plof = pdist / (jnp.mean(pdist[i], axis=1) + 1e-12) - 1.0
    nplof = lam * jnp.sqrt(jnp.mean(plof**2) + 1e-12)
    loop = jnp.maximum(
        jax.scipy.special.erf(plof / (nplof * np.sqrt(2.0) + 1e-12)), 0.0)
    return -loop


# -- LDOF (Zhang et al.) ------------------------------------------------------

def ldof_score(dists, idx, inner_pairwise):
    """d̄(p→kNN) / D̄(inner pairwise of kNN);  inner_pairwise: (n,k+1,k+1)."""
    d, _ = _as_jnp(dists, idx)
    k = d.shape[1]
    dbar = jnp.mean(d, axis=1)
    inner = jnp.asarray(inner_pairwise)[:, 1:, 1:]      # exclude p itself
    # mean over ordered pairs a≠b
    s = jnp.sum(inner, axis=(1, 2))
    Dbar = s / (k * (k - 1) + 1e-12)
    return -(dbar / (Dbar + 1e-12))


# -- ODIN (Hautamaki et al.) --------------------------------------------------

def odin_score(dists, idx):
    """kNN-graph indegree; LOW indegree = anomalous (already aligned)."""
    _, i = _as_jnp(dists, idx)
    n = i.shape[0]
    indeg = jnp.zeros((n,), jnp.float32).at[i.reshape(-1)].add(1.0)
    return indeg


# -- KDEOS (Schubert et al.) --------------------------------------------------

def kdeos_score(dists, idx, bandwidth: float = 5.0, scale: float = 0.2):
    """Gaussian-KDE density z-scored against the kNN set (k_min=k_max=k)."""
    d, i = _as_jnp(dists, idx)
    kdist = d[:, -1]
    h = bandwidth * scale * (kdist + 1e-9)              # per-point bandwidth
    dens = jnp.mean(jnp.exp(-0.5 * (d / h[:, None])**2), axis=1) / h
    mu_nb = jnp.mean(dens[i], axis=1)
    sd_nb = jnp.std(dens[i], axis=1) + 1e-12
    z = (mu_nb - dens) / sd_nb                          # high z = low density
    return -z


# -- LDF (Latecki et al.) ------------------------------------------------------

def ldf_score(dists, idx, h: float = 1.0, c: float = 0.1):
    """Kernel-density LOF variant with reachability distances."""
    d, i = _as_jnp(dists, idx)
    kdist = d[:, -1]
    reach = jnp.maximum(kdist[i], d)                    # (n, k)
    width = h * (kdist[:, None] + 1e-9)
    lde = jnp.mean(jnp.exp(-0.5 * (reach / width)**2) / width, axis=1)
    ldf = jnp.mean(lde[i], axis=1) / (lde + c * jnp.mean(lde[i], axis=1)
                                      + 1e-12)
    return -ldf


# -- INFLO (Jin et al.) ---------------------------------------------------------

def inflo_score(dists, idx, m: float = 0.5):
    """Influenced outlierness over kNN ∪ RkNN (reverse set via scatter)."""
    d, i = _as_jnp(dists, idx)
    n, k = i.shape
    density = 1.0 / (d[:, -1] + 1e-12)
    # sum/count of density over the reverse-kNN set, via scatter-add
    rev_sum = jnp.zeros((n,), jnp.float32).at[i.reshape(-1)].add(
        jnp.repeat(density, k))
    rev_cnt = jnp.zeros((n,), jnp.float32).at[i.reshape(-1)].add(1.0)
    knn_sum = jnp.sum(density[i], axis=1)
    tot = (rev_sum + knn_sum) / (rev_cnt + k)
    inflo = tot / (density + 1e-12)
    return -inflo
