"""Shared brute-force k-nearest-neighbour graph (the substrate all the
ELKI-style baselines consume, computed once per dataset like ELKI's index).

Chunked O(n²·d) JAX computation — exact, memory-bounded; this is the honest
cost the paper's Table 3–5 competitors pay at least once.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.jit, static_argnames=("k",))
def _chunk_topk(chunk: jax.Array, data: jax.Array, base: int, k: int):
    """Exact k+1 smallest distances of ``chunk`` rows against ``data``."""
    # squared euclidean via the expansion trick
    d2 = (jnp.sum(chunk**2, 1)[:, None] - 2.0 * chunk @ data.T
          + jnp.sum(data**2, 1)[None, :])
    d2 = jnp.maximum(d2, 0.0)
    # mask self-distance (rows are data[base:base+m])
    m = chunk.shape[0]
    idx_row = base + jnp.arange(m)
    d2 = d2.at[jnp.arange(m), idx_row].set(jnp.inf)
    neg, idx = jax.lax.top_k(-d2, k)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), idx


def knn_graph(x: np.ndarray, k: int, chunk: int = 2048):
    """Exact kNN graph.  Returns (dists (n,k) f32, idx (n,k) i32)."""
    n = x.shape[0]
    data = jnp.asarray(x, jnp.float32)
    dists = np.empty((n, k), np.float32)
    idx = np.empty((n, k), np.int32)
    for s in range(0, n, chunk):
        e = min(s + chunk, n)
        d_, i_ = _chunk_topk(data[s:e], data, s, k)
        dists[s:e] = np.asarray(d_)
        idx[s:e] = np.asarray(i_)
    return dists, idx


def pairwise_within_neighborhood(x: np.ndarray, idx: np.ndarray):
    """Pairwise distances inside each {p} ∪ kNN(p) set.

    Returns (n, k+1, k+1) float32 where slot 0 is p itself.
    Used by COF (MST chaining) and LDOF (inner pairwise mean).
    """
    n, k = idx.shape
    data = jnp.asarray(x, jnp.float32)
    full_idx = jnp.concatenate(
        [jnp.arange(n, dtype=jnp.int32)[:, None], jnp.asarray(idx)], axis=1)
    pts = data[full_idx]                                    # (n, k+1, d)
    diff = pts[:, :, None, :] - pts[:, None, :, :]
    return jnp.sqrt(jnp.maximum(jnp.sum(diff**2, -1), 0.0))
