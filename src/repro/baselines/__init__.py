"""The paper's 11 baselines (§5.2), all in JAX, all sharing one kNN graph.

``run_baseline(name, x, k)`` returns per-point scores where LOW = anomalous
(the paper's μ−σ thresholding convention), plus the wall-time split into
graph-build and scoring — mirroring how ELKI amortises its index.
"""
from __future__ import annotations

import time

import numpy as np

from repro.baselines import neighbors as nb
from repro.baselines.cof import cof_score
from repro.baselines.fastvoa import fastvoa_score
from repro.baselines.knn_graph import knn_graph, pairwise_within_neighborhood

GRAPH_BASED = {
    "lof": lambda g, x: nb.lof_score(*g),
    "knn": lambda g, x: nb.knn_score(*g),
    "knnw": lambda g, x: nb.knnw_score(*g),
    "loop": lambda g, x: nb.loop_score(*g),
    "odin": lambda g, x: nb.odin_score(*g),
    "kdeos": lambda g, x: nb.kdeos_score(*g),
    "ldf": lambda g, x: nb.ldf_score(*g),
    "inflo": lambda g, x: nb.inflo_score(*g),
}
NEIGHBORHOOD_BASED = {"ldof", "cof"}        # need inner pairwise distances
ALL_BASELINES = (list(GRAPH_BASED) + ["ldof", "cof", "fastvoa"])


def run_baseline(name: str, x: np.ndarray, k: int, graph=None,
                 inner=None, fastvoa_t: int = 320):
    """Returns (scores_lo_anomalous, seconds, graph, inner).

    ``graph``/``inner`` can be passed in to share across methods (ELKI-style);
    their build time is charged to the first method that needs them.
    """
    t0 = time.perf_counter()
    if name == "fastvoa":
        s = np.asarray(fastvoa_score(x, t=fastvoa_t))
        return s, time.perf_counter() - t0, graph, inner

    if graph is None:
        graph = knn_graph(x, k)
    if name in GRAPH_BASED:
        s = np.asarray(GRAPH_BASED[name](graph, x))
        return s, time.perf_counter() - t0, graph, inner

    if inner is None:
        inner = np.asarray(pairwise_within_neighborhood(x, graph[1]))
    if name == "ldof":
        s = np.asarray(nb.ldof_score(graph[0], graph[1], inner))
    elif name == "cof":
        s = np.asarray(cof_score(x, graph[1], inner))
    else:
        raise KeyError(name)
    return s, time.perf_counter() - t0, graph, inner
