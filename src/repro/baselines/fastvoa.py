"""FastVOA — near-linear variance-of-angles estimation (Pham & Pagh, KDD'12).

The paper's sampling-based competitor (§5.2 item 3).  ABOD's outlier signal
is the *variance* over pairs (a, b) of the angle ∠(a, p, b); outliers see
the world in a narrow cone ⇒ low variance.

FastVOA estimates it with t random hyperplanes (SRP!) and AMS sketches:

* For hyperplane w, sort points by z = X·w.  For a pair (a, b) on opposite
  sides of p in this order, Pr = ∠(a,p,b)/(2π) per orientation, so with
  l_p = #left, r_p = #right:   E[l_p·r_p] = Σ_{a≠b pairs} ∠/(2π)·2
  ⇒ MOA1(p) = 2·E[l_p r_p] / ((n−1)(n−2))  estimates the mean angle/π.
* For the second moment, ±1 AMS streams s₁, s₂: with signed prefix sums
  SL_i(p) = Σ_{a left} s(a), SR_i(p) = Σ_{b right} s(b), the product
  P_i = SL_i·SR_i has E[P_i P_j] (independent hyperplanes i≠j, same signs)
  = #pairs split by both ⇒ estimates Σ (∠/π)² terms ⇒ MOA2.
* VOA(p) = MOA2 − MOA1² ;  LOW variance = anomalous (already aligned).

Cost: O(t·(n log n + n·d)) — matches the paper's S1=320 projections, S2=2
sketch repetitions.  Sorting is the expensive part; the paper observes
FastVOA is its slowest competitor, which our Table-3/4/5 bench reproduces.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def _one_projection(x: jax.Array, key: jax.Array, signs: jax.Array):
    """Returns (l·r, (s2, n) products P = SL·SR) for one hyperplane.

    ``signs`` (s2, n) are the AMS ±1 streams — FIXED across all t
    hyperplanes (only then does E[P_i·P_j] for i≠j recover the second
    moment; independent signs would give E = 0).
    """
    n, d = x.shape
    w = jax.random.normal(key, (d,), jnp.float32)
    z = x @ w
    order = jnp.argsort(z)                       # ascending
    rank = jnp.zeros((n,), jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32))
    l = rank.astype(jnp.float32)                 # #points strictly left
    r = (n - 1 - rank).astype(jnp.float32)       # #points strictly right
    f1 = l * r

    s_sorted = signs[:, order]                   # stream in sorted order
    pref = jnp.cumsum(s_sorted, axis=1)          # pref[:, i] = Σ first i+1
    total = pref[:, -1][:, None]
    # SL(p) = Σ signs strictly left of p = pref[:, rank[p]] − sign(p)
    # (sorted slot rank[p] holds p itself).
    rank_b = jnp.broadcast_to(rank[None, :], signs.shape)
    at_p = jnp.take_along_axis(pref, rank_b, axis=1)
    sl = at_p - signs
    sr = total - at_p
    return f1, sl * sr


def fastvoa_score(x: np.ndarray, t: int = 320, s2: int = 2,
                  seed: int = 0) -> jax.Array:
    """Variance-of-angle scores; LOW = anomalous.

    Unbiased throughout: MOA1 from l·r counts; MOA1² and MOA2 from
    cross-products over *independent* hyperplanes (a plug-in mean² would be
    biased upward by the estimator's own variance, which is larger than the
    VOA signal itself).
    """
    xj = jnp.asarray(x, jnp.float32)
    n = xj.shape[0]
    key0, key_s = jax.random.split(jax.random.PRNGKey(seed))
    keys = jax.random.split(key0, t)
    signs = (jax.random.bernoulli(key_s, 0.5, (s2, n)).astype(jnp.float32)
             * 2.0 - 1.0)                        # shared AMS streams

    # Per-projection l·r and SL·SR are exact small integers in f32; the
    # ACCUMULATION must be float64 — f1_sum² reaches ~1e15, far past f32
    # precision, and the final answer is a small difference of such terms.
    f1_sum = np.zeros((n,), np.float64)
    f1_sq = np.zeros((n,), np.float64)
    p_sum = np.zeros((s2, n), np.float64)
    p_sq = np.zeros((s2, n), np.float64)
    for i in range(t):
        f1, p = _one_projection(xj, keys[i], signs)
        f1 = np.asarray(f1, np.float64)
        p = np.asarray(p, np.float64)
        f1_sum += f1
        f1_sq += f1 * f1
        p_sum += p
        p_sq += p * p

    denom_pairs = (n - 1.0) * (n - 2.0) / 2.0    # unordered (a, b) pairs
    tt = t * (t - 1.0)
    moa1 = (f1_sum / t) / denom_pairs
    del moa1  # kept for clarity; VOA uses the unbiased square below
    # unbiased square of the first moment: Σ_{i≠j} f1_i f1_j / (t(t−1))
    moa1_sq = (f1_sum**2 - f1_sq) / tt / denom_pairs**2
    # second moment: Σ_{i≠j} P_i P_j / (t(t−1)), averaged over AMS streams
    cross = (p_sum**2 - p_sq).mean(axis=0)
    moa2 = cross / tt / denom_pairs
    return jnp.asarray(moa2 - moa1_sq, jnp.float32)
