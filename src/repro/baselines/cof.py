"""COF — Connectivity-based Outlier Factor (Tang et al., PAKDD'02).

COF replaces LOF's density with the *average chaining distance* (ac-dist):
the cost of connecting p to its neighbourhood through a set-based nearest
path (an incremental MST rooted at p).  COF(p) = ac(p) / mean ac(o∈kNN(p)).

Vectorised over all n points: each neighbourhood has only k+1 ≤ 11 points,
so Prim's algorithm is a short static loop over k steps, batched with vmap.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _ac_dist_single(pd: jax.Array) -> jax.Array:
    """Average chaining distance from Prim's order on one (k+1, k+1) matrix.

    Slot 0 is p (the root).  The SBN-trail cost e_i is the i-th edge added;
    ac-dist = Σ_i w_i · e_i with the original paper's decreasing weights
    w_i = 2(r−i)/(r(r−1))·... — we use the standard normalised form
    ac = (Σ_{i=1..r-1} 2·(r−i)·e_i) / (r·(r−1)/1) … simplified to the
    common implementation Σ 2(r−i)/(r(r−1)) · e_i   with r = k+1.
    """
    r = pd.shape[0]
    in_tree = jnp.zeros((r,), bool).at[0].set(True)
    best = pd[0]  # distance of each node to the tree

    def step(carry, i):
        in_tree, best = carry
        masked = jnp.where(in_tree, jnp.inf, best)
        nxt = jnp.argmin(masked)
        cost = masked[nxt]
        in_tree = in_tree.at[nxt].set(True)
        best = jnp.minimum(best, pd[nxt])
        return (in_tree, best), cost

    (_, _), costs = jax.lax.scan(step, (in_tree, best),
                                 jnp.arange(1, r))
    i = jnp.arange(1, r, dtype=jnp.float32)
    w = 2.0 * (r - i) / (r * (r - 1.0))
    return jnp.sum(w * costs)


def cof_score(x: np.ndarray, idx: np.ndarray, inner_pairwise) -> jax.Array:
    """COF over the whole dataset; LOW = anomalous (negated).

    inner_pairwise: (n, k+1, k+1) from knn_graph.pairwise_within_neighborhood.
    """
    pd = jnp.asarray(inner_pairwise, jnp.float32)
    ac = jax.vmap(_ac_dist_single)(pd)                     # (n,)
    i = jnp.asarray(idx, jnp.int32)
    cof = ac / (jnp.mean(ac[i], axis=1) + 1e-12)
    return -cof
