"""Deterministic, checkpointable data pipeline with the ACE anomaly filter.

The paper's original deployment surface: a high-rate stream where each
record must be scored in O(K·L) against a 4 MB sketch BEFORE it reaches the
expensive consumer (here: the training loss).

* Determinism & restart: batches are a pure function of (seed, step) — the
  iterator state IS the step counter, so checkpoint/restart and elastic
  re-sharding reproduce the exact stream (fault-tolerance substrate).
* Filtering: per-sequence feature = mean token embedding (or the stub
  frame/patch embedding mean), bias-augmented; scored against the running
  sketch; sequences below μ − α·σ get loss-mask 0 (skip) but still advance
  the stream.  The sketch updates ONLINE with non-anomalous items only.
* Poisoning injection (for tests/examples): ``corrupt_every`` swaps a batch
  with far-out-of-cone garbage, which the filter must catch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sk
from repro.core.sketch import AceConfig


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    corrupt_every: int = 0        # 0 = clean stream
    n_docs: int = 4096            # synthetic corpus size


def synth_batch(cfg: StreamConfig, step: int) -> dict[str, np.ndarray]:
    """Markov-ish synthetic LM batch, pure function of (seed, step)."""
    rng = np.random.default_rng(cfg.seed * 1_000_003 + step)
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    # low-entropy structured stream: random walk over the vocab
    start = rng.integers(0, V, (B, 1))
    steps = rng.integers(-3, 4, (B, S - 1))
    toks = np.concatenate([start, start + np.cumsum(steps, axis=1)], axis=1)
    toks = np.mod(toks, V).astype(np.int32)
    batch = {"tokens": toks, "labels": toks,
             "mask": np.ones((B, S), np.float32)}
    if cfg.corrupt_every and step % cfg.corrupt_every == cfg.corrupt_every - 1:
        # poisoned batch: uniform garbage tokens (very different embedding
        # statistics from the random-walk stream)
        batch["tokens"] = rng.integers(0, V, (B, S)).astype(np.int32)
        batch["labels"] = batch["tokens"]
        batch["_poisoned"] = np.ones((), np.bool_)
    return batch


class DataStream:
    """Stateless-iterator facade: state == step (checkpoint-friendly)."""

    def __init__(self, cfg: StreamConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    def __next__(self):
        b = synth_batch(self.cfg, self.step)
        self.step += 1
        return b

    def state_dict(self):
        return {"step": self.step}

    def load_state_dict(self, s):
        self.step = int(s["step"])


# ---------------------------------------------------------------------------
# ACE data filter (jit-compatible; compiled into train_step)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AceDataFilter:
    d_model: int
    num_bits: int = 13
    num_tables: int = 32
    alpha: float = 4.0
    warmup_items: float = 512.0
    bias_const: float = 0.25

    @property
    def ace_cfg(self) -> AceConfig:
        return AceConfig(dim=self.d_model + 1, num_bits=self.num_bits,
                         num_tables=self.num_tables, seed=29,
                         welford_min_n=self.warmup_items / 2)

    def init(self):
        return sk.init(self.ace_cfg), sk.make_params(self.ace_cfg)

    def features(self, embeds: jax.Array) -> jax.Array:
        """(B, S, D) token/patch/frame embeddings -> (B, D+1) features.

        Unit-normalised mean embedding + a bias coordinate: direction drift
        is what the angular SRP sees; the bias re-encodes magnitude at a
        controlled weight."""
        f = jnp.mean(embeds.astype(jnp.float32), axis=1)
        f = f / (jnp.linalg.norm(f, axis=-1, keepdims=True) + 1e-9)
        bias = jnp.full((f.shape[0], 1), self.bias_const, jnp.float32)
        return jnp.concatenate([f, bias], axis=-1)

    def __call__(self, state, w, embeds, mask):
        """Score + filter + update.  Returns (new_state, new_mask, frac_kept).

        mask: (B, S) loss mask; anomalous sequences are zeroed out.
        """
        cfg = self.ace_cfg
        feat = self.features(embeds)                       # (B, d+1)
        scores = sk.score(state, w, feat, cfg)
        rates = scores / jnp.maximum(state.n, 1.0)
        mu_rate = sk.mean_rate(state)
        sigma = sk.sigma_welford(state)
        armed = state.n >= self.warmup_items
        anom = jnp.logical_and(armed,
                               rates < mu_rate - self.alpha * sigma)
        keep = jnp.logical_not(anom)
        # update sketch with kept items only: scatter-add the keep flag as
        # the increment (0 for anomalous rows) — no sentinel index games.
        buckets = sk.hash_buckets(feat, w, cfg.srp)
        B, L = buckets.shape
        rows = jnp.broadcast_to(
            jnp.arange(L, dtype=jnp.int32)[None, :], (B, L))
        inc = jnp.broadcast_to(
            keep[:, None], (B, L)).astype(state.counts.dtype)
        new_counts = state.counts.at[rows, buckets].add(inc)
        b = jnp.sum(keep.astype(jnp.float32))
        n = state.n
        tot = n + b
        kept_rates = jnp.where(keep, scores / jnp.maximum(tot, 1.0), 0.0)
        mean_b = jnp.sum(kept_rates) / jnp.maximum(b, 1.0)
        m2_b = jnp.sum(jnp.where(keep,
                                 (kept_rates - mean_b) ** 2, 0.0))
        delta = mean_b - state.welford_mean
        safe = jnp.maximum(tot, 1.0)
        new_state = sk.AceState(
            counts=new_counts, n=tot,
            welford_mean=state.welford_mean + delta * b / safe,
            welford_m2=state.welford_m2 + m2_b + delta ** 2 * n * b / safe)
        new_mask = mask * keep[:, None].astype(mask.dtype)
        return new_state, new_mask, jnp.mean(keep.astype(jnp.float32))
