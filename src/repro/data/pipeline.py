"""Deterministic, checkpointable data pipeline with the ACE anomaly filter.

The paper's original deployment surface: a high-rate stream where each
record must be scored in O(K·L) against a 4 MB sketch BEFORE it reaches the
expensive consumer (here: the training loss).

* Determinism & restart: batches are a pure function of (seed, step) — the
  iterator state IS the step counter, so checkpoint/restart and elastic
  re-sharding reproduce the exact stream (fault-tolerance substrate).
* Filtering: per-sequence feature = mean token embedding (or the stub
  frame/patch embedding mean), bias-augmented; scored against the running
  sketch; sequences below μ − α·σ get loss-mask 0 (skip) but still advance
  the stream.  The sketch updates ONLINE with non-anomalous items only.
* Poisoning injection (for tests/examples): ``corrupt_every`` swaps a batch
  with far-out-of-cone garbage, which the filter must catch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sk
from repro.core import srp
from repro.core.sketch import AceConfig


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    corrupt_every: int = 0        # 0 = clean stream
    n_docs: int = 4096            # synthetic corpus size


def synth_batch(cfg: StreamConfig, step: int) -> dict[str, np.ndarray]:
    """Markov-ish synthetic LM batch, pure function of (seed, step)."""
    rng = np.random.default_rng(cfg.seed * 1_000_003 + step)
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    # low-entropy structured stream: random walk over the vocab
    start = rng.integers(0, V, (B, 1))
    steps = rng.integers(-3, 4, (B, S - 1))
    toks = np.concatenate([start, start + np.cumsum(steps, axis=1)], axis=1)
    toks = np.mod(toks, V).astype(np.int32)
    batch = {"tokens": toks, "labels": toks,
             "mask": np.ones((B, S), np.float32)}
    if cfg.corrupt_every and step % cfg.corrupt_every == cfg.corrupt_every - 1:
        # poisoned batch: uniform garbage tokens (very different embedding
        # statistics from the random-walk stream)
        batch["tokens"] = rng.integers(0, V, (B, S)).astype(np.int32)
        batch["labels"] = batch["tokens"]
        batch["_poisoned"] = np.ones((), np.bool_)
    return batch


class DataStream:
    """Stateless-iterator facade: state == step (checkpoint-friendly)."""

    def __init__(self, cfg: StreamConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    def __next__(self):
        b = synth_batch(self.cfg, self.step)
        self.step += 1
        return b

    def state_dict(self):
        return {"step": self.step}

    def load_state_dict(self, s):
        self.step = int(s["step"])


# ---------------------------------------------------------------------------
# ACE data filter (jit-compatible; compiled into train_step)
# ---------------------------------------------------------------------------

def mean_embed_features(embeds: jax.Array, bias_const: float) -> jax.Array:
    """(B, S, D) embeddings -> (B, D+1) unit-mean + bias features.

    Unit-normalised mean embedding + a bias coordinate: direction drift
    is what the angular SRP sees; the bias re-encodes magnitude at a
    controlled weight.  THE featurisation — shared by the flat
    ``AceDataFilter`` and the windowed ``repro.window.WindowedAceFilter``
    so frozen-vs-windowed comparisons (and the E=1 bitwise contract)
    rest on identical features by construction, not by copy-sync.
    """
    f = jnp.mean(embeds.astype(jnp.float32), axis=1)
    f = f / (jnp.linalg.norm(f, axis=-1, keepdims=True) + 1e-9)
    bias = jnp.full((f.shape[0], 1), bias_const, jnp.float32)
    return jnp.concatenate([f, bias], axis=-1)


@dataclasses.dataclass(frozen=True)
class AceDataFilter:
    d_model: int
    num_bits: int = 13
    num_tables: int = 32
    alpha: float = 4.0
    warmup_items: float = 512.0
    bias_const: float = 0.25
    hash_mode: str = "dense"     # "dense" | "srht" | "auto" (SrpConfig)
    insert_all: bool = False     # detector mode: still flag (keep=False)
                                 # but insert EVERY item — for monitoring
                                 # a stream you don't gate (benchmarks,
                                 # dashboards); default is filter mode
                                 # (anomalies never enter the sketch)
    count_dtype: str = "int32"   # narrow planes ("int16"/"int8") cut the
                                 # table and its gather bandwidth 2–4×
    esc_capacity: int = 0        # > 0: exact overflow promotion
                                 # (repro.core.quantize)
    threshold_mode: str = "mu_sigma"   # "mu_sigma" | "quantile" — admit
                                 # rule (repro.quantile); quantile mode
                                 # targets a per-stream false-positive
                                 # RATE q instead of a σ-multiple, which
                                 # μ−ασ cannot hold on heavy-tailed
                                 # score distributions
    quantile_q: float = 0.01     # target flag rate for quantile mode
    attr_rows: int = 0           # > 0: heavy-hitter attribution planes
                                 # (repro.attribution) ride the state
    attr_bits: int = 8           # log2 columns per attribution row

    @property
    def ace_cfg(self) -> AceConfig:
        return AceConfig(dim=self.d_model + 1, num_bits=self.num_bits,
                         num_tables=self.num_tables, seed=29,
                         welford_min_n=self.warmup_items / 2,
                         hash_mode=self.hash_mode,
                         counter_dtype=self.count_dtype,
                         esc_capacity=self.esc_capacity,
                         attr_rows=self.attr_rows,
                         attr_bits=self.attr_bits)

    def init(self):
        state = sk.init(self.ace_cfg)
        if self.threshold_mode == "quantile":
            from repro.quantile import sketch as qsk
            state = state._replace(qhist=qsk.init_hist())
        return state, sk.make_params(self.ace_cfg)

    def features(self, embeds: jax.Array) -> jax.Array:
        """(B, S, D) token/patch/frame embeddings -> (B, D+1) features
        (see ``mean_embed_features``)."""
        return mean_embed_features(embeds, self.bias_const)

    def step(self, state, w, feat, table_mask=None):
        """One filter step over precomputed features: hash ONCE, score from
        the same bucket ids, threshold on-device, masked insert.

        Returns (new_state, keep (B,) bool, margin (B,) float32) where
        ``margin = score − threshold`` (most-negative = most anomalous;
        +inf during warmup, when the threshold is −inf and everything is
        kept).  This is the scan body of ``repro.stream.StreamRunner`` and
        the filter path compiled into ``train_step`` — ONE implementation
        for both, so chunked and per-batch ingest stay equivalent by
        construction.

        Entry-point sanitization (repro.resilience): rows with non-finite
        features are zeroed before hashing, never kept, never inserted
        (even under ``insert_all``), and marked with ``margin = −inf`` so
        drivers can count them as quarantined.  The pre-fix behaviour
        silently inserted them at one bucket per table, skewing counts
        and ssq/μ forever — training data fails CLOSED (garbage must not
        train or enter the sketch).  For all-finite batches the
        sanitization is bitwise identity.

        ``table_mask`` (L,) f32, when given, scores and thresholds over
        healthy tables only (the repro.resilience degraded mode); None
        traces no mask code.

        The decision matches the pre-rewrite μ−ασ rate-space rule moved to
        score space via ``sk.admit_threshold`` (multiply both sides by
        max(n, 1) > 0); the insert + Welford fold delegate to
        ``sk.insert_buckets_masked`` → ``sk.masked_batch_welford``, the
        same single-homed helpers as the serving guardrail and both
        ``repro.dist`` layouts.  Two behaviour notes vs the old inline
        block (both unifications, property-tested in tests/test_stream.py):
        the Welford stream now folds POST-insert scores (Algorithm 1 line
        12's x-vs-D∪{x} convention, like every other insert path) where
        the old code folded pre-insert scores, and the ``welford_min_n``
        cold-start gate declared in ``ace_cfg`` is now actually honoured
        (the hand-rolled block ignored it).
        """
        cfg = self.ace_cfg
        finite = jnp.all(jnp.isfinite(feat), axis=-1)
        feat = jnp.where(finite[:, None], feat, 0.0)
        buckets = srp.hash_buckets(feat, w, cfg.srp)   # the ONE hash
        scores = sk.lookup(state, buckets,             # same bucket ids
                           table_mask=table_mask)
        thresh = sk.admit_threshold(state, self.alpha, self.warmup_items,
                                    table_mask=table_mask,
                                    threshold_mode=self.threshold_mode,
                                    q=self.quantile_q)
        keep = jnp.logical_and(scores >= thresh, finite)
        margin = jnp.where(finite, scores - thresh, -jnp.inf)
        ins = finite if self.insert_all else keep
        new_state = sk.insert_buckets_masked(state, buckets, ins, cfg)
        if self.threshold_mode == "quantile":
            # Calibration stream: EVERY finite-scored item feeds the rate
            # histogram — observing only admitted items would freeze the
            # rejected tail out of the empirical CDF and the Q_q threshold
            # would self-reinforce upward (classic quantile-feedback bug).
            from repro.quantile import sketch as qsk
            rates = scores / jnp.maximum(state.n, 1.0)
            new_state = new_state._replace(qhist=qsk.observe_rates(
                new_state.qhist, rates,
                qsk.calib_mask(finite.astype(jnp.float32), state.n,
                               self.warmup_items)))
        return new_state, keep, margin

    def __call__(self, state, w, embeds, mask):
        """Score + filter + update.  Returns (new_state, new_mask, frac_kept).

        mask: (B, S) loss mask; anomalous sequences are zeroed out.
        """
        feat = self.features(embeds)                       # (B, d+1)
        new_state, keep, _margin = self.step(state, w, feat)
        new_mask = mask * keep[:, None].astype(mask.dtype)
        return new_state, new_mask, jnp.mean(keep.astype(jnp.float32))
