"""Synthetic benchmark datasets shaped like the paper's three benchmarks.

The real files (UCI Statlog Shuttle, ALOI-HSB, KDD-Cup99 HTTP) are not
downloadable in this offline container, so we generate datasets with the
same (n, d, #anomalies) statistics (paper Table 1) and the same qualitative
structure the paper relies on:

* features are NONNEGATIVE (radiator positions / HSB histograms / traffic
  counts), so inliers occupy a few cones in the positive orthant and
  density differences are *angular* — which is what an SRP-based score sees;
* inliers form a handful of dense clusters (normal operating modes /
  object classes / normal HTTP traffic);
* anomalies are a mix of (a) scattered points in low-density directions and
  (b) a couple of tiny tight clusters (the "rare class" style of Shuttle's
  classes 2/3/5/6/7 and KDD's attack bursts).

All generation is deterministic given the dataset name.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# name -> (n_instances, n_anomalies, dim)   [paper Table 1]
PAPER_STATS = {
    "shuttle": (34_987, 879, 9),
    "aloi": (50_000, 1_508, 27),
    "kddcup99_http": (596_853, 1_055, 36),
}


@dataclasses.dataclass
class Dataset:
    name: str
    x: np.ndarray          # (n, d) float32
    y: np.ndarray          # (n,) int8; 1 = anomaly
    n_anomalies: int

    @property
    def n(self) -> int:
        return self.x.shape[0]

    @property
    def dim(self) -> int:
        return self.x.shape[1]

    def bytes(self) -> int:
        return self.x.nbytes


def _unit(v: np.ndarray) -> np.ndarray:
    return v / (np.linalg.norm(v, axis=-1, keepdims=True) + 1e-12)


def make_paper_dataset(name: str, n: int | None = None,
                       seed: int | None = None) -> Dataset:
    """Generate the named benchmark analogue (optionally subsampled to n)."""
    if name not in PAPER_STATS:
        raise KeyError(f"unknown dataset {name!r}; have {list(PAPER_STATS)}")
    n_full, n_anom_full, d = PAPER_STATS[name]
    n = n or n_full
    frac = n / n_full
    n_anom = max(8, int(round(n_anom_full * frac)))
    n_in = n - n_anom
    rng = np.random.default_rng(
        seed if seed is not None else abs(hash(name)) % (2**31))

    # --- inlier clusters: distinct directions in the positive orthant -----
    n_clusters = {9: 4, 27: 6, 36: 5}.get(d, 5)
    centers = rng.gamma(shape=2.0, scale=2.0, size=(n_clusters, d))
    centers *= (rng.uniform(4.0, 9.0, size=(n_clusters, 1))
                / np.linalg.norm(centers, axis=1, keepdims=True))
    # near-balanced cluster masses: heavily skewed masses make the score
    # distribution multimodal with huge σ, which defeats ANY μ−σ rule (the
    # paper's real benchmarks are mass-balanced after its preprocessing)
    weights = rng.dirichlet(np.full(n_clusters, 20.0))
    assign = rng.choice(n_clusters, size=n_in, p=weights)
    # Angular spread matters: near-duplicate clusters (tiny spread) put ACE
    # into its positive-covariance worst case (paper §3.3); real benchmark
    # data has broad within-class variation, which this range mimics.
    spread = rng.uniform(0.4, 1.1, size=(n_clusters,))
    x_in = centers[assign] + rng.normal(
        size=(n_in, d)) * spread[assign][:, None]
    x_in = np.abs(x_in)  # keep the nonnegative-orthant structure

    # --- anomalies: mostly scattered + two loose rare clusters -----------
    # (tight rare clusters would self-mask for every density-style method;
    # the paper's preprocessing — stratified downsampling of rare classes —
    # has the same de-clumping effect.)
    n_scatter = (3 * n_anom) // 4
    dirs = _unit(rng.normal(size=(n_scatter, d)))
    x_scatter = np.abs(dirs) * rng.uniform(6.0, 14.0, size=(n_scatter, 1))
    # push scattered anomalies away from every inlier-cone direction
    x_scatter += rng.exponential(1.0, size=x_scatter.shape)

    n_rare = n_anom - n_scatter
    rare_centers = np.abs(_unit(rng.normal(size=(2, d)))) * 12.0
    rare_assign = rng.choice(2, size=n_rare)
    x_rare = np.abs(rare_centers[rare_assign]
                    + 0.35 * rng.normal(size=(n_rare, d)))

    x = np.concatenate([x_in, x_scatter, x_rare]).astype(np.float32)
    y = np.concatenate([np.zeros(n_in, np.int8),
                        np.ones(n_anom, np.int8)])
    perm = rng.permutation(n)
    return Dataset(name=name, x=x[perm], y=y[perm], n_anomalies=n_anom)


def make_fig1_dataset(seed: int = 0):
    """Paper Figure 1a: inner points, border points, outliers (2-D sim).

    Returns (data, inner_idx, border_idx, outliers) — ``data`` holds inner ∪
    border; outliers are separate query points (as in the paper's plot).
    """
    rng = np.random.default_rng(seed)
    n = 1000
    # dense disk centred off-origin (angular structure for SRP)
    center = np.array([6.0, 6.0])
    r = np.sqrt(rng.uniform(0.0, 1.0, n)) * 2.0
    ang = rng.uniform(0, 2 * np.pi, n)
    pts = center + np.stack([r * np.cos(ang), r * np.sin(ang)], 1)
    radii = np.linalg.norm(pts - center, axis=1)
    inner_idx = np.argsort(radii)[: n // 10]
    border_idx = np.argsort(radii)[-n // 10:]
    outliers = center + np.array([[9.0, -7.5], [10.0, -8.0], [-7.0, 9.5]])
    return (pts.astype(np.float32), inner_idx, border_idx,
            outliers.astype(np.float32))


def make_drift_stream(n_steps: int, batch: int, dim: int, *,
                      shift_step: int, anomaly_every: int = 7,
                      anomaly_frac: float = 0.25, seed: int = 0):
    """Concept-drift stream for windowed-vs-frozen sketch comparisons.

    Yields ``n_steps`` batches of (batch, dim) nonnegative features plus
    per-item anomaly labels.  Three populations, all angularly separated
    (what an SRP score sees):

    * **regime A inliers** — a cone on the first third of the dims; the
      only inlier population before ``shift_step``.
    * **regime B inliers** — a cone on the middle third; replaces A at
      ``shift_step`` (an abrupt shift, the hardest case for a cumulative
      sketch: A's mass never leaves it, so post-shift μ stays pinned to a
      regime that stopped arriving and σ inflates on the A/B mix).
    * **anomalies** — scattered directions on the last third, injected
      into every ``anomaly_every``-th batch at ``anomaly_frac`` of rows,
      SAME distribution throughout (so recall before/after the shift is
      apples-to-apples; only the detector's notion of "normal" moves).

    Returns a list of (x (batch, dim) float32, y (batch,) int8) — pure
    function of the arguments, like every generator in this module.
    """
    rng = np.random.default_rng(seed)
    third = dim // 3
    mu_a = np.zeros(dim)
    mu_a[:third] = 5.0
    mu_b = np.zeros(dim)
    mu_b[third:2 * third] = 5.0
    out = []
    for t in range(n_steps):
        mu = mu_a if t < shift_step else mu_b
        x = np.abs(rng.normal(size=(batch, dim)) * 0.5 + mu)
        y = np.zeros(batch, np.int8)
        if anomaly_every and t % anomaly_every == anomaly_every - 1:
            k = max(1, int(round(batch * anomaly_frac)))
            rows = rng.choice(batch, size=k, replace=False)
            nu = np.zeros(dim)
            nu[2 * third:] = 6.0
            x[rows] = np.abs(rng.normal(size=(k, dim)) * 0.4 + nu)
            y[rows] = 1
        out.append((x.astype(np.float32), y))
    return out


def bias_augment(x: np.ndarray, c: float = 1.0) -> np.ndarray:
    """Append a constant coordinate: makes SRP (angular) sensitive to offsets.

    Classic trick: cos∠([x,c],[y,c]) mixes direction and magnitude, so
    mean-shift anomalies in centred data become angular anomalies.  Used by
    the training-telemetry monitor where features are signed.
    """
    ones = np.full((*x.shape[:-1], 1), c, dtype=x.dtype)
    return np.concatenate([x, ones], axis=-1)
