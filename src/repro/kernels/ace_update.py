"""Pallas TPU kernel: ACE count-array update (streaming insert).

The counts (L, 2^K) stay resident in VMEM (3.2 MB int16 / 6.4 MB int32 at
the paper's K=15, L=50 — the TPU translation of the paper's "fits in L3
cache") and are updated **in place** via input/output aliasing; only the
(B, L) bucket ids stream in from HBM.

Two lowering strategies, chosen by ``mode``:

* ``"scalar"``: TPUs have no fast random scatter, so the per-item
  `A[H(x)]++` of Algorithm 1 becomes a sequential scalar read-modify-write
  loop over the (B, L) ids on the scalar core — exactly what the paper's
  CPU inner loop does, and collision-safe by construction.  Cost ~
  c·B·L scalar cycles (c ≈ 8 for the RMW + loop overhead).

* ``"hist"``: vectorised one-hot compare-accumulate — per table j, compare
  the (B,) id column against the bucket iota and column-sum the (B, 2^K)
  one-hot block on the VPU.  Cost ~ L·⌈B/8⌉·(2^K/128) VPU ops, i.e.
  B·L·2^K/1024 lanes of work: MORE raw ops than the scalar loop but wide
  and parallel, so it wins whenever 2^K ≲ c·1024 AND the batch is big
  enough to amortise the loop setup.

``mode="auto"`` (the default used by ``repro.kernels.ops.ace_update``)
applies that cost model: the hist path is selected when B·L exceeds
``HIST_BREAK_EVEN_BL`` and the bucket space is at most
``HIST_MAX_BUCKETS``; otherwise the scalar loop runs.  Both paths are
bit-identical (property-tested in tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret

# Break-even constants of the cost model above (c ≈ 8 scalar cycles per
# RMW → hist wins up to 2^K = 8192; the B·L floor keeps tiny batches on
# the zero-setup scalar loop).
HIST_BREAK_EVEN_BL = 1024
HIST_MAX_BUCKETS = 8192
# The one-hot block is swept in row tiles of this many batch items so its
# VMEM intermediate stays bounded (128 × 8192 × 4 B = 4 MB at the max
# bucket space) no matter how large B grows.
HIST_ROW_TILE = 128


def choose_mode(B: int, L: int, nbuckets: int) -> str:
    """Pick the insert lowering for a (B, L) batch into 2^K buckets."""
    if B * L >= HIST_BREAK_EVEN_BL and nbuckets <= HIST_MAX_BUCKETS:
        return "hist"
    return "scalar"


def _kernel_scalar(buckets_ref, counts_in_ref, counts_out_ref,
                   *, B: int, L: int):
    # Aliased: counts_out_ref is the same buffer as counts_in_ref.
    def body(t, _):
        b = t // L
        j = t % L
        idx = buckets_ref[b, j]
        c = counts_out_ref[j, pl.dslice(idx, 1)]
        counts_out_ref[j, pl.dslice(idx, 1)] = c + jnp.ones_like(c)
        return 0

    # Touch the input alias so the dataflow is explicit under interpret mode.
    counts_out_ref[0, 0] = counts_in_ref[0, 0]
    jax.lax.fori_loop(0, B * L, body, 0)


def _kernel_hist(buckets_ref, counts_in_ref, counts_out_ref,
                 *, B: int, L: int, nbuckets: int):
    # One-hot compare-accumulate per table (fori, not unrolled, so the
    # Mosaic program stays O(1) in L).  Duplicate ids in a column land on
    # the same one-hot lane and sum — collision-safe like the scalar RMW.
    # The batch axis is swept in HIST_ROW_TILE chunks so the one-hot
    # intermediate is at most (tile, 2^K) in VMEM, independent of B.
    ids = buckets_ref[...]                                       # (B, L)
    dtype = counts_out_ref.dtype
    counts_out_ref[0, 0] = counts_in_ref[0, 0]

    def body(j, _):
        hist = jnp.zeros((1, nbuckets), dtype)
        for t0 in range(0, B, HIST_ROW_TILE):                # static tiling
            bt = min(HIST_ROW_TILE, B - t0)
            col = jax.lax.dynamic_slice(ids, (t0, j), (bt, 1))   # (bt, 1)
            onehot = (col == jax.lax.broadcasted_iota(
                jnp.int32, (bt, nbuckets), 1)).astype(dtype)     # (bt, 2^K)
            hist = hist + jnp.sum(onehot, axis=0, keepdims=True,
                                  dtype=dtype)                   # (1, 2^K)
        row = counts_out_ref[pl.dslice(j, 1), :]
        counts_out_ref[pl.dslice(j, 1), :] = row + hist
        return 0

    jax.lax.fori_loop(0, L, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret", "donate", "mode"))
def ace_update(counts: jax.Array, buckets: jax.Array,
               interpret: bool | None = None, donate: bool = True,
               mode: str = "auto") -> jax.Array:
    """counts (L, 2^K) int; buckets (B, L) int32 -> updated counts.

    In-place on TPU via input_output_aliases (the counts buffer is donated).
    ``mode`` ∈ {"auto", "scalar", "hist"} — see the module docstring.
    """
    interpret = resolve_interpret(interpret)
    L, nbuckets = counts.shape
    B = buckets.shape[0]
    assert buckets.shape == (B, L)
    if mode == "auto":
        mode = choose_mode(B, L, nbuckets)
    if mode == "scalar":
        kern = functools.partial(_kernel_scalar, B=B, L=L)
    elif mode == "hist":
        kern = functools.partial(_kernel_hist, B=B, L=L, nbuckets=nbuckets)
    else:
        raise ValueError(f"unknown mode {mode!r}")

    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((B, L), lambda i: (0, 0)),
            pl.BlockSpec((L, nbuckets), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((L, nbuckets), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((L, nbuckets), counts.dtype),
        input_output_aliases={1: 0} if donate else {},
        interpret=interpret,
    )(buckets, counts)
