"""Pallas TPU kernel: ACE count-array update (streaming insert).

The counts (L, 2^K) stay resident in VMEM (3.2 MB int16 / 6.4 MB int32 at
the paper's K=15, L=50 — the TPU translation of the paper's "fits in L3
cache") and are updated **in place** via input/output aliasing; only the
(B, L) bucket ids stream in from HBM.

TPUs have no fast random scatter, so the per-item `A[H(x)]++` of Algorithm 1
becomes a sequential scalar read-modify-write loop over the (B, L) ids on
the scalar core — which is exactly what the paper's CPU inner loop does,
and is collision-safe by construction.  The loop is O(B·L) scalar ops
against a (B·d·K·L)-FLOP hash matmul, i.e. ~d·K/1 ≳ 10³× cheaper — the
update is never the bottleneck (validated in §Roofline of EXPERIMENTS.md).

A vectorised histogram variant (one-hot compare-accumulate over bucket
tiles) is provided for small K in ``repro.kernels.ops.histogram_small_k``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(buckets_ref, counts_in_ref, counts_out_ref, *, B: int, L: int):
    # Aliased: counts_out_ref is the same buffer as counts_in_ref.
    def body(t, _):
        b = t // L
        j = t % L
        idx = buckets_ref[b, j]
        c = counts_out_ref[j, pl.dslice(idx, 1)]
        counts_out_ref[j, pl.dslice(idx, 1)] = c + jnp.ones_like(c)
        return 0

    # Touch the input alias so the dataflow is explicit under interpret mode.
    counts_out_ref[0, 0] = counts_in_ref[0, 0]
    jax.lax.fori_loop(0, B * L, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret", "donate"))
def ace_update(counts: jax.Array, buckets: jax.Array,
               interpret: bool = True, donate: bool = True) -> jax.Array:
    """counts (L, 2^K) int; buckets (B, L) int32 -> updated counts.

    In-place on TPU via input_output_aliases (the counts buffer is donated).
    """
    L, nbuckets = counts.shape
    B = buckets.shape[0]
    assert buckets.shape == (B, L)

    return pl.pallas_call(
        functools.partial(_kernel, B=B, L=L),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((B, L), lambda i: (0, 0)),
            pl.BlockSpec((L, nbuckets), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((L, nbuckets), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((L, nbuckets), counts.dtype),
        input_output_aliases={1: 0} if donate else {},
        interpret=interpret,
    )(buckets, counts)
