"""Pallas TPU kernel: fused ACE scoring — hash + lookup + mean in one pass.

Beyond-paper optimisation: the serving guardrail scores every request batch;
doing hash (srp_hash) and lookup (ace_query) as separate kernels round-trips
the (B, L) bucket ids through HBM and re-launches.  This kernel keeps the
bucket ids in registers/VMEM and emits only the (B,) scores:

    HBM reads : q (B·d·4) + W (d·P·4, grid-reused) + counts (L·2^K, resident)
    HBM writes: scores (B·4)

Grid: (B/bm, d/bk) with the (bm, P) accumulator in VMEM scratch; on the last
d-tile: sign -> pack-matmul -> ONE flattened row-offset gather
(``buckets + j·2^K`` into the raveled counts, see ``flat_table_gather``) ->
row mean, written to a (bm, 128) output tile (column 0 holds the score; the
wrapper slices).

VMEM at defaults (bm=128, bk=512, P=768, K=15, L=50, int32 counts):
  q 0.25 + W 1.5 + acc 0.4 + pack 0.4 + counts 6.6 + out ~0.1 ≈ 9.2 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.srp import SrpConfig
from repro.kernels.runtime import resolve_interpret
from repro.kernels.srp_hash import make_pack_matrix, _round_up


def flat_table_gather(counts: jax.Array, buckets: jax.Array,
                      L: int, nbuckets: int) -> jax.Array:
    """Gather counts[j, buckets[:, j]] as ONE flattened take.

    counts (L, 2^K) ravels row-major to (L·2^K,) and table j's ids offset
    by j·2^K index straight into it — a single vectorised gather instead
    of L unrolled per-table ``jnp.take``s (at the paper's L=50 the unroll
    bloats the Mosaic program and trace time ~50×).  The ravel is a
    layout no-op when 2^K is lane-aligned (K ≥ 7; always true at serving
    scale — tiny-K test shapes only run under interpret).
    """
    flat = counts.reshape(L * nbuckets)
    offs = buckets[:, :L] + jax.lax.broadcasted_iota(
        jnp.int32, (buckets.shape[0], L), 1) * nbuckets
    return jnp.take(flat, offs, axis=0).astype(jnp.float32)       # (B, L)


def _kernel(q_ref, w_ref, pack_ref, counts_ref, *rest,
            nk: int, L: int, nbuckets: int, weighted: bool):
    if weighted:
        tw_ref, out_ref, acc_ref = rest
    else:
        out_ref, acc_ref = rest
        tw_ref = None
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        q_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _finish():
        bits = (acc_ref[...] >= 0.0).astype(jnp.float32)
        buckets = jnp.dot(bits, pack_ref[...],
                          preferred_element_type=jnp.float32).astype(jnp.int32)
        gathered = flat_table_gather(counts_ref[...], buckets, L, nbuckets)
        if weighted:
            # degraded-mode combine: the caller bakes the health mask AND
            # its 1/num_healthy normaliser into table_weights, so the
            # kernel applies NO 1/L of its own
            tw = tw_ref[...][0, :L]
            score = jnp.sum(gathered * tw[None, :], axis=-1)
        else:
            # reciprocal multiply, not `/ L` — same parity convention as
            # sketch.batch_scores and the fused admit kernel
            score = jnp.sum(gathered, axis=-1) * jnp.float32(1.0 / L)
        out_ref[...] = jnp.broadcast_to(score[:, None], out_ref.shape)


@functools.partial(jax.jit, static_argnames=("cfg", "bm", "bk", "interpret"))
def ace_score_fused(counts: jax.Array, q: jax.Array, w: jax.Array,
                    cfg: SrpConfig, bm: int = 128, bk: int = 512,
                    interpret: bool | None = None,
                    table_weights: jax.Array | None = None) -> jax.Array:
    """counts (L, 2^K), q (B, d), w (d, P) -> scores (B,) float32.

    ``table_weights`` (L,) float32, when given, replaces the 1/L mean
    with the weighted combine ``Σ_j tw_j · gathered_j`` — the degraded
    health-mask path (the caller normalises tw, typically
    mask/num_healthy).  ``None`` compiles the unchanged healthy kernel.
    """
    interpret = resolve_interpret(interpret)
    B, d = q.shape
    P = cfg.padded_projections
    L, nbuckets = counts.shape
    assert w.shape == (d, P) and L == cfg.num_tables

    bm_ = min(bm, _round_up(B, 8))
    bk_ = min(bk, _round_up(d, 128))
    Bp, dp = _round_up(B, bm_), _round_up(d, bk_)
    qp = jnp.pad(q, ((0, Bp - B), (0, dp - d)))
    wp = jnp.pad(w, ((0, dp - d), (0, 0)))
    lp = _round_up(L, 128)
    pack = jnp.asarray(make_pack_matrix(cfg, lp))
    nb, nk = Bp // bm_, dp // bk_
    weighted = table_weights is not None

    in_specs = [
        pl.BlockSpec((bm_, bk_), lambda i, k: (i, k)),
        pl.BlockSpec((bk_, P), lambda i, k: (k, 0)),
        pl.BlockSpec((P, lp), lambda i, k: (0, 0)),
        pl.BlockSpec((L, nbuckets), lambda i, k: (0, 0)),
    ]
    operands = [qp, wp, pack, counts]
    if weighted:
        twp = jnp.pad(table_weights.astype(jnp.float32)[None, :],
                      ((0, 0), (0, lp - L)))
        in_specs.append(pl.BlockSpec((1, lp), lambda i, k: (0, 0)))
        operands.append(twp)

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, L=L, nbuckets=nbuckets,
                          weighted=weighted),
        grid=(nb, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm_, 128), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, 128), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm_, P), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out[:B, 0]
