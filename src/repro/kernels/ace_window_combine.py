"""Pallas TPU kernel: windowed ACE scoring — E-way weighted gather+combine
in ONE launch.

The sliding-window sketch (``repro.window``) scores a query against E
epoch count arrays and combines them with per-epoch decay weights:

    score(q) = (1/L) · Σ_e w_e · Σ_j C_e[j, H_j(q)]

Done naively that is E separate ``ace_query`` launches plus a host-side
weighted sum — E× the launch overhead and E round-trips of the (B, L)
gathered matrices through HBM.  This kernel keeps the whole (E, L, 2^K)
ring VMEM-resident and fuses gather → weight → epoch-sum → table-mean
into one pass; HBM traffic is the (B, L) bucket ids in and the (B,)
scores out, independent of E.

Two lowering strategies, chosen by ``mode``:

* ``"flat"`` (preferred): the ring ravels to one (E·L·2^K,) row and each
  (epoch, table) pair's ids offset by ``(e·L + j)·2^K`` — E·L gather
  columns in a SINGLE vectorised ``jnp.take`` (the window generalisation
  of ``ace_score_fused.flat_table_gather``'s row-offset trick), then the
  weighted epoch reduction runs as one (B, E) @ diag-free contraction.
* ``"unroll"``: per-epoch static loop over E ``flat_table_gather`` calls
  (the guaranteed-lowerable baseline; also what the jnp reference path
  does).  ``choose_mode`` picks ``"flat"`` while the flattened gather
  index space fits the single-take budget, ``"unroll"`` beyond it.

Summation-order contract: BOTH modes accumulate ``w_e · (per-epoch table
row-sum)`` over e in ring-index order and apply ONE final reciprocal
multiply by 1/L — the same formula sequence as
``repro.window.score_windowed`` and ``kernels.ref.ace_window_combine_ref``.
Like every score-emitting kernel here (``ace_score_fused``,
``ace_query`` + mean), the in-kernel L-reduction may reassociate vs the
plain-jnp program, so kernel-vs-ref parity is float-tolerance (rtol
1e-6 in the parity matrix), while the jnp windowed path keeps its OWN
bitwise contracts (E=1 ≡ ``batch_scores``, sharded ≡ replicated).

VMEM at the paper shape (K=15, L=50, int32, E=8): counts 50 MB — past
the ~16 MB budget, so serving-scale windows run table-sharded (the ring
splits over L; see repro.dist) or at int16/K=13; the kernel itself is
shape-agnostic and the tests sweep small awkward shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret


# One vectorised take's index space: beyond this the flat gather's
# (B, E·L) offset matrix + raveled ring stop paying for themselves and
# the per-epoch unroll (E smaller takes) lowers more predictably.
FLAT_MAX_COLS = 4096


def choose_mode(E: int, L: int) -> str:
    """The ``mode="auto"`` break-even: flat single-take vs per-epoch
    unroll, by the number of gather columns E·L."""
    return "flat" if E * L <= FLAT_MAX_COLS else "unroll"


def _weighted_table_sums(counts, buckets, weights, *, E, L, nbuckets,
                         mode, table_weights=None):
    """Σ_e w_e · Σ_j C_e[j, b_j]  for a (bm, L) bucket block -> (bm,).

    Shared by both the kernel body and (via ref) the oracles; the
    canonical summation order lives HERE once.  ``table_weights`` (L,)
    scales each table's gathered column before the row-sum — the
    degraded health-mask combine (None leaves the healthy sums
    untouched).
    """
    rows_off = jax.lax.broadcasted_iota(
        jnp.int32, (buckets.shape[0], L), 1) * nbuckets
    if mode == "flat":
        flat = counts.reshape(E * L * nbuckets)
        # (B, E*L) offsets: epoch-major blocks of table-offset ids
        offs = jnp.concatenate(
            [buckets + rows_off + e * (L * nbuckets) for e in range(E)],
            axis=1)
        gathered = jnp.take(flat, offs, axis=0).astype(jnp.float32)
        acc = jnp.zeros(buckets.shape[:1], jnp.float32)
        for e in range(E):   # ring-index order (parity contract)
            g = gathered[:, e * L:(e + 1) * L]
            if table_weights is not None:
                g = g * table_weights[None, :]
            acc = acc + weights[e] * jnp.sum(g, axis=-1)
        return acc
    # unroll: E independent flattened single-epoch gathers
    acc = jnp.zeros(buckets.shape[:1], jnp.float32)
    for e in range(E):
        flat_e = counts[e].reshape(L * nbuckets)
        g = jnp.take(flat_e, buckets + rows_off,
                     axis=0).astype(jnp.float32)
        if table_weights is not None:
            g = g * table_weights[None, :]
        acc = acc + weights[e] * jnp.sum(g, axis=-1)
    return acc


def _kernel(buckets_ref, w_ref, counts_ref, *rest, E, L, nbuckets,
            mode, weighted):
    if weighted:
        tw_ref, out_ref = rest
        tw = tw_ref[...][0, :L]
    else:
        (out_ref,) = rest
        tw = None
    buckets = buckets_ref[...]
    weights = [w_ref[0, e] for e in range(E)]
    acc = _weighted_table_sums(counts_ref[...], buckets, weights,
                               E=E, L=L, nbuckets=nbuckets, mode=mode,
                               table_weights=tw)
    if weighted:
        # degraded combine: the caller bakes the 1/num_healthy normaliser
        # into table_weights, so no 1/L here
        score = acc
    else:
        score = acc * jnp.float32(1.0 / L)
    out_ref[...] = jnp.broadcast_to(score[:, None], out_ref.shape)


@functools.partial(jax.jit,
                   static_argnames=("interpret", "mode", "bm"))
def ace_window_combine(counts: jax.Array, buckets: jax.Array,
                       weights: jax.Array,
                       interpret: bool | None = None, mode: str = "auto",
                       bm: int = 1024,
                       table_weights: jax.Array | None = None) -> jax.Array:
    """counts (E, L, 2^K), buckets (B, L), weights (E,) -> (B,) scores.

    ``weights`` is the γ^age epoch-weight vector (a traced array — the
    ring cursor moves every rotation, and re-tracing per cursor position
    would defeat the one-executable contract).

    ``table_weights`` (L,) float32, when given, scales each table's
    column and REPLACES the 1/L mean (the caller bakes the health mask
    and its 1/num_healthy normaliser in — the degraded-mode contract
    shared with ``ace_score_fused``)."""
    interpret = resolve_interpret(interpret)
    E, L, nbuckets = counts.shape
    B = buckets.shape[0]
    assert buckets.shape == (B, L), (buckets.shape, (B, L))
    assert weights.shape == (E,), (weights.shape, E)
    if mode == "auto":
        mode = choose_mode(E, L)
    if mode not in ("flat", "unroll"):
        raise ValueError(f"unknown mode {mode!r}")

    bm_ = min(bm, max(B, 8))
    Bp = ((B + bm_ - 1) // bm_) * bm_
    bp = jnp.pad(buckets, ((0, Bp - B), (0, 0)))
    # lane-pad the weights row so the (1, E) block is VPU-addressable
    Ep = ((E + 127) // 128) * 128
    wp = jnp.pad(weights.astype(jnp.float32)[None, :], ((0, 0), (0, Ep - E)))
    weighted = table_weights is not None

    in_specs = [
        pl.BlockSpec((bm_, L), lambda i: (i, 0)),
        pl.BlockSpec((1, Ep), lambda i: (0, 0)),
        pl.BlockSpec((E, L, nbuckets), lambda i: (0, 0, 0)),
    ]
    operands = [bp, wp, counts]
    if weighted:
        Lp = ((L + 127) // 128) * 128
        twp = jnp.pad(table_weights.astype(jnp.float32)[None, :],
                      ((0, 0), (0, Lp - L)))
        in_specs.append(pl.BlockSpec((1, Lp), lambda i: (0, 0)))
        operands.append(twp)

    out = pl.pallas_call(
        functools.partial(_kernel, E=E, L=L, nbuckets=nbuckets, mode=mode,
                          weighted=weighted),
        grid=(Bp // bm_,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm_, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, 128), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out[:B, 0]
