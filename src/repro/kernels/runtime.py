"""Shared interpret-mode resolver for every Pallas kernel entry point.

Every kernel wrapper in ``repro.kernels`` takes ``interpret: bool | None``
and resolves ``None`` through :func:`resolve_interpret`, so there is ONE
place deciding whether kernel bodies run under the Pallas interpreter
(traced JAX on CPU — bit-exact contract validation) or the Mosaic TPU
lowering.  Before this module, ``srp_hash`` and friends hard-coded
``interpret=True`` in their signatures, which meant a TPU run that forgot
to pass the flag silently *timed interpret mode* — benchmarks looked
plausible and measured nothing.

Resolution order:

1. ``REPRO_PALLAS_INTERPRET`` env var, when set: ``"0"`` → Mosaic,
   anything else → interpret.  (Same variable the old ``ops.INTERPRET``
   global read; it now governs every kernel, not just the ops wrappers.)
2. Otherwise: interpret exactly when the default JAX backend is not a
   TPU — CPU containers validate contracts, TPU runtimes get Mosaic
   without any flag-plumbing.

An explicit ``interpret=True/False`` argument always wins (tests pin it;
the VMEM-budget check in ``ace_admit_fused`` keys off the resolved
value).

Also home of the tile-size autotuner (:func:`autotune`): kernel wrappers
that accept ``bm="auto"``/``bk="auto"`` time a few tile candidates once
and cache the winner per ``(kernel, shape, backend)``.  The backend is
part of the key — and "interpret" is a backend of its own — because a
tile size timed under the Pallas interpreter on CPU says NOTHING about
Mosaic on TPU: before the keying fix, one interpret-mode warmup call
could poison the cache with a CPU-tuned tile that every subsequent TPU
call then silently inherited.  The cache is also invalidated wholesale
when the probed default backend changes mid-process (e.g. a TPU runtime
initialised after a CPU-only import), so stale entries from the old
probe can never leak into the new one.
"""
from __future__ import annotations

import os
import time

_ENV = "REPRO_PALLAS_INTERPRET"


def default_interpret() -> bool:
    """The process-wide interpret default (env var, else backend probe)."""
    env = os.environ.get(_ENV)
    if env is not None:
        return env != "0"
    import jax

    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve a kernel wrapper's ``interpret`` argument (None → default)."""
    if interpret is None:
        return default_interpret()
    return bool(interpret)


# ---------------------------------------------------------------------------
# Tile-size autotuner.
# ---------------------------------------------------------------------------

# (kernel_name, shape_key, backend_key) -> winning candidate.  backend_key
# is "interpret" for interpreter runs, else the probed jax backend name —
# NEVER share entries across the two (see module docstring).
_AUTOTUNE_CACHE: dict = {}
_PROBED_BACKEND: str | None = None


def _backend_key(interpret: bool) -> str:
    import jax

    return "interpret" if interpret else jax.default_backend()


def _check_backend_probe() -> None:
    """Invalidate the whole cache if the probed default backend changed
    (a late-initialised TPU runtime, a test reconfiguring platforms)."""
    global _PROBED_BACKEND
    import jax

    probe = jax.default_backend()
    if _PROBED_BACKEND is None:
        _PROBED_BACKEND = probe
    elif _PROBED_BACKEND != probe:
        _AUTOTUNE_CACHE.clear()
        _PROBED_BACKEND = probe


def autotune(kernel_name: str, shape_key: tuple, interpret: bool,
             candidates, bench_fn=None, reps: int = 3):
    """Pick (and cache) the fastest tile candidate for one kernel/shape.

    ``candidates`` is a non-empty sequence of opaque tile params (e.g.
    ``(bm, bk)`` tuples); ``bench_fn(candidate)`` runs the kernel eagerly
    with that tiling and returns something with ``block_until_ready`` (a
    jax array or pytree leaf).  The winner is cached under
    ``(kernel_name, shape_key, backend)`` — min-of-``reps`` timing, so a
    single descheduling blip can't crown a loser.  With ``bench_fn=None``
    (or under tracing, where timing is impossible — callers must pass
    concrete operands or fall back before calling) the first candidate
    is returned WITHOUT caching, so a degraded call can never pin the
    default into the cache.
    """
    import jax

    candidates = list(candidates)
    if not candidates:
        raise ValueError("autotune needs at least one candidate")
    _check_backend_probe()
    key = (kernel_name, tuple(shape_key), _backend_key(interpret))
    if key in _AUTOTUNE_CACHE:
        return _AUTOTUNE_CACHE[key]
    if bench_fn is None:
        return candidates[0]
    best, best_t = None, None
    for cand in candidates:
        try:
            jax.block_until_ready(bench_fn(cand))  # compile warmup
            t = min(_time_one(bench_fn, cand) for _ in range(reps))
        except Exception:
            continue   # a candidate that fails to lower just loses
        if best_t is None or t < best_t:
            best, best_t = cand, t
    if best is None:
        best = candidates[0]   # nothing timed — don't cache a guess
        return best
    _AUTOTUNE_CACHE[key] = best
    return best


def _time_one(bench_fn, cand) -> float:
    import jax

    t0 = time.perf_counter()
    jax.block_until_ready(bench_fn(cand))
    return time.perf_counter() - t0
