"""Shared interpret-mode resolver for every Pallas kernel entry point.

Every kernel wrapper in ``repro.kernels`` takes ``interpret: bool | None``
and resolves ``None`` through :func:`resolve_interpret`, so there is ONE
place deciding whether kernel bodies run under the Pallas interpreter
(traced JAX on CPU — bit-exact contract validation) or the Mosaic TPU
lowering.  Before this module, ``srp_hash`` and friends hard-coded
``interpret=True`` in their signatures, which meant a TPU run that forgot
to pass the flag silently *timed interpret mode* — benchmarks looked
plausible and measured nothing.

Resolution order:

1. ``REPRO_PALLAS_INTERPRET`` env var, when set: ``"0"`` → Mosaic,
   anything else → interpret.  (Same variable the old ``ops.INTERPRET``
   global read; it now governs every kernel, not just the ops wrappers.)
2. Otherwise: interpret exactly when the default JAX backend is not a
   TPU — CPU containers validate contracts, TPU runtimes get Mosaic
   without any flag-plumbing.

An explicit ``interpret=True/False`` argument always wins (tests pin it;
the VMEM-budget check in ``ace_admit_fused`` keys off the resolved
value).
"""
from __future__ import annotations

import os

_ENV = "REPRO_PALLAS_INTERPRET"


def default_interpret() -> bool:
    """The process-wide interpret default (env var, else backend probe)."""
    env = os.environ.get(_ENV)
    if env is not None:
        return env != "0"
    import jax

    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve a kernel wrapper's ``interpret`` argument (None → default)."""
    if interpret is None:
        return default_interpret()
    return bool(interpret)
