"""Shared interpret-mode resolver for every Pallas kernel entry point.

Every kernel wrapper in ``repro.kernels`` takes ``interpret: bool | None``
and resolves ``None`` through :func:`resolve_interpret`, so there is ONE
place deciding whether kernel bodies run under the Pallas interpreter
(traced JAX on CPU — bit-exact contract validation) or the Mosaic TPU
lowering.  Before this module, ``srp_hash`` and friends hard-coded
``interpret=True`` in their signatures, which meant a TPU run that forgot
to pass the flag silently *timed interpret mode* — benchmarks looked
plausible and measured nothing.

Resolution order:

1. ``REPRO_PALLAS_INTERPRET`` env var, when set: ``"0"`` → Mosaic,
   anything else → interpret.  (Same variable the old ``ops.INTERPRET``
   global read; it now governs every kernel, not just the ops wrappers.)
2. Otherwise: interpret exactly when the default JAX backend is not a
   TPU — CPU containers validate contracts, TPU runtimes get Mosaic
   without any flag-plumbing.

An explicit ``interpret=True/False`` argument always wins (tests pin it;
the VMEM-budget check in ``ace_admit_fused`` keys off the resolved
value).

Also home of the tile-size autotuner (:func:`autotune`): kernel wrappers
that accept ``bm="auto"``/``bk="auto"`` time a few tile candidates once
and cache the winner per ``(kernel, shape, backend)``.  The backend is
part of the key — and "interpret" is a backend of its own — because a
tile size timed under the Pallas interpreter on CPU says NOTHING about
Mosaic on TPU: before the keying fix, one interpret-mode warmup call
could poison the cache with a CPU-tuned tile that every subsequent TPU
call then silently inherited.  The cache is also invalidated wholesale
when the probed default backend changes mid-process (e.g. a TPU runtime
initialised after a CPU-only import), so stale entries from the old
probe can never leak into the new one.

Multi-process safety (repro.cluster): the backend probe is memoized to
run ONCE per process at first kernel use — never at import, never per
call — because ``jax.default_backend()`` initialises the backend, and a
subprocess host probing before its ``jax.distributed.initialize()``
would bind a local-only runtime.  Winners optionally persist across
processes via ``REPRO_AUTOTUNE_CACHE_DIR``: one json file per key,
written atomically (tmp + ``os.replace``), so concurrent subprocess
hosts sharing the directory can never read a torn entry — last writer
wins, every intermediate state is a valid cache.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

_ENV = "REPRO_PALLAS_INTERPRET"
_CACHE_DIR_ENV = "REPRO_AUTOTUNE_CACHE_DIR"

# Memoized jax.default_backend() probe.  Probing is not free under a
# multi-process launch: jax.default_backend() INITIALISES the backend,
# and a subprocess host that probes before its jax.distributed
# .initialize() call silently binds a local-only runtime — so the probe
# must run exactly once per process, at first kernel use (after the
# launcher has initialised distributed), never per call.  Tests that
# reconfigure platforms reset it via :func:`reset_runtime_state`.
_PROBED_BACKEND: str | None = None


def probe_backend() -> str:
    """The memoized once-per-process jax.default_backend() probe."""
    global _PROBED_BACKEND
    if _PROBED_BACKEND is None:
        import jax

        _PROBED_BACKEND = jax.default_backend()
    return _PROBED_BACKEND


def reset_runtime_state() -> None:
    """Forget the memoized backend probe and the in-memory autotune
    cache (tests reconfiguring platforms; NOT needed in production)."""
    global _PROBED_BACKEND
    _PROBED_BACKEND = None
    _AUTOTUNE_CACHE.clear()


def default_interpret() -> bool:
    """The process-wide interpret default (env var, else backend probe)."""
    env = os.environ.get(_ENV)
    if env is not None:
        return env != "0"
    return probe_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve a kernel wrapper's ``interpret`` argument (None → default)."""
    if interpret is None:
        return default_interpret()
    return bool(interpret)


# ---------------------------------------------------------------------------
# Tile-size autotuner.
# ---------------------------------------------------------------------------

# (kernel_name, shape_key, backend_key) -> winning candidate.  backend_key
# is "interpret" for interpreter runs, else the probed jax backend name —
# NEVER share entries across the two (see module docstring).
_AUTOTUNE_CACHE: dict = {}


def _backend_key(interpret: bool) -> str:
    return "interpret" if interpret else probe_backend()


def _check_backend_probe() -> None:
    """Invalidate the whole cache if the probed default backend changed
    (a late-initialised TPU runtime, a test reconfiguring platforms).
    The probe itself is the memoized once-per-process one — this re-reads
    jax.default_backend() only when the backend was already initialised,
    so it can never initialise a backend early in a subprocess host."""
    global _PROBED_BACKEND
    if _PROBED_BACKEND is None:
        probe_backend()               # seed the once-per-process probe
        return
    import jax

    probe = jax.default_backend()
    if _PROBED_BACKEND != probe:
        _AUTOTUNE_CACHE.clear()
        _PROBED_BACKEND = probe


# ---------------------------------------------------------------------------
# Optional cross-process persistence: REPRO_AUTOTUNE_CACHE_DIR names a
# directory where each (kernel, shape, backend) winner lives in its OWN
# json file, written atomically (tmp in the same dir + os.replace).
# Multi-process launches share one directory safely: concurrent writers
# of the same key each produce a valid file and the last rename wins;
# readers either see a complete file or no file — never a torn one.
# A single shared mutable file would instead interleave writes from
# subprocess hosts (the race this replaces).  Unreadable entries are
# ignored (same as a cache miss), so a crashed writer costs one re-tune.
# ---------------------------------------------------------------------------

def _cache_file(key: tuple) -> str | None:
    root = os.environ.get(_CACHE_DIR_ENV)
    if not root:
        return None
    import hashlib

    h = hashlib.sha256(repr(key).encode()).hexdigest()[:24]
    return os.path.join(root, f"tune_{h}.json")


def _load_persistent(key: tuple):
    path = _cache_file(key)
    if path is None or not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            entry = json.load(f)
        if entry.get("key") != _jsonable_key(key):
            return None               # hash collision / stale schema
        winner = entry["winner"]
        return tuple(winner) if isinstance(winner, list) else winner
    except (OSError, ValueError, KeyError):
        return None                   # torn/foreign file == miss


def _store_persistent(key: tuple, winner) -> None:
    path = _cache_file(key)
    if path is None:
        return
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                   prefix=".tune_tmp_")
        with os.fdopen(fd, "w") as f:
            json.dump({"key": _jsonable_key(key), "winner": winner}, f)
        os.replace(tmp, path)         # atomic on POSIX
    except OSError:
        pass                          # persistence is best-effort


def _jsonable_key(key: tuple):
    return json.loads(json.dumps(key))


def autotune(kernel_name: str, shape_key: tuple, interpret: bool,
             candidates, bench_fn=None, reps: int = 3):
    """Pick (and cache) the fastest tile candidate for one kernel/shape.

    ``candidates`` is a non-empty sequence of opaque tile params (e.g.
    ``(bm, bk)`` tuples); ``bench_fn(candidate)`` runs the kernel eagerly
    with that tiling and returns something with ``block_until_ready`` (a
    jax array or pytree leaf).  The winner is cached under
    ``(kernel_name, shape_key, backend)`` — min-of-``reps`` timing, so a
    single descheduling blip can't crown a loser.  With ``bench_fn=None``
    (or under tracing, where timing is impossible — callers must pass
    concrete operands or fall back before calling) the first candidate
    is returned WITHOUT caching, so a degraded call can never pin the
    default into the cache.
    """
    import jax

    candidates = list(candidates)
    if not candidates:
        raise ValueError("autotune needs at least one candidate")
    _check_backend_probe()
    key = (kernel_name, tuple(shape_key), _backend_key(interpret))
    if key in _AUTOTUNE_CACHE:
        return _AUTOTUNE_CACHE[key]
    persisted = _load_persistent(key)
    if persisted is not None and persisted in candidates:
        _AUTOTUNE_CACHE[key] = persisted
        return persisted
    if bench_fn is None:
        return candidates[0]
    best, best_t = None, None
    for cand in candidates:
        try:
            jax.block_until_ready(bench_fn(cand))  # compile warmup
            t = min(_time_one(bench_fn, cand) for _ in range(reps))
        except Exception:
            continue   # a candidate that fails to lower just loses
        if best_t is None or t < best_t:
            best, best_t = cand, t
    if best is None:
        best = candidates[0]   # nothing timed — don't cache a guess
        return best
    _AUTOTUNE_CACHE[key] = best
    _store_persistent(key, best)
    return best


def _time_one(bench_fn, cand) -> float:
    import jax

    t0 = time.perf_counter()
    jax.block_until_ready(bench_fn(cand))
    return time.perf_counter() - t0
