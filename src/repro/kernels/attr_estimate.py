"""Pallas kernel: signed count-sketch gather + median-of-rows estimate.

One level of an attribution hierarchy (``repro.attribution``) is an
(R, C) signed plane; a batch point query gathers plane[r, cols[b, r]],
applies the ±1 sign, and takes the median over the R rows:

    out[b] = median_r( signs[b, r] · plane[r, cols[b, r]] )

The plane is VMEM-resident (R·C floats — a few hundred KB at the
default R=5, C=256); queries stream in as (B, R) bucket columns + signs.
Like ``ace_query`` the gather is a static per-row unroll of lane
gathers; the median is an in-register sort over the static (small) R
axis — odd R takes the middle order statistic, even R the midpoint,
matching ``repro.attribution.sketch._median_lastaxis`` exactly (the
shared contract the ``ref.py`` oracle pins).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret


def _kernel(cols_ref, signs_ref, plane_ref, out_ref, *, R: int):
    g = []
    for r in range(R):  # static unroll over sketch rows
        row = plane_ref[r, :]
        ids = cols_ref[:, r]
        g.append(jnp.take(row, ids, axis=0).astype(jnp.float32)
                 * signs_ref[:, r])
    mat = jnp.stack(g, axis=-1)                         # (bm, R)
    srt = jnp.sort(mat, axis=-1)
    mid = R // 2
    if R % 2:
        out_ref[:] = srt[:, mid]
    else:
        out_ref[:] = 0.5 * (srt[:, mid - 1] + srt[:, mid])


@functools.partial(jax.jit, static_argnames=("interpret", "bm"))
def attr_estimate(plane: jax.Array, cols: jax.Array, signs: jax.Array,
                  interpret: bool | None = None,
                  bm: int = 1024) -> jax.Array:
    """plane (R, C) f32, cols (B, R) int32, signs (B, R) f32 ±1
    -> (B,) float32 median-of-rows signed estimates."""
    interpret = resolve_interpret(interpret)
    R, C = plane.shape
    B = cols.shape[0]
    assert cols.shape == (B, R), (cols.shape, (B, R))
    assert signs.shape == (B, R), (signs.shape, (B, R))
    bm_ = min(bm, B)
    Bp = ((B + bm_ - 1) // bm_) * bm_
    cp = jnp.pad(cols, ((0, Bp - B), (0, 0)))
    sp = jnp.pad(signs, ((0, Bp - B), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_kernel, R=R),
        grid=(Bp // bm_,),
        in_specs=[
            pl.BlockSpec((bm_, R), lambda i: (i, 0)),
            pl.BlockSpec((bm_, R), lambda i: (i, 0)),
            pl.BlockSpec((R, C), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm_,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Bp,), jnp.float32),
        interpret=interpret,
    )(cp, sp, plane.astype(jnp.float32))
    return out[:B]
