"""Pallas TPU kernel: SRP meta-hash — matmul + sign + bit-pack.

The compute hot-spot of ACE (paper §3.4: hashing dominates; lookups are
O(L)).  One kernel does:

    proj   = x @ W                  (MXU, accumulated over d tiles in VMEM)
    bits   = proj >= 0              (VPU)
    bucket = bits @ PACK            (MXU; PACK encodes the 2^k weights,
                                     zero columns mask the lane padding)

Grid: (B/bm, d/bk).  The accumulator (bm, P) lives in VMEM scratch across
the d-tile loop; sign+pack run once on the last d step, writing (bm, Lp)
int32 bucket ids.  All dims are padded by the ops wrapper so BlockSpecs are
exact; P = round_up(K·L, 128) keeps the MXU lane-aligned (paper uses
K·L = 750; we compute 768 and mask 18 lanes in PACK).

VMEM budget at defaults (bm=256, bk=512, P=768, f32):
  x 0.5 MB + W 1.5 MB + acc 0.75 MB + pack 0.4 MB + out 0.13 MB ≈ 3.3 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.srp import SrpConfig
from repro.kernels import runtime
from repro.kernels.runtime import resolve_interpret


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def make_pack_matrix(cfg: SrpConfig, lp: int) -> np.ndarray:
    """(P, Lp) f32: PACK[j*K + k, j] = 2^(K-1-k) for j < L, else 0."""
    K, L, P = cfg.num_bits, cfg.num_tables, cfg.padded_projections
    pack = np.zeros((P, lp), np.float32)
    for j in range(L):
        for k in range(K):
            pack[j * K + k, j] = float(1 << (K - 1 - k))
    return pack


def _kernel(x_ref, w_ref, pack_ref, out_ref, acc_ref, *, nk: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _finish():
        bits = (acc_ref[...] >= 0.0).astype(jnp.float32)
        bucket = jnp.dot(bits, pack_ref[...],
                         preferred_element_type=jnp.float32)
        out_ref[...] = bucket.astype(jnp.int32)


# (bm, bk) tile candidates for bm/bk="auto"; the FIRST entry is the
# documented default — it is what degraded autotune calls (tracing, no
# timable operands) fall back to without caching.
TILE_CANDIDATES = ((256, 512), (128, 512), (512, 512),
                   (256, 256), (256, 1024))


def srp_hash(x: jax.Array, w: jax.Array, cfg: SrpConfig,
             bm: int | str = 256, bk: int | str = 512,
             interpret: bool | None = None) -> jax.Array:
    """(B, d) @ (d, P) -> (B, L) int32 bucket ids in [0, 2^K).

    ``interpret=None`` resolves through the shared
    ``repro.kernels.runtime`` resolver (env var / backend probe), so TPU
    runs get the Mosaic lowering without flag-plumbing and benchmarks
    cannot silently time interpret mode.

    ``bm="auto"``/``bk="auto"`` pick the tile pair via
    :func:`repro.kernels.runtime.autotune` — timed once eagerly per
    ``(shape, backend)`` and cached.  Under tracing (operands are
    Tracers) timing is impossible, so the call uses the cached winner if
    one exists, else the default tiles WITHOUT caching — an interpret or
    traced call can never pin a tile choice for the real backend.
    """
    interpret = resolve_interpret(interpret)
    if bm == "auto" or bk == "auto":
        shape_key = (x.shape, w.shape, str(x.dtype))
        traced = isinstance(x, jax.core.Tracer) or isinstance(
            w, jax.core.Tracer)
        bench = None if traced else (
            lambda cand: _srp_hash_impl(x, w, cfg, cand[0], cand[1],
                                        interpret))
        bm, bk = runtime.autotune("srp_hash", shape_key, interpret,
                                  TILE_CANDIDATES, bench_fn=bench)
    return _srp_hash_impl(x, w, cfg, bm, bk, interpret)


@functools.partial(
    jax.jit, static_argnames=("cfg", "bm", "bk", "interpret"))
def _srp_hash_impl(x: jax.Array, w: jax.Array, cfg: SrpConfig,
                   bm: int, bk: int, interpret: bool) -> jax.Array:
    B, d = x.shape
    P = cfg.padded_projections
    assert w.shape == (d, P), (w.shape, (d, P))
    L = cfg.num_tables
    lp = _round_up(L, 128)

    bm_ = min(bm, _round_up(B, 8))
    bk_ = min(bk, _round_up(d, 128))
    Bp, dp = _round_up(B, bm_), _round_up(d, bk_)
    xp = jnp.pad(x, ((0, Bp - B), (0, dp - d)))
    wp = jnp.pad(w, ((0, dp - d), (0, 0)))
    pack = jnp.asarray(make_pack_matrix(cfg, lp))
    nb, nk = Bp // bm_, dp // bk_

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=(nb, nk),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, k: (i, k)),
            pl.BlockSpec((bk_, P), lambda i, k: (k, 0)),
            pl.BlockSpec((P, lp), lambda i, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm_, lp), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, lp), jnp.int32),
        scratch_shapes=[
            # (bm, P) f32 accumulator in VMEM, persistent across the k loop.
            pltpu.VMEM((bm_, P), jnp.float32)
        ],
        interpret=interpret,
    )(xp, wp, pack)
    return out[:B, :L]
