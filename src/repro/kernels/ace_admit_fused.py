"""Pallas TPU kernel: fused ACE guardrail admission — hash + score +
threshold + masked insert in ONE kernel launch and one HBM pass.

The serving guardrail (paper's query phase as an admission filter) used to
take three kernel launches and TWO hash matmuls per request batch:
``srp_hash`` for scoring, a lookup, then ``srp_hash`` again over the
admitted gather for the insert.  This kernel hashes once and keeps the
bucket ids in VMEM for both the score gather and the masked scatter-add:

    proj    = q @ W                    (MXU, accumulated over d tiles)
    buckets = pack(sign(proj))         (MXU)
    score   = mean_j counts[j, b_j]    (flattened row-offset gather)
    admit   = score >= threshold       (threshold: one prefetched scalar,
                                        −inf during warmup — see
                                        sketch.admit_threshold)
    counts[j, b_j] += admit ? 1 : 0    (masked insert, counts ALIASED in
                                        VMEM — updated in place)

    HBM reads : q (B·d·4) + W (d·P·4) + counts (L·2^K, resident)
    HBM writes: scores+mask (B·2·4) + bucket ids (B·L·4, for the Welford
                epilogue in ops.ace_admit) — counts never round-trip.

Scoring happens strictly against the PRE-insert counts (the gather
materialises before the scatter loop), matching the reference path that
scores the whole batch before inserting it.

Grid: (d/bk,) — the whole (padded) batch is one tile so the masked insert
runs after every row's score in a single program; guardrail admission
batches are request batches (B ≤ ~1k at paper scale), and the wrapper
enforces the ~14 MB VMEM budget on the non-interpret path (chunk the
batch if it trips — each chunk is an independent masked insert, so the
split is exact for counts/n).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.srp import SrpConfig
from repro.kernels.ace_score_fused import flat_table_gather
from repro.kernels.runtime import resolve_interpret
from repro.kernels.srp_hash import make_pack_matrix, _round_up


def _kernel(q_ref, w_ref, pack_ref, thresh_ref, counts_in_ref, *rest,
            nk: int, B: int, L: int, nbuckets: int, gated: bool):
    if gated:
        im_ref, counts_out_ref, sm_ref, buckets_ref, acc_ref = rest
    else:
        im_ref = None
        counts_out_ref, sm_ref, buckets_ref, acc_ref = rest
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        # Touch the alias so the in-place dataflow is explicit (ace_update
        # idiom): counts_out_ref IS counts_in_ref's buffer.
        counts_out_ref[0, 0] = counts_in_ref[0, 0]

    acc_ref[...] += jnp.dot(
        q_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _finish():
        Bp = acc_ref.shape[0]
        bits = (acc_ref[...] >= 0.0).astype(jnp.float32)
        buckets = jnp.dot(bits, pack_ref[...],
                          preferred_element_type=jnp.float32).astype(jnp.int32)
        buckets_ref[...] = buckets

        # Score from PRE-insert counts: the gather materialises before any
        # scatter below mutates the (aliased) counts buffer.
        gathered = flat_table_gather(counts_in_ref[...], buckets, L, nbuckets)
        scores = jnp.sum(gathered, axis=-1) * jnp.float32(1.0 / L)  # (Bp,)

        # Pad rows (>= B) hash garbage — never admit them.
        valid = jax.lax.broadcasted_iota(
            jnp.int32, (Bp, 1), 0).reshape(Bp) < B
        admit = jnp.logical_and(scores >= thresh_ref[0, 0], valid)
        if gated:
            # quarantine gate: rows the caller sanitized out (non-finite
            # features) must neither admit nor insert
            admit = jnp.logical_and(admit, im_ref[...][:, 0] > 0.0)
        admitf = jnp.where(admit, 1.0, 0.0).astype(jnp.float32)

        col = jax.lax.broadcasted_iota(jnp.int32, sm_ref.shape, 1)
        sm_ref[...] = jnp.where(
            col == 0, scores[:, None],
            jnp.where(col == 1, admitf[:, None], 0.0))

        # Masked insert: scalar RMW over the LIVE rows only (t < B·L).
        # Admission batches are small, so the scalar loop beats paying the
        # one-hot sweep; weight 0 rows are read-modify-written unchanged,
        # keeping the loop branch-free.
        def body(t, _):
            b = t // L
            j = t % L
            idx = buckets_ref[b, j]
            w_b = sm_ref[b, 1]
            c = counts_out_ref[j, pl.dslice(idx, 1)]
            counts_out_ref[j, pl.dslice(idx, 1)] = \
                c + w_b.astype(c.dtype)
            return 0

        jax.lax.fori_loop(0, B * L, body, 0)


@functools.partial(jax.jit, static_argnames=("cfg", "bk", "interpret"))
def ace_admit_fused(counts: jax.Array, q: jax.Array, w: jax.Array,
                    thresh: jax.Array, cfg: SrpConfig, bk: int = 512,
                    interpret: bool | None = None,
                    item_mask: jax.Array | None = None):
    """One-launch guardrail admission step.

    counts (L, 2^K), q (B, d), w (d, P), thresh () float32 (score-space,
    −inf admits everything) ->
        (new_counts (L, 2^K)  — counts + masked batch histogram (aliased),
         scores (B,) float32  — PRE-insert Ŝ(q, D),
         admit (B,) bool,
         buckets (B, L) int32 — the one hash, re-exported so the Welford
         epilogue never hashes again).

    ``item_mask`` (B,) bool, when given, gates admission per row: masked
    rows (the caller's quarantined non-finite inputs) neither admit nor
    insert, still in the one launch (a lane-broadcast operand + one AND).
    """
    interpret = resolve_interpret(interpret)
    B, d = q.shape
    P = cfg.padded_projections
    L, nbuckets = counts.shape
    assert w.shape == (d, P) and L == cfg.num_tables

    Bp = _round_up(B, 8)
    bk_ = min(bk, _round_up(d, 128))
    dp = _round_up(d, bk_)
    lp = _round_up(L, 128)
    # The whole batch is ONE tile (the masked insert must run after every
    # row's pre-insert score), so VMEM bounds B on the real TPU path:
    # q + w + pack + counts + acc + sm + buckets must fit ~16 MB.
    vmem = 4 * (Bp * bk_ + bk_ * P + P * lp + Bp * P
                + Bp * 128 + Bp * lp) \
        + L * nbuckets * jnp.dtype(counts.dtype).itemsize
    if not interpret and vmem > 14 * 1024 * 1024:
        raise ValueError(
            f"ace_admit_fused: B={B} needs ~{vmem >> 20} MB VMEM at "
            f"P={P}, K·L=({nbuckets.bit_length() - 1},{L}) — over the "
            "~14 MB budget; chunk the admission batch (each chunk is an "
            "independent masked insert, so splitting preserves counts/n "
            "exactly)")
    qp = jnp.pad(q, ((0, Bp - B), (0, dp - d)))
    wp = jnp.pad(w, ((0, dp - d), (0, 0)))
    pack = jnp.asarray(make_pack_matrix(cfg, lp))
    nk = dp // bk_
    thresh_arr = jnp.asarray(thresh, jnp.float32).reshape(1, 1)
    gated = item_mask is not None

    in_specs = [
        pl.BlockSpec((Bp, bk_), lambda k: (0, k)),
        pl.BlockSpec((bk_, P), lambda k: (k, 0)),
        pl.BlockSpec((P, lp), lambda k: (0, 0)),
        pl.BlockSpec(memory_space=pltpu.SMEM),
        pl.BlockSpec((L, nbuckets), lambda k: (0, 0)),
    ]
    operands = [qp, wp, pack, thresh_arr, counts]
    if gated:
        imp = jnp.pad(item_mask.astype(jnp.float32), (0, Bp - B))
        operands.append(jnp.broadcast_to(imp[:, None], (Bp, 128)))
        in_specs.append(pl.BlockSpec((Bp, 128), lambda k: (0, 0)))

    new_counts, sm, buckets = pl.pallas_call(
        functools.partial(_kernel, nk=nk, B=B, L=L, nbuckets=nbuckets,
                          gated=gated),
        grid=(nk,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((L, nbuckets), lambda k: (0, 0)),
            pl.BlockSpec((Bp, 128), lambda k: (0, 0)),
            pl.BlockSpec((Bp, lp), lambda k: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, nbuckets), counts.dtype),
            jax.ShapeDtypeStruct((Bp, 128), jnp.float32),
            jax.ShapeDtypeStruct((Bp, lp), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((Bp, P), jnp.float32)],
        input_output_aliases={4: 0},
        interpret=interpret,
    )(*operands)
    return (new_counts, sm[:B, 0], sm[:B, 1] > 0.0, buckets[:B, :L])
