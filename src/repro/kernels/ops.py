"""jit'd public wrappers over the Pallas kernels, with backend dispatch.

Interpret-vs-Mosaic is resolved per call through the shared
``repro.kernels.runtime`` resolver (REPRO_PALLAS_INTERPRET env var, else
backend probe: interpret everywhere but TPU) — there is no module-level
flag to forget, and every kernel entry point in this package goes through
the same default, so a benchmark can never silently time interpret mode
on one path and Mosaic on another.

Hash-family dispatch: ``hash_dispatch`` routes ``SrpConfig.hash_mode``
between the dense-MXU ``srp_hash`` kernel and the VPU-only ``srht_hash``
kernel (``"auto"`` applies the throughput-weighted break-even of
``repro.core.srht.choose_hash_mode``).  The fused score/admit entry
points honour the same knob: under ``"srht"`` the single hash runs as
the SRHT kernel and the rest of the fused program (gather / threshold /
masked insert) falls back to the shared jnp helpers — still exactly one
hash per batch; the all-in-one-launch Pallas fusions are dense-only
(their hash matmul is welded into the kernel body).

Also exposes the sketch-level convenience ops used by AceEstimator
(``use_kernels=True``) and the serving guardrail.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sketch as _sk
from repro.core.sketch import AceConfig, AceState
from repro.core.srp import SrpConfig, resolve_hash_mode
from repro.kernels import ace_admit_fused as _a
from repro.kernels import ace_fleet_score as _fl
from repro.kernels import ace_query as _q
from repro.kernels import ace_score_fused as _f
from repro.kernels import ace_update as _u
from repro.kernels import ace_window_combine as _wc
from repro.kernels import attr_estimate as _ae
from repro.kernels import srht_hash as _sh
from repro.kernels import srp_hash as _h


def srp_hash(x: jax.Array, w: jax.Array, cfg: SrpConfig) -> jax.Array:
    """(B, d) -> (B, L) bucket ids via the dense-matmul Pallas kernel."""
    return _h.srp_hash(x, w, cfg)


def srht_hash(x: jax.Array, cfg: SrpConfig) -> jax.Array:
    """(B, d) -> (B, L) bucket ids via the SRHT (Fast-JL) Pallas kernel."""
    return _sh.srht_hash(x, cfg)


def hash_dispatch(x: jax.Array, w: jax.Array, cfg: SrpConfig) -> jax.Array:
    """THE kernel-path hash: dense-MXU vs SRHT-VPU by ``cfg.hash_mode``.

    Mirrors ``repro.core.srp.hash_buckets``'s dispatch for the jnp paths;
    every kernel-path caller (fused score/admit, AceEstimator, stream
    benchmarks) hashes through here so the knob governs all of them.
    """
    if resolve_hash_mode(cfg) == "srht":
        return _sh.srht_hash(x, cfg)
    return _h.srp_hash(x, w, cfg)


def attr_estimate(plane: jax.Array, cols: jax.Array, signs: jax.Array,
                  interpret: bool | None = None) -> jax.Array:
    """Signed count-sketch point estimates via the Pallas gather+median
    kernel: one (R, C) attribution-level plane, (B, R) bucket columns
    and ±1 signs -> (B,) median-of-rows estimates.  The batch-query
    entry point of ``repro.attribution.estimate`` (the fixed-shape
    findHH beam uses the inline jnp gather — its 2W×R working set is
    too small to amortise a kernel launch)."""
    return _ae.attr_estimate(plane, cols, signs, interpret=interpret)


def ace_update(state: AceState, buckets: jax.Array,
               cfg: AceConfig) -> AceState:
    """Kernel-path insert (counts only; Welford stream via gathered counts).

    The count-array lowering is ``mode="auto"``: the vectorised one-hot
    histogram when B·L clears the scalar-loop break-even (and the bucket
    space fits the VPU sweep), the sequential scalar RMW loop otherwise —
    see ``repro.kernels.ace_update.choose_mode``.
    """
    if state.esc is not None:
        # Quantized planes must scatter through the exact saturating
        # path (a narrow in-kernel RMW add would wrap at the cap).
        return _sk.insert_buckets(state, buckets, cfg)
    new_counts = _u.ace_update(state.counts, buckets, mode="auto")
    gathered = _q.ace_query(new_counts, buckets)
    scores = jnp.mean(gathered, axis=-1)
    b = jnp.asarray(scores.shape[0], jnp.float32)
    n = state.n
    tot = n + b
    rates = scores / jnp.maximum(tot, 1.0)   # rate stream (see sketch.py)
    mean_b = jnp.mean(rates)
    m2_b = jnp.sum((rates - mean_b) ** 2)
    delta = mean_b - state.welford_mean
    safe = jnp.maximum(tot, 1.0)
    return AceState(
        counts=new_counts, n=tot,
        welford_mean=state.welford_mean + delta * b / safe,
        welford_m2=state.welford_m2 + m2_b + delta**2 * n * b / safe,
        qhist=state.qhist, attr=state.attr)


def _mask_weights(table_mask: jax.Array) -> jax.Array:
    """(… L) 0/1 health mask -> kernel ``table_weights``: mask baked with
    its own 1/num_healthy normaliser (the degraded-combine contract of
    ``ace_score_fused`` / ``ace_window_combine``)."""
    maskf = table_mask.astype(jnp.float32)
    return maskf / jnp.maximum(jnp.sum(maskf, axis=-1, keepdims=True), 1.0)


def ace_query(state: AceState, buckets: jax.Array,
              table_mask: jax.Array | None = None) -> jax.Array:
    """(B, L) bucket ids -> (B,) scores via the Pallas gather kernel."""
    if state.esc is not None:
        # Promoted buckets read through the escalation table (jnp path;
        # the narrow-plane gather alone would clip at the dtype cap).
        return _sk.lookup(state, buckets, table_mask=table_mask)
    gathered = _q.ace_query(state.counts, buckets)
    if table_mask is None:
        return jnp.mean(gathered, axis=-1)
    return _sk.masked_table_mean(gathered, table_mask)


def ace_score(state: AceState, q: jax.Array, w: jax.Array,
              cfg: AceConfig,
              table_mask: jax.Array | None = None) -> jax.Array:
    """Fused hash+lookup+mean scoring of raw query vectors.

    Dense mode: one all-in-one Pallas launch.  SRHT mode: the SRHT hash
    kernel + the gather kernel (two launches, still one hash).

    ``table_mask`` (L,) scores over healthy tables only: the dense
    kernel takes the mask as its weighted-combine operand (still one
    launch); the srht/esc paths thread it through the shared jnp
    helpers.
    """
    if resolve_hash_mode(cfg.srp) == "srht" or state.esc is not None:
        return ace_query(state, hash_dispatch(q, w, cfg.srp),
                         table_mask=table_mask)
    if table_mask is None:
        return _f.ace_score_fused(state.counts, q, w, cfg.srp)
    return _f.ace_score_fused(state.counts, q, w, cfg.srp,
                              table_weights=_mask_weights(table_mask))


def ace_fleet_score(fstate, q: jax.Array, tenant_ids: jax.Array,
                    w: jax.Array, cfg: AceConfig,
                    table_mask: jax.Array | None = None) -> jax.Array:
    """Fused multi-tenant scoring of raw query vectors: each item of the
    mixed batch scores against ITS OWN tenant's tables
    (``repro.fleet.FleetState``), one hash for the whole batch.

    Dense mode: one all-in-one Pallas launch (``ace_fleet_score`` — the
    tenant·L row-offset gather welded after the in-kernel hash).  SRHT
    mode: the SRHT hash kernel + the jnp fleet gather (two dispatches,
    still one hash) — the ``ace_admit`` SRHT precedent.
    """
    from repro.fleet import state as _fls
    if resolve_hash_mode(cfg.srp) == "srht" or table_mask is not None:
        # SRHT hash, or a degraded fleet (the masked per-tenant combine
        # lives in the shared jnp helper): one kernel hash, jnp gather.
        buckets = hash_dispatch(q, w, cfg.srp)
        return _fls.fleet_scores(fstate, tenant_ids, buckets,
                                 table_mask=table_mask)
    return _fl.ace_fleet_score(fstate.counts, q, tenant_ids, w, cfg.srp)


def _observe_maskf(scores: jax.Array, item_mask: jax.Array | None,
                   n: jax.Array, warmup_items: float) -> jax.Array:
    """Calibration mask for quantile-mode rate observation: every
    finite-scored item (item_mask is the guardrail's finite mask; None
    means the whole batch) — NOT just admitted ones, or the rejected
    tail would freeze out of the histogram and the Q_q threshold would
    self-reinforce — gated by the half-warmup cold-start floor
    (``n`` is the pre-insert count the rates were normalized by; see
    repro.quantile.sketch.calib_mask)."""
    from repro.quantile import sketch as qsk
    maskf = (jnp.ones(scores.shape, jnp.float32) if item_mask is None
             else item_mask.astype(jnp.float32))
    return qsk.calib_mask(maskf, n, warmup_items)


def ace_fleet_admit(fstate, q: jax.Array, tenant_ids: jax.Array,
                    w: jax.Array, cfg: AceConfig, *, alpha: float,
                    warmup_items: float,
                    table_mask: jax.Array | None = None,
                    item_mask: jax.Array | None = None,
                    threshold_mode: str = "mu_sigma",
                    quantile_q: float = 0.01):
    """Kernel-path multi-tenant admission: ONE hash, no host syncs.

    The fleet analogue of ``ace_admit``: the single hash runs through
    ``hash_dispatch`` (dense-MXU or SRHT-VPU per ``cfg.hash_mode``);
    scoring, per-tenant thresholds and the one-scatter mixed-batch
    insert delegate to the shared ``repro.fleet.state`` helpers — the
    same single-homed dataflow as the jnp path, so kernel-path and jnp
    admissions agree bitwise downstream of the bucket draw.  (The FLAT
    fleet keeps the composed form; the all-in-one Pallas admission
    exists for the fleet×WINDOW combination — see
    ``ace_fleet_window_admit`` — where the extra tail+live passes made
    the fusion worth the VMEM-resident ring.)  Returns
    (new_state, admit (B,)).
    """
    from repro.fleet import state as _fls
    buckets = hash_dispatch(q, w, cfg.srp)
    scores = _fls.fleet_scores(fstate, tenant_ids, buckets,
                               table_mask=table_mask)
    admit = scores >= _fls.admit_thresholds(
        fstate, alpha, warmup_items, table_mask=table_mask,
        threshold_mode=threshold_mode,
        q=quantile_q)[tenant_ids]
    if item_mask is not None:
        # quarantined rows neither admit nor insert
        admit = jnp.logical_and(admit, item_mask)
    new_state = _fls.insert_masked(fstate, tenant_ids, buckets, admit, cfg)
    if threshold_mode == "quantile":
        from repro.quantile import sketch as qsk
        rates = scores / jnp.maximum(fstate.n, 1.0)[tenant_ids]
        new_state = new_state._replace(qhist=qsk.observe_rates_fleet(
            new_state.qhist, rates, tenant_ids,
            _observe_maskf(scores, item_mask, fstate.n[tenant_ids],
                           warmup_items)))
    return new_state, admit


def ace_fleet_window_admit(state, q: jax.Array, tenant_ids: jax.Array,
                           w: jax.Array, cfg: AceConfig, *, gamma: float,
                           alpha: float, warmup_items: float,
                           rotate_every: int = 0,
                           table_mask: jax.Array | None = None,
                           item_mask: jax.Array | None = None,
                           threshold_mode: str = "mu_sigma",
                           quantile_q: float = 0.01):
    """Kernel-path fleet×window admission: ONE Pallas launch for the hot
    combination that used to cost a hash launch plus four jnp HBM passes.

    Dense mode runs ``ace_fleet_window_admit_fused`` (hash →
    tenant+epoch offset gathers → γ-combine → per-tenant μ−ασ threshold
    → masked live-epoch insert, ring aliased in VMEM); the per-tenant
    ssq/Welford/tick folds run as the shared jnp epilogue
    (``fleet.window._apply_insert_stats`` — the same single-homed code
    the jnp path uses) over the kernel's exported sums, then the
    presence-gated rotation clocks fire.  SRHT mode hashes with the
    SRHT kernel and delegates the rest to the jnp fleet-window helpers
    — still one hash.  Returns (new_state, admit (B,) bool).
    """
    from repro.fleet import window as fw
    from repro.kernels import ace_fleet_window_admit as _fwa
    from repro.window import ring
    # quantile mode still hands the kernel ONE score-space scalar per
    # tenant (thr_t) — the fused executable is byte-identical across
    # threshold modes; only this jnp prologue (and the histogram
    # observation below) differ between the cached programs
    thr_t = fw.window_admit_thresholds(state, gamma, alpha, warmup_items,
                                       table_mask=table_mask,
                                       threshold_mode=threshold_mode,
                                       q=quantile_q)

    def _observe(new_state, scores):
        # live-epoch rate observation, routed per tenant; MUST run
        # before the rotation clocks (rotation retires the epoch row)
        n_w = jax.vmap(lambda s: ring.combined_n(s, gamma))(
            ring.WindowedAceState(*state))
        rates = scores / jnp.maximum(n_w, 1.0)[tenant_ids]
        return fw.observe_current_fleet(
            new_state, rates, tenant_ids,
            _observe_maskf(scores, item_mask, n_w[tenant_ids],
                           warmup_items))
    if resolve_hash_mode(cfg.srp) == "srht" or table_mask is not None:
        # SRHT hash, or a degraded fleet: one kernel hash, the rest of
        # the admission through the shared jnp helpers.  The masked path
        # scores over healthy tables but the insert's ssq increment must
        # see the TRUE (unmasked) sums — so degraded mode pays a second
        # pair of gathers; acceptable off the healthy hot path (its
        # throughput is gated separately in benchmarks/resilience).
        buckets = hash_dispatch(q, w, cfg.srp)
        pre = fw.window_table_sums_fleet(state, tenant_ids, buckets)
        if table_mask is None:
            scores = ring.score_live(pre[0], pre[1], cfg.num_tables)
        else:
            scores = fw.window_fleet_scores(state, tenant_ids, buckets,
                                            table_mask=table_mask)
        admit = scores >= thr_t[tenant_ids]
        if item_mask is not None:
            admit = jnp.logical_and(admit, item_mask)
        new_state = fw.insert_current_fleet(
            state, tenant_ids, buckets, admit, cfg, gamma=gamma,
            pre_sums=pre)
        if threshold_mode == "quantile":
            new_state = _observe(new_state, scores)
        new_state = fw.maybe_rotate_fleet(new_state, rotate_every, gamma,
                                          tenant_ids=tenant_ids)
        return new_state, admit

    new_ring, _scores, admit, buckets, tail_sums, live_pre = \
        _fwa.ace_fleet_window_admit_fused(
            state.counts, state.tail, state.cursor, q, tenant_ids, w,
            thr_t, cfg.srp, item_mask=item_mask)

    # Stats epilogue over POST-insert live sums (O(B·L) gather from the
    # new ring — no second hash, no tail/live re-gather; the
    # ops.ace_admit Welford-epilogue precedent).
    T, E, L, nbuckets = state.counts.shape
    iota_j = jnp.arange(L, dtype=jnp.int32)[None, :]
    ring_rows = (tenant_ids[:, None] * (E * L)
                 + state.cursor[tenant_ids][:, None] * L + iota_j)
    live_post = jnp.sum(
        new_ring.reshape(T * E * L, nbuckets)[ring_rows, buckets]
        .astype(jnp.float32), axis=-1)
    new_state = fw._apply_insert_stats(
        state, new_ring, tenant_ids, admit, cfg, gamma,
        tail_sums, live_pre, live_post)
    if threshold_mode == "quantile":
        new_state = _observe(new_state, _scores)
    new_state = fw.maybe_rotate_fleet(new_state, rotate_every, gamma,
                                      tenant_ids=tenant_ids)
    return new_state, admit


def ace_window_score(wstate, buckets: jax.Array, gamma: float,
                     mode: str = "auto",
                     table_mask: jax.Array | None = None) -> jax.Array:
    """Windowed Ŝ(q): (B, L) bucket ids scored against a
    ``repro.window.WindowedAceState`` epoch ring via the fused
    ``ace_window_combine`` kernel (one launch; E-way weighted gather +
    combine).  ``mode="auto"`` picks the flat single-take lowering while
    E·L fits the gather budget, the per-epoch unroll beyond it
    (``ace_window_combine.choose_mode``).  Same canonical summation order
    as ``repro.window.score_windowed``; agreement is float-tolerance
    (the in-kernel L-reduction may reassociate — the ``ace_score_fused``
    contract).
    """
    from repro.window.ring import epoch_weights
    E = wstate.counts.shape[0]
    weights = epoch_weights(wstate.cursor, E, gamma)
    if table_mask is None:
        return _wc.ace_window_combine(wstate.counts, buckets, weights,
                                      mode=mode)
    return _wc.ace_window_combine(wstate.counts, buckets, weights,
                                  mode=mode,
                                  table_weights=_mask_weights(table_mask))


def ace_admit_windowed(wstate, q: jax.Array, w: jax.Array, cfg: AceConfig,
                       *, gamma: float, alpha: float, warmup_items: float,
                       rotate_every: int = 0,
                       table_mask: jax.Array | None = None,
                       item_mask: jax.Array | None = None,
                       threshold_mode: str = "mu_sigma",
                       quantile_q: float = 0.01):
    """Kernel-path windowed admission: ONE hash, no host syncs.

    The windowed analogue of ``ace_admit``: the single hash runs through
    ``hash_dispatch`` (dense-MXU or SRHT-VPU per ``cfg.hash_mode``);
    scoring, threshold and the live-epoch masked insert delegate to the
    shared ``repro.window`` tail+live helpers, with the scoring gathers
    passed straight into the insert's ssq increment (``pre_sums``) so
    the whole admission costs exactly the jnp windowed path's gather
    budget — NOT the E-way ``ace_window_combine`` launch, which reads
    all E epochs and would then force the insert to re-gather tail+live
    anyway (strictly more HBM traffic at the ring's own γ; that kernel
    is the arbitrary-γ QUERY entry, ``ace_window_score``).  The eager
    epoch clock ticks after the insert, same positions as every other
    windowed driver.  Returns (new_state, admit (B,) bool).
    """
    from repro.window import ring
    buckets = hash_dispatch(q, w, cfg.srp)
    tail_sums, live_sums = ring.window_table_sums(wstate, buckets)
    if table_mask is None:
        scores = ring.score_live(tail_sums, live_sums, cfg.num_tables)
    else:
        # degraded: masked gathers for the DECISION, unmasked sums for
        # the insert's ssq increment (which must see the true counts)
        mt, ml = ring.window_table_sums(wstate, buckets,
                                        table_mask=table_mask)
        scores = ring.score_live(mt, ml, cfg.num_tables,
                                 table_mask=table_mask)
    admit = scores >= ring.admit_threshold_windowed(
        wstate, gamma, alpha, warmup_items, table_mask=table_mask,
        threshold_mode=threshold_mode, q=quantile_q)
    if item_mask is not None:
        admit = jnp.logical_and(admit, item_mask)
    new_state = ring.insert_current(wstate, buckets, admit, cfg,
                                    gamma=gamma,
                                    pre_sums=(tail_sums, live_sums))
    if threshold_mode == "quantile":
        # observe BEFORE the clock ticks — rotation retires the live
        # epoch's histogram row along with its counts
        n_w = ring.combined_n(wstate, gamma)
        rates = scores / jnp.maximum(n_w, 1.0)
        new_state = ring.observe_current(
            new_state, rates,
            _observe_maskf(scores, item_mask, n_w, warmup_items))
    new_state = ring.maybe_rotate(new_state, rotate_every, gamma)
    return new_state, admit


def ace_admit(state: AceState, q: jax.Array, w: jax.Array, cfg: AceConfig,
              *, alpha: float, warmup_items: float,
              table_mask: jax.Array | None = None,
              item_mask: jax.Array | None = None,
              threshold_mode: str = "mu_sigma",
              quantile_q: float = 0.01):
    """Fused guardrail admission: ONE hash, no host syncs.

    The μ−ασ threshold is computed on-device from the state scalars
    (sketch.admit_threshold, −inf during warmup).  Dense mode runs the
    single fused kernel (hash + score + threshold + masked insert, counts
    aliased in VMEM); SRHT mode hashes with the SRHT kernel and runs the
    same score→threshold→masked-insert dataflow through the shared jnp
    helpers.  Both fold the Welford stream from the one set of bucket
    ids — no re-hash.  Returns (new_state, admit_mask (B,) bool).
    """
    # quantile mode still hands the fused kernel ONE score-space device
    # scalar — the kernel program is byte-identical across modes
    thresh = _sk.admit_threshold(state, alpha, warmup_items,
                                 table_mask=table_mask,
                                 threshold_mode=threshold_mode,
                                 q=quantile_q)

    def _observe(new_state, scores):
        from repro.quantile import sketch as qsk
        rates = scores / jnp.maximum(state.n, 1.0)
        return new_state._replace(qhist=qsk.observe_rates(
            new_state.qhist, rates,
            _observe_maskf(scores, item_mask, state.n, warmup_items)))

    if (resolve_hash_mode(cfg.srp) == "srht" or state.esc is not None
            or table_mask is not None):
        # SRHT hash kernel, a quantized plane (whose saturating scatter
        # + escalation reads live in the jnp helpers), or a degraded
        # sketch (masked combine): one kernel/jnp hash, then the shared
        # exact dataflow.
        buckets = hash_dispatch(q, w, cfg.srp)
        scores = _sk.lookup(state, buckets, table_mask=table_mask)
        admit = scores >= thresh
        if item_mask is not None:
            admit = jnp.logical_and(admit, item_mask)
        new_state = _sk.insert_buckets_masked(state, buckets, admit, cfg)
        if threshold_mode == "quantile":
            new_state = _observe(new_state, scores)
        return new_state, admit

    new_counts, _scores, admit, buckets = _a.ace_admit_fused(
        state.counts, q, w, thresh, cfg.srp, item_mask=item_mask)

    # Welford epilogue over POST-insert scores of the admitted items —
    # shared helpers with sketch.insert_buckets_masked (O(B·L) gather, no
    # second hash).
    post = _sk.batch_scores(new_counts, buckets)
    tot, new_mean, new_m2 = _sk.masked_batch_welford(
        state, post, admit.astype(jnp.float32), cfg.welford_min_n)
    new_state = AceState(counts=new_counts, n=tot,
                         welford_mean=new_mean, welford_m2=new_m2,
                         esc=state.esc, qhist=state.qhist,
                         attr=state.attr)
    if threshold_mode == "quantile":
        new_state = _observe(new_state, _scores)
    return new_state, admit
