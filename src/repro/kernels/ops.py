"""jit'd public wrappers over the Pallas kernels, with backend dispatch.

On this CPU container the kernels run under ``interpret=True`` (the kernel
body executes as traced JAX on CPU — bit-exact contract validation); on a
TPU runtime set ``repro.kernels.ops.INTERPRET = False`` (or the
REPRO_PALLAS_INTERPRET=0 env var) for the Mosaic lowering.

Also exposes the sketch-level convenience ops used by AceEstimator
(``use_kernels=True``) and the serving guardrail.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.sketch import AceConfig, AceState
from repro.core.srp import SrpConfig
from repro.kernels import ace_query as _q
from repro.kernels import ace_score_fused as _f
from repro.kernels import ace_update as _u
from repro.kernels import srp_hash as _h

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def srp_hash(x: jax.Array, w: jax.Array, cfg: SrpConfig) -> jax.Array:
    """(B, d) -> (B, L) bucket ids via the Pallas kernel."""
    return _h.srp_hash(x, w, cfg, interpret=INTERPRET)


def ace_update(state: AceState, buckets: jax.Array,
               cfg: AceConfig) -> AceState:
    """Kernel-path insert (counts only; Welford stream via gathered counts)."""
    new_counts = _u.ace_update(state.counts, buckets, interpret=INTERPRET)
    gathered = _q.ace_query(new_counts, buckets, interpret=INTERPRET)
    scores = jnp.mean(gathered, axis=-1)
    b = jnp.asarray(scores.shape[0], jnp.float32)
    n = state.n
    tot = n + b
    rates = scores / jnp.maximum(tot, 1.0)   # rate stream (see sketch.py)
    mean_b = jnp.mean(rates)
    m2_b = jnp.sum((rates - mean_b) ** 2)
    delta = mean_b - state.welford_mean
    safe = jnp.maximum(tot, 1.0)
    return AceState(
        counts=new_counts, n=tot,
        welford_mean=state.welford_mean + delta * b / safe,
        welford_m2=state.welford_m2 + m2_b + delta**2 * n * b / safe)


def ace_query(state: AceState, buckets: jax.Array) -> jax.Array:
    """(B, L) bucket ids -> (B,) scores via the Pallas gather kernel."""
    return jnp.mean(_q.ace_query(state.counts, buckets, interpret=INTERPRET),
                    axis=-1)


def ace_score(state: AceState, q: jax.Array, w: jax.Array,
              cfg: AceConfig) -> jax.Array:
    """Fused hash+lookup+mean scoring of raw query vectors."""
    return _f.ace_score_fused(state.counts, q, w, cfg.srp,
                              interpret=INTERPRET)
