"""jit'd public wrappers over the Pallas kernels, with backend dispatch.

On this CPU container the kernels run under ``interpret=True`` (the kernel
body executes as traced JAX on CPU — bit-exact contract validation); on a
TPU runtime set ``repro.kernels.ops.INTERPRET = False`` (or the
REPRO_PALLAS_INTERPRET=0 env var) for the Mosaic lowering.

Also exposes the sketch-level convenience ops used by AceEstimator
(``use_kernels=True``) and the serving guardrail.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import sketch as _sk
from repro.core.sketch import AceConfig, AceState
from repro.core.srp import SrpConfig
from repro.kernels import ace_admit_fused as _a
from repro.kernels import ace_query as _q
from repro.kernels import ace_score_fused as _f
from repro.kernels import ace_update as _u
from repro.kernels import srp_hash as _h

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def srp_hash(x: jax.Array, w: jax.Array, cfg: SrpConfig) -> jax.Array:
    """(B, d) -> (B, L) bucket ids via the Pallas kernel."""
    return _h.srp_hash(x, w, cfg, interpret=INTERPRET)


def ace_update(state: AceState, buckets: jax.Array,
               cfg: AceConfig) -> AceState:
    """Kernel-path insert (counts only; Welford stream via gathered counts).

    The count-array lowering is ``mode="auto"``: the vectorised one-hot
    histogram when B·L clears the scalar-loop break-even (and the bucket
    space fits the VPU sweep), the sequential scalar RMW loop otherwise —
    see ``repro.kernels.ace_update.choose_mode``.
    """
    new_counts = _u.ace_update(state.counts, buckets, interpret=INTERPRET,
                               mode="auto")
    gathered = _q.ace_query(new_counts, buckets, interpret=INTERPRET)
    scores = jnp.mean(gathered, axis=-1)
    b = jnp.asarray(scores.shape[0], jnp.float32)
    n = state.n
    tot = n + b
    rates = scores / jnp.maximum(tot, 1.0)   # rate stream (see sketch.py)
    mean_b = jnp.mean(rates)
    m2_b = jnp.sum((rates - mean_b) ** 2)
    delta = mean_b - state.welford_mean
    safe = jnp.maximum(tot, 1.0)
    return AceState(
        counts=new_counts, n=tot,
        welford_mean=state.welford_mean + delta * b / safe,
        welford_m2=state.welford_m2 + m2_b + delta**2 * n * b / safe)


def ace_query(state: AceState, buckets: jax.Array) -> jax.Array:
    """(B, L) bucket ids -> (B,) scores via the Pallas gather kernel."""
    return jnp.mean(_q.ace_query(state.counts, buckets, interpret=INTERPRET),
                    axis=-1)


def ace_score(state: AceState, q: jax.Array, w: jax.Array,
              cfg: AceConfig) -> jax.Array:
    """Fused hash+lookup+mean scoring of raw query vectors."""
    return _f.ace_score_fused(state.counts, q, w, cfg.srp,
                              interpret=INTERPRET)


def ace_admit(state: AceState, q: jax.Array, w: jax.Array, cfg: AceConfig,
              *, alpha: float, warmup_items: float):
    """Fused guardrail admission: ONE kernel launch, one hash matmul.

    The μ−ασ threshold is computed on-device from the state scalars
    (sketch.admit_threshold, −inf during warmup), the kernel hashes +
    scores + masked-inserts in a single HBM pass, and the Welford stream
    folds the admitted items from the kernel's re-exported bucket ids —
    no re-hash, no host sync.  Returns (new_state, admit_mask (B,) bool).
    """
    thresh = _sk.admit_threshold(state, alpha, warmup_items)
    new_counts, _scores, admit, buckets = _a.ace_admit_fused(
        state.counts, q, w, thresh, cfg.srp, interpret=INTERPRET)

    # Welford epilogue over POST-insert scores of the admitted items —
    # shared helpers with sketch.insert_buckets_masked (O(B·L) gather, no
    # second hash).
    post = _sk.batch_scores(new_counts, buckets)
    tot, new_mean, new_m2 = _sk.masked_batch_welford(
        state, post, admit.astype(jnp.float32), cfg.welford_min_n)
    new_state = AceState(counts=new_counts, n=tot,
                         welford_mean=new_mean, welford_m2=new_m2)
    return new_state, admit
