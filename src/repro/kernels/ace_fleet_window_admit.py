"""Pallas TPU kernel: fused fleet×window admission — hash + tenant/epoch
routed gathers + γ-combine + μ−ασ threshold + masked live-epoch insert in
ONE kernel launch.

The one hot combination the ROADMAP still listed as multi-pass: a
windowed FLEET admission (repro.fleet.window) used to cost a hash launch
plus four separate jnp HBM passes over the resident (T·E·L, 2^K) ring
(tail gather, live gather, scatter, post gather).  This kernel welds the
per-item dataflow of ``engine._admit_impl``'s fleet-window branch into
the ``ace_admit_fused`` template:

    proj      = q @ W                       (MXU, accumulated over d tiles)
    buckets   = pack(sign(proj))            (MXU)
    tail_sums = Σ_j tail[tid·L + j, b_j]          (f32 γ-weighted tails)
    live_pre  = Σ_j ring[tid·E·L + cur·L + j, b_j]  (live epoch)
    score     = (tail_sums + live_pre)·(1/L)  — the γ-combine at the
                ring's own decay (the tail IS the γ-weighted history;
                same literal combine as ring.score_live)
    admit     = score >= thr[tid]           (per-tenant μ−ασ score-space
                thresholds, routed in as a lane-broadcast block)
    ring[tid·E·L + cur·L + j, b_j] += admit (masked scatter, ring ALIASED
                                             in VMEM — updated in place)

Routing metadata rides in as lane-broadcast (B, 128) int32 blocks (the
``ace_fleet_score`` idiom): the tenant id and the precomputed live row
offset ``row0 = tid·E·L + cursor[tid]·L`` — cursor indirection costs one
host-free jnp gather in the wrapper, not a kernel loop.

    HBM reads : q + W + thresholds/ids (B·3·4) + ring and tails (resident)
    HBM writes: sm block (B·128·4: score/admit/tail/live columns) +
                bucket ids (B·L·4, re-exported for the stats epilogue in
                ops.ace_fleet_window_admit) — the ring never round-trips.

Scoring is strictly PRE-insert (gathers materialise before the scatter
loop).  The per-tenant ssq/Welford/tick folds stay OUTSIDE the kernel in
``fleet.window._apply_insert_stats`` — the same single-homed epilogue as
the jnp path, fed from the kernel's exported sums (the ``ops.ace_admit``
Welford-epilogue precedent).

Grid: (d/bk,) — the whole (padded) batch is one tile so the masked
insert runs after every row's score in one program.  VMEM bounds
T·E·L·2^K on the non-interpret path (~14 MB guard below): the serving
regime (K≈10–13, modest T·E) fits; past it, the jnp path is the right
tool — ``ops`` keeps both behind one entry point.  Narrow (int8/int16)
rings pass straight through: gathers upcast, the masked RMW adds in the
ring's own dtype (exact below saturation — the quantized-plane
contract; promotion is flat-sketch only, see repro.core.quantize).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.srp import SrpConfig
from repro.kernels import runtime
from repro.kernels.runtime import resolve_interpret
from repro.kernels.srp_hash import make_pack_matrix, _round_up


def _kernel(q_ref, w_ref, pack_ref, tid_ref, row0_ref, thr_ref,
            ring_in_ref, tail_ref, ring_out_ref, sm_ref, buckets_ref,
            acc_ref, *, nk: int, B: int, L: int, nbuckets: int):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        # Touch the alias so the in-place dataflow is explicit
        # (ace_admit_fused idiom): ring_out_ref IS ring_in_ref's buffer.
        ring_out_ref[0, 0] = ring_in_ref[0, 0]

    acc_ref[...] += jnp.dot(
        q_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _finish():
        Bp = acc_ref.shape[0]
        bits = (acc_ref[...] >= 0.0).astype(jnp.float32)
        buckets = jnp.dot(bits, pack_ref[...],
                          preferred_element_type=jnp.float32).astype(jnp.int32)
        buckets_ref[...] = buckets

        iota_j = jax.lax.broadcasted_iota(jnp.int32, (Bp, L), 1)
        tids = tid_ref[...][:, :L]                     # lane-broadcast
        row0 = row0_ref[...][:, :L]                    # tid·E·L + cur·L

        # γ-weighted tail sums (f32 tails — the decayed history view).
        offs_tail = buckets[:, :L] + (tids * L + iota_j) * nbuckets
        tail_flat = tail_ref[...].reshape(-1)
        tail_sums = jnp.sum(jnp.take(tail_flat, offs_tail, axis=0),
                            axis=-1)                               # (Bp,)

        # Live-epoch sums from PRE-insert counts: this gather
        # materialises before any scatter below mutates the (aliased)
        # ring buffer.
        offs_live = buckets[:, :L] + (row0 + iota_j) * nbuckets
        ring_flat = ring_in_ref[...].reshape(-1)
        live_pre = jnp.sum(
            jnp.take(ring_flat, offs_live, axis=0).astype(jnp.float32),
            axis=-1)                                               # (Bp,)

        # The canonical windowed combine: one add, ONE reciprocal 1/L
        # (ring.score_live's literal sequence).
        scores = (tail_sums + live_pre) * jnp.float32(1.0 / L)

        # Pad rows (>= B) hash garbage — never admit them.
        valid = jax.lax.broadcasted_iota(
            jnp.int32, (Bp, 1), 0).reshape(Bp) < B
        thr = thr_ref[...][:, 0]                       # per-item routed
        admit = jnp.logical_and(scores >= thr, valid)
        admitf = jnp.where(admit, 1.0, 0.0).astype(jnp.float32)

        col = jax.lax.broadcasted_iota(jnp.int32, sm_ref.shape, 1)
        sm_ref[...] = jnp.where(
            col == 0, scores[:, None],
            jnp.where(col == 1, admitf[:, None],
                      jnp.where(col == 2, tail_sums[:, None],
                                jnp.where(col == 3, live_pre[:, None],
                                          0.0))))

        # Masked insert: scalar RMW over the LIVE rows only (t < B·L),
        # each item scattering into its own tenant's live-epoch rows.
        def body(t, _):
            b = t // L
            j = t % L
            row = row0_ref[b, 0] + j
            idx = buckets_ref[b, j]
            w_b = sm_ref[b, 1]
            c = ring_out_ref[row, pl.dslice(idx, 1)]
            ring_out_ref[row, pl.dslice(idx, 1)] = \
                c + w_b.astype(c.dtype)
            return 0

        jax.lax.fori_loop(0, B * L, body, 0)


# d-tile candidates for bk="auto"; first entry is the no-bench fallback.
BK_CANDIDATES = (512, 256, 1024)


def ace_fleet_window_admit_fused(ring_counts: jax.Array, tail: jax.Array,
                                 cursor: jax.Array, q: jax.Array,
                                 tenant_ids: jax.Array, w: jax.Array,
                                 thresholds: jax.Array, cfg: SrpConfig,
                                 bk: int | str = 512,
                                 interpret: bool | None = None,
                                 item_mask: jax.Array | None = None):
    """One-launch fleet×window admission step (counts half).

    ring_counts (T, E, L, 2^K), tail (T, L, 2^K) f32, cursor (T,) int32,
    q (B, d), tenant_ids (B,) int32 in [0, T), w (d, P),
    thresholds (T,) float32 (per-tenant score-space, −inf admits all) ->
        (new_ring (T, E, L, 2^K) — masked live-epoch scatter (aliased),
         scores (B,) float32    — PRE-insert windowed γ-combine,
         admit (B,) bool,
         buckets (B, L) int32   — the one hash, re-exported,
         tail_sums (B,) float32, live_pre (B,) float32 — the scoring
         gathers, re-exported so the ssq/Welford epilogue
         (fleet.window._apply_insert_stats) never re-gathers the ring).

    ``bk="auto"`` autotunes the d-tile via ``runtime.autotune`` — same
    per-(shape, backend) cache and trace-time fallback as ``srp_hash``.
    Autotune timing mutates a SCRATCH copy of the ring, not the caller's
    buffer (the kernel aliases its ring input in place).

    ``item_mask`` (B,) bool gates admission per row at zero extra kernel
    cost: the threshold routing is already per-item, so quarantined rows
    simply ride in with a +inf threshold (never admit, never insert).
    """
    interpret = resolve_interpret(interpret)
    if bk == "auto":
        shape_key = (ring_counts.shape, q.shape, str(ring_counts.dtype))
        traced = isinstance(q, jax.core.Tracer) or isinstance(
            ring_counts, jax.core.Tracer)
        bench = None if traced else (
            lambda cand: _admit_fused_impl(
                # copy: the impl donates/aliases the ring buffer.
                jnp.array(ring_counts), tail, cursor, q, tenant_ids, w,
                thresholds, cfg, cand[0], interpret,
                item_mask=item_mask)[1])
        (bk,) = (runtime.autotune(
            "ace_fleet_window_admit", shape_key, interpret,
            [(c,) for c in BK_CANDIDATES], bench_fn=bench))
    return _admit_fused_impl(ring_counts, tail, cursor, q, tenant_ids,
                             w, thresholds, cfg, bk, interpret,
                             item_mask=item_mask)


@functools.partial(jax.jit, static_argnames=("cfg", "bk", "interpret"))
def _admit_fused_impl(ring_counts: jax.Array, tail: jax.Array,
                      cursor: jax.Array, q: jax.Array,
                      tenant_ids: jax.Array, w: jax.Array,
                      thresholds: jax.Array, cfg: SrpConfig,
                      bk: int, interpret: bool,
                      item_mask: jax.Array | None = None):
    B, d = q.shape
    P = cfg.padded_projections
    T, E, L, nbuckets = ring_counts.shape
    assert w.shape == (d, P) and L == cfg.num_tables
    assert tenant_ids.shape == (B,), (tenant_ids.shape, B)
    assert tail.shape == (T, L, nbuckets) and cursor.shape == (T,)
    from repro.fleet.state import check_flat_addressable
    check_flat_addressable(T * E * L, nbuckets, "ace_fleet_window_admit")

    Bp = _round_up(B, 8)
    bk_ = min(bk, _round_up(d, 128))
    dp = _round_up(d, bk_)
    lp = _round_up(L, 128)
    # The whole batch is ONE tile (the masked insert must run after every
    # row's pre-insert score), and the ring + tails are VMEM-resident:
    vmem = 4 * (Bp * bk_ + bk_ * P + P * lp + Bp * P
                + 4 * Bp * 128 + Bp * lp) \
        + T * E * L * nbuckets * jnp.dtype(ring_counts.dtype).itemsize \
        + T * L * nbuckets * 4
    if not interpret and vmem > 14 * 1024 * 1024:
        raise ValueError(
            f"ace_fleet_window_admit: T·E·L·2^K=({T},{E},{L},{nbuckets}) "
            f"at B={B} needs ~{vmem >> 20} MB VMEM — over the ~14 MB "
            "budget; use the jnp fleet-window path (ops falls back per "
            "hash_mode) or shrink the resident ring")
    qp = jnp.pad(q, ((0, Bp - B), (0, dp - d)))
    wp = jnp.pad(w, ((0, dp - d), (0, 0)))
    pack = jnp.asarray(make_pack_matrix(cfg, lp))
    nk = dp // bk_

    # Routing metadata as lane-broadcast blocks; pad rows route to
    # tenant 0 row-offset 0 with a +inf threshold (belt and braces: the
    # in-kernel valid guard already blocks pad admits).
    tidp = jnp.pad(tenant_ids.astype(jnp.int32), (0, Bp - B))
    row0 = (tenant_ids.astype(jnp.int32) * (E * L)
            + cursor[tenant_ids] * L)
    row0p = jnp.pad(row0, (0, Bp - B))
    thr_i = thresholds[tenant_ids].astype(jnp.float32)
    if item_mask is not None:
        # quarantine gate at zero kernel cost: a masked row's threshold
        # becomes +inf, so it can neither admit nor insert
        thr_i = jnp.where(item_mask, thr_i, jnp.inf)
    thr_b = jnp.pad(thr_i, (0, Bp - B), constant_values=jnp.inf)
    tid2d = jnp.broadcast_to(tidp[:, None], (Bp, 128))
    row02d = jnp.broadcast_to(row0p[:, None], (Bp, 128))
    thr2d = jnp.broadcast_to(thr_b[:, None], (Bp, 128))

    new_ring, sm, buckets = pl.pallas_call(
        functools.partial(_kernel, nk=nk, B=B, L=L, nbuckets=nbuckets),
        grid=(nk,),
        in_specs=[
            pl.BlockSpec((Bp, bk_), lambda k: (0, k)),
            pl.BlockSpec((bk_, P), lambda k: (k, 0)),
            pl.BlockSpec((P, lp), lambda k: (0, 0)),
            pl.BlockSpec((Bp, 128), lambda k: (0, 0)),
            pl.BlockSpec((Bp, 128), lambda k: (0, 0)),
            pl.BlockSpec((Bp, 128), lambda k: (0, 0)),
            pl.BlockSpec((T * E * L, nbuckets), lambda k: (0, 0)),
            pl.BlockSpec((T * L, nbuckets), lambda k: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((T * E * L, nbuckets), lambda k: (0, 0)),
            pl.BlockSpec((Bp, 128), lambda k: (0, 0)),
            pl.BlockSpec((Bp, lp), lambda k: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T * E * L, nbuckets), ring_counts.dtype),
            jax.ShapeDtypeStruct((Bp, 128), jnp.float32),
            jax.ShapeDtypeStruct((Bp, lp), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((Bp, P), jnp.float32)],
        input_output_aliases={6: 0},
        interpret=interpret,
    )(qp, wp, pack, tid2d, row02d, thr2d,
      ring_counts.reshape(T * E * L, nbuckets),
      tail.reshape(T * L, nbuckets))
    return (new_ring.reshape(T, E, L, nbuckets),
            sm[:B, 0], sm[:B, 1] > 0.0, buckets[:B, :L],
            sm[:B, 2], sm[:B, 3])
