"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors one kernel's contract exactly (same shapes/dtypes,
same bit-packing convention).  Tests sweep shapes/dtypes and
``assert_allclose`` kernel-vs-ref.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.srp import SrpConfig, hash_buckets


def srp_hash_ref(x: jax.Array, w: jax.Array, cfg: SrpConfig) -> jax.Array:
    """(B, d), (d, P) -> (B, L) int32 bucket ids."""
    return hash_buckets(x, w, cfg)


def ace_update_ref(counts: jax.Array, buckets: jax.Array) -> jax.Array:
    """counts (L, 2^K) += histogram of buckets (B, L)."""
    L = counts.shape[0]
    rows = jnp.broadcast_to(
        jnp.arange(L, dtype=jnp.int32)[None, :], buckets.shape)
    return counts.at[rows, buckets].add(1)


def ace_query_ref(counts: jax.Array, buckets: jax.Array) -> jax.Array:
    """gathered counts: (B, L) float32 with col j = counts[j, buckets[:, j]]."""
    L = counts.shape[0]
    rows = jnp.arange(L, dtype=jnp.int32)
    return counts[rows[None, :], buckets].astype(jnp.float32)


def ace_score_ref(counts: jax.Array, q: jax.Array, w: jax.Array,
                  cfg: SrpConfig,
                  table_weights: jax.Array | None = None) -> jax.Array:
    """Fused hash+lookup+mean: (B, d) queries -> (B,) scores.

    ``table_weights`` mirrors the kernel's degraded combine: the weighted
    sum Σ_j tw_j · gathered_j with NO 1/L (the caller bakes the
    health-mask normaliser into tw)."""
    buckets = hash_buckets(q, w, cfg)
    gathered = ace_query_ref(counts, buckets)
    if table_weights is None:
        return jnp.mean(gathered, axis=-1)
    return jnp.sum(gathered * table_weights[None, :], axis=-1)


def ace_window_combine_ref(counts: jax.Array, buckets: jax.Array,
                           weights: jax.Array,
                           table_weights: jax.Array | None = None
                           ) -> jax.Array:
    """Windowed scoring: counts (E, L, 2^K), buckets (B, L), weights (E,)
    -> (B,) scores.

    Mirrors ``ace_window_combine``'s canonical summation order (per-epoch
    table row-sum, weighted, accumulated over e in ring-index order, ONE
    final 1/L reciprocal multiply — the same sequence as
    ``repro.window.score_windowed``); kernel-vs-ref comparisons are
    float-tolerance like every score-emitting kernel (the in-kernel
    L-reduction may reassociate).  ``table_weights`` mirrors the kernel's
    degraded combine (per-table scaling, no 1/L).
    """
    E, L = counts.shape[0], counts.shape[1]
    acc = jnp.zeros(buckets.shape[:1], jnp.float32)
    for e in range(E):
        g = ace_query_ref(counts[e], buckets)
        if table_weights is not None:
            g = g * table_weights[None, :]
        acc = acc + weights[e] * jnp.sum(g, axis=-1)
    if table_weights is not None:
        return acc
    return acc * jnp.float32(1.0 / L)


def attr_estimate_ref(plane: jax.Array, cols: jax.Array,
                      signs: jax.Array) -> jax.Array:
    """Signed count-sketch point estimates: plane (R, C), cols (B, R)
    int32, signs (B, R) ±1 -> (B,) median_r(signs·plane[r, cols[:, r]]).

    Mirrors ``attr_estimate``'s median convention exactly (sort over the
    static R axis; odd R → middle order statistic, even R → midpoint of
    the two middles — the shared ``repro.attribution`` contract)."""
    R = plane.shape[0]
    g = plane[jnp.arange(R, dtype=jnp.int32)[None, :], cols] \
        .astype(jnp.float32) * signs
    srt = jnp.sort(g, axis=-1)
    mid = R // 2
    if R % 2:
        return srt[:, mid]
    return 0.5 * (srt[:, mid - 1] + srt[:, mid])


def ace_fleet_score_ref(counts: jax.Array, q: jax.Array,
                        tenant_ids: jax.Array, w: jax.Array,
                        cfg: SrpConfig) -> jax.Array:
    """Fused multi-tenant scoring: counts (T, L, 2^K), q (B, d),
    tenant_ids (B,) -> (B,) scores, each item vs its OWN tenant's tables.

    Mirrors ``ace_fleet_score``'s contract (the tenant·L row-offset
    gather + the canonical row-sum / reciprocal-1/L combine of
    ``repro.fleet.state.fleet_scores``)."""
    T, L = counts.shape[0], counts.shape[1]
    buckets = hash_buckets(q, w, cfg)
    rows = tenant_ids[:, None] * L + jnp.arange(L, dtype=jnp.int32)[None, :]
    flat = counts.reshape(T * L, counts.shape[2])
    gathered = flat[rows, buckets].astype(jnp.float32)
    return jnp.sum(gathered, axis=-1) * jnp.float32(1.0 / L)


def ace_fleet_window_admit_ref(ring_counts: jax.Array, tail: jax.Array,
                               cursor: jax.Array, q: jax.Array,
                               tenant_ids: jax.Array, w: jax.Array,
                               thresholds: jax.Array, cfg: SrpConfig):
    """Fused fleet×window admission: hash once, tenant/epoch-routed tail +
    live gathers, γ-combine score, per-tenant threshold, masked
    live-epoch scatter.

    Mirrors ``ace_fleet_window_admit_fused``'s contract — the composed
    flat-admit → window-combine → fleet-score reference, built from the
    same literal sequences as ``repro.fleet.window``'s helpers (tail
    gather at row tid·L + j, live gather at tid·E·L + cursor·L + j, one
    add + ONE reciprocal 1/L).  Returns (new_ring, scores, admit,
    buckets, tail_sums, live_pre)."""
    T, E, L, nbuckets = ring_counts.shape
    buckets = hash_buckets(q, w, cfg)
    iota_j = jnp.arange(L, dtype=jnp.int32)[None, :]
    tail_rows = tenant_ids[:, None] * L + iota_j
    tail_sums = jnp.sum(
        tail.reshape(T * L, nbuckets)[tail_rows, buckets], axis=-1)
    ring_rows = (tenant_ids[:, None] * (E * L)
                 + cursor[tenant_ids][:, None] * L + iota_j)
    flat = ring_counts.reshape(T * E * L, nbuckets)
    live_pre = jnp.sum(flat[ring_rows, buckets].astype(jnp.float32),
                       axis=-1)
    scores = (tail_sums + live_pre) * jnp.float32(1.0 / L)
    admit = scores >= thresholds[tenant_ids]
    w_ctr = jnp.broadcast_to(
        admit.astype(ring_counts.dtype)[:, None], buckets.shape)
    new_ring = flat.at[ring_rows, buckets].add(w_ctr) \
        .reshape(ring_counts.shape)
    return new_ring, scores, admit, buckets, tail_sums, live_pre


def ace_admit_ref(counts: jax.Array, q: jax.Array, w: jax.Array,
                  thresh: jax.Array, cfg: SrpConfig):
    """Fused admission: hash once, score pre-insert, threshold, masked add.

    Mirrors ``ace_admit_fused``: returns (new_counts, scores, admit,
    buckets)."""
    buckets = hash_buckets(q, w, cfg)
    gathered = ace_query_ref(counts, buckets)                      # (B, L)
    scores = jnp.sum(gathered, axis=-1) * jnp.float32(1.0 / cfg.num_tables)
    admit = scores >= thresh
    rows = jnp.broadcast_to(
        jnp.arange(cfg.num_tables, dtype=jnp.int32)[None, :], buckets.shape)
    w_ctr = jnp.broadcast_to(
        admit.astype(counts.dtype)[:, None], buckets.shape)
    new_counts = counts.at[rows, buckets].add(w_ctr)
    return new_counts, scores, admit, buckets
