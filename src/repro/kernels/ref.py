"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors one kernel's contract exactly (same shapes/dtypes,
same bit-packing convention).  Tests sweep shapes/dtypes and
``assert_allclose`` kernel-vs-ref.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.srp import SrpConfig, hash_buckets


def srp_hash_ref(x: jax.Array, w: jax.Array, cfg: SrpConfig) -> jax.Array:
    """(B, d), (d, P) -> (B, L) int32 bucket ids."""
    return hash_buckets(x, w, cfg)


def ace_update_ref(counts: jax.Array, buckets: jax.Array) -> jax.Array:
    """counts (L, 2^K) += histogram of buckets (B, L)."""
    L = counts.shape[0]
    rows = jnp.broadcast_to(
        jnp.arange(L, dtype=jnp.int32)[None, :], buckets.shape)
    return counts.at[rows, buckets].add(1)


def ace_query_ref(counts: jax.Array, buckets: jax.Array) -> jax.Array:
    """gathered counts: (B, L) float32 with col j = counts[j, buckets[:, j]]."""
    L = counts.shape[0]
    rows = jnp.arange(L, dtype=jnp.int32)
    return counts[rows[None, :], buckets].astype(jnp.float32)


def ace_score_ref(counts: jax.Array, q: jax.Array, w: jax.Array,
                  cfg: SrpConfig) -> jax.Array:
    """Fused hash+lookup+mean: (B, d) queries -> (B,) scores."""
    buckets = hash_buckets(q, w, cfg)
    return jnp.mean(ace_query_ref(counts, buckets), axis=-1)
