"""Pallas TPU kernel: SRHT meta-hash — FWHT butterflies + sign diagonals +
row gather + bit-pack, all in VMEM on the VPU.

The Fast-JL construction of paper §2.2: instead of the O(d·KL) dense
Gaussian matmul, compute

    y    = H·D2·H·D1·x        (two sign-diagonal + Walsh–Hadamard rounds)
    proj = y[rows]            (m = K·L sampled rows)
    b_j  = pack(sign(proj))   (K-bit big-endian pack per meta-hash)

in O(d log d + m) per item.  Everything runs on the VPU: each of the
log2(d) butterfly stages is one add/sub pass over the (bm, d_pad) tile
resident in VMEM, the row sample is a lane gather, and the pack is an
integer multiply-accumulate over the K axis — the MXU is left completely
free for the model the ingest pipeline feeds (the dense ``srp_hash``
kernel, by contrast, owns the MXU for both its matmuls).  At guardrail
scale (d_model 4096–12288) this is the difference between the hash being
the dominant FLOPs of every insert/score/admit and it disappearing into
the VPU's idle lanes.

The stage arithmetic reuses ``repro.core.srht.fwht`` verbatim, so the
kernel is bit-identical to the ``srht_bits`` reference under interpret
mode by construction (asserted in tests/test_stream.py), and the bucket
pack matches ``repro.core.srp.pack_buckets`` term for term.

Grid: (B/bm,) — one tile owns the whole transform for its rows; there is
no cross-tile reduction (unlike the dense kernel's d-tile loop) because
the FWHT needs all d lanes at once.  VMEM at defaults (bm=128, d_pad=8192,
m_pad=768): x 4 MB + butterfly temp ~4 MB + proj 0.4 MB ≈ 8.5 MB.

Lowering note: written for interpret mode (this container) and
lane-aligned shapes; on a real Mosaic lowering, d_pad < 128 tiles would
need lane padding — irrelevant in practice because ``hash_mode="auto"``
never routes small d to SRHT (the dense matmul wins below the crossover).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.srht import SrhtParams, fwht, srht_params
from repro.core.srp import SrpConfig
from repro.kernels.runtime import resolve_interpret
from repro.kernels.srp_hash import _round_up


def _kernel(x_ref, s1_ref, s2_ref, rows_ref, out_ref,
            *, K: int, L: int, m: int):
    # Two H·D rounds — same op order as core.srht.srht_bits, so every
    # float add/sub happens on identical values in identical order.
    y = fwht(x_ref[...] * s1_ref[...])          # (bm, d_pad)
    y = fwht(y * s2_ref[...])

    rows = rows_ref[0, :m]                      # (m,) int32, static slice
    proj = jnp.take(y, rows, axis=1)            # (bm, m) lane gather
    bits = (proj >= 0).astype(jnp.int32)

    # VPU bit-pack: (bm, L, K) · 2^(K-1-k) summed over k — integer MAC,
    # matching pack_buckets' big-endian convention exactly (no MXU pack
    # matmul like the dense kernels).
    grouped = bits.reshape(bits.shape[0], L, K)
    weights = jnp.left_shift(
        jnp.int32(1),
        K - 1 - jax.lax.broadcasted_iota(jnp.int32, (L, K), 1))
    buckets = jnp.sum(grouped * weights[None, :, :], axis=-1,
                      dtype=jnp.int32)          # (bm, L)
    lp = out_ref.shape[-1]
    out_ref[...] = jnp.pad(buckets, ((0, 0), (0, lp - L)))


@functools.partial(jax.jit, static_argnames=("cfg", "bm", "interpret"))
def srht_hash(x: jax.Array, cfg: SrpConfig, bm: int = 128,
              interpret: bool | None = None) -> jax.Array:
    """(B, d) -> (B, L) int32 bucket ids via the SRHT Pallas kernel.

    Parameters (sign diagonals + row sample) derive from ``cfg.seed``
    through the shared ``repro.core.srht.srht_params`` cache — the same
    draw the jnp reference uses, so kernel and reference implement ONE
    hash function.  No projection matrix ``w`` is consumed.
    """
    interpret = resolve_interpret(interpret)
    params: SrhtParams = srht_params(cfg)
    B, d = x.shape
    assert d == cfg.dim, (d, cfg.dim)
    d_pad = params.d_pad
    L, K, m = cfg.num_tables, cfg.num_bits, cfg.num_projections
    lp = _round_up(L, 128)
    m_pad = _round_up(m, 128)

    bm_ = min(bm, _round_up(B, 8))
    Bp = _round_up(B, bm_)
    xp = jnp.pad(x.astype(jnp.float32), ((0, Bp - B), (0, d_pad - d)))
    s1 = params.signs1[None, :]
    s2 = params.signs2[None, :]
    rows = jnp.pad(params.rows, (0, m_pad - m))[None, :]

    out = pl.pallas_call(
        functools.partial(_kernel, K=K, L=L, m=m),
        grid=(Bp // bm_,),
        in_specs=[
            pl.BlockSpec((bm_, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((1, d_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, d_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, m_pad), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm_, lp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, lp), jnp.int32),
        interpret=interpret,
    )(xp, s1, s2, rows)
    return out[:B, :L]
