"""Pallas TPU kernel: ACE query — gather counts[j, H_j(q)] per table.

Counts (L, 2^K) are VMEM-resident; queries stream in as (B, L) bucket ids;
output is the gathered (B, L) float32 count matrix (the ops wrapper takes the
mean over the live L columns — kept separate so diagnostics can see per-table
counts, e.g. for the variance analysis of Theorem 1).

Two lowering strategies, chosen by ``mode``:

* ``"vector"`` (default): per table j, a lane-gather ``jnp.take(row, ids)``
  — one vectorised gather per table, 50 total.  Lowers to Mosaic's dynamic
  gather on current toolchains; always correct under interpret mode.
* ``"scalar"``: fully scalar fori_loop RMW (guaranteed-lowerable baseline,
  mirrors ace_update's loop).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret


def _kernel_vector(buckets_ref, counts_ref, out_ref, *, L: int):
    for j in range(L):  # static unroll over tables
        row = counts_ref[j, :]
        ids = buckets_ref[:, j]
        out_ref[:, j] = jnp.take(row, ids, axis=0).astype(jnp.float32)


def _kernel_scalar(buckets_ref, counts_ref, out_ref, *, B: int, L: int):
    def body(t, _):
        b = t // L
        j = t % L
        idx = buckets_ref[b, j]
        c = counts_ref[j, pl.dslice(idx, 1)]
        out_ref[b, pl.dslice(j, 1)] = c.astype(jnp.float32)
        return 0

    jax.lax.fori_loop(0, B * L, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret", "mode", "bm"))
def ace_query(counts: jax.Array, buckets: jax.Array,
              interpret: bool | None = None, mode: str = "vector",
              bm: int = 1024) -> jax.Array:
    """counts (L, 2^K), buckets (B, L) -> gathered (B, L) float32."""
    interpret = resolve_interpret(interpret)
    L, nbuckets = counts.shape
    B = buckets.shape[0]
    assert buckets.shape == (B, L)
    bm_ = min(bm, B)
    Bp = ((B + bm_ - 1) // bm_) * bm_
    bp = jnp.pad(buckets, ((0, Bp - B), (0, 0)))

    if mode == "vector":
        kern = functools.partial(_kernel_vector, L=L)
    elif mode == "scalar":
        kern = functools.partial(_kernel_scalar, B=bm_, L=L)
    else:
        raise ValueError(f"unknown mode {mode!r}")

    out = pl.pallas_call(
        kern,
        grid=(Bp // bm_,),
        in_specs=[
            pl.BlockSpec((bm_, L), lambda i: (i, 0)),
            pl.BlockSpec((L, nbuckets), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm_, L), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, L), jnp.float32),
        interpret=interpret,
    )(bp, counts)
    return out[:B]
