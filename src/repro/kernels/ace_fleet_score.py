"""Pallas TPU kernel: fused multi-tenant ACE scoring — hash + tenant-routed
lookup + mean in one pass.

The fleet analogue of ``ace_score_fused``: a mixed-tenant batch is hashed
ONCE (the whole fleet shares one SRP bank — see ``repro.fleet.state``),
and each item's (B, L) bucket ids gather from ITS OWN tenant's tables by
extending the flattened row-offset gather with a tenant·L term:

    row(i, j) = tenant_ids[i]·L + j        into counts as (T·L, 2^K)

so the per-item cost is identical to the single-tenant kernel — the
tenant axis adds one integer multiply-add to the gather index, not a loop.

    HBM reads : q (B·d·4) + W (d·P·4, grid-reused) + tenant ids (B·4)
                + counts (T·L·2^K, resident)
    HBM writes: scores (B·4)

Grid: (B/bm, d/bk), (bm, P) accumulator in VMEM scratch; on the last
d-tile: sign -> pack-matmul -> tenant-offset flattened gather -> row
mean, written to a (bm, 128) output tile (column 0; the wrapper slices).

Tenant ids ride in as a (B, 128) int32 lane-broadcast block (each row
repeats its id across the lane so the (bm, 128) BlockSpec is natively
tileable; the kernel reads the first L lanes, which is all it needs).

VMEM: the single-tenant budget + the resident (T·L, 2^K) fleet — at the
paper's K=15, L=50, int32 this caps T at a handful of tenants per launch
on real VMEM; the serving regime (K≈13, L≈32) fits T≈64.  Beyond that
the jnp path (HBM-resident gather) is the right tool; ``ops.ace_fleet_score``
keeps both behind one entry point.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.srp import SrpConfig
from repro.kernels.runtime import resolve_interpret
from repro.kernels.srp_hash import make_pack_matrix, _round_up


def _kernel(q_ref, w_ref, pack_ref, tid_ref, counts_ref, out_ref, acc_ref,
            *, nk: int, L: int, nbuckets: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        q_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _finish():
        bits = (acc_ref[...] >= 0.0).astype(jnp.float32)
        buckets = jnp.dot(bits, pack_ref[...],
                          preferred_element_type=jnp.float32).astype(jnp.int32)
        # tenant·L row-offset extension of flat_table_gather: counts is
        # the (T·L, 2^K) flat fleet; item rows offset by tid·L
        tids = tid_ref[...][:, :L]                         # lane-broadcast
        rows = tids * L + jax.lax.broadcasted_iota(
            jnp.int32, (buckets.shape[0], L), 1)
        flat = counts_ref[...].reshape(-1)
        offs = buckets[:, :L] + rows * nbuckets
        gathered = jnp.take(flat, offs, axis=0).astype(jnp.float32)
        # reciprocal multiply, not `/ L` — same parity convention as
        # sketch.batch_scores / fleet.fleet_scores
        score = jnp.sum(gathered, axis=-1) * jnp.float32(1.0 / L)
        out_ref[...] = jnp.broadcast_to(score[:, None], out_ref.shape)


@functools.partial(jax.jit, static_argnames=("cfg", "bm", "bk", "interpret"))
def ace_fleet_score(counts: jax.Array, q: jax.Array,
                    tenant_ids: jax.Array, w: jax.Array,
                    cfg: SrpConfig, bm: int = 128, bk: int = 512,
                    interpret: bool | None = None) -> jax.Array:
    """counts (T, L, 2^K), q (B, d), tenant_ids (B,) int32 in [0, T),
    w (d, P) -> scores (B,) float32 — each item vs its own tenant."""
    interpret = resolve_interpret(interpret)
    B, d = q.shape
    P = cfg.padded_projections
    T, L, nbuckets = counts.shape
    assert w.shape == (d, P) and L == cfg.num_tables
    assert tenant_ids.shape == (B,), (tenant_ids.shape, B)
    from repro.fleet.state import check_flat_addressable
    check_flat_addressable(T * L, nbuckets, "ace_fleet_score")

    bm_ = min(bm, _round_up(B, 8))
    bk_ = min(bk, _round_up(d, 128))
    Bp, dp = _round_up(B, bm_), _round_up(d, bk_)
    qp = jnp.pad(q, ((0, Bp - B), (0, dp - d)))
    wp = jnp.pad(w, ((0, dp - d), (0, 0)))
    lp = _round_up(L, 128)
    pack = jnp.asarray(make_pack_matrix(cfg, lp))
    # lane-broadcast tenant ids; pad rows route to tenant 0 (their
    # garbage scores are sliced off below, the gather stays in-bounds)
    tidp = jnp.pad(tenant_ids.astype(jnp.int32), (0, Bp - B))
    tid2d = jnp.broadcast_to(tidp[:, None], (Bp, 128))
    nb, nk = Bp // bm_, dp // bk_

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, L=L, nbuckets=nbuckets),
        grid=(nb, nk),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, k: (i, k)),
            pl.BlockSpec((bk_, P), lambda i, k: (k, 0)),
            pl.BlockSpec((P, lp), lambda i, k: (0, 0)),
            pl.BlockSpec((bm_, 128), lambda i, k: (i, 0)),
            pl.BlockSpec((T * L, nbuckets), lambda i, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm_, 128), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, 128), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm_, P), jnp.float32)],
        interpret=interpret,
    )(qp, wp, pack, tid2d, counts.reshape(T * L, nbuckets))
    return out[:B, 0]
