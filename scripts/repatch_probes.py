import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))
"""Recompute the `corrected` probe block of existing dryrun_results/*.json
(after the probe-fidelity fix: chunked attention stays ON, statically
unrolled).  Usage: PYTHONPATH=src python scripts/repatch_probes.py [dir]"""

import json
import sys

import jax

from repro.launch.dryrun import probe_costs
from repro.dist.mesh import make_production_mesh, rules_for
from repro.models.common import set_rules
from repro.models.registry import Arch


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results"
    for name in sorted(os.listdir(d)):
        if not name.endswith(".json"):
            continue
        path = os.path.join(d, name)
        with open(path) as f:
            cell = json.load(f)
        if not cell.get("ok"):
            continue
        if cell.get("corrected", {}).get("probe_fixed"):
            print(f"[skip] {name}")
            continue
        mp = cell["mesh"] == "2x16x16"
        mesh = make_production_mesh(multi_pod=mp)
        long_ctx = cell["shape"] == "long_500k"
        rules = rules_for(mesh, long_context=long_ctx)
        set_rules(rules)
        arch = Arch(cell["arch"])
        n_sb = arch.cfg.num_layers // max(len(arch.cfg.block_pattern), 1)
        try:
            corr = probe_costs(cell["arch"], cell["shape"], mesh, rules,
                               long_ctx, n_sb)
            corr["probe_fixed"] = True
            cell["corrected"] = corr
            cell["probe_error"] = None
        except Exception as e:  # noqa: BLE001
            cell["probe_error"] = f"{type(e).__name__}: {e}"
            print(f"[probe FAIL] {name}: {cell['probe_error'][:120]}")
        with open(path, "w") as f:
            json.dump(cell, f, indent=1)
        print(f"[repatched] {name}", flush=True)


if __name__ == "__main__":
    main()
