"""CI perf-regression gate: diff fresh BENCH_*.json against baselines.

Benchmarks in this repo run inside shared CI containers whose timing
noise is brutal — the committed ``BENCH_fleet.json`` records rep
speedups spanning 18.7x..77.4x for the SAME code.  A naive
"fresh >= 0.9 * baseline" gate would flake weekly.  This gate is built
so that noise alone can never fail it:

1. **Gated metrics only.**  Only numeric leaves whose (dotted) name ends
   in ``items_per_s`` or whose leaf name starts with ``speedup`` /
   ``eff_bw`` are compared — all are higher-is-better throughput-shaped
   numbers.  Config echo (batch sizes, bit widths) and latency/ms leaves
   are ignored: configs are not regressions and the ms leaves are the
   reciprocals of gated ones.

2. **Best-of-reps fresh value.**  When several fresh files exist for one
   benchmark (CI can run the bench N times), each file contributes its
   value (median for list-valued leaves, the scalar otherwise) and the
   gate takes the BEST across files.  A regression must reproduce in
   every reflight to fail; one descheduled run cannot.

3. **Adaptive noise floor.**  The pass threshold for a metric is

       threshold = baseline * min(fail_ratio, spread * safety)

   where ``spread`` is the baseline's own observed rep spread
   (min_rep / median_rep over any ``rep_*`` list in that baseline file,
   e.g. 18.67/60.25 = 0.31 for the fleet bench).  A benchmark that
   demonstrably wobbles 3x in the container gets a 3x-wide gate; a
   stable one gets the tight ``fail_ratio`` gate.  ``safety`` (< 1)
   widens the observed spread a little: three committed reps
   under-sample the true noise distribution.

Failure conditions (exit 1):
  - a gated metric's best fresh value is below its threshold,
  - a gated metric present in the baseline is MISSING from the fresh
    run (a silently-dropped benchmark is the stealthiest regression),
  - a baseline benchmark has no fresh file at all.

A fresh benchmark with no baseline is a NOTE, not a failure — new
benches land before their baselines are blessed.  Exit 2 is reserved
for usage/IO errors (unreadable JSON, empty dirs).  ``--report`` writes
the full per-metric comparison as JSON for the CI artifact.

Usage:
    python scripts/bench_gate.py \
        --baseline-dir benchmarks/baselines --fresh-dir . \
        --report bench_gate_report.json
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

# Leaf-name patterns that make a numeric value a gated metric
# (higher-is-better by construction of every BENCH writer in this repo).
_GATED = re.compile(r"(^|\.)(items_per_s|speedup[^.]*|eff_bw[^.]*)$")
# rep_* lists feed the adaptive noise floor, never the gate directly.
_REP = re.compile(r"(^|\.)rep_[^.]*$")


def _flatten(node, prefix=""):
    """dict tree -> {dotted_path: leaf} for numeric / numeric-list leaves."""
    out = {}
    if isinstance(node, dict):
        for k, v in node.items():
            path = f"{prefix}.{k}" if prefix else k
            out.update(_flatten(v, path))
    elif isinstance(node, bool):
        pass
    elif isinstance(node, (int, float)):
        out[prefix] = float(node)
    elif isinstance(node, list) and node and all(
            isinstance(x, (int, float)) and not isinstance(x, bool)
            for x in node):
        out[prefix] = [float(x) for x in node]
    return out


def _median(xs):
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


def _value(leaf):
    """Gate value of a leaf: median for rep lists, the scalar otherwise."""
    return _median(leaf) if isinstance(leaf, list) else leaf


def _spread_ratio(leaves) -> float:
    """Observed baseline rep spread: min over rep_* lists of
    min/median (1.0 when no rep list exists — no evidence of noise)."""
    ratio = 1.0
    for path, leaf in leaves.items():
        if _REP.search(path) and isinstance(leaf, list):
            med = _median(leaf)
            if med > 0:
                ratio = min(ratio, min(leaf) / med)
    return ratio


def _bench_name(path: str) -> str:
    """BENCH_fleet.json / BENCH_fleet.rep2.json -> 'fleet'."""
    stem = os.path.basename(path)
    stem = re.sub(r"^BENCH_", "", stem)
    stem = re.sub(r"\.json$", "", stem)
    return stem.split(".")[0]


def _load_dir(dirname: str):
    """-> {bench_name: [flattened leaf dicts, one per file]}"""
    out: dict = {}
    for path in sorted(glob.glob(os.path.join(dirname, "BENCH_*.json"))):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise SystemExit(f"bench_gate: cannot read {path}: {e}")
        out.setdefault(_bench_name(path), []).append(_flatten(data))
    return out


def compare(baselines: dict, fresh: dict, *, fail_ratio: float,
            safety: float) -> dict:
    """Pure comparison (tests drive this directly): -> report dict with
    ``failures``, ``passes``, ``notes`` lists and an ``ok`` bool."""
    failures, passes, notes = [], [], []

    for bench, base_files in sorted(baselines.items()):
        fresh_files = fresh.get(bench)
        if not fresh_files:
            failures.append({
                "bench": bench, "metric": None,
                "reason": "baseline benchmark has no fresh BENCH file"})
            continue
        # Baseline value per metric: median across baseline files.
        base_metrics: dict = {}
        spread = 1.0
        for leaves in base_files:
            spread = min(spread, _spread_ratio(leaves))
            for path, leaf in leaves.items():
                if _GATED.search(path):
                    base_metrics.setdefault(path, []).append(_value(leaf))
        floor_ratio = min(fail_ratio, spread * safety)
        for path, vals in sorted(base_metrics.items()):
            base_v = _median(vals)
            fresh_vals = [_value(leaves[path]) for leaves in fresh_files
                          if path in leaves]
            if not fresh_vals:
                failures.append({
                    "bench": bench, "metric": path, "baseline": base_v,
                    "reason": "metric missing from fresh run"})
                continue
            best = max(fresh_vals)              # best-of-reps (see module
            threshold = base_v * floor_ratio    # docstring, items 2-3)
            entry = {
                "bench": bench, "metric": path, "baseline": base_v,
                "fresh_best": best, "threshold": threshold,
                "floor_ratio": floor_ratio, "baseline_spread": spread,
            }
            if best < threshold:
                entry["reason"] = (
                    f"best fresh {best:.4g} < threshold {threshold:.4g} "
                    f"({floor_ratio:.2f} x baseline {base_v:.4g})")
                failures.append(entry)
            else:
                passes.append(entry)

    for bench in sorted(set(fresh) - set(baselines)):
        notes.append({"bench": bench,
                      "reason": "new benchmark — no baseline yet"})

    return {"ok": not failures, "fail_ratio": fail_ratio,
            "safety": safety, "failures": failures, "passes": passes,
            "notes": notes}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Perf-regression gate over BENCH_*.json files.")
    ap.add_argument("--baseline-dir", required=True)
    ap.add_argument("--fresh-dir", required=True)
    ap.add_argument("--report", default=None,
                    help="write the full comparison JSON here")
    ap.add_argument("--fail-ratio", type=float, default=0.5,
                    help="max allowed fresh/baseline drop for stable "
                         "benches (default 0.5)")
    ap.add_argument("--safety", type=float, default=0.8,
                    help="multiplier widening the observed baseline rep "
                         "spread (default 0.8)")
    args = ap.parse_args(argv)

    baselines = _load_dir(args.baseline_dir)
    fresh = _load_dir(args.fresh_dir)
    if not baselines:
        print(f"bench_gate: no BENCH_*.json under {args.baseline_dir}",
              file=sys.stderr)
        return 2
    if not fresh:
        print(f"bench_gate: no BENCH_*.json under {args.fresh_dir}",
              file=sys.stderr)
        return 2

    report = compare(baselines, fresh, fail_ratio=args.fail_ratio,
                     safety=args.safety)
    if args.report:
        with open(args.report, "w") as f:
            json.dump(report, f, indent=2)

    for n in report["notes"]:
        print(f"NOTE  {n['bench']}: {n['reason']}")
    for p in report["passes"]:
        print(f"PASS  {p['bench']}.{p['metric']}: "
              f"{p['fresh_best']:.4g} vs baseline {p['baseline']:.4g} "
              f"(floor {p['floor_ratio']:.2f})")
    for fl in report["failures"]:
        metric = fl.get("metric") or "<bench>"
        print(f"FAIL  {fl['bench']}.{metric}: {fl['reason']}")
    print(f"bench_gate: {len(report['passes'])} pass, "
          f"{len(report['failures'])} fail, {len(report['notes'])} new")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
