"""Chaos-drill driver: inject the full fault menu against a live
guardrail + checkpoint stack and emit a machine-readable resilience
report (``RESILIENCE.json``) — the CI chaos lane's artifact.

The drill is the repro.resilience lifecycle end to end, in order:

1. serve a clean stream (baseline admit behaviour);
2. quarantine — NaN/Inf request rows must be sanitized, counted, and
   answered by the fail policy;
3. corrupt — bit-flip count tables, verify ``health_check`` localises
   exactly the flipped tables and degrades scoring to the healthy rest;
4. repair — re-zero the corrupted tables, re-warm them on live traffic,
   and confirm the guardrail returns to the healthy executable;
5. checkpoints — tear the newest checkpoint and confirm
   ``restore_latest`` falls back to the newest intact step.

Every stage appends pass/fail + evidence to the report; the script exits
non-zero if any stage fails, so the chaos lane is a gate, not a log.

Usage:
    PYTHONPATH=src python scripts/chaos_report.py [--json RESILIENCE.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import resilience as rz
from repro.serve.engine import Guardrail, GuardrailConfig
from repro.train import checkpoint as ck

D_MODEL, NUM_BITS, NUM_TABLES = 16, 6, 8
BATCH, SEQ, WARMUP = 32, 2, 64.0


def _embeds(rng, n=BATCH):
    return rng.normal(size=(n, SEQ, D_MODEL)).astype(np.float32)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="RESILIENCE.json")
    args = ap.parse_args()
    rng = np.random.default_rng(0)
    stages = []

    def stage(name, ok, **evidence):
        stages.append({"stage": name, "ok": bool(ok), **evidence})
        print(f"[{'ok' if ok else 'FAIL'}] {name}: {evidence}")

    g = Guardrail(GuardrailConfig(d_model=D_MODEL, num_bits=NUM_BITS,
                                  num_tables=NUM_TABLES,
                                  warmup_items=WARMUP))

    # 1. clean baseline — warm past warmup so thresholds are armed
    for _ in range(4):
        g.admit(jnp.asarray(_embeds(rng)))
    base_report = jax.device_get(rz.health_check(g.state))
    stage("baseline", bool(np.asarray(base_report.ok)),
          n=float(np.asarray(g.state.n)))

    # 2. quarantine: corrupted rows sanitized + counted, policy-answered
    e = _embeds(rng)
    bad = rng.random(BATCH) < 0.25
    e[bad] = np.inf
    before = g.quarantined
    verdict = g.admit(jnp.asarray(e))
    quarantined = g.quarantined - before
    clean_report = jax.device_get(rz.health_check(g.state))
    stage("quarantine",
          quarantined == int(bad.sum()) and bool(np.asarray(clean_report.ok))
          and bool(verdict[bad].all()),  # default policy is fail_open
          injected=int(bad.sum()), quarantined=quarantined)

    # 3. corrupt tables -> health_check localises them, guardrail degrades
    flip_tables = [1, NUM_TABLES - 2]
    counts = g.state.counts
    for t in flip_tables:
        counts = rz.flip_count_bits(counts, jax.random.PRNGKey(t),
                                    num_flips=3, tables=(t,))
    g.state = g.state._replace(counts=counts)
    report = g.health_check()
    table_ok = np.asarray(report.table_ok, bool)
    localised = set(np.nonzero(~table_ok)[0].tolist()) == set(flip_tables)
    still_serving = bool(
        g.admit(jnp.asarray(_embeds(rng))).shape == (BATCH,))
    stage("degrade", localised and g.degraded and still_serving,
          flipped=flip_tables,
          masked=np.nonzero(~table_ok)[0].tolist())

    # 4. repair + re-warm back to the healthy executable
    g.repair()
    repaired_ok = bool(np.asarray(
        jax.device_get(rz.health_check(g.state, g._repair_offsets)).ok))
    while g.degraded:
        g.admit(jnp.asarray(_embeds(rng)))
        g.health_check()
    stage("repair", repaired_ok and not g.degraded,
          rewarmed_n=float(np.asarray(g.state.n)))

    # 5. checkpoint tear -> CRC-verified fallback restore
    with tempfile.TemporaryDirectory() as d:
        tree = {"sketch": g.state, "w": g.w}
        ck.save(d, 100, tree, keep=5)
        for _ in range(2):
            g.admit(jnp.asarray(_embeds(rng)))
        ck.save(d, 200, {"sketch": g.state, "w": g.w}, keep=5)
        rz.tear_checkpoint(d, 200, mode="truncate")
        mgr = ck.CheckpointManager(d, keep=5)
        restored, manifest = mgr.restore_latest(tree)
        fell_back = manifest is not None and manifest["step"] == 100
        stage("checkpoint_fallback", bool(fell_back),
              intact_step=None if manifest is None else manifest["step"])

    ok = all(s["ok"] for s in stages)
    out = {"ok": ok, "stages": stages,
           "quarantined_total": int(g.quarantined)}
    with open(args.json, "w") as f:
        json.dump(out, f, indent=2)
    print(f"report -> {args.json} (ok={ok})")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
