"""Generate the §Dry-run and §Roofline markdown tables from
dryrun_results/*.json (EXPERIMENTS.md embeds the output).

    PYTHONPATH=src python scripts/gen_experiments_tables.py [dir]
"""
import json
import os
import sys


def human(x):
    if x is None:
        return "-"
    for unit, div in (("P", 1e15), ("T", 1e12), ("G", 1e9), ("M", 1e6)):
        if abs(x) >= div:
            return f"{x / div:.2f}{unit}"
    return f"{x:.0f}"


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results"
    cells = []
    for name in sorted(os.listdir(d)):
        if name.endswith(".json"):
            with open(os.path.join(d, name)) as f:
                cells.append(json.load(f))

    print("### §Dry-run: per-cell compile results\n")
    print("| arch | shape | mesh | ok | compile_s | HLO flops/dev "
          "(corrected) | HLO bytes/dev | collective B/dev | temp GB "
          "(CPU-measured) | policy |")
    print("|" + "---|" * 10)
    for c in cells:
        if not c["ok"]:
            print(f"| {c['arch']} | {c['shape']} | {c['mesh']} | FAIL | "
                  f"{c['seconds']} | - | - | - | - | - |")
            continue
        corr = c.get("corrected") or {}
        pol = c.get("policy") or {}
        ps = f"{pol.get('optimizer','-')}/mb{pol.get('microbatches','-')}"
        print(f"| {c['arch']} | {c['shape']} | {c['mesh']} | OK | "
              f"{c['seconds']} | {human(corr.get('flops', c['flops']))} | "
              f"{human(corr.get('bytes_accessed', c['bytes_accessed']))} | "
              f"{human((corr.get('collectives') or {}).get('total_bytes'))}"
              f" | {(c['memory']['temp'] or 0) / 2**30:.1f} | {ps} |")

    from repro.models.registry import LONG_CONTEXT_SKIP
    print("\nSkipped cells (long_500k, pure-full-attention rule):")
    for a, why in LONG_CONTEXT_SKIP.items():
        print(f"* `{a} × long_500k` — SKIP({why})")

    print("\n### §Roofline: three-term model (TPU v5e: 197 TF/s bf16, "
          "819 GB/s HBM, 50 GB/s/link ICI)\n")
    from repro.dist.roofline import build_all, format_table
    rows = build_all(d)
    print(format_table(rows))
    print("\nPer-cell dominant-term notes:")
    seen = set()
    for r in rows:
        key = (r.arch, r.shape)
        if r.mesh != "16x16" or key in seen:
            continue
        seen.add(key)
        print(f"* {r.arch} × {r.shape}: {r.dominant}-bound "
              f"(c={r.compute_s:.4f}s m={r.memory_s:.4f}s "
              f"n={r.collective_s:.4f}s) — {r.note}")


if __name__ == "__main__":
    main()
