"""Core ACE invariants: unbiasedness, the closed-form mean identity,
dynamic updates/deletes, merge associativity, threshold policy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (AceConfig, AceEstimator, exact_score, rse_score,
                        collision_probs)
from repro.core import sketch as sk
from repro.core.srp import (SrpConfig, collision_probability, hash_buckets,
                            make_projections, pack_buckets, srp_bits)

jax.config.update("jax_platform_name", "cpu")


def _data(n=400, d=12, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, d)), jnp.float32)


# ---------------------------------------------------------------------------
# SRP
# ---------------------------------------------------------------------------

class TestSrp:
    def test_collision_probability_matches_theory(self):
        """Empirical SRP collision rate ≈ 1 − θ/π (paper Eq. 1)."""
        d = 32
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
        y = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
        cfg = SrpConfig(dim=d, num_bits=1, num_tables=4096, seed=7)
        w = make_projections(cfg)
        bx = srp_bits(x[None], w, cfg)[0]
        by = srp_bits(y[None], w, cfg)[0]
        emp = float(jnp.mean((bx == by).astype(jnp.float32)))
        theory = float(collision_probability(x, y))
        assert abs(emp - theory) < 0.03

    def test_bucket_range(self):
        cfg = SrpConfig(dim=8, num_bits=6, num_tables=9, seed=1)
        w = make_projections(cfg)
        b = hash_buckets(_data(100, 8), w, cfg)
        assert b.shape == (100, 9)
        assert int(b.min()) >= 0 and int(b.max()) < 64

    def test_pack_is_bijective_on_bits(self):
        cfg = SrpConfig(dim=4, num_bits=3, num_tables=2, seed=0)
        bits = jnp.asarray(
            [[1, 0, 1, 0, 1, 1]], jnp.int32)  # tables: [101, 011]
        assert pack_buckets(bits, cfg).tolist() == [[5, 3]]

    def test_identical_points_always_collide(self):
        cfg = SrpConfig(dim=16, num_bits=15, num_tables=50, seed=3)
        w = make_projections(cfg)
        x = _data(5, 16)
        b1 = hash_buckets(x, w, cfg)
        b2 = hash_buckets(x, w, cfg)
        assert bool(jnp.all(b1 == b2))

    def test_scale_invariance(self):
        """SRP depends only on direction: h(cx) == h(x) for c > 0."""
        cfg = SrpConfig(dim=16, num_bits=10, num_tables=20, seed=3)
        w = make_projections(cfg)
        x = _data(50, 16)
        assert bool(jnp.all(hash_buckets(x, w, cfg) ==
                            hash_buckets(3.7 * x, w, cfg)))


# ---------------------------------------------------------------------------
# Sketch invariants
# ---------------------------------------------------------------------------

class TestSketch:
    CFG = AceConfig(dim=12, num_bits=8, num_tables=16, seed=11)

    def test_insert_counts_sum(self):
        """Each insert adds exactly L to the total count mass."""
        cfg = self.CFG
        st_ = sk.insert(sk.init(cfg), sk.make_params(cfg), _data(37), cfg)
        assert int(st_.counts.sum()) == 37 * cfg.num_tables
        assert float(st_.n) == 37

    def test_closed_form_mu_equals_sequential_eq11(self):
        """μ = Σ‖A‖²/(nL)  ≡  the paper's streaming Eq. 11."""
        cfg = self.CFG
        w = sk.make_params(cfg)
        x = _data(60)
        bks = hash_buckets(x, w, cfg.srp)
        st_ = sk.init(cfg)
        mu_seq = None
        for i in range(60):
            st_, mu_seq = sk.mu_sequential_increment(st_, bks[i], cfg)
        st_batch = sk.insert_buckets(sk.init(cfg), bks, cfg)
        assert np.isclose(float(mu_seq), float(sk.mean_mu(st_batch)),
                          rtol=1e-5)

    def test_mu_order_invariance(self):
        cfg = self.CFG
        w = sk.make_params(cfg)
        x = _data(64)
        s1 = sk.insert(sk.init(cfg), w, x, cfg)
        perm = np.random.default_rng(0).permutation(64)
        s2 = sk.insert(sk.init(cfg), w, x[perm], cfg)
        assert bool(jnp.all(s1.counts == s2.counts))
        assert np.isclose(float(sk.mean_mu(s1)), float(sk.mean_mu(s2)))

    def test_delete_inverts_insert(self):
        """Paper §3.4.1 / Eq. 12: delete is the exact inverse on counts+μ."""
        cfg = self.CFG
        w = sk.make_params(cfg)
        base, extra = _data(50, seed=1), _data(10, seed=2)
        s0 = sk.insert(sk.init(cfg), w, base, cfg)
        s1 = sk.insert(s0, w, extra, cfg)
        s2 = sk.delete(s1, w, extra, cfg)
        assert bool(jnp.all(s2.counts == s0.counts))
        assert float(s2.n) == float(s0.n)
        assert np.isclose(float(sk.mean_mu(s2)), float(sk.mean_mu(s0)))

    def test_merge_equals_bulk_insert(self):
        """CRDT merge: sketch(A) ⊕ sketch(B) == sketch(A ∪ B) on counts/μ."""
        cfg = self.CFG
        w = sk.make_params(cfg)
        a, b = _data(40, seed=3), _data(24, seed=4)
        sa = sk.insert(sk.init(cfg), w, a, cfg)
        sb = sk.insert(sk.init(cfg), w, b, cfg)
        sm = sk.merge(sa, sb)
        sfull = sk.insert(sk.insert(sk.init(cfg), w, a, cfg), w, b, cfg)
        assert bool(jnp.all(sm.counts == sfull.counts))
        assert float(sm.n) == float(sfull.n)
        assert np.isclose(float(sk.mean_mu(sm)), float(sk.mean_mu(sfull)))

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(1, 80), seed=st.integers(0, 10_000))
    def test_mu_closed_form_property(self, n, seed):
        """Hypothesis: closed-form μ equals mean of all items' scores."""
        cfg = AceConfig(dim=6, num_bits=6, num_tables=8, seed=seed % 17)
        w = sk.make_params(cfg)
        x = _data(n, 6, seed=seed)
        st_ = sk.insert(sk.init(cfg), w, x, cfg)
        scores = sk.score(st_, w, x, cfg)
        assert np.isclose(float(sk.mean_mu(st_)), float(scores.mean()),
                          rtol=1e-4)

    def test_welford_sigma_positive_and_finite(self):
        cfg = self.CFG
        est = AceEstimator(cfg).fit(_data(200))
        sig = float(sk.sigma_welford(est.state))
        assert np.isfinite(sig) and sig >= 0
        assert np.isfinite(float(sk.sigma_cubic_proxy(est.state)))


# ---------------------------------------------------------------------------
# Estimator statistics (Theorems 1 & 2)
# ---------------------------------------------------------------------------

class TestEstimators:
    def test_ace_unbiasedness(self):
        """Mean of Ŝ over independent hash seeds ≈ S(q, D)  (Theorem 1)."""
        d, n, K, L = 10, 300, 6, 16
        X = _data(n, d, seed=5)
        q = X[7]
        s_true = float(exact_score(q, X, K))
        ests = []
        for seed in range(24):
            cfg = AceConfig(dim=d, num_bits=K, num_tables=L, seed=seed)
            ests.append(float(AceEstimator(cfg).fit(X).score(q[None])[0]))
        se = np.std(ests) / np.sqrt(len(ests))
        assert abs(np.mean(ests) - s_true) < 4 * se + 0.05 * s_true

    def test_rse_unbiasedness(self):
        d, n, K, L = 10, 300, 6, 32
        X = _data(n, d, seed=6)
        q = X[3]
        s_true = float(exact_score(q, X, K))
        vals = [float(rse_score(q[None], X, K, L, jax.random.PRNGKey(s))[0])
                for s in range(64)]
        se = np.std(vals) / np.sqrt(len(vals))
        assert abs(np.mean(vals) - s_true) < 4 * se + 0.05 * s_true

    def test_ace_beats_rse_variance(self):
        """The paper's headline estimator claim (Fig. 3–5), on gaussian data."""
        d, n, K, L = 10, 400, 8, 16
        X = _data(n, d, seed=7)
        Q = X[:16]
        s_true = np.asarray(exact_score(Q, X, K))
        ace_err, rse_err = [], []
        for seed in range(12):
            cfg = AceConfig(dim=d, num_bits=K, num_tables=L, seed=seed)
            e = AceEstimator(cfg).fit(X)
            ace_err.append(np.mean((np.asarray(e.score(Q)) - s_true) ** 2))
            r = np.asarray(rse_score(Q, X, K, L, jax.random.PRNGKey(seed)))
            rse_err.append(np.mean((r - s_true) ** 2))
        assert np.mean(ace_err) < np.mean(rse_err)

    def test_outliers_score_lower(self):
        """Discriminative power (paper Fig. 1): outliers ≪ inliers ≈ μ.

        SRP is an ANGULAR hash, so anomalies must be angularly separated —
        inliers live in a cone around +μ, outliers around −μ (the paper's
        benchmark features are nonnegative, so offsets are angular there).
        """
        rng = np.random.default_rng(8)
        d = 16
        mu = 4.0 * np.ones(d) / np.sqrt(d)
        inliers = jnp.asarray(rng.normal(size=(1000, d)) + mu, jnp.float32)
        outliers = jnp.asarray(0.3 * rng.normal(size=(20, d)) - 3 * mu,
                               jnp.float32)
        cfg = AceConfig(dim=d, num_bits=12, num_tables=32, seed=0)
        est = AceEstimator(cfg).fit(inliers)
        s_in = float(est.score(inliers[:100]).mean())
        s_out = float(est.score(outliers).mean())
        assert s_out < 0.5 * s_in

    def test_predict_flags_planted_outliers(self):
        rng = np.random.default_rng(9)
        d = 16
        mu = 4.0 * np.ones(d) / np.sqrt(d)
        inl = jnp.asarray(rng.normal(size=(2000, d)) + mu, jnp.float32)
        out = jnp.asarray(0.3 * rng.normal(size=(30, d)) - 3 * mu, jnp.float32)
        cfg = AceConfig(dim=d, num_bits=13, num_tables=32, seed=1)
        est = AceEstimator(cfg).fit(inl)
        flags_out = np.asarray(est.predict(out, alpha=1.0))
        flags_in = np.asarray(est.predict(inl[:200], alpha=1.0))
        assert flags_out.mean() > 0.9          # nearly all outliers caught
        assert flags_in.mean() < 0.45          # inlier FP rate bounded

    def test_collision_probs_bounds(self):
        X = _data(50, 8, seed=10)
        p = np.asarray(collision_probs(X[0], X))
        assert (p >= 0).all() and (p <= 1).all()
        assert np.isclose(p[0], 1.0, atol=1e-5)  # self-similarity


# ---------------------------------------------------------------------------
# Privacy (§4)
# ---------------------------------------------------------------------------

class TestPrivacy:
    def test_private_hash_shape_and_determinism_given_key(self):
        from repro.core.privacy import private_hash_buckets, gaussian_sigma
        cfg = SrpConfig(dim=8, num_bits=6, num_tables=10, seed=0)
        w = make_projections(cfg)
        x = _data(20, 8)
        key = jax.random.PRNGKey(0)
        sig = gaussian_sigma(1.0, 1e-5, 1.0)
        b1 = private_hash_buckets(x, w, cfg, key, sig)
        b2 = private_hash_buckets(x, w, cfg, key, sig)
        assert b1.shape == (20, 10) and bool(jnp.all(b1 == b2))

    def test_noise_zero_matches_plain_srp(self):
        from repro.core.privacy import private_hash_buckets
        cfg = SrpConfig(dim=8, num_bits=6, num_tables=10, seed=0)
        w = make_projections(cfg)
        x = _data(20, 8)
        b = private_hash_buckets(x, w, cfg, jax.random.PRNGKey(0), 0.0)
        assert bool(jnp.all(b == hash_buckets(x, w, cfg)))

    def test_utility_degrades_gracefully(self):
        """Small noise: most buckets unchanged; huge noise: mostly changed."""
        from repro.core.privacy import private_srp_bits
        cfg = SrpConfig(dim=32, num_bits=8, num_tables=16, seed=0)
        w = make_projections(cfg)
        x = _data(100, 32)
        plain = srp_bits(x, w, cfg)
        lo = private_srp_bits(x, w, cfg, jax.random.PRNGKey(1), 0.01)
        hi = private_srp_bits(x, w, cfg, jax.random.PRNGKey(1), 1e3)
        agree_lo = float(jnp.mean((plain == lo).astype(jnp.float32)))
        agree_hi = float(jnp.mean((plain == hi).astype(jnp.float32)))
        assert agree_lo > 0.95
        assert 0.4 < agree_hi < 0.6


# ---------------------------------------------------------------------------
# SRHT fast path
# ---------------------------------------------------------------------------

class TestSrht:
    def test_fwht_orthogonality(self):
        from repro.core.srht import fwht
        x = _data(4, 64, seed=11)
        y = fwht(fwht(x)) / 64.0   # H H^T = n I
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-4)

    def test_srht_collision_rate_tracks_similarity(self):
        """SRHT bits behave like SRP: collision rate ≈ 1 − θ/π."""
        from repro.core.srht import SrhtParams, srht_bits
        d = 64
        rng = np.random.default_rng(12)
        x = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
        eps = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
        y = x + 0.3 * eps
        cfg = SrpConfig(dim=d, num_bits=1, num_tables=4096, seed=13)
        params = SrhtParams(cfg)
        bx = srht_bits(x[None], params)[0]
        by = srht_bits(y[None], params)[0]
        emp = float(jnp.mean((bx == by).astype(jnp.float32)))
        theory = float(collision_probability(x, y))
        assert abs(emp - theory) < 0.06

    def test_srht_flops_beat_dense_for_high_d(self):
        from repro.core.srht import flops_dense, flops_srht
        cfg = SrpConfig(dim=4096, num_bits=15, num_tables=50)
        assert flops_srht(cfg, 1) < flops_dense(cfg, 1) / 5
