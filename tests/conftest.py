"""Test-suite bootstrap.

Ensures ``src`` is importable when pytest is launched without PYTHONPATH
(the pyproject dev install makes this redundant), and gates the
``hypothesis`` dependency: when the real package is absent (hermetic
containers where installs are forbidden), a minimal shim is registered in
``sys.modules`` implementing the tiny surface the suite uses — ``@given``
with keyword strategies, ``@settings(max_examples=, deadline=)`` and
``st.integers(lo, hi)`` — running each property against deterministic
pseudorandom draws.  Install the ``dev`` extra (``pip install -e .[dev]``)
to property-test with the real engine; CI does.

Also home of :func:`assert_allclose_dtype`, the suite's ONE float
comparison helper: tolerance is chosen by the operands' dtype instead of
per-call-site magic numbers, so "how close is close enough for fp32"
is answered once (tests import it with ``from conftest import
assert_allclose_dtype`` — pytest puts this directory on sys.path).
"""
from __future__ import annotations

import os
import sys

import numpy as np

# Per-dtype relative tolerances: ~2 decimal digits of headroom over the
# dtype's epsilon, matching the tightest bounds the suite historically
# asserted ad hoc (fp32 comparisons were a mix of 1e-5..1e-7; bf16 sign
# tests used percentage agreement instead and still do).
_DTYPE_RTOL = {
    np.dtype(np.float64): 1e-12,
    np.dtype(np.float32): 1e-5,
    np.dtype(np.float16): 5e-3,
}


def assert_allclose_dtype(actual, desired, rtol=None, atol=0.0,
                          err_msg=""):
    """np.testing.assert_allclose with dtype-derived default tolerance.

    The rtol defaults to the loosest tolerance among the two operands'
    float dtypes (int operands compare exactly via rtol=0 unless the
    other side is float).  Pass ``rtol``/``atol`` explicitly only when a
    computation is genuinely less stable than its dtype (say so in the
    test).  jax arrays, numpy arrays and python scalars all accepted.
    """
    a = np.asarray(actual)
    d = np.asarray(desired)
    if rtol is None:
        cands = [_DTYPE_RTOL[x.dtype] for x in (a, d)
                 if x.dtype in _DTYPE_RTOL]
        # bfloat16 (not a numpy dtype) arrives as its ml_dtypes alias —
        # fall back to its epsilon-scale tolerance by name
        for x in (a, d):
            if "bfloat16" in str(x.dtype):
                cands.append(2e-2)
        rtol = max(cands) if cands else 0.0
    np.testing.assert_allclose(a.astype(np.float64, copy=False)
                               if "bfloat16" in str(a.dtype) else a,
                               d.astype(np.float64, copy=False)
                               if "bfloat16" in str(d.dtype) else d,
                               rtol=rtol, atol=atol, err_msg=err_msg)

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401
except ImportError:
    import random
    import types

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    _DEFAULT_EXAMPLES = 10

    def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            # NOT functools.wraps: that exposes fn's signature via
            # __wrapped__ and pytest would resolve the strategy params as
            # fixtures ("fixture 'n' not found")
            def wrapper(*args, **kwargs):
                # @settings above @given sets the attribute on THIS
                # wrapper; below @given it lands on the inner fn
                n = getattr(wrapper, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples",
                                    _DEFAULT_EXAMPLES))
                rng = random.Random(0)
                for i in range(n):
                    draws = {k: s.draw(rng) for k, s in strategies.items()}
                    try:
                        fn(*args, **draws, **kwargs)
                    except Exception:
                        print(f"[hypothesis-shim] falsifying example "
                              f"#{i}: {draws}", file=sys.stderr)
                        raise
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.__doc__ = ("Minimal fallback for the real `hypothesis` package "
                   "(see tests/conftest.py). Install repro[dev] for the "
                   "real engine.")
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
