"""Test-suite bootstrap.

Ensures ``src`` is importable when pytest is launched without PYTHONPATH
(the pyproject dev install makes this redundant), and gates the
``hypothesis`` dependency: when the real package is absent (hermetic
containers where installs are forbidden), a minimal shim is registered in
``sys.modules`` implementing the tiny surface the suite uses — ``@given``
with keyword strategies, ``@settings(max_examples=, deadline=)`` and
``st.integers(lo, hi)`` — running each property against deterministic
pseudorandom draws.  Install the ``dev`` extra (``pip install -e .[dev]``)
to property-test with the real engine; CI does.
"""
from __future__ import annotations

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401
except ImportError:
    import random
    import types

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    _DEFAULT_EXAMPLES = 10

    def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            # NOT functools.wraps: that exposes fn's signature via
            # __wrapped__ and pytest would resolve the strategy params as
            # fixtures ("fixture 'n' not found")
            def wrapper(*args, **kwargs):
                # @settings above @given sets the attribute on THIS
                # wrapper; below @given it lands on the inner fn
                n = getattr(wrapper, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples",
                                    _DEFAULT_EXAMPLES))
                rng = random.Random(0)
                for i in range(n):
                    draws = {k: s.draw(rng) for k, s in strategies.items()}
                    try:
                        fn(*args, **draws, **kwargs)
                    except Exception:
                        print(f"[hypothesis-shim] falsifying example "
                              f"#{i}: {draws}", file=sys.stderr)
                        raise
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.__doc__ = ("Minimal fallback for the real `hypothesis` package "
                   "(see tests/conftest.py). Install repro[dev] for the "
                   "real engine.")
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
