"""Streaming-ingest overhaul tests: scan-fused StreamRunner equivalence,
SRHT Pallas kernel parity, hash_mode dispatch, the filter's hash-once and
Welford-delegation contracts, and the serve decode loop's single-transfer
contract."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_allclose_dtype
from repro.core import sketch as sk
from repro.core import srp
from repro.core.srp import SrpConfig, hash_buckets, resolve_hash_mode
from repro.core.srht import (choose_hash_mode, effective_cost_dense,
                             effective_cost_srht, srht_hash_buckets,
                             srht_params)
from repro.data.pipeline import AceDataFilter
from repro.kernels import runtime
from repro.kernels.srht_hash import srht_hash
from repro.stream import StreamRunner

jax.config.update("jax_platform_name", "cpu")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _embeds(rng, B=8, S=4, D=16, scale=0.3, mu=2.0):
    return jnp.asarray(rng.normal(size=(B, S, D)) * scale + mu, jnp.float32)


# ---------------------------------------------------------------------------
# StreamRunner: chunk-of-T ≡ T sequential per-batch filter calls.
# ---------------------------------------------------------------------------

class TestStreamRunner:
    def _filter(self):
        return AceDataFilter(d_model=16, warmup_items=64.0, alpha=3.0)

    def test_chunk_equals_sequential_filter_calls(self):
        """One scan chunk must reproduce T per-batch AceDataFilter calls
        bitwise on counts/n (and to fp tolerance on the Welford stream),
        masks included — mixing warmup and armed steps."""
        filt = self._filter()
        rng = np.random.default_rng(0)
        T = 12
        embeds = [_embeds(rng) for _ in range(T)]
        embeds[-1] = _embeds(rng, mu=-6.0)         # a batch the armed
        embeds[-2] = _embeds(rng, mu=-6.0)         # filter should reject

        s_seq, w = filt.init()
        masks_seq, fracs = [], []
        for e in embeds:
            m = jnp.ones((e.shape[0], e.shape[1]), jnp.float32)
            s_seq, new_mask, frac = filt(s_seq, w, e, m)
            masks_seq.append(new_mask)
            fracs.append(float(frac))

        runner = StreamRunner(filt, chunk_T=T, return_masks=True)
        s_run, w2 = runner.init()
        feats = jnp.stack([filt.features(e) for e in embeds])
        s_run, summary, keeps = runner.consume(s_run, w2, feats)

        assert bool(jnp.all(s_run.counts == s_seq.counts))
        assert float(s_run.n) == float(s_seq.n)
        assert_allclose_dtype(s_run.welford_mean, s_seq.welford_mean)
        assert_allclose_dtype(s_run.welford_m2, s_seq.welford_m2)
        for t in range(T):
            want = masks_seq[t][:, 0] > 0
            assert bool(jnp.all(keeps[t] == want)), f"mask mismatch at {t}"
        assert_allclose_dtype(summary.kept_frac,
                              np.float32(np.mean(fracs)))
        # the rejected batches show up in the per-step anomaly counts
        assert int(summary.anom_counts[-1]) == 8
        assert int(summary.anom_counts[0]) == 0

    def test_one_executable_across_chunks_with_donation(self):
        filt = self._filter()
        runner = StreamRunner(filt, chunk_T=4)
        state, w = runner.init()
        rng = np.random.default_rng(1)
        for _ in range(3):
            feats = jnp.stack([filt.features(_embeds(rng))
                               for _ in range(4)])
            state, _summary = runner.consume(state, w, feats)
        assert runner.trace_count == 1
        assert float(state.n) > 0

    def test_topk_points_at_most_anomalous_items(self):
        """The on-device top-k must name the poisoned coordinates."""
        filt = self._filter()
        runner = StreamRunner(filt, chunk_T=4, topk=2)
        state, w = runner.init()
        rng = np.random.default_rng(2)
        # warmup chunk (filter arms at 64 items; 4*8=32 per chunk)
        for _ in range(2):
            feats = jnp.stack([filt.features(_embeds(rng))
                               for _ in range(4)])
            state, summary = runner.consume(state, w, feats)
        # poisoned chunk: step 2 rows are far out of cone
        embeds = [_embeds(rng) for _ in range(4)]
        embeds[2] = _embeds(rng, mu=-6.0)
        feats = jnp.stack([filt.features(e) for e in embeds])
        state, summary = runner.consume(state, w, feats)
        s = jax.device_get(summary)
        assert (s.topk_step == 2).all(), s
        assert (np.diff(s.topk_margin) >= 0).all()   # most anomalous first
        assert runner.trace_count == 1

    def test_quarantine_never_displaces_genuine_anomalies(self):
        """S3 regression: quarantined non-finite rows ride the transfer
        with margin = −inf, which is also the most-anomalous extreme of
        the top-k ordering — a dirty batch must NOT mask a genuine
        burst.  The ranking maps −inf to +inf so junk sorts last."""
        filt = self._filter()
        runner = StreamRunner(filt, chunk_T=4, topk=4)
        state, w = runner.init()
        rng = np.random.default_rng(3)
        for _ in range(2):                    # arm the filter (64 items)
            feats = jnp.stack([filt.features(_embeds(rng))
                               for _ in range(4)])
            state, summary = runner.consume(state, w, feats)
        # mixed chunk: step 1 is a genuine out-of-cone burst; NaN rows
        # land in OTHER steps and would out-sort it under raw margins
        embeds = [_embeds(rng) for _ in range(4)]
        embeds[1] = _embeds(rng, mu=-6.0)
        feats = np.array(jnp.stack([filt.features(e) for e in embeds]))
        feats[0, 2] = np.nan
        feats[3, 6] = np.nan
        state, summary = runner.consume(state, w, jnp.asarray(feats))
        s = jax.device_get(summary)
        assert int(s.quarantined) == 2
        got = {(int(s.topk_step[i]), int(s.topk_item[i]))
               for i in range(4)}
        assert not (got & {(0, 2), (3, 6)})   # junk never in top-k
        assert (s.topk_step == 1).all()       # the burst owns the top-k
        assert np.isfinite(s.topk_margin).all()
        assert runner.trace_count == 1

    def test_fleet_quarantine_never_displaces_genuine_anomalies(self):
        """S3, fleet path: same contract through ``_fleet_summary`` —
        mixed-tenant chunk, NaN rows in one tenant's traffic, burst in
        another's."""
        from repro.fleet.filter import FleetDataFilter
        filt = FleetDataFilter(d_model=16, num_tenants=2,
                               warmup_items=32.0, alpha=3.0)
        runner = StreamRunner(filt, chunk_T=4, topk=4)
        state, w = runner.init()
        rng = np.random.default_rng(4)
        tids = jnp.asarray(np.tile([0, 1], 4 * 4).reshape(4, 8), jnp.int32)
        for _ in range(3):                    # arm both tenants
            feats = jnp.stack([filt.features(_embeds(rng))
                               for _ in range(4)])
            state, summary = runner.consume(state, w, feats, tids)
        embeds = [_embeds(rng) for _ in range(4)]
        embeds[2] = _embeds(rng, mu=-6.0)     # burst step
        feats = np.array(jnp.stack([filt.features(e) for e in embeds]))
        feats[0, 1] = np.nan
        feats[1, 4] = np.nan
        state, summary = runner.consume(state, w, jnp.asarray(feats), tids)
        s = jax.device_get(summary)
        assert int(s.quarantined) == 2
        got = {(int(s.topk_step[i]), int(s.topk_item[i]))
               for i in range(4)}
        assert not (got & {(0, 1), (1, 4)})
        assert (s.topk_step == 2).all()
        assert np.isfinite(s.topk_margin).all()
        assert runner.trace_count == 1

    @pytest.mark.slow
    def test_sharded_layouts_match_single_device(self):
        """Same scan program under repro.dist placements (jit/SPMD mode):
        replicated and table-sharded chunk ingest must match the
        single-device runner bitwise on counts/n (fake 2-device CPU mesh
        in a subprocess, like tests/test_dist_sharded.py)."""
        code = """
            import jax, jax.numpy as jnp, numpy as np
            from repro.data.pipeline import AceDataFilter
            from repro.stream import StreamRunner

            filt = AceDataFilter(d_model=8, num_bits=6, num_tables=10,
                                 warmup_items=16.0, alpha=3.0)
            rng = np.random.default_rng(0)
            feats = jnp.asarray(rng.normal(size=(6, 16, 9)) + 1.0,
                                jnp.float32)

            base = StreamRunner(filt, chunk_T=6)
            s0, w = base.init()
            s_ref, _ = base.consume(s0, w, feats)

            mesh = jax.make_mesh((1, 2), ("data", "model"))
            for layout in ("replicated", "table_sharded"):
                r = StreamRunner(filt, chunk_T=6, mesh=mesh,
                                 sketch_layout=layout)
                s, w2 = r.init()
                s, _ = r.consume(s, w2, feats)
                assert np.array_equal(np.asarray(jax.device_get(s.counts)),
                                      np.asarray(jax.device_get(
                                          s_ref.counts))), layout
                assert float(s.n) == float(s_ref.n), layout
                np.testing.assert_allclose(
                    float(s.welford_mean), float(s_ref.welford_mean),
                    rtol=1e-6)
            print("LAYOUTS-MATCH")
        """
        env = dict(os.environ)
        env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=2 "
                            + env.get("XLA_FLAGS", ""))
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                             capture_output=True, text=True, timeout=420,
                             env=env)
        assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
        assert "LAYOUTS-MATCH" in out.stdout


# ---------------------------------------------------------------------------
# AceDataFilter contracts: hash once; Welford delegation bitwise.
# ---------------------------------------------------------------------------

class TestFilterContracts:
    def test_filter_hashes_exactly_once_per_batch(self, monkeypatch):
        """__call__ (and step) must hit the hash dispatch exactly once —
        the pre-PR path hashed every batch twice (score + insert)."""
        calls = []
        orig = srp.hash_buckets

        def counting(*a, **k):
            calls.append(1)
            return orig(*a, **k)

        monkeypatch.setattr(srp, "hash_buckets", counting)
        filt = AceDataFilter(d_model=16, warmup_items=8.0)
        state, w = filt.init()
        rng = np.random.default_rng(3)
        e = _embeds(rng)
        filt(state, w, e, jnp.ones((8, 4), jnp.float32))
        assert len(calls) == 1
        calls.clear()
        filt.step(state, w, filt.features(e))
        assert len(calls) == 1

    def test_masked_welford_matches_old_inline_formulas_bitwise(self):
        """The pre-rewrite hand-rolled Welford block of
        AceDataFilter.__call__, fed the same (state, scores, keep), must
        equal sk.masked_batch_welford BITWISE (min_n=0 — the old block
        had no cold-start gate)."""

        def old_fold(state, scores, keep):
            b = jnp.sum(keep.astype(jnp.float32))
            n = state.n
            tot = n + b
            kept_rates = jnp.where(keep,
                                   scores / jnp.maximum(tot, 1.0), 0.0)
            mean_b = jnp.sum(kept_rates) / jnp.maximum(b, 1.0)
            m2_b = jnp.sum(jnp.where(keep,
                                     (kept_rates - mean_b) ** 2, 0.0))
            delta = mean_b - state.welford_mean
            safe = jnp.maximum(tot, 1.0)
            return (tot,
                    state.welford_mean + delta * b / safe,
                    state.welford_m2 + m2_b + delta ** 2 * n * b / safe)

        rng = np.random.default_rng(4)
        cfg = sk.AceConfig(dim=8, num_bits=6, num_tables=10, seed=0)
        state = sk.insert(sk.init(cfg), sk.make_params(cfg),
                          jnp.asarray(rng.normal(size=(40, 8)),
                                      jnp.float32), cfg)
        for keep_p in (1.0, 0.5, 0.0):
            scores = jnp.asarray(rng.uniform(1, 9, size=(32,)), jnp.float32)
            keep = jnp.asarray(rng.uniform(size=(32,)) < keep_p)
            want = old_fold(state, scores, keep)
            got = sk.masked_batch_welford(
                state, scores, keep.astype(jnp.float32), min_n=0.0)
            for g, wnt in zip(got, want):
                assert float(g) == float(wnt), (keep_p, got, want)


# ---------------------------------------------------------------------------
# SRHT Pallas kernel ≡ core.srht reference; hash_mode dispatch.
# ---------------------------------------------------------------------------

SHAPES = [
    (16, 32, 8, 10),
    (100, 300, 15, 50),   # paper's K, L
    (7, 9, 4, 3),
    (33, 128, 12, 50),
    (256, 64, 6, 7),
]


class TestSrhtHashKernel:
    @pytest.mark.parametrize("B,d,K,L", SHAPES)
    def test_matches_reference_bitwise(self, B, d, K, L):
        cfg = SrpConfig(dim=d, num_bits=K, num_tables=L, seed=B + d,
                        hash_mode="srht")
        x = jnp.asarray(np.random.default_rng(d).normal(size=(B, d)),
                        jnp.float32)
        got = srht_hash(x, cfg)
        want = srht_hash_buckets(x, srht_params(cfg))
        assert got.shape == (B, L) and got.dtype == jnp.int32
        assert bool(jnp.all(got == want))

    @pytest.mark.parametrize("bm", [8, 32, 256])
    def test_batch_tiling_invariance(self, bm):
        cfg = SrpConfig(dim=48, num_bits=9, num_tables=12, seed=5,
                        hash_mode="srht")
        x = jnp.asarray(np.random.default_rng(6).normal(size=(70, 48)),
                        jnp.float32)
        assert bool(jnp.all(srht_hash(x, cfg, bm=bm) ==
                            srht_hash_buckets(x, srht_params(cfg))))

    def test_hash_buckets_dispatches_by_mode(self):
        d = 64
        x = jnp.asarray(np.random.default_rng(7).normal(size=(20, d)),
                        jnp.float32)
        dense_cfg = SrpConfig(dim=d, num_bits=8, num_tables=10, seed=1)
        srht_cfg = dataclasses.replace(dense_cfg, hash_mode="srht")
        w = srp.make_projections(dense_cfg)
        assert bool(jnp.all(
            hash_buckets(x, w, srht_cfg) ==
            srht_hash_buckets(x, srht_params(srht_cfg))))
        assert bool(jnp.all(
            hash_buckets(x, w, dense_cfg) ==
            srp.pack_buckets(srp.srp_bits(x, w, dense_cfg), dense_cfg)))
        # the two families are genuinely different hash draws
        assert not bool(jnp.all(hash_buckets(x, w, srht_cfg) ==
                                hash_buckets(x, w, dense_cfg)))


class TestHashModeDispatch:
    def test_auto_break_even_picks_the_measured_winner(self):
        """dense below the crossover (tiny matmul, the m-row gather
        dominates SRHT), srht above it (O(d·KL) vs O(d log d)) — the two
        benchmark corners of benchmarks/stream_throughput.py."""
        lo = SrpConfig(dim=64, hash_mode="auto")      # K=15, L=50
        hi = SrpConfig(dim=4096, hash_mode="auto")
        assert choose_hash_mode(lo) == "dense"
        assert choose_hash_mode(hi) == "srht"
        assert resolve_hash_mode(lo) == "dense"
        assert resolve_hash_mode(hi) == "srht"
        assert effective_cost_srht(hi) < effective_cost_dense(hi)
        assert effective_cost_srht(lo) > effective_cost_dense(lo)

    def test_auto_is_batch_free_and_monotone_at_scale(self):
        # crossover is a pure function of the static config
        for d in (1024, 2048, 8192, 12288):
            cfg = SrpConfig(dim=d, hash_mode="auto")
            assert choose_hash_mode(cfg) == "srht", d

    def test_estimator_kernel_path_respects_hash_mode(self):
        """AceEstimator(use_kernels=True) must hash through the dispatch:
        under 'srht' the dense w is a (d, 0) placeholder and a direct
        srp_hash call would crash; insert/score must match the jnp path."""
        from repro.core.estimators import AceEstimator
        from repro.core.sketch import AceConfig
        cfg = AceConfig(dim=12, num_bits=6, num_tables=8, seed=3,
                        hash_mode="srht")
        x = jnp.asarray(np.random.default_rng(9).normal(size=(40, 12)),
                        jnp.float32)
        q = jnp.asarray(np.random.default_rng(10).normal(size=(8, 12)),
                        jnp.float32)
        est_k = AceEstimator(cfg, use_kernels=True).update(x)
        est_j = AceEstimator(cfg).update(x)
        assert bool(jnp.all(est_k.state.counts == est_j.state.counts))
        assert_allclose_dtype(est_k.score(q), est_j.score(q))

    def test_invalid_mode_raises(self):
        with pytest.raises(ValueError, match="hash_mode"):
            resolve_hash_mode(SrpConfig(dim=8, hash_mode="fwht"))

    def test_interpret_resolver_env_override(self, monkeypatch):
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
        assert runtime.default_interpret() is False
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
        assert runtime.default_interpret() is True
        monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
        # backend probe: this container is CPU -> interpret
        assert runtime.default_interpret() is True
        assert runtime.resolve_interpret(False) is False
        assert runtime.resolve_interpret(None) is True


# ---------------------------------------------------------------------------
# Serve decode loop: tokens accumulate on device, ONE transfer per call.
# ---------------------------------------------------------------------------

class TestServeDecodeTransfers:
    def _engine(self):
        from repro.models.registry import Arch
        from repro.serve import engine as engine_mod
        a = Arch("qwen2_1_5b", reduced=True)
        a.cfg = dataclasses.replace(
            a.cfg, num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
            head_dim=32, d_ff=128, vocab_size=256, dtype="float32")
        params, _ = a.init_params(jax.random.PRNGKey(0))
        return engine_mod, engine_mod.ServeEngine(a, s_max=32), params

    def test_generate_transfers_once(self, monkeypatch):
        engine_mod, eng, params = self._engine()
        transfers = []
        orig = engine_mod._to_host

        def counting(x):
            transfers.append(1)
            return orig(x)

        monkeypatch.setattr(engine_mod, "_to_host", counting)
        toks = jnp.asarray(
            np.random.default_rng(8).integers(0, 256, (2, 8)), jnp.int32)
        out = eng.generate(params, {"tokens": toks}, num_new_tokens=6,
                           prompt_len=8)
        assert out.shape == (2, 6) and out.dtype == np.int32
        assert len(transfers) == 1, \
            f"decode loop made {len(transfers)} host transfers, want 1"
        # deterministic greedy decode: a second call agrees
        out2 = eng.generate(params, {"tokens": toks}, num_new_tokens=6,
                            prompt_len=8)
        np.testing.assert_array_equal(out, out2)
