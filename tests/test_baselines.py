"""Baseline correctness: small-case oracles + discriminative sanity checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines import ALL_BASELINES, run_baseline
from repro.baselines.knn_graph import knn_graph, pairwise_within_neighborhood
from repro.baselines import neighbors as nb
from repro.data.synthetic import make_paper_dataset, PAPER_STATS

jax.config.update("jax_platform_name", "cpu")


def _clustered_with_outliers(n=400, d=8, n_out=12, seed=0):
    """Inlier blob + SCATTERED far outliers (one per random direction).

    Scattered, not micro-clustered: a tight outlier clump has small kNN
    distances and high mutual indegree, so local-density methods correctly
    call it dense (the classic 'masking' effect) — that would test the
    data, not the implementations.
    """
    rng = np.random.default_rng(seed)
    mu = 4.0 * np.ones(d) / np.sqrt(d)
    inl = rng.normal(size=(n - n_out, d)) + mu
    dirs = rng.normal(size=(n_out, d))
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    out = mu + dirs * rng.uniform(12.0, 20.0, size=(n_out, 1))
    x = np.vstack([inl, out]).astype(np.float32)
    y = np.concatenate([np.zeros(n - n_out), np.ones(n_out)]).astype(np.int8)
    return x, y


class TestKnnGraph:
    def test_exact_vs_numpy(self):
        x, _ = _clustered_with_outliers(n=120)
        d_np = np.linalg.norm(x[:, None] - x[None, :], axis=-1)
        np.fill_diagonal(d_np, np.inf)
        want_idx = np.argsort(d_np, axis=1)[:, :5]
        dists, idx = knn_graph(x, 5, chunk=37)
        want_d = np.take_along_axis(d_np, want_idx, 1)
        # f32 expansion-trick precision: |err| ~ ||x||²·eps ≈ 1e-3
        np.testing.assert_allclose(dists, want_d, rtol=1e-3, atol=2e-3)
        # indices may differ on exact ties; distances must match
        got_d = np.take_along_axis(d_np, idx.astype(int), 1)
        np.testing.assert_allclose(got_d, want_d, rtol=1e-4, atol=1e-4)

    def test_chunking_invariance(self):
        x, _ = _clustered_with_outliers(n=150)
        d1, i1 = knn_graph(x, 7, chunk=150)
        d2, i2 = knn_graph(x, 7, chunk=31)
        np.testing.assert_allclose(d1, d2, rtol=1e-3, atol=2e-3)

    def test_inner_pairwise_shape_and_symmetry(self):
        x, _ = _clustered_with_outliers(n=60)
        _, idx = knn_graph(x, 4)
        inner = np.asarray(pairwise_within_neighborhood(x, idx))
        assert inner.shape == (60, 5, 5)
        np.testing.assert_allclose(inner, inner.transpose(0, 2, 1),
                                   rtol=1e-5, atol=1e-5)
        assert np.allclose(np.diagonal(inner, axis1=1, axis2=2), 0.0,
                           atol=1e-5)


class TestLofOracle:
    def test_lof_matches_handcomputed(self):
        """LOF on a tiny fixed configuration vs a literal implementation."""
        x = np.array([[0., 0.], [0., 1.], [1., 0.], [1., 1.],
                      [10., 10.]], np.float32)
        k = 2
        dists, idx = knn_graph(x, k)
        got = -np.asarray(nb.lof_score(dists, idx))   # un-negate
        # literal LOF
        d_np = np.linalg.norm(x[:, None] - x[None, :], axis=-1)
        np.fill_diagonal(d_np, np.inf)
        nn = np.argsort(d_np, 1)[:, :k]
        kdist = np.sort(d_np, 1)[:, k - 1]
        reach = np.maximum(kdist[nn], np.take_along_axis(d_np, nn, 1))
        lrd = 1.0 / reach.mean(1)
        lof = np.array([lrd[nn[i]].mean() / lrd[i] for i in range(len(x))])
        np.testing.assert_allclose(got, lof, rtol=1e-4)
        assert got[-1] == got.max()     # the far point is the outlier


@pytest.mark.parametrize("name",
                         [b for b in ALL_BASELINES if b != "fastvoa"])
def test_baseline_runs_and_discriminates(name):
    """Every kNN-family baseline: finite scores, and planted far outliers
    rank in the anomalous tail."""
    x, y = _clustered_with_outliers(n=300, d=8, n_out=10, seed=3)
    s, sec, _, _ = run_baseline(name, x, k=5)
    assert np.isfinite(s).all()
    order = np.argsort(s)                     # ascending = most anomalous
    top = set(order[:60].tolist())
    hits = sum(1 for i in np.where(y == 1)[0] if i in top)
    assert hits >= 6, f"{name}: only {hits}/10 outliers in tail"


class TestFastVOA:
    """FastVOA's per-point scores at the paper's S1=320/S2=2 are dominated
    by AMS estimator noise (its weak accuracy in the paper's Tables 3–5
    reflects this), so we validate the implementation at MOMENT level."""

    def _tiny(self, n=8, d=4, seed=1):
        rng = np.random.default_rng(seed)
        return rng.normal(size=(n, d)).astype(np.float32)

    def _exact_moments(self, X):
        n = len(X)
        m1 = np.zeros(n)
        m2 = np.zeros(n)
        for p in range(n):
            rel = np.delete(X, p, 0) - X[p]
            rel /= np.linalg.norm(rel, axis=1, keepdims=True) + 1e-12
            cos = np.clip(rel @ rel.T, -1, 1)
            ang = np.arccos(cos) / np.pi
            iu = np.triu_indices(n - 1, 1)
            m1[p] = ang[iu].mean()
            m2[p] = (ang[iu] ** 2).mean()
        return m1, m2

    def test_moa1_unbiased_and_concentrated(self):
        import jax as _jax
        from repro.baselines.fastvoa import _one_projection
        X = self._tiny(n=20, d=5)
        m1, _ = self._exact_moments(X)
        t = 1500
        keys = _jax.random.split(_jax.random.PRNGKey(0), t)
        signs = jnp.ones((1, 20), jnp.float32)
        acc = np.zeros(20)
        for i in range(t):
            f1, _ = _one_projection(jnp.asarray(X), keys[i], signs)
            acc += np.asarray(f1)
        pairs = 19 * 18 / 2
        est = acc / t / pairs
        np.testing.assert_allclose(est, m1, rtol=0.08)

    def test_voa_unbiased_small_case(self):
        """Full-score VOA ≈ exact VOA on a tiny set with generous sampling.

        The small case must carry SIGNAL: the original version of this
        test drew 10 i.i.d. Gaussian points, whose true VOA spread
        across points (std ≈ 0.008) is SMALLER than the seed-averaged
        estimator noise (std ≈ 0.02–0.04, consistent with the verified
        AMS variance) — the correlation assert was measuring noise and
        failed deterministically at ~0.28 while the estimator itself was
        fine (moment-level unbiasedness passes above, absolute error is
        within its variance budget).  A near-collinear configuration
        spans the statistic's real dynamic range instead: interior
        points see bimodal {0, π} angles (VOA ≈ 0.2, near the 0.25 max),
        endpoints see a single tight cone (VOA ≈ 0) — spread ≈ 0.08,
        10× the noise, so correlation is a statement about the
        implementation again (measured ≈ 0.99 at these budgets).
        """
        from repro.baselines.fastvoa import fastvoa_score
        rng = np.random.default_rng(1)
        n, d = 10, 4
        X = np.zeros((n, d), np.float32)
        X[:, 0] = np.arange(n, dtype=np.float32)        # collinear spine
        X[:, 1:] += rng.normal(size=(n, d - 1)).astype(np.float32) * 0.15
        m1, m2 = self._exact_moments(X)
        voa = m2 - m1**2
        assert voa.std() > 0.05          # the case really carries signal
        est = np.stack([
            np.asarray(fastvoa_score(X, t=600, s2=24, seed=s))
            for s in range(8)]).mean(0)
        assert np.corrcoef(voa, est)[0, 1] > 0.8
        assert np.abs(est - voa).mean() < 0.05
        # the two endpoints (lowest true VOA by an order of magnitude)
        # must land in the estimator's bottom two — the ABOD decision
        # the score exists for
        assert set(np.argsort(est)[:2].tolist()) == {0, n - 1}

    def test_runs_at_paper_params(self):
        x, _ = _clustered_with_outliers(n=200, d=8, n_out=6)
        s, sec, _, _ = run_baseline("fastvoa", x, k=5, fastvoa_t=320)
        assert s.shape == (200,) and np.isfinite(s).all()


def test_paper_dataset_stats():
    for name, (n, n_anom, d) in PAPER_STATS.items():
        ds = make_paper_dataset(name, n=2000)
        assert ds.x.shape == (2000, d)
        assert ds.y.sum() == ds.n_anomalies
        assert abs(ds.n_anomalies / 2000 - n_anom / n) < 0.02
        assert (ds.x >= 0).all()              # nonnegative features
