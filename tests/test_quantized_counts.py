"""Differential-oracle suite for quantized (int8/int16) count planes.

The quantized-plane contract (repro.core.quantize) makes two promises:

1. **Below saturation, narrow is FREE.**  Every op — insert, masked
   insert, delete, merge, window rotation, mixed-tenant fleet ingest —
   is bitwise identical to the float32-counter oracle as long as no
   bucket exceeds the narrow dtype's max.  Not approximately: the
   gathers upcast exact integers and every score path shares the same
   literal sum + reciprocal-1/L sequence, so the float32 downstream is
   the SAME float32 downstream.

2. **Past saturation, promotion keeps it exact.**  With
   ``esc_capacity > 0`` a bucket crossing the cap (127 / 32767 —
   exactly the dtype max, no early slack) promotes into the escalation
   table and logical counts stay exact; dropping back below the cap
   un-promotes and frees the slot; only escalation-table overflow loses
   mass, and that loss is counted (``esc.lost``), never silent.

Properties are stated over hypothesis-drawn shapes/seeds (st.integers
only — the suite runs under the deterministic fallback shim in
conftest.py) with all batch sizes chosen so the below-saturation cases
genuinely stay below saturation for int8's 127 cap.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quantize as qz
from repro.core import sketch as sk
from repro.core.sketch import AceConfig
from repro.fleet import state as fleet
from repro.train import checkpoint
from repro.window import ring

jax.config.update("jax_platform_name", "cpu")

NARROW = ("int8", "int16")


def _cfgs(K, L, dtype, esc=8, seed=0):
    """(quantized cfg, float32-oracle cfg) — identical hash geometry."""
    kw = dict(dim=6, num_bits=K, num_tables=L, seed=seed)
    return (AceConfig(counter_dtype=dtype, esc_capacity=esc, **kw),
            AceConfig(counter_dtype="float32", **kw))


def _buckets(rng, B, cfg):
    return jnp.asarray(
        rng.integers(0, cfg.num_buckets, size=(B, cfg.num_tables)),
        jnp.int32)


def _same_bucket(B, cfg, bucket=0):
    """B items that ALL land in `bucket` of every table — the
    saturation battering ram."""
    return jnp.full((B, cfg.num_tables), bucket, jnp.int32)


def _assert_state_parity(q, o):
    """Quantized state ≡ float32 oracle state, bitwise."""
    dense = qz.densify(q.counts, q.esc).astype(jnp.float32)
    assert bool(jnp.array_equal(dense, o.counts))
    assert float(q.n) == float(o.n)
    assert float(q.welford_mean) == float(o.welford_mean)
    assert float(q.welford_m2) == float(o.welford_m2)
    assert float(sk.mean_mu(q)) == float(sk.mean_mu(o))


class TestBelowSaturationParity:
    """Ops on narrow planes ≡ the float32 oracle while counts < cap."""

    @settings(max_examples=20, deadline=None)
    @given(B=st.integers(1, 30), K=st.integers(2, 6), L=st.integers(1, 6),
           dt=st.integers(0, 1), seed=st.integers(0, 9999))
    def test_insert_bitwise(self, B, K, L, dt, seed):
        cq, co = _cfgs(K, L, NARROW[dt])
        rng = np.random.default_rng(seed)
        b1, b2 = _buckets(rng, B, cq), _buckets(rng, B + 1, cq)
        q = sk.insert_buckets(sk.insert_buckets(sk.init(cq), b1, cq),
                              b2, cq)
        o = sk.insert_buckets(sk.insert_buckets(sk.init(co), b1, co),
                              b2, co)
        _assert_state_parity(q, o)
        probe = _buckets(rng, 7, cq)
        assert bool(jnp.array_equal(sk.lookup(q, probe),
                                    sk.lookup(o, probe)))

    @settings(max_examples=15, deadline=None)
    @given(B=st.integers(1, 24), K=st.integers(2, 5),
           dt=st.integers(0, 1), seed=st.integers(0, 9999))
    def test_masked_insert_bitwise(self, B, K, dt, seed):
        cq, co = _cfgs(K, 4, NARROW[dt])
        rng = np.random.default_rng(seed)
        b = _buckets(rng, B, cq)
        mask = jnp.asarray(rng.integers(0, 2, size=(B,)) > 0)
        q = sk.insert_buckets_masked(sk.init(cq), b, mask, cq)
        o = sk.insert_buckets_masked(sk.init(co), b, mask, co)
        _assert_state_parity(q, o)

    @settings(max_examples=15, deadline=None)
    @given(B=st.integers(1, 20), K=st.integers(2, 5),
           dt=st.integers(0, 1), seed=st.integers(0, 9999))
    def test_delete_bitwise(self, B, K, dt, seed):
        cq, co = _cfgs(K, 3, NARROW[dt])
        rng = np.random.default_rng(seed)
        seed_b, del_b = _buckets(rng, 25, cq), None
        # delete a prefix of what was inserted (matched streams never
        # take a bucket below 0 — the quantize module's documented
        # domain)
        del_b = seed_b[:B]
        q = sk.delete_buckets(sk.insert_buckets(sk.init(cq), seed_b, cq),
                              del_b, cq)
        o = sk.delete_buckets(sk.insert_buckets(sk.init(co), seed_b, co),
                              del_b, co)
        _assert_state_parity(q, o)

    @settings(max_examples=12, deadline=None)
    @given(B=st.integers(1, 20), K=st.integers(2, 5),
           dt=st.integers(0, 1), seed=st.integers(0, 9999))
    def test_merge_bitwise(self, B, K, dt, seed):
        cq, co = _cfgs(K, 3, NARROW[dt])
        rng = np.random.default_rng(seed)
        b1, b2 = _buckets(rng, B, cq), _buckets(rng, B + 3, cq)
        q = sk.merge(sk.insert_buckets(sk.init(cq), b1, cq),
                     sk.insert_buckets(sk.init(cq), b2, cq))
        o = sk.merge(sk.insert_buckets(sk.init(co), b1, co),
                     sk.insert_buckets(sk.init(co), b2, co))
        _assert_state_parity(q, o)

    @settings(max_examples=12, deadline=None)
    @given(B=st.integers(2, 24), T=st.integers(1, 4), K=st.integers(2, 5),
           dt=st.integers(0, 1), seed=st.integers(0, 9999))
    def test_mixed_tenant_ingest_bitwise(self, B, T, K, dt, seed):
        """Fleet tables take narrow dtypes WITHOUT promotion (plain
        wrap-add scatter) — below saturation the whole mixed-tenant
        ingest matches the float32 fleet bitwise."""
        aq = AceConfig(dim=6, num_bits=K, num_tables=3,
                       counter_dtype=NARROW[dt])
        ao = AceConfig(dim=6, num_bits=K, num_tables=3,
                       counter_dtype="float32")
        fq = fleet.init(fleet.FleetConfig(ace=aq, num_tenants=T))
        fo = fleet.init(fleet.FleetConfig(ace=ao, num_tenants=T))
        rng = np.random.default_rng(seed)
        for _ in range(3):
            b = _buckets(rng, B, aq)
            tids = jnp.asarray(rng.integers(0, T, size=(B,)), jnp.int32)
            mask = jnp.asarray(rng.integers(0, 2, size=(B,)) > 0)
            fq = fleet.insert_masked(fq, tids, b, mask, aq)
            fo = fleet.insert_masked(fo, tids, b, mask, ao)
        assert bool(jnp.array_equal(fq.counts.astype(jnp.float32),
                                    fo.counts))
        assert bool(jnp.array_equal(fq.n, fo.n))
        assert bool(jnp.array_equal(fq.welford_mean, fo.welford_mean))
        assert bool(jnp.array_equal(fq.welford_m2, fo.welford_m2))

    @settings(max_examples=10, deadline=None)
    @given(B=st.integers(1, 16), E=st.integers(1, 4),
           dt=st.integers(0, 1), seed=st.integers(0, 9999))
    def test_window_rotate_bitwise(self, B, E, dt, seed):
        """Narrow epoch rings: insert/rotate cycles ≡ the float32 ring
        (rotation decays the f32 tail and zeroes the narrow live epoch —
        no narrow arithmetic beyond the same exact integer adds)."""
        aq = AceConfig(dim=6, num_bits=4, num_tables=3,
                       counter_dtype=NARROW[dt])
        ao = AceConfig(dim=6, num_bits=4, num_tables=3,
                       counter_dtype="float32")
        rq, ro = ring.init(aq, E), ring.init(ao, E)
        rng = np.random.default_rng(seed)
        for step in range(2 * E + 1):
            b = _buckets(rng, B, aq)
            mask = jnp.asarray(rng.integers(0, 2, size=(B,)) > 0)
            rq = ring.insert_current(rq, b, mask, aq)
            ro = ring.insert_current(ro, b, mask, ao)
            if step % 2 == 1:
                rq = ring.rotate(rq, gamma=0.5)
                ro = ring.rotate(ro, gamma=0.5)
        assert bool(jnp.array_equal(rq.counts.astype(jnp.float32),
                                    ro.counts))
        assert bool(jnp.array_equal(rq.tail, ro.tail))
        assert bool(jnp.array_equal(rq.n, ro.n))
        assert int(rq.cursor) == int(ro.cursor)
        assert float(rq.ssq) == float(ro.ssq)


class TestOverflowPromotion:
    """Crossing the cap promotes; estimates stay EXACT past 127/32767."""

    def test_promotion_fires_at_exactly_dtype_max(self):
        cfg, _ = _cfgs(2, 1, "int8", esc=4)
        cap = qz.cap_for("int8")
        assert cap == 127
        state = sk.init(cfg)
        # Fill bucket 0 to EXACTLY the cap: still narrow, no slot used.
        for _ in range(cap // 16):
            state = sk.insert_buckets(state, _same_bucket(16, cfg), cfg)
        state = sk.insert_buckets(state, _same_bucket(cap % 16, cfg), cfg)
        assert int(state.counts[0, 0]) == cap
        assert int(jnp.sum(state.esc.offs != qz.SENTINEL)) == 0
        # One more item crosses the cap: the slot allocates and the
        # logical count is cap+1 exactly.
        state = sk.insert_buckets(state, _same_bucket(1, cfg), cfg)
        assert int(jnp.sum(state.esc.offs != qz.SENTINEL)) == 1
        dense = qz.densify(state.counts, state.esc)
        assert int(dense[0, 0]) == cap + 1
        assert int(state.counts[0, 0]) == cap      # narrow stays clipped

    @settings(max_examples=6, deadline=None)
    @given(extra=st.integers(1, 120), dt=st.integers(0, 1),
           seed=st.integers(0, 99))
    def test_estimates_exact_past_saturation(self, extra, dt, seed):
        """n_total = cap + extra items into one bucket: the score of
        that bucket is exactly n_total — where an unpromoted narrow
        plane would have clipped at cap."""
        if NARROW[dt] == "int16":
            # int16's cap is unreachable batch-by-batch in test time;
            # synthesise the pre-saturated plane instead.
            cfg, _ = _cfgs(2, 1, "int16", esc=4)
            cap = qz.cap_for("int16")
            state = sk.init(cfg)
            state = state._replace(
                counts=state.counts.at[0, 0].set(cap))
        else:
            cfg, _ = _cfgs(2, 1, "int8", esc=4)
            cap = qz.cap_for("int8")
            state = sk.init(cfg)
            while int(state.counts[0, 0]) < cap:
                step = min(16, cap - int(state.counts[0, 0]))
                state = sk.insert_buckets(state, _same_bucket(step, cfg),
                                          cfg)
        for _ in range(extra // 16):
            state = sk.insert_buckets(state, _same_bucket(16, cfg), cfg)
        state = sk.insert_buckets(state, _same_bucket(extra % 16, cfg),
                                  cfg)
        probe = _same_bucket(1, cfg)
        assert float(sk.lookup(state, probe)[0]) == float(cap + extra)
        assert float(state.esc.lost) == 0.0

    def test_delete_unpromotes(self):
        cfg, _ = _cfgs(2, 1, "int8", esc=4)
        cap = qz.cap_for("int8")
        state = sk.init(cfg)
        state = state._replace(counts=state.counts.at[0, 0].set(cap))
        state = sk.insert_buckets(state, _same_bucket(10, cfg), cfg)
        assert int(jnp.sum(state.esc.offs != qz.SENTINEL)) == 1
        # Delete back below the cap: slot freed, narrow exact again.
        state = sk.delete_buckets(state, _same_bucket(15, cfg), cfg)
        assert int(jnp.sum(state.esc.offs != qz.SENTINEL)) == 0
        assert int(state.counts[0, 0]) == cap - 5
        probe = _same_bucket(1, cfg)
        assert float(sk.lookup(state, probe)[0]) == float(cap - 5)

    def test_esc_overflow_counts_lost_mass(self):
        """More promoted buckets than slots: the overflow is DROPPED but
        COUNTED — esc.lost bills the missing mass, nothing crashes."""
        cfg = AceConfig(dim=6, num_bits=2, num_tables=2,
                        counter_dtype="int8", esc_capacity=1)
        cap = qz.cap_for("int8")
        state = sk.init(cfg)
        # Both tables' bucket 0 sit at the cap; one batch pushes BOTH
        # over — only one slot exists.
        state = state._replace(
            counts=state.counts.at[:, 0].set(cap))
        state = sk.insert_buckets(state, _same_bucket(5, cfg), cfg)
        assert int(jnp.sum(state.esc.offs != qz.SENTINEL)) == 1
        assert float(state.esc.lost) == 5.0
        dense = qz.densify(state.counts, state.esc)
        kept = sorted(int(dense[j, 0]) for j in range(2))
        assert kept == [cap, cap + 5]

    def test_merge_requires_matching_quantization(self):
        cq, co = _cfgs(3, 2, "int8", esc=4)
        with pytest.raises(ValueError, match="merge"):
            sk.merge(sk.init(cq), sk.init(co))


class TestCheckpointRoundTrip:
    """Serialization preserves the narrow dtype AND the esc table."""

    @settings(max_examples=5, deadline=None)
    @given(dt=st.integers(0, 1), seed=st.integers(0, 999))
    def test_quantized_state_round_trips(self, dt, seed):
        cfg, _ = _cfgs(3, 2, NARROW[dt], esc=4)
        rng = np.random.default_rng(seed)
        state = sk.insert_buckets(sk.init(cfg), _buckets(rng, 20, cfg),
                                  cfg)
        # force a promoted slot into the picture
        state = state._replace(
            counts=state.counts.at[0, 0].set(qz.cap_for(NARROW[dt])))
        state = sk.insert_buckets(state, _same_bucket(3, cfg), cfg)
        with tempfile.TemporaryDirectory() as d:
            checkpoint.save(d, 0, state)
            back, _ = checkpoint.restore(d, 0, sk.init(cfg))
        assert back.counts.dtype == jnp.dtype(NARROW[dt])
        assert bool(jnp.array_equal(back.counts, state.counts))
        assert bool(jnp.array_equal(back.esc.offs, state.esc.offs))
        assert bool(jnp.array_equal(back.esc.vals, state.esc.vals))
        assert float(back.esc.lost) == float(state.esc.lost)
        assert float(back.n) == float(state.n)
        # and the restored state still scores exactly
        probe = _same_bucket(1, cfg)
        assert float(sk.lookup(back, probe)[0]) == float(
            sk.lookup(state, probe)[0])

    def test_unquantized_state_has_no_esc_leaves(self):
        cfg = AceConfig(dim=6, num_bits=3, num_tables=2)
        state = sk.init(cfg)
        assert state.esc is None
        with tempfile.TemporaryDirectory() as d:
            checkpoint.save(d, 0, state)
            back, _ = checkpoint.restore(d, 0, sk.init(cfg))
        assert back.esc is None


class TestConfigGuards:
    """Promotion is flat-sketch only; configs say so loudly."""

    def test_esc_requires_narrow_dtype(self):
        with pytest.raises(ValueError, match="narrow"):
            AceConfig(dim=6, num_bits=3, esc_capacity=4)   # int32 default

    def test_negative_capacity_rejected(self):
        with pytest.raises(ValueError, match="esc_capacity"):
            AceConfig(dim=6, num_bits=3, counter_dtype="int8",
                      esc_capacity=-1)

    def test_window_rejects_promotion(self):
        cfg = AceConfig(dim=6, num_bits=3, num_tables=2,
                        counter_dtype="int8", esc_capacity=2)
        with pytest.raises(NotImplementedError, match="flat"):
            ring.WindowConfig(ace=cfg)
        with pytest.raises(NotImplementedError):
            ring.init(cfg, 2)

    def test_fleet_rejects_promotion(self):
        cfg = AceConfig(dim=6, num_bits=3, num_tables=2,
                        counter_dtype="int8", esc_capacity=2)
        with pytest.raises(NotImplementedError, match="flat"):
            fleet.FleetConfig(ace=cfg, num_tenants=2)

    def test_memory_bytes_reflects_narrow_planes(self):
        mk = lambda dt: AceConfig(dim=6, num_bits=8, num_tables=4,
                                  counter_dtype=dt)
        f32, i16, i8 = (mk("float32").memory_bytes(),
                        mk("int16").memory_bytes(),
                        mk("int8").memory_bytes())
        assert i16 < f32 and i8 < i16
