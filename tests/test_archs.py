"""Per-architecture smoke tests (deliverable f): every assigned arch as a
REDUCED same-family config — forward/train-step on CPU, shape + no-NaN
asserts — plus serving-path equivalence and block-level properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.registry import Arch, is_whisper

jax.config.update("jax_platform_name", "cpu")


def _batch_for(a: Arch, B=2, S=12, seed=0):
    rng = np.random.default_rng(seed)
    cfg = a.cfg
    batch = {}
    if is_whisper(cfg):
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    elif cfg.input_mode == "embeds":
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
        if cfg.mrope_sections:
            batch["positions"] = jnp.asarray(
                np.tile(np.arange(S), (3, B, 1)), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    return batch


@pytest.mark.parametrize("name", ARCHS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, name):
        a = Arch(name, reduced=True)
        params, _ = a.init_params(jax.random.PRNGKey(0))
        batch = _batch_for(a)
        logits, aux = a.forward(params, batch, remat=False)
        B, S = batch["labels"].shape
        assert logits.shape[-1] == a.cfg.vocab_size
        assert logits.shape[0] == B
        assert not bool(jnp.isnan(logits).any())

    def test_one_train_step_finite_and_decreases(self, name):
        """SGD step on one batch: finite grads, loss drops on re-eval.

        The step uses a geometric backoff (0.5, 0.25, 0.125 / ‖g‖) and
        requires SOME scale to decrease the loss — the guarantee
        gradient descent actually gives (the gradient is a descent
        direction for sufficiently small steps; no fixed global scale
        is safe for every curvature).  The backoff exists for jamba:
        its mamba mixer's inner SSM RMSNorm (the Jamba paper's
        stabilization trick) normalizes an O(0.01)-scale branch signal
        at init, which amplifies the embed-ward gradient ~15× over the
        other archs (the embed leaf is 56 of ‖g‖ = 60.7) and makes the
        fixed 0.5 step overshoot along the embed direction specifically
        (stepping embed alone RAISES the loss; every other leaf's step
        lowers it; the full step decreases cleanly at 0.25).  A genuinely
        broken gradient fails at every scale.
        """
        a = Arch(name, reduced=True)
        params, _ = a.init_params(jax.random.PRNGKey(0))
        batch = _batch_for(a)

        loss_fn = jax.jit(lambda p: a.loss(p, batch, remat=True)[0])
        loss0, grads = jax.value_and_grad(
            lambda p: a.loss(p, batch, remat=True)[0])(params)
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in jax.tree.leaves(grads)))
        assert np.isfinite(float(loss0)) and np.isfinite(float(gnorm))
        losses = {}
        for scale in (0.5, 0.25, 0.125):
            lr = scale / max(float(gnorm), 1.0)
            params2 = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                                   params, grads)
            losses[scale] = float(loss_fn(params2))
            if losses[scale] < float(loss0):
                break
        assert min(losses.values()) < float(loss0), \
            (name, float(loss0), losses)


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode_matches_forward(name):
    """Serving path == training path on the last-token logits."""
    a = Arch(name, reduced=True)
    cfg = a.cfg
    params, _ = a.init_params(jax.random.PRNGKey(1))
    B, S = 2, 12
    batch = _batch_for(a, B=B, S=S, seed=1)
    logits_full, _ = a.forward(params, batch, remat=False)

    if is_whisper(cfg):
        pre = {"embeds": batch["embeds"], "tokens": batch["tokens"][:, :-1]}
        dec = {"tokens": batch["tokens"][:, -1:]}
        pos = jnp.full((B,), S - 1, jnp.int32)
    elif cfg.input_mode == "embeds":
        pre = {"embeds": batch["embeds"][:, :-1]}
        dec = {"embeds": batch["embeds"][:, -1:]}
        if cfg.mrope_sections:
            pre["positions"] = batch["positions"][:, :, :-1]
            dec["positions"] = batch["positions"][:, :, -1:]
            pos = jnp.full((3, B), S - 1, jnp.int32)
        else:
            pos = jnp.full((B,), S - 1, jnp.int32)
    else:
        pre = {"tokens": batch["tokens"][:, :-1]}
        dec = {"tokens": batch["tokens"][:, -1:]}
        pos = jnp.full((B,), S - 1, jnp.int32)

    _, cache = a.prefill(params, pre, s_max=S)
    ld, _ = a.decode_step(params, dec, cache, pos)
    want = np.asarray(logits_full[:, -1, :], np.float32)
    got = np.asarray(ld[:, 0, :], np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


class TestBlockProperties:
    def test_sliding_window_masks_past(self):
        """SINGLE-LAYER property: a token > window back has zero influence.
        (Across layers the receptive field grows by W per layer, so the
        whole-model version of this check would be vacuous.)"""
        from repro.models import attention as attn
        from repro.models.common import KeyGen
        cfg = Arch("mixtral_8x7b", reduced=True).cfg    # window 16
        p, _ = attn.init_attention(cfg, KeyGen(jax.random.PRNGKey(2)))
        rng = np.random.default_rng(2)
        S = 24
        x1 = rng.normal(size=(1, S, cfg.d_model)).astype(np.float32)
        x2 = x1.copy()
        x2[0, 0] += 5.0                                 # perturb token 0
        pos = jnp.arange(S, dtype=jnp.int32)[None]
        o1, _ = attn.attention(p, jnp.asarray(x1), cfg, positions=pos,
                               layer_kind="swa")
        o2, _ = attn.attention(p, jnp.asarray(x2), cfg, positions=pos,
                               layer_kind="swa")
        d = np.abs(np.asarray(o1) - np.asarray(o2))[0].max(axis=-1)
        assert d[:16].max() > 1e-3          # inside window: influenced
        np.testing.assert_allclose(d[16:], 0.0, atol=1e-6)  # beyond: zero

    def test_causality(self):
        """Future tokens must not affect past logits (dense arch)."""
        a = Arch("olmo_1b", reduced=True)
        params, _ = a.init_params(jax.random.PRNGKey(3))
        rng = np.random.default_rng(3)
        S = 10
        t1 = rng.integers(0, a.cfg.vocab_size, (1, S))
        t2 = t1.copy()
        t2[0, -1] = (t1[0, -1] + 3) % a.cfg.vocab_size
        l1, _ = a.forward(params, {"tokens": jnp.asarray(t1, jnp.int32)},
                          remat=False)
        l2, _ = a.forward(params, {"tokens": jnp.asarray(t2, jnp.int32)},
                          remat=False)
        np.testing.assert_allclose(np.asarray(l1[0, :-1]),
                                   np.asarray(l2[0, :-1]), atol=1e-5)

    def test_mamba_scan_equals_stepwise(self):
        from repro.models import mamba as mb
        from repro.models.common import KeyGen, ModelConfig
        cfg = Arch("jamba_v01_52b", reduced=True).cfg
        p, _ = mb.init_mamba(cfg, KeyGen(jax.random.PRNGKey(4)))
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.normal(size=(2, 9, cfg.d_model)), jnp.float32)
        y_scan, _ = mb.mamba_scan(p, x, cfg)
        st = mb.init_mamba_state(cfg, 2, jnp.float32)
        outs = []
        for t in range(9):
            y, st = mb.mamba_step(p, x[:, t:t + 1], st, cfg)
            outs.append(y)
        y_step = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step),
                                   rtol=2e-4, atol=2e-4)

    def test_rwkv_scan_equals_stepwise(self):
        from repro.models import rwkv6 as rw
        from repro.models.common import KeyGen
        cfg = Arch("rwkv6_7b", reduced=True).cfg
        p, _ = rw.init_rwkv_time(cfg, KeyGen(jax.random.PRNGKey(5)))
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.normal(size=(2, 7, cfg.d_model)), jnp.float32)
        st0 = rw.init_rwkv_state(cfg, 2, jnp.float32)
        y_scan, _, _ = rw.rwkv_time_scan(p, x, st0.x_prev_att, st0.wkv, cfg)
        xp = st0.x_prev_att
        wkv = st0.wkv
        outs = []
        for t in range(7):
            y, xp, wkv = rw.rwkv_time_step(
                p, x[:, t:t + 1], rw.RwkvState(xp, st0.x_prev_ffn, wkv), cfg)
            outs.append(y)
        y_step = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step),
                                   rtol=2e-4, atol=2e-4)

    def test_moe_no_drop_at_full_capacity(self):
        from repro.models import mlp as mlp_mod
        from repro.models.common import KeyGen
        cfg = Arch("mixtral_8x7b", reduced=True).cfg
        p, _ = mlp_mod.init_moe(cfg, KeyGen(jax.random.PRNGKey(6)))
        rng = np.random.default_rng(6)
        x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32)
        _, aux = mlp_mod.moe(p, x, cfg,
                             capacity_factor=float(cfg.moe_num_experts)
                             / cfg.moe_top_k)
        assert float(aux["moe_drop_frac"]) == 0.0

    def test_mrope_sections_change_behavior(self):
        """Different h/w position streams must change qwen2-vl outputs."""
        a = Arch("qwen2_vl_7b", reduced=True)
        params, _ = a.init_params(jax.random.PRNGKey(7))
        rng = np.random.default_rng(7)
        B, S = 1, 8
        emb = jnp.asarray(rng.normal(size=(B, S, a.cfg.d_model)), jnp.float32)
        p1 = np.tile(np.arange(S), (3, B, 1))
        p2 = p1.copy()
        p2[1] = p2[1][:, ::-1]    # reverse the h-stream
        l1, _ = a.forward(params, {"embeds": emb,
                                   "positions": jnp.asarray(p1, jnp.int32)},
                          remat=False)
        l2, _ = a.forward(params, {"embeds": emb,
                                   "positions": jnp.asarray(p2, jnp.int32)},
                          remat=False)
        assert np.abs(np.asarray(l1) - np.asarray(l2)).max() > 1e-4

    def test_gemma2_softcap_bounds_logits(self):
        a = Arch("gemma2_27b", reduced=True)
        params, _ = a.init_params(jax.random.PRNGKey(8))
        batch = _batch_for(a, seed=8)
        logits, _ = a.forward(params, batch, remat=False)
        assert float(jnp.abs(logits).max()) <= 30.0 + 1e-3  # final softcap


def test_ring_cache_equals_full():
    """§Perf B4: a window-sized ring KV cache is bit-equivalent to the full
    cache for pure-SWA archs (mixtral), verified over a 24-step decode."""
    from repro.models import transformer as tf
    a = Arch("mixtral_8x7b", reduced=True)    # window 16, all layers swa
    cfg = a.cfg
    params, _ = a.init_params(jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    B, T = 2, 24
    toks = rng.integers(0, cfg.vocab_size, (B, T))

    def decode_all(s_max):
        cache = tf.init_cache(cfg, B, s_max)
        outs = []
        for t in range(T):
            pos = jnp.full((B,), t, jnp.int32)
            logits, cache = a.decode_step(
                params, {"tokens": jnp.asarray(toks[:, t:t + 1], jnp.int32)},
                cache, pos)
            outs.append(np.asarray(logits[:, 0], np.float32))
        return np.stack(outs)

    full = decode_all(T)
    ring = decode_all(16)                      # ring == window size
    np.testing.assert_allclose(ring, full, atol=2e-4, rtol=2e-4)
