"""Training-substrate tests: optimizers, schedules, checkpointing (elastic),
compression, ACE gradient monitor, ACE data filter, end-to-end loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import AceDataFilter, DataStream, StreamConfig, \
    synth_batch
from repro.models.registry import Arch
from repro.train import checkpoint as ck
from repro.train.compression import (compress_grads_with_ef,
                                     decompress_grads, init_error_feedback)
from repro.train.fault import GradMonitor
from repro.train.optim import AdamW, Adafactor, Sgd, clip_by_global_norm, \
    make_optimizer
from repro.train.schedule import ConstantSchedule, CosineSchedule
from repro.train.train_loop import TrainConfig, init_train_state, \
    make_train_step, train

jax.config.update("jax_platform_name", "cpu")


def _quad_problem(seed=0, n=64, d=8):
    """Least squares: params {'w','b'}; loss convex -> optimizers must
    converge."""
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    w_true = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    y = X @ w_true + 0.5

    def loss_fn(params):
        pred = X @ params["w"] + params["b"]
        return jnp.mean((pred - y) ** 2)

    params = {"w": jnp.zeros((d,), jnp.float32),
              "b": jnp.zeros((), jnp.float32)}
    return loss_fn, params


class TestOptimizers:
    @pytest.mark.parametrize("name,lr", [("sgd", 0.1), ("adamw", 0.05),
                                         ("adafactor", 0.5)])
    def test_converges_on_quadratic(self, name, lr):
        loss_fn, params = _quad_problem()
        opt = make_optimizer(name) if name != "adamw" \
            else AdamW(weight_decay=0.0)
        state = opt.init(params)
        l0 = float(loss_fn(params))
        # adafactor takes ~unit-RMS steps of size lr (no momentum), so a
        # constant lr limit-cycles at loss ∝ lr²; anneal as in practice.
        steps = 600 if name == "adafactor" else 200
        for step in range(steps):
            lr_t = lr / np.sqrt(step + 1) if name == "adafactor" else lr
            g = jax.grad(loss_fn)(params)
            params, state = opt.update(params, g, state,
                                       jnp.asarray(step), lr_t)
        l1 = float(loss_fn(params))
        assert l1 < 0.05 * l0, (name, l0, l1)

    def test_adamw_decoupled_decay(self):
        """With zero grads, weights shrink by exactly lr*wd each step."""
        opt = AdamW(weight_decay=0.1)
        params = {"w": jnp.ones((4,), jnp.float32)}
        state = opt.init(params)
        g = {"w": jnp.zeros((4,), jnp.float32)}
        new, _ = opt.update(params, g, state, jnp.asarray(0), 0.01)
        np.testing.assert_allclose(np.asarray(new["w"]),
                                   1.0 - 0.01 * 0.1, rtol=1e-5)

    def test_adafactor_memory_is_factored(self):
        opt = Adafactor()
        params = {"w": jnp.ones((64, 32), jnp.float32)}
        state = opt.init(params)
        slot = state["slots"]["w"]
        assert slot["vr"].shape == (64,) and slot["vc"].shape == (32,)

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        total = float(jnp.sqrt(sum(jnp.sum(x**2)
                                   for x in jax.tree.leaves(clipped))))
        assert abs(total - 1.0) < 1e-5
        assert abs(float(norm) - np.sqrt(90 + 160)) < 1e-3


class TestSchedules:
    def test_cosine_shape(self):
        s = CosineSchedule(peak_lr=1.0, warmup_steps=10, total_steps=100)
        assert float(s(0)) == 0.0
        assert abs(float(s(10)) - 1.0) < 1e-6
        assert float(s(100)) <= 0.11
        assert float(s(55)) < float(s(20))


class TestCheckpoint:
    def test_roundtrip_and_gc(self, tmp_path):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "nested": {"b": jnp.ones((4,), jnp.int32)}}
        for step in (1, 2, 3, 4):
            ck.save(str(tmp_path), step, tree, extra={"k": step}, keep=2)
        assert ck.all_steps(str(tmp_path)) == [3, 4]
        like = jax.tree.map(jnp.zeros_like, tree)
        restored, manifest = ck.restore(str(tmp_path), 4, like)
        assert manifest["extra"]["k"] == 4
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))

    def test_structure_mismatch_rejected(self, tmp_path):
        ck.save(str(tmp_path), 1, {"a": jnp.ones(3)})
        with pytest.raises(ValueError):
            ck.restore(str(tmp_path), 1, {"zzz": jnp.ones(3)})

    def test_elastic_reshard_on_load(self, tmp_path):
        """Restore with explicit shardings (single-device here; the API is
        topology-free — the multi-device path is exercised in
        tests/test_distributed.py via subprocess)."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        tree = {"w": jnp.arange(8, dtype=jnp.float32)}
        ck.save(str(tmp_path), 7, tree)
        mesh = jax.make_mesh((1,), ("data",))
        sh = {"w": NamedSharding(mesh, P())}
        restored, _ = ck.restore(str(tmp_path), 7,
                                 jax.tree.map(jnp.zeros_like, tree), sh)
        assert restored["w"].sharding == sh["w"]


class TestCompression:
    def test_ef_reduces_error_over_steps(self):
        """Error feedback: repeated quantisation of the same gradient must
        converge (residual carries the rounding error)."""
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.normal(size=(256,)), jnp.float32)}
        ef = init_error_feedback(g)
        applied = jnp.zeros((256,), jnp.float32)
        for i in range(20):
            q, s, ef = compress_grads_with_ef(g, ef, jax.random.PRNGKey(i))
            applied += decompress_grads(q, s)["w"]
        avg = applied / 20
        err = float(jnp.linalg.norm(avg - g["w"]) / jnp.linalg.norm(g["w"]))
        assert err < 0.05

    def test_quantise_roundtrip_bounded(self):
        from repro.train.compression import dequantise_int8, quantise_int8
        x = jnp.linspace(-3, 3, 100)
        q, s = quantise_int8(x, jax.random.PRNGKey(0))
        err = jnp.abs(dequantise_int8(q, s) - x).max()
        assert float(err) <= float(s) * 1.01


class TestGradMonitor:
    def test_flags_gradient_spike(self):
        gm = GradMonitor(feature_dim=8, warmup=20, alpha=4.0)
        state, w = gm.init()
        rng = np.random.default_rng(0)

        def grads_like(scale):
            return {"a": jnp.asarray(rng.normal(size=(16,)) * scale,
                                     jnp.float32),
                    "b": jnp.asarray(rng.normal(size=(8,)) * scale,
                                     jnp.float32)}

        flags = []
        for i in range(60):
            state, anom, _ = gm.step(state, w, grads_like(1.0),
                                     jnp.asarray(1.0))
            flags.append(bool(anom))
        assert sum(flags) <= 4                       # healthy stream ~clean
        # inject a 1000x gradient spike
        state, anom, _ = gm.step(state, w, grads_like(1000.0),
                                 jnp.asarray(50.0))
        assert bool(anom)

    def test_warmup_never_flags(self):
        gm = GradMonitor(feature_dim=4, warmup=100)
        state, w = gm.init()
        state, anom, _ = gm.step(
            state, w, {"a": jnp.ones((4,)) * 1e6}, jnp.asarray(1e9))
        assert not bool(anom)


class TestDataFilterAndStream:
    def test_stream_determinism_and_restart(self):
        cfg = StreamConfig(vocab_size=100, seq_len=8, global_batch=4, seed=3)
        s1 = DataStream(cfg)
        batches = [next(s1) for _ in range(5)]
        s2 = DataStream(cfg)
        s2.load_state_dict({"step": 3})
        np.testing.assert_array_equal(next(s2)["tokens"],
                                      batches[3]["tokens"])

    def test_filter_catches_poisoned_embeddings(self):
        filt = AceDataFilter(d_model=16, warmup_items=64, alpha=3.0)
        state, w = filt.init()
        rng = np.random.default_rng(0)
        mu = np.ones(16) * 2.0
        # healthy stream: clustered sequence embeddings
        for _ in range(30):
            emb = jnp.asarray(rng.normal(size=(8, 4, 16)) * 0.3 + mu,
                              jnp.float32)
            mask = jnp.ones((8, 4), jnp.float32)
            state, _, kept = filt(state, w, emb, mask)
        # poisoned batch: reversed-direction embeddings
        bad = jnp.asarray(rng.normal(size=(8, 4, 16)) * 0.3 - 3 * mu,
                          jnp.float32)
        state, new_mask, kept = filt(state, w, bad,
                                     jnp.ones((8, 4), jnp.float32))
        assert float(kept) < 0.5
        assert float(new_mask.sum()) < 0.5 * new_mask.size


class TestEndToEnd:
    def test_train_restart_from_checkpoint_is_exact(self, tmp_path):
        """Fault-tolerance core: crash + restore reproduces the same state
        as an uninterrupted run (same data order, same params)."""
        a = Arch("qwen2_1_5b", reduced=True)
        tcfg = TrainConfig(total_steps=20, warmup_steps=2, peak_lr=1e-3,
                           use_data_filter=False, use_grad_monitor=False,
                           ckpt_dir=str(tmp_path), ckpt_interval=5,
                           seed=5)
        scfg = StreamConfig(vocab_size=a.cfg.vocab_size, seq_len=8,
                            global_batch=4, seed=5)
        # continuous 10-step run
        state_a, _ = train(a, tcfg, DataStream(scfg), num_steps=10,
                           log_every=0)
        # interrupted: 7 steps, then a fresh driver restores step 5 + runs 5
        tcfg_b = TrainConfig(**{**tcfg.__dict__,
                                "ckpt_dir": str(tmp_path) + "_b"})
        state_b, _ = train(a, tcfg_b, DataStream(scfg), num_steps=7,
                           log_every=0)
        state_c, _ = train(a, tcfg_b, DataStream(scfg), num_steps=5,
                           log_every=0)   # auto-restores from step 5
        assert int(state_c.step) == 10
        flat_a = jax.tree.leaves(state_a.params)
        flat_c = jax.tree.leaves(state_c.params)
        for x, y in zip(flat_a, flat_c):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-6, atol=1e-6)

    def test_chunked_prefilter_runs_chunks_and_tail(self):
        """filter_chunk=T: chunk branch + tail fallback both produce
        per-step keep fracs, and the filter sketch sees every batch."""
        a = Arch("qwen2_1_5b", reduced=True)
        tcfg = TrainConfig(total_steps=7, warmup_steps=2,
                           use_grad_monitor=False, use_data_filter=True,
                           filter_chunk=3, seed=7)
        scfg = StreamConfig(vocab_size=a.cfg.vocab_size, seq_len=8,
                            global_batch=4, seed=7)
        state, hist = train(a, tcfg, DataStream(scfg), num_steps=7,
                            log_every=0)   # 2 chunks + 1 tail batch
        assert len(hist) == 7
        assert all("filter_keep_frac" in m for m in hist)
        assert int(state.step) == 7
        # every batch (kept or not) advanced the filter's Welford/n stream
        assert float(state.filter_state.n) > 0

    def test_chunked_prefilter_restart_from_checkpoint_is_exact(
            self, tmp_path):
        """Chunk-atomic checkpointing: saves land only on chunk-final
        steps, so crash + restore reproduces the uninterrupted run
        exactly — sketch, stream position and params all consistent."""
        a = Arch("qwen2_1_5b", reduced=True)
        tcfg = TrainConfig(total_steps=20, warmup_steps=2, peak_lr=1e-3,
                           use_data_filter=True, filter_chunk=2,
                           use_grad_monitor=False,
                           ckpt_dir=str(tmp_path), ckpt_interval=2,
                           seed=6)
        scfg = StreamConfig(vocab_size=a.cfg.vocab_size, seq_len=8,
                            global_batch=4, seed=6)
        state_a, _ = train(a, tcfg, DataStream(scfg), num_steps=8,
                           log_every=0)
        tcfg_b = TrainConfig(**{**tcfg.__dict__,
                                "ckpt_dir": str(tmp_path) + "_b"})
        state_b, _ = train(a, tcfg_b, DataStream(scfg), num_steps=5,
                           log_every=0)   # saves land at steps 2 and 4
        state_c, _ = train(a, tcfg_b, DataStream(scfg), num_steps=4,
                           log_every=0)   # auto-restores from step 4
        assert int(state_c.step) == 8
        assert bool(jnp.all(state_a.filter_state.counts ==
                            state_c.filter_state.counts))
        assert float(state_a.filter_state.n) == \
            float(state_c.filter_state.n)
        for x, y in zip(jax.tree.leaves(state_a.params),
                        jax.tree.leaves(state_c.params)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-6, atol=1e-6)

    def test_windowed_filter_chunked_restart_is_exact(self, tmp_path):
        """Sliding-window filter + chunked prefilter: checkpoints carry
        the WHOLE epoch ring (counts, tail, ssq, ring pointer, tick,
        per-epoch moments) and stay chunk-atomic, so crash + restore
        reproduces the uninterrupted run exactly — rotations land at the
        same stream positions either side of the restart."""
        a = Arch("qwen2_1_5b", reduced=True)
        tcfg = TrainConfig(total_steps=20, warmup_steps=2, peak_lr=1e-3,
                           use_data_filter=True, filter_chunk=2,
                           filter_window_epochs=2, filter_rotate_every=2,
                           use_grad_monitor=False,
                           ckpt_dir=str(tmp_path), ckpt_interval=2,
                           seed=9)
        scfg = StreamConfig(vocab_size=a.cfg.vocab_size, seq_len=8,
                            global_batch=4, seed=9)
        state_a, _ = train(a, tcfg, DataStream(scfg), num_steps=8,
                           log_every=0)
        tcfg_b = TrainConfig(**{**tcfg.__dict__,
                                "ckpt_dir": str(tmp_path) + "_b"})
        state_b, _ = train(a, tcfg_b, DataStream(scfg), num_steps=5,
                           log_every=0)   # saves land at steps 2 and 4
        state_c, _ = train(a, tcfg_b, DataStream(scfg), num_steps=4,
                           log_every=0)   # auto-restores from step 4
        assert int(state_c.step) == 8
        ring_a, ring_c = state_a.filter_state, state_c.filter_state
        assert bool(jnp.all(ring_a.counts == ring_c.counts))
        assert bool(jnp.all(ring_a.tail == ring_c.tail))
        assert float(ring_a.ssq) == float(ring_c.ssq)
        assert int(ring_a.cursor) == int(ring_c.cursor)
        assert int(ring_a.tick) == int(ring_c.tick)
        np.testing.assert_array_equal(np.asarray(ring_a.n),
                                      np.asarray(ring_c.n))
        np.testing.assert_allclose(np.asarray(ring_a.welford_m2),
                                   np.asarray(ring_c.welford_m2),
                                   rtol=1e-6)
        for x, y in zip(jax.tree.leaves(state_a.params),
                        jax.tree.leaves(state_c.params)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-6, atol=1e-6)

    def test_monitor_skips_poisoned_step(self):
        """Poisoned batches spike the loss/grads; the monitor must skip at
        least some of them once armed."""
        a = Arch("olmo_1b", reduced=True)
        tcfg = TrainConfig(total_steps=100, warmup_steps=2, peak_lr=1e-3,
                           use_data_filter=False, use_grad_monitor=True,
                           seed=1)
        step_fn = jax.jit(make_train_step(a, tcfg))
        state = init_train_state(a, tcfg, jax.random.PRNGKey(1))
        scfg = StreamConfig(vocab_size=a.cfg.vocab_size, seq_len=16,
                            global_batch=8, seed=1)
        stream = DataStream(scfg)
        for _ in range(30):      # healthy warmup
            b = {k: jnp.asarray(v) for k, v in next(stream).items()
                 if not k.startswith("_")}
            state, m = step_fn(state, b)
        params_before = jax.tree.leaves(state.params)
        # poisoned step: gradient bomb via giant labels mismatch + lr
        bad = next(stream)
        bad_b = {k: jnp.asarray(v) for k, v in bad.items()
                 if not k.startswith("_")}
        bad_b["tokens"] = jnp.zeros_like(bad_b["tokens"])
        bad_b["labels"] = jnp.full_like(bad_b["labels"],
                                        a.cfg.vocab_size - 1)
        state2, m2 = step_fn(state, bad_b)
        # either flagged (params frozen) or absorbed; flag expected
        if float(m2["grad_anomaly"]) == 1.0:
            for x, y in zip(params_before, jax.tree.leaves(state2.params)):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            pytest.skip("monitor did not flag this particular spike "
                        "(threshold is statistical)")
