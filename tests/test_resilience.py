"""Self-healing resilience suite: fault injectors, on-device health
invariants, quarantine/fail-policy semantics, degraded scoring, repair +
re-warm lifecycle, checkpoint integrity, train-loop rollback wiring, and
the end-to-end chaos property (marked ``chaos`` — the CI chaos lane)."""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_allclose_dtype
from repro import resilience as rz
from repro.core import sketch as sk
from repro.core import srp
from repro.core.sketch import AceConfig
from repro.serve.engine import Guardrail, GuardrailConfig
from repro.train import checkpoint as ck
from repro.train.fault import GradMonitor, StepTimer


def _cfg(**kw):
    base = dict(dim=17, num_bits=6, num_tables=8, seed=3,
                welford_min_n=4.0)
    base.update(kw)
    return AceConfig(**base)


def _grown_state(cfg, n_batches=4, batch=16, seed=0):
    state = sk.init(cfg)
    w = sk.make_params(cfg)
    rng = np.random.default_rng(seed)
    for _ in range(n_batches):
        x = jnp.asarray(rng.normal(size=(batch, cfg.dim)), jnp.float32)
        state = sk.insert_buckets(state, srp.hash_buckets(x, w, cfg.srp),
                                  cfg)
    return state, w


def _embeds(rng, batch=32, seq=2, d=16, mu=0.0):
    return (mu + rng.normal(size=(batch, seq, d))).astype(np.float32)


# ---------------------------------------------------------------------------
# Health invariants per state type
# ---------------------------------------------------------------------------

class TestHealthInvariants:
    def test_healthy_flat_state_passes(self):
        state, _ = _grown_state(_cfg())
        rep = jax.device_get(rz.health_check(state))
        assert bool(rep.ok) and rep.table_ok.all() and bool(rep.moments_ok)

    @pytest.mark.parametrize("count_dtype", ["int32", "int16", "float32"])
    def test_bit_flip_localised_to_table(self, count_dtype):
        cfg = _cfg(counter_dtype=count_dtype)
        state, _ = _grown_state(cfg)
        bad = 3
        counts = rz.flip_count_bits(state.counts, jax.random.PRNGKey(0),
                                    num_flips=2, tables=(bad,))
        rep = jax.device_get(rz.health_check(state._replace(counts=counts)))
        table_ok = np.asarray(rep.table_ok, bool)
        assert not table_ok[bad]
        assert table_ok[np.arange(8) != bad].all(), \
            "flip must not implicate healthy tables"
        assert not bool(rep.ok)

    def test_saturation_breaks_conservation(self):
        cfg = _cfg()
        state, _ = _grown_state(cfg)
        counts = rz.saturate_table(state.counts, 5)
        rep = jax.device_get(rz.health_check(state._replace(counts=counts)))
        assert not np.asarray(rep.table_ok, bool)[5]

    @pytest.mark.parametrize("kind", ["nan", "neg"])
    def test_poisoned_moments_flagged(self, kind):
        state, _ = _grown_state(_cfg())
        rep = jax.device_get(rz.health_check(
            rz.poison_moments(state, kind=kind)))
        assert not bool(rep.moments_ok)
        assert np.asarray(rep.table_ok, bool).all(), \
            "moment poison must not implicate the count planes"

    def test_quantized_esc_planes_pass_and_detect(self):
        cfg = _cfg(counter_dtype="int8", esc_capacity=16)
        state, _ = _grown_state(cfg, n_batches=8)
        rep = jax.device_get(rz.health_check(state))
        assert bool(rep.ok)
        counts = rz.flip_count_bits(state.counts, jax.random.PRNGKey(1),
                                    num_flips=4, tables=(2,))
        rep2 = jax.device_get(rz.health_check(
            state._replace(counts=counts)))
        assert not np.asarray(rep2.table_ok, bool)[2]

    def test_windowed_state_checks(self):
        from repro.window import ring
        wcfg = ring.WindowConfig(ace=_cfg(), num_epochs=3, rotate_every=2)
        state = ring.init_window(wcfg)
        w = sk.make_params(wcfg.ace)
        rng = np.random.default_rng(1)
        for i in range(6):
            x = jnp.asarray(rng.normal(size=(8, 17)), jnp.float32)
            b = srp.hash_buckets(x, w, wcfg.ace.srp)
            state = ring.insert_current(state, b,
                                        jnp.ones(8, bool), wcfg.ace)
            state = ring.maybe_rotate(state, 2, 1.0)
        rep = jax.device_get(rz.health_check(state))
        assert bool(rep.ok)
        # corrupt one epoch plane of one table -> that table flagged
        counts = state.counts.at[0, 4, 7].add(
            jnp.asarray(1 << 20, state.counts.dtype))
        rep2 = jax.device_get(rz.health_check(
            state._replace(counts=counts)))
        tok = np.asarray(rep2.table_ok, bool)
        assert not tok[4] and tok[np.arange(8) != 4].all()
        # cursor out of range is a structural failure
        rep3 = jax.device_get(rz.health_check(state._replace(
            cursor=jnp.asarray(99, state.cursor.dtype))))
        assert not bool(rep3.struct_ok)

    def test_fleet_checks_per_tenant(self):
        from repro.fleet import state as fl
        cfg = _cfg()
        fstate = fl.init(fl.FleetConfig(ace=cfg, num_tenants=3))
        w = sk.make_params(cfg)
        rng = np.random.default_rng(2)
        for _ in range(4):
            x = jnp.asarray(rng.normal(size=(12, 17)), jnp.float32)
            tids = jnp.asarray(rng.integers(0, 3, 12), jnp.int32)
            b = srp.hash_buckets(x, w, cfg.srp)
            fstate = fl.insert_masked(fstate, tids, b,
                                      jnp.ones(12, bool), cfg)
        rep = jax.device_get(rz.health_check(fstate))
        assert np.asarray(rep.ok, bool).all()           # (T,) verdicts
        assert np.asarray(rep.table_ok).shape == (3, 8)
        counts = fstate.counts.at[1, 6, 0].add(
            jnp.asarray(7, fstate.counts.dtype))
        rep2 = jax.device_get(rz.health_check(
            fstate._replace(counts=counts)))
        tok = np.asarray(rep2.table_ok, bool)
        assert not tok[1, 6]
        assert tok[0].all() and tok[2].all(), \
            "tenant isolation: corruption in tenant 1 must not flag 0/2"


# ---------------------------------------------------------------------------
# Injectors
# ---------------------------------------------------------------------------

class TestInjectors:
    def test_corrupt_embeddings_marks_rows(self):
        x = jnp.ones((32, 4, 8), jnp.float32)
        for kind in ("nan", "inf", "mixed"):
            y, bad = rz.corrupt_embeddings(x, jax.random.PRNGKey(0),
                                           frac=0.25, kind=kind)
            bad = np.asarray(bad, bool)
            assert 0 < bad.sum() < 32
            finite = np.isfinite(np.asarray(y)).all(axis=(1, 2))
            assert (finite == ~bad).all()

    def test_flip_count_bits_changes_only_target_tables(self):
        state, _ = _grown_state(_cfg())
        flipped = rz.flip_count_bits(state.counts, jax.random.PRNGKey(3),
                                     num_flips=3, tables=(2, 5))
        diff = np.asarray(flipped != state.counts)
        rows = set(np.nonzero(diff)[0].tolist())
        assert rows and rows <= {2, 5}

    def test_stall_step_trips_the_timer(self):
        t = StepTimer(slo_seconds=60.0)
        assert t.tick() is False
        rz.stall_step(t, 120.0)
        assert t.tick() is True and t.breaches == 1


# ---------------------------------------------------------------------------
# Checkpoint integrity
# ---------------------------------------------------------------------------

class TestCheckpointIntegrity:
    def _trees(self):
        return ({"w": jnp.arange(12.0).reshape(3, 4),
                 "n": jnp.asarray(7.0)},
                {"w": jnp.zeros((3, 4)), "n": jnp.zeros(())})

    @pytest.mark.parametrize("mode", ["truncate", "flip"])
    def test_torn_checkpoint_detected_and_fallback_bitwise(
            self, tmp_path, mode):
        tree, like = self._trees()
        d = str(tmp_path)
        ck.save(d, 100, tree, keep=5)
        ck.save(d, 200, {"w": jnp.ones((3, 4)), "n": jnp.asarray(1.0)},
                keep=5)
        rz.tear_checkpoint(d, 200, mode=mode, nbytes=32, seed=0)
        with pytest.raises(ck.CheckpointCorruptError):
            ck.restore(d, 200, like)
        restored, manifest = ck.CheckpointManager(d).restore_latest(like)
        assert manifest["step"] == 100
        assert np.array_equal(np.asarray(restored["w"]),
                              np.arange(12.0).reshape(3, 4))

    def test_crc_catches_silent_leaf_rewrite(self, tmp_path):
        """A leaf whose bytes change with the zip container left intact
        must fail the manifest CRC, not load silently."""
        tree, like = self._trees()
        d = str(tmp_path)
        path = ck.save(d, 7, tree, keep=5)
        npz = os.path.join(path, "arrays.npz")
        with np.load(npz) as z:
            arrays = {k: z[k].copy() for k in z.files}
        arrays["a0"] = arrays["a0"] + 1        # silent value corruption
        np.savez(npz, **arrays)
        with pytest.raises(ck.CheckpointCorruptError, match="CRC"):
            ck.restore(d, 7, like)

    def test_legacy_manifest_without_checksums_restores(self, tmp_path):
        tree, like = self._trees()
        d = str(tmp_path)
        path = ck.save(d, 3, tree, keep=5)
        mp = os.path.join(path, "manifest.json")
        with open(mp) as f:
            man = json.load(f)
        man.pop("checksums")
        with open(mp, "w") as f:
            json.dump(man, f)
        restored, _ = ck.restore(d, 3, like)
        assert float(restored["n"]) == 7.0

    def test_all_corrupt_returns_none(self, tmp_path):
        tree, like = self._trees()
        d = str(tmp_path)
        ck.save(d, 1, tree, keep=5)
        rz.tear_checkpoint(d, 1, mode="truncate")
        restored, manifest = ck.CheckpointManager(d).restore_latest(like)
        assert restored is None and manifest is None


# ---------------------------------------------------------------------------
# Guardrail quarantine + fail policy
# ---------------------------------------------------------------------------

class TestGuardrailQuarantine:
    def _gcfg(self, **kw):
        base = dict(d_model=16, num_bits=6, num_tables=8,
                    warmup_items=64.0)
        base.update(kw)
        return GuardrailConfig(**base)

    def test_quarantined_rows_counted_and_never_inserted(self):
        g = Guardrail(self._gcfg())
        rng = np.random.default_rng(0)
        e = _embeds(rng)
        bad = np.zeros(32, bool)
        bad[[3, 17, 30]] = True
        e[bad] = np.nan
        verdict = g.admit(jnp.asarray(e))
        assert g.quarantined == 3
        assert float(np.asarray(g.state.n)) == 29.0
        assert verdict[bad].all()              # default fail_open
        rep = jax.device_get(rz.health_check(g.state))
        assert bool(rep.ok), "NaN batch must not corrupt the sketch"

    def test_fail_closed_rejects_quarantined(self):
        g = Guardrail(self._gcfg(fail_policy="fail_closed"))
        rng = np.random.default_rng(1)
        e = _embeds(rng)
        e[5] = np.inf
        verdict = g.admit(jnp.asarray(e))
        assert not verdict[5]
        assert verdict[np.arange(32) != 5].all()   # warmup admits finite

    def test_per_tenant_fail_policy(self):
        g = Guardrail(self._gcfg(num_tenants=2,
                                 fail_policy=("fail_open",
                                              "fail_closed")))
        rng = np.random.default_rng(2)
        e = _embeds(rng)
        e[0] = np.nan                               # tenant 0: fail_open
        e[1] = np.nan                               # tenant 1: fail_closed
        tids = np.zeros(32, np.int32)
        tids[1] = 1
        verdict = g.admit(jnp.asarray(e), tenant_ids=tids)
        assert verdict[0] and not verdict[1]
        assert g.quarantined == 2

    def test_invalid_policy_rejected(self):
        with pytest.raises(ValueError, match="fail_policy"):
            Guardrail(self._gcfg(fail_policy="fail_maybe"))
        with pytest.raises(ValueError, match="entries"):
            Guardrail(self._gcfg(num_tenants=3,
                                 fail_policy=("fail_open", "fail_closed")))

    @pytest.mark.parametrize("use_kernels", [False, True])
    def test_dirty_batch_counts_match_clean_subset_oracle(
            self, use_kernels):
        """Feeding a batch with NaN rows must leave EXACTLY the sketch
        that feeding only its finite rows would — the silent fail-open
        bug inserted the garbage rows at one bucket per table."""
        rng = np.random.default_rng(3)
        e = _embeds(rng)
        bad = rng.random(32) < 0.25
        e_dirty = e.copy()
        e_dirty[bad] = np.nan

        g_dirty = Guardrail(self._gcfg(), use_kernels=use_kernels)
        g_clean = Guardrail(self._gcfg(), use_kernels=use_kernels)
        g_dirty.admit(jnp.asarray(e_dirty))
        g_clean.admit(jnp.asarray(e[~bad]))
        assert np.array_equal(np.asarray(g_dirty.state.counts),
                              np.asarray(g_clean.state.counts))
        assert float(np.asarray(g_dirty.state.n)) == \
            float(np.asarray(g_clean.state.n))
        assert_allclose_dtype(g_dirty.state.welford_mean,
                              g_clean.state.welford_mean)


# ---------------------------------------------------------------------------
# Degraded scoring + repair/re-warm lifecycle
# ---------------------------------------------------------------------------

class TestDegradedLifecycle:
    def _serve(self, g, rng, n=1, tenants=None, batch=32):
        for _ in range(n):
            e = jnp.asarray(_embeds(rng, batch=batch))
            if tenants is not None:
                g.admit(e, tenant_ids=rng.integers(
                    0, tenants, batch).astype(np.int32))
            else:
                g.admit(e)

    @pytest.mark.parametrize("flavour", ["flat", "windowed", "fleet",
                                         "fleet_window"])
    def test_corrupt_degrade_repair_rewarm(self, flavour):
        kw = dict(d_model=16, num_bits=6, num_tables=8, warmup_items=32.0)
        if flavour in ("windowed", "fleet_window"):
            kw.update(window_epochs=2, rotate_every=2)
        if flavour in ("fleet", "fleet_window"):
            kw.update(num_tenants=2)
        g = Guardrail(GuardrailConfig(**kw))
        tenants = 2 if "fleet" in flavour else None
        rng = np.random.default_rng(4)
        self._serve(g, rng, n=3, tenants=tenants)
        assert not g.degraded

        counts = rz.flip_count_bits(g.state.counts, jax.random.PRNGKey(9),
                                    num_flips=3, tables=(2,))
        g.state = g.state._replace(counts=counts)
        rep = g.health_check()
        assert g.degraded and not np.asarray(rep.table_ok, bool).all()
        traces_before = g.trace_count
        self._serve(g, rng, n=1, tenants=tenants)     # degraded serving
        assert g.trace_count == traces_before + 1, \
            "degraded mode is ONE extra cached executable"

        g.repair()
        post = jax.device_get(rz.health_check(g.state, g._repair_offsets))
        assert bool(np.asarray(post.table_ok).all()), \
            "repaired tables must satisfy the invariants immediately"
        assert g.degraded, "repaired tables re-warm before serving"
        for _ in range(8):
            self._serve(g, rng, n=1, tenants=tenants)
            g.health_check()
            if not g.degraded:
                break
        assert not g.degraded, "re-warm must finish within one window"
        traces = g.trace_count
        self._serve(g, rng, n=1, tenants=tenants)
        assert g.trace_count == traces, \
            "healthy executable must be reused after recovery"

    def test_masked_scores_ignore_corrupt_tables(self):
        cfg = _cfg()
        state, w = _grown_state(cfg)
        rng = np.random.default_rng(5)
        q = jnp.asarray(rng.normal(size=(8, 17)), jnp.float32)
        b = srp.hash_buckets(q, w, cfg.srp)
        mask = jnp.ones(8, jnp.float32).at[1].set(0.0)
        before = sk.lookup(state, b, table_mask=mask)
        counts = rz.saturate_table(state.counts, 1)
        after = sk.lookup(state._replace(counts=counts), b,
                          table_mask=mask)
        assert np.array_equal(np.asarray(before), np.asarray(after)), \
            "masked table's corruption must be invisible to scoring"


# ---------------------------------------------------------------------------
# StreamRunner + filter sanitization
# ---------------------------------------------------------------------------

class TestRunnerResilience:
    def test_summary_counts_quarantined_and_degraded(self):
        from repro.data.pipeline import AceDataFilter
        from repro.stream.runner import StreamRunner
        filt = AceDataFilter(d_model=16, num_bits=6, num_tables=8,
                             warmup_items=1e9)
        r = StreamRunner(filt, chunk_T=4, topk=4)
        state, w = r.init()
        rng = np.random.default_rng(6)
        feats = rng.normal(size=(4, 8, 17)).astype(np.float32)
        feats[1, 2] = np.nan
        feats[3, 5] = np.inf
        state, summ = r.consume(state, w, jnp.asarray(feats))
        h = jax.device_get(summ)
        assert int(h.quarantined) == 2 and not bool(h.degraded)
        assert float(h.n) == 30.0
        # S3: quarantined rows (margin = −inf) are junk, not "maximally
        # anomalous" — they must NOT hijack top-k slots from genuine
        # rows (the ranking maps −inf to +inf, the least-anomalous end)
        got = {(int(h.topk_step[i]), int(h.topk_item[i]))
               for i in range(4)}
        assert not (got & {(1, 2), (3, 5)})
        mask = jnp.ones(8, jnp.float32).at[0].set(0.0)
        state, summ2 = r.consume(
            state, w,
            jnp.asarray(rng.normal(size=(4, 8, 17)).astype(np.float32)),
            table_mask=mask)
        h2 = jax.device_get(summ2)
        assert bool(h2.degraded) and int(h2.quarantined) == 0
        assert r.trace_count == 2


# ---------------------------------------------------------------------------
# Train-loop wiring: SLO config + monitor-tripped rollback
# ---------------------------------------------------------------------------

class TestTrainLoopResilience:
    def test_step_slo_and_breach_totals(self):
        from repro.data.pipeline import DataStream, StreamConfig
        from repro.models.registry import Arch
        from repro.train.train_loop import TrainConfig, train
        a = Arch("qwen2_1_5b", reduced=True)
        tcfg = TrainConfig(total_steps=3, warmup_steps=1,
                           use_data_filter=False, use_grad_monitor=False,
                           step_slo_seconds=0.0)       # every step breaches
        scfg = StreamConfig(vocab_size=a.cfg.vocab_size, seq_len=8,
                            global_batch=2, seed=11)
        _, hist = train(a, tcfg, DataStream(scfg), num_steps=3,
                        log_every=0)
        assert all(m["straggler_breach"] == 1.0 for m in hist)
        assert hist[-1]["straggler_breaches_total"] == 3.0

    def test_monitor_trip_rolls_back_bounded(self, tmp_path, monkeypatch):
        """Force rollback_needed on every step: the driver must restore
        the newest intact checkpoint at most ``max_rollbacks`` times and
        then continue in skip-updates mode (trip counter cleared)."""
        from repro.data.pipeline import DataStream, StreamConfig
        from repro.models.registry import Arch
        from repro.train.train_loop import TrainConfig, train
        monkeypatch.setattr(
            GradMonitor, "rollback_needed",
            lambda self, st: jnp.ones((), bool))
        a = Arch("qwen2_1_5b", reduced=True)
        tcfg = TrainConfig(total_steps=6, warmup_steps=1,
                           use_data_filter=False, use_grad_monitor=True,
                           ckpt_dir=str(tmp_path), ckpt_interval=1,
                           max_rollbacks=3, seed=12)
        scfg = StreamConfig(vocab_size=a.cfg.vocab_size, seq_len=8,
                            global_batch=2, seed=12)
        state, hist = train(a, tcfg, DataStream(scfg), num_steps=6,
                            log_every=0)
        rollbacks = [m.get("rollback", 0.0) for m in hist]
        # ATTEMPTS are bounded (a restore loop can't run forever): the
        # step-0 trip burns one attempt against an empty ckpt dir
        # (rollback=0), then two restores succeed, then budget is spent.
        assert rollbacks[0] == 0.0, "no checkpoint exists at step 0"
        assert sum(rollbacks) == 2.0, \
            "rollback retries must stop at max_rollbacks"
        assert all(m["rollback_needed"] == 1.0 for m in hist)
        assert len(hist) == 6

    def test_rollback_skips_torn_checkpoint(self, tmp_path, monkeypatch):
        """The rollback path must restore the newest INTACT step when the
        newest checkpoint is torn mid-write."""
        from repro.models.registry import Arch
        from repro.train.train_loop import TrainConfig, init_train_state
        a = Arch("qwen2_1_5b", reduced=True)
        tcfg = TrainConfig(use_data_filter=False, use_grad_monitor=False)
        st = init_train_state(a, tcfg, jax.random.PRNGKey(0))
        d = str(tmp_path)
        ck.save(d, 5, st, extra={"data_step": 5}, keep=5)
        ck.save(d, 10, st, extra={"data_step": 10}, keep=5)
        rz.tear_checkpoint(d, 10, mode="flip", nbytes=64, seed=2)
        restored, manifest = ck.CheckpointManager(d).restore_latest(st)
        assert manifest["step"] == 5 and restored is not None


# ---------------------------------------------------------------------------
# The end-to-end chaos property (CI chaos lane)
# ---------------------------------------------------------------------------

def _cone_embeds(rng, base, batch=32, seq=2, ood_rows=0):
    """In-cone traffic = tight cluster around ``base``; the first
    ``ood_rows`` rows point the opposite way (detectable anomalies)."""
    e = (base + 0.05 * rng.normal(size=(batch, seq, base.shape[-1]))
         ).astype(np.float32)
    if ood_rows:
        e[:ood_rows] = (-base + 0.05 * rng.normal(
            size=(ood_rows, seq, base.shape[-1]))).astype(np.float32)
    return e


@pytest.mark.chaos
class TestChaosProperty:
    def test_fleet_survives_nan_flips_and_torn_checkpoint(
            self, tmp_path, monkeypatch):
        """The acceptance scenario: NaN request batches + ⌈L/4⌉
        bit-flipped tables + one torn checkpoint, against a fault-free
        oracle fed the identical stream.  The fleet must keep serving
        (degraded flag up), healthy-table scores must match the oracle
        exactly, anomaly recall must hold within 0.9× of fault-free, the
        repair must re-converge within one warmup window, and the hot
        path must stay at ONE device→host transfer per admit call."""
        import repro.serve.engine as engine_mod
        L, T, B = 8, 2, 32
        gk = dict(d_model=16, num_bits=6, num_tables=L, num_tenants=T,
                  warmup_items=64.0, alpha=3.0)
        g = Guardrail(GuardrailConfig(**gk))          # chaos victim
        oracle = Guardrail(GuardrailConfig(**gk))     # stream-mirror twin
        ff = Guardrail(GuardrailConfig(**gk))         # fault-free recall ref
        rng = np.random.default_rng(21)
        base = rng.normal(size=16)
        base = 4.0 * base / np.linalg.norm(base)
        tids = rng.integers(0, T, B).astype(np.int32)

        # ---- D2H counter: every admit() pulls exactly one packed block
        transfers = []

        class _CountingNp:
            def __getattr__(self, name):
                return getattr(np, name)

            def asarray(self, x, *a, **k):
                transfers.append(1)
                return np.asarray(x, *a, **k)

        monkeypatch.setattr(engine_mod, "np", _CountingNp())

        def serve(guard, e):
            before = len(transfers)
            v = guard.admit(jnp.asarray(e), tenant_ids=tids)
            assert len(transfers) == before + 1, \
                "hot path must stay at ONE device→host transfer"
            return v

        # ---- warmup: identical clean traffic into all three fleets.
        # ``oracle`` mirrors the victim's effective stream exactly (for
        # score parity); ``ff`` absorbs the eval batches so the recall
        # measurement never perturbs the oracle's insertion history.
        for _ in range(6):
            e = _cone_embeds(rng, base)
            serve(g, e)
            serve(oracle, e)
            serve(ff, e)

        # ---- fault-free recall on a frozen eval stream
        eval_batches = [_cone_embeds(np.random.default_rng(100 + i), base,
                                     ood_rows=8) for i in range(4)]
        ff_rejected = sum(
            int((~serve(ff, e)[:8]).sum()) for e in eval_batches)
        recall_ff = ff_rejected / (8 * len(eval_batches))
        assert recall_ff > 0.5, "reference must actually detect OOD rows"

        # ---- chaos: checkpoint, NaN batches, bit flips, torn newest ckpt
        d = str(tmp_path)
        ck.save(d, 1, g.state, keep=5)
        e = _cone_embeds(rng, base)
        nan_rows = np.zeros(B, bool)
        nan_rows[10:14] = True
        e[nan_rows] = np.nan
        q_before = g.quarantined
        serve(g, e)
        clean = e.copy()
        clean[nan_rows] = _cone_embeds(rng, base, ood_rows=B)[nan_rows]
        v_orc = serve(oracle, clean)
        assert g.quarantined - q_before == 4

        flipped = sorted(rng.choice(L, size=-(-L // 4), replace=False))
        counts = g.state.counts
        for t in flipped:
            counts = rz.flip_count_bits(counts, jax.random.PRNGKey(40 + t),
                                        num_flips=2, tables=(t,))
        g.state = g.state._replace(counts=counts)
        ck.save(d, 2, g.state, keep=5)                # the torn write
        rz.tear_checkpoint(d, 2, mode="truncate")

        rep = g.health_check()
        assert g.degraded
        # every flagged cell belongs to a flipped table
        bad_tables = set(
            np.nonzero(~np.asarray(rep.table_ok, bool))[1].tolist())
        assert bad_tables <= set(flipped) and bad_tables, rep.table_ok

        # ---- healthy-table scores must match the uncorrupted oracle:
        # the NaN batch was quarantined in g and replaced by rows the
        # oracle REJECTED (out-of-cone), so neither state inserted them
        # wherever admits agree — compare masked scores directly.
        assert not bool(np.asarray(v_orc[nan_rows]).any()), \
            "armed oracle must reject the OOD stand-in rows"
        from repro.fleet import state as fl
        probe = jnp.asarray(_cone_embeds(rng, base))
        from repro.data.pipeline import mean_embed_features
        feat = mean_embed_features(probe, 0.25)
        b = srp.hash_buckets(feat, g.w, g.ace_cfg.srp)
        jtids = jnp.asarray(tids)
        mask = g._table_mask
        s_chaos = fl.fleet_scores(g.state, jtids, b, table_mask=mask)
        s_orc = fl.fleet_scores(oracle.state, jtids, b, table_mask=mask)
        assert_allclose_dtype(s_chaos, s_orc)

        # ---- degraded recall on the SAME eval stream ≥ 0.9× fault-free
        chaos_rejected = sum(
            int((~serve(g, e)[:8]).sum()) for e in eval_batches)
        recall_chaos = chaos_rejected / (8 * len(eval_batches))
        assert recall_chaos >= 0.9 * recall_ff, \
            (recall_chaos, recall_ff)

        # ---- torn checkpoint: fallback restores the intact step 1
        restored, manifest = ck.CheckpointManager(d).restore_latest(
            g.state)
        assert manifest["step"] == 1

        # ---- repair + re-warm within one warmup window of traffic
        g.repair()
        assert g.degraded
        # one warmup window of traffic, measured for the SLOWEST tenant:
        # each batch feeds ~bincount(tids) rows per tenant
        min_rows = int(np.bincount(tids, minlength=T).min())
        warmup_batches = int(np.ceil(gk["warmup_items"] / min_rows)) + 2
        for _ in range(warmup_batches):
            serve(g, _cone_embeds(rng, base))
            g.health_check()
            if not g.degraded:
                break
        assert not g.degraded, \
            "repaired fleet must re-converge within one warmup window"
        post = jax.device_get(rz.health_check(g.state, g._repair_offsets))
        assert bool(np.asarray(post.table_ok).all())
        # healthy executable resumed: serving again costs no retrace
        traces = g.trace_count
        serve(g, _cone_embeds(rng, base))
        assert g.trace_count == traces
