"""Property-based invariant suite for the WHOLE sketch algebra.

The repo's layers lean on a growing pile of algebraic claims — inserts
are order-invariant histograms, deletes are exact inverses (paper Eq.
12), merge is the CRDT of a commutative monoid, the masked insert is the
gather-insert in disguise, and the epoch ring is "just" E sketches under
that same monoid.  Each claim used to be spot-checked with a few
hand-enumerated cases; this suite states them as PROPERTIES over random
shapes/batches/masks, so any future refactor of the count algebra has to
survive a hypothesis sweep rather than three lucky examples.

Strategies stay within ``st.integers`` so the suite still collects and
runs under the deterministic hypothesis fallback in ``conftest.py``
(hermetic containers without the real package); sizes are drawn as
integers and the arrays derived from a seeded ``np.random.default_rng``.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from conftest import assert_allclose_dtype
from repro.core import sketch as sk
from repro.core.sketch import AceConfig
from repro.window import ring

jax.config.update("jax_platform_name", "cpu")


def _cfg(K, L, seed=0, min_n=0.0):
    return AceConfig(dim=6, num_bits=K, num_tables=L, seed=seed,
                     welford_min_n=min_n)


def _buckets(rng, B, cfg):
    return jnp.asarray(
        rng.integers(0, cfg.num_buckets, size=(B, cfg.num_tables)),
        jnp.int32)


def _seeded_state(cfg, rng, n_prior=20):
    b = _buckets(rng, n_prior, cfg)
    return sk.insert_buckets(sk.init(cfg), b, cfg)


class TestInsertDelete:
    @settings(max_examples=25, deadline=None)
    @given(B=st.integers(1, 40), K=st.integers(2, 8), L=st.integers(1, 10),
           seed=st.integers(0, 10_000))
    def test_insert_then_delete_is_counts_identity(self, B, K, L, seed):
        """delete_buckets ∘ insert_buckets restores counts, n and the
        exact μ bitwise (Eq. 12: deletes are exact inverses; only the
        one-pass Welford stream is irrecoverable by design)."""
        cfg = _cfg(K, L)
        rng = np.random.default_rng(seed)
        state = _seeded_state(cfg, rng)
        b = _buckets(rng, B, cfg)
        round_trip = sk.delete_buckets(sk.insert_buckets(state, b, cfg),
                                       b, cfg)
        assert bool(jnp.all(round_trip.counts == state.counts))
        assert float(round_trip.n) == float(state.n)
        assert float(sk.mean_mu(round_trip)) == float(sk.mean_mu(state))

    @settings(max_examples=10, deadline=None)
    @given(B=st.integers(1, 24), K=st.integers(2, 6), seed=st.integers(0, 99))
    def test_delete_commutes_with_insert(self, B, K, seed):
        """Deleting batch A after inserting batch X equals inserting X
        after deleting A (counts are an abelian group under ±1)."""
        cfg = _cfg(K, 5)
        rng = np.random.default_rng(seed)
        state = _seeded_state(cfg, rng, n_prior=30)
        a = _buckets(rng, B, cfg)
        x = _buckets(rng, B + 1, cfg)
        one = sk.insert_buckets(sk.delete_buckets(state, a, cfg), x, cfg)
        two = sk.delete_buckets(sk.insert_buckets(state, x, cfg), a, cfg)
        assert bool(jnp.all(one.counts == two.counts))
        assert float(one.n) == float(two.n)


class TestMerge:
    @settings(max_examples=20, deadline=None)
    @given(Ba=st.integers(1, 30), Bb=st.integers(1, 30),
           K=st.integers(2, 7), L=st.integers(1, 8),
           seed=st.integers(0, 10_000))
    def test_merge_commutative(self, Ba, Bb, K, L, seed):
        """merge(a, b) ≡ merge(b, a): counts/n exactly, Welford scalars
        to float tolerance (Chan's rule is symmetric up to rounding)."""
        cfg = _cfg(K, L)
        rng = np.random.default_rng(seed)
        a = sk.insert_buckets(sk.init(cfg), _buckets(rng, Ba, cfg), cfg)
        b = sk.insert_buckets(sk.init(cfg), _buckets(rng, Bb, cfg), cfg)
        ab, ba = sk.merge(a, b), sk.merge(b, a)
        assert bool(jnp.all(ab.counts == ba.counts))
        assert float(ab.n) == float(ba.n)
        assert_allclose_dtype(ab.welford_mean, ba.welford_mean,
                              atol=1e-7)
        assert_allclose_dtype(ab.welford_m2, ba.welford_m2, atol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(Ba=st.integers(1, 20), Bb=st.integers(1, 20),
           Bc=st.integers(1, 20), K=st.integers(2, 6),
           seed=st.integers(0, 10_000))
    def test_merge_associative(self, Ba, Bb, Bc, K, seed):
        cfg = _cfg(K, 6)
        rng = np.random.default_rng(seed)
        parts = [sk.insert_buckets(sk.init(cfg), _buckets(rng, n, cfg), cfg)
                 for n in (Ba, Bb, Bc)]
        left = sk.merge(sk.merge(parts[0], parts[1]), parts[2])
        right = sk.merge(parts[0], sk.merge(parts[1], parts[2]))
        assert bool(jnp.all(left.counts == right.counts))
        assert float(left.n) == float(right.n)
        assert_allclose_dtype(left.welford_mean, right.welford_mean,
                              atol=1e-7)
        assert_allclose_dtype(left.welford_m2, right.welford_m2,
                              atol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(B=st.integers(2, 40), split=st.integers(1, 39),
           K=st.integers(2, 7), seed=st.integers(0, 10_000))
    def test_merge_of_shards_equals_sequential_insertion(self, B, split,
                                                         K, seed):
        """Sharding a batch, sketching each shard fresh, and merging
        equals inserting the whole batch into one sketch — counts/n/μ
        exact (the repro.dist story in one property)."""
        cfg = _cfg(K, 7)
        split = min(split, B - 1)
        rng = np.random.default_rng(seed)
        b = _buckets(rng, B, cfg)
        whole = sk.insert_buckets(sk.init(cfg), b, cfg)
        merged = sk.merge(
            sk.insert_buckets(sk.init(cfg), b[:split], cfg),
            sk.insert_buckets(sk.init(cfg), b[split:], cfg))
        assert bool(jnp.all(whole.counts == merged.counts))
        assert float(whole.n) == float(merged.n)
        assert float(sk.mean_mu(whole)) == float(sk.mean_mu(merged))


class TestMaskedInsert:
    @settings(max_examples=20, deadline=None)
    @given(B=st.integers(1, 40), K=st.integers(2, 7), L=st.integers(1, 8),
           seed=st.integers(0, 10_000))
    def test_all_ones_mask_is_plain_insert(self, B, K, L, seed):
        """insert_buckets_masked with an all-ones mask ≡ insert_buckets:
        counts/n/μ exact; the Welford stream to float summation order
        (the masked path reduces Σ(rates·mask)/b where the dense path
        reduces jnp.mean — same value, different reduction tree; this is
        the documented contract of insert_buckets_masked)."""
        cfg = _cfg(K, L, min_n=float(seed % 3) * 4.0)
        rng = np.random.default_rng(seed)
        state = _seeded_state(cfg, rng)
        b = _buckets(rng, B, cfg)
        masked = sk.insert_buckets_masked(state, b,
                                          jnp.ones((B,), bool), cfg)
        dense = sk.insert_buckets(state, b, cfg)
        assert bool(jnp.all(masked.counts == dense.counts))
        assert float(masked.n) == float(dense.n)
        assert float(sk.mean_mu(masked)) == float(sk.mean_mu(dense))
        assert_allclose_dtype(masked.welford_mean, dense.welford_mean,
                              atol=1e-7)
        assert_allclose_dtype(masked.welford_m2, dense.welford_m2,
                              atol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(B=st.integers(1, 30), K=st.integers(2, 6),
           density=st.integers(0, 10), seed=st.integers(0, 10_000))
    def test_mask_splits_into_two_masked_inserts(self, B, K, density,
                                                 seed):
        """Counts of a masked insert equal the sum of the two
        complementary masked inserts' count deltas (scatter weights are
        additive)."""
        cfg = _cfg(K, 5)
        rng = np.random.default_rng(seed)
        state = _seeded_state(cfg, rng)
        b = _buckets(rng, B, cfg)
        m = jnp.asarray(rng.uniform(size=B) < density / 10.0)
        all_in = sk.insert_buckets_masked(state, b,
                                          jnp.ones((B,), bool), cfg)
        part1 = sk.insert_buckets_masked(state, b, m, cfg)
        part2 = sk.insert_buckets_masked(state, b, ~m, cfg)
        delta = (part1.counts - state.counts) + (part2.counts
                                                 - state.counts)
        assert bool(jnp.all(delta == all_in.counts - state.counts))


class TestWindowRing:
    @settings(max_examples=10, deadline=None)
    @given(E=st.integers(1, 5), B=st.integers(1, 20), K=st.integers(2, 6),
           seed=st.integers(0, 10_000))
    def test_rotate_pow_E_is_zeroed_ring(self, E, B, K, seed):
        """rotate^E ≡ the all-zero init (every epoch expired once), with
        the cursor back where it started — counts, tail, ssq, n and the
        per-epoch Welford moments all cleared."""
        cfg = _cfg(K, 4)
        rng = np.random.default_rng(seed)
        st_ = ring.init(cfg, E)
        for _ in range(3):
            st_ = ring.insert_current(st_, _buckets(rng, B, cfg),
                                      jnp.ones((B,), bool), cfg)
            st_ = ring.maybe_rotate(st_, 2, 1.0)
        cursor0 = int(st_.cursor)
        for _ in range(E):
            st_ = ring.rotate(st_)
        assert int(st_.cursor) == cursor0
        assert int(jnp.sum(jnp.abs(st_.counts))) == 0
        assert float(jnp.sum(jnp.abs(st_.tail))) == 0.0
        assert float(st_.ssq) == 0.0
        assert float(jnp.sum(st_.n)) == 0.0
        assert float(jnp.sum(jnp.abs(st_.welford_mean))) == 0.0
        assert float(jnp.sum(jnp.abs(st_.welford_m2))) == 0.0

    @settings(max_examples=10, deadline=None)
    @given(E=st.integers(2, 5), B=st.integers(1, 16), K=st.integers(2, 6),
           steps=st.integers(1, 12), seed=st.integers(0, 10_000))
    def test_ring_total_equals_flat_sketch_of_window(self, E, B, K,
                                                     steps, seed):
        """Hard window, no expiry yet (fewer steps than the window
        spans): the ring's combined counts equal ONE flat sketch fed the
        same batches — windowing changes nothing until something
        expires."""
        cfg = _cfg(K, 4)
        rng = np.random.default_rng(seed)
        R = 3
        # stay strictly inside the window span: the E·R-th insert's
        # rotation is the FIRST expiry
        steps = min(steps, E * R - 1)
        st_ = ring.init(cfg, E)
        flat = sk.init(cfg)
        for _ in range(steps):
            b = _buckets(rng, B, cfg)
            m = jnp.asarray(rng.uniform(size=B) < 0.7)
            st_ = ring.insert_current(st_, b, m, cfg)
            st_ = ring.maybe_rotate(st_, R, 1.0)
            flat = sk.insert_buckets_masked(flat, b, m, cfg)
        assert bool(jnp.all(
            ring.decayed_counts(st_, 1.0) ==
            flat.counts.astype(jnp.float32)))
        assert float(ring.combined_n(st_, 1.0)) == float(flat.n)
        c = flat.counts.astype(jnp.float32)
        assert float(st_.ssq) == float(jnp.sum(c * c))

    @settings(max_examples=8, deadline=None)
    @given(E=st.integers(1, 4), B=st.integers(1, 12), K=st.integers(2, 5),
           seed=st.integers(0, 10_000))
    def test_insert_order_invariance_within_epoch(self, E, B, K, seed):
        """Within one epoch, inserting batch A then B equals B then A on
        counts/tail/ssq (the monoid property lifted to the ring)."""
        cfg = _cfg(K, 4)
        rng = np.random.default_rng(seed)
        st0 = ring.init(cfg, E)
        a = _buckets(rng, B, cfg)
        b = _buckets(rng, B + 1, cfg)
        ones_a = jnp.ones((B,), bool)
        ones_b = jnp.ones((B + 1,), bool)
        ab = ring.insert_current(
            ring.insert_current(st0, a, ones_a, cfg), b, ones_b, cfg)
        ba = ring.insert_current(
            ring.insert_current(st0, b, ones_b, cfg), a, ones_a, cfg)
        assert bool(jnp.all(ab.counts == ba.counts))
        assert float(ab.ssq) == float(ba.ssq)
        assert float(jnp.sum(ab.n)) == float(jnp.sum(ba.n))
