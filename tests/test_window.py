"""Sliding-window ACE tests: epoch-ring algebra (rotation, tail/ssq
streams, windowed moments), degenerate-case bitwise contracts, the
stream runner's in-scan rotation (chunk ≡ sequential, no retraces, no
extra transfers), the windowed guardrail, dist-layout parity on a fake
2-device mesh, and checkpoint round-tripping of the ring state."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_allclose_dtype
from repro.core import sketch as sk
from repro.core import srp
from repro.data.pipeline import AceDataFilter
from repro.stream import StreamRunner
from repro.window import ring
from repro.window import WindowConfig, WindowedAceFilter

jax.config.update("jax_platform_name", "cpu")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg(**kw):
    base = dict(dim=10, num_bits=6, num_tables=8, seed=3,
                welford_min_n=8.0)
    base.update(kw)
    return sk.AceConfig(**base)


def _buckets(rng, B, cfg):
    return jnp.asarray(
        rng.integers(0, cfg.num_buckets, size=(B, cfg.num_tables)),
        jnp.int32)


def _embeds(rng, B=8, S=4, D=16, scale=0.3, mu=2.0):
    return jnp.asarray(rng.normal(size=(B, S, D)) * scale + mu, jnp.float32)


# ---------------------------------------------------------------------------
# Ring algebra: maintained tail/ssq vs recompute oracles.
# ---------------------------------------------------------------------------

class TestRingAlgebra:
    @pytest.mark.parametrize("gamma", [1.0, 0.7])
    def test_tail_and_ssq_match_direct_recompute(self, gamma):
        """The maintained tail (Σ_{e≠cur} γ^age C_e) and ssq (‖C_w‖²)
        streams equal a from-scratch recompute after any interleaving of
        masked inserts and rotations — bitwise for γ=1 (exact integer
        f32), float-tolerance for γ<1 (error also γ-decays)."""
        cfg = _cfg()
        rng = np.random.default_rng(0)
        st = ring.init(cfg, 4)
        for i in range(25):
            b = _buckets(rng, 9, cfg)
            m = jnp.asarray(rng.uniform(size=9) < 0.6)
            st = ring.insert_current(st, b, m, cfg, gamma=gamma)
            st = ring.maybe_rotate(st, 3, gamma)
            dc = np.asarray(ring.decayed_counts(st, gamma))
            want_tail = dc - np.asarray(ring.live_epoch(st).counts,
                                        dtype=np.float32)
            want_ssq = float(np.sum(dc * dc))
            if gamma == 1.0:
                assert np.array_equal(np.asarray(st.tail), want_tail), i
                assert float(st.ssq) == want_ssq, i
            else:
                assert_allclose_dtype(st.tail, want_tail, atol=1e-4)
                assert_allclose_dtype(st.ssq, want_ssq, rtol=1e-4)

    def test_rotate_pow_E_is_zeroed_ring(self):
        cfg = _cfg()
        rng = np.random.default_rng(1)
        st = ring.init(cfg, 3)
        for _ in range(4):
            st = ring.insert_current(st, _buckets(rng, 7, cfg),
                                     jnp.ones((7,), bool), cfg)
        cursor0 = int(st.cursor)
        for _ in range(3):
            st = ring.rotate(st)
        assert int(st.cursor) == cursor0
        assert int(jnp.sum(jnp.abs(st.counts))) == 0
        assert float(jnp.sum(jnp.abs(st.tail))) == 0.0
        assert float(st.ssq) == 0.0
        assert float(jnp.sum(st.n)) == 0.0
        assert float(jnp.sum(jnp.abs(st.welford_m2))) == 0.0

    def test_hard_window_equals_merge_of_epochs(self):
        """γ=1, one batch per epoch: the window is sketch.merge of the
        epochs — counts/n exact, μ via the γ-generalised closed form."""
        cfg = _cfg()
        rng = np.random.default_rng(2)
        st = ring.init(cfg, 3)
        for e in range(3):
            st = ring.insert_current(st, _buckets(rng, 7, cfg),
                                     jnp.ones((7,), bool), cfg)
            if e < 2:
                st = ring.rotate(st)
        acc = ring.combined_ace(st)
        q = _buckets(rng, 5, cfg)
        got = ring.score_windowed(st, q, 1.0)
        want = sk.batch_scores(acc.counts.astype(jnp.float32), q)
        assert_allclose_dtype(got, want)
        assert_allclose_dtype(ring.mean_mu_windowed(st, 1.0),
                              sk.mean_mu(acc))
        assert float(ring.combined_n(st, 1.0)) == float(acc.n)

    @pytest.mark.parametrize("gamma", [1.0, 0.5])
    def test_score_hot_path_matches_eway_reference(self, gamma):
        """tail+live scoring (the hot path) ≡ the E-way query-time
        combine at the ring's own γ — bitwise for the hard window."""
        cfg = _cfg()
        rng = np.random.default_rng(3)
        st = ring.init(cfg, 4)
        for _ in range(9):
            st = ring.insert_current(st, _buckets(rng, 6, cfg),
                                     jnp.ones((6,), bool), cfg,
                                     gamma=gamma)
            st = ring.maybe_rotate(st, 2, gamma)
        q = _buckets(rng, 11, cfg)
        hot = ring.score_combined(st, q)
        ref = ring.score_windowed(st, q, gamma)
        if gamma == 1.0:
            assert bool(jnp.all(hot == ref))
        else:
            assert_allclose_dtype(hot, ref, rtol=1e-5)

    def test_window_config_validation(self):
        with pytest.raises(ValueError, match="num_epochs"):
            WindowConfig(ace=_cfg(), num_epochs=0)
        with pytest.raises(ValueError, match="decay"):
            WindowConfig(ace=_cfg(), decay=0.0)
        with pytest.raises(ValueError, match="decay"):
            WindowConfig(ace=_cfg(), decay=1.5)
        assert WindowConfig(ace=_cfg(), num_epochs=4).memory_bytes() > \
            4 * _cfg().memory_bytes()


# ---------------------------------------------------------------------------
# Degenerate case: E=1 window ≡ the flat sketch, bitwise.
# ---------------------------------------------------------------------------

class TestSingleEpochIsFlatSketch:
    def test_filter_step_bitwise(self):
        """WindowedAceFilter(num_epochs=1) ≡ AceDataFilter step for step:
        same keep/margin decisions, same counts, same Welford scalars,
        same admit threshold — bitwise."""
        fw = WindowedAceFilter(d_model=12, num_bits=6, num_tables=8,
                               warmup_items=16.0, alpha=3.0, num_epochs=1)
        ff = AceDataFilter(d_model=12, num_bits=6, num_tables=8,
                           warmup_items=16.0, alpha=3.0)
        ws, w1 = fw.init()
        fs, w2 = ff.init()
        assert np.array_equal(np.asarray(w1), np.asarray(w2))
        rng = np.random.default_rng(4)
        for i in range(8):
            feat = jnp.asarray(rng.normal(size=(8, 13)) + 1.0, jnp.float32)
            ws, kw, mw = fw.step(ws, w1, feat)
            fs, kf, mf = ff.step(fs, w2, feat)
            assert bool(jnp.all(kw == kf)), i
            assert bool(jnp.all(mw == mf)), i
            live = ring.live_epoch(ws)
            assert bool(jnp.all(live.counts == fs.counts)), i
            assert float(live.n) == float(fs.n)
            assert float(live.welford_mean) == float(fs.welford_mean), i
            assert float(live.welford_m2) == float(fs.welford_m2), i
            assert float(ring.admit_threshold_windowed(
                ws, 1.0, 3.0, 16.0)) == \
                float(sk.admit_threshold(fs, 3.0, 16.0)), i

    def test_ssq_equals_flat_mu_numerator(self):
        """E=1 ssq stream ≡ the flat sketch's fresh Σ‖A‖² reduction
        (both exact integers inside the f32 envelope)."""
        cfg = _cfg()
        rng = np.random.default_rng(5)
        st = ring.init(cfg, 1)
        flat = sk.init(cfg)
        for _ in range(6):
            b = _buckets(rng, 9, cfg)
            m = jnp.asarray(rng.uniform(size=9) < 0.7)
            st = ring.insert_current(st, b, m, cfg)
            flat = sk.insert_buckets_masked(flat, b, m, cfg)
        c = flat.counts.astype(jnp.float32)
        assert float(st.ssq) == float(jnp.sum(c * c))


# ---------------------------------------------------------------------------
# StreamRunner: rotation inside the donated program.
# ---------------------------------------------------------------------------

class TestWindowedStreamRunner:
    def _filter(self, **kw):
        base = dict(d_model=16, num_bits=7, num_tables=12,
                    warmup_items=64.0, alpha=3.0, num_epochs=3,
                    rotate_every=4)
        base.update(kw)
        return WindowedAceFilter(**base)

    def test_chunk_equals_sequential_with_rotation(self):
        """One scan chunk (rotations at in-chunk segment boundaries) ≡
        T per-batch calls (rotations via the eager maybe_rotate clock):
        counts/tail/ssq/cursor/tick bitwise, masks included."""
        filt = self._filter()
        rng = np.random.default_rng(6)
        T = 12
        embeds = [_embeds(rng) for _ in range(T)]
        embeds[-1] = _embeds(rng, mu=-6.0)
        s_seq, w = filt.init()
        keeps_seq = []
        for e in embeds:
            m = jnp.ones((e.shape[0], e.shape[1]), jnp.float32)
            s_seq, new_mask, _frac = filt(s_seq, w, e, m)
            keeps_seq.append(new_mask[:, 0] > 0)

        runner = StreamRunner(filt, chunk_T=T, return_masks=True)
        s_run, w2 = runner.init()
        feats = jnp.stack([filt.features(e) for e in embeds])
        s_run, _summary, keeps = runner.consume(s_run, w2, feats)

        assert bool(jnp.all(s_run.counts == s_seq.counts))
        assert bool(jnp.all(s_run.tail == s_seq.tail))
        assert float(s_run.ssq) == float(s_seq.ssq)
        assert int(s_run.cursor) == int(s_seq.cursor)
        assert int(s_run.tick) == int(s_seq.tick)
        assert_allclose_dtype(s_run.welford_m2, s_seq.welford_m2,
                              rtol=1e-5)
        for t in range(T):
            assert bool(jnp.all(keeps[t] == keeps_seq[t])), t

    def test_rotate_every_multiple_of_chunk(self):
        """R a multiple of T: rotations land on chunk boundaries via one
        tick-gated clock per chunk — still equivalent to sequential."""
        filt = self._filter(rotate_every=8)
        runner = StreamRunner(filt, chunk_T=4, return_masks=True)
        s_run, w = runner.init()
        s_seq, _ = filt.init()
        rng = np.random.default_rng(7)
        feats = jnp.stack([filt.features(_embeds(rng)) for _ in range(12)])
        for c in range(3):
            chunk = feats[c * 4:(c + 1) * 4]
            s_run, _s, _k = runner.consume(s_run, w, chunk)
            for t in range(4):
                s_seq, _keep, _m = filt.step(s_seq, w, chunk[t])
                s_seq = ring.maybe_rotate(s_seq, 8, 1.0)
        assert bool(jnp.all(s_run.counts == s_seq.counts))
        assert int(s_run.cursor) == int(s_seq.cursor)
        assert runner.trace_count == 1

    def test_unaligned_rotate_every_rejected(self):
        with pytest.raises(ValueError, match="rotate_every"):
            StreamRunner(self._filter(rotate_every=7), chunk_T=10)

    def test_flat_filter_with_rotate_every_rejected(self):
        with pytest.raises(ValueError, match="windowed"):
            StreamRunner(AceDataFilter(d_model=8), chunk_T=4,
                         rotate_every=2)

    def test_rotation_adds_no_retraces_or_transfers(self, monkeypatch):
        """The windowed runner with in-scan rotation stays ONE compiled
        executable across chunks, and the host driver still pulls
        exactly one D2H per chunk — rotation costs zero extra syncs."""
        filt = self._filter()
        runner = StreamRunner(filt, chunk_T=4)
        state, w = runner.init()
        pulls = []
        orig = jax.device_get

        def counting(x):
            pulls.append(1)
            return orig(x)

        monkeypatch.setattr(jax, "device_get", counting)
        rng = np.random.default_rng(8)
        batches = [np.asarray(filt.features(_embeds(rng)))
                   for _ in range(12)]
        state, summaries = runner.run(state, w, batches)
        assert len(summaries) == 3
        assert len(pulls) == 3, \
            f"{len(pulls)} D2H pulls for 3 chunks (want exactly 1 each)"
        assert runner.trace_count == 1
        # rotations actually happened on schedule: 12 steps / R=4
        assert int(state.tick) == 12
        assert int(state.cursor) == 0      # 3 rotations mod E=3

    def test_summary_n_is_ring_total(self):
        filt = self._filter()
        runner = StreamRunner(filt, chunk_T=4)
        state, w = runner.init()
        rng = np.random.default_rng(9)
        feats = jnp.stack([filt.features(_embeds(rng)) for _ in range(4)])
        state, summary = runner.consume(state, w, feats)
        assert float(summary.n) == float(jnp.sum(state.n))

    @pytest.mark.slow
    def test_sharded_layouts_match_single_device(self):
        """Windowed scan ingest under repro.dist placements (jit/SPMD):
        replicated and table-sharded epoch rings must match the
        single-device runner bitwise on counts/tail/cursor (fake
        2-device CPU mesh in a subprocess)."""
        code = """
            import jax, jax.numpy as jnp, numpy as np
            from repro.window import WindowedAceFilter
            from repro.stream import StreamRunner

            filt = WindowedAceFilter(d_model=8, num_bits=6, num_tables=10,
                                     warmup_items=16.0, alpha=3.0,
                                     num_epochs=3, rotate_every=2)
            rng = np.random.default_rng(0)
            feats = jnp.asarray(rng.normal(size=(6, 16, 9)) + 1.0,
                                jnp.float32)

            base = StreamRunner(filt, chunk_T=6)
            s0, w = base.init()
            s_ref, _ = base.consume(s0, w, feats)

            mesh = jax.make_mesh((1, 2), ("data", "model"))
            for layout in ("replicated", "table_sharded"):
                r = StreamRunner(filt, chunk_T=6, mesh=mesh,
                                 sketch_layout=layout)
                s, w2 = r.init()
                s, _ = r.consume(s, w2, feats)
                assert np.array_equal(
                    np.asarray(jax.device_get(s.counts)),
                    np.asarray(jax.device_get(s_ref.counts))), layout
                assert np.array_equal(
                    np.asarray(jax.device_get(s.tail)),
                    np.asarray(jax.device_get(s_ref.tail))), layout
                assert int(s.cursor) == int(s_ref.cursor), layout
                assert float(jnp.sum(s.n)) == float(jnp.sum(s_ref.n))
                np.testing.assert_allclose(
                    float(s.ssq), float(s_ref.ssq), rtol=1e-6)

            # shard_map-mode E-way windowed score builder: per-epoch
            # partials psum BEFORE the gamma weighting, so it matches
            # the replicated combine bitwise for every gamma
            from repro.dist.sketch_parallel import \\
                make_table_sharded_window_score
            from repro.window import ring, epoch_weights, score_windowed
            cfg = filt.ace_cfg
            q = jnp.asarray(rng.normal(size=(8, cfg.dim)), jnp.float32)
            for gamma in (1.0, 0.6):
                wts = epoch_weights(s_ref.cursor, 3, gamma)
                scr = make_table_sharded_window_score(mesh, cfg)
                got = scr(s_ref.counts, wts, q, w)
                import repro.core.srp as srp
                want = score_windowed(
                    s_ref, srp.hash_buckets(q, w, cfg.srp), gamma)
                assert np.array_equal(np.asarray(got),
                                      np.asarray(want)), gamma
            print("WINDOW-LAYOUTS-MATCH")
        """
        env = dict(os.environ)
        env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=2 "
                            + env.get("XLA_FLAGS", ""))
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                             capture_output=True, text=True, timeout=420,
                             env=env)
        assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
        assert "WINDOW-LAYOUTS-MATCH" in out.stdout


# ---------------------------------------------------------------------------
# Windowed guardrail + kernel-path admission.
# ---------------------------------------------------------------------------

class TestWindowedGuardrail:
    def _gcfg(self):
        from repro.serve.engine import GuardrailConfig
        return GuardrailConfig(d_model=12, num_bits=6, num_tables=8,
                               warmup_items=32.0, alpha=3.0,
                               window_epochs=3, rotate_every=4)

    def test_one_executable_and_ring_advances(self):
        from repro.serve.engine import Guardrail
        g = Guardrail(self._gcfg())
        rng = np.random.default_rng(10)
        for _ in range(9):
            admit = g.admit(_embeds(rng, D=12))
        assert g.trace_count == 1
        assert int(g.state.tick) == 9
        assert int(g.state.cursor) == 2          # 2 rotations, E=3
        assert admit.shape == (8,)

    def test_kernel_path_matches_jnp_windowed_sequence(self):
        """ops.ace_admit_windowed (SRHT/dense hash dispatch + fused
        E-way combine kernel + shared ring helpers) reproduces the jnp
        windowed admission sequence: same masks, same counts/tail."""
        from repro.kernels import ops
        cfg = _cfg()
        w = sk.make_params(cfg)
        rng = np.random.default_rng(11)
        st_k = st_j = ring.init(cfg, 3)
        for i in range(6):
            q = jnp.asarray(rng.normal(size=(16, cfg.dim)) + 1.0,
                            jnp.float32)
            st_k, mk = ops.ace_admit_windowed(
                st_k, q, w, cfg, gamma=0.8, alpha=2.0,
                warmup_items=16.0, rotate_every=2)
            b = srp.hash_buckets(q, w, cfg.srp)
            ts, ls = ring.window_table_sums(st_j, b)
            s = ring.score_live(ts, ls, cfg.num_tables)
            mj = s >= ring.admit_threshold_windowed(st_j, 0.8, 2.0, 16.0)
            st_j = ring.insert_current(st_j, b, mj, cfg, gamma=0.8,
                                       pre_sums=(ts, ls))
            st_j = ring.maybe_rotate(st_j, 2, 0.8)
            assert bool(jnp.all(mk == mj)), i
        assert bool(jnp.all(st_k.counts == st_j.counts))
        assert_allclose_dtype(st_k.tail, st_j.tail, rtol=1e-6)

    def test_windowed_guardrail_recovers_from_traffic_shift(self):
        """After a regime shift, the frozen guardrail keeps rejecting the
        new inlier traffic forever (it can never re-learn: rejects are
        not inserted); the windowed guardrail's stale epochs expire, its
        window drains below warmup, and it re-admits + re-learns."""
        from repro.serve.engine import Guardrail, GuardrailConfig
        common = dict(d_model=12, num_bits=8, num_tables=16,
                      warmup_items=64.0, alpha=2.0)
        frozen = Guardrail(GuardrailConfig(**common))
        windowed = Guardrail(GuardrailConfig(
            **common, window_epochs=3, rotate_every=6))
        rng = np.random.default_rng(12)
        mu_a = np.zeros(12); mu_a[:6] = 3.0
        mu_b = np.zeros(12); mu_b[6:] = 3.0

        def batch(mu):
            return jnp.asarray(
                rng.normal(size=(16, 4, 12)) * 0.3 + mu, jnp.float32)

        fa, wa = [], []
        for _ in range(20):                      # regime A
            fa.append(frozen.admit(batch(mu_a)).mean())
            wa.append(windowed.admit(batch(mu_a)).mean())
        # both armed and admitting the in-distribution traffic (the
        # windowed σ is tighter, so allow the odd borderline flag)
        assert np.mean(fa[-5:]) > 0.8 and np.mean(wa[-5:]) > 0.7
        f_admit, w_admit = [], []
        for i in range(30):                      # regime B
            f_admit.append(frozen.admit(batch(mu_b)).mean())
            w_admit.append(windowed.admit(batch(mu_b)).mean())
        # frozen never recovers; windowed re-admits after the window
        # (3 epochs × 6 calls) has drained the stale regime
        assert np.mean(f_admit[-5:]) < 0.2, f_admit
        assert np.mean(w_admit[-5:]) > 0.8, w_admit


# ---------------------------------------------------------------------------
# Checkpoint round-tripping of the ring state.
# ---------------------------------------------------------------------------

class TestWindowCheckpoint:
    def test_ring_state_roundtrips_exactly(self, tmp_path):
        """save → restore reproduces every leaf of the ring bitwise —
        cursor and tick (int32 scalars) included."""
        from repro.train import checkpoint as ck
        cfg = _cfg()
        rng = np.random.default_rng(13)
        st = ring.init(cfg, 3)
        for _ in range(5):
            st = ring.insert_current(st, _buckets(rng, 9, cfg),
                                     jnp.ones((9,), bool), cfg)
            st = ring.maybe_rotate(st, 2, 1.0)
        ck.save(str(tmp_path), 1, st)
        like = jax.tree.map(jnp.zeros_like, st)
        restored, _manifest = ck.restore(str(tmp_path), 1, like)
        for got, want in zip(restored, st):
            assert np.asarray(got).dtype == np.asarray(want).dtype
            assert np.array_equal(np.asarray(got), np.asarray(want))
        # restore hands back host arrays (device placement is the
        # caller's shardings choice) — put them back on device to resume
        restored = jax.tree.map(jnp.asarray, restored)
        assert int(restored.cursor) == int(st.cursor)
        assert int(restored.tick) == int(st.tick)
        # the restored ring keeps operating identically
        b = _buckets(rng, 9, cfg)
        m = jnp.ones((9,), bool)
        a = ring.insert_current(restored, b, m, cfg)
        bb = ring.insert_current(st, b, m, cfg)
        assert bool(jnp.all(a.counts == bb.counts))
        assert float(a.ssq) == float(bb.ssq)
