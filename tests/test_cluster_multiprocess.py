"""Two REAL ``jax.distributed`` processes: KV/gossip plumbing and the
chaos host-kill/re-shard acceptance test.

The chaos property (ISSUE 8): T=16 tenants sharded over 2 processes;
SIGKILL-equivalent death of one host mid-stream must
  * keep the surviving shard serving throughout (its tenants' final
    states and per-batch verdicts stay PARITY-EXACT with a never-failed
    oracle — tenant isolation + ownership masking),
  * re-home the dead host's tenants from its last gossiped snapshot
    within one epoch of stream loss, and
  * hold post-rejoin detection recall at >= 0.9x the fault-free run.

The oracle is a same-process replay of each tenant's exact batch
sequence (deterministic by (tenant, index)) through the same
fleet-filter program — per-tenant streams are bitwise independent of
chunk grouping, so any interleaving that preserves per-tenant batch
order must match bitwise.

Everything here spawns subprocesses (jax.distributed needs real
processes) — minutes, not seconds; the CI fast lane skips it.
"""
import json
import os
import socket
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

pytestmark = [pytest.mark.slow, pytest.mark.cluster]


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _spawn(code: str, env_extra: dict) -> subprocess.Popen:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.update(env_extra)
    return subprocess.Popen([sys.executable, "-c", textwrap.dedent(code)],
                            stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True, env=env)


def _run_pair(code: str, tmp, timeout=420, expect_rc=(0, 0)):
    """Run ``code`` in 2 jax.distributed processes (ACE_PROC selects
    the role).  Returns (stdout0, stdout1)."""
    coord = f"127.0.0.1:{_free_port()}"
    procs = [_spawn(code, {"ACE_PROC": str(i), "ACE_COORD": coord,
                           "ACE_TMP": str(tmp)}) for i in range(2)]
    outs = []
    for i, p in enumerate(procs):
        try:
            out, err = p.communicate(timeout=timeout)
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            raise
        assert p.returncode == expect_rc[i], \
            f"proc {i} rc={p.returncode}\nstderr:\n{err[-4000:]}"
        outs.append(out)
    return outs


_BOOT = """
import json, os, sys, time
import numpy as np
pid = int(os.environ["ACE_PROC"])
tmp = os.environ["ACE_TMP"]
import jax
jax.distributed.initialize(coordinator_address=os.environ["ACE_COORD"],
                           num_processes=2, process_id=pid)
import jax.numpy as jnp
from repro.cluster import (ClusterConfig, ClusterNode, DistributedStore,
                           GossipBus, MembershipConfig, pack_snapshot,
                           unpack_snapshot)

def wait_key(store, key, tries=400):
    for _ in range(tries):
        v = store.get(key)
        if v is not None:
            return v
    raise RuntimeError("timeout waiting for " + key)
"""

# the chaos stream generator — ONE definition shared (verbatim) by the
# workers and the in-driver oracle, so both replay identical batches
_GEN = """
B, D = 16, 8

def tenant_batch(t, idx):
    # clustered inliers + scattered anomalies — the same structure as
    # repro.data.synthetic: ACE flags NOVEL directions, so anomalies
    # must be scattered (unique per row), not a recurring offset
    rng = np.random.default_rng(1 + 7919 * t + idx)
    crng = np.random.default_rng(555 + t)
    centers = crng.normal(size=(3, D)).astype(np.float32)
    centers *= 6.0 / np.linalg.norm(centers, axis=1, keepdims=True)
    assign = rng.integers(0, 3, size=B)
    x = (centers[assign]
         + 0.5 * rng.normal(size=(B, D))).astype(np.float32)
    y = np.zeros(B, bool)
    if idx >= 6 and idx % 3 == 0:        # anomaly burst every 3rd batch
        y[:4] = True
        d = rng.normal(size=(4, D))
        d /= np.linalg.norm(d, axis=1, keepdims=True)
        x[:4] = (8.0 * d
                 + 0.3 * rng.normal(size=(4, D))).astype(np.float32)
    return x, y
"""


class TestKvGossipTwoProcs:
    def test_kv_roundtrip_and_gossip_fetch(self, tmp_path):
        code = _BOOT + _GEN + """
from repro.fleet.filter import FleetDataFilter

store = DistributedStore()
filt = FleetDataFilter(d_model=D, num_tenants=4, num_bits=5,
                       num_tables=4, warmup_items=16.0, insert_all=True,
                       count_dtype="int8")
state, w = filt.init()
if pid == 0:
    for t in range(4):
        x, _ = tenant_batch(t, 0)
        feat = filt.features(jnp.asarray(x[:, None, :]))
        state, _, _ = filt.step(state, w, feat,
                                jnp.full((B,), t, jnp.int32))
    host = jax.device_get(state)
    bus = GossipBus(store, "h0")
    nbytes = bus.publish(1, host, [0, 1, 2, 3])
    assert nbytes > 0
    store.set("k1", "v1")
    store.set("ready0", "1")
    wait_key(store, "done1")
    np.save(os.path.join(tmp, "pub_counts.npy"), host.counts)
else:
    wait_key(store, "ready0")
    assert store.get("k1") == "v1"
    assert store.get("missing/key") is None
    epoch, states, _ = GossipBus(store, "h1").latest("h0")
    assert epoch == 1 and set(states) == {0, 1, 2, 3}
    assert states[0].counts.dtype == np.int8
    assert float(sum(states[t].n for t in range(4))) == 4.0 * B
    np.save(os.path.join(tmp, "got_counts.npy"),
            np.stack([states[t].counts for t in range(4)]))
    store.set("done1", "1")
print("OK", pid)
"""
        _run_pair(code, tmp_path)
        pub = np.load(tmp_path / "pub_counts.npy")
        got = np.load(tmp_path / "got_counts.npy")
        assert np.array_equal(pub, got)      # cross-process bitwise


_CHAOS_WORKER = _BOOT + _GEN + """
HOSTS = ("h0", "h1")
T = 16
cfg = ClusterConfig(host_id=HOSTS[pid], hosts=HOSTS, num_tenants=T,
                    d_model=D, num_bits=5, num_tables=4, alpha=2.0,
                    warmup_items=48.0, insert_all=True, chunk_T=8,
                    epoch_chunks=2, ckpt_root=os.path.join(tmp, "ckpt"),
                    ckpt_every_epochs=1, ckpt_keep=3,
                    membership=MembershipConfig(heartbeat_interval=0.05,
                                                failure_timeout=0.6))
store = DistributedStore()
node = ClusterNode(cfg, store)

counters = {t: 0 for t in range(T)}
step_no = 0
served = []       # (tenant, idx) per scan step, in order
keeps_log = []    # matching (B,) keep rows

def run_chunk():
    global step_no
    owned = node.owned()
    embeds = np.zeros((cfg.chunk_T * B, 1, D), np.float32)
    tids = np.zeros((cfg.chunk_T, B), np.int32)
    meta = []
    for j in range(cfg.chunk_T):
        t = owned[(step_no + j) % len(owned)]
        idx = counters[t]; counters[t] += 1
        x, _ = tenant_batch(t, idx)
        embeds[j * B:(j + 1) * B, 0, :] = x
        tids[j] = t
        meta.append((t, idx))
    step_no += cfg.chunk_T
    feats = np.asarray(node.filt.features(jnp.asarray(embeds)))
    feats = feats.reshape(cfg.chunk_T, B, D + 1)
    _, keeps = node.ingest_chunk(feats, tids)
    for (t, idx), k in zip(meta, np.asarray(keeps)):
        served.append((t, idx)); keeps_log.append(k)

# chunk 1 doubles as program compile; sync AFTER it so the failure
# detector's clock only runs once both hosts are past compilation
run_chunk()
store.set("warm/%s" % cfg.host_id, "1")
wait_key(store, "warm/%s" % HOSTS[1 - pid])

if pid == 1:
    for _ in range(10):                  # chunks 2..11: die mid-epoch 6
        run_chunk()
        node.control_step()
        time.sleep(0.05)
    sys.stdout.flush()
    os._exit(137)                        # SIGKILL-equivalent: no cleanup

n_adopt_seen = 0
for loop in range(200):
    run_chunk()
    node.control_step()
    for rec in node.adoptions[n_adopt_seen:]:   # resume adopted streams
        counters[rec["tenant"]] = int(round(rec["n"] / B))
        n_adopt_seen += 1
    time.sleep(0.03)
    if len(node.owned()) == T:
        adopted = [a["tenant"] for a in node.adoptions]
        if adopted and all(counters[t] >= 16 for t in adopted):
            break
else:
    raise RuntimeError("h1 death never produced a full adoption")

node.control_step()
surv = sorted(set(range(T)) - {a["tenant"] for a in node.adoptions})
qx = np.random.default_rng(424242).normal(size=(B, D)).astype(np.float32)
qf = np.asarray(node.filt.features(jnp.asarray(qx[:, None, :])))
probe = np.stack([node.probe_scores(qf, np.full(B, t, np.int32))
                  for t in surv])
final = jax.device_get(node.state)
np.savez(os.path.join(tmp, "h0_result.npz"),
         counts=final.counts, n=final.n, mean=final.welford_mean,
         m2=final.welford_m2,
         served_t=np.array([t for t, _ in served], np.int32),
         served_i=np.array([i for _, i in served], np.int32),
         keeps=np.stack(keeps_log), probe=probe,
         surv=np.array(surv, np.int32))
with open(os.path.join(tmp, "h0_result.json"), "w") as f:
    json.dump({"adoptions": node.adoptions, "epoch": node.epoch,
               "map_version": node.map.version,
               "gossip_bytes": node.gossip.published_bytes}, f)
print("H0 DONE")
sys.stdout.flush()
# skip jax.distributed's atexit shutdown barrier: the dead peer can
# never join it, and the client aborts the process when it fails —
# the fleet itself already proved it outlives the death
os._exit(0)
"""


class TestChaosHostKill:
    def test_host_kill_reshard_parity_and_recall(self, tmp_path):
        outs = _run_pair(_CHAOS_WORKER, tmp_path, expect_rc=(0, 137))
        assert "H0 DONE" in outs[0]
        res = np.load(tmp_path / "h0_result.npz")
        with open(tmp_path / "h0_result.json") as f:
            meta = json.load(f)

        # ---- adoption happened, from gossip, within one epoch --------
        adopted = {a["tenant"]: a for a in meta["adoptions"]}
        surv = set(res["surv"].tolist())
        assert surv and adopted
        assert surv | set(adopted) == set(range(16))
        assert not (surv & set(adopted))
        for rec in adopted.values():
            assert rec["source"] == "gossip"
            assert rec["source_epoch"] == 5       # h1's last boundary
            # h1 died 1 chunk (= half an epoch) past its last publish:
            # 10 of its 11 absorbed batches survive in the snapshot
            assert rec["n"] == 10.0 * 16
        assert meta["map_version"] == 1
        assert meta["gossip_bytes"] > 0

        # ---- replay the never-failed oracle --------------------------
        ns: dict = {"np": np}
        exec(textwrap.dedent(_GEN), ns)
        tenant_batch = ns["tenant_batch"]

        import jax
        import jax.numpy as jnp
        from repro.core import srp
        from repro.fleet import state as fl
        from repro.fleet.filter import FleetDataFilter
        from repro.stream.runner import StreamRunner

        filt = FleetDataFilter(d_model=8, num_tenants=16, num_bits=5,
                               num_tables=4, alpha=2.0,
                               warmup_items=48.0, insert_all=True)
        runner = StreamRunner(filt, chunk_T=1, return_masks=True)
        state, w = runner.init()
        served = list(zip(res["served_t"].tolist(),
                          res["served_i"].tolist()))
        max_idx = {}
        for t, i in served:
            max_idx[t] = max(max_idx.get(t, -1), i)
        # adopted tenants: indices 0..9 ran on h1 (lost log); the
        # resume point proves h0 replays them from the snapshot state
        oracle_keeps = {}
        for t in range(16):
            for idx in range(max_idx[t] + 1):
                x, _ = tenant_batch(t, idx)
                feats = filt.features(jnp.asarray(x[:, None, :]))[None]
                state, _, k = runner.consume(
                    state, w, feats, jnp.full((1, 16), t, jnp.int32))
                oracle_keeps[(t, idx)] = np.asarray(k)[0]
        oracle = jax.device_get(state)

        # ---- survivor parity: state bitwise, probe scores exact ------
        for t in surv:
            assert np.array_equal(res["counts"][t], oracle.counts[t])
            assert res["n"][t] == oracle.n[t]
            assert res["mean"][t] == oracle.welford_mean[t]
            assert res["m2"][t] == oracle.welford_m2[t]
        qx = np.random.default_rng(424242).normal(
            size=(16, 8)).astype(np.float32)
        qf = filt.features(jnp.asarray(qx[:, None, :]))
        buckets = srp.hash_buckets(qf, w, filt.ace_cfg.srp)
        for row, t in zip(res["probe"], sorted(surv)):
            ref = np.asarray(fl.fleet_scores(
                jax.tree.map(jnp.asarray, oracle),
                jnp.full(16, t, jnp.int32), buckets))
            assert np.array_equal(row, ref)

        # ---- adopted-tenant state parity (seamless resume) -----------
        for t in adopted:
            assert np.array_equal(res["counts"][t], oracle.counts[t])
            assert res["n"][t] == oracle.n[t]

        # ---- per-batch verdict parity for every batch h0 served ------
        for (t, i), keep in zip(served, res["keeps"]):
            assert np.array_equal(keep.astype(bool),
                                  oracle_keeps[(t, i)].astype(bool)), \
                f"verdict mismatch tenant {t} batch {i}"

        # ---- recall: faulted run >= 0.9x fault-free ------------------
        def recall(keep_lookup, pairs):
            flagged = total = 0
            for t, i in pairs:
                _, y = tenant_batch(t, i)
                if not y.any():
                    continue
                k = np.asarray(keep_lookup(t, i), bool)
                flagged += int((~k[y]).sum())
                total += int(y.sum())
            return flagged / max(total, 1), total

        kill_idx = 11                       # h1 died serving batch 11
        post = [(t, i) for (t, i) in served
                if t in adopted and i >= 10]       # what h0 re-served
        faulted = {(t, i): k for (t, i), k in zip(served, res["keeps"])}
        r_fault, n_fault = recall(lambda t, i: faulted[(t, i)], post)
        oracle_post = [(t, i) for t in adopted
                       for i in range(kill_idx, max_idx[t] + 1)]
        r_free, n_free = recall(
            lambda t, i: oracle_keeps[(t, i)], oracle_post)
        assert n_fault > 0 and n_free > 0   # bursts actually measured
        assert r_free > 0                   # detector detects at all
        assert r_fault >= 0.9 * r_free


class TestAutotuneCacheAcrossProcesses:
    _CHILD = """
import os, sys, time
import jax.numpy as jnp
from repro.kernels import runtime as rt

def bench(c):
    time.sleep(0.004 if c != 16 else 0.0)
    return jnp.zeros(())

mode = sys.argv[1] if len(sys.argv) > 1 else os.environ["ACE_MODE"]
if mode == "tune":
    print(rt.autotune("xproc", (64, 64), True, [8, 16, 32], bench,
                      reps=1))
else:
    # no bench_fn: only a persisted winner can beat the first candidate
    print(rt.autotune("xproc", (64, 64), True, [8, 16, 32], None))
"""

    def _child(self, tmp, mode):
        return _spawn(self._CHILD, {"REPRO_AUTOTUNE_CACHE_DIR": str(tmp),
                                    "ACE_MODE": mode})

    def test_winner_shared_between_processes(self, tmp_path):
        p = self._child(tmp_path, "tune")
        out, err = p.communicate(timeout=180)
        assert p.returncode == 0, err[-2000:]
        assert out.strip() == "16"
        q = self._child(tmp_path, "read")
        out, err = q.communicate(timeout=180)
        assert q.returncode == 0, err[-2000:]
        assert out.strip() == "16"          # read from the shared file

    def test_concurrent_tuners_no_torn_files(self, tmp_path):
        procs = [self._child(tmp_path, "tune") for _ in range(3)]
        for p in procs:
            out, err = p.communicate(timeout=180)
            assert p.returncode == 0, err[-2000:]
            assert out.strip() == "16"
        files = [f for f in os.listdir(tmp_path)
                 if f.startswith("tune_")]
        assert len(files) == 1
        with open(tmp_path / files[0]) as f:
            blob = json.load(f)             # valid JSON: never torn
        assert blob["winner"] == 16
