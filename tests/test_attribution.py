"""Heavy-hitter attribution tier tests (repro.attribution).

Covers the full tentpole surface:

* count-sketch recovery — planted heavy coordinates are ALL named by
  the dyadic ``find_hh`` drill-down, point estimates respect the
  Charikar ‖v‖₂·√(8/C) bound, non-power-of-two dims never leak padded
  coordinates;
* the Pallas ``attr_estimate`` kernel against its ``ref.py`` oracle
  (odd AND even R — the two median conventions) and the jnp
  ``estimate_level`` path;
* state wiring — merge linearity (count sketches are linear), window
  rotation zeroing, cursor-row-only observation for window and
  fleet×window states;
* runner integration — attribution rides the ONE jitted consume
  program (trace_count == 1), fleet-of-1 is bitwise the flat path,
  an all-quarantined chunk reports ``topk_valid`` all-False (the
  garbage-rows bugfix) without poisoning the attribution planes;
* the falpha saturation bugfix — quantized int8 planes with overflow
  promotion report the SAME moment index as int32 planes (densified),
  where the raw narrow plane provably diverges.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_allclose_dtype
from repro import attribution as at
from repro.attribution import AttrConfig
from repro.core import sketch as sk
from repro.core.sketch import AceConfig
from repro.data.pipeline import AceDataFilter
from repro.fleet.filter import FleetDataFilter
from repro.kernels import ops
from repro.kernels.ref import attr_estimate_ref
from repro.stream import StreamRunner
from repro.window import ring

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# Count-sketch recovery
# ---------------------------------------------------------------------------

class TestCountSketchRecovery:
    def test_point_estimates_within_theory_bound(self):
        """Each leaf estimate errs ≤ ‖v‖₂·√(8/C) (Charikar bound; the
        median over R=5 rows makes per-coordinate failure unlikely
        enough that we assert the bound over ALL coordinates)."""
        cfg = AttrConfig(dim=64, rows=5, bits=8)
        rng = np.random.default_rng(0)
        v = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
        plane = at.sketch_vector(cfg, v)
        est = at.estimate_level(cfg, plane, jnp.arange(64, dtype=jnp.int32),
                                cfg.num_levels - 1)
        bound = float(jnp.linalg.norm(v)) * np.sqrt(8.0 / cfg.width)
        err = np.abs(np.asarray(est) - np.asarray(v))
        assert err.max() <= bound, (err.max(), bound)

    def test_find_hh_names_all_planted_heavies(self):
        """The acceptance criterion in miniature: every planted heavy
        coordinate is named, signs preserved, valid lanes only."""
        cfg = AttrConfig(dim=64, rows=5, bits=8)
        rng = np.random.default_rng(1)
        planted = {3: 10.0, 17: -12.0, 41: 9.0}
        v = rng.normal(size=(64,)).astype(np.float32) * 0.1
        for c, m in planted.items():
            v[c] = m
        coords, ests, valid = at.find_hh(cfg, at.sketch_vector(
            cfg, jnp.asarray(v)), topk=3)
        coords, ests, valid = map(np.asarray, (coords, ests, valid))
        assert valid.all()
        assert set(coords.tolist()) == set(planted)
        for c, e in zip(coords, ests):
            assert np.sign(e) == np.sign(planted[int(c)]), (c, e)
            assert abs(e - planted[int(c)]) <= 2.0, (c, e)

    def test_find_hh_non_power_of_two_dim(self):
        """dim=37 pads to 64 leaves; padded coordinates must never
        surface as valid heavy hitters."""
        cfg = AttrConfig(dim=37, rows=5, bits=7)
        rng = np.random.default_rng(2)
        v = rng.normal(size=(37,)).astype(np.float32) * 0.05
        v[36] = 8.0                       # heavy at the LAST real coord
        v[5] = -7.0
        coords, _, valid = at.find_hh(cfg, at.sketch_vector(
            cfg, jnp.asarray(v)), topk=4)
        coords, valid = np.asarray(coords), np.asarray(valid)
        assert (coords[valid] < 37).all(), coords
        assert {36, 5} <= set(coords[valid].tolist())

    def test_l2estimate_tracks_norm(self):
        cfg = AttrConfig(dim=64, rows=5, bits=8)
        rng = np.random.default_rng(3)
        v = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
        l2 = at.l2estimate(at.sketch_vector(cfg, v))
        assert l2.shape == (cfg.num_levels,)
        true = float(jnp.linalg.norm(v))
        # every level sketches the same mass; the leaf is the headline
        assert abs(float(l2[-1]) - true) <= 0.3 * true

    def test_sketch_linearity(self):
        """sketch(a + b) == sketch(a) + sketch(b) — the property merge
        and the two-channel accumulation rest on."""
        cfg = AttrConfig(dim=32, rows=4, bits=6)
        rng = np.random.default_rng(4)
        a = jnp.asarray(rng.normal(size=(32,)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(32,)), jnp.float32)
        assert_allclose_dtype(at.sketch_vector(cfg, a + b),
                              at.sketch_vector(cfg, a)
                              + at.sketch_vector(cfg, b))


# ---------------------------------------------------------------------------
# Kernel vs oracle vs jnp path
# ---------------------------------------------------------------------------

class TestAttrEstimateKernel:
    @pytest.mark.parametrize("R", [1, 2, 3, 4, 5, 8])
    @pytest.mark.parametrize("B", [1, 7, 64])
    def test_kernel_matches_oracle(self, R, B):
        """Pallas gather+median ≡ the numpy-style oracle for odd R
        (middle order statistic) AND even R (midpoint)."""
        C = 64
        rng = np.random.default_rng(R * 100 + B)
        plane = jnp.asarray(rng.normal(size=(R, C)), jnp.float32)
        cols = jnp.asarray(rng.integers(0, C, size=(B, R)), jnp.int32)
        signs = jnp.asarray(rng.choice([-1.0, 1.0], size=(B, R)),
                            jnp.float32)
        got = ops.attr_estimate(plane, cols, signs, interpret=True)
        want = attr_estimate_ref(plane, cols, signs)
        assert got.shape == (B,)
        assert_allclose_dtype(got, want)

    def test_estimate_dispatch_matches_jnp_level_path(self):
        """cfg-table estimates: the kernel batch entry point
        (attribution.estimate) ≡ estimate_level at the leaf level."""
        cfg = AttrConfig(dim=48, rows=5, bits=7)
        rng = np.random.default_rng(9)
        v = jnp.asarray(rng.normal(size=(48,)), jnp.float32)
        plane = at.sketch_vector(cfg, v)
        coords = jnp.asarray(rng.integers(0, 48, size=(16,)), jnp.int32)
        got = at.estimate(cfg, plane, coords, interpret=True)
        want = at.estimate_level(cfg, plane, coords, cfg.num_levels - 1)
        assert_allclose_dtype(got, want)


# ---------------------------------------------------------------------------
# State wiring
# ---------------------------------------------------------------------------

_ACFG = AceConfig(dim=13, num_bits=5, num_tables=6, attr_rows=3,
                  attr_bits=5)


class TestStateWiring:
    def test_flat_merge_adds_attr_planes(self):
        """Count sketches are linear: merged state attr == sum."""
        rng = np.random.default_rng(10)
        a = sk.init(_ACFG)._replace(attr=jnp.asarray(
            rng.normal(size=_ACFG.attr.plane_shape()), jnp.float32))
        b = sk.init(_ACFG)._replace(attr=jnp.asarray(
            rng.normal(size=_ACFG.attr.plane_shape()), jnp.float32))
        m = sk.merge(a, b)
        assert_allclose_dtype(m.attr, a.attr + b.attr)
        with pytest.raises(ValueError):
            sk.merge(a, b._replace(attr=None))

    def test_window_rotate_zeroes_only_new_live_row(self):
        E = 4
        st = ring.init(_ACFG, E)
        filled = st._replace(attr=jnp.ones_like(st.attr))
        rot = ring.rotate(filled)
        new_cursor = int(rot.cursor)
        attr = np.asarray(rot.attr)
        assert (attr[new_cursor] == 0).all()
        for e in range(E):
            if e != new_cursor:
                assert (attr[e] == 1).all(), e

    def test_observe_window_touches_cursor_row_only(self):
        E = 3
        st = ring.init(_ACFG, E)
        plane = jnp.ones(_ACFG.attr.plane_shape(), jnp.float32)
        out = at.observe_window(st.attr, plane, jnp.int32(1))
        out = np.asarray(out)
        assert (out[1] == 1).all()
        assert (out[0] == 0).all() and (out[2] == 0).all()

    def test_observe_fleet_window_per_tenant_cursors(self):
        acfg = _ACFG.attr
        T, E = 3, 4
        attr = jnp.zeros((T, E) + acfg.plane_shape(), jnp.float32)
        planes = jnp.stack([jnp.full(acfg.plane_shape(), float(t + 1))
                            for t in range(T)])
        cursor = jnp.asarray([0, 2, 3], jnp.int32)
        out = np.asarray(at.observe_fleet_window(attr, planes, cursor))
        for t, c in enumerate([0, 2, 3]):
            assert (out[t, c] == t + 1).all()
            mask = np.ones(E, bool)
            mask[c] = False
            assert (out[t, mask] == 0).all()


# ---------------------------------------------------------------------------
# Runner integration
# ---------------------------------------------------------------------------

def _stream(rng, CT, B, d, mu=2.0, scale=0.3):
    return jnp.asarray(rng.normal(size=(CT, B, d + 1)) * scale + mu,
                       jnp.float32)


class TestRunnerAttribution:
    D, CT, B = 16, 4, 8

    def _flat(self):
        return AceDataFilter(d_model=self.D, num_bits=5, num_tables=8,
                             warmup_items=16.0, alpha=3.0, attr_rows=5,
                             attr_bits=6)

    def test_single_program_and_summary_fields(self):
        filt = self._flat()
        runner = StreamRunner(filt, chunk_T=self.CT, topk=4)
        state, w = runner.init()
        assert state.attr is not None
        rng = np.random.default_rng(20)
        for _ in range(3):
            feats = _stream(rng, self.CT, self.B, self.D)
            state, summary = runner.consume(state, w, feats)
        assert runner.trace_count == 1
        s = jax.device_get(summary)
        assert s.hh_coord.shape == (4,) and s.hh_est.shape == (4,)
        assert s.hh_valid.shape == (4,) and s.topk_valid.shape == (4,)
        assert (np.asarray(s.hh_coord) < self.D + 1).all()
        # background traffic observed → channel 0 accumulated energy
        assert float(jnp.sum(jnp.abs(state.attr[0]))) > 0.0

    def test_fleet_of_one_bitwise_flat(self):
        """Acceptance criterion: attribution for a fleet of 1 ≡ the
        single-tenant path, bitwise — hh outputs AND the attr planes."""
        flat = self._flat()
        fleet = FleetDataFilter(d_model=self.D, num_tenants=1,
                                num_bits=5, num_tables=8,
                                warmup_items=16.0, alpha=3.0,
                                attr_rows=5, attr_bits=6)
        r1 = StreamRunner(flat, chunk_T=self.CT, topk=4)
        rf = StreamRunner(fleet, chunk_T=self.CT, topk=4)
        s1, w1 = r1.init()
        sf, wf = rf.init()
        tids = jnp.zeros((self.CT, self.B), jnp.int32)
        rng = np.random.default_rng(21)
        for i in range(3):
            feats = _stream(rng, self.CT, self.B, self.D,
                            mu=2.0 if i < 2 else -5.0)
            s1, sum1 = r1.consume(s1, w1, feats)
            sf, sumf = rf.consume(sf, wf, feats, tids)
            np.testing.assert_array_equal(np.asarray(sum1.hh_coord),
                                          np.asarray(sumf.hh_coord))
            np.testing.assert_array_equal(np.asarray(sum1.hh_est),
                                          np.asarray(sumf.hh_est))
            np.testing.assert_array_equal(np.asarray(sum1.hh_valid),
                                          np.asarray(sumf.hh_valid))
        np.testing.assert_array_equal(np.asarray(s1.attr),
                                      np.asarray(sf.attr[0]))

    def test_all_quarantined_chunk_topk_valid_false(self):
        """The garbage-rows bugfix: a fully-quarantined chunk must
        report topk_valid all-False (hosts mask on it instead of
        consuming padding), count every row quarantined, and leave the
        sketch AND attribution planes untouched."""
        filt = self._flat()
        runner = StreamRunner(filt, chunk_T=self.CT, topk=4)
        state, w = runner.init()
        rng = np.random.default_rng(22)
        for _ in range(2):                       # arm the filter
            state, _ = runner.consume(
                state, w, _stream(rng, self.CT, self.B, self.D))
        n_before = float(state.n)
        attr_before = np.asarray(state.attr)
        dirty = jnp.full((self.CT, self.B, self.D + 1), jnp.nan,
                         jnp.float32)
        state, summary = runner.consume(state, w, dirty)
        s = jax.device_get(summary)
        assert not s.topk_valid.any(), s.topk_valid
        assert int(s.quarantined) == self.CT * self.B
        assert float(state.n) == n_before
        # −inf margins exclude quarantined rows from BOTH channels:
        # the chunk contributes zero energy, planes bitwise unchanged
        np.testing.assert_array_equal(np.asarray(state.attr), attr_before)
        assert runner.trace_count == 1

    def test_partially_anomalous_chunk_topk_valid_mask(self):
        """topk_valid is True exactly on genuinely-flagged rows: a
        chunk with one poisoned step flags B rows; with topk > B the
        remaining lanes are padding and must read False."""
        filt = self._flat()
        runner = StreamRunner(filt, chunk_T=self.CT, topk=self.B + 4)
        state, w = runner.init()
        rng = np.random.default_rng(23)
        for _ in range(2):
            state, _ = runner.consume(
                state, w, _stream(rng, self.CT, self.B, self.D))
        feats = np.array(_stream(rng, self.CT, self.B, self.D))
        feats[2] = np.asarray(_stream(rng, 1, self.B, self.D,
                                      mu=-6.0))[0]
        state, summary = runner.consume(state, w, jnp.asarray(feats))
        s = jax.device_get(summary)
        nvalid = int(s.topk_valid.sum())
        assert 0 < nvalid <= self.B
        # valid lanes lead (most-anomalous-first ordering)
        assert s.topk_valid[:nvalid].all()
        assert not s.topk_valid[nvalid:].any()
        assert (s.topk_step[s.topk_valid] == 2).all()

    def test_windowed_runner_attr_rides_ring(self):
        from repro.window.filter import WindowedAceFilter
        filt = WindowedAceFilter(d_model=self.D, num_bits=5,
                                 num_tables=8, warmup_items=16.0,
                                 alpha=3.0, num_epochs=3, rotate_every=2,
                                 attr_rows=4, attr_bits=6)
        runner = StreamRunner(filt, chunk_T=self.CT, topk=4)
        state, w = runner.init()
        assert state.attr.shape[0] == 3
        rng = np.random.default_rng(24)
        for _ in range(3):
            state, summary = runner.consume(
                state, w, _stream(rng, self.CT, self.B, self.D))
        assert runner.trace_count == 1
        assert jax.device_get(summary).hh_coord.shape == (4,)
        # rotation zeroed expired epochs; the live row carries energy
        live = int(state.cursor)
        assert float(jnp.sum(jnp.abs(state.attr[live]))) > 0.0


# ---------------------------------------------------------------------------
# falpha over quantized planes (saturation bugfix)
# ---------------------------------------------------------------------------

class TestFalphaQuantizedDensified:
    def test_int8_esc_matches_int32_past_saturation(self):
        """Differential acceptance test: the SAME concentrated stream
        through an int8+escalation filter and an int32 filter must
        report the SAME falpha once buckets saturate — and the raw
        narrow plane must provably understate it (the bug)."""
        from repro.core import quantize as qz
        from repro.quantile import falpha_index
        D, CT, B = 12, 4, 16
        mk = dict(d_model=D, num_bits=4, num_tables=4,
                  warmup_items=1e9, alpha=3.0)
        f8 = AceDataFilter(count_dtype="int8", esc_capacity=64, **mk)
        f32 = AceDataFilter(**mk)
        r8 = StreamRunner(f8, chunk_T=CT)
        r32 = StreamRunner(f32, chunk_T=CT)
        s8, w8 = r8.init()
        s32, w32 = r32.init()
        rng = np.random.default_rng(30)
        # near-identical items hammer the same buckets: 10 chunks ×
        # 64 items ≫ int8 max 127 per bucket
        base = rng.normal(size=(1, 1, D + 1)).astype(np.float32)
        for _ in range(10):
            feats = jnp.asarray(
                base + 0.01 * rng.normal(size=(CT, B, D + 1)),
                jnp.float32)
            s8, sum8 = r8.consume(s8, w8, feats)
            s32, sum32 = r32.consume(s32, w32, feats)
        assert int(jnp.max(s32.counts)) > 127, "stream failed to saturate"
        dense = qz.densify(s8.counts, s8.esc)
        np.testing.assert_array_equal(np.asarray(dense),
                                      np.asarray(s32.counts))
        assert_allclose_dtype(sum8.falpha, sum32.falpha)
        # the raw narrow plane diverges at the saturation boundary —
        # this is what the summary used to report
        raw = float(falpha_index(s8.counts, s8.n))
        assert raw < float(sum32.falpha), (raw, float(sum32.falpha))
